"""Training runtimes.

``DenseTrainer`` — any model whose parameters are all dense (LM, GNN):
podded replicas + k-step Adam; per-pod batches; static local/merge
executables; checkpoint/restart; optional delayed (asynchronous) merge
application (``merge_delay``).

``HybridTrainer`` — the paper's CTR/recsys regime: dense tower under k-step
Adam + giant sparse tables owned by an ``EmbeddingEngine``.  Algorithm 1's
pull -> train -> push runs as TWO compiled stages behind a pluggable
``EmbeddingBackend``: a PULL stage (dedup + gather/route/cache admission)
and a TRAIN+PUSH stage (fwd/bwd on the working set, k-step Adam, row-update
scatter).  The split is what enables the paper's Fig. 5 pipeline: with
``TrainerConfig.prefetch`` the trainer dispatches batch t+1's pull right
after batch t's train stage is queued (``repro.core.prefetch``), so under
JAX async dispatch the pull overlaps the step still executing — and the
hand-off of the pull's returned ``(tables, accum, state)`` trees serializes
the cache tier's spills, keeping prefetched training bit-identical to
synchronous training.  Checkpoints are only written at commit boundaries
(never with a pull in flight); ``save`` enforces this loudly.

The hot path never blocks the host: ``train_step`` returns the loss as a
device array and accumulates the overflow counter on-device; Python floats
materialize only at ``log_every``/checkpoint boundaries (``fit`` history
values are plain floats as before).  ``sparse_metrics`` reports PER-INTERVAL
deltas (since the previous logging boundary) with whole-run cumulative
values under ``*_total`` keys.

Construct trainers directly, or — config-driven — through
``repro.runtime.factory.build_trainer(arch_name, TrainerConfig)``, which
wires models, engines, and placements from the ``repro.configs`` registry.

Both runtimes implement the fault-tolerance contract:
- crash-consistent checkpoints (atomic dirs) at a configurable cadence,
  including the int8 error-feedback residual when ``merge="int8_ef"``,
- ``resume()`` picks up the newest complete checkpoint (mesh-independent),
- the k-step merge is the only cross-pod sync point,
- ``merge_delay > 0`` (DenseTrainer) applies each merge's cross-pod average
  ``merge_delay`` boundaries late, preserving the local drift since its
  snapshot (DCN latency hiding; the in-flight merge queue is not
  checkpointed — a restart resumes with an empty queue).

Config knobs are never silently ignored: a trainer that cannot honor
``prefetch``/``merge_delay``/``merge_quorum`` raises at construction.
"""

from __future__ import annotations

import collections
import dataclasses
import os
import shutil
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, latest_step, read_manifest
from repro.core.embedding_engine import EmbeddingEngine
from repro.core.kstep import KStepAdam, KStepConfig, pod_replicate, pod_slice
from repro.core.prefetch import PrefetchingEngine
from repro.core.sparse_optim import SparseAdagradConfig

Pytree = Any


@dataclasses.dataclass
class TrainerConfig:
    n_pod: int = 1
    kstep: KStepConfig = dataclasses.field(default_factory=KStepConfig)
    sparse: SparseAdagradConfig = dataclasses.field(default_factory=SparseAdagradConfig)
    placement: str = "gather"     # sparse backend: "gather"|"routed"|"cached"
    capacity: Optional[int] = None  # working-set bound (None: arch default)
    cache_rows: Optional[int] = None  # device cache size for "cached"
                                      # (None: arch default; must be >= capacity)
    prefetch: bool = False        # double-buffered pull prefetch
                                  # (HybridTrainer only; Fig. 5 overlap)
    fused_kernels: Optional[bool] = None  # fused Pallas sparse pull/push +
                                          # bag (HybridTrainer only).  None =
                                          # auto: on for a real TPU backend,
                                          # off elsewhere (ops.resolve_fused)
    store: str = "host"           # cold tier: "host" (resident tables) |
                                  # "disk" (paged spill dir; HybridTrainer)
    spill_dir: Optional[str] = None   # page directory (required for "disk")
    page_rows: Optional[int] = None   # rows per page file (None: 1024)
    page_cache_pages: Optional[int] = None  # RAM page-cache capacity
                                            # (None: unbounded full mirror)
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 200
    ckpt_keep: int = 3
    ckpt_async: bool = True
    merge_quorum: float = 1.0     # reserved: only 1.0 (all pods) implemented
    merge_delay: int = 0          # async merge application lag, in merges
                                  # (DenseTrainer only)
    log_every: int = 50
    donate: bool = True


def _reject_dead_knobs(cfg: TrainerConfig, trainer: str, merge_delay_ok: bool):
    """No-silent-config contract: a documented knob either works or raises —
    it is never accepted and ignored."""
    if cfg.merge_quorum != 1.0:
        raise NotImplementedError(
            f"{trainer}: merge_quorum={cfg.merge_quorum} is not implemented "
            "(there is no straggler/failure detector yet — merges always "
            "run over all pods); set merge_quorum=1.0"
        )
    if cfg.merge_delay < 0:
        raise ValueError(f"merge_delay must be >= 0, got {cfg.merge_delay}")
    if cfg.merge_delay > 0 and not merge_delay_ok:
        raise ValueError(
            f"{trainer} does not support merge_delay={cfg.merge_delay}: the "
            "sparse side synchronizes every step, so a delayed dense merge "
            "would shear the two halves of the model — use DenseTrainer, or "
            "merge_delay=0"
        )


def next_pow2(n) -> int:
    """Smallest power of two >= n (powers of two keep routed shard
    divisibility — shared by capacity defaults and autoscaling)."""
    cap = 1
    while cap < n:
        cap <<= 1
    return cap


def pod_batch(batch: Dict[str, np.ndarray], n_pod: int) -> Dict[str, jnp.ndarray]:
    """Split a global batch into per-pod shards (leading pod dim).

    Host batches are staged with EXPLICIT ``jax.device_put`` (a no-op for
    already-device leaves) so the loop survives
    ``jax.transfer_guard("disallow")`` — the strict-transfers contract:
    every host->device crossing in the hot path is deliberate."""
    def f(x):
        x = jax.device_put(x)
        return x.reshape((n_pod, x.shape[0] // n_pod) + x.shape[1:])
    return jax.tree.map(f, batch)


def _drop_ef_if_absent(like: dict, ckpt: CheckpointManager) -> dict:
    """Restoring with merge="int8_ef" must tolerate checkpoints written
    without the residual (older runs, or runs under a lossless merge): drop
    'ef' from the restore template when the newest manifest lacks it, so
    resume keeps the fresh zero residual instead of raising KeyError."""
    if "ef" not in like:
        return like
    step = latest_step(ckpt.directory)
    man = read_manifest(ckpt.directory, step) if step is not None else None
    if man is not None and not any(
        k.split("/")[0] == "ef" for k in man["leaves"]
    ):
        like = dict(like)
        like.pop("ef")
    return like


def history_record(trainer, loss, t0: float) -> dict:
    """One fit-history record at a logging boundary — the single copy of
    the record schema shared by ``fit`` and ``repro.runtime.online``:
    step/loss/sec plus the trainer's PER-INTERVAL sparse metrics
    (``advance=True``: recording moves the interval baseline forward)."""
    rec = {"step": trainer.step_num, "loss": float(jax.device_get(loss)),
           "sec": time.perf_counter() - t0}
    sparse_metrics = getattr(trainer, "sparse_metrics", None)
    if sparse_metrics is not None:
        rec.update(sparse_metrics(advance=True))
    return rec


def _fit_loop(trainer, batches: Iterator, steps: int, eval_fn=None) -> list:
    """Shared fit(): train ``steps`` batches, log every ``log_every``.

    Runs one batch ahead of the device: the next batch is drawn from the
    iterator while the step executes, and — when the trainer prefetches
    (``cfg.prefetch``) — its pull is dispatched as soon as the current step
    is queued.  Checkpoints (inside ``train_step``) and logged metrics both
    materialize BEFORE the next pull is dispatched, so they capture the
    committed state, never a speculative pull."""
    if steps <= 0:
        if trainer.ckpt:
            trainer.ckpt.wait()   # fit(gen, 0) still flushes async saves
        return trainer.history
    t0 = time.perf_counter()
    prefetch = getattr(trainer, "prefetch", None)
    b = next(batches)
    if prefetch is not None:
        prefetch(b)
    for i in range(steps):
        loss = trainer.train_step(b)
        b = next(batches) if i + 1 < steps else None
        if trainer.step_num % trainer.cfg.log_every == 0:
            # sparse-path health (per-interval overflow + cache hit rate/
            # evictions) rides along; only the logger moves the baseline.
            rec = history_record(trainer, loss, t0)
            if eval_fn:
                rec["eval"] = eval_fn(trainer)
            trainer.history.append(rec)
        if prefetch is not None and b is not None:
            prefetch(b)
    if trainer.ckpt:
        trainer.ckpt.wait()
    return trainer.history


class DenseTrainer:
    """All-dense models: k-step Adam over podded replicas."""

    def __init__(
        self,
        loss_fn: Callable[[Pytree, Dict], jnp.ndarray],
        params: Pytree,
        cfg: TrainerConfig,
        mesh: Optional[jax.sharding.Mesh] = None,
        param_shardings: Optional[Pytree] = None,
    ):
        self.cfg = cfg
        _reject_dead_knobs(cfg, "DenseTrainer", merge_delay_ok=True)
        if cfg.prefetch:
            raise ValueError(
                "DenseTrainer: prefetch=True is a sparse-path feature "
                "(HybridTrainer's pull prefetch) — an all-dense model has "
                "no pull stage to overlap; set prefetch=False"
            )
        if cfg.fused_kernels:
            raise ValueError(
                "DenseTrainer: fused_kernels=True is a sparse-path feature "
                "(the fused embedding pull/push kernels) — an all-dense "
                "model has no working set to fuse over; leave "
                "fused_kernels=None"
            )
        if (cfg.store != "host" or cfg.spill_dir is not None
                or cfg.page_rows is not None
                or cfg.page_cache_pages is not None):
            raise ValueError(
                "DenseTrainer: store/spill_dir/page_rows/page_cache_pages "
                "are sparse-path knobs (the embedding tables' storage "
                "hierarchy) — an all-dense model has no tables to spill"
            )
        if cfg.merge_delay > 0 and cfg.kstep.merge == "int8_ef":
            raise NotImplementedError(
                "merge_delay>0 with merge='int8_ef' is not supported: the "
                "error-feedback residual needs the fused merge path"
            )
        self.n_pod = cfg.n_pod
        self.mesh = mesh
        self.params = pod_replicate(params, cfg.n_pod)
        if param_shardings is not None:
            self.params = jax.tree.map(jax.device_put, self.params, param_shardings)
        self.opt = KStepAdam(cfg.kstep, cfg.n_pod, mesh=mesh)
        self.opt_state = self.opt.init(self.params)
        self.step_num = 0
        self.ckpt = (
            CheckpointManager(cfg.ckpt_dir, cfg.ckpt_keep, cfg.ckpt_every, cfg.ckpt_async)
            if cfg.ckpt_dir else None
        )
        self._loss_fn = loss_fn
        donate = (0, 2) if cfg.donate else ()
        self._local = jax.jit(self._make_step(merge=False), donate_argnums=donate)
        self._merge = jax.jit(self._make_step(merge=True), donate_argnums=donate)
        # merge_delay > 0: queue of (snapshot, in-flight merged average)
        self._pending_merges: collections.deque = collections.deque()
        if cfg.merge_delay > 0:
            # donation decisions (undonated-hot-jit contract): the collective
            # keeps params alive (snapshot + local steps still read them) but
            # consumes the opt_state it replaces; the delayed apply consumes
            # all three — params are reassigned from its output, and the
            # snapshot/merged pair is popped from the queue (snapshot is a
            # real copy, so no donate-twice aliasing with params).
            self._delayed_collective = jax.jit(
                self.opt.delayed_merge_collective, donate_argnums=(1,)
            )
            self._delayed_apply = jax.jit(
                KStepAdam.apply_delayed_merge, donate_argnums=(0, 1, 2)
            )
        self.history: list = []

    def _make_step(self, merge: bool):
        def step(params, batch_podded, opt_state):
            def total_loss(p):
                losses = jax.vmap(lambda pi, bi: self._loss_fn(pi, bi))(p, batch_podded)
                return jnp.sum(losses), losses
            grads, losses = jax.grad(total_loss, has_aux=True)(params)
            new_p, new_s = self.opt.step(params, grads, opt_state, merge=merge)
            return new_p, new_s, jnp.mean(losses)
        return step

    def pod_batch(self, batch: Dict[str, np.ndarray]) -> Dict[str, jnp.ndarray]:
        return pod_batch(batch, self.n_pod)

    def _delayed_merge_boundary(self):
        """``merge_delay > 0``: at each merge boundary, first apply the
        average launched ``merge_delay`` boundaries ago (preserving the
        local drift since its snapshot — ``KStepAdam.apply_delayed_merge``),
        then launch this boundary's cross-pod collective (parameter average
        + the Algorithm-2 ``v_hat <- mean v_local`` refresh, which applies
        immediately so local denominators stay fresh)."""
        if len(self._pending_merges) >= self.cfg.merge_delay:
            snap_old, merged_old = self._pending_merges.popleft()
            self.params = self._delayed_apply(self.params, snap_old, merged_old)
        snap = KStepAdam.snapshot(self.params)
        merged, self.opt_state = self._delayed_collective(
            self.params, self.opt_state
        )
        self._pending_merges.append((snap, merged))

    def train_step(self, batch, podded: bool = False) -> jnp.ndarray:
        """``podded=True``: batch leaves already carry the leading pod dim
        (e.g. full-graph training where each pod sees the same graph).

        Returns the mean loss as a DEVICE array (no host sync — the hot
        path never blocks; ``float()`` it at logging boundaries)."""
        self.step_num += 1
        is_boundary = (self.step_num % self.cfg.kstep.k) == 0
        fused_merge = is_boundary and self.cfg.merge_delay == 0
        fn = self._merge if fused_merge else self._local
        pb = jax.tree.map(jnp.asarray, batch) if podded else self.pod_batch(batch)
        self.params, self.opt_state, loss = fn(self.params, pb, self.opt_state)
        if is_boundary and self.cfg.merge_delay > 0:
            self._delayed_merge_boundary()
        if self.ckpt and self.ckpt.should_save(self.step_num):
            self.save()
        return loss

    # ----------------------------------------------------- fault tolerance
    def _ckpt_tree(self):
        tree = {"params": self.params, "m": self.opt_state.m,
                "v_local": self.opt_state.v_local, "v_hat": self.opt_state.v_hat}
        if self.opt_state.ef is not None:
            # int8_ef merge: the error-feedback residual is state — dropping
            # it on restart silently re-zeros the compensation.
            tree["ef"] = self.opt_state.ef
        return tree

    def save(self):
        # checkpointing deliberately materializes device state host-side —
        # an allow-listed section under strict-transfers runs
        with jax.transfer_guard("allow"):
            self.ckpt.save(
                self.step_num, self._ckpt_tree(),
                meta={"n_pod": self.n_pod, "k": self.cfg.kstep.k},
            )

    def resume(self) -> bool:
        if not self.ckpt:
            return False
        like = _drop_ef_if_absent(self._ckpt_tree(), self.ckpt)
        step, tree = self.ckpt.restore_latest(like)
        if step is None:
            return False
        self.step_num = step
        self.params = tree["params"]
        self.opt_state = self.opt_state._replace(
            step=jnp.asarray(step, jnp.int32), m=tree["m"],
            v_local=tree["v_local"], v_hat=tree["v_hat"],
            ef=tree.get("ef", self.opt_state.ef),
        )
        self._pending_merges.clear()   # in-flight delayed merges don't resume
        return True

    def fit(self, batches: Iterator, steps: int, eval_fn=None) -> list:
        return _fit_loop(self, batches, steps, eval_fn)


class HybridTrainer:
    """Dense tower (k-step Adam, podded) + sparse tables behind an
    ``EmbeddingEngine`` — the paper's production regime.

    Parameters
    ----------
    dense_params: the dense tower's parameter pytree (un-podded).
    engine: owns TableSpecs, capacity, the sparse optimizer, and the
        placement backend; the trainer never touches raw tables directly.
    embed_fn(workings, invs, batch): build model inputs from pulled rows
        (``workings[name]`` = ``WorkingSet.rows``, ``invs[name]`` = the
        inverse map restricted to this pod's batch shard).
    loss_fn(dense, emb, batch, predict=False): dense-side loss given
        embeddings (``predict=True`` returns scores).
    tables: optional pre-initialized tables IN THE BACKEND'S LAYOUT
        (e.g. from ``engine.init`` or ``engine.prepare``); ``None`` lets the
        engine initialize them from ``rng``.

    The train step runs as two compiled stages sharing one contract —
    ``pull`` (``engine.pull_stage``) and ``train+push`` — so the synchronous
    path and the prefetched path (``cfg.prefetch``; see
    ``repro.core.prefetch``) execute the SAME executables and produce
    bit-identical results; the prefetched path merely dispatches the pull of
    batch t+1 before batch t's train stage has finished executing.
    """

    def __init__(
        self,
        dense_params: Pytree,
        engine: EmbeddingEngine,
        embed_fn: Callable,
        loss_fn: Callable,
        cfg: TrainerConfig,
        mesh: Optional[jax.sharding.Mesh] = None,
        tables: Optional[Dict[str, jnp.ndarray]] = None,
        rng: Optional[jax.Array] = None,
    ):
        self.cfg = cfg
        _reject_dead_knobs(cfg, "HybridTrainer", merge_delay_ok=False)
        self.n_pod = cfg.n_pod
        self.mesh = mesh
        self.engine = engine
        self.dense = pod_replicate(dense_params, cfg.n_pod)
        self.tables = (
            tables if tables is not None
            else engine.init(rng if rng is not None else jax.random.key(0))
        )
        self.opt = KStepAdam(cfg.kstep, cfg.n_pod, mesh=mesh)
        self.opt_state = self.opt.init(self.dense)
        self.sparse_state = engine.init_state(self.tables)
        # per-table backend state (cache-tier id->slot map/counters/rows;
        # empty tuples for the stateless placements) — threaded through the
        # compiled stages and checkpointed alongside the tables.
        self.backend_state = engine.init_backend_state(self.tables)
        self.step_num = 0
        # device-resident cumulative overflow counter (materialized only at
        # logging/checkpoint boundaries — the hot path never syncs the host)
        self._overflow = jnp.zeros((), jnp.int32)
        self._commit_to_mesh()
        self._metrics_prev: Dict[str, float] = {}  # counter snapshot at last log
        self._metrics_base_step = 0   # step the counters were last re-zeroed at
        self._embed = embed_fn
        self._loss = loss_fn
        # the checkpoint GC doubles as the spill-dir wreckage sweeper when
        # the engine's tables live in a DiskStore
        self.ckpt = (
            CheckpointManager(
                cfg.ckpt_dir, cfg.ckpt_keep, cfg.ckpt_every, cfg.ckpt_async,
                spill_dir=getattr(engine.store, "spill_dir", None),
            )
            if cfg.ckpt_dir else None
        )
        donate = cfg.donate
        # stage 1: the engine's compiled pull (shared with the prefetcher —
        # same executable => prefetched training is bit-identical)
        self._pull = engine.pull_stage(donate=donate)
        # stage 2: fwd/bwd on the working set + k-step Adam + push.  The
        # working sets (arg 4) are NOT donated: their int index buffers and
        # capacity-shaped rows can never alias the stage's outputs.
        train_donate = (0, 1, 2, 3, 6, 7) if donate else ()
        self._train_local = jax.jit(
            self._make_train(False), donate_argnums=train_donate
        )
        self._train_merge = jax.jit(
            self._make_train(True), donate_argnums=train_donate
        )
        self._prefetcher = (
            PrefetchingEngine(engine, donate=donate) if cfg.prefetch else None
        )
        # inference path: READ-ONLY lookup + embed + score compiled as one
        # stage so the per-request loop dispatches a single executable (an
        # eager pull ships scalar operands host->device on every call).
        # Nothing is donated — predict must not consume the committed
        # training state (the engine's lookup contract guarantees it also
        # mutates none of it).
        self._predict_jit = jax.jit(self._predict_traced, donate_argnums=())
        # serving-side meters, accumulated host-side per predict — kept
        # fully separate from the training-interval cache stats so
        # interleaved serving never moves sparse_metrics (see
        # ``serve_metrics``)
        self._serve_counters: Dict[str, float] = {}
        self.history: list = []

    def _make_train(self, merge: bool):
        def train(dense, tables, accum, bstate, wss, batch_podded, opt_state,
                  overflow):
            workings = {n: ws.rows for n, ws in wss.items()}
            # inverse indices sliced per pod so each replica embeds only its
            # own batch shard (vmapped leading pod dim)
            invs_podded = {
                n: ws.inverse.reshape(self.n_pod, -1) for n, ws in wss.items()
            }

            # ---- local fwd/bwd on the working set (Algorithm 1 line 12)
            def total_loss(dense_p, w):
                def per_pod(dp, bp, inv_p):
                    emb = self._embed(w, inv_p, bp)
                    return self._loss(dp, emb, bp)
                losses = jax.vmap(per_pod, in_axes=(0, 0, 0))(
                    dense_p, batch_podded, invs_podded
                )
                return jnp.sum(losses), losses

            (dense_g, work_g), losses = jax.grad(total_loss, argnums=(0, 1), has_aux=True)(
                dense, workings
            )
            # sparse grads are summed over pods by autodiff; average them
            # (paper: sparse side synchronized every iteration).
            work_g = jax.tree.map(lambda g: g / self.n_pod, work_g)

            # ---- dense k-step Adam
            new_dense, new_opt = self.opt.step(dense, dense_g, opt_state, merge=merge)

            # ---- PUSH (line 13): backend scatters/routes the row updates.
            new_tables, new_accum, bstate = self.engine.push(
                tables, accum, bstate, wss, work_g
            )
            new_overflow = overflow + self.engine.overflow(wss).astype(jnp.int32)
            return (new_dense, new_tables, new_accum, bstate, new_opt,
                    jnp.mean(losses), new_overflow)

        return train

    def pod_batch(self, batch):
        return pod_batch(batch, self.n_pod)

    def _commit_to_mesh(self):
        """Commit the trainer state to the mesh's replicated sharding.

        Mesh-backed steps (routed placement) emit every state leaf with
        ``NamedSharding(mesh, P())``; eagerly-initialized (or freshly
        restored) state is uncommitted ``SingleDeviceSharding``, so without
        this the FIRST train executable is compiled for a signature no later
        step ever uses again — a full silent double-compile of the largest
        jit (caught by the trace audit's retrace check).

        The backend's internal mesh counts too: ``RoutedBackend`` builds one
        when none is passed, and its shard_maps stamp that mesh's sharding
        on every output flowing through the train jit."""
        mesh = self.mesh if self.mesh is not None else getattr(
            self.engine.backend, "mesh", None)
        if mesh is None:
            return
        rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
        put = lambda tree: jax.device_put(tree, rep)
        self.dense = put(self.dense)
        self.tables = put(self.tables)
        self.opt_state = put(self.opt_state)
        self.sparse_state = put(self.sparse_state)
        self.backend_state = put(self.backend_state)
        self._overflow = put(self._overflow)

    def _stage(self, batch):
        # explicit h2d staging: jax.device_put is transfer-guard-exempt
        # (deliberate), where jnp.asarray would count as an implicit sync
        return jax.device_put(batch)

    def prefetch(self, batch) -> bool:
        """Speculatively dispatch ``batch``'s working-set pull (the Fig. 5
        overlap).  No-op unless ``cfg.prefetch``; idempotent for the batch
        already in flight; a DIFFERENT batch while one is pending is a
        pipeline bug and raises.  After dispatch the trainer's sparse-state
        handles point at the pull's pass-through trees (logically identical
        values — a pull moves rows coherently, only push changes them), so
        online ``predict`` keeps working mid-flight."""
        if self._prefetcher is None or batch is None:
            return False
        pending = self._prefetcher.pending
        if pending is not None:
            if pending.src is batch:
                return True
            raise RuntimeError(
                "HybridTrainer.prefetch: a pull for a different batch is "
                "already in flight — train_step() it before prefetching "
                "the next batch (the pipeline is one batch deep)"
            )
        pending = self._prefetcher.dispatch(
            self.tables, self.sparse_state.accum, self.backend_state,
            self._stage(batch), src=batch,
        )
        # the dispatch donated the committed buffers; the post-pull trees
        # are now the only valid handles until the commit in train_step
        self.tables = pending.tables
        self.backend_state = pending.bstate
        self.sparse_state = self.sparse_state._replace(accum=pending.accum)
        return True

    def train_step(self, batch) -> jnp.ndarray:
        """One pull -> train -> push step on ``batch``.

        Uses the prefetched pull when one is in flight (``cfg.prefetch``),
        otherwise dispatches the pull stage synchronously — the same
        executables either way.  Returns the mean loss as a DEVICE array
        (no host sync; ``float()`` it at logging boundaries)."""
        if self._prefetcher is not None:
            pending = self._prefetcher.pending
            # reject BEFORE any state moves (step_num included): a caught
            # misuse error must not shift the merge/checkpoint cadence
            if pending is not None and pending.src is not batch:
                raise RuntimeError(
                    "HybridTrainer.train_step: the in-flight prefetched pull "
                    "belongs to a different batch than the one passed — "
                    "feed the same batch to prefetch() and train_step()"
                )
        self.step_num += 1
        is_merge = (self.step_num % self.cfg.kstep.k) == 0
        fn = self._train_merge if is_merge else self._train_local
        if self._prefetcher is not None:
            if self._prefetcher.pending is None:
                self.prefetch(batch)   # cold start: pull now (not early)
            p = self._prefetcher.commit()
            wss, staged = p.wss, p.batch
            tables, accum, bstate = p.tables, p.accum, p.bstate
        else:
            staged = self._stage(batch)
            wss, tables, accum, bstate = self.engine.commit(self._pull(
                self.tables, self.sparse_state.accum, self.backend_state,
                self.engine.ids_from_batch(staged),
            ))
        (self.dense, self.tables, accum, self.backend_state, self.opt_state,
         loss, self._overflow) = fn(
            self.dense, tables, accum, bstate, wss,
            self.pod_batch(staged), self.opt_state, self._overflow,
        )
        self.sparse_state = self.sparse_state._replace(accum=accum)
        if self.ckpt and self.ckpt.should_save(self.step_num):
            self.save()   # committed state: the next pull is not yet queued
        return loss

    def train_step_prefetched(self, batch, next_batch=None) -> jnp.ndarray:
        """One pipelined step for manual (non-``fit``) loops: train on
        ``batch`` (consuming its prefetched pull, or pulling now on a cold
        start), then dispatch ``next_batch``'s pull so it overlaps the step
        just queued."""
        loss = self.train_step(batch)
        if next_batch is not None:
            self.prefetch(next_batch)
        return loss

    @property
    def overflow_dropped(self) -> int:
        """Cumulative unserved pull/push requests, across restarts (the
        counter is checkpointed) — materializes the device-resident scalar
        (read at logging boundaries, not per step; explicit device_get keeps
        strict-transfers runs clean)."""
        return int(jax.device_get(self._overflow))

    def predict(self, batch) -> np.ndarray:
        """Inference with pod-0's dense replica (online predict-then-train,
        and the executable the co-located CTR server drives).

        Runs on the engine's READ-ONLY lookup contract: the sparse rows are
        served exactly as a pull would serve them (cache-fresh values
        included — a row trained at step t is servable immediately) but
        NOTHING mutates — no cache admission/eviction, no counter writes,
        no disk absorb — so any interleaving of predicts leaves the
        training trajectory and the training-interval stats bit-identical.
        Valid while a prefetched pull is in flight: the pass-through trees
        it reads are logically identical to the committed state."""
        if self.engine.store.kind == "disk":
            return self._predict_disk(batch)
        batch = self._stage(batch)
        scores, aux = self._predict_jit(
            self.dense, self.tables, self.sparse_state.accum,
            self.backend_state, batch,
        )
        return self._finish_predict(scores, aux)

    def _predict_disk(self, batch) -> np.ndarray:
        """Disk-store inference: stage THIS batch's rows, read-only.

        The training staging buffers hold another batch's rows, so predict
        builds its own through ``engine.stage_lookup``: host-dedup the
        batch's ids, serve-metered ``store.gather``, then OVERLAY any
        pending staged training outputs onto the gathered rows host-side —
        the freshest values are served without absorbing (writing) anything
        into the store, and the same ``_predict_jit`` runs over them (the
        staged shapes match the training buffers, so no recompile).  The
        overlay replaces the old absorb-before-predict: it is exact in
        every pipeline state (un-absorbed push outputs are patched to their
        post-absorb values; a pending prefetched pull's pass-through rows
        patch idempotently; in-flight cache spills patch to the values the
        next absorb will commit)."""
        batch = self._stage(batch)
        ids_np = {
            n: np.asarray(jax.device_get(ids))
            for n, ids in self.engine.ids_from_batch(batch).items()
        }
        staged_t, staged_a = self.engine.stage_lookup(
            self.tables, self.sparse_state.accum, self.backend_state, ids_np
        )
        scores, aux = self._predict_jit(
            self.dense, staged_t, staged_a, self.backend_state, batch,
        )
        return self._finish_predict(scores, aux)

    def _finish_predict(self, scores, aux) -> np.ndarray:
        # scores are consumed host-side (streaming AUC / response writing):
        # ONE explicit d2h materializes them together with the lookup's
        # serve meters, which accumulate into the serve-side counters
        got = jax.device_get({"scores": scores, "aux": aux})
        c = self._serve_counters
        c["serve_requests"] = c.get("serve_requests", 0.0) + float(
            np.asarray(got["scores"]).shape[0])
        for k, v in got["aux"].items():
            c[k] = c.get(k, 0.0) + float(v)
        return np.asarray(got["scores"])

    def _predict_traced(self, dense, tables, accum, bstate, batch):
        dense0 = pod_slice(dense, 0)
        wss, aux = self.engine.lookup_batch(tables, accum, bstate, batch)
        workings = {n: ws.rows for n, ws in wss.items()}
        invs = {n: ws.inverse for n, ws in wss.items()}
        emb = self._embed(workings, invs, batch)
        return self._loss(dense0, emb, batch, predict=True), aux

    def serve_metrics(self) -> Dict[str, float]:
        """Cumulative SERVING-side counters — the monitoring surface of the
        co-located inference tier, fully separate from ``sparse_metrics``
        (whose training-interval stats never count inference traffic):
        ``serve_requests`` (instances scored), ``serve_lookups`` (id slots
        served), and under the cache tier ``serve_misses`` +
        ``serve_hit_rate`` (same ``1 - misses/lookups`` convention as
        training).  DiskStore page meters for serving reads ride along
        under ``serve_page_*``/``serve_disk_*`` keys."""
        m = dict(self._serve_counters)
        if "serve_misses" in m:
            lk = m.get("serve_lookups", 0.0)
            m["serve_hit_rate"] = (
                0.0 if lk <= 0.0 else 1.0 - m["serve_misses"] / lk)
        for k, v in self.engine.store.serve_stats().items():
            m[f"serve_{k}"] = float(v)
        return m

    def sparse_metrics(self, advance: bool = False) -> Dict[str, float]:
        """Sparse-path health for trainer history/monitoring, PER INTERVAL
        (deltas since the last logging boundary — the current window):
        ``overflow_dropped`` plus, under the cached placement,
        ``cache_hit_rate``/``evictions``/host<->device byte meters.
        Whole-run cumulative values ride along under ``*_total`` keys
        (``cache_hit_rate_total`` is the whole-run blend).

        A PURE read by default — poll it freely between boundaries.  Only
        ``advance=True`` (what ``fit``'s logger passes) moves the interval
        baseline forward, so external polls never eat a window's deltas out
        from under the history records."""
        total = int(jax.device_get(self._overflow))
        counters = self.engine.cache_counters(self.backend_state)
        prev = self._metrics_prev
        m: Dict[str, float] = {
            "overflow_dropped": total - int(prev.get("overflow", 0)),
            "overflow_dropped_total": total,
        }
        if counters:
            delta = {k: v - prev.get(k, 0.0) for k, v in counters.items()}
            m.update(self.engine.derive_cache_stats(delta))
            for k, v in self.engine.derive_cache_stats(counters).items():
                m[f"{k}_total"] = v
        if advance:
            self._metrics_prev = {"overflow": total, **counters}
        return m

    def suggest_capacity(self, history=None, safety: float = 1.25) -> int:
        """Recommend a dedup capacity from observed overflow (the first step
        of overflow-aware capacity autoscaling).

        Reads the PER-INTERVAL ``overflow_dropped`` records from ``history``
        (default: this trainer's own ``fit`` history, whose first interval
        starts at the step the counters were last zeroed — construction or
        resume): with no drops the current capacity stands; otherwise grow
        to the next power of two covering the current capacity plus
        ``safety`` x the worst observed per-step drop rate (powers of two
        keep routed shard divisibility).
        """
        hist = self.history if history is None else history
        worst = 0.0
        prev_step = self._metrics_base_step if history is None else 0
        for rec in hist:
            if "overflow_dropped" not in rec:
                continue
            d_steps = rec["step"] - prev_step
            if d_steps > 0:
                worst = max(worst, rec["overflow_dropped"] / d_steps)
            prev_step = rec["step"]
        if not hist and self.step_num > 0:
            # no logged records yet: fall back to the cumulative average
            # (the overflow counter spans the whole run — it is checkpointed)
            worst = self.overflow_dropped / self.step_num
        if worst <= 0:
            return self.engine.capacity
        return next_pow2(self.engine.capacity + safety * worst)

    def fit(self, batches: Iterator, steps: int, eval_fn=None) -> list:
        return _fit_loop(self, batches, steps, eval_fn)

    # ----------------------------------------------------- fault tolerance
    def _ckpt_tree(self):
        tree = {"dense": self.dense, "tables": self.tables,
                "accum": self.sparse_state.accum, "m": self.opt_state.m,
                "v_local": self.opt_state.v_local, "v_hat": self.opt_state.v_hat}
        if self.opt_state.ef is not None:
            tree["ef"] = self.opt_state.ef
        if jax.tree.leaves(self.backend_state):
            # cache-tier (or other stateful-placement) state is training
            # state: host tables alone are stale while rows sit dirty in the
            # device cache, so the cache must roundtrip with them.
            tree["bstate"] = self.backend_state
        # the overflow counter rides along so post-resume *_total metrics
        # share one baseline with the cache counters living in bstate
        tree["overflow"] = self._overflow
        return tree

    def _backend_sig(self):
        """Identity of the sparse physical layout baked into the tables
        (+ cache geometry, which shapes the checkpointed backend state)."""
        b = self.engine.backend
        sig = {"backend": type(b).__name__,
               "n_shards": getattr(b, "n_shards", 1),
               "store": self.engine.store.kind}
        cache_rows = getattr(b, "cache_rows", None)
        if cache_rows is not None:
            sig["cache_rows"] = int(cache_rows)
        if self.engine.store.kind == "disk":
            # page geometry shapes the checkpoint's page files
            sig["page_rows"] = int(self.engine.store.page_rows)
        return sig

    def save(self):
        if self._prefetcher is not None and self._prefetcher.pending is not None:
            # flush-on-checkpoint: a checkpoint must capture the committed
            # (post-push) state — the speculative pull's cache admissions
            # would double-count on resume.  fit/train_step save at commit
            # boundaries before the next pull is dispatched.
            raise RuntimeError(
                "HybridTrainer.save: a prefetched pull is in flight — "
                "checkpoints capture committed state only; save at step "
                "boundaries (as fit/train_step do) before prefetching"
            )
        extras_dir = None
        if self.engine.store.kind == "disk":
            # commit everything in flight to the store, then snapshot its
            # pages SYNCHRONOUSLY into a staging dir — the async writer only
            # renames the finished snapshot into the checkpoint, so live
            # page mutations after this point can't tear it.  The staged
            # buffers/spill state in the npz tree stay consistent with the
            # snapshot: re-absorbing them on resume rewrites the same values
            # (absolute-row writes are idempotent).
            self.engine.sync_store(
                self.tables, self.sparse_state.accum, self.backend_state)
            extras_dir = os.path.join(
                self.ckpt.directory, f"pages_staging_{self.step_num}")
            if os.path.exists(extras_dir):
                shutil.rmtree(extras_dir)
            self.engine.store.snapshot_to(extras_dir)
        # checkpointing deliberately materializes device state host-side —
        # an allow-listed section under strict-transfers runs
        with jax.transfer_guard("allow"):
            self.ckpt.save(
                self.step_num, self._ckpt_tree(),
                meta={"n_pod": self.n_pod, "k": self.cfg.kstep.k,
                      **self._backend_sig()},
                extras_dir=extras_dir,
            )

    def resume(self) -> bool:
        if not self.ckpt:
            return False
        # Tables are checkpointed in the backend's physical layout; loading
        # them under a different backend (or routed shard count, which
        # changes the hash-slot permutation; or a cached run's host tables,
        # which are stale wherever rows sat dirty in the device cache)
        # would silently read wrong rows.
        s = latest_step(self.ckpt.directory)
        man = read_manifest(self.ckpt.directory, s) if s is not None else None
        if man is not None and "backend" in man.get("meta", {}):
            sig = self._backend_sig()
            saved = {k: man["meta"][k]
                     for k in ("backend", "n_shards", "cache_rows",
                               "store", "page_rows")
                     if k in man["meta"]}
            # pre-store checkpoints carry no "store" key — they were host
            # runs, so only a disk-configured engine must refuse them
            if saved != {k: sig.get(k) for k in saved} or (
                "cache_rows" in sig and "cache_rows" not in saved
            ) or (sig["store"] == "disk" and "store" not in saved):
                raise ValueError(
                    f"checkpoint written with {saved} but the current engine "
                    f"uses {sig}: the tables' physical "
                    f"layouts differ — resume with the saving placement, or "
                    f"export/re-prepare the tables explicitly"
                )
        like = _drop_ef_if_absent(self._ckpt_tree(), self.ckpt)
        if man is not None and not any(
            k.split("/")[0] == "overflow" for k in man["leaves"]
        ):
            like.pop("overflow", None)   # pre-PR3 checkpoint: counter at 0
        step, tree = self.ckpt.restore_latest(like)
        if step is None:
            return False
        if self.engine.store.kind == "disk":
            # pages first: the restored npz state (staged buffers, cache
            # spill ids) is only consistent against the SAVE-TIME pages
            self.engine.store.restore_from(os.path.join(
                self.ckpt.directory, f"step_{step:010d}", "pages"))
            self.engine.reset_staging()
        self.step_num = step
        self.dense, self.tables = tree["dense"], tree["tables"]
        self.sparse_state = self.sparse_state._replace(accum=tree["accum"])
        self.backend_state = tree.get("bstate", self.backend_state)
        self.opt_state = self.opt_state._replace(
            step=jnp.asarray(step, jnp.int32), m=tree["m"],
            v_local=tree["v_local"], v_hat=tree["v_hat"],
            ef=tree.get("ef", self.opt_state.ef),
        )
        # restore the cumulative overflow counter and re-baseline the
        # interval snapshot so the first post-resume window reports only
        # post-resume deltas (totals keep the whole-run baseline, matching
        # the cache counters restored inside bstate)
        self._overflow = jnp.asarray(tree.get("overflow", 0), jnp.int32)
        self._commit_to_mesh()   # restored leaves are uncommitted host reads
        self._metrics_prev = {
            "overflow": int(jax.device_get(self._overflow)),
            **self.engine.cache_counters(self.backend_state),
        }
        self._metrics_base_step = step
        return True
