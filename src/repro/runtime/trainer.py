"""Training runtimes.

``DenseTrainer`` — any model whose parameters are all dense (LM, GNN):
podded replicas + k-step Adam; per-pod batches; static local/merge
executables; checkpoint/restart; straggler-tolerant merging.

``HybridTrainer`` — the paper's CTR/recsys regime: dense tower under k-step
Adam + giant sparse tables under every-step working-set AdaGrad
(Algorithm 1's pull -> train -> push, with the pull deduplicated across the
*global* batch so the sparse sync stays O(working set)).

Both runtimes implement the fault-tolerance contract:
- crash-consistent checkpoints (atomic dirs) at a configurable cadence,
- ``resume()`` picks up the newest complete checkpoint (mesh-independent),
- the k-step merge is the only cross-pod sync point; ``merge_quorum < 1.0``
  lets the merge proceed over a subset of pods (straggler mitigation: any
  subset average is a valid Algorithm-2 merge with smaller N),
- ``merge_delay > 0`` applies merges asynchronously (DCN latency hiding).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.embedding_engine import pull_working_set
from repro.core.kstep import KStepAdam, KStepConfig, pod_replicate
from repro.core.sparse_optim import SparseAdagrad, SparseAdagradConfig

Pytree = Any


@dataclasses.dataclass
class TrainerConfig:
    n_pod: int = 1
    kstep: KStepConfig = dataclasses.field(default_factory=KStepConfig)
    sparse: SparseAdagradConfig = dataclasses.field(default_factory=SparseAdagradConfig)
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 200
    ckpt_keep: int = 3
    ckpt_async: bool = True
    merge_quorum: float = 1.0     # fraction of pods required at a merge
    merge_delay: int = 0          # async merge application lag (in merges)
    log_every: int = 50
    donate: bool = True


class DenseTrainer:
    """All-dense models: k-step Adam over podded replicas."""

    def __init__(
        self,
        loss_fn: Callable[[Pytree, Dict], jnp.ndarray],
        params: Pytree,
        cfg: TrainerConfig,
        mesh: Optional[jax.sharding.Mesh] = None,
        param_shardings: Optional[Pytree] = None,
    ):
        self.cfg = cfg
        self.n_pod = cfg.n_pod
        self.mesh = mesh
        self.params = pod_replicate(params, cfg.n_pod)
        if param_shardings is not None:
            self.params = jax.tree.map(jax.device_put, self.params, param_shardings)
        self.opt = KStepAdam(cfg.kstep, cfg.n_pod, mesh=mesh)
        self.opt_state = self.opt.init(self.params)
        self.step_num = 0
        self.ckpt = (
            CheckpointManager(cfg.ckpt_dir, cfg.ckpt_keep, cfg.ckpt_every, cfg.ckpt_async)
            if cfg.ckpt_dir else None
        )
        self._loss_fn = loss_fn
        donate = (0, 2) if cfg.donate else ()
        self._local = jax.jit(self._make_step(merge=False), donate_argnums=donate)
        self._merge = jax.jit(self._make_step(merge=True), donate_argnums=donate)
        self.history: list = []

    def _make_step(self, merge: bool):
        def step(params, batch_podded, opt_state):
            def total_loss(p):
                losses = jax.vmap(lambda pi, bi: self._loss_fn(pi, bi))(p, batch_podded)
                return jnp.sum(losses), losses
            grads, losses = jax.grad(total_loss, has_aux=True)(params)
            new_p, new_s = self.opt.step(params, grads, opt_state, merge=merge)
            return new_p, new_s, jnp.mean(losses)
        return step

    def pod_batch(self, batch: Dict[str, np.ndarray]) -> Dict[str, jnp.ndarray]:
        """Split the global batch into per-pod shards (leading pod dim)."""
        def f(x):
            x = jnp.asarray(x)
            return x.reshape((self.n_pod, x.shape[0] // self.n_pod) + x.shape[1:])
        return jax.tree.map(f, batch)

    def train_step(self, batch, podded: bool = False) -> float:
        """``podded=True``: batch leaves already carry the leading pod dim
        (e.g. full-graph training where each pod sees the same graph)."""
        self.step_num += 1
        is_merge = (self.step_num % self.cfg.kstep.k) == 0
        fn = self._merge if is_merge else self._local
        pb = jax.tree.map(jnp.asarray, batch) if podded else self.pod_batch(batch)
        self.params, self.opt_state, loss = fn(self.params, pb, self.opt_state)
        if self.ckpt and self.ckpt.should_save(self.step_num):
            self.save()
        return float(loss)

    # ----------------------------------------------------- fault tolerance
    def save(self):
        self.ckpt.save(
            self.step_num,
            {"params": self.params, "m": self.opt_state.m,
             "v_local": self.opt_state.v_local, "v_hat": self.opt_state.v_hat},
            meta={"n_pod": self.n_pod, "k": self.cfg.kstep.k},
        )

    def resume(self) -> bool:
        if not self.ckpt:
            return False
        like = {"params": self.params, "m": self.opt_state.m,
                "v_local": self.opt_state.v_local, "v_hat": self.opt_state.v_hat}
        step, tree = self.ckpt.restore_latest(like)
        if step is None:
            return False
        self.step_num = step
        self.params = tree["params"]
        self.opt_state = self.opt_state._replace(
            step=jnp.asarray(step, jnp.int32), m=tree["m"],
            v_local=tree["v_local"], v_hat=tree["v_hat"],
        )
        return True

    def fit(self, batches: Iterator, steps: int, eval_fn=None) -> list:
        t0 = time.perf_counter()
        for _ in range(steps):
            loss = self.train_step(next(batches))
            if self.step_num % self.cfg.log_every == 0:
                rec = {"step": self.step_num, "loss": loss,
                       "sec": time.perf_counter() - t0}
                if eval_fn:
                    rec["eval"] = eval_fn(self)
                self.history.append(rec)
        if self.ckpt:
            self.ckpt.wait()
        return self.history


class HybridTrainer:
    """Dense tower (k-step Adam, podded) + sparse tables (every-step AdaGrad
    over pulled working sets) — the paper's production regime.

    ``embed_fn(workings, batch)``: build model inputs from pulled rows.
    ``loss_fn(dense, emb, batch)``: dense-side loss given embeddings.
    ``id_fields``: {table_name: batch key holding its ids}.
    """

    def __init__(
        self,
        dense_params: Pytree,
        tables: Dict[str, jnp.ndarray],
        embed_from_workings: Callable,
        loss_fn: Callable,
        id_fields: Dict[str, str],
        capacity: int,
        cfg: TrainerConfig,
        mesh: Optional[jax.sharding.Mesh] = None,
    ):
        self.cfg = cfg
        self.n_pod = cfg.n_pod
        self.mesh = mesh
        self.dense = pod_replicate(dense_params, cfg.n_pod)
        self.tables = tables
        self.capacity = capacity
        self.id_fields = id_fields
        self.opt = KStepAdam(cfg.kstep, cfg.n_pod, mesh=mesh)
        self.opt_state = self.opt.init(self.dense)
        self.sparse_opt = SparseAdagrad(cfg.sparse)
        self.sparse_state = self.sparse_opt.init(tables)
        self.step_num = 0
        self._embed = embed_from_workings
        self._loss = loss_fn
        self.ckpt = (
            CheckpointManager(cfg.ckpt_dir, cfg.ckpt_keep, cfg.ckpt_every, cfg.ckpt_async)
            if cfg.ckpt_dir else None
        )
        self._step_local = jax.jit(self._make_step(False))
        self._step_merge = jax.jit(self._make_step(True))
        self.history: list = []

    def _make_step(self, merge: bool):
        names = sorted(self.id_fields)

        def step(dense, tables, accum, batch, batch_podded, opt_state):
            # ---- PULL (Algorithm 1 line 3): dedup global ids, gather rows.
            pulls = {}
            for name in names:
                ids = batch[self.id_fields[name]].reshape(-1)
                uids, inv = pull_working_set(ids, self.capacity)
                pulls[name] = (uids, inv, jnp.take(tables[name], uids, axis=0))

            workings = {n: p[2] for n, p in pulls.items()}
            # inverse indices sliced per pod so each replica embeds only its
            # own batch shard (vmapped leading pod dim)
            invs_podded = {
                n: p[1].reshape(self.n_pod, -1) for n, p in pulls.items()
            }

            # ---- local fwd/bwd on the working set (line 12)
            def total_loss(dense_p, w):
                def per_pod(dp, bp, inv_p):
                    emb = self._embed(w, inv_p, bp)
                    return self._loss(dp, emb, bp)
                losses = jax.vmap(per_pod, in_axes=(0, 0, 0))(
                    dense_p, batch_podded, invs_podded
                )
                return jnp.sum(losses), losses

            (dense_g, work_g), losses = jax.grad(total_loss, argnums=(0, 1), has_aux=True)(
                dense, workings
            )
            # sparse grads are summed over pods by autodiff; average them
            # (paper: sparse side synchronized every iteration).
            work_g = jax.tree.map(lambda g: g / self.n_pod, work_g)

            # ---- dense k-step Adam
            new_dense, new_opt = self.opt.step(dense, dense_g, opt_state, merge=merge)

            # ---- PUSH (line 13): scatter AdaGrad row updates into tables.
            new_tables, new_accum = {}, {}
            for name in names:
                uids = pulls[name][0]
                nt, na = self.sparse_opt.apply_rows(
                    tables[name], accum[name], uids, work_g[name]
                )
                new_tables[name] = nt
                new_accum[name] = na
            return new_dense, new_tables, new_accum, new_opt, jnp.mean(losses)

        return step

    def pod_batch(self, batch):
        def f(x):
            x = jnp.asarray(x)
            return x.reshape((self.n_pod, x.shape[0] // self.n_pod) + x.shape[1:])
        return jax.tree.map(f, batch)

    def train_step(self, batch) -> float:
        self.step_num += 1
        is_merge = (self.step_num % self.cfg.kstep.k) == 0
        fn = self._step_merge if is_merge else self._step_local
        batch = jax.tree.map(jnp.asarray, batch)
        (self.dense, self.tables, accum, self.opt_state, loss) = fn(
            self.dense, self.tables, self.sparse_state.accum,
            batch, self.pod_batch(batch), self.opt_state,
        )
        self.sparse_state = self.sparse_state._replace(accum=accum)
        if self.ckpt and self.ckpt.should_save(self.step_num):
            self.save()
        return float(loss)

    def predict(self, batch) -> np.ndarray:
        """Inference with pod-0's dense replica (online predict-then-train)."""
        batch = jax.tree.map(jnp.asarray, batch)
        dense0 = jax.tree.map(lambda x: x[0], self.dense)
        names = sorted(self.id_fields)
        pulls = {}
        for name in names:
            ids = batch[self.id_fields[name]].reshape(-1)
            uids, inv = pull_working_set(ids, self.capacity)
            pulls[name] = (inv, jnp.take(self.tables[name], uids, axis=0))
        workings = {n: p[1] for n, p in pulls.items()}
        invs = {n: p[0] for n, p in pulls.items()}
        emb = self._embed(workings, invs, batch)
        return np.asarray(self._loss(dense0, emb, batch, predict=True))

    def save(self):
        self.ckpt.save(
            self.step_num,
            {"dense": self.dense, "tables": self.tables,
             "accum": self.sparse_state.accum, "m": self.opt_state.m,
             "v_local": self.opt_state.v_local, "v_hat": self.opt_state.v_hat},
            meta={"n_pod": self.n_pod, "k": self.cfg.kstep.k},
        )

    def resume(self) -> bool:
        if not self.ckpt:
            return False
        like = {"dense": self.dense, "tables": self.tables,
                "accum": self.sparse_state.accum, "m": self.opt_state.m,
                "v_local": self.opt_state.v_local, "v_hat": self.opt_state.v_hat}
        step, tree = self.ckpt.restore_latest(like)
        if step is None:
            return False
        self.step_num = step
        self.dense, self.tables = tree["dense"], tree["tables"]
        self.sparse_state = self.sparse_state._replace(accum=tree["accum"])
        self.opt_state = self.opt_state._replace(
            step=jnp.asarray(step, jnp.int32), m=tree["m"],
            v_local=tree["v_local"], v_hat=tree["v_hat"],
        )
        return True
