"""Training runtimes.

``DenseTrainer`` — any model whose parameters are all dense (LM, GNN):
podded replicas + k-step Adam; per-pod batches; static local/merge
executables; checkpoint/restart; straggler-tolerant merging.

``HybridTrainer`` — the paper's CTR/recsys regime: dense tower under k-step
Adam + giant sparse tables owned by an ``EmbeddingEngine`` (Algorithm 1's
pull -> train -> push through a pluggable ``EmbeddingBackend``; the pull is
deduplicated across the *global* batch so the sparse sync stays O(working
set), and overflowed pulls are counted in ``overflow_dropped``).  Each
backend's per-table state pytree (the cache tier's id->slot map/counters/
cached rows under ``--placement cached``) is threaded through the compiled
step, checkpointed alongside the tables, and surfaced into ``fit`` history
as ``cache_hit_rate``/``evictions`` next to ``overflow_dropped``.

Construct trainers directly, or — config-driven — through
``repro.runtime.factory.build_trainer(arch_name, TrainerConfig)``, which
wires models, engines, and placements from the ``repro.configs`` registry.

Both runtimes implement the fault-tolerance contract:
- crash-consistent checkpoints (atomic dirs) at a configurable cadence,
  including the int8 error-feedback residual when ``merge="int8_ef"``,
- ``resume()`` picks up the newest complete checkpoint (mesh-independent),
- the k-step merge is the only cross-pod sync point; ``merge_quorum < 1.0``
  lets the merge proceed over a subset of pods (straggler mitigation: any
  subset average is a valid Algorithm-2 merge with smaller N),
- ``merge_delay > 0`` applies merges asynchronously (DCN latency hiding).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, latest_step, read_manifest
from repro.core.embedding_engine import EmbeddingEngine
from repro.core.kstep import KStepAdam, KStepConfig, pod_replicate, pod_slice
from repro.core.sparse_optim import SparseAdagradConfig

Pytree = Any


@dataclasses.dataclass
class TrainerConfig:
    n_pod: int = 1
    kstep: KStepConfig = dataclasses.field(default_factory=KStepConfig)
    sparse: SparseAdagradConfig = dataclasses.field(default_factory=SparseAdagradConfig)
    placement: str = "gather"     # sparse backend: "gather"|"routed"|"cached"
    capacity: Optional[int] = None  # working-set bound (None: arch default)
    cache_rows: Optional[int] = None  # device cache size for "cached"
                                      # (None: arch default; must be >= capacity)
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 200
    ckpt_keep: int = 3
    ckpt_async: bool = True
    merge_quorum: float = 1.0     # fraction of pods required at a merge
    merge_delay: int = 0          # async merge application lag (in merges)
    log_every: int = 50
    donate: bool = True


def pod_batch(batch: Dict[str, np.ndarray], n_pod: int) -> Dict[str, jnp.ndarray]:
    """Split a global batch into per-pod shards (leading pod dim)."""
    def f(x):
        x = jnp.asarray(x)
        return x.reshape((n_pod, x.shape[0] // n_pod) + x.shape[1:])
    return jax.tree.map(f, batch)


def _drop_ef_if_absent(like: dict, ckpt: CheckpointManager) -> dict:
    """Restoring with merge="int8_ef" must tolerate checkpoints written
    without the residual (older runs, or runs under a lossless merge): drop
    'ef' from the restore template when the newest manifest lacks it, so
    resume keeps the fresh zero residual instead of raising KeyError."""
    if "ef" not in like:
        return like
    step = latest_step(ckpt.directory)
    man = read_manifest(ckpt.directory, step) if step is not None else None
    if man is not None and not any(
        k.split("/")[0] == "ef" for k in man["leaves"]
    ):
        like = dict(like)
        like.pop("ef")
    return like


def _fit_loop(trainer, batches: Iterator, steps: int, eval_fn=None) -> list:
    """Shared fit(): train ``steps`` batches, log every ``log_every``."""
    t0 = time.perf_counter()
    for _ in range(steps):
        loss = trainer.train_step(next(batches))
        if trainer.step_num % trainer.cfg.log_every == 0:
            rec = {"step": trainer.step_num, "loss": loss,
                   "sec": time.perf_counter() - t0}
            # sparse-path health: overflow counter + cache-tier hit
            # rate/evictions (HybridTrainer; cached placement only)
            sparse_metrics = getattr(trainer, "sparse_metrics", None)
            if sparse_metrics is not None:
                rec.update(sparse_metrics())
            if eval_fn:
                rec["eval"] = eval_fn(trainer)
            trainer.history.append(rec)
    if trainer.ckpt:
        trainer.ckpt.wait()
    return trainer.history


class DenseTrainer:
    """All-dense models: k-step Adam over podded replicas."""

    def __init__(
        self,
        loss_fn: Callable[[Pytree, Dict], jnp.ndarray],
        params: Pytree,
        cfg: TrainerConfig,
        mesh: Optional[jax.sharding.Mesh] = None,
        param_shardings: Optional[Pytree] = None,
    ):
        self.cfg = cfg
        self.n_pod = cfg.n_pod
        self.mesh = mesh
        self.params = pod_replicate(params, cfg.n_pod)
        if param_shardings is not None:
            self.params = jax.tree.map(jax.device_put, self.params, param_shardings)
        self.opt = KStepAdam(cfg.kstep, cfg.n_pod, mesh=mesh)
        self.opt_state = self.opt.init(self.params)
        self.step_num = 0
        self.ckpt = (
            CheckpointManager(cfg.ckpt_dir, cfg.ckpt_keep, cfg.ckpt_every, cfg.ckpt_async)
            if cfg.ckpt_dir else None
        )
        self._loss_fn = loss_fn
        donate = (0, 2) if cfg.donate else ()
        self._local = jax.jit(self._make_step(merge=False), donate_argnums=donate)
        self._merge = jax.jit(self._make_step(merge=True), donate_argnums=donate)
        self.history: list = []

    def _make_step(self, merge: bool):
        def step(params, batch_podded, opt_state):
            def total_loss(p):
                losses = jax.vmap(lambda pi, bi: self._loss_fn(pi, bi))(p, batch_podded)
                return jnp.sum(losses), losses
            grads, losses = jax.grad(total_loss, has_aux=True)(params)
            new_p, new_s = self.opt.step(params, grads, opt_state, merge=merge)
            return new_p, new_s, jnp.mean(losses)
        return step

    def pod_batch(self, batch: Dict[str, np.ndarray]) -> Dict[str, jnp.ndarray]:
        return pod_batch(batch, self.n_pod)

    def train_step(self, batch, podded: bool = False) -> float:
        """``podded=True``: batch leaves already carry the leading pod dim
        (e.g. full-graph training where each pod sees the same graph)."""
        self.step_num += 1
        is_merge = (self.step_num % self.cfg.kstep.k) == 0
        fn = self._merge if is_merge else self._local
        pb = jax.tree.map(jnp.asarray, batch) if podded else self.pod_batch(batch)
        self.params, self.opt_state, loss = fn(self.params, pb, self.opt_state)
        if self.ckpt and self.ckpt.should_save(self.step_num):
            self.save()
        return float(loss)

    # ----------------------------------------------------- fault tolerance
    def _ckpt_tree(self):
        tree = {"params": self.params, "m": self.opt_state.m,
                "v_local": self.opt_state.v_local, "v_hat": self.opt_state.v_hat}
        if self.opt_state.ef is not None:
            # int8_ef merge: the error-feedback residual is state — dropping
            # it on restart silently re-zeros the compensation.
            tree["ef"] = self.opt_state.ef
        return tree

    def save(self):
        self.ckpt.save(
            self.step_num, self._ckpt_tree(),
            meta={"n_pod": self.n_pod, "k": self.cfg.kstep.k},
        )

    def resume(self) -> bool:
        if not self.ckpt:
            return False
        like = _drop_ef_if_absent(self._ckpt_tree(), self.ckpt)
        step, tree = self.ckpt.restore_latest(like)
        if step is None:
            return False
        self.step_num = step
        self.params = tree["params"]
        self.opt_state = self.opt_state._replace(
            step=jnp.asarray(step, jnp.int32), m=tree["m"],
            v_local=tree["v_local"], v_hat=tree["v_hat"],
            ef=tree.get("ef", self.opt_state.ef),
        )
        return True

    def fit(self, batches: Iterator, steps: int, eval_fn=None) -> list:
        return _fit_loop(self, batches, steps, eval_fn)


class HybridTrainer:
    """Dense tower (k-step Adam, podded) + sparse tables behind an
    ``EmbeddingEngine`` — the paper's production regime.

    Parameters
    ----------
    dense_params: the dense tower's parameter pytree (un-podded).
    engine: owns TableSpecs, capacity, the sparse optimizer, and the
        placement backend; the trainer never touches raw tables directly.
    embed_fn(workings, invs, batch): build model inputs from pulled rows
        (``workings[name]`` = ``WorkingSet.rows``, ``invs[name]`` = the
        inverse map restricted to this pod's batch shard).
    loss_fn(dense, emb, batch, predict=False): dense-side loss given
        embeddings (``predict=True`` returns scores).
    tables: optional pre-initialized tables IN THE BACKEND'S LAYOUT
        (e.g. from ``engine.init`` or ``engine.prepare``); ``None`` lets the
        engine initialize them from ``rng``.
    """

    def __init__(
        self,
        dense_params: Pytree,
        engine: EmbeddingEngine,
        embed_fn: Callable,
        loss_fn: Callable,
        cfg: TrainerConfig,
        mesh: Optional[jax.sharding.Mesh] = None,
        tables: Optional[Dict[str, jnp.ndarray]] = None,
        rng: Optional[jax.Array] = None,
    ):
        self.cfg = cfg
        self.n_pod = cfg.n_pod
        self.mesh = mesh
        self.engine = engine
        self.dense = pod_replicate(dense_params, cfg.n_pod)
        self.tables = (
            tables if tables is not None
            else engine.init(rng if rng is not None else jax.random.key(0))
        )
        self.opt = KStepAdam(cfg.kstep, cfg.n_pod, mesh=mesh)
        self.opt_state = self.opt.init(self.dense)
        self.sparse_state = engine.init_state(self.tables)
        # per-table backend state (cache-tier id->slot map/counters/rows;
        # empty tuples for the stateless placements) — threaded through the
        # compiled step and checkpointed alongside the tables.
        self.backend_state = engine.init_backend_state(self.tables)
        self.step_num = 0
        self.overflow_dropped = 0   # cumulative unserved pull/push requests
        self._embed = embed_fn
        self._loss = loss_fn
        self.ckpt = (
            CheckpointManager(cfg.ckpt_dir, cfg.ckpt_keep, cfg.ckpt_every, cfg.ckpt_async)
            if cfg.ckpt_dir else None
        )
        self._step_local = jax.jit(self._make_step(False))
        self._step_merge = jax.jit(self._make_step(True))
        self.history: list = []

    def _make_step(self, merge: bool):
        def step(dense, tables, accum, bstate, batch, batch_podded, opt_state):
            # ---- PULL (Algorithm 1 line 3): engine dedups + gathers/routes/
            # serves from cache.  tables/accum come back because a cache-tier
            # pull spills evicted dirty rows into the host table.
            wss, tables, accum, bstate = self.engine.pull_batch(
                tables, accum, bstate, batch
            )
            workings = {n: ws.rows for n, ws in wss.items()}
            # inverse indices sliced per pod so each replica embeds only its
            # own batch shard (vmapped leading pod dim)
            invs_podded = {
                n: ws.inverse.reshape(self.n_pod, -1) for n, ws in wss.items()
            }

            # ---- local fwd/bwd on the working set (line 12)
            def total_loss(dense_p, w):
                def per_pod(dp, bp, inv_p):
                    emb = self._embed(w, inv_p, bp)
                    return self._loss(dp, emb, bp)
                losses = jax.vmap(per_pod, in_axes=(0, 0, 0))(
                    dense_p, batch_podded, invs_podded
                )
                return jnp.sum(losses), losses

            (dense_g, work_g), losses = jax.grad(total_loss, argnums=(0, 1), has_aux=True)(
                dense, workings
            )
            # sparse grads are summed over pods by autodiff; average them
            # (paper: sparse side synchronized every iteration).
            work_g = jax.tree.map(lambda g: g / self.n_pod, work_g)

            # ---- dense k-step Adam
            new_dense, new_opt = self.opt.step(dense, dense_g, opt_state, merge=merge)

            # ---- PUSH (line 13): backend scatters/routes the row updates.
            new_tables, new_accum, bstate = self.engine.push(
                tables, accum, bstate, wss, work_g
            )
            return (new_dense, new_tables, new_accum, bstate, new_opt,
                    jnp.mean(losses), self.engine.overflow(wss))

        return step

    def pod_batch(self, batch):
        return pod_batch(batch, self.n_pod)

    def train_step(self, batch) -> float:
        self.step_num += 1
        is_merge = (self.step_num % self.cfg.kstep.k) == 0
        fn = self._step_merge if is_merge else self._step_local
        batch = jax.tree.map(jnp.asarray, batch)
        (self.dense, self.tables, accum, self.backend_state, self.opt_state,
         loss, dropped) = fn(
            self.dense, self.tables, self.sparse_state.accum,
            self.backend_state, batch, self.pod_batch(batch), self.opt_state,
        )
        self.sparse_state = self.sparse_state._replace(accum=accum)
        self.overflow_dropped += int(dropped)
        if self.ckpt and self.ckpt.should_save(self.step_num):
            self.save()
        return float(loss)

    def predict(self, batch) -> np.ndarray:
        """Inference with pod-0's dense replica (online predict-then-train).

        Reads through the sparse path without committing its side effects:
        cache admissions/spills from the inference pull are discarded, so
        predict never perturbs training state (misses are still served —
        the pull fetches from the authoritative host rows)."""
        batch = jax.tree.map(jnp.asarray, batch)
        dense0 = pod_slice(self.dense, 0)
        wss, _, _, _ = self.engine.pull_batch(
            self.tables, self.sparse_state.accum, self.backend_state, batch
        )
        workings = {n: ws.rows for n, ws in wss.items()}
        invs = {n: ws.inverse for n, ws in wss.items()}
        emb = self._embed(workings, invs, batch)
        return np.asarray(self._loss(dense0, emb, batch, predict=True))

    def sparse_metrics(self) -> Dict[str, float]:
        """Sparse-path health counters for trainer history/monitoring:
        cumulative ``overflow_dropped`` plus, under the cached placement,
        ``cache_hit_rate``/``evictions``/host<->device byte counters."""
        m: Dict[str, float] = {"overflow_dropped": self.overflow_dropped}
        m.update(self.engine.cache_stats(self.backend_state))
        return m

    def suggest_capacity(self, history=None, safety: float = 1.25) -> int:
        """Recommend a dedup capacity from observed overflow (the first step
        of overflow-aware capacity autoscaling).

        Reads the ``overflow_dropped`` series from ``history`` (default: this
        trainer's own ``fit`` history): with no drops the current capacity
        stands; otherwise grow to the next power of two covering the current
        capacity plus ``safety`` x the worst observed per-step drop rate
        (powers of two keep routed shard divisibility).
        """
        hist = self.history if history is None else history
        worst = 0.0
        prev_step, prev_drop = 0, 0.0
        for rec in hist:
            if "overflow_dropped" not in rec:
                continue
            d_steps = rec["step"] - prev_step
            if d_steps > 0:
                worst = max(
                    worst, (rec["overflow_dropped"] - prev_drop) / d_steps
                )
            prev_step, prev_drop = rec["step"], rec["overflow_dropped"]
        if not hist and self.step_num > 0:
            # no logged records yet: fall back to the cumulative average
            worst = self.overflow_dropped / self.step_num
        if worst <= 0:
            return self.engine.capacity
        need = self.engine.capacity + safety * worst
        cap = 1
        while cap < need:
            cap <<= 1
        return cap

    def fit(self, batches: Iterator, steps: int, eval_fn=None) -> list:
        return _fit_loop(self, batches, steps, eval_fn)

    # ----------------------------------------------------- fault tolerance
    def _ckpt_tree(self):
        tree = {"dense": self.dense, "tables": self.tables,
                "accum": self.sparse_state.accum, "m": self.opt_state.m,
                "v_local": self.opt_state.v_local, "v_hat": self.opt_state.v_hat}
        if self.opt_state.ef is not None:
            tree["ef"] = self.opt_state.ef
        if jax.tree.leaves(self.backend_state):
            # cache-tier (or other stateful-placement) state is training
            # state: host tables alone are stale while rows sit dirty in the
            # device cache, so the cache must roundtrip with them.
            tree["bstate"] = self.backend_state
        return tree

    def _backend_sig(self):
        """Identity of the sparse physical layout baked into the tables
        (+ cache geometry, which shapes the checkpointed backend state)."""
        b = self.engine.backend
        sig = {"backend": type(b).__name__,
               "n_shards": getattr(b, "n_shards", 1)}
        cache_rows = getattr(b, "cache_rows", None)
        if cache_rows is not None:
            sig["cache_rows"] = int(cache_rows)
        return sig

    def save(self):
        self.ckpt.save(
            self.step_num, self._ckpt_tree(),
            meta={"n_pod": self.n_pod, "k": self.cfg.kstep.k,
                  **self._backend_sig()},
        )

    def resume(self) -> bool:
        if not self.ckpt:
            return False
        # Tables are checkpointed in the backend's physical layout; loading
        # them under a different backend (or routed shard count, which
        # changes the hash-slot permutation; or a cached run's host tables,
        # which are stale wherever rows sat dirty in the device cache)
        # would silently read wrong rows.
        s = latest_step(self.ckpt.directory)
        man = read_manifest(self.ckpt.directory, s) if s is not None else None
        if man is not None and "backend" in man.get("meta", {}):
            sig = self._backend_sig()
            saved = {k: man["meta"][k]
                     for k in ("backend", "n_shards", "cache_rows")
                     if k in man["meta"]}
            if saved != {k: sig.get(k) for k in saved} or (
                "cache_rows" in sig and "cache_rows" not in saved
            ):
                raise ValueError(
                    f"checkpoint written with {saved} but the current engine "
                    f"uses {sig}: the tables' physical "
                    f"layouts differ — resume with the saving placement, or "
                    f"export/re-prepare the tables explicitly"
                )
        like = _drop_ef_if_absent(self._ckpt_tree(), self.ckpt)
        step, tree = self.ckpt.restore_latest(like)
        if step is None:
            return False
        self.step_num = step
        self.dense, self.tables = tree["dense"], tree["tables"]
        self.sparse_state = self.sparse_state._replace(accum=tree["accum"])
        self.backend_state = tree.get("bstate", self.backend_state)
        self.opt_state = self.opt_state._replace(
            step=jnp.asarray(step, jnp.int32), m=tree["m"],
            v_local=tree["v_local"], v_hat=tree["v_hat"],
            ef=tree.get("ef", self.opt_state.ef),
        )
        return True
