from repro.runtime.trainer import DenseTrainer, HybridTrainer, TrainerConfig  # noqa: F401
from repro.runtime.metrics import auc  # noqa: F401
