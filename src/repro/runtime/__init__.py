from repro.runtime.trainer import (  # noqa: F401
    DenseTrainer,
    HybridTrainer,
    TrainerConfig,
    pod_batch,
)
from repro.runtime.factory import build_trainer  # noqa: F401
from repro.runtime.metrics import auc  # noqa: F401
from repro.runtime.online import fit_online  # noqa: F401
