"""Co-located CTR serving tier over the trainer's LIVE embedding state.

The paper's deployment serves ads models from the same parameter servers
that train them; here the analogue is a recsys inference server that reads
the ``HybridTrainer``'s live tables through the engine's READ-ONLY lookup
contract (``HybridTrainer.predict``) — a row trained at step t is servable
at the next prefetch-commit boundary, with zero effect on the training
trajectory or the training-interval stats.

Structure mirrors ``serve.BatchedServer``'s static-slot pattern: requests
enter a FIFO deque, the server drains them in dynamic batches of up to
``max_batch`` instances, and ONE compiled predict executable handles every
batch — a short tail batch is padded up to ``max_batch`` by repeating a
valid instance (the pad scores are computed and discarded host-side), so
occupancy never changes the executable, only which outputs are kept.

Thread-safety: the server is driven from the training loop's thread (the
co-located scenario interleaves ``drain()`` at commit boundaries); it is
not itself a network listener.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class PredictRequest:
    """One inference instance: a feature dict WITHOUT the batch dim (and
    without a label — serving traffic is unlabeled; ``requests_from_batch``
    strips it)."""
    features: Dict[str, np.ndarray]
    score: Optional[float] = None       # filled by the server
    latency: Optional[float] = None     # submit -> scored, seconds
    _t_submit: float = 0.0


def requests_from_batch(batch: Dict[str, np.ndarray]) -> List[PredictRequest]:
    """Split a (B, ...) training-format batch into B single-instance
    requests, dropping ``label`` (a serving request has none)."""
    feats = {k: np.asarray(v) for k, v in batch.items() if k != "label"}
    n = next(iter(feats.values())).shape[0]
    return [PredictRequest({k: v[i] for k, v in feats.items()})
            for i in range(n)]


class CTRServer:
    """Dynamic-batching CTR scorer on a live ``HybridTrainer``.

    One compiled executable: every drained batch is exactly ``max_batch``
    instances (tail batches pad by repeating instance 0 of the batch), so
    ``trainer.predict`` — and the read-only lookup stage under it — never
    recompiles for occupancy.  Stats mirror ``BatchedServer.stats``:
    ``served`` (requests scored, pads excluded), ``steps`` (predict calls),
    ``wall`` (seconds inside predict); per-request latencies accumulate in
    ``self.latencies`` for the percentile summary.
    """

    def __init__(self, trainer, max_batch: int = 64):
        self.trainer = trainer
        self.max_batch = int(max_batch)
        self.pending: Deque[PredictRequest] = collections.deque()
        self.stats = {"served": 0, "steps": 0, "wall": 0.0}
        self.latencies: List[float] = []

    def submit(self, req: PredictRequest) -> None:
        req._t_submit = time.perf_counter()
        self.pending.append(req)

    def submit_batch(self, batch: Dict[str, np.ndarray]) -> None:
        for req in requests_from_batch(batch):
            self.submit(req)

    def step(self) -> bool:
        """Score one dynamic batch off the queue head. False when idle."""
        if not self.pending:
            return False
        reqs = [self.pending.popleft()
                for _ in range(min(self.max_batch, len(self.pending)))]
        # pad the tail up to max_batch with copies of a real instance: the
        # executable sees one static batch shape; pad scores are dropped
        feats = reqs[0].features
        batch = {
            k: np.stack([r.features[k] for r in reqs]
                        + [feats[k]] * (self.max_batch - len(reqs)))
            for k in feats
        }
        t0 = time.perf_counter()
        scores = self.trainer.predict(batch)
        t1 = time.perf_counter()
        self.stats["wall"] += t1 - t0
        self.stats["steps"] += 1
        self.stats["served"] += len(reqs)
        for i, req in enumerate(reqs):
            req.score = float(scores[i])
            req.latency = t1 - req._t_submit
            self.latencies.append(req.latency)
        return True

    def drain(self) -> int:
        """Serve until the queue is empty; returns requests scored."""
        before = self.stats["served"]
        while self.step():
            pass
        return self.stats["served"] - before

    def latency_percentiles(self) -> Dict[str, float]:
        """{p50, p99} over per-request submit->scored latency, seconds."""
        if not self.latencies:
            return {"p50": 0.0, "p99": 0.0}
        arr = np.asarray(self.latencies)
        return {"p50": float(np.percentile(arr, 50)),
                "p99": float(np.percentile(arr, 99))}

    def summary(self) -> Dict[str, float]:
        """Throughput + latency + serve-side lookup meters, one dict."""
        out: Dict[str, float] = {
            "served": float(self.stats["served"]),
            "steps": float(self.stats["steps"]),
            "wall_s": float(self.stats["wall"]),
            "qps": (self.stats["served"] / self.stats["wall"]
                    if self.stats["wall"] > 0 else 0.0),
        }
        out.update(self.latency_percentiles())
        out.update(self.trainer.serve_metrics())
        return out
