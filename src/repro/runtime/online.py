"""Online predict-then-train loop (paper §5 evaluation protocol).

One canonical copy of the loop the launcher and the examples used to
hand-roll: score each incoming batch with the CURRENT model, then train on
it — the production regime where every ad impression is first served, then
learned from.  Works with any trainer the factory builds:

  - ``trainer.prefetch(b)`` dispatches the batch's working-set pull before
    the predict/train pair (a no-op unless ``TrainerConfig.prefetch``;
    predictions legally read the in-flight pull's pass-through state),
  - unlabeled streams (two-tower retrieval) skip the scoring side and train
    only — ``fit_online`` then returns ``auc=None``,
  - ``strict_transfers=True`` (launcher: ``--strict-transfers``) wraps each
    predict/train pair in ``jax.transfer_guard("disallow")``: any IMPLICIT
    host<->device transfer in the hot path raises immediately with the
    offending op — the runtime arm of the ``repro.analysis`` sync audit.
    Deliberate crossings stay legal because they are explicit: batch staging
    uses ``jax.device_put``, score/loss materialization uses
    ``jax.device_get``, and checkpoint writes run in a transfer-allowed
    section.  Logging boundaries (``history_record``) run OUTSIDE the guard
    — materializing the interval's metrics there is the contract.

History records land in ``trainer.history`` exactly like ``fit``'s, plus an
``auc`` key for labeled streams.
"""

from __future__ import annotations

import contextlib
import time
from typing import Iterator, Optional, Tuple

import jax

from repro.runtime.metrics import StreamingAUC
from repro.runtime.trainer import history_record


def _format_record(rec: dict, steps_this_run: int) -> str:
    parts = [f"step {rec['step']:5d}", f"loss {rec['loss']:.4f}"]
    if "auc" in rec:
        parts.append(f"AUC {rec['auc']:.4f}")
    if "cache_hit_rate" in rec:
        parts.append(f"cache_hit {rec['cache_hit_rate']:.3f}")
    if rec.get("overflow_dropped", 0):
        parts.append(f"dropped {rec['overflow_dropped']}")
    # throughput of THIS run: rec["step"] is the global (resume-inclusive)
    # counter, but rec["sec"] only spans this loop
    parts.append(f"{steps_this_run / max(rec['sec'], 1e-9):.1f} steps/s")
    return "  ".join(parts)


def fit_online(
    trainer,
    batches: Iterator,
    steps: int,
    window: int = 30,
    log=None,
    strict_transfers: bool = False,
) -> Tuple[list, Optional[float]]:
    """Predict-then-train ``steps`` batches; returns ``(history, auc)``.

    ``auc`` is the streaming AUC over the last ``window`` scored batches
    (``None`` when the stream carries no labels).  ``log`` (e.g. ``print``)
    receives one formatted line per ``TrainerConfig.log_every`` boundary.
    ``strict_transfers`` fails fast on any implicit host<->device transfer
    inside the predict/train hot path (debug gate; see module docstring).
    """
    meter = StreamingAUC(window=window)
    scored = False
    loss = None
    start_step = trainer.step_num
    t0 = time.perf_counter()
    prefetch = getattr(trainer, "prefetch", None)
    guard = ((lambda: jax.transfer_guard("disallow")) if strict_transfers
             else contextlib.nullcontext)

    def _record():
        rec = history_record(trainer, loss, t0)   # fit's record schema
        if scored:
            rec["auc"] = meter.value()
        trainer.history.append(rec)
        if log:
            log(_format_record(rec, trainer.step_num - start_step))

    for _ in range(steps):
        try:
            b = next(batches)
        except StopIteration:
            break   # finite stream shorter than steps: finish cleanly
        with guard():
            if prefetch is not None:
                prefetch(b)
            scores = trainer.predict(b) if "label" in b else None
            loss = trainer.train_step(b)
        if scores is not None:
            # meter update happens OUTSIDE the guard: predict() already
            # materialized scores host-side via an explicit device_get
            meter.update(b["label"], scores)
            scored = True
        if trainer.step_num % trainer.cfg.log_every == 0:
            _record()
    if loss is not None and (
        not trainer.history or trainer.history[-1]["step"] != trainer.step_num
    ):
        _record()   # short runs (steps < log_every) still get a final record
    if trainer.ckpt:
        trainer.ckpt.wait()   # surface async-writer failures at loop exit
    return trainer.history, (meter.value() if scored else None)
