"""Batched LM serving loop: prefill + decode with a static-slot batch.

A minimal continuous-batching server: requests occupy slots; finished slots
(EOS or max tokens) are refilled from the queue between decode steps.  The
device-side ``decode_step`` is a single compiled executable regardless of
slot occupancy (inactive slots decode padding and are ignored host-side).
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm


@dataclasses.dataclass
class Request:
    prompt: np.ndarray           # (P,) int32
    max_new_tokens: int = 16
    out: Optional[List[int]] = None


class BatchedServer:
    def __init__(self, params, cfg: tfm.TransformerConfig, slots: int, max_len: int,
                 eos_id: int = -1, greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.cache = tfm.init_cache(cfg, slots, max_len)
        # The KV cache is rewritten every decode step and the old handle is
        # dropped on reassignment — donate it, or every step materializes a
        # second full cache next to the live one (2x peak KV memory).
        self._decode = jax.jit(
            lambda p, c, t: tfm.decode_step(p, c, t, cfg),
            donate_argnums=(1,),
        )
        self.active: List[Optional[Request]] = [None] * slots
        self.remaining = np.zeros(slots, np.int64)
        # FIFO admission queue: deque, because slot refill pops from the
        # head every decode step — list.pop(0) is O(queue depth) and the
        # queue is exactly what grows under load.
        self.pending: Deque[Request] = collections.deque()
        self.tokens = np.zeros(slots, np.int32)
        self.stats = {"decoded_tokens": 0, "steps": 0, "wall": 0.0}

    def submit(self, req: Request):
        req.out = []
        self.pending.append(req)

    def _fill_slots(self):
        for i in range(self.slots):
            if self.active[i] is None and self.pending:
                req = self.pending.popleft()
                self.active[i] = req
                # Feed prompt tokens one-by-one through decode (prefill-by-
                # decode keeps one executable; long-prompt serving uses
                # tfm.prefill instead and writes the cache in one shot).
                for tok in req.prompt[:-1]:
                    _, self.cache = self._decode(
                        self.params, self.cache,
                        jnp.asarray(self.tokens).at[i].set(int(tok)),
                    )
                self.tokens[i] = int(req.prompt[-1])
                self.remaining[i] = req.max_new_tokens

    def step(self) -> bool:
        """One decode step across all slots. Returns False when idle."""
        self._fill_slots()
        if all(r is None for r in self.active):
            return False
        t0 = time.perf_counter()
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.tokens)
        )
        nxt = np.asarray(jnp.argmax(logits, axis=-1)).astype(np.int32)
        self.stats["wall"] += time.perf_counter() - t0
        self.stats["steps"] += 1
        for i in range(self.slots):
            req = self.active[i]
            if req is None:
                continue
            req.out.append(int(nxt[i]))
            self.tokens[i] = nxt[i]
            self.remaining[i] -= 1
            self.stats["decoded_tokens"] += 1
            if self.remaining[i] <= 0 or nxt[i] == self.eos_id:
                self.active[i] = None
        return True

    def run_to_completion(self) -> Dict:
        while self.step():
            pass
        return self.stats
