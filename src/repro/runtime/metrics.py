"""Streaming evaluation metrics (AUC is the paper's quality measure)."""

from __future__ import annotations

import numpy as np


def auc(labels: np.ndarray, scores: np.ndarray) -> float:
    """Exact AUC via the rank statistic (ties get average rank)."""
    labels = np.asarray(labels).astype(np.float64).reshape(-1)
    scores = np.asarray(scores).astype(np.float64).reshape(-1)
    n_pos = labels.sum()
    n_neg = len(labels) - n_pos
    if n_pos == 0 or n_neg == 0:
        return 0.5
    order = np.argsort(scores, kind="mergesort")
    ranks = np.empty_like(scores)
    ranks[order] = np.arange(1, len(scores) + 1)
    # average ranks for ties
    sorted_scores = scores[order]
    i = 0
    while i < len(scores):
        j = i
        while j + 1 < len(scores) and sorted_scores[j + 1] == sorted_scores[i]:
            j += 1
        if j > i:
            ranks[order[i:j + 1]] = (i + j + 2) / 2.0
        i = j + 1
    return float((ranks[labels == 1].sum() - n_pos * (n_pos + 1) / 2) / (n_pos * n_neg))


class StreamingAUC:
    """Online-learning evaluation (paper §5 Data: predict-then-train)."""

    def __init__(self, window: int = 0):
        self.labels: list = []
        self.scores: list = []
        self.window = window

    def update(self, labels, scores):
        self.labels.append(np.asarray(labels).reshape(-1))
        self.scores.append(np.asarray(scores).reshape(-1))
        if self.window and len(self.labels) > self.window:
            self.labels.pop(0)
            self.scores.pop(0)

    def value(self) -> float:
        if not self.labels:
            return 0.5
        return auc(np.concatenate(self.labels), np.concatenate(self.scores))
