"""Config-driven trainer construction: ``build_trainer(arch, TrainerConfig)``.

One entry point from the ``repro.configs`` registry to a ready trainer, so
examples, benchmarks, and ``repro.launch.train`` stop hand-rolling model
construction:

    tcfg = TrainerConfig(n_pod=2, placement="routed")
    tr = build_trainer("baidu-ctr", tcfg)        # HybridTrainer
    tr.fit(S.ctr_batches(...), steps=200)

Family wiring:
  - ``lm``  -> DenseTrainer over ``repro.models.transformer``
  - ``gnn`` -> DenseTrainer over ``repro.models.gin``
  - ``recsys`` -> HybridTrainer for EVERY registered recsys arch —
    ``baidu-ctr``, ``dlrm-mlperf``, ``din``, ``dien``, and
    ``two-tower-retrieval``: an ``EmbeddingEngine`` built from the arch's
    ``*_table_specs`` (single giant table, DLRM's 26 per-feature tables, or
    the DIN/two-tower history+target split — see ``TableSpec.id_field``/
    ``id_col``) with the backend selected by ``TrainerConfig.placement``
    ("gather" | "routed" | "cached" — the cache tier sizes its device cache
    from ``TrainerConfig.cache_rows``), plus the arch's canonical
    ``*_embed_from_workings``/``*_hybrid_loss`` adapters from
    ``repro.models.recsys``.  ``TrainerConfig.prefetch`` turns on the
    double-buffered pull prefetch (any placement, bit-identical results);
    dense families reject it.  ``TrainerConfig.fused_kernels`` selects the
    fused Pallas sparse pull/push + bag kernels (None = auto: on for real
    TPU backends — ``kernels.ops.resolve_fused``), threaded to the backend
    and the embed adapters; DenseTrainer rejects an explicit True.

``model_cfg`` overrides the registry's smoke/full config (used by examples
that scale the table up or down).
"""

from __future__ import annotations

from typing import Any, Optional

import jax

from repro import configs
from repro.core.embedding_backend import make_backend
from repro.core.embedding_engine import EmbeddingEngine
from repro.core.sparse_optim import SparseAdagrad
from repro.runtime.trainer import (
    DenseTrainer,
    HybridTrainer,
    TrainerConfig,
    next_pow2,
)

# Bounds the deduplicated ids of one global batch at smoke/example scales
# (batch 1k x nnz 100 Zipf draws stay well under this); per-arch defaults
# clamp it to the table size (a 200-row smoke table never needs a 16k pull).
DEFAULT_CTR_CAPACITY = 1 << 14


def _default_capacity(max_rows: int) -> int:
    return next_pow2(min(DEFAULT_CTR_CAPACITY, max_rows))


def _build_engine(
    specs,
    cfg: TrainerConfig,
    mesh: Optional[jax.sharding.Mesh],
) -> EmbeddingEngine:
    """Placement-selected engine over ``specs`` (shared by all recsys archs)."""
    from repro.core.row_store import make_store

    capacity = cfg.capacity or _default_capacity(
        max(s.rows for s in specs.values())
    )
    # ---- cold-tier store (three-level hierarchy when store="disk")
    if cfg.store == "host" and (
        cfg.page_rows is not None or cfg.page_cache_pages is not None
    ):
        # no-silent-config: page geometry without the disk tier is a
        # mis-specified experiment, not a default to ignore
        raise ValueError(
            "page_rows/page_cache_pages are disk-store knobs — set "
            "store='disk' (with spill_dir) to use them"
        )
    if cfg.store == "disk" and cfg.placement == "routed":
        raise NotImplementedError(
            "store='disk' with placement='routed' is not implemented: the "
            "routed exchange addresses shard-resident rows, which the "
            "staged working-set dataflow does not provide — use 'gather' "
            "or 'cached'"
        )
    store = make_store(
        cfg.store, spill_dir=cfg.spill_dir,
        page_rows=cfg.page_rows if cfg.page_rows is not None else 1024,
        page_cache_pages=cfg.page_cache_pages,
    )

    kwargs = {}
    if store.kind == "disk":
        kwargs["staged"] = True
        if cfg.placement == "cached":
            kwargs["capacity"] = capacity   # sizes the per-pull spill buffers
    if cfg.placement == "cached":
        # default to the minimum feasible cache (one batch's working set);
        # an EXPLICIT undersized cache_rows is an error, not a silent clamp
        # (a cache-size experiment must run with the cache it asked for)
        if cfg.cache_rows and cfg.cache_rows < capacity:
            raise ValueError(
                f"cache_rows ({cfg.cache_rows}) must cover the working-set "
                f"capacity ({capacity}): one batch's pull must fit in the "
                f"device cache"
            )
        kwargs["cache_rows"] = cfg.cache_rows or capacity
    from repro.kernels import ops

    return EmbeddingEngine(
        specs,
        capacity=capacity,
        optimizer=SparseAdagrad(cfg.sparse),
        backend=make_backend(
            cfg.placement, mesh=mesh,
            fused=ops.resolve_fused(cfg.fused_kernels), **kwargs,
        ),
        store=store,
    )


def build_ctr_engine(model_cfg, cfg, mesh=None) -> EmbeddingEngine:
    """EmbeddingEngine for the paper's CTR model, placement-selected."""
    from repro.models import recsys as R

    return _build_engine(R.ctr_table_specs(model_cfg), cfg, mesh)


def build_dlrm_engine(model_cfg, cfg, mesh=None) -> EmbeddingEngine:
    """DLRM: 26 per-feature tables sharing the (B, 26) ``sparse_ids`` field."""
    from repro.models import recsys as R

    return _build_engine(R.dlrm_table_specs(model_cfg), cfg, mesh)


def build_din_engine(model_cfg, cfg, mesh=None) -> EmbeddingEngine:
    """DIN/DIEN: one item table fed by history + target ids."""
    from repro.models import recsys as R

    return _build_engine(R.din_table_specs(model_cfg), cfg, mesh)


def build_two_tower_engine(model_cfg, cfg, mesh=None) -> EmbeddingEngine:
    """Two-tower retrieval: one item table fed by user history + item ids."""
    from repro.models import recsys as R

    return _build_engine(R.two_tower_table_specs(model_cfg), cfg, mesh)


def _recsys_wiring(mcfg):
    """(init_dense, build_engine, embed_adapter, loss_adapter) for a recsys
    model config — dispatched on the config type so ``model_cfg`` overrides
    and dien (a DINConfig with ``gru_dim > 0``) route correctly."""
    from repro.models import recsys as R

    wiring = {
        R.CTRConfig: (R.ctr_init_dense, build_ctr_engine,
                      R.ctr_embed_from_workings, R.ctr_hybrid_loss),
        R.DLRMConfig: (R.dlrm_init_dense, build_dlrm_engine,
                       R.dlrm_embed_from_workings, R.dlrm_hybrid_loss),
        R.DINConfig: (R.din_init_dense, build_din_engine,
                      R.din_embed_from_workings, R.din_hybrid_loss),
        R.TwoTowerConfig: (R.two_tower_init_dense, build_two_tower_engine,
                           R.two_tower_embed_from_workings,
                           R.two_tower_hybrid_loss),
    }
    for cls, w in wiring.items():
        if isinstance(mcfg, cls):
            return w
    raise TypeError(
        f"build_trainer: unknown recsys model config {type(mcfg).__name__} "
        f"(expected one of {sorted(c.__name__ for c in wiring)})"
    )


def build_trainer(
    arch: str,
    cfg: TrainerConfig,
    *,
    smoke: bool = True,
    mesh: Optional[jax.sharding.Mesh] = None,
    seed: int = 0,
    model_cfg: Any = None,
    table_scale: float = 0.05,
):
    """Construct the trainer for ``arch`` from the config registry."""
    spec = configs.get(arch)
    mcfg = model_cfg if model_cfg is not None else (
        spec.smoke_cfg if smoke else spec.model_cfg
    )
    rng = jax.random.key(seed)

    if spec.family == "lm":
        from repro.models import transformer as T

        params = T.init_params(rng, mcfg)
        return DenseTrainer(lambda p, b: T.loss_fn(p, b, mcfg), params, cfg, mesh=mesh)

    if spec.family == "gnn":
        from repro.models import gin as G

        params = G.init_params(rng, mcfg)
        return DenseTrainer(lambda p, b: G.loss_fn(p, b, mcfg), params, cfg, mesh=mesh)

    if spec.family == "recsys":
        from repro.kernels import ops

        init_dense, build_engine, embed_of, loss_of = _recsys_wiring(mcfg)
        dense = init_dense(rng, mcfg)
        engine = build_engine(mcfg, cfg, mesh=mesh)
        tables = engine.init(rng, scale=table_scale)
        fused = ops.resolve_fused(cfg.fused_kernels)
        return HybridTrainer(
            dense, engine, embed_of(mcfg, fused=fused), loss_of(mcfg),
            cfg, mesh=mesh, tables=tables,
        )

    raise ValueError(f"build_trainer: unknown family {spec.family!r} for {arch!r}")


def build_ctr_server(trainer, max_batch: int = 64):
    """Co-located serving tier over a live ``HybridTrainer`` (the trainer
    the server reads IS the trainer that keeps training — see
    ``runtime.serve_ctr``).  Dense families have no sparse state to share
    and use ``runtime.serve.BatchedServer`` instead."""
    from repro.runtime.serve_ctr import CTRServer

    if not isinstance(trainer, HybridTrainer):
        raise TypeError(
            "build_ctr_server: co-located CTR serving reads a "
            f"HybridTrainer's live embedding state, got "
            f"{type(trainer).__name__}"
        )
    return CTRServer(trainer, max_batch=max_batch)
