"""Config-driven trainer construction: ``build_trainer(arch, TrainerConfig)``.

One entry point from the ``repro.configs`` registry to a ready trainer, so
examples, benchmarks, and ``repro.launch.train`` stop hand-rolling model
construction:

    tcfg = TrainerConfig(n_pod=2, placement="routed")
    tr = build_trainer("baidu-ctr", tcfg)        # HybridTrainer
    tr.fit(S.ctr_batches(...), steps=200)

Family wiring:
  - ``lm``  -> DenseTrainer over ``repro.models.transformer``
  - ``gnn`` -> DenseTrainer over ``repro.models.gin``
  - ``recsys`` (baidu-ctr) -> HybridTrainer: an ``EmbeddingEngine`` built
    from ``ctr_table_specs`` with the backend selected by
    ``TrainerConfig.placement`` ("gather" | "routed" | "cached" — the
    cache tier sizes its device cache from ``TrainerConfig.cache_rows``),
    and the canonical embed/loss adapters from ``repro.models.recsys``.
    ``TrainerConfig.prefetch`` turns on the double-buffered pull prefetch
    (any placement, bit-identical results); dense families reject it.

``model_cfg`` overrides the registry's smoke/full config (used by examples
that scale the table up or down); other recsys archs (dlrm/din/dien/
two-tower) keep their example drivers until their working-set adapters are
added (ROADMAP open item).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax

from repro import configs
from repro.core.embedding_backend import make_backend
from repro.core.embedding_engine import EmbeddingEngine, TableSpec
from repro.core.sparse_optim import SparseAdagrad
from repro.runtime.trainer import DenseTrainer, HybridTrainer, TrainerConfig

# Bounds the deduplicated ids of one global batch for CTR smoke shapes
# (batch 1k x nnz 100 Zipf draws stay well under this).
DEFAULT_CTR_CAPACITY = 1 << 14


def build_ctr_engine(
    model_cfg,
    cfg: TrainerConfig,
    mesh: Optional[jax.sharding.Mesh] = None,
) -> EmbeddingEngine:
    """EmbeddingEngine for the paper's CTR model, placement-selected."""
    from repro.models import recsys as R

    specs = {
        name: dataclasses.replace(s, id_field="ids")
        for name, s in R.ctr_table_specs(model_cfg).items()
    }
    capacity = cfg.capacity or DEFAULT_CTR_CAPACITY
    kwargs = {}
    if cfg.placement == "cached":
        # default to the minimum feasible cache (one batch's working set);
        # an EXPLICIT undersized cache_rows is an error, not a silent clamp
        # (a cache-size experiment must run with the cache it asked for)
        if cfg.cache_rows and cfg.cache_rows < capacity:
            raise ValueError(
                f"cache_rows ({cfg.cache_rows}) must cover the working-set "
                f"capacity ({capacity}): one batch's pull must fit in the "
                f"device cache"
            )
        kwargs["cache_rows"] = cfg.cache_rows or capacity
    return EmbeddingEngine(
        specs,
        capacity=capacity,
        optimizer=SparseAdagrad(cfg.sparse),
        backend=make_backend(cfg.placement, mesh=mesh, **kwargs),
    )


def build_trainer(
    arch: str,
    cfg: TrainerConfig,
    *,
    smoke: bool = True,
    mesh: Optional[jax.sharding.Mesh] = None,
    seed: int = 0,
    model_cfg: Any = None,
    table_scale: float = 0.05,
):
    """Construct the trainer for ``arch`` from the config registry."""
    spec = configs.get(arch)
    mcfg = model_cfg if model_cfg is not None else (
        spec.smoke_cfg if smoke else spec.model_cfg
    )
    rng = jax.random.key(seed)

    if spec.family == "lm":
        from repro.models import transformer as T

        params = T.init_params(rng, mcfg)
        return DenseTrainer(lambda p, b: T.loss_fn(p, b, mcfg), params, cfg, mesh=mesh)

    if spec.family == "gnn":
        from repro.models import gin as G

        params = G.init_params(rng, mcfg)
        return DenseTrainer(lambda p, b: G.loss_fn(p, b, mcfg), params, cfg, mesh=mesh)

    if arch == "baidu-ctr":
        from repro.models import recsys as R

        dense = R.ctr_init_dense(rng, mcfg)
        engine = build_ctr_engine(mcfg, cfg, mesh=mesh)
        tables = engine.init(rng, scale=table_scale)
        return HybridTrainer(
            dense, engine,
            R.ctr_embed_from_workings(mcfg), R.ctr_hybrid_loss(mcfg),
            cfg, mesh=mesh, tables=tables,
        )

    raise NotImplementedError(
        f"build_trainer: no working-set adapter for {arch!r} yet "
        f"(supported: all lm/gnn archs + baidu-ctr; dlrm/din/dien/two-tower "
        f"run through their example drivers)"
    )
