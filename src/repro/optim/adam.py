"""Reference dense optimizers (single-replica) — the non-k-step baselines the
paper compares against, and the oracles for the k-step tests (k=1, N=1 must
match these exactly)."""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any


class AdamState(NamedTuple):
    step: jnp.ndarray
    m: Pytree
    v: Pytree


@dataclasses.dataclass(frozen=True)
class Adam:
    """Adam matching Algorithm 2 at N=1 (no bias correction, v0 = eps)."""

    lr: float = 1e-3
    b1: float = 0.0
    b2: float = 0.999
    eps: float = 1e-8
    bias_correction: bool = False

    def init(self, params: Pytree) -> AdamState:
        return AdamState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params),
            v=jax.tree.map(lambda x: jnp.full(x.shape, self.eps, jnp.float32), params),
        )

    def step_fn(self, params, grads, state: AdamState):
        t = state.step + 1
        m = jax.tree.map(
            lambda mm, g: self.b1 * mm + (1 - self.b1) * g.astype(jnp.float32),
            state.m, grads)
        v = jax.tree.map(
            lambda vv, g: self.b2 * vv + (1 - self.b2) * jnp.square(g.astype(jnp.float32)),
            state.v, grads)
        if self.bias_correction:
            ms = 1.0 / (1 - self.b1 ** t.astype(jnp.float32)) if self.b1 > 0 else 1.0
            vs = 1.0 / (1 - self.b2 ** t.astype(jnp.float32))
        else:
            ms = vs = 1.0
        new_p = jax.tree.map(
            lambda p, mm, vv: (p.astype(jnp.float32)
                               - self.lr * (mm * ms) / jnp.sqrt(vv * vs)).astype(p.dtype),
            params, m, v)
        return new_p, AdamState(step=t, m=m, v=v)


class AdagradState(NamedTuple):
    accum: Pytree


@dataclasses.dataclass(frozen=True)
class Adagrad:
    lr: float = 0.05
    eps: float = 1e-10
    initial_accumulator: float = 0.1

    def init(self, params: Pytree) -> AdagradState:
        return AdagradState(
            accum=jax.tree.map(
                lambda x: jnp.full(x.shape, self.initial_accumulator, jnp.float32), params
            )
        )

    def step_fn(self, params, grads, state: AdagradState):
        accum = jax.tree.map(
            lambda a, g: a + jnp.square(g.astype(jnp.float32)), state.accum, grads)
        new_p = jax.tree.map(
            lambda p, g, a: (p.astype(jnp.float32)
                             - self.lr * g.astype(jnp.float32) / (jnp.sqrt(a) + self.eps)
                             ).astype(p.dtype),
            params, grads, accum)
        return new_p, AdagradState(accum=accum)
