from repro.optim.adam import Adam, AdamState, Adagrad, AdagradState  # noqa: F401
