"""CLI: ``python -m repro.analysis`` — the hot-path correctness gate.

    python -m repro.analysis --lint                # layer 1 + 3 lint (fast)
    python -m repro.analysis --trace-audit         # layer 2 only
    python -m repro.analysis --sched-audit         # layer 3 dynamic only
    python -m repro.analysis --all                 # everything (the CI gate)
    python -m repro.analysis --all --report analysis-report.json
    python -m repro.analysis --lint --update-baseline
    python -m repro.analysis --all --format github --strict-baseline

Exit code 0 iff every finding is covered by the checked-in baseline
(``analysis-baseline.json`` at the repo root).  New findings print with
file:line and fail the gate; stale baseline entries are reported but don't
fail unless ``--strict-baseline`` (run ``--update-baseline`` to drop them —
it preserves the justifications of surviving entries and marks new ones to
fill in).  ``--format github`` emits workflow commands so CI annotates the
offending lines in the diff view.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def _default_paths():
    import repro

    pkg = Path(repro.__file__).resolve().parent        # .../src/repro
    repo = pkg.parent.parent if pkg.parent.name == "src" else Path.cwd()
    return pkg, repo


def _gh_annotation(f) -> str:
    """One GitHub Actions workflow command per finding: annotates
    ``path:line`` in the PR diff view."""
    msg = f.message.replace("%", "%25").replace("\r", "%0D").replace(
        "\n", "%0A")
    return (f"::error file={f.path},line={max(f.line, 1)},"
            f"title={f.rule}::{msg}")


def main(argv=None) -> int:
    pkg_root, repo_root = _default_paths()
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="hot-path static analysis + trace/schedule audit gate",
    )
    ap.add_argument("--lint", action="store_true", help="run the AST lint")
    ap.add_argument("--trace-audit", action="store_true",
                    help="run the trace audit (builds smoke trainers)")
    ap.add_argument("--sched-audit", action="store_true",
                    help="run the deterministic schedule audit over the "
                         "storage/serving threads")
    ap.add_argument("--all", action="store_true",
                    help="lint + trace audit + schedule audit")
    ap.add_argument("--src", type=Path, default=pkg_root,
                    help="source root to lint (default: the repro package)")
    ap.add_argument("--baseline", type=Path,
                    default=repo_root / "analysis-baseline.json")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline to the current findings "
                         "(keeps existing justifications)")
    ap.add_argument("--strict-baseline", action="store_true",
                    help="fail (exit 1) on stale baseline entries too — "
                         "the baseline must match reality exactly")
    ap.add_argument("--format", choices=["text", "github"], default="text",
                    help="new-finding output format: plain text, or GitHub "
                         "workflow commands (::error file=...) for CI "
                         "annotations")
    ap.add_argument("--report", type=Path, default=None,
                    help="write a JSON findings/check report here")
    ap.add_argument("--archs", nargs="*", default=None,
                    help="trace-audit arch filter (default: all recsys)")
    ap.add_argument("--placements", nargs="*",
                    default=["gather", "routed", "cached"])
    ap.add_argument("--no-transfer-check", action="store_true",
                    help="skip the runtime transfer_guard step check")
    ap.add_argument("--sched-cells", nargs="*", default=None,
                    help="schedule-audit cell filter (default: all cells)")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)

    if args.all or not (args.lint or args.trace_audit or args.sched_audit):
        args.lint = args.trace_audit = args.sched_audit = True

    log = (lambda *a: None) if args.quiet else (
        lambda *a: print(*a, file=sys.stderr))

    findings = []
    trace_report = []
    sched_report = []
    if args.lint:
        from repro.analysis.lint import Project, run_lint, summarize

        log(f"lint: {args.src}")
        lint_findings = run_lint(Project(args.src))
        log(f"lint: {len(lint_findings)} finding(s) {summarize(lint_findings)}")
        findings.extend(lint_findings)
    if args.trace_audit:
        from repro.analysis.trace_audit import run_trace_audit

        audit_findings, trace_report = run_trace_audit(
            archs=args.archs, placements=tuple(args.placements),
            check_transfers=not args.no_transfer_check, log=log,
        )
        n_checks = len(trace_report)
        log(f"trace-audit: {n_checks} check(s), "
            f"{len(audit_findings)} failure(s)")
        findings.extend(audit_findings)
    if args.sched_audit:
        from repro.analysis.sched_audit import run_sched_audit

        sched_findings, sched_report = run_sched_audit(
            cells=args.sched_cells, log=log,
        )
        log(f"sched-audit: {len(sched_report)} check(s), "
            f"{len(sched_findings)} failure(s)")
        findings.extend(sched_findings)

    from repro.analysis.baseline import Baseline

    baseline = Baseline.load(args.baseline)
    if args.update_baseline:
        missing = baseline.update(findings)
        print(f"baseline updated: {len(findings)} entr(ies) -> "
              f"{args.baseline}"
              + (f" ({missing} justification(s) to fill in)" if missing
                 else ""))
        return 0

    new, old, stale = baseline.split(findings)
    if args.report:
        args.report.write_text(json.dumps({
            "new": [f.__dict__ for f in new],
            "baselined": [f.__dict__ for f in old],
            "stale_baseline": [list(k) for k in stale],
            "trace_checks": trace_report,
            "sched_checks": sched_report,
        }, indent=2) + "\n")
        log(f"report: {args.report}")

    for f in old:
        log(f"baselined: {f}")
    for k in stale:
        print(f"stale baseline entry (matched nothing): {k}",
              file=sys.stderr)
    for f in new:
        if args.format == "github":
            print(_gh_annotation(f))
        else:
            print(f"FAIL {f}")
    fail = bool(new) or (args.strict_baseline and bool(stale))
    if new:
        print(f"\n{len(new)} new finding(s) not covered by "
              f"{args.baseline.name} — fix them, or baseline WITH a "
              "justification (--update-baseline, then edit the "
              "justification fields).")
    if args.strict_baseline and stale:
        print(f"{len(stale)} stale baseline entr(ies) under "
              "--strict-baseline — run --update-baseline to drop them.")
    if fail:
        return 1
    print(f"analysis clean: {len(findings)} finding(s), all baselined"
          if findings else "analysis clean: no findings")
    return 0


if __name__ == "__main__":
    sys.exit(main())
