"""Hot-path correctness tooling — the machine checker for the invariants the
paper's pipeline depends on.

The whole thesis of the framework is that the GPU<->CPU<->SSD hot path stays
communication-clean: no per-step host syncs, no silent retraces, donated
buffers actually donated, config knobs actually wired.  The last several PRs
each re-discovered violations of these invariants by hand (per-step
``float(loss)`` syncs, dead ``merge_delay``/``merge_quorum`` knobs, a
donate-twice XLA error, a silent sqrtn fallback).  This package turns them
into enforced checks, in two layers:

Layer 1 — AST lint (``repro.analysis.lint`` + ``repro.analysis.rules``):
  repo-specific static rules over the source tree.

  - R1 ``host-sync-in-jit``: host-synchronizing calls (``float()``,
    ``.item()``, ``np.asarray``, ``jax.device_get``,
    ``.block_until_ready()``) reachable from traced functions (anything
    passed to ``jax.jit`` or defined inside a ``_make_*`` step factory).
  - R2 ``dead-config-knob``: dataclass config/spec fields never read
    anywhere outside their definition.
  - R3 ``nondeterminism-in-trace``: wall clock / host RNG
    (``time.time``, ``np.random.*``, ``random.*``) inside traced functions.
  - R4 ``undonated-hot-jit``: ``jax.jit`` call sites in the designated
    hot-path modules with no explicit donation decision
    (``donate_argnums``/``donate_argnames``).

Layer 2 — trace audit (``repro.analysis.trace_audit``):
  build each registered recsys arch x placement trainer at smoke scale,
  trace one real step, and assert on the jaxpr / lowered HLO: no
  ``pure_callback``/``io_callback`` primitives, no f64 widening, donation
  actually marked in the lowered module, the jit caches stop growing after
  the warm-up step (retrace guard), and the hot path survives
  ``jax.transfer_guard("disallow")`` (runtime sync check).

Findings are gated against a checked-in baseline (``analysis-baseline.json``
at the repo root): pre-existing accepted cases carry a justification and do
not fail the gate; anything new does.  CLI: ``python -m repro.analysis --all``
(see ``docs/analysis.md``).
"""

from repro.analysis.lint import Finding, Project, run_lint  # noqa: F401
from repro.analysis.baseline import Baseline  # noqa: F401
