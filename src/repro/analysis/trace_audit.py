"""Layer 2 — trace audit: build real trainers, trace real steps, assert the
hot path is clean.

The AST lint (layer 1) sees what the source *says*; this layer checks what
the compiler actually *gets*.  For every registered recsys arch x placement
{gather, routed, cached} (and the LM serving decode step), a trainer is
built at smoke scale, one real step is traced, and the jaxpr / lowered
module is audited.  The co-located CTR serving tier gets its own audit
(``audit_serve_lookup``): same hygiene, plus the inverted donation
invariant — the read-only lookup must donate NOTHING (it shares live
training buffers):

- ``callback``:   no ``pure_callback``/``io_callback``/``debug_callback``
                  primitives anywhere in the step jaxpr — a callback in the
                  hot path is a per-step host round trip.
- ``f64``:        no float64/complex128 intermediates (silent widening
                  doubles every wire byte the paper counts).
- ``donation``:   the pull/train/decode executables that promise donation
                  really mark donors in the lowered module
                  (``tf.aliasing_output`` / ``jax.buffer_donor``).
- ``retrace``:    after the warm-up step(s), running more steps must not
                  grow any jit cache — a growing cache is a silent
                  recompile-per-step bug.
- ``transfer-sync``: the inner loop survives
                  ``jax.transfer_guard("disallow")`` — no implicit
                  host<->device transfer per step at runtime (explicit
                  ``jax.device_put``/``device_get`` at staging/logging
                  boundaries are allowed by the guard).

Each failed check is reported as a ``Finding`` (same baseline gating as the
lint).  ``fit_online(..., strict_transfers=True)`` / the launcher's
``--strict-transfers`` run the same transfer guard in production loops.
"""

from __future__ import annotations

import dataclasses
import shutil
import tempfile
import traceback
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis.lint import Finding

PLACEMENTS = ("gather", "routed", "cached")

_CALLBACK_PRIMS = {"pure_callback", "io_callback", "debug_callback"}

# source anchors used as Finding paths (the audit is cross-module; these
# name the module that owns the audited executable)
_TRAINER_PATH = "src/repro/runtime/trainer.py"
_SERVE_PATH = "src/repro/runtime/serve.py"
_SERVE_CTR_PATH = "src/repro/runtime/serve_ctr.py"


# ------------------------------------------------------------ jaxpr walking
def iter_eqns(jaxpr) -> Iterable[Any]:
    """All equations of a (Closed)Jaxpr, recursing into sub-jaxprs."""
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                yield from iter_eqns(sub)


def _sub_jaxprs(v) -> Iterable[Any]:
    if hasattr(v, "jaxpr"):
        yield v
    elif isinstance(v, (list, tuple)):
        for vi in v:
            if hasattr(vi, "jaxpr"):
                yield vi


def callback_primitives(jaxpr) -> List[str]:
    return sorted({
        e.primitive.name for e in iter_eqns(jaxpr)
        if e.primitive.name in _CALLBACK_PRIMS
        or "callback" in e.primitive.name
    })


def f64_leaks(jaxpr) -> List[str]:
    """Primitives producing float64/complex128 outputs."""
    import numpy as np
    bad = set()
    for e in iter_eqns(jaxpr):
        for v in e.outvars:
            aval = getattr(v, "aval", None)
            dt = getattr(aval, "dtype", None)
            if dt is not None and np.dtype(dt) in (
                    np.dtype("float64"), np.dtype("complex128")):
                bad.add(e.primitive.name)
    return sorted(bad)


def donation_marked(lowered_text: str) -> bool:
    """Donated arguments appear in the lowered module as aliased/donor
    parameters (StableHLO spells it ``tf.aliasing_output``; newer jaxlibs
    also emit ``jax.buffer_donor``)."""
    return ("tf.aliasing_output" in lowered_text
            or "jax.buffer_donor" in lowered_text)


# ------------------------------------------------------------- audit result
@dataclasses.dataclass
class CheckResult:
    target: str      # e.g. "baidu-ctr/cached" or "serve-decode"
    check: str       # callback | f64 | donation | retrace | transfer-sync
    ok: bool
    detail: str = ""


def _finding(path: str, res: CheckResult) -> Finding:
    return Finding(
        rule=f"trace-{res.check}", path=path, line=0,
        symbol=res.target, detail=res.check,
        message=f"trace audit [{res.target}] {res.check}: {res.detail}",
    )


# --------------------------------------------------------------- the audits
def _build_recsys(arch: str, placement: str, prefetch: bool, n_pod: int = 2,
                  store: str = "host", spill_dir: Optional[str] = None):
    from repro.core.kstep import KStepConfig
    from repro.runtime.factory import build_trainer
    from repro.runtime.trainer import TrainerConfig

    tcfg = TrainerConfig(
        n_pod=n_pod, kstep=KStepConfig(k=2), placement=placement,
        prefetch=prefetch, log_every=10_000,
        store=store, spill_dir=spill_dir,
    )
    return build_trainer(arch, tcfg, smoke=True)


def audit_recsys(
    arch: str, placement: str, prefetch: bool = False,
    batch: int = 32, check_transfers: bool = True, store: str = "host",
) -> List[CheckResult]:
    """Trace-audit one arch x placement trainer: jaxpr hygiene + donation on
    the pull and train executables, then run 2k steps for the retrace guard
    and (optionally) the transfer-guard runtime sync check.

    ``store="disk"`` audits the three-level hierarchy representative over a
    throwaway spill dir: the jitted executables are the same ones (the disk
    path wraps, never replaces, them), and the transfer-sync check proves
    the staging protocol's host IO stays behind explicit
    ``device_put``/``device_get`` at commit boundaries.
    """
    import jax
    from repro import configs
    from repro.data import synthetic as S

    target = (f"{arch}/{placement}" + ("/prefetch" if prefetch else "")
              + ("/disk" if store == "disk" else ""))
    results: List[CheckResult] = []
    spill = tempfile.mkdtemp(prefix="trace_audit_spill_") \
        if store == "disk" else None
    tr = _build_recsys(arch, placement, prefetch, store=store,
                       spill_dir=spill)
    mcfg = configs.get(arch).smoke_cfg
    gen = S.recsys_batches(mcfg, batch=batch, seed=0)
    b0 = next(gen)

    # ---- static: jaxpr + lowered-module audits on the real step functions
    staged = tr._stage(b0)
    flat_ids = tr.engine.ids_from_batch(staged)
    accum = tr.sparse_state.accum
    pull_jaxpr = jax.make_jaxpr(
        lambda t, a, s, ids: tr.engine.pull(t, a, s, ids)
    )(tr.tables, accum, tr.backend_state, flat_ids)
    wss, t2, a2, s2 = tr.engine.pull(
        tr.tables, accum, tr.backend_state, flat_ids
    )
    train_args = (tr.dense, t2, a2, s2, wss, tr.pod_batch(staged),
                  tr.opt_state, tr._overflow)
    train_jaxpr = jax.make_jaxpr(tr._make_train(False))(*train_args)

    for name, jx in (("pull", pull_jaxpr), ("train", train_jaxpr)):
        cbs = callback_primitives(jx)
        results.append(CheckResult(
            target, "callback", not cbs,
            f"{name} stage callbacks: {cbs}" if cbs else ""))
        wides = f64_leaks(jx)
        results.append(CheckResult(
            target, "f64", not wides,
            f"{name} stage f64 outputs from: {wides}" if wides else ""))

    # under the disk store tr._pull is the host-staging WRAPPER around the
    # jitted pull; the lowered-module/donation/retrace checks want the jit
    pull_jit = (next(iter(tr.engine._pull_jits.values()))
                if store == "disk" else tr._pull)
    pull_txt = pull_jit.lower(
        tr.tables, accum, tr.backend_state, flat_ids).as_text()
    train_txt = tr._train_local.lower(*train_args).as_text()
    for name, txt in (("pull", pull_txt), ("train", train_txt)):
        ok = donation_marked(txt)
        results.append(CheckResult(
            target, "donation", ok,
            "" if ok else (
                f"{name} stage promises buffer donation but the lowered "
                "module marks no donor parameters"),
        ))

    # ---- dynamic: retrace guard + runtime transfer-sync over 2k steps
    # (the online loop is predict-then-train, so predict rides along: it
    # must neither recompile per step nor sync implicitly)
    k = tr.cfg.kstep.k
    jits = {"pull": pull_jit, "train_local": tr._train_local,
            "train_merge": tr._train_merge, "predict": tr._predict_jit}
    b = b0
    transfer_err: Optional[str] = None
    for i in range(2 * k):
        if i == k:   # warm-up done: local + merge both compiled
            sizes = {n: j._cache_size() for n, j in jits.items()}
        if check_transfers and i >= k and transfer_err is None:
            try:
                with jax.transfer_guard("disallow"):
                    if tr._prefetcher is not None:
                        tr.prefetch(b)
                    tr.predict(b)
                    tr.train_step(b)
            except Exception as e:   # guard trip = per-step implicit sync
                transfer_err = f"{type(e).__name__}: {e}"
                break
        else:
            if tr._prefetcher is not None:
                tr.prefetch(b)
            tr.predict(b)
            tr.train_step(b)
        b = next(gen)
    growth = {n: j._cache_size() - sizes[n] for n, j in jits.items()
              if j._cache_size() != sizes[n]}
    results.append(CheckResult(
        target, "retrace", not growth,
        f"jit caches grew after warm-up: {growth}" if growth else ""))
    if check_transfers:
        results.append(CheckResult(
            target, "transfer-sync", transfer_err is None,
            ("implicit host<->device transfer in the inner loop under "
             f"jax.transfer_guard('disallow'): {transfer_err}")
            if transfer_err else ""))
    if spill is not None:
        tr.engine.store.close()
        shutil.rmtree(spill, ignore_errors=True)
    return results


def audit_serve_decode() -> List[CheckResult]:
    """The LM serving decode step: KV-cache donation + jaxpr hygiene +
    retrace stability across slot refills."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.models import transformer as tfm
    from repro.runtime.serve import BatchedServer, Request

    target = "serve-decode"
    results: List[CheckResult] = []
    cfg = tfm.TransformerConfig(
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
        vocab=50, dtype=jnp.float32, moe_group_size=32,
    )
    params = tfm.init_params(jax.random.key(0), cfg)
    srv = BatchedServer(params, cfg, slots=2, max_len=16)

    jx = jax.make_jaxpr(
        lambda p, c, t: tfm.decode_step(p, c, t, cfg)
    )(params, srv.cache, jnp.zeros(2, jnp.int32))
    cbs = callback_primitives(jx)
    results.append(CheckResult(
        target, "callback", not cbs,
        f"decode callbacks: {cbs}" if cbs else ""))
    wides = f64_leaks(jx)
    results.append(CheckResult(
        target, "f64", not wides,
        f"decode f64 outputs from: {wides}" if wides else ""))

    txt = srv._decode.lower(
        params, srv.cache, jnp.zeros(2, jnp.int32)).as_text()
    ok = donation_marked(txt)
    results.append(CheckResult(
        target, "donation", ok,
        "" if ok else (
            "decode_step jit donates nothing — the KV cache is rewritten "
            "every step and must be donated (doubles peak cache memory "
            "otherwise)"),
    ))

    for i in range(4):
        srv.submit(Request(prompt=np.asarray([1 + i, 2]), max_new_tokens=3))
    srv.step()
    size0 = srv._decode._cache_size()
    srv.run_to_completion()
    grew = srv._decode._cache_size() - size0
    results.append(CheckResult(
        target, "retrace", grew == 0,
        f"decode jit cache grew by {grew} across slot refills" if grew
        else ""))
    return results


def audit_serve_lookup(arch: str = "baidu-ctr", placement: str = "cached",
                       batch: int = 32) -> List[CheckResult]:
    """The co-located CTR serving tier (``runtime/serve_ctr.py``): audit the
    read-only lookup executable that ``CTRServer`` drives.

    Beyond the usual jaxpr hygiene, the serving-specific invariants:

    - ``no-donation``: predict shares the LIVE training buffers (tables,
      accumulators, cache state) with the trainer — its lowered module must
      mark NO donor parameters, or a serve call would invalidate the
      trainer's handles mid-run.
    - ``transfer-sync``: interleaved train_step + server drain survives
      ``jax.transfer_guard("disallow")`` — serving adds no implicit
      host<->device syncs to the co-located loop (its h2d staging and d2h
      score reads are explicit device_put/device_get).
    - ``retrace``: server drains must reuse the one compiled predict
      executable (dynamic batches pad to a static shape)."""
    import jax
    from repro import configs
    from repro.data import synthetic as S
    from repro.runtime.factory import build_ctr_server
    from repro.runtime.serve_ctr import requests_from_batch

    target = f"serve-ctr/{placement}"
    results: List[CheckResult] = []
    tr = _build_recsys(arch, placement, prefetch=False)
    mcfg = configs.get(arch).smoke_cfg
    gen = S.recsys_batches(mcfg, batch=batch, seed=0)
    srv = build_ctr_server(tr, max_batch=batch)

    # ---- static: hygiene + the no-donation invariant on the real predict
    b0 = next(gen)
    staged = tr._stage({k: v for k, v in b0.items() if k != "label"})
    args = (tr.dense, tr.tables, tr.sparse_state.accum, tr.backend_state,
            staged)
    jx = jax.make_jaxpr(tr._predict_traced)(*args)
    cbs = callback_primitives(jx)
    results.append(CheckResult(
        target, "callback", not cbs,
        f"serve lookup callbacks: {cbs}" if cbs else ""))
    wides = f64_leaks(jx)
    results.append(CheckResult(
        target, "f64", not wides,
        f"serve lookup f64 outputs from: {wides}" if wides else ""))
    txt = tr._predict_jit.lower(*args).as_text()
    ok = not donation_marked(txt)
    results.append(CheckResult(
        target, "no-donation", ok,
        "" if ok else (
            "serve lookup lowered module marks donor parameters — predict "
            "reads the trainer's LIVE tables/accum/cache state and must "
            "never donate them"),
    ))

    # ---- dynamic: co-located loop (train + drain) -> retrace + guard
    for _ in range(2):   # warm-up: compile predict + train executables
        tr.train_step(next(gen))
        srv.submit_batch(next(gen))
        srv.drain()
    size0 = tr._predict_jit._cache_size()
    transfer_err: Optional[str] = None
    try:
        with jax.transfer_guard("disallow"):
            for _ in range(2):
                tr.train_step(next(gen))
                for req in requests_from_batch(next(gen)):
                    srv.submit(req)
                srv.drain()
    except Exception as e:
        transfer_err = f"{type(e).__name__}: {e}"
    grew = tr._predict_jit._cache_size() - size0
    results.append(CheckResult(
        target, "retrace", grew == 0,
        f"predict jit cache grew by {grew} across server drains" if grew
        else ""))
    results.append(CheckResult(
        target, "transfer-sync", transfer_err is None,
        ("implicit host<->device transfer in the co-located train+serve "
         f"loop under jax.transfer_guard('disallow'): {transfer_err}")
        if transfer_err else ""))
    return results


# ----------------------------------------------------------------- the gate
def run_trace_audit(
    archs: Optional[Sequence[str]] = None,
    placements: Sequence[str] = PLACEMENTS,
    include_serve: bool = True,
    check_transfers: bool = True,
    log=None,
) -> Tuple[List[Finding], List[Dict]]:
    """Audit the full matrix; returns ``(findings, report)`` where findings
    are the FAILED checks (baseline-gated by the CLI) and report records
    every check for the CI artifact.

    The prefetch axis shares the placement executables by construction
    (same jits), so it is audited on one arch rather than the full matrix.
    """
    from repro import configs

    if archs is None:
        archs = [a for a in configs.list_archs()
                 if configs.get(a).family == "recsys"]
    findings: List[Finding] = []
    report: List[Dict] = []

    combos = [(a, p, False, "host") for a in archs for p in placements]
    if archs:
        # prefetch and disk-store representatives: both axes share the
        # placement executables by construction, so one cell each suffices
        combos.append((archs[0], "cached", True, "host"))
        combos.append((archs[0], "cached", True, "disk"))
    for arch, placement, prefetch, store in combos:
        target = (f"{arch}/{placement}" + ("/prefetch" if prefetch else "")
                  + ("/disk" if store == "disk" else ""))
        if log:
            log(f"trace-audit: {target}")
        try:
            results = audit_recsys(
                arch, placement, prefetch, check_transfers=check_transfers,
                store=store)
        except Exception:
            results = [CheckResult(
                target, "audit-error", False,
                traceback.format_exc(limit=3).strip())]
        for r in results:
            report.append(dataclasses.asdict(r))
            if not r.ok:
                findings.append(_finding(_TRAINER_PATH, r))

    if include_serve:
        if log:
            log("trace-audit: serve-decode")
        try:
            results = audit_serve_decode()
        except Exception:
            results = [CheckResult(
                "serve-decode", "audit-error", False,
                traceback.format_exc(limit=3).strip())]
        for r in results:
            report.append(dataclasses.asdict(r))
            if not r.ok:
                findings.append(_finding(_SERVE_PATH, r))

        # co-located CTR serving tier (read-only lookup + no-donation)
        if log:
            log("trace-audit: serve-ctr")
        try:
            results = audit_serve_lookup(
                archs[0] if archs else "baidu-ctr")
        except Exception:
            results = [CheckResult(
                "serve-ctr", "audit-error", False,
                traceback.format_exc(limit=3).strip())]
        for r in results:
            report.append(dataclasses.asdict(r))
            if not r.ok:
                findings.append(_finding(_SERVE_CTR_PATH, r))
    return findings, report
