"""Layer 1 — the AST lint driver.

``Project`` parses every ``*.py`` under a source root (never imports them);
``run_lint`` applies the registered rules (``repro.analysis.rules.ALL_RULES``)
and returns ``Finding``s.  Findings are plain data — the CLI handles baseline
gating and reporting.
"""

from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Sequence

from repro.analysis.astutil import ModuleInfo


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str          # rule id, e.g. "host-sync-in-jit"
    path: str          # repo-relative file path
    line: int          # 1-based line of the offending node
    symbol: str        # qualname of the enclosing function/class ("" at module level)
    detail: str        # stable short form, e.g. the offending call name
    message: str       # human explanation

    def location(self) -> str:
        return f"{self.path}:{self.line}"

    def __str__(self) -> str:
        sym = f" [{self.symbol}]" if self.symbol else ""
        return f"{self.location()}: {self.rule}{sym}: {self.message}"


class Project:
    """All parsed modules under a source root.

    ``rel_root`` anchors the repo-relative paths used in findings and the
    baseline (default: the parent of ``root``'s ``src`` directory when the
    root lives under one, else ``root`` itself) — so findings read
    ``src/repro/runtime/serve.py`` regardless of where the tool runs.
    """

    def __init__(self, root: Path, rel_root: Optional[Path] = None):
        self.root = Path(root).resolve()
        if rel_root is None:
            rel_root = self.root
            for p in self.root.parents:
                if p.name == "src":
                    rel_root = p.parent
                    break
        self.rel_root = Path(rel_root).resolve()
        self.modules: List[ModuleInfo] = []
        self.errors: List[str] = []
        for path in sorted(self.root.rglob("*.py")):
            try:
                tree = ast.parse(path.read_text(), filename=str(path))
            except SyntaxError as e:  # a broken file is itself a finding
                self.errors.append(f"{path}: {e}")
                continue
            try:
                rel = str(path.relative_to(self.rel_root))
            except ValueError:
                rel = str(path)
            self.modules.append(ModuleInfo(path, rel, tree))

    def __iter__(self) -> Iterator[ModuleInfo]:
        return iter(self.modules)


def run_lint(
    project: Project, rules: Optional[Sequence] = None
) -> List[Finding]:
    if rules is None:
        from repro.analysis.rules import ALL_RULES
        rules = ALL_RULES
    findings: List[Finding] = []
    for rule in rules:
        findings.extend(rule.run(project))
    for err in project.errors:
        findings.append(Finding(
            rule="parse-error", path=err.split(":")[0], line=0,
            symbol="", detail="syntax", message=err,
        ))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def lint_paths(root: Path, rules=None) -> List[Finding]:
    return run_lint(Project(root), rules=rules)


def summarize(findings: List[Finding]) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for f in findings:
        out[f.rule] = out.get(f.rule, 0) + 1
    return out
