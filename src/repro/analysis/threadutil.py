"""Static thread model shared by the layer-3 concurrency rules.

Like :mod:`repro.analysis.astutil`, everything here is pure ``ast`` — the
threaded modules are parsed, never imported.  The model is deliberately
module-local and name-based (the same conservatism as ``traced_functions``):

- A **thread class** is any class that constructs ``threading.Thread``.
  Its *worker domain* is the set of methods reachable from the thread
  targets through ``self.method()`` calls; everything else (except
  ``__init__``, which runs before any ``start()`` and therefore
  happens-before the worker) is the *main domain*.
- A **lock** is any ``with``-acquired attribute or name whose final path
  segment matches ``lock``/``mutex`` (case-insensitive).  Locks held at a
  node are the lexically enclosing ``with`` locks up to the nearest
  function boundary, plus the locks *provably held at every call site* of
  that function (a fixpoint over the module-local call graph — a helper
  only called from inside ``with self._lock:`` blocks counts as guarded).
- Attributes bound to internally-synchronized constructors
  (``queue.Queue``, ``threading.Event``, ``collections.deque``, the lock
  types themselves, ...) never need a lock of their own.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from typing import Dict, FrozenSet, Iterable, List, Optional, Set

from repro.analysis.astutil import (
    FUNC_TYPES,
    FuncInfo,
    ModuleInfo,
    dotted_name,
    enclosing,
    parent,
)

LOCK_NAME_RE = re.compile(r"lock|mutex", re.IGNORECASE)

# Constructors whose instances are internally synchronized (or ARE the
# synchronization primitive): attributes bound to one of these are exempt
# from the shared-state rule.
THREADSAFE_CONSTRUCTORS = {
    "queue.Queue", "queue.SimpleQueue", "queue.LifoQueue",
    "queue.PriorityQueue",
    "collections.deque",
    "threading.Event", "threading.Lock", "threading.RLock",
    "threading.Condition", "threading.Semaphore",
    "threading.BoundedSemaphore", "threading.Barrier", "threading.Thread",
    "threading.local",
}

# Canonical callables that block on the filesystem (or sleep).  Calling one
# of these while a lock is held stalls every thread contending for it —
# on this repo's hot path that means the training thread waits out SSD
# latency inside the page-cache critical section.
BLOCKING_CALLS = {
    "open",
    "numpy.load", "numpy.save", "numpy.savez", "numpy.savez_compressed",
    "os.replace", "os.rename", "os.fsync", "os.remove", "os.unlink",
    "os.makedirs", "os.walk",
    "shutil.copy", "shutil.copy2", "shutil.copyfile", "shutil.copytree",
    "shutil.move", "shutil.rmtree",
    "json.dump", "json.load",
    "time.sleep",
}

# dict/set/list/deque mutators: `self.x.append(...)` is a write to `x`.
MUTATING_METHODS = {
    "append", "appendleft", "add", "discard", "remove", "pop", "popleft",
    "popitem", "clear", "update", "extend", "insert", "setdefault",
    "move_to_end", "sort", "reverse",
}


# --------------------------------------------------------------------------
# lock scopes
# --------------------------------------------------------------------------

def _with_lock_names(node: ast.With) -> Set[str]:
    """Leaf names of lock-ish context managers acquired by this ``with``."""
    out: Set[str] = set()
    for item in node.items:
        name = dotted_name(item.context_expr)
        if name is None and isinstance(item.context_expr, ast.Call):
            # with self._lock: vs with self._lock.acquire_timeout(...):
            name = dotted_name(item.context_expr.func)
        if name is not None:
            leaf = name.split(".")[-1]
            if LOCK_NAME_RE.search(leaf):
                out.add(leaf)
    return out


def lexical_locks(node: ast.AST) -> FrozenSet[str]:
    """Lock names acquired by ``with`` statements between ``node`` and its
    nearest enclosing function boundary.  Stops at the boundary: a closure
    defined inside a locked block may run on another thread later, so the
    outer ``with`` proves nothing for its body."""
    out: Set[str] = set()
    p = parent(node)
    while p is not None and not isinstance(p, FUNC_TYPES):
        if isinstance(p, ast.With):
            out |= _with_lock_names(p)
        p = parent(p)
    return frozenset(out)


def walk_scope(root: ast.AST) -> Iterable[ast.AST]:
    """``ast.walk`` that does NOT descend into nested function definitions —
    a closure's body executes when the closure is *called*, not where it is
    defined, so lexical lock/ordering facts must stop at its boundary."""
    yield root
    stack = list(ast.iter_child_nodes(root))
    while stack:
        n = stack.pop()
        yield n
        if not isinstance(n, FUNC_TYPES):
            stack.extend(ast.iter_child_nodes(n))


def resolve_calls(mod: ModuleInfo) -> Dict[int, List[FuncInfo]]:
    """id(call node) -> module-local functions it (by name) resolves to.
    ``foo(...)`` and ``self.m(...)`` resolve; ``obj.m(...)`` on an unknown
    receiver does not.  ``ClassName(...)`` resolves to
    ``ClassName.__init__``."""
    by_name: Dict[str, List[FuncInfo]] = {}
    init_by_cls: Dict[str, FuncInfo] = {}
    for f in mod.functions:
        by_name.setdefault(f.name, []).append(f)
        if f.name == "__init__" and f.cls is not None:
            init_by_cls[f.cls] = f
    out: Dict[int, List[FuncInfo]] = {}
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        targets: List[FuncInfo] = []
        if isinstance(fn, ast.Name):
            targets = by_name.get(fn.id, [])
            if not targets and fn.id in init_by_cls:
                targets = [init_by_cls[fn.id]]
        elif isinstance(fn, ast.Attribute):
            if isinstance(fn.value, ast.Name) and fn.value.id == "self":
                targets = by_name.get(fn.attr, [])
        if targets:
            out[id(node)] = targets
    return out


def _call_resolution(mod: ModuleInfo) -> Dict[int, List[ast.Call]]:
    """id(func node) -> call sites in this module that resolve to it."""
    resolved = resolve_calls(mod)
    sites: Dict[int, List[ast.Call]] = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call):
            for t in resolved.get(id(node), []):
                sites.setdefault(id(t.node), []).append(node)
    return sites


def lock_held_map(mod: ModuleInfo) -> Dict[int, FrozenSet[str]]:
    """id(func node) -> lock names provably held at EVERY call site of that
    function.  Functions with no resolvable call sites hold nothing (their
    callers are unknown).  Fixpoint from the optimistic all-locks start."""
    sites = _call_resolution(mod)
    all_locks: Set[str] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.With):
            all_locks |= _with_lock_names(node)
    held: Dict[int, FrozenSet[str]] = {}
    for f in mod.functions:
        held[id(f.node)] = (
            frozenset(all_locks) if sites.get(id(f.node)) else frozenset()
        )
    changed = True
    while changed:
        changed = False
        for f in mod.functions:
            calls = sites.get(id(f.node))
            if not calls:
                continue
            acc: Optional[FrozenSet[str]] = None
            for c in calls:
                encl = mod.enclosing_function(c)
                inherited = (
                    held.get(id(encl.node), frozenset())
                    if encl is not None else frozenset()
                )
                at_site = lexical_locks(c) | inherited
                acc = at_site if acc is None else (acc & at_site)
            acc = acc or frozenset()
            if acc != held[id(f.node)]:
                held[id(f.node)] = acc
                changed = True
    return held


def locks_at(
    mod: ModuleInfo, held: Dict[int, FrozenSet[str]], node: ast.AST,
) -> FrozenSet[str]:
    """Locks held when ``node`` executes: lexical withs plus the enclosing
    function's call-site guarantee."""
    f = mod.enclosing_function(node)
    base = held.get(id(f.node), frozenset()) if f is not None else frozenset()
    return lexical_locks(node) | base


# --------------------------------------------------------------------------
# blocking-call closure
# --------------------------------------------------------------------------

def _is_blocking_call(mod: ModuleInfo, call: ast.Call) -> bool:
    name = mod.canonical_call(call)
    if name in BLOCKING_CALLS:
        return True
    # self.<queue-or-thread attr>.join() — zero positional args keeps
    # str.join(parts) out.
    fn = call.func
    if (isinstance(fn, ast.Attribute) and fn.attr == "join"
            and not call.args):
        recv = dotted_name(fn.value)
        if recv is not None and recv.split(".")[0] == "self":
            return True
    return False


def blocking_functions(mod: ModuleInfo) -> Set[int]:
    """id(func node) for functions that (transitively) perform a blocking
    call from :data:`BLOCKING_CALLS`."""
    sites = _call_resolution(mod)
    callers_of: Dict[int, Set[int]] = {}
    for fid, calls in sites.items():
        for c in calls:
            encl = mod.enclosing_function(c)
            if encl is not None:
                callers_of.setdefault(fid, set())
                callers_of[fid].add(id(encl.node))
    blocking: Set[int] = set()
    for f in mod.functions:
        for node in ast.walk(f.node):
            if (isinstance(node, ast.Call)
                    and mod.enclosing_function(node) is f
                    and _is_blocking_call(mod, node)):
                blocking.add(id(f.node))
                break
    # propagate through callers: f calls blocking g => f blocks too
    changed = True
    while changed:
        changed = False
        for fid in list(blocking):
            for caller in callers_of.get(fid, ()):  # callers of fid
                if caller not in blocking:
                    blocking.add(caller)
                    changed = True
    return blocking


# --------------------------------------------------------------------------
# thread classes: worker domains + accesses
# --------------------------------------------------------------------------

@dataclasses.dataclass
class ThreadStart:
    call: ast.Call                 # the threading.Thread(...) constructor
    target_method: Optional[str]   # self.<m> target, if resolvable
    bound_attr: Optional[str]      # self.<X> = Thread(...)
    bound_local: Optional[str]     # x = Thread(...)
    func: Optional[FuncInfo]       # function containing the constructor


@dataclasses.dataclass
class AttrAccess:
    attr: str
    node: ast.Attribute
    func: FuncInfo
    write: bool
    locks: FrozenSet[str]
    worker: bool                   # reachable from a thread target
    init: bool                     # inside __init__ (happens-before start)


def _is_thread_ctor(mod: ModuleInfo, call: ast.Call) -> bool:
    return mod.canonical_call(call) == "threading.Thread"


def _is_write(node: ast.Attribute) -> bool:
    if isinstance(node.ctx, (ast.Store, ast.Del)):
        return True
    p = parent(node)
    # self.x[...] = v   /   del self.x[...]   /   self.x[...] += v
    if (isinstance(p, ast.Subscript) and p.value is node
            and isinstance(p.ctx, (ast.Store, ast.Del))):
        return True
    # self.x.append(v) etc.
    if (isinstance(p, ast.Attribute) and p.value is node
            and p.attr in MUTATING_METHODS):
        pp = parent(p)
        if isinstance(pp, ast.Call) and pp.func is p:
            return True
    return False


class ThreadClass:
    """The static thread model of one class that starts worker threads."""

    def __init__(self, mod: ModuleInfo, node: ast.ClassDef):
        self.mod = mod
        self.node = node
        self.name = node.name
        # direct methods only — closures nested inside a method belong to
        # that method's domain, not to the class namespace
        self.methods: Dict[str, List[FuncInfo]] = {}
        for f in mod.functions:
            if parent(f.node) is node:
                self.methods.setdefault(f.name, []).append(f)
        self.starts: List[ThreadStart] = self._find_starts()
        self.worker_methods: Set[str] = self._worker_closure()
        self.safe_attrs: Set[str] = self._safe_attrs()

    # -------------------------------------------------------------- starts
    def _find_starts(self) -> List[ThreadStart]:
        out: List[ThreadStart] = []
        for node in ast.walk(self.node):
            if not (isinstance(node, ast.Call)
                    and _is_thread_ctor(self.mod, node)):
                continue
            target = None
            for kw in node.keywords:
                if kw.arg == "target":
                    tn = dotted_name(kw.value)
                    if tn is not None and tn.startswith("self."):
                        target = tn.split(".", 1)[1]
            bound_attr = bound_local = None
            p = parent(node)
            if isinstance(p, ast.Assign) and len(p.targets) == 1:
                t = p.targets[0]
                if (isinstance(t, ast.Attribute)
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "self"):
                    bound_attr = t.attr
                elif isinstance(t, ast.Name):
                    bound_local = t.id
            out.append(ThreadStart(
                call=node, target_method=target, bound_attr=bound_attr,
                bound_local=bound_local,
                func=self.mod.enclosing_function(node),
            ))
        return out

    # ------------------------------------------------------- worker domain
    def closure_of(self, method: str) -> Set[str]:
        """Method names reachable from ``method`` through ``self.m()``
        calls — the code that runs on the thread targeting ``method``."""
        work: List[str] = [method]
        seen: Set[str] = set()
        while work:
            name = work.pop()
            if name in seen or name not in self.methods:
                continue
            seen.add(name)
            for f in self.methods[name]:
                for n in ast.walk(f.node):
                    if (isinstance(n, ast.Call)
                            and isinstance(n.func, ast.Attribute)
                            and isinstance(n.func.value, ast.Name)
                            and n.func.value.id == "self"):
                        work.append(n.func.attr)
        return seen

    def _worker_closure(self) -> Set[str]:
        seen: Set[str] = set()
        for s in self.starts:
            if s.target_method is not None:
                seen |= self.closure_of(s.target_method)
        return seen

    # ---------------------------------------------------------- safe attrs
    def _safe_attrs(self) -> Set[str]:
        safe: Set[str] = set()
        for node in ast.walk(self.node):
            if not (isinstance(node, ast.Assign) and len(node.targets) == 1):
                continue
            t = node.targets[0]
            if not (isinstance(t, ast.Attribute)
                    and isinstance(t.value, ast.Name)
                    and t.value.id == "self"):
                continue
            if (isinstance(node.value, ast.Call)
                    and self.mod.canonical_call(node.value)
                    in THREADSAFE_CONSTRUCTORS):
                safe.add(t.attr)
        return safe

    def _owning_method(self, g: FuncInfo) -> Optional[FuncInfo]:
        """The direct method whose body (transitively) contains ``g``."""
        p = parent(g.node)
        while p is not None and p is not self.node:
            if isinstance(p, FUNC_TYPES) and parent(p) is self.node:
                return self.mod.info_for(p)
            p = parent(p)
        return None

    # ------------------------------------------------------------ accesses
    def attr_accesses(
        self, held: Dict[int, FrozenSet[str]],
    ) -> List[AttrAccess]:
        out: List[AttrAccess] = []
        for name, infos in self.methods.items():
            for f in infos:
                # closures (transitively) nested inside a method run in its
                # domain
                members = [f] + [
                    g for g in self.mod.functions
                    if g.node is not f.node
                    and enclosing(g.node, ast.ClassDef) is self.node
                    and self._owning_method(g) is f
                ]
                for g in members:
                    for n in ast.walk(g.node):
                        if not (isinstance(n, ast.Attribute)
                                and isinstance(n.value, ast.Name)
                                and n.value.id == "self"):
                            continue
                        if self.mod.enclosing_function(n) is not g:
                            continue
                        out.append(AttrAccess(
                            attr=n.attr, node=n, func=g,
                            write=_is_write(n),
                            locks=locks_at(self.mod, held, n),
                            worker=name in self.worker_methods,
                            init=(name == "__init__"),
                        ))
        return out


def thread_classes(mod: ModuleInfo) -> List[ThreadClass]:
    """Every class in ``mod`` that constructs a ``threading.Thread`` — the
    scope of the unguarded-shared-state / lifecycle rules."""
    out: List[ThreadClass] = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.ClassDef):
            continue
        if any(isinstance(n, ast.Call) and _is_thread_ctor(mod, n)
               for n in ast.walk(node)):
            out.append(ThreadClass(mod, node))
    return out
