"""Shared AST infrastructure for the lint rules.

Everything here is pure ``ast`` — modules are parsed, never imported, so the
linter can run over fixture files with deliberate violations (and over this
repo) without executing anything.

The central abstractions:

- ``ModuleInfo``: one parsed file with parent links, import-alias resolution
  (``np`` -> ``numpy``, ``jnp`` -> ``jax.numpy``), and a table of every
  function-like node (def / async def / lambda) with stable qualnames.
- ``traced_functions(mod)``: the set of functions whose bodies end up inside
  a ``jax.jit`` trace — jit call arguments, ``@jax.jit``-decorated defs
  (including ``functools.partial(jax.jit, ...)``), functions defined inside
  ``_make_*`` step factories, plus everything reachable from those through
  the module-local call graph (plain calls and ``self.method`` calls).
"""

from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Union

FuncNode = Union[ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda]
FUNC_TYPES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)

# step-factory naming convention: functions defined inside a `_make_*`
# function are jit-traced by construction (the factory's return value is
# handed to jax.jit)
MAKE_FACTORY_RE = re.compile(r"^_make_")


def add_parents(tree: ast.AST) -> None:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._parent = node  # type: ignore[attr-defined]


def parent(node: ast.AST) -> Optional[ast.AST]:
    return getattr(node, "_parent", None)


def enclosing(node: ast.AST, types) -> Optional[ast.AST]:
    """Nearest ancestor of one of ``types`` (excluding ``node`` itself)."""
    p = parent(node)
    while p is not None:
        if isinstance(p, types):
            return p
        p = parent(p)
    return None


def dotted_name(node: ast.AST) -> Optional[str]:
    """`a.b.c` attribute/name chain -> "a.b.c" (None for anything else)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


@dataclasses.dataclass
class FuncInfo:
    node: FuncNode
    qualname: str               # e.g. "HybridTrainer._make_train.<locals>.train"
    name: str                   # bare name ("<lambda>" for lambdas)
    cls: Optional[str]          # enclosing class name, if a method


class ModuleInfo:
    """One parsed source file, with the lookup tables the rules share."""

    def __init__(self, path: Path, rel: str, tree: ast.Module):
        self.path = path
        self.rel = rel            # repo-relative path used in findings
        self.tree = tree
        add_parents(tree)
        self.aliases = self._import_aliases(tree)
        self.functions: List[FuncInfo] = self._collect_functions(tree)
        self._by_node: Dict[int, FuncInfo] = {
            id(f.node): f for f in self.functions
        }

    # ------------------------------------------------------------ imports
    @staticmethod
    def _import_aliases(tree: ast.Module) -> Dict[str, str]:
        """local name -> canonical dotted module/object path."""
        aliases: Dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    aliases[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0]
                    )
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    aliases[a.asname or a.name] = f"{node.module}.{a.name}"
        return aliases

    def canonical(self, name: Optional[str]) -> Optional[str]:
        """Resolve the leading segment of a dotted name through the module's
        import aliases: ``np.random.seed`` -> ``numpy.random.seed``."""
        if name is None:
            return None
        head, _, rest = name.partition(".")
        base = self.aliases.get(head, head)
        return f"{base}.{rest}" if rest else base

    def canonical_call(self, call: ast.Call) -> Optional[str]:
        return self.canonical(dotted_name(call.func))

    # ---------------------------------------------------------- functions
    def _collect_functions(self, tree: ast.Module) -> List[FuncInfo]:
        out: List[FuncInfo] = []
        for node in ast.walk(tree):
            if not isinstance(node, FUNC_TYPES):
                continue
            name = getattr(node, "name", "<lambda>")
            parts: List[str] = [name]
            cls = None
            p = parent(node)
            while p is not None:
                if isinstance(p, FUNC_TYPES):
                    parts.append("<locals>")
                    parts.append(getattr(p, "name", "<lambda>"))
                elif isinstance(p, ast.ClassDef):
                    if cls is None:
                        cls = p.name
                    parts.append(p.name)
                p = parent(p)
            out.append(FuncInfo(node, ".".join(reversed(parts)), name, cls))
        return out

    def info_for(self, node: FuncNode) -> FuncInfo:
        return self._by_node[id(node)]

    def enclosing_function(self, node: ast.AST) -> Optional[FuncInfo]:
        f = enclosing(node, FUNC_TYPES)
        return self._by_node[id(f)] if f is not None else None


def is_jit_call(mod: ModuleInfo, call: ast.Call) -> bool:
    """True for ``jax.jit(...)`` / ``jit(...)`` call expressions and for
    ``functools.partial(jax.jit, ...)`` (the decorator spelling)."""
    name = mod.canonical_call(call)
    if name in ("jax.jit", "jax.jit.jit", "jit"):
        return True
    if name in ("functools.partial", "partial") and call.args:
        return mod.canonical(dotted_name(call.args[0])) in ("jax.jit", "jit")
    return False


def jit_traced_args(call: ast.Call) -> Iterable[ast.AST]:
    """The positional arguments of a jit call that name the traced function
    (for ``functools.partial(jax.jit, ...)`` there is none at the call)."""
    if not call.args:
        return []
    first = call.args[0]
    if dotted_name(first) in ("jax.jit", "jit"):
        return call.args[1:2]   # partial(jax.jit, fn?) — rarely carries fn
    return call.args[:1]


def _local_defs(mod: ModuleInfo) -> Dict[str, List[FuncInfo]]:
    """bare name -> defs in this module (used for call-graph resolution)."""
    table: Dict[str, List[FuncInfo]] = {}
    for f in mod.functions:
        table.setdefault(f.name, []).append(f)
    return table


def _called_names(func: FuncNode) -> Set[str]:
    """Bare names this function calls: ``foo(...)`` -> foo,
    ``self.bar(...)`` / ``obj.bar(...)`` -> bar.  Also names merely
    *referenced* (passed to vmap/grad/scan) so higher-order wrappers keep
    the callee reachable."""
    names: Set[str] = set()
    for node in ast.walk(func):
        if node is func:
            continue
        if isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Name):
                names.add(fn.id)
            elif isinstance(fn, ast.Attribute):
                names.add(fn.attr)
        elif isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            names.add(node.id)
    return names


def traced_functions(mod: ModuleInfo) -> Dict[int, FuncInfo]:
    """id(node) -> FuncInfo for every function whose body is jit-traced.

    Roots:
      * lambdas / local function names passed to ``jax.jit(...)``,
      * defs decorated with ``@jax.jit`` or
        ``@functools.partial(jax.jit, ...)``,
      * functions *defined inside* a ``_make_*`` factory (the repo's step
        construction convention — their return value is always jitted).

    Closure: module-local call-graph reachability (a helper called from a
    traced function is traced too).  Resolution is by bare name within the
    module — deliberately conservative; cross-module flow is the trace
    audit's job (layer 2), not the linter's.
    """
    roots: List[FuncInfo] = []
    defs = _local_defs(mod)

    for f in mod.functions:
        node = f.node
        # nested inside a _make_* factory
        p = enclosing(node, FUNC_TYPES)
        if p is not None and MAKE_FACTORY_RE.match(getattr(p, "name", "")):
            roots.append(f)
        # decorated with jax.jit / partial(jax.jit, ...)
        for dec in getattr(node, "decorator_list", []):
            dn = mod.canonical(dotted_name(dec))
            if dn in ("jax.jit", "jit"):
                roots.append(f)
            elif isinstance(dec, ast.Call) and is_jit_call(mod, dec):
                roots.append(f)

    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call) and is_jit_call(mod, node)):
            continue
        for arg in jit_traced_args(node):
            if isinstance(arg, ast.Lambda):
                roots.append(mod.info_for(arg))
            else:
                name = dotted_name(arg)
                if name is None and isinstance(arg, ast.Call):
                    # jax.jit(self._make_step(...)): the factory's nested
                    # defs are already roots via the _make_* convention
                    continue
                if name is not None:
                    bare = name.split(".")[-1]
                    roots.extend(defs.get(bare, []))

    reach: Dict[int, FuncInfo] = {}
    stack = list(roots)
    while stack:
        f = stack.pop()
        if id(f.node) in reach:
            continue
        reach[id(f.node)] = f
        # nested defs (inner closures) of a traced function are traced
        for g in mod.functions:
            if enclosing(g.node, FUNC_TYPES) is f.node:
                stack.append(g)
        for name in _called_names(f.node):
            for g in defs.get(name, []):
                if id(g.node) not in reach:
                    stack.append(g)
    return reach
