"""Layer 3 (dynamic) — deterministic schedule audit over the threaded
subsystems.

The thread-safety lint (layer 3 static, ``rules/``) proves the locking
discipline; this audit proves the *protocol*: no matter when the
read-ahead, write-behind, checkpoint-write, and serve-drain work actually
runs relative to the training loop, the fit/predict trajectories are
bit-identical and the store invariants hold.

The trick is that no real concurrency is used.  Each DiskStore worker
thread is retired and its queue replaced by a ``_PumpQueue`` that parks
the queued work items; a ``SteppedStore`` wrapper then replays the parked
items inline — on the driving thread — at *yield points* chosen by a
deterministic bit ``Schedule``:

- ``readahead``: bit=1 -> the read-ahead faults its pages NOW (before the
  training gather); bit=0 -> the gather races it and faults the pages
  itself, the parked read-ahead running later (finding them resident).
- ``gather``: bit=1 -> any parked read-ahead completes first.
- ``scatter``: bit=1 -> one parked write-behind page write lands right
  after the mutation (eviction vs in-flight read-ahead boundary).
- ``flush``: bit=1 -> one parked write lands before the flush enqueues the
  rest (write-behind flush vs ``save()`` boundary).
- *fault window* (``DiskStore._fault_hook``): the store calls the hook
  with the lock released, between a page fault's file read and its
  reacquire — the one schedule the queue-level yield points above cannot
  reach, because a fault replayed atomically never observes another
  thread's scatter + write-behind completing mid-read.  The
  ``fault-vs-writeback`` cell injects exactly that interference and
  checks the generation guard discards the stale file bytes.

``SteppedCkpt`` gives the checkpoint async writer the same treatment: the
write body runs at a schedule-chosen point (immediately, or deferred to
the next ``wait()``/``save()`` boundary) instead of on a thread.  The
serve cell moves the ``CTRServer.drain`` of a co-located request stream
before/after each train step.  Because every replayed interleaving runs
on one thread, a failure reproduces exactly from ``(cell, schedule)`` —
see docs/analysis.md for the local repro recipe.

Checks per cell (each failure becomes a ``sched-<check>`` Finding, same
baseline gating as the lint):

- ``trajectory``: per-step losses and predict scores bit-identical across
  every schedule.
- ``store-state``: after ``flush()``: ``_dirty`` and ``_in_flight`` empty,
  no stray ``*.tmp`` page files, meters finite and non-negative.
- ``pages``: final on-disk page bytes identical across schedules.
- ``ckpt``: checkpoint content (manifest sans timestamps, array leaves,
  snapshot pages) identical across schedules, and a resumed trainer
  continues with the reference trajectory.
- ``serve``: every submitted request scored; serving leaves the training
  trajectory untouched (compared against a no-serve reference run).
- ``pipeline``: PrefetchPipeline-fed training matches direct-fed training
  bit-for-bit; a raising producer surfaces on the consumer.
"""

from __future__ import annotations

import collections
import dataclasses
import glob
import json
import os
import random
import shutil
import tempfile
import traceback
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.lint import Finding
from repro.analysis.trace_audit import CheckResult

_ROW_STORE_PATH = "src/repro/core/row_store.py"
_TRAINER_PATH = "src/repro/runtime/trainer.py"
_SERVE_CTR_PATH = "src/repro/runtime/serve_ctr.py"
_PIPELINE_PATH = "src/repro/data/pipeline.py"


# ------------------------------------------------------------- schedules
class Schedule:
    """A deterministic stream of yield-point decisions: ``take()`` returns
    the next bit of ``pattern``, cycling forever.  The consumption order is
    fixed by the (single-threaded) replay loop, so ``(name, pattern)``
    fully reproduces an interleaving."""

    def __init__(self, name: str, pattern: Sequence[int]):
        if not pattern:
            raise ValueError("schedule pattern must be non-empty")
        self.name = name
        self.pattern = [int(b) for b in pattern]
        self._i = 0

    def take(self) -> bool:
        b = self.pattern[self._i % len(self.pattern)]
        self._i += 1
        return bool(b)

    def fresh(self) -> "Schedule":
        return Schedule(self.name, self.pattern)


def default_schedules() -> List[Schedule]:
    """The enumerated interleavings: both extremes, both phases of strict
    alternation, and a seeded pseudo-random stream."""
    rnd = random.Random(0xD15C)
    return [
        Schedule("eager", [1]),          # background work always wins
        Schedule("lazy", [0]),           # background work always loses
        Schedule("alternate", [1, 0]),
        Schedule("alternate-off", [0, 1]),
        Schedule("random-d15c", [rnd.randint(0, 1) for _ in range(64)]),
    ]


# ---------------------------------------------------- worker replacement
class _PumpQueue:
    """``queue.Queue`` lookalike that parks items and replays them inline.

    Installed in place of a DiskStore worker queue after the worker thread
    is retired: ``put`` parks, ``join`` (the store's own drain points)
    replays everything on the calling thread, ``pump(n)`` replays up to
    ``n`` items at a schedule-chosen yield point.  ``None`` shutdown
    sentinels are ignored — there is no thread to stop."""

    def __init__(self, process):
        self._process = process
        self._items: collections.deque = collections.deque()

    def put(self, item, *args, **kwargs):
        if item is not None:
            self._items.append(item)

    def task_done(self):
        pass

    def join(self):
        while self._items:
            self._process(self._items.popleft())

    def pump(self, n: int = 1) -> int:
        done = 0
        while self._items and done < n:
            self._process(self._items.popleft())
            done += 1
        return done

    def __len__(self) -> int:
        return len(self._items)


def _retire_workers(store) -> None:
    """Stop the DiskStore worker threads cleanly (sentinel + join, without
    setting ``_stop`` — processing must keep working inline)."""
    store._write_q.join()
    store._read_q.join()
    store._write_q.put(None)
    store._read_q.put(None)
    store._writer.join(timeout=30)
    store._reader.join(timeout=30)


class SteppedStore:
    """DiskStore wrapper replaying worker-queue items at schedule-chosen
    yield points (single-threaded — see module docstring)."""

    kind = "disk"

    def __init__(self, store, schedule: Schedule):
        self.inner = store
        self.schedule = schedule
        _retire_workers(store)
        store._write_q = _PumpQueue(store._process_write_item)
        store._read_q = _PumpQueue(store._process_read_item)

    def __getattr__(self, name):
        return getattr(self.inner, name)

    # ------------------------------------------------------- yield points
    def readahead(self, name, uids):
        self.inner.readahead(name, uids)
        if self.schedule.take():
            self.inner._read_q.join()   # read-ahead wins: pages land now

    def gather(self, name, uids, serve=False):
        if self.schedule.take():
            self.inner._read_q.join()   # parked read-ahead completes first
        return self.inner.gather(name, uids, serve=serve)

    def scatter(self, name, uids, rows, accum):
        out = self.inner.scatter(name, uids, rows, accum)
        if self.schedule.take():
            self.inner._write_q.pump(1)  # one write-behind page lands now
        return out

    def flush(self):
        if self.schedule.take():
            self.inner._write_q.pump(1)  # a write races the flush enqueue
        self.inner.flush()


class SteppedCkpt:
    """CheckpointManager facade whose async write body runs at a
    schedule-chosen point on the calling thread (immediately when the bit
    is 1, else deferred to the next ``wait()``/``save()`` boundary) —
    exactly the two extremes a real writer thread can land in relative to
    the training loop."""

    def __init__(self, ckpt, schedule: Schedule):
        self.inner = ckpt
        self.schedule = schedule
        self._pending: Optional[tuple] = None

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def save(self, step, tree, meta=None, block=False, extras_dir=None):
        import jax

        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self.wait()
        self._pending = (step, host_tree, meta, extras_dir)
        if block or not self.inner.async_save or self.schedule.take():
            self.wait()   # the write lands before training resumes

    def wait(self):
        if self._pending is not None:
            step, host_tree, meta, extras_dir = self._pending
            self._pending = None
            self.inner._write_async(step, host_tree, meta,
                                    extras_dir=extras_dir)
        self.inner.wait()


# ------------------------------------------------------------- trainers
def _build_disk_trainer(prefetch: bool, spill_dir: str,
                        ckpt_dir: Optional[str] = None,
                        ckpt_every: int = 200):
    from repro.core.kstep import KStepConfig
    from repro.runtime.factory import build_trainer
    from repro.runtime.trainer import TrainerConfig

    tcfg = TrainerConfig(
        n_pod=2, kstep=KStepConfig(k=2), placement="cached",
        prefetch=prefetch, log_every=10_000,
        store="disk", spill_dir=spill_dir,
        # small pages + a tight cache: evictions and faults on every step
        page_rows=256, page_cache_pages=8,
        ckpt_dir=ckpt_dir, ckpt_every=ckpt_every, ckpt_async=True,
    )
    return build_trainer("baidu-ctr", tcfg, smoke=True)


def _batches(n: int, batch: int = 64, seed: int = 0) -> List[dict]:
    from repro import configs
    from repro.data import synthetic as S

    mcfg = configs.get("baidu-ctr").smoke_cfg
    gen = S.recsys_batches(mcfg, batch=batch, seed=seed)
    return [next(gen) for _ in range(n)]


@dataclasses.dataclass
class _Run:
    losses: List[float]
    predicts: List[np.ndarray]


def _run_steps(tr, batches: List[dict]) -> _Run:
    """The driving loop every disk cell shares: predict-then-train with the
    prefetch hand-off when configured."""
    losses: List[float] = []
    predicts: List[np.ndarray] = []
    for i, b in enumerate(batches):
        predicts.append(np.asarray(tr.predict(b)))
        nxt = batches[i + 1] if i + 1 < len(batches) else None
        if tr._prefetcher is not None:
            loss = tr.train_step_prefetched(b, nxt)
        else:
            loss = tr.train_step(b)
        losses.append(float(loss))
    return _Run(losses, predicts)


# ------------------------------------------------------------ comparators
def _store_state_checks(target: str, store, spill_dir: str) -> List[CheckResult]:
    out: List[CheckResult] = []
    store.flush()
    with store._lock:
        dirty = set(store._dirty)
        in_flight = dict(store._in_flight)
    ok = not dirty and not in_flight
    out.append(CheckResult(
        target, "store-state", ok,
        "" if ok else (
            f"after flush(): dirty={sorted(dirty)} "
            f"in_flight={sorted(in_flight)}")))
    stray = glob.glob(os.path.join(spill_dir, "**", "*.tmp"),
                      recursive=True)
    out.append(CheckResult(
        target, "store-state", not stray,
        f"stray tmp files after flush: {stray}" if stray else ""))
    meters = {**store.stats(), **store.serve_stats()}
    bad = {k: v for k, v in meters.items()
           if not np.isfinite(v) or v < 0}
    out.append(CheckResult(
        target, "store-state", not bad,
        f"non-finite/negative meters: {bad}" if bad else ""))
    return out


def _page_bytes(spill_dir: str) -> Dict[str, bytes]:
    out = {}
    for path in sorted(glob.glob(
            os.path.join(spill_dir, "**", "page_*.npz"), recursive=True)):
        with open(path, "rb") as f:
            out[os.path.relpath(path, spill_dir)] = f.read()
    return out


def _runs_identical(target: str, check: str, name: str, ref: _Run,
                    got: _Run) -> CheckResult:
    if ref.losses != got.losses:
        i = next(i for i, (a, b) in
                 enumerate(zip(ref.losses, got.losses)) if a != b)
        return CheckResult(
            target, check, False,
            f"schedule {name}: loss diverges at step {i}: "
            f"{ref.losses[i]!r} vs {got.losses[i]!r}")
    for i, (a, b) in enumerate(zip(ref.predicts, got.predicts)):
        if not np.array_equal(a, b):
            return CheckResult(
                target, check, False,
                f"schedule {name}: predict diverges at probe {i} "
                f"(max |d|={np.max(np.abs(a - b))})")
    return CheckResult(target, check, True, "")


def _ckpt_content(ckpt_dir: str) -> Dict[str, object]:
    """Semantic checkpoint content: manifests (sans wall-clock), array
    leaves, snapshot page arrays — keyed by relative path."""
    out: Dict[str, object] = {}
    for path in sorted(glob.glob(
            os.path.join(ckpt_dir, "step_*", "**"), recursive=True)):
        if os.path.isdir(path):
            continue
        rel = os.path.relpath(path, ckpt_dir)
        if path.endswith("manifest.json"):
            with open(path) as f:
                man = json.load(f)
            man.pop("time", None)
            out[rel] = json.dumps(man, sort_keys=True)
        elif path.endswith(".npz"):
            with np.load(path) as z:
                out[rel] = {k: z[k].tobytes() for k in z.files}
    return out


# ------------------------------------------------------------------ cells
def cell_evict_vs_readahead(schedules: Sequence[Schedule],
                            steps: int = 8) -> List[CheckResult]:
    """Page eviction vs in-flight read-ahead: the tight page cache evicts
    dirty pages into the write queue while read-aheads for the same tables
    sit parked — every replay order must serve identical rows."""
    target = "sched/evict-vs-readahead"
    results: List[CheckResult] = []
    batches = _batches(steps)
    ref: Optional[_Run] = None
    for sch in schedules:
        spill = tempfile.mkdtemp(prefix="sched_audit_evict_")
        try:
            tr = _build_disk_trainer(prefetch=True, spill_dir=spill)
            tr.engine.store = SteppedStore(tr.engine.store, sch.fresh())
            run = _run_steps(tr, batches)
            results.extend(_store_state_checks(
                f"{target}/{sch.name}", tr.engine.store.inner, spill))
            pages = _page_bytes(spill)
            if ref is None:
                ref, ref_pages = run, pages
            else:
                results.append(_runs_identical(
                    target, "trajectory", sch.name, ref, run))
                results.append(CheckResult(
                    target, "pages", pages == ref_pages,
                    "" if pages == ref_pages else (
                        f"schedule {sch.name}: final page files differ "
                        f"from {schedules[0].name}")))
            tr.engine.store.close()
        finally:
            shutil.rmtree(spill, ignore_errors=True)
    return results


def cell_flush_vs_save(schedules: Sequence[Schedule],
                       steps: int = 9) -> List[CheckResult]:
    """Write-behind flush vs ``save()``: checkpoints land at schedule-
    chosen times relative to further training; every schedule must publish
    identical checkpoints, and resuming from one must continue exactly on
    the reference trajectory."""
    target = "sched/flush-vs-save"
    results: List[CheckResult] = []
    extra = 3
    batches = _batches(steps + extra)
    ref: Optional[_Run] = None
    ref_tail: Optional[List[float]] = None
    ref_ckpt: Optional[Dict[str, object]] = None
    for sch in schedules:
        spill = tempfile.mkdtemp(prefix="sched_audit_save_")
        ckdir = tempfile.mkdtemp(prefix="sched_audit_ckpt_")
        try:
            tr = _build_disk_trainer(prefetch=True, spill_dir=spill,
                                     ckpt_dir=ckdir, ckpt_every=3)
            tr.engine.store = SteppedStore(tr.engine.store, sch.fresh())
            tr.ckpt = SteppedCkpt(tr.ckpt, sch.fresh())
            run = _run_steps(tr, batches[:steps])
            tr.ckpt.wait()   # land the final deferred write
            content = _ckpt_content(ckdir)
            if ref is None:
                ref, ref_ckpt = run, content
                # reference continuation: 3 more steps past the last save
                ref_tail = [float(tr.train_step_prefetched(
                    batches[steps + i],
                    batches[steps + i + 1] if i + 1 < extra else None))
                    for i in range(extra)]
                tr.engine.store.close()
            else:
                results.append(_runs_identical(
                    target, "trajectory", sch.name, ref, run))
                same = content == ref_ckpt
                results.append(CheckResult(
                    target, "ckpt", same,
                    "" if same else (
                        f"schedule {sch.name}: checkpoint content differs "
                        f"from {schedules[0].name}: "
                        f"{sorted(set(content) ^ set(ref_ckpt))[:4] or 'payload bytes'}")))
                # resume-continuation: a fresh trainer resumed from THIS
                # schedule's checkpoint walks the reference tail
                tr.engine.store.close()
                tr2 = _build_disk_trainer(prefetch=True, spill_dir=spill,
                                          ckpt_dir=ckdir, ckpt_every=10**9)
                resumed = tr2.resume()
                tail: List[float] = []
                if resumed:
                    tail = [float(tr2.train_step_prefetched(
                        batches[steps + i],
                        batches[steps + i + 1] if i + 1 < extra else None))
                        for i in range(extra)]
                ok = resumed and tail == ref_tail
                results.append(CheckResult(
                    target, "ckpt", ok,
                    "" if ok else (
                        f"schedule {sch.name}: resumed continuation "
                        f"diverges: {tail} vs {ref_tail}"
                        if resumed else
                        f"schedule {sch.name}: resume() found no "
                        f"checkpoint")))
                tr2.engine.store.close()
        finally:
            shutil.rmtree(spill, ignore_errors=True)
            shutil.rmtree(ckdir, ignore_errors=True)
    return results


def cell_prefetch_vs_serve(schedules: Sequence[Schedule],
                           steps: int = 6) -> List[CheckResult]:
    """Prefetch commit vs serve drain: a co-located ``CTRServer`` drains a
    second request stream before or after each train step (schedule bit),
    with a prefetched pull in flight either way — training must stay
    bit-identical to a run that never serves, and every request must be
    scored."""
    from repro.runtime.factory import build_ctr_server

    target = "sched/prefetch-vs-serve"
    results: List[CheckResult] = []
    batches = _batches(steps)
    serve_batches = _batches(steps, batch=32, seed=1)

    # no-serve reference
    spill = tempfile.mkdtemp(prefix="sched_audit_serve_ref_")
    try:
        tr = _build_disk_trainer(prefetch=True, spill_dir=spill)
        tr.engine.store = SteppedStore(
            tr.engine.store, Schedule("eager", [1]))
        ref = _run_steps(tr, batches)
        tr.engine.store.close()
    finally:
        shutil.rmtree(spill, ignore_errors=True)

    for sch in schedules:
        spill = tempfile.mkdtemp(prefix="sched_audit_serve_")
        try:
            tr = _build_disk_trainer(prefetch=True, spill_dir=spill)
            tr.engine.store = SteppedStore(tr.engine.store, sch.fresh())
            srv = build_ctr_server(tr, max_batch=32)
            drain_sch = sch.fresh()
            submitted = [0]

            def drain(i):
                srv.submit_batch(serve_batches[i])
                submitted[0] += len(serve_batches[i]["label"])
                srv.drain()

            run = _Run([], [])
            for i, b in enumerate(batches):
                run.predicts.append(np.asarray(tr.predict(b)))
                if drain_sch.take():
                    drain(i)   # drain BEFORE the step, pull in flight
                    post = False
                else:
                    post = True
                nxt = batches[i + 1] if i + 1 < len(batches) else None
                run.losses.append(
                    float(tr.train_step_prefetched(b, nxt)))
                if post:
                    drain(i)
            results.append(_runs_identical(
                target, "trajectory", sch.name, ref, run))
            served = srv.stats["served"]
            ok = served == submitted[0] and not srv.pending
            results.append(CheckResult(
                target, "serve", ok,
                "" if ok else (
                    f"schedule {sch.name}: served {served} of "
                    f"{submitted[0]} submitted "
                    f"({len(srv.pending)} still queued)")))
            results.extend(_store_state_checks(
                f"{target}/{sch.name}", tr.engine.store.inner, spill))
            tr.engine.store.close()
        finally:
            shutil.rmtree(spill, ignore_errors=True)
    return results


def cell_fault_vs_writeback(schedules: Sequence[Schedule]) -> List[CheckResult]:
    """Page-fault file read vs write-behind completion: while a fault holds
    the store lock RELEASED for its ``np.load``, a racing thread (replayed
    inline through ``DiskStore._fault_hook``) faults the same page,
    scatters it, eviction queues the dirty page — and, on schedule bit 1,
    the write-behind lands and the lookaside retires before the fault
    reacquires.  Both orders must surface the scattered values (the
    generation guard forces the fault to discard its pre-scatter file
    bytes and re-read) and converge to identical on-disk pages."""
    from repro.core.row_store import DiskStore

    target = "sched/fault-vs-writeback"
    results: List[CheckResult] = []
    new_rows = np.full((2, 2), 5.0, np.float32)
    new_acc = np.full((2, 2), 1.0, np.float32)
    ref_pages: Optional[Dict[str, bytes]] = None
    ref_name = schedules[0].name if schedules else ""
    for sch in schedules:
        spill = tempfile.mkdtemp(prefix="sched_audit_fault_")
        try:
            st = DiskStore(spill, page_rows=4, page_cache_pages=1)
            st.create_table("t", rows=8, dim=2, dtype=np.float32)
            _retire_workers(st)
            st._write_q = _PumpQueue(st._process_write_item)
            st._read_q = _PumpQueue(st._process_read_item)
            s = sch.fresh()
            fired: List[tuple] = []

            def interfere(key, st=st, s=s, fired=fired):
                # one-shot, page 0 only — the inner scatters re-enter the
                # fault path (pages 0 and 1) and must not recurse
                if fired or key[1] != 0:
                    return
                fired.append(key)
                st.scatter("t", np.array([0, 1], np.int64),
                           new_rows, new_acc)
                # faulting page 1 into the 1-page cache evicts dirty
                # page 0 into the (parked) write-behind queue
                st.scatter("t", np.array([4], np.int64),
                           np.full((1, 2), 9.0, np.float32),
                           np.full((1, 2), 2.0, np.float32))
                if s.take():
                    # the hazardous order: the write lands and the
                    # lookaside retires INSIDE the fault window
                    st._write_q.join()

            st._fault_hook = interfere
            v, a = st.gather("t", np.arange(4, dtype=np.int64))
            st._fault_hook = None
            ok = (bool(fired)
                  and np.array_equal(v[:2], new_rows)
                  and np.array_equal(a[:2], new_acc)
                  and np.array_equal(v[2:], np.zeros((2, 2), np.float32)))
            results.append(CheckResult(
                target, "trajectory", ok,
                "" if ok else (
                    f"schedule {sch.name}: fault window lost the racing "
                    f"scatter (hook fired={bool(fired)}, "
                    f"rows={v[:2].tolist()})")))
            results.extend(_store_state_checks(
                f"{target}/{sch.name}", st, spill))
            pages = _page_bytes(spill)
            if ref_pages is None:
                ref_pages = pages
            else:
                results.append(CheckResult(
                    target, "pages", pages == ref_pages,
                    "" if pages == ref_pages else (
                        f"schedule {sch.name}: final page files differ "
                        f"from {ref_name}")))
            st.close()
        finally:
            shutil.rmtree(spill, ignore_errors=True)
    return results


def cell_pipeline_producer(schedules: Sequence[Schedule],
                           steps: int = 6) -> List[CheckResult]:
    """The data-pipeline producer thread: pipeline-fed training must match
    direct-fed training bit-for-bit, and a raising producer must surface
    on the consumer thread (never a silent end-of-stream)."""
    from repro.data.pipeline import PrefetchPipeline

    target = "sched/pipeline-producer"
    results: List[CheckResult] = []
    batches = _batches(steps)

    def train(feed) -> List[float]:
        spill = tempfile.mkdtemp(prefix="sched_audit_pipe_")
        try:
            tr = _build_disk_trainer(prefetch=False, spill_dir=spill)
            tr.engine.store = SteppedStore(
                tr.engine.store, Schedule("eager", [1]))
            losses = [float(tr.train_step(b)) for b in feed]
            tr.engine.store.close()
            return losses
        finally:
            shutil.rmtree(spill, ignore_errors=True)

    direct = train(iter(batches))
    pipe = PrefetchPipeline(iter(batches), depth=2)
    piped = train(pipe)
    pipe.close()
    ok = direct == piped
    results.append(CheckResult(
        target, "pipeline", ok,
        "" if ok else "pipeline-fed losses differ from direct-fed"))

    def failing_source():
        yield batches[0]
        raise RuntimeError("boom at batch 1")

    pipe = PrefetchPipeline(failing_source(), depth=2)
    got: Optional[str] = None
    try:
        for _ in pipe:
            pass
    except RuntimeError as e:
        got = str(e.__cause__)
    finally:
        pipe.close()
    ok = got == "boom at batch 1"
    results.append(CheckResult(
        target, "pipeline", ok,
        "" if ok else (
            f"producer exception not re-raised on the consumer "
            f"(saw {got!r})")))
    return results


# ------------------------------------------------------------------ gate
_CELLS = {
    "evict-vs-readahead": (cell_evict_vs_readahead, _ROW_STORE_PATH),
    "fault-vs-writeback": (cell_fault_vs_writeback, _ROW_STORE_PATH),
    "flush-vs-save": (cell_flush_vs_save, _TRAINER_PATH),
    "prefetch-vs-serve": (cell_prefetch_vs_serve, _SERVE_CTR_PATH),
    "pipeline-producer": (cell_pipeline_producer, _PIPELINE_PATH),
}


def _finding(path: str, res: CheckResult) -> Finding:
    return Finding(
        rule=f"sched-{res.check}", path=path, line=0,
        symbol=res.target, detail=res.check,
        message=f"schedule audit [{res.target}] {res.check}: {res.detail}",
    )


def run_sched_audit(
    cells: Optional[Sequence[str]] = None,
    schedules: Optional[Sequence[Schedule]] = None,
    log=None,
) -> Tuple[List[Finding], List[Dict]]:
    """Replay every cell under every schedule; returns ``(findings,
    report)`` — findings are the FAILED checks (baseline-gated by the
    CLI), the report records every check for the CI artifact."""
    if schedules is None:
        schedules = default_schedules()
    names = list(cells) if cells is not None else list(_CELLS)
    unknown = [n for n in names if n not in _CELLS]
    if unknown:
        raise ValueError(
            f"unknown sched-audit cell(s) {unknown}; "
            f"available: {sorted(_CELLS)}")
    findings: List[Finding] = []
    report: List[Dict] = []
    for name in names:
        fn, path = _CELLS[name]
        if log:
            log(f"sched-audit: {name} x {len(schedules)} schedules")
        try:
            results = fn(schedules)
        except Exception:
            results = [CheckResult(
                f"sched/{name}", "audit-error", False,
                traceback.format_exc(limit=3).strip())]
        for r in results:
            report.append(dataclasses.asdict(r))
            if not r.ok:
                findings.append(_finding(path, r))
    return findings, report
