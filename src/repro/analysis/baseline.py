"""Checked-in findings baseline — pre-existing accepted cases don't fail CI.

The baseline file (``analysis-baseline.json`` at the repo root) holds one
entry per accepted finding, keyed by ``(rule, file, symbol, detail)`` — NO
line numbers, so unrelated edits that shift lines don't invalidate entries.
Every entry carries a human ``justification``; ``--update-baseline`` writes
the current findings (preserving justifications of entries that survive) and
prints the ones that need a justification filled in.

A baseline entry that matches nothing is *stale* and reported (exit stays 0
— stale entries are cleanup debt, not a gate failure; ``--update-baseline``
drops them).
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, Iterable, List, Tuple

Key = Tuple[str, str, str, str]

FILL_ME = "TODO: justify or fix"


def finding_key(f) -> Key:
    return (f.rule, f.path, f.symbol, f.detail)


@dataclasses.dataclass
class Baseline:
    entries: Dict[Key, str]          # key -> justification
    path: Path

    @classmethod
    def load(cls, path: Path) -> "Baseline":
        entries: Dict[Key, str] = {}
        if path.exists():
            data = json.loads(path.read_text())
            for e in data.get("entries", []):
                key = (e["rule"], e["file"], e["symbol"], e["detail"])
                entries[key] = e.get("justification", "")
        return cls(entries=entries, path=path)

    def split(self, findings: Iterable) -> Tuple[List, List, List[Key]]:
        """-> (new_findings, baselined_findings, stale_keys)."""
        findings = list(findings)
        seen = {finding_key(f) for f in findings}
        new = [f for f in findings if finding_key(f) not in self.entries]
        old = [f for f in findings if finding_key(f) in self.entries]
        stale = [k for k in self.entries if k not in seen]
        return new, old, stale

    def update(self, findings: Iterable) -> int:
        """Rewrite the baseline to exactly the current findings, keeping
        existing justifications.  Returns the number of entries still
        needing a justification."""
        entries = []
        missing = 0
        for f in sorted(findings, key=finding_key):
            key = finding_key(f)
            just = self.entries.get(key, FILL_ME)
            if just == FILL_ME:
                missing += 1
            entries.append({
                "rule": key[0], "file": key[1], "symbol": key[2],
                "detail": key[3], "justification": just,
            })
        self.path.write_text(json.dumps(
            {"version": 1, "entries": entries}, indent=2) + "\n")
        self.entries = {
            (e["rule"], e["file"], e["symbol"], e["detail"]):
                e["justification"]
            for e in entries
        }
        return missing
