"""R1 ``host-sync-in-jit`` — host-synchronizing calls inside traced code.

A ``float()``/``.item()``/``np.asarray``/``jax.device_get``/
``.block_until_ready()`` on a traced value either fails at trace time or —
worse — silently forces a device->host round trip per step when the value is
a constant being folded.  Any of them appearing in a function that jit
traces (directly jitted, passed to ``jax.jit``, defined inside a ``_make_*``
step factory, or called from one of those) is a finding.

The materialization points the hot path is *allowed* to use live outside
traced functions (logging/checkpoint boundaries) and use explicit
``jax.device_get`` — which this rule only flags INSIDE traces, where it is
always a bug.
"""

from __future__ import annotations

import ast
from typing import Iterable, List

from repro.analysis.astutil import ModuleInfo, dotted_name, traced_functions
from repro.analysis import lint

# builtin conversions that force a scalar materialization
_SYNC_BUILTINS = {"float", "int", "bool"}
# canonical (alias-resolved) dotted calls that move device values to host
_SYNC_CALLS = {
    "numpy.asarray", "numpy.array", "numpy.float32", "numpy.float64",
    "jax.device_get",
}
# method calls that synchronize regardless of receiver typing
_SYNC_METHODS = {"item", "block_until_ready"}


class HostSyncInJitRule:
    name = "host-sync-in-jit"
    description = (
        "host-synchronizing call (float/.item/np.asarray/jax.device_get/"
        ".block_until_ready) reachable from a jit-traced function"
    )

    def run(self, project) -> Iterable["lint.Finding"]:
        findings: List[lint.Finding] = []
        for mod in project:
            traced = traced_functions(mod)
            for info in traced.values():
                findings.extend(self._scan(mod, info, traced))
        return findings

    def _scan(self, mod: ModuleInfo, info, traced) -> List["lint.Finding"]:
        out = []
        for node in ast.walk(info.node):
            if not isinstance(node, ast.Call):
                continue
            # skip calls that lexically belong to a NESTED function — it is
            # scanned under its own FuncInfo (keeps symbol names exact)
            encl = mod.enclosing_function(node)
            if encl is None or encl.node is not info.node:
                continue
            detail = self._offending(mod, node)
            if detail is None:
                continue
            out.append(lint.Finding(
                rule=self.name, path=mod.rel, line=node.lineno,
                symbol=info.qualname, detail=detail,
                message=(
                    f"`{detail}` inside jit-traced `{info.qualname}` forces "
                    "a host sync (or fails at trace time) — keep the hot "
                    "path on device; materialize at logging/checkpoint "
                    "boundaries with jax.device_get"
                ),
            ))
        return out

    @staticmethod
    def _offending(mod: ModuleInfo, call: ast.Call):
        fn = call.func
        if isinstance(fn, ast.Name) and fn.id in _SYNC_BUILTINS:
            # float(...) on a literal/shape constant is fine; on anything
            # else it is a sync.  Only suppress the obviously-static cases.
            if call.args and isinstance(call.args[0], ast.Constant):
                return None
            return f"{fn.id}()"
        name = mod.canonical(dotted_name(fn))
        if name in _SYNC_CALLS:
            return name
        if isinstance(fn, ast.Attribute) and fn.attr in _SYNC_METHODS:
            return f".{fn.attr}()"
        return None
