"""R2 ``dead-config-knob`` — dataclass config fields nothing ever reads.

PR 3 found ``merge_delay`` and ``merge_quorum`` silently accepted and
ignored; this rule makes the class of bug structural.  A field of any
``@dataclass`` whose class name ends in ``Config`` or ``Spec`` must be READ
somewhere — an ``obj.field`` attribute load or a literal
``getattr(obj, "field")`` — anywhere in the tree outside the class
definition's own field declarations.  Constructor keywords and
``dataclasses.replace`` keywords are *writes*, not reads: a knob that is
only ever set is exactly the bug.

Matching is by attribute name project-wide (any ``.field`` load anywhere
counts), so a generic name like ``rows`` never false-positives; the rule
errs toward silence — what it DOES flag is truly read nowhere.
"""

from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, List, Set, Tuple

from repro.analysis.astutil import dotted_name
from repro.analysis import lint

CONFIG_CLASS_RE = re.compile(r"(Config|Spec)$")


def _is_dataclass(node: ast.ClassDef) -> bool:
    for dec in node.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = dotted_name(target)
        if name and name.split(".")[-1] == "dataclass":
            return True
    return False


class DeadConfigKnobRule:
    name = "dead-config-knob"
    description = (
        "dataclass *Config/*Spec field never read (attribute load or "
        "getattr) anywhere in the project"
    )

    def run(self, project) -> Iterable["lint.Finding"]:
        # pass 1: declared fields of every config dataclass
        fields: List[Tuple] = []   # (mod, class_node, field, line)
        for mod in project:
            for node in ast.walk(mod.tree):
                if not (isinstance(node, ast.ClassDef)
                        and CONFIG_CLASS_RE.search(node.name)
                        and _is_dataclass(node)):
                    continue
                for stmt in node.body:
                    if (isinstance(stmt, ast.AnnAssign)
                            and isinstance(stmt.target, ast.Name)
                            and not stmt.target.id.startswith("_")):
                        fields.append(
                            (mod, node, stmt.target.id, stmt.lineno)
                        )

        if not fields:
            return []

        # pass 2: every attribute-load / literal-getattr name in the project
        # (outside class bodies' own declarations — a field's default or
        # annotation referencing a sibling name is not a read of the knob)
        read: Set[str] = set()
        for mod in project:
            for node in ast.walk(mod.tree):
                if isinstance(node, ast.Attribute) and isinstance(
                        node.ctx, ast.Load):
                    read.add(node.attr)
                elif isinstance(node, ast.Call):
                    name = dotted_name(node.func)
                    if (name and name.split(".")[-1] == "getattr"
                            and len(node.args) >= 2
                            and isinstance(node.args[1], ast.Constant)
                            and isinstance(node.args[1].value, str)):
                        read.add(node.args[1].value)

        findings: List[lint.Finding] = []
        for mod, cls, field, line in fields:
            if field in read:
                continue
            findings.append(lint.Finding(
                rule=self.name, path=mod.rel, line=line,
                symbol=f"{cls.name}.{field}", detail=field,
                message=(
                    f"config knob `{cls.name}.{field}` is never read "
                    "anywhere — wire it, delete it, or make its "
                    "constructor reject non-default values (the "
                    "no-silent-config contract)"
                ),
            ))
        return findings
