"""R4 ``undonated-hot-jit`` — hot-path jit call sites with no donation
decision.

The step/pull/decode executables run every iteration over buffers the
caller immediately replaces (tables, accumulators, KV cache, optimizer
state).  A ``jax.jit`` with no ``donate_argnums``/``donate_argnames`` there
doubles the peak working set — XLA must materialize the outputs next to the
still-live inputs (exactly the bug fixed for the decode KV cache in
``runtime/serve.py``).

The rule flags every jit call in the designated hot-path modules that makes
NO donation decision at all.  ``donate_argnums=()`` (explicitly donating
nothing) passes: the contract is that donation was *considered*, not that
every jit must donate — merge-boundary or setup jits legitimately keep
their inputs alive, and say so explicitly (or carry a baseline entry with
the justification).
"""

from __future__ import annotations

import ast
import fnmatch
from typing import Iterable, List, Sequence

from repro.analysis.astutil import dotted_name, is_jit_call
from repro.analysis import lint

# modules whose jits ARE the hot path: one executable per train/pull/decode
# step.  Glob-matched against the repo-relative path.
DEFAULT_HOT_MODULES = (
    "*/runtime/trainer.py",
    "*/runtime/serve.py",
    "*/runtime/serve_ctr.py",
    "*/core/embedding_engine.py",
    "*/core/prefetch.py",
    "*/core/cache_tier.py",
)

_DONATE_KWARGS = {"donate_argnums", "donate_argnames"}


class UndonatedHotJitRule:
    name = "undonated-hot-jit"
    description = (
        "jax.jit call in a hot-path module with no donate_argnums/"
        "donate_argnames decision"
    )

    def __init__(self, hot_modules: Sequence[str] = DEFAULT_HOT_MODULES):
        self.hot_modules = tuple(hot_modules)

    def _is_hot(self, rel: str) -> bool:
        return any(fnmatch.fnmatch(rel, pat) for pat in self.hot_modules)

    def run(self, project) -> Iterable["lint.Finding"]:
        findings: List[lint.Finding] = []
        for mod in project:
            if not self._is_hot(mod.rel):
                continue
            for node in ast.walk(mod.tree):
                if not (isinstance(node, ast.Call)
                        and is_jit_call(mod, node)):
                    continue
                kwargs = {kw.arg for kw in node.keywords}
                if kwargs & _DONATE_KWARGS:
                    continue
                encl = mod.enclosing_function(node)
                symbol = encl.qualname if encl is not None else mod.rel
                if node.args:
                    target = dotted_name(node.args[0]) or (
                        "<lambda>" if isinstance(node.args[0], ast.Lambda)
                        else "<expr>"
                    )
                else:
                    target = "<partial>"
                findings.append(lint.Finding(
                    rule=self.name, path=mod.rel, line=node.lineno,
                    symbol=symbol, detail=f"jit({target})",
                    message=(
                        "hot-path jax.jit makes no donation decision — "
                        "donate the per-step buffers the caller replaces "
                        "(donate_argnums=...), or state donate_argnums=() "
                        "explicitly / baseline with a justification"
                    ),
                ))
        return findings
