"""Lint rule registry.

Each rule exposes ``name``, ``description``, and ``run(project) ->
Iterable[Finding]``.  Rules only *report* — gating against the checked-in
baseline happens in the CLI, so a rule never needs to know which findings
are accepted.
"""

from repro.analysis.rules.host_sync import HostSyncInJitRule
from repro.analysis.rules.dead_knob import DeadConfigKnobRule
from repro.analysis.rules.nondeterminism import NondeterminismInTraceRule
from repro.analysis.rules.donation import UndonatedHotJitRule

ALL_RULES = [
    HostSyncInJitRule(),
    DeadConfigKnobRule(),
    NondeterminismInTraceRule(),
    UndonatedHotJitRule(),
]

__all__ = [
    "ALL_RULES",
    "HostSyncInJitRule",
    "DeadConfigKnobRule",
    "NondeterminismInTraceRule",
    "UndonatedHotJitRule",
]
