"""Lint rule registry.

Each rule exposes ``name``, ``description``, and ``run(project) ->
Iterable[Finding]``.  Rules only *report* — gating against the checked-in
baseline happens in the CLI, so a rule never needs to know which findings
are accepted.

Rules R1–R4 police the jitted single-thread hot path (layer 1, PR 5);
R5–R9 are the concurrency layer over the threaded storage/serving
subsystems (layer 3 — see docs/analysis.md).
"""

from repro.analysis.rules.host_sync import HostSyncInJitRule
from repro.analysis.rules.dead_knob import DeadConfigKnobRule
from repro.analysis.rules.nondeterminism import NondeterminismInTraceRule
from repro.analysis.rules.donation import UndonatedHotJitRule
from repro.analysis.rules.shared_state import UnguardedSharedStateRule
from repro.analysis.rules.blocking_io import BlockingIOUnderLockRule
from repro.analysis.rules.lock_order import LockOrderInversionRule
from repro.analysis.rules.worker_lifecycle import (
    SilentDaemonDeathRule,
    UnjoinedWorkerRule,
)

ALL_RULES = [
    HostSyncInJitRule(),
    DeadConfigKnobRule(),
    NondeterminismInTraceRule(),
    UndonatedHotJitRule(),
    UnguardedSharedStateRule(),
    BlockingIOUnderLockRule(),
    LockOrderInversionRule(),
    UnjoinedWorkerRule(),
    SilentDaemonDeathRule(),
]

__all__ = [
    "ALL_RULES",
    "HostSyncInJitRule",
    "DeadConfigKnobRule",
    "NondeterminismInTraceRule",
    "UndonatedHotJitRule",
    "UnguardedSharedStateRule",
    "BlockingIOUnderLockRule",
    "LockOrderInversionRule",
    "UnjoinedWorkerRule",
    "SilentDaemonDeathRule",
]
