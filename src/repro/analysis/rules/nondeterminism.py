"""R3 ``nondeterminism-in-trace`` — wall clock / host RNG inside traces.

``time.time()`` or ``np.random.*`` inside a traced function doesn't do what
it looks like: the value is captured ONCE at trace time and baked into the
executable as a constant, so every subsequent step reuses the first step's
"random" draw / timestamp — silently.  Reproducible sparse training (and the
bit-identity contract between the prefetched and synchronous paths) requires
all randomness to flow through ``jax.random`` keys and all timing to stay on
the host side of the step boundary.
"""

from __future__ import annotations

import ast
from typing import Iterable, List

from repro.analysis.astutil import ModuleInfo, dotted_name, traced_functions
from repro.analysis import lint

# canonical (alias-resolved) prefixes that are nondeterministic on the host
_NONDET_EXACT = {
    "time.time", "time.time_ns", "time.perf_counter",
    "time.perf_counter_ns", "time.monotonic", "datetime.datetime.now",
    "uuid.uuid4",
}
_NONDET_PREFIXES = ("numpy.random.", "random.")


class NondeterminismInTraceRule:
    name = "nondeterminism-in-trace"
    description = (
        "host wall clock or host RNG (time.*, np.random.*, random.*) inside "
        "a jit-traced function — baked in as a trace-time constant"
    )

    def run(self, project) -> Iterable["lint.Finding"]:
        findings: List[lint.Finding] = []
        for mod in project:
            traced = traced_functions(mod)
            for info in traced.values():
                for node in ast.walk(info.node):
                    if not isinstance(node, ast.Call):
                        continue
                    encl = mod.enclosing_function(node)
                    if encl is None or encl.node is not info.node:
                        continue
                    name = mod.canonical(dotted_name(node.func))
                    if name is None:
                        continue
                    if not (name in _NONDET_EXACT or any(
                            name.startswith(p) for p in _NONDET_PREFIXES)):
                        continue
                    findings.append(lint.Finding(
                        rule=self.name, path=mod.rel, line=node.lineno,
                        symbol=info.qualname, detail=name,
                        message=(
                            f"`{name}` inside jit-traced `{info.qualname}` "
                            "is evaluated once at trace time and baked into "
                            "the executable — use jax.random keys / pass "
                            "host values in as arguments"
                        ),
                    ))
        return findings
