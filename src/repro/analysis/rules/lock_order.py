"""R7 ``lock-order-inversion`` — two locks acquired in both orders.

Classic deadlock precondition: thread 1 holds A and wants B while thread 2
holds B and wants A.  The rule collects every nested acquisition ordering
in the project — lexically nested ``with`` blocks, plus one level of
call-graph transitivity (``with A: helper()`` where ``helper`` acquires B)
— and reports every site that participates in an inverted pair.

Lock identity is ``Class.attr`` for ``self.<attr>`` locks and
``<module-stem>.<name>`` for module-level locks, so two classes' private
``_lock`` attributes are distinct.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis import lint
from repro.analysis.astutil import (
    FUNC_TYPES,
    ModuleInfo,
    dotted_name,
    enclosing,
)
from repro.analysis.threadutil import (
    LOCK_NAME_RE,
    resolve_calls,
    walk_scope,
)

Witness = Tuple[str, int, str]   # (path, line, symbol)


def _lock_ids(mod: ModuleInfo, node: ast.With) -> List[str]:
    """Qualified ids of lock-ish context managers acquired by ``node``, in
    acquisition order."""
    out: List[str] = []
    for item in node.items:
        name = dotted_name(item.context_expr)
        if name is None:
            continue
        leaf = name.split(".")[-1]
        if not LOCK_NAME_RE.search(leaf):
            continue
        if name.startswith("self."):
            cls = enclosing(node, ast.ClassDef)
            owner = cls.name if cls is not None else Path(mod.rel).stem
        else:
            owner = Path(mod.rel).stem
        out.append(f"{owner}.{leaf}")
    return out


class LockOrderInversionRule:
    name = "lock-order-inversion"
    description = "two locks are acquired in both orders across the project"

    def run(self, project) -> Iterable["lint.Finding"]:
        # ordered pair (outer, inner) -> witness sites
        pairs: Dict[Tuple[str, str], List[Witness]] = {}

        def witness(outer: str, inner: str, mod: ModuleInfo,
                    node: ast.AST) -> None:
            if outer == inner:
                return
            encl = mod.enclosing_function(node)
            sym = encl.qualname if encl is not None else ""
            pairs.setdefault((outer, inner), []).append(
                (mod.rel, node.lineno, sym)
            )

        for mod in project:
            withs = [
                n for n in ast.walk(mod.tree) if isinstance(n, ast.With)
            ]
            if not withs:
                continue
            resolved = resolve_calls(mod)
            # per-function transitive acquire sets (direct + callees)
            acquires: Dict[int, Set[str]] = {}
            for f in mod.functions:
                direct: Set[str] = set()
                for n in walk_scope(f.node):
                    if isinstance(n, ast.With):
                        direct |= set(_lock_ids(mod, n))
                acquires[id(f.node)] = direct
            changed = True
            while changed:
                changed = False
                for f in mod.functions:
                    acc = acquires[id(f.node)]
                    for n in walk_scope(f.node):
                        if not isinstance(n, ast.Call):
                            continue
                        for t in resolved.get(id(n), []):
                            extra = acquires.get(id(t.node), set()) - acc
                            if extra:
                                acc |= extra
                                changed = True

            for w in withs:
                ids = _lock_ids(mod, w)
                if not ids:
                    continue
                # multi-item `with a, b:` orders a before b
                for i, outer in enumerate(ids):
                    for inner in ids[i + 1:]:
                        witness(outer, inner, mod, w)
                outer = ids[-1]
                for n in walk_scope(w):
                    if n is w:
                        continue
                    if isinstance(n, ast.With):
                        for inner in _lock_ids(mod, n):
                            witness(outer, inner, mod, n)
                    elif isinstance(n, ast.Call):
                        for t in resolved.get(id(n), []):
                            for inner in acquires.get(id(t.node), ()):
                                witness(outer, inner, mod, n)

        findings: List[lint.Finding] = []
        for (a, b), sites in sorted(pairs.items()):
            if (b, a) not in pairs or a > b:
                continue   # report each inverted {A, B} set once per order…
            for order, osites in (((a, b), pairs[(b, a)]),
                                  ((b, a), pairs[(a, b)])):
                for path, line, sym in pairs[order]:
                    opath, oline, _ = osites[0]
                    findings.append(lint.Finding(
                        rule=self.name, path=path, line=line, symbol=sym,
                        detail=f"{order[0]} -> {order[1]}",
                        message=(
                            f"acquires {order[1]} while holding "
                            f"{order[0]}, but the opposite order is taken "
                            f"at {opath}:{oline} — pick one global order "
                            f"(or collapse to a single lock)"
                        ),
                    ))
        return findings
