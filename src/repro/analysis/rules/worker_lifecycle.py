"""R8 ``unjoined-worker`` + R9 ``silent-daemon-death`` — worker thread
lifecycle hygiene.

``unjoined-worker``: a started thread that no code ever joins.  Daemon
workers that outlive ``close()``/commit boundaries keep file handles and
queues alive past checkpoint publication — the DiskStore contract is that
``close()``/``flush()`` drain and join before ``snapshot_to`` publishes
pages.

``silent-daemon-death``: a worker target whose closure never captures an
exception into instance state (or ships it through a queue/callback).  A
daemon thread that dies silently turns "write-behind stopped" into data
loss discovered at restore time; the repo-wide idiom is
``except BaseException as e: self._err = e`` re-raised on the main thread
at the next checkpoint boundary (``CheckpointManager.wait``,
``DiskStore._check_bg``).
"""

from __future__ import annotations

import ast
from typing import Iterable, List, Set

from repro.analysis import lint
from repro.analysis.astutil import dotted_name, parent
from repro.analysis.threadutil import ThreadClass, thread_classes


def _method_calls_on(tc: ThreadClass, method: str) -> Set[str]:
    """Attributes X such that ``self.X.<method>(...)`` appears anywhere in
    the class body."""
    out: Set[str] = set()
    for node in ast.walk(tc.node):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == method):
            continue
        recv = dotted_name(node.func.value)
        if recv is not None and recv.startswith("self."):
            out.add(recv.split(".", 1)[1])
    return out


class UnjoinedWorkerRule:
    name = "unjoined-worker"
    description = "thread is started but never joined at any boundary"

    def run(self, project) -> Iterable["lint.Finding"]:
        findings: List[lint.Finding] = []
        for mod in project:
            for tc in thread_classes(mod):
                started = _method_calls_on(tc, "start")
                joined = _method_calls_on(tc, "join")
                for s in tc.starts:
                    label = s.target_method or "<thread>"
                    if s.bound_attr is not None:
                        if s.bound_attr not in started:
                            continue   # constructed but never started
                        if s.bound_attr in joined:
                            continue
                        where = f"self.{s.bound_attr}"
                    elif s.bound_local is not None:
                        def locals_calling(method: str) -> Set[str]:
                            if s.func is None:
                                return set()
                            return {
                                n.func.value.id
                                for n in ast.walk(s.func.node)
                                if isinstance(n, ast.Call)
                                and isinstance(n.func, ast.Attribute)
                                and n.func.attr == method
                                and isinstance(n.func.value, ast.Name)
                            }
                        starts = locals_calling("start")
                        joins = locals_calling("join")
                        if s.bound_local not in starts:
                            continue
                        if s.bound_local in joins:
                            continue
                        where = s.bound_local
                    else:
                        # anonymous: only a chained .start() makes it run,
                        # and then nothing can ever join it
                        p = parent(s.call)
                        chained = (
                            isinstance(p, ast.Attribute)
                            and p.attr == "start"
                            and isinstance(parent(p), ast.Call)
                        )
                        if not chained:
                            continue
                        where = "<anonymous>"
                    findings.append(lint.Finding(
                        rule=self.name, path=mod.rel, line=s.call.lineno,
                        symbol=(s.func.qualname if s.func else tc.name),
                        detail=f"{tc.name}.{label}",
                        message=(
                            f"worker thread ({where}, target "
                            f"{label}) is started but never joined — "
                            f"join it at the close()/commit boundary so "
                            f"shutdown and checkpoint publication are "
                            f"ordered after the worker's last write"
                        ),
                    ))
        return findings


def _handler_captures_to_self(handler: ast.ExceptHandler) -> bool:
    """Does this ``except X as e`` body publish ``e`` to instance state
    (``self.attr = e``) or ship it through a self call
    (``self._q.put(wrap(e))``)?"""
    if handler.name is None:
        return False

    def refs_exc(node: ast.AST) -> bool:
        return any(
            isinstance(n, ast.Name) and n.id == handler.name
            for n in ast.walk(node)
        )

    for node in ast.walk(handler):
        if isinstance(node, ast.Assign) and refs_exc(node.value):
            for t in node.targets:
                d = dotted_name(t)
                if d is not None and d.startswith("self."):
                    return True
        elif isinstance(node, ast.Call):
            d = dotted_name(node.func)
            if (d is not None and d.startswith("self.")
                    and any(refs_exc(a) for a in node.args)):
                return True
    return False


class SilentDaemonDeathRule:
    name = "silent-daemon-death"
    description = (
        "worker body never captures exceptions for the main thread — the "
        "daemon dies silently"
    )

    def run(self, project) -> Iterable["lint.Finding"]:
        findings: List[lint.Finding] = []
        for mod in project:
            for tc in thread_classes(mod):
                targets = sorted({
                    s.target_method for s in tc.starts
                    if s.target_method is not None
                    and s.target_method in tc.methods
                })
                for m in targets:
                    captured = False
                    for name in tc.closure_of(m):
                        for f in tc.methods.get(name, []):
                            for n in ast.walk(f.node):
                                if (isinstance(n, ast.ExceptHandler)
                                        and _handler_captures_to_self(n)):
                                    captured = True
                    if captured:
                        continue
                    fdef = tc.methods[m][0]
                    findings.append(lint.Finding(
                        rule=self.name, path=mod.rel,
                        line=fdef.node.lineno, symbol=fdef.qualname,
                        detail=f"{tc.name}.{m}",
                        message=(
                            f"thread target {tc.name}.{m} never captures "
                            f"exceptions into instance state — wrap the "
                            f"body in try/except BaseException and "
                            f"publish the error for the main thread to "
                            f"re-raise at the next boundary"
                        ),
                    ))
        return findings
