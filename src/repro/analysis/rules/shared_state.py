"""R5 ``unguarded-shared-state`` — instance state shared across the
worker/main thread boundary with no common lock.

Scope: classes that start a ``threading.Thread`` (the DiskStore
reader/writer pair, the checkpoint async writer, the pipeline producer).
For every instance attribute touched by both the worker domain (methods
reachable from a thread target) and the main domain, the rule demands that
every (worker access, main access) pair with at least one write share a
lock.  Exemptions: ``__init__`` (runs before ``start()``, so it
happens-before the worker) and attributes bound to internally-synchronized
constructors (queues, events, locks themselves).

One finding per (class, attribute), anchored at the earliest unguarded
write when there is one.
"""

from __future__ import annotations

from typing import Dict, Iterable, List

from repro.analysis import lint
from repro.analysis.threadutil import (
    AttrAccess,
    lock_held_map,
    thread_classes,
)


class UnguardedSharedStateRule:
    name = "unguarded-shared-state"
    description = (
        "instance attribute crosses the worker/main thread boundary with "
        "a write and no common lock"
    )

    def run(self, project) -> Iterable["lint.Finding"]:
        findings: List[lint.Finding] = []
        for mod in project:
            classes = thread_classes(mod)
            if not classes:
                continue
            held = lock_held_map(mod)
            for tc in classes:
                by_attr: Dict[str, List[AttrAccess]] = {}
                for a in tc.attr_accesses(held):
                    by_attr.setdefault(a.attr, []).append(a)
                for attr, accs in sorted(by_attr.items()):
                    if attr in tc.safe_attrs:
                        continue
                    workers = [a for a in accs if a.worker and not a.init]
                    mains = [
                        a for a in accs if not a.worker and not a.init
                    ]
                    hazards = [
                        (w, m) for w in workers for m in mains
                        if (w.write or m.write) and not (w.locks & m.locks)
                    ]
                    if not hazards:
                        continue
                    participants = {
                        id(a.node): a for wm in hazards for a in wm
                    }
                    anchor = min(
                        participants.values(),
                        key=lambda a: (not a.write, a.node.lineno),
                    )
                    other = next(
                        a for w, m in hazards for a in (w, m)
                        if (w is anchor or m is anchor) and a is not anchor
                    )
                    findings.append(lint.Finding(
                        rule=self.name, path=mod.rel,
                        line=anchor.node.lineno,
                        symbol=anchor.func.qualname,
                        detail=f"{tc.name}.{attr}",
                        message=(
                            f"self.{attr} is shared between the worker and "
                            f"main thread domains with a write and no "
                            f"common lock (other side: "
                            f"{other.func.qualname}:{other.node.lineno}) — "
                            f"guard both sides with the same lock, or make "
                            f"the hand-off go through a queue/Event"
                        ),
                    ))
        return findings
