"""R6 ``blocking-io-under-lock`` — filesystem IO inside a lock's critical
section.

The DiskStore lock serializes the training thread against the read-ahead /
write-behind workers.  A ``np.load`` / ``open`` / ``os.replace`` executed
while that lock is held turns every cache hit on the other threads into an
SSD-latency stall — the exact overlap the paper's design exists to avoid.
The rule flags every call that (directly, or through a module-local helper)
blocks on the filesystem while any lock is provably held, using the same
call-site lock fixpoint as the shared-state rule: a helper only ever called
under ``with self._lock:`` is itself "under lock".
"""

from __future__ import annotations

import ast
from typing import Iterable, List

from repro.analysis import lint
from repro.analysis.astutil import dotted_name
from repro.analysis.threadutil import (
    _is_blocking_call,
    blocking_functions,
    lock_held_map,
    locks_at,
    resolve_calls,
)


class BlockingIOUnderLockRule:
    name = "blocking-io-under-lock"
    description = (
        "blocking filesystem call while a lock is held — stalls every "
        "thread contending for the lock behind SSD latency"
    )

    def run(self, project) -> Iterable["lint.Finding"]:
        findings: List[lint.Finding] = []
        for mod in project:
            if not any(
                isinstance(n, ast.With) for n in ast.walk(mod.tree)
            ):
                continue
            held = lock_held_map(mod)
            blocking = blocking_functions(mod)
            resolved = resolve_calls(mod)
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Call):
                    continue
                if _is_blocking_call(mod, node):
                    detail = (
                        mod.canonical_call(node)
                        or f"{dotted_name(node.func)}"
                    )
                else:
                    targets = [
                        t for t in resolved.get(id(node), [])
                        if id(t.node) in blocking
                    ]
                    if not targets:
                        continue
                    detail = f"{targets[0].name}()"
                locks = locks_at(mod, held, node)
                if not locks:
                    continue
                encl = mod.enclosing_function(node)
                findings.append(lint.Finding(
                    rule=self.name, path=mod.rel, line=node.lineno,
                    symbol=encl.qualname if encl else "",
                    detail=detail,
                    message=(
                        f"{detail} blocks on the filesystem while holding "
                        f"{{{', '.join(sorted(locks))}}} — move the IO "
                        f"outside the critical section (copy under the "
                        f"lock, write unlocked, reacquire to publish)"
                    ),
                ))
        return findings
