"""repro — Communication-efficient terabyte-scale training framework (JAX/TPU).

Reproduction + extension of Zhao et al. (2022), "Communication-Efficient
TeraByte-Scale Model Training Framework for Online Advertising": k-step Adam
model merging across slow-fabric (pod/DCN) boundaries, a hierarchical sharded
embedding engine with working-set pulls, and topology-aware collective
schedules — expressed natively in JAX (pjit/GSPMD + Pallas TPU kernels).
"""

__version__ = "1.0.0"
