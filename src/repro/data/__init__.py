from repro.data.pipeline import PrefetchPipeline  # noqa: F401
from repro.data.graph_sampler import NeighborSampler  # noqa: F401
