"""Host-side input pipeline with prefetch overlap.

TPU adaptation of the paper's core-binding + pipelined Read-Ins stage
(§3.1, Fig. 5): a background thread stages the next batches (parse, shard,
device_put) while the device executes the current step, so input I/O
overlaps compute instead of serializing with it.  Stage timings are recorded
so the Fig.-5 benchmark can report overlapped vs serialized time.
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Iterator, Optional


class _ProducerFailure:
    """In-band envelope shipping a producer-thread exception to the
    consumer — the daemon must never die silently (same contract as
    ``CheckpointManager.wait`` re-raising ``_exc``)."""

    def __init__(self, exc: BaseException):
        self.exc = exc


class PrefetchPipeline:
    """Wrap a batch iterator with a depth-bounded background prefetcher.

    Producer-thread failures (a raising ``source`` or ``stage_fn``) are
    captured and re-raised by ``__next__`` on the consumer thread — a dead
    producer surfaces as an exception at the next batch, not as a silent
    end-of-stream.
    """

    def __init__(
        self,
        source: Iterator[Any],
        depth: int = 2,
        stage_fn: Optional[Callable[[Any], Any]] = None,
    ):
        self.source = source
        self.stage_fn = stage_fn or (lambda b: b)
        self.depth = depth
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self.read_seconds = 0.0       # producer-side time (Read Ins + staging)
        self.wait_seconds = 0.0       # consumer-side stall (pipeline bubble)
        self.batches = 0
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _put(self, item) -> bool:
        """Bounded put that keeps honoring ``close()``; False = shut down."""
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _produce(self):
        try:
            for item in self.source:
                if self._stop.is_set():
                    return
                t0 = time.perf_counter()
                staged = self.stage_fn(item)
                self.read_seconds += time.perf_counter() - t0
                if not self._put(staged):
                    return
            self._put(None)   # clean end-of-stream sentinel
        except BaseException as e:   # re-raised by __next__ on the consumer
            self._put(_ProducerFailure(e))

    def __iter__(self):
        return self

    def __next__(self):
        t0 = time.perf_counter()
        item = self._q.get()
        self.wait_seconds += time.perf_counter() - t0
        self.batches += 1
        if item is None:
            raise StopIteration
        if isinstance(item, _ProducerFailure):
            # keep the failure in-band so every subsequent next() re-raises
            # instead of blocking on a queue the dead producer never feeds
            self._q.put(item)
            raise RuntimeError(
                "PrefetchPipeline producer failed") from item.exc
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        # joined, not abandoned: shutdown is ordered after the producer's
        # last queue operation (its put loop observes _stop within 100ms)
        self._thread.join(timeout=30)


def serialized_baseline(source: Iterator[Any], stage_fn, n: int):
    """No-overlap reference (paper's 'without pipeline' column): stage each
    batch inline.  Returns (batches, staging_seconds)."""
    out, total = [], 0.0
    for _ in range(n):
        item = next(source)
        t0 = time.perf_counter()
        out.append(stage_fn(item))
        total += time.perf_counter() - t0
    return out, total
