"""Fanout neighbor sampler for sampled-minibatch GNN training (GraphSAGE
style), required by the ``minibatch_lg`` shape.  Host-side numpy: builds a
CSR adjacency once, then yields fixed-size (padded) relabeled subgraphs so
the device step has static shapes.
"""

from __future__ import annotations

from typing import Dict, Sequence

import numpy as np


class NeighborSampler:
    def __init__(self, n_nodes: int, edge_src: np.ndarray, edge_dst: np.ndarray):
        self.n_nodes = n_nodes
        # CSR over incoming edges: for a seed (dst) we sample its in-neighbors
        # (message sources).
        order = np.argsort(edge_dst, kind="stable")
        self.src_sorted = edge_src[order]
        self.indptr = np.zeros(n_nodes + 1, np.int64)
        counts = np.bincount(edge_dst, minlength=n_nodes)
        self.indptr[1:] = np.cumsum(counts)

    def _sample_neighbors(self, rng, nodes: np.ndarray, fanout: int):
        starts = self.indptr[nodes]
        degs = self.indptr[nodes + 1] - starts
        # with-replacement sampling keeps everything vectorized
        offs = (rng.random((len(nodes), fanout)) * np.maximum(degs, 1)[:, None]).astype(np.int64)
        nbrs = self.src_sorted[starts[:, None] + offs]
        valid = (degs > 0)[:, None] & np.ones((1, fanout), bool)
        return nbrs, valid

    def sample_block(
        self, rng: np.random.Generator, seeds: np.ndarray, fanouts: Sequence[int],
    ) -> Dict[str, np.ndarray]:
        """Layered fanout sample. Returns a relabeled padded subgraph:
        nodes (n_max,), edge_src/edge_dst (e_max,) LOCAL indices,
        edge_mask, seed_mask over nodes.  n_max/e_max are the deterministic
        worst-case sizes for (len(seeds), fanouts) — static device shapes."""
        n_seeds = len(seeds)
        frontier = np.unique(seeds)
        seen = [frontier]
        all_src, all_dst, all_keep = [], [], []
        for f in fanouts:
            nbrs, valid = self._sample_neighbors(rng, frontier, f)
            src = nbrs.reshape(-1)
            dst = np.repeat(frontier, f)
            keep = valid.reshape(-1)
            all_src.append(np.where(keep, src, dst))  # self-loop for invalid
            all_dst.append(dst)
            all_keep.append(keep)
            # next layer expands only the NEW neighbors (bounds worst case)
            frontier = np.unique(src[keep])
            seen.append(frontier)

        # global -> local relabel over the union of all layers
        sub_nodes = np.unique(np.concatenate(seen))
        n_max = self.worst_case_nodes(n_seeds, fanouts)
        e_max = self.worst_case_edges(n_seeds, fanouts)
        src_cat = np.concatenate(all_src)
        dst_cat = np.concatenate(all_dst)
        mask_cat = np.concatenate(all_keep)
        loc_src = np.searchsorted(sub_nodes, src_cat).astype(np.int32)
        loc_dst = np.searchsorted(sub_nodes, dst_cat).astype(np.int32)

        def pad(a, n, fill=0):
            out = np.full((n,), fill, a.dtype)
            out[: len(a)] = a
            return out

        nodes_pad = pad(sub_nodes.astype(np.int32), n_max)
        node_valid = pad(np.ones(len(sub_nodes), np.float32), n_max)
        seed_local = np.searchsorted(sub_nodes, np.unique(seeds)).astype(np.int32)
        seed_mask = np.zeros(n_max, np.float32)
        seed_mask[seed_local] = 1.0
        return {
            "nodes": nodes_pad,                        # global ids (for features)
            "node_valid": node_valid,
            "edge_src": pad(loc_src, e_max),
            "edge_dst": pad(loc_dst, e_max),
            "edge_mask": pad(mask_cat.astype(np.float32), e_max),
            "seed_mask": seed_mask,
            "n_real_nodes": np.int32(len(sub_nodes)),
        }

    @staticmethod
    def worst_case_nodes(n_seeds: int, fanouts: Sequence[int]) -> int:
        n, total = n_seeds, n_seeds
        for f in fanouts:
            n = n * f
            total += n
        return total

    @staticmethod
    def worst_case_edges(n_seeds: int, fanouts: Sequence[int]) -> int:
        n, total = n_seeds, 0
        for f in fanouts:
            total += n * f
            n = n * f
        return total
