"""Synthetic data streams with *learnable* signal.

The paper evaluates AUC on a production click stream; offline we need data
where AUC is meaningful, so every CTR generator draws labels from a hidden
teacher (hash-derived per-id weights + feature interactions) — a model that
trains is then measurably better than chance, and k-step-vs-baseline AUC
deltas (paper Fig. 9) are real quantities.

All generators are numpy-side (host pipeline territory) and deterministic in
their seed; different worker shards draw i.i.d. slices (paper §2.3: "the
streamed data for different nodes are in an i.i.d. distribution").
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


def _id_weights(ids: np.ndarray, salt: int = 0x9E3779B9) -> np.ndarray:
    """Deterministic pseudo-random weight per id in [-1, 1] (splitmix-style)."""
    x = (ids.astype(np.uint64) + np.uint64(salt)) * np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(27)
    x *= np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(31)
    return (x.astype(np.float64) / 2**64) * 2.0 - 1.0


def _zipf_ids(rng: np.random.Generator, shape, vocab: int, a: float = 1.1) -> np.ndarray:
    """Zipf-ish id draw truncated to vocab (hot-head like real CTR traffic)."""
    u = rng.random(shape)
    # inverse-CDF of a bounded pareto on [1, vocab]
    ids = (vocab ** (1 - a) * (1 - u) + u) ** (1 / (1 - a))
    return np.minimum(ids.astype(np.int64), vocab - 1)


# ------------------------------------------------------------------- CTR
def ctr_batches(
    seed: int, batch: int, rows: int, n_fields: int = 40, nnz: int = 100,
    worker: int = 0, zipf_a: float = 1.1,
) -> Iterator[Dict[str, np.ndarray]]:
    """Paper CTR model stream: multi-hot ids + field ids + teacher labels.

    ``zipf_a`` sets the id skew (lower = flatter; the cache-tier hit-rate
    experiments use 1.05, the paper-motivated hot-head regime)."""
    rng = np.random.default_rng(seed + worker * 1_000_003)
    while True:
        ids = _zipf_ids(rng, (batch, nnz), rows, a=zipf_a)
        field_ids = rng.integers(0, n_fields, (batch, nnz)).astype(np.int32)
        mask = (rng.random((batch, nnz)) < 0.9).astype(np.float32)
        score = (_id_weights(ids) * mask).sum(1) / np.sqrt(nnz)
        pair = (_id_weights(ids, salt=17) * mask)
        score = score + 0.5 * (pair.sum(1) ** 2 - (pair ** 2).sum(1)) / nnz
        p = 1.0 / (1.0 + np.exp(-3.0 * score))
        label = (rng.random(batch) < p).astype(np.float32)
        yield {
            "ids": ids.astype(np.int32),
            "field_ids": field_ids,
            "mask": mask,
            "label": label,
        }


def dlrm_batches(
    seed: int, batch: int, rows, n_dense: int = 13, worker: int = 0,
) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed + worker * 1_000_003)
    rows = list(rows)
    while True:
        dense = rng.standard_normal((batch, n_dense)).astype(np.float32)
        ids = np.stack(
            [_zipf_ids(rng, (batch,), r) for r in rows], axis=1
        )
        w = np.stack([_id_weights(ids[:, i], salt=31 * i + 7) for i in range(len(rows))], 1)
        score = w.mean(1) * 2.0 + 0.3 * dense[:, :4].sum(1) / 2.0 + 0.4 * w[:, 0] * w[:, 1]
        p = 1.0 / (1.0 + np.exp(-2.0 * score))
        label = (rng.random(batch) < p).astype(np.float32)
        yield {
            "dense": dense,
            "sparse_ids": ids.astype(np.int32),
            "label": label,
        }


def din_batches(
    seed: int, batch: int, vocab: int, seq_len: int = 100, worker: int = 0,
) -> Iterator[Dict[str, np.ndarray]]:
    """Behavior-sequence stream: label = teacher affinity(target, history)."""
    rng = np.random.default_rng(seed + worker * 1_000_003)
    n_interests = 32
    while True:
        # each user has an interest cluster; history and positive targets
        # concentrate in it
        interest = rng.integers(0, n_interests, (batch,))
        base = interest * (vocab // n_interests)
        width = vocab // n_interests
        hist = (base[:, None] + _zipf_ids(rng, (batch, seq_len), width)) % vocab
        lens = rng.integers(seq_len // 4, seq_len + 1, (batch,))
        mask = (np.arange(seq_len)[None, :] < lens[:, None]).astype(np.float32)
        pos = rng.random(batch) < 0.5
        in_cluster = (base + _zipf_ids(rng, (batch,), width)) % vocab
        random_item = rng.integers(0, vocab, (batch,))
        target = np.where(pos, in_cluster, random_item)
        # teacher: affinity + noise
        aff = (_id_weights(target) * _id_weights(hist[:, 0]) * 0.3 + np.where(pos, 0.8, -0.8))
        p = 1.0 / (1.0 + np.exp(-2.0 * aff))
        label = (rng.random(batch) < p).astype(np.float32)
        yield {
            "hist_ids": hist.astype(np.int32),
            "hist_mask": mask,
            "target_id": target.astype(np.int32),
            "label": label,
        }


def two_tower_batches(
    seed: int, batch: int, vocab: int, hist_len: int = 50, worker: int = 0,
) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed + worker * 1_000_003)
    n_interests = 64
    while True:
        interest = rng.integers(0, n_interests, (batch,))
        base = interest * (vocab // n_interests)
        width = vocab // n_interests
        hist = (base[:, None] + _zipf_ids(rng, (batch, hist_len), width)) % vocab
        lens = rng.integers(hist_len // 4, hist_len + 1, (batch,))
        mask = (np.arange(hist_len)[None, :] < lens[:, None]).astype(np.float32)
        item = (base + _zipf_ids(rng, (batch,), width)) % vocab  # positive item
        yield {
            "user_ids": hist.astype(np.int32),
            "user_mask": mask,
            "item_id": item.astype(np.int32),
        }


def recsys_batches(
    model_cfg, batch: int, seed: int = 1, worker: int = 0,
) -> Iterator[Dict[str, np.ndarray]]:
    """The right synthetic stream for a recsys model config (dispatched on
    config type — the launcher/factory counterpart of ``_recsys_wiring``)."""
    from repro.models import recsys as R

    if isinstance(model_cfg, R.CTRConfig):
        return ctr_batches(seed=seed, batch=batch, rows=model_cfg.rows,
                           n_fields=model_cfg.n_fields,
                           nnz=model_cfg.nnz_per_instance, worker=worker)
    if isinstance(model_cfg, R.DLRMConfig):
        return dlrm_batches(seed=seed, batch=batch, rows=model_cfg.rows,
                            n_dense=model_cfg.n_dense, worker=worker)
    if isinstance(model_cfg, R.DINConfig):
        return din_batches(seed=seed, batch=batch, vocab=model_cfg.item_vocab,
                           seq_len=model_cfg.seq_len, worker=worker)
    if isinstance(model_cfg, R.TwoTowerConfig):
        return two_tower_batches(seed=seed, batch=batch,
                                 vocab=model_cfg.item_vocab,
                                 hist_len=model_cfg.user_hist_len,
                                 worker=worker)
    raise TypeError(f"no synthetic stream for {type(model_cfg).__name__}")


# -------------------------------------------------------------------- LM
def lm_batches(
    seed: int, batch: int, seq_len: int, vocab: int, worker: int = 0,
) -> Iterator[Dict[str, np.ndarray]]:
    """Learnable token stream: affine-recurrence sequences (next token is a
    deterministic function of the previous) with random starts + noise."""
    rng = np.random.default_rng(seed + worker * 1_000_003)
    a, c = 31, 17
    while True:
        start = rng.integers(0, vocab, (batch, 1))
        toks = np.zeros((batch, seq_len + 1), np.int64)
        toks[:, 0] = start[:, 0]
        for t in range(seq_len):
            nxt = (toks[:, t] * a + c) % vocab
            noise = rng.random(batch) < 0.05
            toks[:, t + 1] = np.where(noise, rng.integers(0, vocab, batch), nxt)
        yield {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }


# ------------------------------------------------------------------ graphs
@dataclasses.dataclass
class SyntheticGraph:
    x: np.ndarray          # (N, F)
    edge_src: np.ndarray   # (E,)
    edge_dst: np.ndarray   # (E,)
    labels: np.ndarray     # (N,)


def community_graph(
    seed: int, n_nodes: int, avg_degree: int, d_feat: int, n_classes: int,
) -> SyntheticGraph:
    """SBM-ish graph: intra-community edges dominate; features = noisy class
    prototypes, so a GNN can actually learn the labels."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, (n_nodes,))
    n_edges = n_nodes * avg_degree
    src = rng.integers(0, n_nodes, (n_edges,))
    same = rng.random(n_edges) < 0.8
    # intra-community partner: another random node of the same class
    perm = np.argsort(labels, kind="stable")
    class_start = np.searchsorted(labels[perm], np.arange(n_classes))
    class_count = np.bincount(labels, minlength=n_classes)
    rnd = rng.integers(0, 1 << 31, (n_edges,))
    intra = perm[(class_start[labels[src]] + rnd % np.maximum(class_count[labels[src]], 1))]
    inter = rng.integers(0, n_nodes, (n_edges,))
    dst = np.where(same, intra, inter)
    protos = rng.standard_normal((n_classes, d_feat)).astype(np.float32)
    x = protos[labels] + 1.5 * rng.standard_normal((n_nodes, d_feat)).astype(np.float32)
    return SyntheticGraph(
        x=x, edge_src=src.astype(np.int32), edge_dst=dst.astype(np.int32),
        labels=labels.astype(np.int32),
    )


def molecule_batches(
    seed: int, batch: int, n_nodes: int, n_edges: int, d_feat: int,
    n_classes: int, worker: int = 0,
) -> Iterator[Dict[str, np.ndarray]]:
    """Batched disjoint small graphs with graph-level labels."""
    rng = np.random.default_rng(seed + worker * 1_000_003)
    while True:
        xs, srcs, dsts, gids, ys = [], [], [], [], []
        for g in range(batch):
            label = rng.integers(0, n_classes)
            x = rng.standard_normal((n_nodes, d_feat)).astype(np.float32) + label
            src = rng.integers(0, n_nodes, (n_edges,))
            dst = rng.integers(0, n_nodes, (n_edges,))
            xs.append(x)
            srcs.append(src + g * n_nodes)
            dsts.append(dst + g * n_nodes)
            gids.append(np.full((n_nodes,), g))
            ys.append(label)
        yield {
            "x": np.concatenate(xs, 0),
            "edge_src": np.concatenate(srcs, 0).astype(np.int32),
            "edge_dst": np.concatenate(dsts, 0).astype(np.int32),
            "graph_ids": np.concatenate(gids, 0).astype(np.int32),
            "labels": np.asarray(ys, np.int32),
        }
