"""Decoder-only LM family: GQA, qk-norm, QKV bias, RoPE, sliding-window /
chunked attention, MoE FFNs — one configurable implementation covering
qwen3-14b, qwen2-7b, granite-8b, mixtral-8x7b and llama4-scout.

Layers are ``lax.scan``-stacked (leading L dim on every layer leaf) with full
per-layer remat, which keeps the lowered HLO one-layer-sized — essential for
the 512-device dry-run — and bounds training activation memory to the scan
carries (sharded across every mesh axis via ``shard_hint``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (
    apply_rope,
    he_init,
    rms_norm,
    shard_hint,
    sharded_embed_lookup,
    softmax_cross_entropy,
)
from repro.models import moe as moe_lib


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str = "lm"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab: int = 1024
    head_dim: Optional[int] = None          # default d_model // n_heads
    rope_theta: float = 1e6
    qk_norm: bool = False                   # qwen3
    qkv_bias: bool = False                  # qwen2
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # Attention pattern: full | window (SWA, mixtral) | chunked (llama4 iRoPE)
    attn_window: Optional[int] = None       # sliding window size
    attn_chunk: Optional[int] = None        # local chunk size
    global_every: int = 0                   # with attn_chunk: every Nth layer full
    # MoE (0 experts = dense FFN)
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    moe_group_size: int = 4096
    shared_expert: bool = False             # llama4 shared expert
    router_aux_coef: float = 0.0
    dtype: Any = jnp.bfloat16
    # Dense (materialized-scores) attention below this seq len; q-blocked
    # (training, exact, rematerialized per block) / online-softmax blockwise
    # (forward-only prefill) above it.
    dense_attn_threshold: int = 1024
    attn_block_kv: int = 1024
    attn_block_q: int = 512
    ce_chunk_tokens: int = 65536  # global tokens per fused-CE chunk
    # Activation sharding: False = d_model over 'model' (TP layouts);
    # True = sequence over 'model' (the fsdp_seq layout, §Perf).
    seq_shard: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def active_params(self) -> int:
        """Parameters touched per token (for MODEL_FLOPS = 6 * N_active * D)."""
        d, hd, H, Kv, L = self.d_model, self.hd, self.n_heads, self.n_kv_heads, self.n_layers
        attn = d * (H * hd) + 2 * d * (Kv * hd) + (H * hd) * d
        if self.n_experts:
            ffn = 3 * d * self.d_ff * self.top_k
            ffn += d * self.n_experts  # router
            if self.shared_expert:
                ffn += 3 * d * self.d_ff
        else:
            ffn = 3 * d * self.d_ff
        embed = 0 if self.tie_embeddings else d * self.vocab
        return L * (attn + ffn) + d * self.vocab + embed

    def total_params(self) -> int:
        d, hd, H, Kv, L = self.d_model, self.hd, self.n_heads, self.n_kv_heads, self.n_layers
        attn = d * (H * hd) + 2 * d * (Kv * hd) + (H * hd) * d
        if self.n_experts:
            ffn = 3 * d * self.d_ff * self.n_experts + d * self.n_experts
            if self.shared_expert:
                ffn += 3 * d * self.d_ff
        else:
            ffn = 3 * d * self.d_ff
        return L * (attn + ffn + 2 * d) + 2 * d * self.vocab + d




def _act3(cfg):
    """(B, S, D)-activation PartitionSpec entries for shard_hint."""
    if cfg.seq_shard:
        return (("pod", "data"), "model", None)
    return (("pod", "data"), None, "model")

# ----------------------------------------------------------------- params
def init_params(rng: jax.Array, cfg: TransformerConfig) -> Dict[str, Any]:
    d, hd, H, Kv, L, F = (
        cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads, cfg.n_layers, cfg.d_ff,
    )
    dt = cfg.dtype
    k = jax.random.split(rng, 16)

    def stack(key, shape, fan_in):
        return he_init(key, (L,) + shape, dt, fan_in=fan_in)

    layers: Dict[str, Any] = {
        "attn_norm": jnp.ones((L, d), dt),
        "ffn_norm": jnp.ones((L, d), dt),
        "wq": stack(k[0], (d, H * hd), d),
        "wk": stack(k[1], (d, Kv * hd), d),
        "wv": stack(k[2], (d, Kv * hd), d),
        "wo": stack(k[3], (H * hd, d), H * hd),
    }
    if cfg.qkv_bias:
        layers["bq"] = jnp.zeros((L, H * hd), dt)
        layers["bk"] = jnp.zeros((L, Kv * hd), dt)
        layers["bv"] = jnp.zeros((L, Kv * hd), dt)
    if cfg.qk_norm:
        layers["q_norm"] = jnp.ones((L, hd), dt)
        layers["k_norm"] = jnp.ones((L, hd), dt)
    if cfg.n_experts:
        E = cfg.n_experts
        layers["router"] = stack(k[4], (d, E), d)
        layers["we_gate"] = he_init(k[5], (L, E, d, F), dt, fan_in=d)
        layers["we_up"] = he_init(k[6], (L, E, d, F), dt, fan_in=d)
        layers["we_down"] = he_init(k[7], (L, E, F, d), dt, fan_in=F)
        if cfg.shared_expert:
            layers["ws_gate"] = stack(k[8], (d, F), d)
            layers["ws_up"] = stack(k[9], (d, F), d)
            layers["ws_down"] = stack(k[10], (F, d), F)
    else:
        layers["w_gate"] = stack(k[11], (d, F), d)
        layers["w_up"] = stack(k[12], (d, F), d)
        layers["w_down"] = stack(k[13], (F, d), F)

    params = {
        "embed": he_init(k[14], (cfg.vocab, d), dt, fan_in=d),
        "layers": layers,
        "final_norm": jnp.ones((d,), dt),
    }
    if not cfg.tie_embeddings:
        params["head"] = he_init(k[15], (d, cfg.vocab), dt, fan_in=d)
    return params




def _whint(cfg, w):
    """fsdp_seq: anchor weight shards to ('data','model') at the point of use
    so the partitioner's pre-dot gather runs over in-pod axes only (without
    this it has been observed gathering the vmapped pod dim across DCN)."""
    if cfg.seq_shard and w.ndim >= 2:
        return shard_hint(w, ("data", "model"), *([None] * (w.ndim - 1)))
    return w

# -------------------------------------------------------------- attention
def _mask(cfg: TransformerConfig, layer_idx, q_pos, kv_pos):
    """(Sq, Skv) boolean mask. q_pos/kv_pos absolute positions (int32)."""
    m = kv_pos[None, :] <= q_pos[:, None]  # causal
    if cfg.attn_window is not None:
        m &= (q_pos[:, None] - kv_pos[None, :]) < cfg.attn_window
    if cfg.attn_chunk is not None:
        local = (q_pos[:, None] // cfg.attn_chunk) == (kv_pos[None, :] // cfg.attn_chunk)
        if cfg.global_every > 0:
            is_global = (layer_idx % cfg.global_every) == (cfg.global_every - 1)
            m &= jnp.where(is_global, True, local)
        else:
            m &= local
    return m


def _sdpa_dense(cfg, layer_idx, q, kk, vv, q_pos, kv_pos, kv_valid=None):
    """Materialized-scores GQA attention.
    q: (B,Sq,H,hd)  kk/vv: (B,Skv,Kv,hd) -> (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    Kv = kk.shape[2]
    G = H // Kv
    qg = q.reshape(B, Sq, Kv, G, hd)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, kk).astype(jnp.float32)
    s *= 1.0 / (hd ** 0.5)
    m = _mask(cfg, layer_idx, q_pos, kv_pos)
    if kv_valid is not None:
        m &= kv_valid[None, :]
    s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgst,btkd->bskgd", p, vv)
    return o.reshape(B, Sq, H, hd)


def _sdpa_blockwise(cfg, layer_idx, q, kk, vv, q_pos, kv_pos):
    """Online-softmax (flash-style) attention, scan over KV blocks.
    Forward-only path for long prefill; O(Sq * blk) live memory."""
    B, Sq, H, hd = q.shape
    Kv = kk.shape[2]
    G = H // Kv
    blk = cfg.attn_block_kv
    Skv = kk.shape[1]
    nb = Skv // blk
    assert Skv % blk == 0, f"Skv={Skv} not divisible by kv block {blk}"
    qg = (q.reshape(B, Sq, Kv, G, hd) * (1.0 / hd ** 0.5)).astype(q.dtype)
    kb = kk.reshape(B, nb, blk, Kv, hd).transpose(1, 0, 2, 3, 4)
    vb = vv.reshape(B, nb, blk, Kv, hd).transpose(1, 0, 2, 3, 4)
    pb = kv_pos.reshape(nb, blk)

    def body(carry, xs):
        acc, m_run, l_run = carry
        k_blk, v_blk, p_blk = xs
        s = jnp.einsum("bskgd,btkd->bkgst", qg, k_blk).astype(jnp.float32)
        msk = _mask(cfg, layer_idx, q_pos, p_blk)
        s = jnp.where(msk[None, None, None], s, -1e30)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        corr = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p.astype(q.dtype), v_blk
        ).astype(jnp.float32)
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((B, Kv, G, Sq, hd), jnp.float32)
    m0 = jnp.full((B, Kv, G, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Kv, G, Sq), jnp.float32)
    (acc, _, l), _ = jax.lax.scan(body, (acc0, m0, l0), (kb, vb, pb))
    o = (acc / jnp.maximum(l[..., None], 1e-30)).astype(q.dtype)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)


def _sdpa_qblocked(cfg, layer_idx, q, kk, vv, q_pos, kv_pos):
    """Exact attention computed one q-block at a time (differentiable).

    Scores for a (block_q x Skv) tile are materialized, softmaxed and
    discarded per block; ``jax.checkpoint`` on the block body keeps the
    backward pass from retaining per-block probabilities — live attention
    memory is O(block_q * Skv) for any sequence length.
    """
    B, Sq, H, hd = q.shape
    blk = cfg.attn_block_q
    nb = Sq // blk
    assert Sq % blk == 0, (Sq, blk)
    qb = q.reshape(B, nb, blk, H, hd).transpose(1, 0, 2, 3, 4)  # (nb,B,blk,H,hd)
    pb = q_pos.reshape(nb, blk)

    @jax.checkpoint
    def block(q_blk, p_blk):
        return _sdpa_dense(cfg, layer_idx, q_blk, kk, vv, p_blk, kv_pos)

    def body(_, xs):
        q_blk, p_blk = xs
        return None, block(q_blk, p_blk)

    _, ob = jax.lax.scan(body, None, (qb, pb))
    return ob.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)


# ------------------------------------------------------------------ layer
def _attn_block(cfg, lp, layer_idx, x, q_pos, cache=None):
    """Self-attention sublayer. With ``cache=(ck, cv, kv_pos, kv_valid)``,
    attends over the cache (decode); otherwise self-attends over x."""
    B, S, d = x.shape
    H, Kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q = h @ _whint(cfg, lp["wq"])
    kx = h @ _whint(cfg, lp["wk"])
    vx = h @ _whint(cfg, lp["wv"])
    if cfg.qkv_bias:
        q, kx, vx = q + lp["bq"], kx + lp["bk"], vx + lp["bv"]
    q = q.reshape(B, S, H, hd)
    kx = kx.reshape(B, S, Kv, hd)
    vx = vx.reshape(B, S, Kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"], cfg.norm_eps)
        kx = rms_norm(kx, lp["k_norm"], cfg.norm_eps)
    q = apply_rope(q, q_pos[None, :].repeat(B, 0), cfg.rope_theta)
    kx = apply_rope(kx, q_pos[None, :].repeat(B, 0), cfg.rope_theta)

    if cache is not None:
        ck, cv, kv_pos, kv_valid, write_idx = cache
        ck = jax.lax.dynamic_update_slice(ck, kx, (0, write_idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, vx, (0, write_idx, 0, 0))
        o = _sdpa_dense(cfg, layer_idx, q, ck, cv, q_pos, kv_pos, kv_valid)
        new_cache = (ck, cv)
    else:
        # With sequence-sharded activations each shard owns its q rows and
        # attends against the (gathered) full KV — dense attention is then
        # shard-local; the q-block scan would instead replicate q per block
        # and psum every block output across the model axis.
        if S <= cfg.dense_attn_threshold or cfg.seq_shard:
            o = _sdpa_dense(cfg, layer_idx, q, kx, vx, q_pos, q_pos)
        else:
            o = _sdpa_qblocked(cfg, layer_idx, q, kx, vx, q_pos, q_pos)
        new_cache = (kx, vx)
    o = shard_hint(o.reshape(B, S, H * hd), *_act3(cfg))
    return x + o @ _whint(cfg, lp["wo"]), new_cache


def _ffn_block(cfg, lp, x):
    h = rms_norm(x, lp["ffn_norm"], cfg.norm_eps)
    if cfg.n_experts:
        y, aux = moe_lib.moe_ffn(h, lp, cfg)
    else:
        g = jax.nn.silu(h @ _whint(cfg, lp["w_gate"])) * (h @ _whint(cfg, lp["w_up"]))
        g = shard_hint(g, *_act3(cfg))
        y = g @ _whint(cfg, lp["w_down"])
        aux = jnp.zeros((), jnp.float32)
    return x + y, aux


def _layer(cfg, lp, layer_idx, x, q_pos, cache=None):
    x = shard_hint(x, *_act3(cfg))
    x, new_cache = _attn_block(cfg, lp, layer_idx, x, q_pos, cache)
    x, aux = _ffn_block(cfg, lp, x)
    x = shard_hint(x, *_act3(cfg))
    return x, new_cache, aux


# ---------------------------------------------------------------- forward
def trunk(params, tokens: jnp.ndarray, cfg: TransformerConfig):
    """tokens (B, S) -> (final-normed hidden (B, S, D), aux_loss)."""
    B, S = tokens.shape
    x = sharded_embed_lookup(params["embed"], tokens)
    x = shard_hint(x, *_act3(cfg))
    q_pos = jnp.arange(S, dtype=jnp.int32)
    layer_ids = jnp.arange(cfg.n_layers, dtype=jnp.int32)

    def body(carry, xs):
        x = carry
        lp, lid = xs
        x, _, aux = _layer(cfg, lp, lid, x, q_pos)
        return x, aux

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, auxs = jax.lax.scan(body, x, (params["layers"], layer_ids))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, jnp.sum(auxs)


def forward(params, tokens: jnp.ndarray, cfg: TransformerConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens (B, S) -> (logits (B, S, V), aux_loss scalar)."""
    x, aux = trunk(params, tokens, cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = x @ head
    logits = shard_hint(logits, *_act3(cfg))
    return logits, aux


def loss_fn(params, batch, cfg: TransformerConfig) -> jnp.ndarray:
    """batch: {'tokens': (B,S) int32, 'labels': (B,S) int32}.

    The vocab projection + CE runs in token chunks (``jax.checkpoint``ed scan)
    so the (tokens, V) f32 logits never materialize at once — live CE memory
    is one chunk regardless of batch/seq (the fused-CE trick).
    """
    x, aux = trunk(params, batch["tokens"], cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    B, S, D = x.shape
    T = B * S
    # Chunk over the (unsharded) sequence dim: every data shard participates
    # in every chunk, and no resharding of x is needed.
    n_chunks = max(1, min(T // max(cfg.ce_chunk_tokens, 1), S, 64))
    while S % n_chunks:
        n_chunks -= 1
    xt = x.reshape(B, n_chunks, S // n_chunks, D).transpose(1, 0, 2, 3)
    lt = batch["labels"].reshape(B, n_chunks, S // n_chunks).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_ce(xc, lc):
        if cfg.seq_shard:
            # gather this chunk's sequence slice (small) so the vocab-
            # parallel head matmul is shard-local over V
            xc = shard_hint(xc, ("pod", "data"), None, None)
        logits = xc @ head
        logits = shard_hint(logits, ("pod", "data"), None, "model")
        return jnp.sum(softmax_cross_entropy(logits, lc))

    def body(acc, xs):
        xc, lc = xs
        return acc + chunk_ce(xc, lc), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xt, lt))
    return total / T + cfg.router_aux_coef * aux


# ----------------------------------------------------------------- decode
def cache_len(cfg: TransformerConfig, seq_len: int) -> int:
    """Physical KV length: SWA models keep only a window-size ring buffer."""
    if cfg.attn_window is not None:
        return min(seq_len, cfg.attn_window)
    return seq_len


def init_cache(cfg: TransformerConfig, batch: int, seq_len: int):
    Skv = cache_len(cfg, seq_len)
    shp = (cfg.n_layers, batch, Skv, cfg.n_kv_heads, cfg.hd)
    return {
        "k": jnp.zeros(shp, cfg.dtype),
        "v": jnp.zeros(shp, cfg.dtype),
        # absolute position of each physical cache slot, -1 = empty
        "pos": jnp.full((Skv,), -1, jnp.int32),
        "t": jnp.zeros((), jnp.int32),  # next absolute position
    }


def decode_step(params, cache, tokens: jnp.ndarray, cfg: TransformerConfig):
    """One serving step: tokens (B,) -> (logits (B,V), new_cache).

    The new token's KV is written at slot ``t % Skv`` (a ring buffer — for
    SWA models old entries are naturally evicted; for full-attention caches
    Skv covers the whole context so nothing is ever overwritten).
    """
    B = tokens.shape[0]
    Skv = cache["k"].shape[2]
    t = cache["t"]
    write_idx = t % Skv
    q_pos = t[None].astype(jnp.int32)
    kv_pos = jax.lax.dynamic_update_index_in_dim(cache["pos"], t, write_idx, 0)
    kv_valid = kv_pos >= 0

    x = jnp.take(params["embed"], tokens[:, None], axis=0)  # (B,1,d)
    x = shard_hint(x, ("pod", "data"), None, None)
    layer_ids = jnp.arange(cfg.n_layers, dtype=jnp.int32)

    def body(x, xs):
        lp, lid, ck, cv = xs
        x, (nk, nv), _ = _layer(
            cfg, lp, lid, x, q_pos, cache=(ck, cv, kv_pos, kv_valid, write_idx)
        )
        return x, (nk, nv)

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["layers"], layer_ids, cache["k"], cache["v"])
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = (x @ head)[:, 0]
    new_cache = {"k": nk, "v": nv, "pos": kv_pos, "t": t + 1}
    return logits, new_cache


def prefill(params, tokens: jnp.ndarray, cfg: TransformerConfig):
    """Inference prefill: full forward returning last-position logits.
    (Long-context serving runs this once, then ``decode_step`` repeatedly.)"""
    logits, _ = forward(params, tokens, cfg)
    return logits[:, -1]
