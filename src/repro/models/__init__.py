"""Model zoo: LM transformer family, GIN, and the recsys/CTR family."""
