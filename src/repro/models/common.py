"""Shared model building blocks + activation-sharding hints.

``sharding_ctx(mesh)`` installs a mesh for the duration of a trace; inside it
``shard_hint(x, spec...)`` lowers to ``with_sharding_constraint`` so the same
model code runs unannotated on one CPU device and fully annotated under the
production mesh.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

_TLS = threading.local()


@contextlib.contextmanager
def sharding_ctx(mesh: Optional[jax.sharding.Mesh], exclude: tuple = ()):
    """Install a mesh for shard_hint.  ``exclude`` names mesh axes that are
    MANUAL in the current region (inside a shard_map over them) — hints drop
    those entries since constraints may only reference auto axes there."""
    prev = getattr(_TLS, "mesh", None)
    prev_ex = getattr(_TLS, "exclude", ())
    _TLS.mesh = mesh
    _TLS.exclude = tuple(exclude)
    try:
        yield
    finally:
        _TLS.mesh = prev
        _TLS.exclude = prev_ex


def current_mesh() -> Optional[jax.sharding.Mesh]:
    return getattr(_TLS, "mesh", None)


def shard_hint(x: jnp.ndarray, *spec) -> jnp.ndarray:
    """Constrain x to PartitionSpec(*spec) if a mesh is installed.

    Axis names absent from the installed mesh (or marked manual via
    sharding_ctx(exclude=...)) are dropped from the spec, so hints written
    for the multi-pod mesh degrade gracefully on smaller ones.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    excluded = getattr(_TLS, "exclude", ())
    names = tuple(a for a in mesh.axis_names if a not in excluded)

    def _filter(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in names)
            return kept if kept else None
        return entry if entry in names else None

    fspec = P(*[_filter(e) for e in spec])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, fspec))


# ------------------------------------------------------------------- layers
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    # Variance in f32, application in the compute dtype: keeps the backward
    # residual-stream cotangent (and its TP all-reduce) in bf16 instead of
    # promoting the whole gradient chain to f32.
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * scale


def sharded_embed_lookup(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """``table[ids]`` whose BACKWARD scatter stays sharded.

    The vanilla VJP of ``jnp.take`` scatters into a zeros-like table; GSPMD
    frequently materializes that scatter unpartitioned (a full (V, D) f32
    buffer per device).  This custom VJP pins the cotangent scatter to the
    embedding-dim sharding of the primal table via ``shard_hint``.
    """
    return _embed_lookup(tuple(table.shape), str(table.dtype), table, ids)


from functools import partial as _partial  # noqa: E402


@_partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _embed_lookup(tshape, tdtype, table, ids):
    return jnp.take(table, ids, axis=0)


def _embed_lookup_fwd(tshape, tdtype, table, ids):
    return jnp.take(table, ids, axis=0), ids


def _embed_lookup_bwd(tshape, tdtype, ids, g):
    flat_ids = ids.reshape(-1)
    # Constrain operand AND updates to the same embedding-dim sharding so
    # the SPMD partitioner keeps the scatter shard-local on dim 1.
    flat_g = shard_hint(g.reshape(-1, tshape[-1]), None, ("data", "model"))
    zeros = shard_hint(jnp.zeros(tshape, tdtype), None, ("data", "model"))
    dt = zeros.at[flat_ids].add(flat_g.astype(tdtype))
    dt = shard_hint(dt, None, ("data", "model"))
    return dt, None


_embed_lookup.defvjp(_embed_lookup_fwd, _embed_lookup_bwd)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def he_init(key, shape, dtype=jnp.float32, fan_in: Optional[int] = None):
    fan = fan_in if fan_in is not None else shape[-2] if len(shape) >= 2 else shape[-1]
    return (jax.random.normal(key, shape, jnp.float32) * (2.0 / fan) ** 0.5).astype(dtype)


def glorot_init(key, shape, dtype=jnp.float32):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    fan_out = shape[-1]
    lim = (6.0 / (fan_in + fan_out)) ** 0.5
    return jax.random.uniform(key, shape, jnp.float32, -lim, lim).astype(dtype)


def mlp_init(key, sizes: Sequence[int], dtype=jnp.float32, bias: bool = True):
    """[(w, b)] stack for a plain MLP with given layer sizes."""
    params = []
    for i in range(len(sizes) - 1):
        kw = jax.random.fold_in(key, i)
        w = he_init(kw, (sizes[i], sizes[i + 1]), dtype)
        b = jnp.zeros((sizes[i + 1],), dtype) if bias else None
        params.append({"w": w, "b": b} if bias else {"w": w})
    return params


def mlp_apply(params, x, act=jax.nn.relu, final_act=None):
    n = len(params)
    for i, layer in enumerate(params):
        x = x @ layer["w"]
        if "b" in layer and layer["b"] is not None:
            x = x + layer["b"]
        if i < n - 1:
            x = act(x)
        elif final_act is not None:
            x = final_act(x)
    return x


# --------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------------- losses
def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Token-level CE; logits (..., V) any dtype, computed in f32.

    The gold logit is extracted with an iota-compare + masked reduce instead
    of ``take_along_axis`` — a gather over a vocab-sharded logits tensor
    would force GSPMD to all-gather the full (tokens, V) array; the masked
    reduce stays element-wise over the shard and reduces with a tiny psum.
    """
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    logz = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(
        jnp.where(iota == labels[..., None], shifted, 0.0), axis=-1
    ) + m[..., 0]
    return logz - gold


def bce_with_logits(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logits = logits.astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    return jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
