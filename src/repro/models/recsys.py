"""Recsys/CTR family: DLRM, DIN, DIEN (AUGRU), two-tower retrieval, and the
paper's own CTR model (giant multi-hot embedding -> field attention -> MLP).

These are the archs the paper's framework was built for: huge sparse
embedding tables trained with every-step sparse AdaGrad through the
working-set pull path (core/embedding_engine.py), and dense towers trained
with k-step Adam.  All models expose the same two-stage API:

    embed_batch(tables, batch, cfg)      -> pooled embedding features (gathers)
    forward_from_emb(dense, emb, batch)  -> logits

so the trainer can route the lookup through pulled working sets and take
gradients w.r.t. the compact pulled rows only (the PS pull/push of Alg. 1).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.embedding_engine import EmbeddingEngine, TableSpec, embedding_bag
from repro.models.common import (
    bce_with_logits,
    he_init,
    mlp_apply,
    mlp_init,
    shard_hint,
)

# Criteo-1TB per-feature cardinalities (MLPerf DLRM reference).
CRITEO_ROWS = [
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
]


# ======================================================================= DLRM
@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 128
    bot_mlp: Sequence[int] = (13, 512, 256, 128)
    top_mlp: Sequence[int] = (1024, 1024, 512, 256, 1)
    rows: Sequence[int] = tuple(CRITEO_ROWS)
    dtype: Any = jnp.float32

    @property
    def interact_dim(self) -> int:
        n = self.n_sparse + 1
        return n * (n - 1) // 2 + self.embed_dim


def dlrm_table_specs(cfg: DLRMConfig) -> Dict[str, TableSpec]:
    # 26 single-hot tables share one (B, 26) ``sparse_ids`` batch field:
    # table i reads column i (TableSpec.id_col).
    return {
        f"emb_{i:02d}": TableSpec(
            f"emb_{i:02d}", rows=cfg.rows[i], dim=cfg.embed_dim,
            id_field="sparse_ids", id_col=i,
        )
        for i in range(cfg.n_sparse)
    }


def dlrm_init_dense(rng: jax.Array, cfg: DLRMConfig):
    kb, kt = jax.random.split(rng)
    return {
        "bot": mlp_init(kb, list(cfg.bot_mlp), cfg.dtype),
        "top": mlp_init(kt, [cfg.interact_dim] + list(cfg.top_mlp), cfg.dtype),
    }


def dot_interaction(feats: jnp.ndarray) -> jnp.ndarray:
    """feats (B, F, D) -> lower-triangle pairwise dots (B, F*(F-1)/2)."""
    B, F, D = feats.shape
    z = jnp.einsum("bfd,bgd->bfg", feats, feats)
    li, lj = jnp.tril_indices(F, k=-1)
    return z[:, li, lj]


def dlrm_embed_batch(tables, batch, cfg: DLRMConfig) -> jnp.ndarray:
    """sparse_ids (B, 26) single-hot -> (B, 26, D)."""
    ids = batch["sparse_ids"]
    embs = [jnp.take(tables[f"emb_{i:02d}"], ids[:, i], axis=0) for i in range(cfg.n_sparse)]
    return jnp.stack(embs, axis=1)


def dlrm_forward_from_emb(dense, emb, batch, cfg: DLRMConfig) -> jnp.ndarray:
    x = mlp_apply(dense["bot"], batch["dense"].astype(cfg.dtype), act=jax.nn.relu)
    x = shard_hint(x, ("pod", "data"), None)
    feats = jnp.concatenate([x[:, None, :], emb.astype(cfg.dtype)], axis=1)  # (B,27,D)
    inter = dot_interaction(feats)
    top_in = jnp.concatenate([x, inter], axis=-1)
    return mlp_apply(dense["top"], top_in, act=jax.nn.relu)[:, 0]


def dlrm_embed_from_workings(cfg: DLRMConfig, fused: bool = False):
    """HybridTrainer embed adapter: the 26 single-hot lookups routed through
    each table's pulled working set (``invs["emb_XX"]`` has shape (B,) — one
    row per instance), so grads land on the compact pulled rows only.

    ``fused`` is accepted for adapter-signature uniformity: single-hot takes
    have no bag reduction to fuse (the fused push still applies)."""
    del fused

    def embed(workings, invs, batch):
        embs = [
            jnp.take(workings[f"emb_{i:02d}"], invs[f"emb_{i:02d}"], axis=0)
            for i in range(cfg.n_sparse)
        ]
        return jnp.stack(embs, axis=1)                      # (B, 26, D)

    return embed


def dlrm_hybrid_loss(cfg: DLRMConfig):
    """HybridTrainer loss adapter: BCE over the dot-interaction tower
    (``predict=True`` returns sigmoid click scores)."""

    def loss(dense, emb, batch, predict=False):
        logits = dlrm_forward_from_emb(dense, emb, batch, cfg)
        if predict:
            return jax.nn.sigmoid(logits)
        return pointwise_loss(logits, batch["label"])

    return loss


# ==================================================================== DIN/DIEN
@dataclasses.dataclass(frozen=True)
class DINConfig:
    name: str = "din"
    embed_dim: int = 18
    seq_len: int = 100
    attn_mlp: Sequence[int] = (80, 40)
    mlp: Sequence[int] = (200, 80)
    item_vocab: int = 2_000_000
    gru_dim: int = 0            # DIEN: 108; 0 disables the GRU/AUGRU stage
    dtype: Any = jnp.float32


def din_table_specs(cfg: DINConfig) -> Dict[str, TableSpec]:
    # history + target ids feed ONE item table: the pull concatenates the
    # fields per instance into (B, seq_len + 1) before deduplicating.
    return {
        "items": TableSpec(
            "items", rows=cfg.item_vocab, dim=cfg.embed_dim,
            id_field=("hist_ids", "target_id"),
        )
    }


def din_init_dense(rng: jax.Array, cfg: DINConfig):
    d = cfg.embed_dim
    k = jax.random.split(rng, 8)
    params = {
        "att": mlp_init(k[0], [4 * (cfg.gru_dim or d)] + list(cfg.attn_mlp) + [1], cfg.dtype),
        "mlp": mlp_init(
            k[1], [(cfg.gru_dim or d) * 2 + 2 * d] + list(cfg.mlp) + [1], cfg.dtype
        ),
    }
    if cfg.gru_dim:
        h = cfg.gru_dim
        params["gru"] = {
            "wx": he_init(k[2], (d, 3 * h), cfg.dtype),
            "wh": he_init(k[3], (h, 3 * h), cfg.dtype),
            "b": jnp.zeros((3 * h,), cfg.dtype),
        }
        params["augru"] = {
            "wx": he_init(k[4], (h, 3 * h), cfg.dtype),
            "wh": he_init(k[5], (h, 3 * h), cfg.dtype),
            "b": jnp.zeros((3 * h,), cfg.dtype),
        }
        params["tproj"] = he_init(k[6], (d, h), cfg.dtype)
    return params


def _gru_scan(p, xs, h0, att: Optional[jnp.ndarray] = None):
    """GRU over time; with ``att`` (T, B) the update gate is attention-scaled
    (AUGRU, Zhou et al. 2019).  xs: (T, B, d) -> (T, B, h), final h."""
    H = p["wh"].shape[0]

    def cell(h, inp):
        x, a = inp
        gx = x @ p["wx"] + p["b"]
        gh = h @ p["wh"]
        xr, xz, xn = jnp.split(gx, 3, axis=-1)
        hr, hz, hn = jnp.split(gh, 3, axis=-1)
        r = jax.nn.sigmoid(xr + hr)
        z = jax.nn.sigmoid(xz + hz)   # update gate: weight of the NEW state
        n = jnp.tanh(xn + r * hn)
        if a is not None:
            # AUGRU (DIEN eq. 5): u~_t = a_t * u_t — attention scales how much
            # of the candidate is written; a=0 leaves the hidden state frozen.
            z = a[:, None] * z
        h_new = (1.0 - z) * h + z * n
        return h_new, h_new

    a_seq = att if att is not None else jnp.zeros((xs.shape[0],), xs.dtype)
    inputs = (xs, att) if att is not None else (xs, None)
    if att is None:
        h_final, hs = jax.lax.scan(lambda h, x: cell(h, (x, None)), h0, xs)
    else:
        h_final, hs = jax.lax.scan(cell, h0, (xs, att))
    return hs, h_final


def din_attention(dense, hist: jnp.ndarray, target: jnp.ndarray, mask: jnp.ndarray):
    """hist (B,T,d), target (B,d) -> attention weights (B,T) (masked softmax)."""
    B, T, d = hist.shape
    tt = jnp.broadcast_to(target[:, None, :], hist.shape)
    feat = jnp.concatenate([hist, tt, hist - tt, hist * tt], axis=-1)
    scores = mlp_apply(dense["att"], feat, act=jax.nn.sigmoid)[..., 0]  # (B,T)
    scores = jnp.where(mask > 0, scores, -1e30)
    return jax.nn.softmax(scores, axis=-1)


def din_embed_batch(tables, batch, cfg: DINConfig):
    hist = jnp.take(tables["items"], batch["hist_ids"], axis=0)   # (B,T,d)
    target = jnp.take(tables["items"], batch["target_id"], axis=0)  # (B,d)
    return {"hist": hist, "target": target}


def din_forward_from_emb(dense, emb, batch, cfg: DINConfig) -> jnp.ndarray:
    hist, target = emb["hist"].astype(cfg.dtype), emb["target"].astype(cfg.dtype)
    mask = batch["hist_mask"].astype(cfg.dtype)                   # (B,T)
    if cfg.gru_dim:
        # DIEN: interest extraction GRU -> attention -> AUGRU evolution.
        xs = (hist * mask[..., None]).transpose(1, 0, 2)          # (T,B,d)
        h0 = jnp.zeros((hist.shape[0], cfg.gru_dim), cfg.dtype)
        states, _ = _gru_scan(dense["gru"], xs, h0)               # (T,B,h)
        t_h = target @ dense["tproj"]                             # (B,h)
        att_in = states.transpose(1, 0, 2)                        # (B,T,h)
        tt = jnp.broadcast_to(t_h[:, None, :], att_in.shape)
        feat = jnp.concatenate([att_in, tt, att_in - tt, att_in * tt], axis=-1)
        scores = mlp_apply(dense["att"], feat, act=jax.nn.sigmoid)[..., 0]
        scores = jnp.where(mask > 0, scores, -1e30)
        att = jax.nn.softmax(scores, axis=-1)                     # (B,T)
        _, final = _gru_scan(dense["augru"], states, h0, att=att.T)
        pooled = final                                            # (B,h)
        rep = jnp.concatenate([pooled, t_h, target, target * 0 + jnp.mean(hist * mask[..., None], 1)], -1)
    else:
        att = din_attention(dense, hist, target, mask)
        att_hist = jnp.einsum("bt,btd->bd", att, hist)
        sum_pool = jnp.sum(hist * mask[..., None], axis=1)
        rep = jnp.concatenate([att_hist, target, att_hist * target, sum_pool], axis=-1)
    return mlp_apply(dense["mlp"], rep, act=jax.nn.relu)[:, 0]


def din_embed_from_workings(cfg: DINConfig, fused: bool = False):
    """HybridTrainer embed adapter for DIN/DIEN: history + target ids feed
    one item table (``din_table_specs`` concatenates the two fields per
    instance), so ``invs["items"]`` reshapes to (B, seq_len + 1) — the first
    ``seq_len`` columns are the history lookups, the last is the target.

    ``fused`` is accepted for adapter-signature uniformity: the attention
    tower consumes unpooled rows, there is no bag reduction to fuse (the
    fused push still applies)."""
    del fused
    T = cfg.seq_len

    def embed(workings, invs, batch):
        B = batch["hist_ids"].shape[0]
        inv = invs["items"].reshape(B, T + 1)
        hist = jnp.take(workings["items"], inv[:, :T], axis=0)    # (B,T,d)
        target = jnp.take(workings["items"], inv[:, T], axis=0)   # (B,d)
        return {"hist": hist, "target": target}

    return embed


def din_hybrid_loss(cfg: DINConfig):
    """HybridTrainer loss adapter: BCE over the (AU)GRU/attention tower
    (``predict=True`` returns sigmoid click scores)."""

    def loss(dense, emb, batch, predict=False):
        logits = din_forward_from_emb(dense, emb, batch, cfg)
        if predict:
            return jax.nn.sigmoid(logits)
        return pointwise_loss(logits, batch["label"])

    return loss


# ================================================================== two-tower
@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two_tower"
    embed_dim: int = 256
    tower_mlp: Sequence[int] = (1024, 512, 256)
    user_hist_len: int = 50
    item_vocab: int = 5_000_000
    temperature: float = 0.05
    # In-batch negatives are capped at this pool size: full BxB softmax at
    # production batch (65k) would materialize a 17 TB logits matrix.
    neg_pool: int = 4096
    dtype: Any = jnp.float32


def two_tower_table_specs(cfg: TwoTowerConfig) -> Dict[str, TableSpec]:
    # user history + positive item share the item table, (B, hist_len + 1);
    # the user-history bag pools by the spec's combiner (mean over the mask)
    return {
        "items": TableSpec(
            "items", rows=cfg.item_vocab, dim=cfg.embed_dim,
            combiner="mean", id_field=("user_ids", "item_id"),
        )
    }


def two_tower_init_dense(rng: jax.Array, cfg: TwoTowerConfig):
    ku, ki = jax.random.split(rng)
    sizes = [cfg.embed_dim] + list(cfg.tower_mlp)
    return {"user": mlp_init(ku, sizes, cfg.dtype), "item": mlp_init(ki, sizes, cfg.dtype)}


def two_tower_embed_batch(tables, batch, cfg: TwoTowerConfig):
    T = batch["user_ids"].shape[1]
    B = batch["user_ids"].shape[0]
    flat = batch["user_ids"].reshape(-1)
    seg = jnp.repeat(jnp.arange(B, dtype=jnp.int32), T)
    w = batch["user_mask"].reshape(-1)
    spec = two_tower_table_specs(cfg)["items"]
    user = embedding_bag(tables["items"], flat, seg, num_bags=B, weights=w,
                         combiner=spec.combiner)
    item = jnp.take(tables["items"], batch["item_id"], axis=0)
    return {"user": user, "item": item}


def _tower(params, x, dtype):
    y = mlp_apply(params, x.astype(dtype), act=jax.nn.relu)
    # sqrt(max(|y|^2, eps^2)) == max(|y|, eps), but with a well-defined
    # gradient at y == 0: jnp.linalg.norm's 0/0 grad would NaN-poison the
    # push whenever a capacity-dropped id reads the all-zero drop row.
    sq = jnp.sum(jnp.square(y), axis=-1, keepdims=True)
    return y / jnp.sqrt(jnp.maximum(sq, 1e-12))


def two_tower_forward_from_emb(dense, emb, batch, cfg: TwoTowerConfig):
    u = _tower(dense["user"], emb["user"], cfg.dtype)   # (B, D)
    v = _tower(dense["item"], emb["item"], cfg.dtype)   # (B, D)
    return u, v


def two_tower_loss(dense, emb, batch, cfg: TwoTowerConfig) -> jnp.ndarray:
    """In-batch sampled softmax with logQ correction (Yi et al., RecSys'19).

    Negatives come from a pool of the first ``neg_pool`` in-batch items; each
    row's own positive is scored explicitly and its duplicate in the pool is
    masked, so the loss is exact sampled softmax for any batch size without
    a (B, B) logits matrix.
    """
    u, v = two_tower_forward_from_emb(dense, emb, batch, cfg)
    B = u.shape[0]
    M = min(cfg.neg_pool, B)
    pool = v[:M]                                          # (M, D)
    pos = jnp.sum(u * v, axis=-1) / cfg.temperature       # (B,)
    negs = (u @ pool.T) / cfg.temperature                 # (B, M)
    logq = batch.get("sample_logq")
    if logq is not None:
        negs = negs - logq[:M][None, :]
    # mask each row's own positive inside the pool (rows < M)
    row = jnp.arange(B)
    dup = (row[:, None] == jnp.arange(M)[None, :])
    negs = jnp.where(dup, -1e30, negs.astype(jnp.float32))
    logits = jnp.concatenate([pos.astype(jnp.float32)[:, None], negs], axis=1)
    logz = jax.nn.logsumexp(logits, axis=-1)
    return jnp.mean(logz - pos.astype(jnp.float32))


def two_tower_score_candidates(dense, tables, user_emb_pooled, cand_ids, cfg: TwoTowerConfig):
    """Retrieval scoring: one (or few) users against n_candidates items."""
    u = _tower(dense["user"], user_emb_pooled, cfg.dtype)            # (B, D)
    cand = jnp.take(tables["items"], cand_ids, axis=0)               # (C, D)
    v = _tower(dense["item"], cand, cfg.dtype)
    return u @ v.T                                                   # (B, C)


def two_tower_embed_from_workings(cfg: TwoTowerConfig, fused: bool = False):
    """HybridTrainer embed adapter: user-history mean bag + positive item,
    both served from the pulled item working set (``invs["items"]`` reshapes
    to (B, hist_len + 1); see ``two_tower_table_specs``).  ``fused`` routes
    the history bag through the fused Pallas gather+bag kernel."""
    H = cfg.user_hist_len
    combiner = two_tower_table_specs(cfg)["items"].combiner

    def embed(workings, invs, batch):
        B = batch["user_ids"].shape[0]
        inv = invs["items"].reshape(B, H + 1)
        seg = jnp.repeat(jnp.arange(B, dtype=jnp.int32), H)
        user = EmbeddingEngine.bag_from_working(
            workings["items"], inv[:, :H].reshape(-1), seg, num_bags=B,
            weights=batch["user_mask"].reshape(-1), combiner=combiner,
            fused=fused,
        )
        item = jnp.take(workings["items"], inv[:, H], axis=0)
        return {"user": user, "item": item}

    return embed


def two_tower_hybrid_loss(cfg: TwoTowerConfig):
    """HybridTrainer loss adapter: in-batch sampled softmax with logQ
    correction; ``predict=True`` returns each instance's positive-item
    retrieval score u·v (towers are L2-normalized, so scores are in
    [-1, 1])."""

    def loss(dense, emb, batch, predict=False):
        if predict:
            u, v = two_tower_forward_from_emb(dense, emb, batch, cfg)
            return jnp.sum(u * v, axis=-1)
        return two_tower_loss(dense, emb, batch, cfg)

    return loss


# ============================================================ paper CTR model
@dataclasses.dataclass(frozen=True)
class CTRConfig:
    """The paper's web-search CTR model (Fig. 2): giant multi-hot sparse
    input -> 64-d embeddings -> field self-attention -> MLP."""
    name: str = "baidu_ctr"
    rows: int = 4_000_000_000     # terabyte-scale at dim 64 + f32 accumulator
    embed_dim: int = 64
    n_fields: int = 40
    nnz_per_instance: int = 100
    attn_heads: int = 4
    mlp: Sequence[int] = (512, 256, 1)
    dtype: Any = jnp.float32


def ctr_table_specs(cfg: CTRConfig) -> Dict[str, TableSpec]:
    return {
        "sparse": TableSpec(
            "sparse", rows=cfg.rows, dim=cfg.embed_dim, id_field="ids"
        )
    }


def ctr_init_dense(rng: jax.Array, cfg: CTRConfig):
    d = cfg.embed_dim
    k = jax.random.split(rng, 6)
    return {
        "wq": he_init(k[0], (d, d), cfg.dtype),
        "wk": he_init(k[1], (d, d), cfg.dtype),
        "wv": he_init(k[2], (d, d), cfg.dtype),
        "mlp": mlp_init(k[3], [cfg.n_fields * d] + list(cfg.mlp), cfg.dtype),
    }


def ctr_embed_batch(tables, batch, cfg: CTRConfig) -> jnp.ndarray:
    """ids (B, nnz) + field_ids (B, nnz) + mask -> per-field bags (B, F, d)."""
    B, nnz = batch["ids"].shape
    flat = batch["ids"].reshape(-1)
    # bag index = instance * n_fields + field
    seg = (jnp.arange(B, dtype=jnp.int32)[:, None] * cfg.n_fields
           + batch["field_ids"]).reshape(-1)
    w = batch["mask"].reshape(-1)
    bags = embedding_bag(
        tables["sparse"], flat, seg, num_bags=B * cfg.n_fields, weights=w,
        combiner=ctr_table_specs(cfg)["sparse"].combiner,
    )
    return bags.reshape(B, cfg.n_fields, cfg.embed_dim)


def ctr_embed_from_workings(cfg: CTRConfig, fused: bool = False):
    """Build the HybridTrainer embed adapter for the paper's CTR model.

    The returned ``embed(workings, invs, batch)`` routes the per-field bag
    lookup through the pulled working set (``workings["sparse"]`` are the
    deduplicated rows, ``invs["sparse"]`` maps id slots to working rows), so
    autodiff lands gradients on the compact pulled rows — Algorithm 1's
    pull path.  This is the one canonical copy used by the trainer factory,
    examples, and benchmarks.  Pooling honors ``TableSpec.combiner`` (sum
    for the paper's CTR model — masked rows contribute zero); ``fused``
    routes it through the fused Pallas gather+bag kernel.
    """
    combiner = ctr_table_specs(cfg)["sparse"].combiner

    def embed(workings, invs, batch):
        B, _ = batch["ids"].shape
        seg = (jnp.arange(B, dtype=jnp.int32)[:, None] * cfg.n_fields
               + batch["field_ids"]).reshape(-1)
        bags = EmbeddingEngine.bag_from_working(
            workings["sparse"], invs["sparse"], seg,
            num_bags=B * cfg.n_fields, weights=batch["mask"].reshape(-1),
            combiner=combiner, fused=fused,
        )
        return bags.reshape(B, cfg.n_fields, cfg.embed_dim)

    return embed


def ctr_hybrid_loss(cfg: CTRConfig):
    """Build the HybridTrainer loss adapter: BCE on the field-attention
    tower (``predict=True`` returns sigmoid scores for online inference)."""

    def loss(dense, emb, batch, predict=False):
        logits = ctr_forward_from_emb(dense, emb, batch, cfg)
        if predict:
            return jax.nn.sigmoid(logits)
        return pointwise_loss(logits, batch["label"])

    return loss


def ctr_forward_from_emb(dense, emb, batch, cfg: CTRConfig) -> jnp.ndarray:
    x = emb.astype(cfg.dtype)                                       # (B,F,d)
    H = cfg.attn_heads
    d = cfg.embed_dim
    hd = d // H
    B, F, _ = x.shape
    q = (x @ dense["wq"]).reshape(B, F, H, hd)
    k = (x @ dense["wk"]).reshape(B, F, H, hd)
    v = (x @ dense["wv"]).reshape(B, F, H, hd)
    s = jnp.einsum("bfhd,bghd->bhfg", q, k) / (hd ** 0.5)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(cfg.dtype)
    o = jnp.einsum("bhfg,bghd->bfhd", p, v).reshape(B, F, d)
    o = (x + o).reshape(B, F * d)
    return mlp_apply(dense["mlp"], o, act=jax.nn.relu)[:, 0]


# ----------------------------------------------------------------- losses
def pointwise_loss(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(bce_with_logits(logits, labels))
