"""GIN (Graph Isomorphism Network, Xu et al. 2019) in the segment-sum regime.

JAX sparse is BCOO-only, so message passing is an edge-index scatter:
``agg[v] = sum_{(u,v) in E} h[u]`` via ``jax.ops.segment_sum`` — this IS the
SpMM kernel of the GCN/GIN family, expressed TPU-natively (gathers + scatter
adds partition cleanly over a row-sharded node state under GSPMD).

Supports: full-graph training (node classification), sampled minibatch
(seed-node loss over a fanout-sampled block, see data/graph_sampler.py) and
batched disjoint small graphs with segment readout (molecule regime).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.common import he_init, shard_hint, softmax_cross_entropy


def _eps(params, cfg: "GINConfig") -> jnp.ndarray:
    """Per-layer eps vector; GIN-0 (``train_eps=False``) stops its gradient
    so eps stays at init while the params pytree keeps a stable structure
    (checkpoints/optimizer states are layout-identical either way)."""
    eps = params["eps"]
    return eps if cfg.train_eps else jax.lax.stop_gradient(eps)


@dataclasses.dataclass(frozen=True)
class GINConfig:
    name: str = "gin"
    n_layers: int = 5
    d_in: int = 1433
    d_hidden: int = 64
    n_classes: int = 7
    train_eps: bool = True        # eps=learnable; False freezes eps at its
                                  # init (GIN-0): the forward stops the eps
                                  # gradient so the optimizer never moves it
    readout: str = "node"         # node | graph (segment readout over graph_id)
    dtype: Any = jnp.float32
    # §Perf knobs: node_shard=False replicates the node state in-pod (edges
    # stay sharded; the per-layer scatter reduces with ONE all-reduce instead
    # of per-edge cross-shard gathers); message_dtype=bf16 halves its wire.
    node_shard: bool = True
    message_dtype: Any = None     # None = dtype
    # Exact rewrite: W1 commutes with the sum aggregator, so when the input
    # width exceeds d_hidden, project BEFORE message passing — gathers and
    # scatters then move d_hidden-wide rows instead of d_in-wide ones.
    pre_project: bool = False


def init_params(rng: jax.Array, cfg: GINConfig) -> Dict[str, Any]:
    params: Dict[str, Any] = {"eps": jnp.zeros((cfg.n_layers,), jnp.float32), "layers": []}
    d_prev = cfg.d_in
    for i in range(cfg.n_layers):
        k1, k2 = jax.random.split(jax.random.fold_in(rng, i))
        params["layers"].append({
            "w1": he_init(k1, (d_prev, cfg.d_hidden), cfg.dtype),
            "b1": jnp.zeros((cfg.d_hidden,), cfg.dtype),
            "w2": he_init(k2, (cfg.d_hidden, cfg.d_hidden), cfg.dtype),
            "b2": jnp.zeros((cfg.d_hidden,), cfg.dtype),
        })
        d_prev = cfg.d_hidden
    ko = jax.random.fold_in(rng, 999)
    params["out"] = he_init(ko, (cfg.d_hidden, cfg.n_classes), cfg.dtype)
    return params


def forward(
    params,
    x: jnp.ndarray,          # (N, d_in) node features
    edge_src: jnp.ndarray,   # (E,) int32
    edge_dst: jnp.ndarray,   # (E,) int32
    cfg: GINConfig,
    edge_mask: Optional[jnp.ndarray] = None,   # (E,) bool — padding edges
    graph_ids: Optional[jnp.ndarray] = None,   # (N,) for graph readout
    num_graphs: int = 0,
) -> jnp.ndarray:
    N = x.shape[0]
    h = x.astype(cfg.dtype)
    node_spec = (("pod", "data"), None) if cfg.node_shard else (None, None)
    mdt = cfg.message_dtype or cfg.dtype
    eps = _eps(params, cfg)
    for i, lp in enumerate(params["layers"]):
        pre = cfg.pre_project and h.shape[-1] > lp["w1"].shape[-1]
        src_feat = (h @ lp["w1"]).astype(mdt) if pre else h.astype(mdt)
        msg = jnp.take(src_feat, edge_src, axis=0)              # gather
        if edge_mask is not None:
            msg = msg * edge_mask[:, None].astype(msg.dtype)
        agg = jax.ops.segment_sum(msg, edge_dst, num_segments=N)  # scatter-add
        agg = shard_hint(agg, *node_spec)
        if pre:
            # W1((1+eps)h + sum_j h_j) == (1+eps)(h W1) + sum_j (h_j W1)
            z = ((1.0 + eps[i]) * src_feat.astype(jnp.float32)
                 + agg.astype(jnp.float32)).astype(cfg.dtype)
            z = jax.nn.relu(z + lp["b1"])
        else:
            z = ((1.0 + eps[i]) * h.astype(jnp.float32)
                 + agg.astype(jnp.float32)).astype(cfg.dtype)
            z = jax.nn.relu(z @ lp["w1"] + lp["b1"])
        h = jax.nn.relu(z @ lp["w2"] + lp["b2"])
        h = shard_hint(h, *node_spec)
    if cfg.readout == "graph":
        assert graph_ids is not None and num_graphs > 0
        pooled = jax.ops.segment_sum(h, graph_ids, num_segments=num_graphs)
        return pooled @ params["out"]
    return h @ params["out"]


def loss_fn(params, batch, cfg: GINConfig) -> jnp.ndarray:
    """batch: x, edge_src, edge_dst, labels, optional edge_mask/node_mask
    (node_mask restricts the loss to seed/valid nodes), optional graph_ids."""
    if cfg.readout == "graph":
        logits = forward(
            params, batch["x"], batch["edge_src"], batch["edge_dst"], cfg,
            edge_mask=batch.get("edge_mask"),
            graph_ids=batch["graph_ids"], num_graphs=batch["labels"].shape[0],
        )
        ce = softmax_cross_entropy(logits, batch["labels"])
        return jnp.mean(ce)
    logits = forward(
        params, batch["x"], batch["edge_src"], batch["edge_dst"], cfg,
        edge_mask=batch.get("edge_mask"),
    )
    ce = softmax_cross_entropy(logits, batch["labels"])
    mask = batch.get("node_mask")
    if mask is not None:
        return jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(ce)


def dense_reference_forward(params, x, adj: jnp.ndarray, cfg: GINConfig):
    """Oracle using a dense adjacency matrix — tests only."""
    h = x.astype(cfg.dtype)
    eps = _eps(params, cfg)
    for i, lp in enumerate(params["layers"]):
        agg = adj.T.astype(jnp.float32) @ h.astype(jnp.float32)
        z = ((1.0 + eps[i]) * h.astype(jnp.float32) + agg).astype(cfg.dtype)
        z = jax.nn.relu(z @ lp["w1"] + lp["b1"])
        h = jax.nn.relu(z @ lp["w2"] + lp["b2"])
    return h @ params["out"]
