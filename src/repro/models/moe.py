"""Mixture-of-Experts FFN — TPU-idiomatic capacity routing without
all-to-all: tokens are bucketed into groups (sharded over the data axes),
each group scatter-dispatches its tokens into per-expert capacity slots, and
expert FFNs run as one batched einsum with weights sharded over the model
axis.  Dispatch/combine are pure gathers/scatters (no one-hot matmuls), so
compiled FLOPs stay proportional to *active* parameters — keeping the
MODEL_FLOPS / HLO_FLOPS roofline ratio honest.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import shard_hint


def _route_group(xg, wr, E: int, top_k: int, capacity: int):
    """xg: (S, D) one token group.  Returns dispatch plan + aux-loss stats."""
    S, D = xg.shape
    logits = (xg @ wr).astype(jnp.float32)          # (S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gv, gi = jax.lax.top_k(probs, top_k)            # (S, top_k)
    gv = gv / jnp.maximum(jnp.sum(gv, -1, keepdims=True), 1e-9)  # renormalize
    # Priority order: all 1st choices claim capacity before any 2nd choice.
    e_flat = gi.T.reshape(-1)                       # (top_k*S,)
    w_flat = gv.T.reshape(-1)
    t_flat = jnp.tile(jnp.arange(S, dtype=jnp.int32), top_k)
    onehot = (e_flat[:, None] == jnp.arange(E)[None, :]).astype(jnp.int32)
    pos_in_e = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, axis=1) - 1
    keep = pos_in_e < capacity
    slot = jnp.where(keep, e_flat * capacity + pos_in_e, E * capacity)
    # Aux (load-balance) stats: fraction routed + mean prob per expert.
    frac = jnp.mean(onehot.astype(jnp.float32), axis=0) * top_k
    mean_p = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac * mean_p)
    return slot, t_flat, w_flat.astype(xg.dtype), keep, aux


def _dispatch_group(xg, slot, t_flat, E, capacity):
    S, D = xg.shape
    buf = jnp.zeros((E * capacity + 1, D), xg.dtype)
    buf = buf.at[slot].set(jnp.take(xg, t_flat, axis=0), mode="drop")
    return buf[: E * capacity].reshape(E, capacity, D)


def _combine_group(ye, slot, t_flat, w_flat, keep, S):
    E, C, D = ye.shape
    flat = jnp.concatenate([ye.reshape(E * C, D), jnp.zeros((1, D), ye.dtype)], 0)
    contrib = jnp.take(flat, slot, axis=0) * (w_flat * keep.astype(ye.dtype))[:, None]
    out = jnp.zeros((S, D), ye.dtype)
    return out.at[t_flat].add(contrib)


def moe_ffn(x: jnp.ndarray, lp, cfg):
    """x: (B, S, D) post-norm activations -> (y, aux_loss).

    ``lp`` holds router (D,E) and expert weights we_gate/we_up/we_down
    (E,D,F)/(E,F,D).  Tokens are processed in groups of cfg.moe_group_size so
    capacity bookkeeping is shard-local under the data axes.
    """
    B, S, D = x.shape
    E, top_k = cfg.n_experts, cfg.top_k
    T = B * S
    gsz = min(cfg.moe_group_size, T)
    G = T // gsz
    assert T % gsz == 0, f"tokens {T} not divisible by moe group {gsz}"
    capacity = max(top_k, int(gsz * top_k * cfg.capacity_factor / E))
    capacity = min(gsz * top_k, -(-capacity // 8) * 8)  # pad to multiple of 8

    xg = x.reshape(G, gsz, D)
    xg = shard_hint(xg, ("pod", "data"), None, None)

    def per_group(xg1):
        slot, t_flat, w_flat, keep, aux = _route_group(
            xg1, lp["router"], E, top_k, capacity
        )
        xe = _dispatch_group(xg1, slot, t_flat, E, capacity)
        return xe, (slot, t_flat, w_flat, keep), aux

    xe, plan, aux = jax.vmap(per_group)(xg)          # xe: (G, E, C, D)
    xe = shard_hint(xe, ("pod", "data"), None, None, None)
    # Batched expert FFN (swiglu), expert weights sharded over 'model' on F.
    g = jnp.einsum("gecd,edf->gecf", xe, lp["we_gate"])
    u = jnp.einsum("gecd,edf->gecf", xe, lp["we_up"])
    h = jax.nn.silu(g) * u
    h = shard_hint(h, ("pod", "data"), None, None, "model")
    ye = jnp.einsum("gecf,efd->gecd", h, lp["we_down"])

    def per_group_combine(ye1, plan1):
        slot, t_flat, w_flat, keep = plan1
        return _combine_group(ye1, slot, t_flat, w_flat, keep, gsz)

    y = jax.vmap(per_group_combine)(ye, plan).reshape(B, S, D)

    if cfg.shared_expert:
        sg = jax.nn.silu(x.reshape(T, D) @ lp["ws_gate"]) * (x.reshape(T, D) @ lp["ws_up"])
        y = y + (sg @ lp["ws_down"]).reshape(B, S, D)
    return y, jnp.mean(aux)
