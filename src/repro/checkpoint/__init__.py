from repro.checkpoint.ckpt import (  # noqa: F401
    CheckpointManager,
    latest_step,
    read_manifest,
    restore_tree,
    save_tree,
)
