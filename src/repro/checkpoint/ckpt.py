"""Fault-tolerant checkpointing: atomic sharded .npz + JSON manifest.

Design (1000-node deployment notes):
- Atomicity: write into ``step_XXXX.tmp/``, fsync, then ``rename`` — a crash
  mid-save never corrupts the latest restorable state.
- Multi-host: each process saves only its addressable shards under
  ``proc_{i}`` (here: single process saves everything); the manifest records
  the logical shapes so restore is layout-independent.
- Elasticity: ``restore_tree(..., shardings=...)`` re-``device_put``s the
  logical arrays onto the *current* mesh — pod count and data-parallel width
  may differ from the saving run (elastic re-mesh).
- Retention: keep-last-N GC; ``latest_step`` scans for the newest complete
  manifest, skipping torn ``.tmp`` dirs (crash-consistent resume).
- Async: ``CheckpointManager(async_save=True)`` snapshots to host then writes
  in a background thread so the device step is never blocked on disk; a
  failed background write is never silent — it re-raises from ``wait()`` or
  from the next ``save()``.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

Pytree = Any

_SEP = "/"

# a pages_staging_* dir older than this is dead-process wreckage; younger
# ones may belong to a live trainer sharing the checkpoint directory
# (staging is written synchronously and renamed away within one save)
_STAGING_STALE_S = 3600.0


def _flatten_with_names(tree: Pytree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        name = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[name] = leaf
    return out, treedef


def _fsync_path(path: str):
    """fsync a file or directory by path (directory fsync persists the
    entry names — the other half of the rename-atomicity recipe)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save_tree(directory: str, step: int, tree: Pytree, meta: Optional[Dict] = None,
              extras_dir: Optional[str] = None):
    """Atomically persist ``tree`` for ``step``. Returns the final dir.

    Crash-atomicity recipe: write arrays + manifest into ``step_X.tmp/``,
    fsync BOTH files and the tmp directory, then rename into place and
    fsync the parent.  Overwriting an existing ``step_X`` renames it aside
    (``step_X.old`` — invisible to ``latest_step``) instead of rmtree'ing
    it first, so a kill between the two renames still leaves every earlier
    checkpoint complete and restorable; the aside copy is deleted only
    after the replacement is in place.

    ``extras_dir``: a fully-written staging directory (the DiskStore's page
    snapshot) MOVED into ``step_X.tmp/pages`` by rename — it rides the same
    whole-directory atomicity as the arrays, and because the caller wrote
    it synchronously before handing it over, an async writer thread never
    races live page mutations.
    """
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp, aside = final + ".tmp", final + ".old"
    for stale in (tmp, aside):   # leftovers of a previously crashed save
        if os.path.exists(stale):
            shutil.rmtree(stale)
    os.makedirs(tmp)
    if extras_dir is not None:
        os.rename(extras_dir, os.path.join(tmp, "pages"))
    named, _ = _flatten_with_names(tree)
    arrays = {k: np.asarray(v) for k, v in named.items()}
    arrays_path = os.path.join(tmp, "arrays_proc0.npz")
    np.savez(arrays_path, **arrays)
    _fsync_path(arrays_path)   # array data durable BEFORE the manifest
    manifest = {
        "step": int(step),
        "time": time.time(),
        "leaves": {k: {"shape": list(a.shape), "dtype": str(a.dtype)} for k, a in arrays.items()},
        "meta": meta or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    _fsync_path(tmp)           # both directory entries durable
    if os.path.exists(final):
        os.rename(final, aside)
    os.rename(tmp, final)
    _fsync_path(directory)     # the renames durable
    if os.path.exists(aside):
        shutil.rmtree(aside)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "manifest.json")):
            s = int(m.group(1))
            best = s if best is None else max(best, s)
    return best


def read_manifest(directory: str, step: int) -> Optional[Dict]:
    """The manifest of one checkpoint (leaves + meta), or None if absent."""
    path = os.path.join(directory, f"step_{step:010d}", "manifest.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def restore_tree(
    directory: str,
    step: int,
    like: Pytree,
    shardings: Optional[Pytree] = None,
) -> Pytree:
    """Restore into the structure of ``like``; optionally re-shard onto the
    current mesh (elastic restart across different meshes/pod counts)."""
    path = os.path.join(directory, f"step_{step:010d}")
    data = np.load(os.path.join(path, "arrays_proc0.npz"))
    named_like, treedef = _flatten_with_names(like)
    leaves = []
    shard_named = None
    if shardings is not None:
        shard_named, _ = _flatten_with_names(shardings)
    for name, ref in named_like.items():
        arr = data[name]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"shape mismatch for {name}: ckpt {arr.shape} vs model {ref.shape}")
        val = arr.astype(ref.dtype)
        if shard_named is not None and name in shard_named:
            val = jax.device_put(val, shard_named[name])
        else:
            val = jax.numpy.asarray(val)
        leaves.append(val)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Save cadence + retention + optional async writes."""

    def __init__(
        self,
        directory: str,
        keep_last: int = 3,
        save_every: int = 100,
        async_save: bool = False,
        spill_dir: Optional[str] = None,
    ):
        self.directory = directory
        self.keep_last = keep_last
        self.save_every = save_every
        self.async_save = async_save
        # a DiskStore spill directory to sweep for write-behind wreckage
        # (*.tmp page files) alongside checkpoint GC — see _gc
        self.spill_dir = spill_dir
        self._thread: Optional[threading.Thread] = None
        # _exc crosses the writer-thread/main boundary; _lock guards it
        # (join() alone gives the happens-before, but the lock keeps the
        # hand-off explicit and auditable)
        self._lock = threading.Lock()
        self._exc: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)
        # crash recovery for page-snapshot staging dirs, HERE and not in
        # _gc: THIS manager has no writer running at construction, so a
        # staging dir it sees is not its own.  _gc runs on the async
        # writer thread, and the trainer stages the NEXT snapshot before
        # save() joins the previous write — sweeping there deletes a live
        # staging dir (the schedule audit's flush-vs-save cell caught
        # exactly this).  The sweep is age-gated because the directory
        # may be shared with ANOTHER live process (an eval/inspection job
        # constructing its own manager against a running trainer's
        # directory): a trainer's staging dir lives seconds, so only dirs
        # older than _STAGING_STALE_S can be dead-process wreckage.
        now = time.time()
        for name in os.listdir(directory):
            if not re.fullmatch(r"pages_staging_\d+", name):
                continue
            path = os.path.join(directory, name)
            try:
                age = now - os.path.getmtime(path)
            except OSError:
                continue   # vanished under us: someone else is live here
            if age > _STAGING_STALE_S:
                shutil.rmtree(path, ignore_errors=True)

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.save_every == 0

    def _write(self, step: int, host_tree, meta, extras_dir=None):
        save_tree(self.directory, step, host_tree, meta, extras_dir=extras_dir)
        self._gc()

    def _write_async(self, step: int, host_tree, meta, extras_dir=None):
        # A failed background save must not be silent: capture the
        # exception so wait() / the next save() re-raises it on the caller.
        try:
            self._write(step, host_tree, meta, extras_dir=extras_dir)
        except BaseException as e:   # noqa: BLE001 — re-raised from wait()
            with self._lock:
                self._exc = e

    def save(self, step: int, tree: Pytree, meta: Optional[Dict] = None,
             block: bool = False, extras_dir: Optional[str] = None):
        # Snapshot to host memory first so devices are released immediately.
        # extras_dir must likewise already be a complete host-side snapshot
        # (the trainer writes it synchronously) — the async thread only
        # renames it into the checkpoint.
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        # drain the in-flight background writer first — EVERY path: a
        # blocking save must not race the previous async one, and a pending
        # failure is raised here instead of being deferred
        self.wait()
        if self.async_save and not block:
            self._thread = threading.Thread(
                target=self._write_async, args=(step, host_tree, meta, extras_dir),
                daemon=True,
            )
            self._thread.start()
        else:
            self._write(step, host_tree, meta, extras_dir=extras_dir)

    def wait(self):
        """Block until the in-flight background save lands; re-raise its
        failure (once) — a crashed writer never fails silently."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        with self._lock:
            exc, self._exc = self._exc, None
        if exc is not None:
            raise exc

    def _gc(self):
        names = os.listdir(self.directory)
        steps = sorted(
            int(m.group(1))
            for name in names
            if (m := re.fullmatch(r"step_(\d+)", name))
        )
        for s in steps[: -self.keep_last] if self.keep_last > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"), ignore_errors=True)
        # wreckage of crashed/failed saves: this manager's writes are
        # serialized (save() drains the writer first), so any .tmp/.old
        # dir still present when _gc runs is dead — sweep it, or a failed
        # async save leaks a checkpoint-sized directory forever
        for name in names:
            if re.fullmatch(r"step_\d+\.(tmp|old)", name):
                shutil.rmtree(os.path.join(self.directory, name), ignore_errors=True)
            # pages_staging_* dirs are deliberately NOT swept here: _gc runs
            # on the async writer thread, and the trainer may already have
            # staged the NEXT save's snapshot — that dir is live, not
            # wreckage.  Dead staging dirs are swept at manager construction.
        if self.spill_dir and os.path.isdir(self.spill_dir):
            # DiskStore write-behind wreckage: a kill mid page write leaves
            # <page>.tmp next to the (still complete) old page — orphaned
            # spill pages are dead by construction, sweep them here too
            for dirpath, _, files in os.walk(self.spill_dir):
                for fn in files:
                    if fn.endswith(".tmp"):
                        try:
                            os.remove(os.path.join(dirpath, fn))
                        except OSError:
                            pass

    def restore_latest(self, like: Pytree, shardings=None):
        s = latest_step(self.directory)
        if s is None:
            return None, None
        return s, restore_tree(self.directory, s, like, shardings)
