"""din [recsys] — embed_dim=18, seq_len=100, attn MLP 80-40, MLP 200-80,
target attention.  [arXiv:1706.06978; paper]"""

from repro.configs import ArchSpec, recsys_shapes
from repro.models.recsys import DINConfig

MODEL = DINConfig(
    name="din", embed_dim=18, seq_len=100,
    attn_mlp=(80, 40), mlp=(200, 80), item_vocab=2_000_000, gru_dim=0,
)

SMOKE = DINConfig(
    name="din-smoke", embed_dim=8, seq_len=20,
    attn_mlp=(16, 8), mlp=(32, 16), item_vocab=500, gru_dim=0,
)

ARCH = ArchSpec(
    name="din", family="recsys", model_cfg=MODEL, smoke_cfg=SMOKE,
    shapes=recsys_shapes(), source="arXiv:1706.06978; paper",
)
