"""baidu-ctr — the paper's own model (§2.1 Fig. 2): ~1e11-dim multi-hot
sparse input (~100 nnz/instance) -> 64-d embedding bags per field -> field
self-attention -> MLP.  The production table is 10 TB; here the full config
is terabyte-scale — 2e9 rows x 64 f32 = 512 GB table + 512 GB AdaGrad
accumulator ~= 1 TB of sparse state sharded over all 512 chips (2e9 keeps
row ids within int32, the JAX gather index type), exercised via the
dry-run; the smoke config is CPU-size.

Shapes follow the paper's §5 setup: mini-batches of ~1000 instances
(training), plus the online-inference path (predict-then-train).
"""

from repro.configs import ArchSpec, ShapeSpec
from repro.models.recsys import CTRConfig

MODEL = CTRConfig(
    name="baidu-ctr", rows=2_000_000_000, embed_dim=64, n_fields=40,
    nnz_per_instance=100, mlp=(512, 256, 1),
)

SMOKE = CTRConfig(
    name="baidu-ctr-smoke", rows=20_000, embed_dim=16, n_fields=8,
    nnz_per_instance=20, mlp=(32, 1), attn_heads=2,
)

SHAPES = {
    "train_mb1k": ShapeSpec("train_mb1k", "train", {"batch": 1024}),
    "train_mb8k": ShapeSpec("train_mb8k", "train", {"batch": 8192}),
    "serve_online": ShapeSpec("serve_online", "serve", {"batch": 1024}),
}

ARCH = ArchSpec(
    name="baidu-ctr", family="recsys", model_cfg=MODEL, smoke_cfg=SMOKE,
    shapes=SHAPES, source="the paper (Zhao et al. 2022)",
)
