"""llama4-scout-17b-16e [MoE LM] — 48L d5120 40H (GQA kv=8) dff8192
vocab202048, MoE 16 experts top-1 + shared expert, chunked local attention
(8192) with every-4th-layer global (iRoPE).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Chunked attention makes long_500k runnable (local layers attend within an
8k chunk; global layers use the full cache — sub-quadratic overall).
"""

import jax.numpy as jnp

from repro.configs import ArchSpec, lm_shapes
from repro.models.transformer import TransformerConfig

MODEL = TransformerConfig(
    name="llama4-scout-17b-16e",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202048, head_dim=128,
    attn_chunk=8192, global_every=4,
    n_experts=16, top_k=1, capacity_factor=1.25, shared_expert=True,
    router_aux_coef=0.01, rope_theta=5e5, dtype=jnp.bfloat16,
)

SMOKE = TransformerConfig(
    name="llama4-scout-smoke",
    n_layers=4, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=128, vocab=512, head_dim=16,
    attn_chunk=16, global_every=4,
    n_experts=4, top_k=1, shared_expert=True,
    router_aux_coef=0.01, dtype=jnp.float32, moe_group_size=64,
)

ARCH = ArchSpec(
    name="llama4-scout-17b-16e", family="lm", model_cfg=MODEL, smoke_cfg=SMOKE,
    shapes=lm_shapes(), source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
