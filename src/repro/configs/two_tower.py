"""two-tower-retrieval [recsys] — embed_dim=256, tower MLP 1024-512-256,
dot interaction, sampled softmax retrieval.  [RecSys'19 (YouTube); unverified]"""

from repro.configs import ArchSpec, recsys_shapes
from repro.models.recsys import TwoTowerConfig

MODEL = TwoTowerConfig(
    name="two-tower-retrieval", embed_dim=256,
    tower_mlp=(1024, 512, 256), user_hist_len=50, item_vocab=5_000_000,
)

SMOKE = TwoTowerConfig(
    name="two-tower-smoke", embed_dim=16,
    tower_mlp=(32, 16), user_hist_len=10, item_vocab=500,
)

ARCH = ArchSpec(
    name="two-tower-retrieval", family="recsys", model_cfg=MODEL,
    smoke_cfg=SMOKE, shapes=recsys_shapes(),
    source="RecSys'19 (YouTube); unverified",
)
