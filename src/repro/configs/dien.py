"""dien [recsys] — embed_dim=18, seq_len=100, GRU(108) + AUGRU interest
evolution, attn MLP 80-40 (hidden-space), MLP 200-80.
[arXiv:1809.03672; unverified]"""

from repro.configs import ArchSpec, recsys_shapes
from repro.models.recsys import DINConfig

MODEL = DINConfig(
    name="dien", embed_dim=18, seq_len=100,
    attn_mlp=(80, 40), mlp=(200, 80), item_vocab=2_000_000, gru_dim=108,
)

SMOKE = DINConfig(
    name="dien-smoke", embed_dim=8, seq_len=20,
    attn_mlp=(16, 8), mlp=(32, 16), item_vocab=500, gru_dim=12,
)

ARCH = ArchSpec(
    name="dien", family="recsys", model_cfg=MODEL, smoke_cfg=SMOKE,
    shapes=recsys_shapes(), source="arXiv:1809.03672; unverified",
)
