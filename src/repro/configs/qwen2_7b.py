"""qwen2-7b [dense LM] — 28L d3584 28H (GQA kv=4) dff18944 vocab152064,
GQA + QKV bias.  [arXiv:2407.10671; hf]"""

import dataclasses
import jax.numpy as jnp

from repro.configs import ArchSpec, lm_shapes
from repro.models.transformer import TransformerConfig

MODEL = TransformerConfig(
    name="qwen2-7b",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab=152064, head_dim=128,
    qkv_bias=True, rope_theta=1e6, dtype=jnp.bfloat16,
)

SMOKE = TransformerConfig(
    name="qwen2-7b-smoke",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab=512, head_dim=32,
    qkv_bias=True, dtype=jnp.float32, moe_group_size=128,
)

shapes = lm_shapes()
shapes["long_500k"] = dataclasses.replace(
    shapes["long_500k"],
    skip="pure full-attention arch: 500k decode requires sub-quadratic attention (DESIGN.md §5)",
)

ARCH = ArchSpec(
    name="qwen2-7b", family="lm", model_cfg=MODEL, smoke_cfg=SMOKE,
    shapes=shapes, source="arXiv:2407.10671; hf",
)
