"""Architecture registry: the 10 assigned archs + the paper's own model.

Each config module defines ``ARCH`` (an ArchSpec).  ``get(name)`` /
``list_archs()`` are the public lookup API used by --arch flags everywhere,
and the source of truth for ``repro.runtime.factory.build_trainer`` — the
config-driven path from an arch name + ``TrainerConfig`` to a ready
Dense/Hybrid trainer (models, embedding engine, and sparse placement wired
per family).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable, Dict, List, Optional

_MODULES = {
    "baidu-ctr": "repro.configs.baidu_ctr",
    "qwen3-14b": "repro.configs.qwen3_14b",
    "qwen2-7b": "repro.configs.qwen2_7b",
    "granite-8b": "repro.configs.granite_8b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "llama4-scout-17b-16e": "repro.configs.llama4_scout",
    "gin-tu": "repro.configs.gin_tu",
    "dien": "repro.configs.dien",
    "din": "repro.configs.din",
    "two-tower-retrieval": "repro.configs.two_tower",
    "dlrm-mlperf": "repro.configs.dlrm_mlperf",
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One (arch x input-shape) cell."""
    name: str
    kind: str                 # train | prefill | decode | serve | retrieval
    dims: Dict[str, int]
    skip: Optional[str] = None  # reason string if inapplicable (noted in DESIGN.md)


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    family: str               # lm | gnn | recsys
    model_cfg: Any            # full-size model config (dry-run only)
    smoke_cfg: Any            # reduced config (CPU tests / examples)
    shapes: Dict[str, ShapeSpec]
    source: str = ""          # provenance tag from the assignment


def get(name: str) -> ArchSpec:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).ARCH


def list_archs() -> List[str]:
    return sorted(_MODULES)


def lm_shapes() -> Dict[str, ShapeSpec]:
    return {
        "train_4k": ShapeSpec("train_4k", "train", {"seq": 4096, "batch": 256}),
        "prefill_32k": ShapeSpec("prefill_32k", "prefill", {"seq": 32768, "batch": 32}),
        "decode_32k": ShapeSpec("decode_32k", "decode", {"seq": 32768, "batch": 128}),
        "long_500k": ShapeSpec("long_500k", "decode", {"seq": 524288, "batch": 1}),
    }


def recsys_shapes() -> Dict[str, ShapeSpec]:
    return {
        "train_batch": ShapeSpec("train_batch", "train", {"batch": 65536}),
        "serve_p99": ShapeSpec("serve_p99", "serve", {"batch": 512}),
        "serve_bulk": ShapeSpec("serve_bulk", "serve", {"batch": 262144}),
        "retrieval_cand": ShapeSpec(
            "retrieval_cand", "retrieval", {"batch": 1, "n_candidates": 1_000_000}
        ),
    }
