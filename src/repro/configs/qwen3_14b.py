"""qwen3-14b [dense LM] — 40L d5120 40H (GQA kv=8) dff17408 vocab151936,
qk-norm, GQA.  [hf:Qwen/Qwen3-8B; hf]"""

import dataclasses
import jax.numpy as jnp

from repro.configs import ArchSpec, lm_shapes
from repro.models.transformer import TransformerConfig

MODEL = TransformerConfig(
    name="qwen3-14b",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=17408, vocab=151936, head_dim=128,
    qk_norm=True, rope_theta=1e6, dtype=jnp.bfloat16,
)

SMOKE = TransformerConfig(
    name="qwen3-14b-smoke",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=256, vocab=512, head_dim=16,
    qk_norm=True, rope_theta=1e6, dtype=jnp.float32, moe_group_size=128,
)

shapes = lm_shapes()
shapes["long_500k"] = dataclasses.replace(
    shapes["long_500k"],
    skip="pure full-attention arch: 500k decode requires sub-quadratic attention (DESIGN.md §5)",
)

ARCH = ArchSpec(
    name="qwen3-14b", family="lm", model_cfg=MODEL, smoke_cfg=SMOKE,
    shapes=shapes, source="hf:Qwen/Qwen3-8B; hf",
)
