"""gin-tu [GNN] — 5 layers, d_hidden=64, sum aggregator, learnable eps.
[arXiv:1810.00826; paper]

Four shape regimes: Cora-size full batch, Reddit-scale sampled minibatch
(real fanout-15/10 neighbor sampler), ogbn-products full batch, and batched
small molecule graphs with graph readout.
"""

import dataclasses
from typing import Dict

from repro.configs import ArchSpec, ShapeSpec
from repro.models.gin import GINConfig

MODEL = GINConfig(
    name="gin-tu", n_layers=5, d_hidden=64, d_in=1433, n_classes=7,
    train_eps=True,
)

SMOKE = GINConfig(
    name="gin-tu-smoke", n_layers=3, d_hidden=16, d_in=8, n_classes=3,
    train_eps=True,
)

SHAPES: Dict[str, ShapeSpec] = {
    "full_graph_sm": ShapeSpec(
        "full_graph_sm", "train",
        {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433, "n_classes": 7},
    ),
    "minibatch_lg": ShapeSpec(
        "minibatch_lg", "train",
        {"n_nodes": 232965, "n_edges": 114615892, "batch_nodes": 1024,
         "fanout0": 15, "fanout1": 10, "d_feat": 602, "n_classes": 41},
    ),
    "ogb_products": ShapeSpec(
        "ogb_products", "train",
        {"n_nodes": 2449029, "n_edges": 61859140, "d_feat": 100, "n_classes": 47},
    ),
    "molecule": ShapeSpec(
        "molecule", "train",
        {"n_nodes": 30, "n_edges": 64, "batch": 128, "d_feat": 16, "n_classes": 2},
    ),
}

ARCH = ArchSpec(
    name="gin-tu", family="gnn", model_cfg=MODEL, smoke_cfg=SMOKE,
    shapes=SHAPES, source="arXiv:1810.00826; paper",
)
