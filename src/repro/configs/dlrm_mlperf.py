"""dlrm-mlperf [recsys] — MLPerf DLRM benchmark config (Criteo 1TB):
13 dense + 26 sparse features, embed_dim=128, bot 13-512-256-128,
top 1024-1024-512-256-1, dot interaction.  [arXiv:1906.00091; paper]"""

from repro.configs import ArchSpec, recsys_shapes
from repro.models.recsys import CRITEO_ROWS, DLRMConfig

MODEL = DLRMConfig(
    name="dlrm-mlperf",
    n_dense=13, n_sparse=26, embed_dim=128,
    bot_mlp=(13, 512, 256, 128),
    top_mlp=(1024, 1024, 512, 256, 1),
    rows=tuple(CRITEO_ROWS),
)

SMOKE = DLRMConfig(
    name="dlrm-smoke",
    n_dense=13, n_sparse=26, embed_dim=16,
    bot_mlp=(13, 32, 16),
    top_mlp=(64, 32, 1),
    rows=tuple([200] * 26),
)

ARCH = ArchSpec(
    name="dlrm-mlperf", family="recsys", model_cfg=MODEL, smoke_cfg=SMOKE,
    shapes=recsys_shapes(), source="arXiv:1906.00091; paper",
)
