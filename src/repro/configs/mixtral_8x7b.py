"""mixtral-8x7b [MoE LM] — 32L d4096 32H (GQA kv=8) dff14336 vocab32000,
8 experts top-2, sliding-window attention (W=4096).  [arXiv:2401.04088; hf]

SWA makes long_500k runnable: the decode KV cache is a W-slot ring buffer.
"""

import jax.numpy as jnp

from repro.configs import ArchSpec, lm_shapes
from repro.models.transformer import TransformerConfig

MODEL = TransformerConfig(
    name="mixtral-8x7b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000, head_dim=128,
    attn_window=4096, n_experts=8, top_k=2, capacity_factor=1.25,
    router_aux_coef=0.01, rope_theta=1e6, dtype=jnp.bfloat16,
)

SMOKE = TransformerConfig(
    name="mixtral-8x7b-smoke",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab=512, head_dim=32,
    attn_window=16, n_experts=4, top_k=2,
    router_aux_coef=0.01, dtype=jnp.float32, moe_group_size=64,
)

ARCH = ArchSpec(
    name="mixtral-8x7b", family="lm", model_cfg=MODEL, smoke_cfg=SMOKE,
    shapes=lm_shapes(), source="arXiv:2401.04088; hf",
)
