"""granite-8b [dense LM] — 36L d4096 32H (GQA kv=8) dff14336 vocab49152,
llama-arch, code model.  [arXiv:2405.04324; hf]"""

import dataclasses
import jax.numpy as jnp

from repro.configs import ArchSpec, lm_shapes
from repro.models.transformer import TransformerConfig

MODEL = TransformerConfig(
    name="granite-8b",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=49152, head_dim=128,
    rope_theta=1e4, dtype=jnp.bfloat16,
)

SMOKE = TransformerConfig(
    name="granite-8b-smoke",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab=512, head_dim=32,
    rope_theta=1e4, dtype=jnp.float32, moe_group_size=128,
)

shapes = lm_shapes()
shapes["long_500k"] = dataclasses.replace(
    shapes["long_500k"],
    skip="pure full-attention arch: 500k decode requires sub-quadratic attention (DESIGN.md §5)",
)

ARCH = ArchSpec(
    name="granite-8b", family="lm", model_cfg=MODEL, smoke_cfg=SMOKE,
    shapes=shapes, source="arXiv:2405.04324; hf",
)
