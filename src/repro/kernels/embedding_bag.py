"""Fused embedding-bag kernel: out[b] = sum_{j: seg[j]==b} w[j] * working[inv[j]].

TPU adaptation of the FBGEMM-style table-batched embedding bag: the gather
runs over the *pulled working set* (which fits VMEM — that is the point of
the paper's working-set pull), fused with the segment reduction in one
kernel pass.  Two formulations share the wrapper:

- ``mxu`` (real-TPU default): the segment-sum is a one-hot matmul so it
  runs on the MXU instead of as a scatter (TPU has no fast scatter; a
  (bags x nnz) @ (nnz x dim) matmul is the idiomatic segment-sum).
  Accumulates in f32 on the MXU — numerically equivalent to, but not
  bit-identical with, the jnp segment-sum.
- ``exact`` (interpret default): in-kernel gather + drop-safe scatter-add
  into the bag block.  Adds values in exactly the order the XLA
  ``segment_sum`` oracle does, so it is bit-identical to the unfused bag —
  the formulation behind the fused-vs-unfused parity contract.

Block geometry is auto-selected and never constrained: the bag grid uses
``pl.cdiv`` (out-of-block segment ids are masked/dropped in-kernel), and
the nnz stream is padded to the block size with weights=0 / seg=OOB, so
arbitrary batch/capacity geometries work instead of tripping shape asserts.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bag_kernel_mxu(inv_ref, seg_ref, w_ref, working_ref, out_ref, *,
                    bag_block: int):
    i = pl.program_id(0)  # bag block
    j = pl.program_id(1)  # nnz block

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    inv = inv_ref[...]                      # (nnz_blk,)
    seg = seg_ref[...]                      # (nnz_blk,)
    w = w_ref[...]                          # (nnz_blk,)
    working = working_ref[...]              # (C, D) — whole working set in VMEM
    emb = jnp.take(working, inv, axis=0)    # (nnz_blk, D) VMEM gather
    emb = emb * w[:, None].astype(emb.dtype)
    # one-hot segment-sum on the MXU: (bag_blk, nnz_blk) @ (nnz_blk, D)
    local = seg - i * bag_block
    onehot = (
        local[None, :] == jax.lax.broadcasted_iota(jnp.int32, (bag_block, 1), 0)
    ).astype(emb.dtype)
    out_ref[...] += jax.lax.dot(
        onehot, emb, preferred_element_type=out_ref.dtype
    )


def _bag_kernel_exact(inv_ref, seg_ref, w_ref, working_ref, out_ref, *,
                      bag_block: int, weighted: bool):
    i = pl.program_id(0)  # bag block; the whole nnz stream is one block
    emb = jnp.take(working_ref[...], inv_ref[...], axis=0)
    if weighted:
        emb = emb * w_ref[...][:, None].astype(emb.dtype)
    local = seg_ref[...] - i * bag_block
    # Out-of-block locals (either direction — negative indices would WRAP in
    # jnp scatter) route to the OOB index bag_block and are dropped.
    safe = jnp.where((local >= 0) & (local < bag_block), local, bag_block)
    out_ref[...] = jnp.zeros_like(out_ref).at[safe].add(emb, mode="drop")


def _auto_block(n: int, target: int) -> int:
    return max(1, min(target, n))


@functools.partial(
    jax.jit,
    static_argnames=("num_bags", "bag_block", "nnz_block", "interpret", "exact"),
)
def embedding_bag_pallas(
    working: jnp.ndarray,   # (C, D) pulled rows
    inv: jnp.ndarray,       # (nnz,) row index into working
    seg: jnp.ndarray,       # (nnz,) bag index (any order)
    weights: jnp.ndarray,   # (nnz,) or None
    num_bags: int,
    bag_block: int = 256,
    nnz_block: int = 512,
    interpret: bool = False,
    exact: bool | None = None,
) -> jnp.ndarray:
    C, D = working.shape
    nnz = inv.shape[0]
    if exact is None:
        exact = interpret  # bit-exact formulation wherever bits are checked
    bag_block = _auto_block(num_bags, bag_block)
    n_bag_blocks = pl.cdiv(num_bags, bag_block)
    nbp = n_bag_blocks * bag_block
    weighted = weights is not None
    if weights is None:
        weights = jnp.ones((nnz,), working.dtype)

    if exact:
        out = pl.pallas_call(
            functools.partial(
                _bag_kernel_exact, bag_block=bag_block, weighted=weighted
            ),
            grid=(n_bag_blocks,),
            in_specs=[
                pl.BlockSpec((nnz,), lambda i: (0,)),
                pl.BlockSpec((nnz,), lambda i: (0,)),
                pl.BlockSpec((nnz,), lambda i: (0,)),
                pl.BlockSpec((C, D), lambda i: (0, 0)),
            ],
            out_specs=pl.BlockSpec((bag_block, D), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((nbp, D), working.dtype),
            interpret=interpret,
        )(inv, seg, weights, working)
        return out[:num_bags]

    # MXU formulation: pad the nnz stream so every block is full — padded
    # entries carry seg=nbp (matches no block-local index → zero one-hot
    # column) and weight 0.
    nnz_block = _auto_block(nnz, nnz_block)
    n_nnz_blocks = pl.cdiv(nnz, nnz_block)
    pad = n_nnz_blocks * nnz_block - nnz
    if pad:
        inv = jnp.pad(inv, (0, pad))
        seg = jnp.pad(seg, (0, pad), constant_values=nbp)
        weights = jnp.pad(weights, (0, pad))
    out = pl.pallas_call(
        functools.partial(_bag_kernel_mxu, bag_block=bag_block),
        grid=(n_bag_blocks, n_nnz_blocks),
        in_specs=[
            pl.BlockSpec((nnz_block,), lambda i, j: (j,)),
            pl.BlockSpec((nnz_block,), lambda i, j: (j,)),
            pl.BlockSpec((nnz_block,), lambda i, j: (j,)),
            pl.BlockSpec((C, D), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bag_block, D), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nbp, D), working.dtype),
        interpret=interpret,
    )(inv, seg, weights, working)
    return out[:num_bags]
