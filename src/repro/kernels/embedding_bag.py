"""Fused embedding-bag kernel: out[b] = sum_{j: seg[j]==b} w[j] * working[inv[j]].

TPU adaptation of the FBGEMM-style table-batched embedding bag: the gather
runs over the *pulled working set* (which fits VMEM — that is the point of
the paper's working-set pull), and the segment reduction is expressed as a
one-hot matmul so it runs on the MXU instead of as a scatter (TPU has no
fast scatter; a (bags x nnz) @ (nnz x dim) matmul is the idiomatic
segment-sum).

Grid: (n_bag_blocks, n_nnz_blocks); the output block index depends only on
the bag block, so nnz blocks accumulate into the same VMEM tile across the
sequential TPU grid (standard Pallas accumulation pattern).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bag_kernel(inv_ref, seg_ref, w_ref, working_ref, out_ref, *, bag_block: int):
    i = pl.program_id(0)  # bag block
    j = pl.program_id(1)  # nnz block

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    inv = inv_ref[...]                      # (nnz_blk,)
    seg = seg_ref[...]                      # (nnz_blk,)
    w = w_ref[...]                          # (nnz_blk,)
    working = working_ref[...]              # (C, D) — whole working set in VMEM
    emb = jnp.take(working, inv, axis=0)    # (nnz_blk, D) VMEM gather
    emb = emb * w[:, None].astype(emb.dtype)
    # one-hot segment-sum on the MXU: (bag_blk, nnz_blk) @ (nnz_blk, D)
    local = seg - i * bag_block
    onehot = (
        local[None, :] == jax.lax.broadcasted_iota(jnp.int32, (bag_block, 1), 0)
    ).astype(emb.dtype)
    out_ref[...] += jax.lax.dot(
        onehot, emb, preferred_element_type=out_ref.dtype
    )


@functools.partial(
    jax.jit, static_argnames=("num_bags", "bag_block", "nnz_block", "interpret")
)
def embedding_bag_pallas(
    working: jnp.ndarray,   # (C, D) pulled rows
    inv: jnp.ndarray,       # (nnz,) row index into working
    seg: jnp.ndarray,       # (nnz,) bag index (any order)
    weights: jnp.ndarray,   # (nnz,)
    num_bags: int,
    bag_block: int = 256,
    nnz_block: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    C, D = working.shape
    nnz = inv.shape[0]
    assert num_bags % bag_block == 0, (num_bags, bag_block)
    assert nnz % nnz_block == 0, (nnz, nnz_block)
    grid = (num_bags // bag_block, nnz // nnz_block)
    return pl.pallas_call(
        functools.partial(_bag_kernel, bag_block=bag_block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((nnz_block,), lambda i, j: (j,)),
            pl.BlockSpec((nnz_block,), lambda i, j: (j,)),
            pl.BlockSpec((nnz_block,), lambda i, j: (j,)),
            pl.BlockSpec((C, D), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bag_block, D), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((num_bags, D), working.dtype),
        interpret=interpret,
    )(inv, seg, weights, working)
