"""Fused local-Adam update kernel (the k-step *local* branch, Algorithm 2
lines 5-9): one pass over (p, g, m, v_local, v_hat) producing
(p', m', v_local') with no intermediate HBM round-trips.

The unfused XLA chain reads/writes each moment tensor several times; fusing
the whole element-wise chain makes the local step exactly memory-bound at
its lower bound (5 reads + 3 writes per element).  Grid over flat blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adam_kernel(p_ref, g_ref, m_ref, v_ref, vhat_ref, np_ref, nm_ref, nv_ref,
                 *, lr, b1, b2):
    g = g_ref[...].astype(jnp.float32)
    m = b1 * m_ref[...] + (1.0 - b1) * g
    v = b2 * v_ref[...] + (1.0 - b2) * g * g
    p = p_ref[...].astype(jnp.float32) - lr * m / jnp.sqrt(vhat_ref[...])
    np_ref[...] = p.astype(np_ref.dtype)
    nm_ref[...] = m
    nv_ref[...] = v


@functools.partial(
    jax.jit, static_argnames=("lr", "b1", "b2", "block", "interpret")
)
def fused_adam_pallas(
    p: jnp.ndarray, g: jnp.ndarray, m: jnp.ndarray, v: jnp.ndarray,
    v_hat: jnp.ndarray,
    lr: float = 1e-3, b1: float = 0.0, b2: float = 0.999,
    block: int = 65536, interpret: bool = False,
):
    """All inputs flat 1-D of equal length (callers ravel/unravel)."""
    n = p.shape[0]
    block = max(1, min(block, n))
    grid = (pl.cdiv(n, block),)  # uneven trailing block is masked by Pallas
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_adam_kernel, lr=lr, b1=b1, b2=b2),
        grid=grid,
        in_specs=[spec] * 5,
        out_specs=[spec] * 3,
        out_shape=[
            jax.ShapeDtypeStruct((n,), p.dtype),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(p, g, m, v, v_hat)
