"""Pallas TPU kernels for the framework's compute hot spots.

The paper's hot path is the sparse embedding layer (pull -> bag-reduce ->
push); on TPU that is a gather + segment-reduce, fused MXU-style (one-hot
matmul segment sum) in ``embedding_bag``.  ``dot_interaction`` fuses DLRM's
pairwise-dot feature cross; ``fused_adam`` and ``sparse_adagrad`` fuse the
optimizer element-wise chains.

Every kernel ships with a jit'd wrapper (ops.py) and a pure-jnp oracle
(ref.py); tests sweep shapes/dtypes in interpret mode (this container is
CPU-only — TPU is the compilation target).
"""
