"""Device linear-probe hash map: the O(cache_rows) id→slot index.

``CachedBackend`` used to carry a dense ``(table_rows,)`` int32 id→slot
array on device — the last O(table_rows) device allocation in the cache
tier.  This module replaces it with an open-addressing linear-probe hash
map sized O(cache_rows), carried through the jitted pull/push as three
small arrays:

  - ``key_tab``  (H,) int32 — the id stored in each bucket (-1 = EMPTY),
  - ``slot_tab`` (H,) int32 — the cache slot that id mapped to,
  - ``n_occupied`` ()  int32 — occupied buckets, *including stale ones*.

Liveness is checked against ``slot_uid`` instead of deleting: an entry
``(k, s)`` is live iff ``slot_uid[s] == k``.  Eviction overwrites
``slot_uid[s]`` with the admitted id, which kills the evicted id's entry
for free — no tombstones, no unlink pass.  Buckets therefore only go
EMPTY → occupied; probe chains never shrink between rebuilds, which is
exactly what makes bounded probing *exact*:

  - **lookup** probes from ``h(k)`` until it sees ``k`` (at most one
    bucket per key can hold it) or an EMPTY bucket (the chain end);
  - **insert** of a key claims the first EMPTY bucket on its chain — or
    *reuses* the key's own stale bucket, which must appear before any
    EMPTY bucket on the chain (it was placed at a first-EMPTY position
    and nothing empties);
  - **rebuild** (when stale entries pile up past the occupancy bound)
    re-inserts only the live ``(slot_uid[s], s)`` pairs into fresh
    buckets, restoring load ≤ cache_rows / H.

``hash_table_size`` keeps H ≥ 4·cache_rows, and ``CachedBackend``
rebuilds before occupancy can cross 3H/4, so every chain ends in an
EMPTY bucket and both loops terminate.

The batch *lookup* is the hot-path kernel (``hash_lookup_pallas``, one
probe loop per working-set id, parity-locked to ``ref.hash_lookup_ref``
— dispatch via ``ops.hash_lookup`` per docs/kernels.md).  The map
*maintenance* (insert / rebuild) is trace-level jnp shared verbatim by
every dispatch mode: the map contents are bit-identical whether lookups
run through Pallas, the interpreter, or the jnp reference.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

EMPTY = -1  # bucket key for never-occupied buckets


def hash_table_size(cache_rows: int) -> int:
    """Bucket count H for a cache of ``cache_rows`` slots: the next power
    of two ≥ 4·cache_rows (load factor ≤ 0.25 after every rebuild), so
    probe chains stay short and an EMPTY chain-terminator always exists."""
    n = max(int(cache_rows), 8) * 4
    return 1 << (n - 1).bit_length()


def hash_bucket(keys: jnp.ndarray, n_buckets: int) -> jnp.ndarray:
    """Home bucket per key: 32-bit murmur3 finalizer, masked to H-1.

    The mix is a bijection on uint32 (distinct ids never alias before the
    mask), computed in wrapping uint32 so no x64 widening enters the jit.
    """
    assert n_buckets & (n_buckets - 1) == 0, "n_buckets must be a power of 2"
    x = keys.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return (x & jnp.uint32(n_buckets - 1)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# maintenance (trace-level jnp — shared by every dispatch mode)
# ---------------------------------------------------------------------------

def hash_insert(key_tab, slot_tab, n_occupied, keys, slots, mask):
    """Batch-insert ``keys[i] -> slots[i]`` where ``mask[i]`` (keys under
    the mask are distinct and not live in the map).

    Round-based parallel probing: every pending key claims the first
    bucket on its chain that is EMPTY or already holds the key (a stale
    entry from a past residency — reused in place, so the map never holds
    two buckets for one key).  Conflicting claims on one EMPTY bucket are
    resolved by a deterministic scatter-max (highest key position wins);
    losers advance one probe and retry.  Terminates because every round
    either places a key or advances its probe toward an EMPTY bucket.
    """
    H = key_tab.shape[0]
    K = keys.shape[0]
    base = hash_bucket(keys, H)
    pos = jnp.arange(K, dtype=jnp.int32)

    def cond(carry):
        return jnp.any(carry[2])

    def body(carry):
        key_tab, slot_tab, pending, off, n_occ = carry
        b = (base + off) & (H - 1)
        kb = key_tab[b]
        reuse = pending & (kb == keys)           # own stale bucket: no conflict
        free = pending & (kb == EMPTY)
        winner = (
            jnp.full((H,), -1, jnp.int32)
            .at[jnp.where(free, b, H)]
            .max(pos, mode="drop")
        )
        won_free = free & (winner[b] == pos)
        won = reuse | won_free
        sink = jnp.where(won, b, H)
        key_tab = key_tab.at[sink].set(keys, mode="drop")
        slot_tab = slot_tab.at[sink].set(slots, mode="drop")
        n_occ = n_occ + jnp.sum(won_free.astype(jnp.int32))
        pending = pending & ~won
        off = jnp.where(pending, off + 1, off)
        return key_tab, slot_tab, pending, off, n_occ

    init = (key_tab, slot_tab, mask, jnp.zeros((K,), jnp.int32), n_occupied)
    key_tab, slot_tab, _, _, n_occupied = jax.lax.while_loop(cond, body, init)
    return key_tab, slot_tab, n_occupied


def hash_rebuild(slot_uid, n_buckets: int):
    """Fresh (key_tab, slot_tab, n_occupied) holding only the live
    ``(slot_uid[s], s)`` pairs — drops every stale entry in one shot."""
    C = slot_uid.shape[0]
    key_tab = jnp.full((n_buckets,), EMPTY, jnp.int32)
    slot_tab = jnp.zeros((n_buckets,), jnp.int32)
    return hash_insert(
        key_tab, slot_tab, jnp.zeros((), jnp.int32),
        slot_uid, jnp.arange(C, dtype=jnp.int32), slot_uid >= 0,
    )


# ---------------------------------------------------------------------------
# lookup kernel (the Pallas probe; jnp oracle lives in kernels/ref.py)
# ---------------------------------------------------------------------------

def _mix_scalar(u, hmask):
    x = u.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return (x & jnp.uint32(hmask)).astype(jnp.int32)


def _lookup_kernel(uids_ref, key_ref, slot_ref, suid_ref, out_ref, *, hmask):
    """One working-set id per grid step: probe from the home bucket until
    the key or an EMPTY bucket appears; a found entry resolves to its slot
    only if still live (``slot_uid[slot] == key``) — a stale hit is a miss
    and the probe stops (at most one bucket per key)."""
    u = uids_ref[pl.program_id(0)]
    base = _mix_scalar(u, hmask)

    def cond(carry):
        return carry[0] == 0

    def body(carry):
        _, off, slot = carry
        b = (base + off) & hmask
        kb = key_ref[b, 0]
        s = slot_ref[b, 0]
        live = (kb == u) & (suid_ref[s, 0] == u)
        done = (kb == u) | (kb == EMPTY)
        slot = jnp.where(live, s, slot)
        return done.astype(jnp.int32), off + 1, slot

    zero = jnp.zeros((), jnp.int32)
    _, _, slot = jax.lax.while_loop(
        cond, body, (zero, zero, jnp.full((), -1, jnp.int32))
    )
    out_ref[0, 0] = slot


@functools.partial(jax.jit, static_argnames=("interpret",))
def hash_lookup_pallas(key_tab, slot_tab, slot_uid, uids, interpret=False):
    """slots[i] = live slot of uids[i], or -1 — the Pallas probe whose
    output feeds the fused cached gather/scatter index streams."""
    H = key_tab.shape[0]
    K = uids.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=(K,),
        in_specs=[
            pl.BlockSpec((H, 1), lambda i, uids: (0, 0)),
            pl.BlockSpec((H, 1), lambda i, uids: (0, 0)),
            pl.BlockSpec((slot_uid.shape[0], 1), lambda i, uids: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, uids: (i, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_lookup_kernel, hmask=H - 1),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((K, 1), jnp.int32),
        interpret=interpret,
    )(uids, key_tab[:, None], slot_tab[:, None], slot_uid[:, None])
    return out[:, 0]
