"""Fused DLRM dot-interaction kernel: (B, F, D) -> (B, F*(F-1)/2).

Fuses the batched self-Gram ``z = feats @ feats^T`` with the lower-triangle
extraction so the full (B, F, F) Gram never round-trips through HBM — on a
65k batch with F=27 that saves 65536*27*27*4B ~ 191 MB of HBM traffic per
step each way.  Grid over batch blocks; each block keeps (Bb, F, D) and
(Bb, F, F) in VMEM (F is small for every recsys arch here).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _dot_kernel(feats_ref, idx_ref, out_ref):
    feats = feats_ref[...]                         # (Bb, F, D)
    z = jax.lax.dot_general(
        feats, feats,
        dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                              # (Bb, F, F)
    Bb, F, _ = z.shape
    flat = z.reshape(Bb, F * F)
    out_ref[...] = jnp.take(flat, idx_ref[...], axis=1).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("batch_block", "interpret"))
def dot_interaction_pallas(
    feats: jnp.ndarray,       # (B, F, D)
    batch_block: int = 1024,
    interpret: bool = False,
) -> jnp.ndarray:
    B, F, D = feats.shape
    batch_block = min(batch_block, B)
    assert B % batch_block == 0, (B, batch_block)
    li, lj = np.tril_indices(F, k=-1)
    n_out = len(li)
    idx = jnp.asarray(li * F + lj, jnp.int32)
    return pl.pallas_call(
        _dot_kernel,
        grid=(B // batch_block,),
        in_specs=[
            pl.BlockSpec((batch_block, F, D), lambda i: (i, 0, 0)),
            pl.BlockSpec((n_out,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((batch_block, n_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, n_out), feats.dtype),
        interpret=interpret,
    )(feats, idx)
