"""jit'd public wrappers for the Pallas kernels.

On TPU the Pallas path compiles natively; everywhere else (this CPU
container) the wrappers run the kernels in interpret mode when
``REPRO_KERNEL_INTERPRET=1`` (tests) or fall back to the jnp oracle —
so the framework is runnable on any backend while keeping the TPU kernel
as the deployment path.
"""

from __future__ import annotations

import os

import jax

from repro.kernels import ref
from repro.kernels.dot_interaction import dot_interaction_pallas
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.fused_adam import fused_adam_pallas
from repro.kernels.sparse_adagrad import sparse_adagrad_pallas


def _mode() -> str:
    if os.environ.get("REPRO_KERNEL_INTERPRET") == "1":
        return "interpret"
    if jax.default_backend() == "tpu":
        return "pallas"
    return "ref"


def embedding_bag(working, inv, seg, weights, num_bags, **kw):
    mode = _mode()
    if mode == "ref":
        return ref.embedding_bag_ref(working, inv, seg, weights, num_bags)
    return embedding_bag_pallas(
        working, inv, seg, weights, num_bags,
        interpret=(mode == "interpret"), **kw,
    )


def dot_interaction(feats, **kw):
    mode = _mode()
    if mode == "ref":
        return ref.dot_interaction_ref(feats)
    return dot_interaction_pallas(feats, interpret=(mode == "interpret"), **kw)


def fused_adam(p, g, m, v, v_hat, lr=1e-3, b1=0.0, b2=0.999, **kw):
    mode = _mode()
    if mode == "ref":
        return ref.fused_adam_ref(p, g, m, v, v_hat, lr, b1, b2)
    return fused_adam_pallas(
        p, g, m, v, v_hat, lr=lr, b1=b1, b2=b2,
        interpret=(mode == "interpret"), **kw,
    )


def sparse_adagrad(rows, accum, grads, lr=0.05, eps=1e-10, **kw):
    mode = _mode()
    if mode == "ref":
        return ref.sparse_adagrad_ref(rows, accum, grads, lr, eps)
    return sparse_adagrad_pallas(
        rows, accum, grads, lr=lr, eps=eps,
        interpret=(mode == "interpret"), **kw,
    )
