"""jit'd public wrappers for the Pallas kernels.

On TPU the Pallas path compiles natively; everywhere else (this CPU
container) the wrappers run the kernels in interpret mode when
``REPRO_KERNEL_INTERPRET=1`` (tests) or fall back to the jnp oracle —
so the framework is runnable on any backend while keeping the TPU kernel
as the deployment path.

``kernel_mode()`` is the dispatch truth ("pallas" / "interpret" / "ref");
``resolve_fused()`` maps the ``TrainerConfig.fused_kernels`` tri-state
(None = auto) to a bool: fused defaults ON only on a real TPU backend.
An *explicit* fused=True elsewhere still executes — through interpret
under ``REPRO_KERNEL_INTERPRET=1`` (how the parity suite checks bits) or
through the jnp reference otherwise (bit-identical by construction) — so
the config axis is portable across backends.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.dot_interaction import dot_interaction_pallas
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.fused_adam import fused_adam_pallas
from repro.kernels.hash_map import hash_lookup_pallas
from repro.kernels.sparse_adagrad import (
    adagrad_row_updates,
    gather_rows_cached_pallas,
    sparse_adagrad_apply_pallas,
    sparse_adagrad_cached_apply_pallas,
    sparse_adagrad_pallas,
)

_COMBINERS = ("sum", "mean", "sqrtn")


def kernel_mode() -> str:
    """How fused ops execute here: "pallas" | "interpret" | "ref"."""
    if os.environ.get("REPRO_KERNEL_INTERPRET") == "1":
        return "interpret"
    if jax.default_backend() == "tpu":
        return "pallas"
    return "ref"


_mode = kernel_mode  # internal alias, kept for existing callers


def fused_default() -> bool:
    """Auto policy for ``fused_kernels=None``: on only for real Pallas.

    Deliberately NOT keyed on REPRO_KERNEL_INTERPRET — the env var selects
    how an *explicitly requested* fused op executes, it must not flip the
    whole test suite onto emulated kernels.
    """
    return jax.default_backend() == "tpu"


def resolve_fused(flag) -> bool:
    """Map the TrainerConfig/--fused-kernels tri-state to a bool."""
    return fused_default() if flag is None else bool(flag)


def embedding_bag(working, inv, seg, weights, num_bags, **kw):
    mode = _mode()
    if mode == "ref":
        return ref.embedding_bag_ref(working, inv, seg, weights, num_bags)
    return embedding_bag_pallas(
        working, inv, seg, weights, num_bags,
        interpret=(mode == "interpret"), **kw,
    )


def embedding_bag_working(working, inv, seg, weights, num_bags,
                          combiner="sum"):
    """Differentiable fused gather+bag over the pulled working set.

    Forward: one kernel pass (gather + segment reduction); the combiner
    division stays outside, as the identical expression the unfused
    ``bag_from_working`` uses.  Backward: defined as the vjp of the
    unfused reference expression, so gradients match the unfused path's
    autodiff exactly — XLA DCEs the replayed forward, leaving only the
    transpose ops (gather of the bag cotangent, scatter-add into working).
    """
    if combiner not in _COMBINERS:
        raise ValueError(f"unknown combiner: {combiner!r}")
    mode = _mode()
    if mode == "ref":
        return ref.embedding_bag_combiner_ref(
            working, inv, seg, weights, num_bags, combiner)
    interpret = mode == "interpret"

    # inv/seg are primal args (NOT closed over — closures would leak tracers
    # under vmap/grad) with float0 cotangents, as integer inputs require.
    @jax.custom_vjp
    def bag(wk, inv_, seg_, w):
        out = embedding_bag_pallas(wk, inv_, seg_, w, num_bags,
                                   interpret=interpret)
        if combiner != "sum":
            denom = ref.bag_combiner_denom_ref(seg_, num_bags, combiner,
                                               wk.dtype)
            out = out / denom[:, None]
        return out

    def fwd(wk, inv_, seg_, w):
        return bag(wk, inv_, seg_, w), (wk, inv_, seg_, w)

    def bwd(res, g):
        wk, inv_, seg_, w = res
        _, vjp = jax.vjp(
            lambda wk_, w_: ref.embedding_bag_combiner_ref(
                wk_, inv_, seg_, w_, num_bags, combiner),
            wk, w,
        )
        g_wk, g_w = vjp(g)
        f0 = lambda x: np.zeros(x.shape, jax.dtypes.float0)
        return g_wk, f0(inv_), f0(seg_), g_w

    bag.defvjp(fwd, bwd)
    return bag(working, inv, seg, weights)


def dot_interaction(feats, **kw):
    mode = _mode()
    if mode == "ref":
        return ref.dot_interaction_ref(feats)
    return dot_interaction_pallas(feats, interpret=(mode == "interpret"), **kw)


def adam_defaults() -> tuple:
    """(b1, b2) single-sourced from the dense optimizer config (paper §5).

    Lazy import: kernels must stay importable without repro.core.
    """
    from repro.core.kstep import KStepConfig
    return (KStepConfig.b1, KStepConfig.b2)


def fused_adam(p, g, m, v, v_hat, lr=1e-3, b1=None, b2=None, **kw):
    if b1 is None or b2 is None:
        db1, db2 = adam_defaults()
        b1 = db1 if b1 is None else b1
        b2 = db2 if b2 is None else b2
    mode = _mode()
    if mode == "ref":
        return ref.fused_adam_ref(p, g, m, v, v_hat, lr, b1, b2)
    return fused_adam_pallas(
        p, g, m, v, v_hat, lr=lr, b1=b1, b2=b2,
        interpret=(mode == "interpret"), **kw,
    )


def sparse_adagrad(rows, accum, grads, lr=0.05, eps=1e-10, **kw):
    mode = _mode()
    if mode == "ref":
        return ref.sparse_adagrad_ref(rows, accum, grads, lr, eps)
    return sparse_adagrad_pallas(
        rows, accum, grads, lr=lr, eps=eps,
        interpret=(mode == "interpret"), **kw,
    )


def sparse_adagrad_apply(table, accum, uids, grads, *, lr, eps):
    """Fused push: AdaGrad row updates applied straight into the table.

    The row math runs once, outside, via :func:`adagrad_row_updates` (the
    same pinned helper the unfused ``SparseAdagrad.apply_rows`` uses), so
    the scatter — Pallas or jnp — receives identical (delta, g2) bits.
    """
    delta, g2 = adagrad_row_updates(accum[uids], grads, table.dtype,
                                    lr=lr, eps=eps)
    mode = _mode()
    if mode == "ref":
        return ref.sparse_adagrad_apply_ref(table, accum, uids, delta, g2)
    return sparse_adagrad_apply_pallas(
        table, accum, uids, delta, g2, interpret=(mode == "interpret"))


def hash_lookup(key_tab, slot_tab, slot_uid, uids):
    """Linear-probe id→slot lookup over the O(cache_rows) hash map.

    slots[i] = live cache slot of uids[i] (or -1).  Exact in every mode:
    the Pallas probe kernel and the jnp reference walk identical chains
    over identical map contents (map maintenance is shared trace-level
    jnp), so the dispatch mode can never change a hit into a miss.
    """
    mode = _mode()
    if mode == "ref":
        return ref.hash_lookup_ref(key_tab, slot_tab, slot_uid, uids)
    return hash_lookup_pallas(
        key_tab, slot_tab, slot_uid, uids, interpret=(mode == "interpret"))


def gather_rows_cached(cache_rows, slots):
    """Fused cached pull: out[i] = cache_rows[slots[i]], with the
    hash-probe output as the kernel's index stream."""
    mode = _mode()
    if mode == "ref":
        return ref.gather_rows_cached_ref(cache_rows, slots)
    return gather_rows_cached_pallas(
        cache_rows, slots, interpret=(mode == "interpret"))


def sparse_adagrad_cached_apply(cache_rows, cache_accum, slots, grads,
                                *, lr, eps):
    """Fused cached push: the hash-probe id→slot output drives the
    scatter's scalar-prefetch index stream directly."""
    accum_rows = gather_rows_cached(cache_accum, slots)
    delta, g2 = adagrad_row_updates(accum_rows, grads, cache_rows.dtype,
                                    lr=lr, eps=eps)
    mode = _mode()
    if mode == "ref":
        return ref.sparse_adagrad_apply_ref(
            cache_rows, cache_accum, slots, delta, g2)
    return sparse_adagrad_cached_apply_pallas(
        cache_rows, cache_accum, slots, delta, g2,
        interpret=(mode == "interpret"))
