"""Pure-jnp oracles for every Pallas kernel (the correctness contracts)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def embedding_bag_ref(working, inv, seg, weights, num_bags):
    emb = jnp.take(working, inv, axis=0)
    if weights is not None:
        emb = emb * weights[:, None].astype(working.dtype)
    return jax.ops.segment_sum(emb, seg, num_segments=num_bags)


def bag_combiner_denom_ref(seg, num_bags, combiner, dtype):
    """Per-bag divisor for mean/sqrtn — the SAME expression on the fused and
    unfused paths (the division stays outside the kernel either way)."""
    cnt = jax.ops.segment_sum(
        jnp.ones_like(seg, dtype), seg, num_segments=num_bags
    )
    denom = jnp.maximum(cnt, 1.0)
    if combiner == "sqrtn":
        denom = jnp.sqrt(denom)
    return denom


def embedding_bag_combiner_ref(working, inv, seg, weights, num_bags, combiner):
    out = embedding_bag_ref(working, inv, seg, weights, num_bags)
    if combiner == "sum":
        return out
    if combiner not in ("mean", "sqrtn"):
        raise ValueError(f"unknown combiner: {combiner!r}")
    denom = bag_combiner_denom_ref(seg, num_bags, combiner, working.dtype)
    return out / denom[:, None]


def sparse_adagrad_apply_ref(table, accum, uids, delta, g2):
    """Scatter the precomputed (delta, g2) row updates — the unfused push."""
    return table.at[uids].add(delta), accum.at[uids].add(g2)


def gather_rows_cached_ref(cache_rows, slots):
    return jnp.take(cache_rows, slots, axis=0)


def hash_lookup_ref(key_tab, slot_tab, slot_uid, uids):
    """Batch linear-probe lookup, the oracle for ``hash_lookup_pallas``.

    slots[i] = the live cache slot of uids[i] (an entry ``(k, s)`` is live
    iff ``slot_uid[s] == k``), or -1.  Vectorized over the batch: one
    while_loop advances every still-probing id one bucket per round until
    each has seen its key (at most one bucket holds it) or an EMPTY
    chain-terminator.  Terminates because the map keeps occupancy < H.
    """
    from repro.kernels.hash_map import EMPTY, hash_bucket

    H = key_tab.shape[0]
    base = hash_bucket(uids, H)
    K = uids.shape[0]

    def cond(carry):
        return jnp.any(carry[0])

    def body(carry):
        active, off, slot = carry
        b = (base + off) & (H - 1)
        kb = key_tab[b]
        s = slot_tab[b]
        found = active & (kb == uids)
        live = found & (slot_uid[s] == uids)
        slot = jnp.where(live, s, slot)
        active = active & ~found & (kb != EMPTY)
        off = jnp.where(active, off + 1, off)
        return active, off, slot

    _, _, slot = jax.lax.while_loop(
        cond, body,
        (jnp.ones((K,), bool), jnp.zeros((K,), jnp.int32),
         jnp.full((K,), -1, jnp.int32)),
    )
    return slot


def dot_interaction_ref(feats):
    B, F, D = feats.shape
    z = jnp.einsum("bfd,bgd->bfg", feats.astype(jnp.float32), feats.astype(jnp.float32))
    li, lj = np.tril_indices(F, k=-1)
    return z[:, li, lj].astype(feats.dtype)


def fused_adam_ref(p, g, m, v, v_hat, lr=1e-3, b1=0.0, b2=0.999):
    g32 = g.astype(jnp.float32)
    m_new = b1 * m + (1 - b1) * g32
    v_new = b2 * v + (1 - b2) * g32 * g32
    p_new = (p.astype(jnp.float32) - lr * m_new / jnp.sqrt(v_hat)).astype(p.dtype)
    return p_new, m_new, v_new


def sparse_adagrad_ref(rows, accum, grads, lr=0.05, eps=1e-10):
    g = grads.astype(jnp.float32)
    a = accum + g * g
    w = (rows.astype(jnp.float32) - lr * g / (jnp.sqrt(a) + eps)).astype(rows.dtype)
    return w, a
