"""Fused working-set sparse-AdaGrad kernels (the PS "push" math, paper §5).

Two layers:

``sparse_adagrad_pallas`` operates on a dense pulled row block: given
(rows, accum, grads) of the working set it produces updated rows and
accumulators in one fused element-wise pass —
``a' = a + g^2;  w' = w - lr * g / (sqrt(a') + eps)``.  Grid over row
blocks (uneven trailing blocks are masked by Pallas, so any (C, D)
geometry works).

``sparse_adagrad_apply_pallas`` is the *scatter* push used by the real
hot path: it applies per-row (delta, g2) updates directly into the full
(rows, dim) table/accumulator via scalar-prefetched row indices, aliasing
the table and accumulator buffers so no intermediate updated-rows array is
materialized.  The AdaGrad arithmetic itself is computed ONCE outside the
kernel by :func:`adagrad_row_updates` (shared with the unfused
``SparseAdagrad.apply_rows``) and the kernel body is pure data movement
(``add`` of two loads) — that is what makes the fused push bit-identical
to the unfused scatter on every backend: LLVM/XLA cannot re-contract a
mul+add into an FMA when the kernel never sees the mul.

The grid walks the working set in REVERSE: ``pull_working_set`` pads
``uids`` with copies of the minimum real id at the END of the vector, so
reversed order makes the pad rows (zero grads → bit-preserving writes)
execute first and the single real visit to the duplicated row last —
safe against stale-read/overwrite races when the TPU pipeline revisits
the same table row.

``sparse_adagrad_cached_apply_pallas`` / ``gather_rows_cached_pallas``
are the cache-tier variants: the id→slot hash-probe output
(``kernels.hash_map.hash_lookup_pallas``) is the kernel's scalar-prefetch
index stream (``row = slots[i]``), so the cached pull/push do one indexed
pass over the (slots, dim) cache instead of materializing slot-translated
row gathers around the kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def adagrad_row_updates(accum_rows, grads, table_dtype, *, lr, eps):
    """The AdaGrad row math, pinned against FMA re-association.

    Returns ``(delta, g2)`` with ``delta = -lr * g / (sqrt(a + g^2) + eps)``
    cast to the table dtype.  The two ``optimization_barrier``s force g2 and
    delta to materialize exactly once, so the *same* bits feed both the
    unfused ``.at[].add`` scatter and the fused Pallas apply — without them
    XLA fuses the delta computation into the scatter and single-rounds it
    (recip+FMA), breaking fused-vs-unfused bit identity.
    """
    g = grads.astype(jnp.float32)
    g2 = jax.lax.optimization_barrier(jnp.square(g))
    a_new = accum_rows + g2
    delta = -lr * g / (jnp.sqrt(a_new) + eps)
    delta = jax.lax.optimization_barrier(delta.astype(table_dtype))
    return delta, g2


def _adagrad_kernel(w_ref, a_ref, g_ref, nw_ref, na_ref, *, lr, eps):
    g = g_ref[...].astype(jnp.float32)
    a = a_ref[...] + g * g
    w = w_ref[...].astype(jnp.float32) - lr * g / (jnp.sqrt(a) + eps)
    nw_ref[...] = w.astype(nw_ref.dtype)
    na_ref[...] = a


@functools.partial(
    jax.jit, static_argnames=("lr", "eps", "row_block", "interpret")
)
def sparse_adagrad_pallas(
    rows: jnp.ndarray,    # (C, D) pulled table rows
    accum: jnp.ndarray,   # (C, D) f32
    grads: jnp.ndarray,   # (C, D)
    lr: float = 0.05, eps: float = 1e-10,
    row_block: int = 512, interpret: bool = False,
):
    C, D = rows.shape
    # Any geometry: cdiv grid, Pallas masks the uneven trailing block.
    row_block = max(1, min(row_block, C))
    spec = pl.BlockSpec((row_block, D), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_adagrad_kernel, lr=lr, eps=eps),
        grid=(pl.cdiv(C, row_block),),
        in_specs=[spec] * 3,
        out_specs=[spec] * 2,
        out_shape=[
            jax.ShapeDtypeStruct((C, D), rows.dtype),
            jax.ShapeDtypeStruct((C, D), jnp.float32),
        ],
        interpret=interpret,
    )(rows, accum, grads)


def _apply_kernel(uids_ref, t_ref, a_ref, d_ref, g2_ref, nt_ref, na_ref):
    # Pure data movement: both adds combine two LOADS (delta/g2 precomputed
    # outside) — contraction-proof, hence bit-identical to the jnp scatter.
    nt_ref[...] = t_ref[...] + d_ref[...]
    na_ref[...] = a_ref[...] + g2_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def sparse_adagrad_apply_pallas(
    table: jnp.ndarray,   # (R, D) full table
    accum: jnp.ndarray,   # (R, D) f32 accumulator
    uids: jnp.ndarray,    # (cap,) row ids, pads (= min real id) at the END
    delta: jnp.ndarray,   # (cap, D) table-dtype update, from adagrad_row_updates
    g2: jnp.ndarray,      # (cap, D) f32 squared grads
    interpret: bool = False,
):
    cap = uids.shape[0]
    D = table.shape[1]
    row = lambda i, uids: (uids[cap - 1 - i], 0)     # reversed: pads first
    seq = lambda i, uids: (cap - 1 - i, 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=(cap,),
        in_specs=[pl.BlockSpec((1, D), row), pl.BlockSpec((1, D), row),
                  pl.BlockSpec((1, D), seq), pl.BlockSpec((1, D), seq)],
        out_specs=[pl.BlockSpec((1, D), row), pl.BlockSpec((1, D), row)],
    )
    return pl.pallas_call(
        _apply_kernel, grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(table.shape, table.dtype),
                   jax.ShapeDtypeStruct(accum.shape, jnp.float32)],
        # alias indices count the scalar-prefetch arg: uids=0, table=1, accum=2
        input_output_aliases={1: 0, 2: 1},
        interpret=interpret,
    )(uids, table, accum, delta, g2)


def _cached_apply_kernel(slots_ref, t_ref, a_ref, d_ref, g2_ref,
                         nt_ref, na_ref):
    nt_ref[...] = t_ref[...] + d_ref[...]
    na_ref[...] = a_ref[...] + g2_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def sparse_adagrad_cached_apply_pallas(
    cache_rows: jnp.ndarray,   # (slots, D) device cache
    cache_accum: jnp.ndarray,  # (slots, D) f32
    slots: jnp.ndarray,        # (cap,) cache slot per working-set id — the
                               # hash-probe output; pad ids share the first
                               # real id's slot and carry zero delta/g2
    delta: jnp.ndarray,        # (cap, D)
    g2: jnp.ndarray,           # (cap, D)
    interpret: bool = False,
):
    cap = slots.shape[0]
    D = cache_rows.shape[1]
    # The hash-probe lookup output IS the index stream: one indexed pass
    # over the cache, no slot-translated gather materialized.
    row = lambda i, slots: (slots[cap - 1 - i], 0)
    seq = lambda i, slots: (cap - 1 - i, 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=(cap,),
        in_specs=[pl.BlockSpec((1, D), row), pl.BlockSpec((1, D), row),
                  pl.BlockSpec((1, D), seq), pl.BlockSpec((1, D), seq)],
        out_specs=[pl.BlockSpec((1, D), row), pl.BlockSpec((1, D), row)],
    )
    return pl.pallas_call(
        _cached_apply_kernel, grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(cache_rows.shape, cache_rows.dtype),
                   jax.ShapeDtypeStruct(cache_accum.shape, jnp.float32)],
        input_output_aliases={1: 0, 2: 1},
        interpret=interpret,
    )(slots, cache_rows, cache_accum, delta, g2)


def _gather_cached_kernel(slots_ref, rows_ref, out_ref):
    out_ref[...] = rows_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_rows_cached_pallas(
    cache_rows: jnp.ndarray,  # (slots, D)
    slots: jnp.ndarray,       # (cap,) cache slot per working-set id
    interpret: bool = False,
):
    """out[i] = cache_rows[slots[i]] — the fused cached pull, indexed by
    the hash-probe output stream."""
    cap = slots.shape[0]
    D = cache_rows.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=(cap,),
        in_specs=[pl.BlockSpec((1, D), lambda i, slots: (slots[i], 0))],
        out_specs=pl.BlockSpec((1, D), lambda i, slots: (i, 0)),
    )
    return pl.pallas_call(
        _gather_cached_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((cap, D), cache_rows.dtype),
        interpret=interpret,
    )(slots, cache_rows)
