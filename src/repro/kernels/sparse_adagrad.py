"""Fused working-set sparse-AdaGrad kernel (the PS "push" math, paper §5).

Operates on the pulled row block: given (rows, accum, grads) of the working
set, produces updated rows and accumulators in one fused pass —
``a' = a + g^2;  w' = w - lr * g / (sqrt(a') + eps)``.  The scatter back
into the sharded table stays outside (XLA's partitioned scatter); the
kernel removes the 4-pass element-wise chain XLA would otherwise emit over
the (capacity, dim) block.  Grid over row blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adagrad_kernel(w_ref, a_ref, g_ref, nw_ref, na_ref, *, lr, eps):
    g = g_ref[...].astype(jnp.float32)
    a = a_ref[...] + g * g
    w = w_ref[...].astype(jnp.float32) - lr * g / (jnp.sqrt(a) + eps)
    nw_ref[...] = w.astype(nw_ref.dtype)
    na_ref[...] = a


@functools.partial(
    jax.jit, static_argnames=("lr", "eps", "row_block", "interpret")
)
def sparse_adagrad_pallas(
    rows: jnp.ndarray,    # (C, D) pulled table rows
    accum: jnp.ndarray,   # (C, D) f32
    grads: jnp.ndarray,   # (C, D)
    lr: float = 0.05, eps: float = 1e-10,
    row_block: int = 512, interpret: bool = False,
):
    C, D = rows.shape
    row_block = min(row_block, C)
    assert C % row_block == 0, (C, row_block)
    spec = pl.BlockSpec((row_block, D), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_adagrad_kernel, lr=lr, eps=eps),
        grid=(C // row_block,),
        in_specs=[spec] * 3,
        out_specs=[spec] * 2,
        out_shape=[
            jax.ShapeDtypeStruct((C, D), rows.dtype),
            jax.ShapeDtypeStruct((C, D), jnp.float32),
        ],
        interpret=interpret,
    )(rows, accum, grads)
