"""Pallas TPU flash attention (forward) — the §Perf-identified next lever
for the LM training cells: the pure-JAX attention materializes the f32
score tile chain through HBM (~40% of the qwen3 fsdp_seq memory term);
this kernel keeps the (block_q x block_kv) tile resident in VMEM with the
online-softmax recurrence, so HBM traffic drops to Q/K/V/O once each.

Grid: (batch*kv_head*group, n_q_blocks, n_kv_blocks) — the kv-block axis is
innermost (sequential on TPU), accumulating into the same VMEM output tile
with running max/denominator carried in scratch.  Causal masking uses the
absolute block offsets.  GQA is handled by the caller reshaping q to
(B*Kv*G, S, hd) against k/v (B*Kv, S, hd) broadcast over G.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, scale, block_q, block_kv, causal):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                  # (bkv, hd)
    s = q @ k.T                                       # (bq, bkv)
    if causal:
        q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kv_pos = kj * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kv_pos <= q_pos, s, -1e30)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    v = v_ref[0].astype(jnp.float32)
    acc_scr[...] = acc_scr[...] * corr + p @ v
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kj == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_kv", "interpret"),
)
def flash_attention_pallas(
    q: jnp.ndarray,    # (BH, Sq, hd)  BH = batch*heads (GQA pre-flattened)
    k: jnp.ndarray,    # (BH, Skv, hd)
    v: jnp.ndarray,    # (BH, Skv, hd)
    causal: bool = True,
    block_q: int = 512,
    block_kv: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    BH, Sq, hd = q.shape
    Skv = k.shape[1]
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    assert Sq % block_q == 0 and Skv % block_kv == 0, (Sq, Skv, block_q, block_kv)
    grid = (BH, Sq // block_q, Skv // block_kv)
    scale = 1.0 / (hd ** 0.5)
    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, block_q=block_q,
                          block_kv=block_kv, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        # running max / denominator / accumulator live in VMEM scratch across
        # the sequential kv-block grid axis
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
