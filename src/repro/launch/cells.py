"""Cell builders: for every (arch x input-shape) pair, construct the jitted
step functions with abstract inputs (ShapeDtypeStruct — never allocated) and
their shardings on a given mesh.  Used by the dry-run, the roofline
derivation, and the launcher.

Train cells produce TWO steps — ``train_local`` (the hot k-1 steps, no
cross-pod traffic) and ``train_merge`` (the k-th step carrying the paper's
model-merge collectives) — so per-step cost is reported as
local + merge/k, with the merge bytes visible in isolation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ArchSpec, ShapeSpec, get as get_arch
from repro.core.embedding_engine import pull_working_set
from repro.core.kstep import KStepAdam, KStepConfig
from repro.core.sparse_optim import SparseAdagrad, SparseAdagradConfig
from repro.data.graph_sampler import NeighborSampler
from repro.models import gin as gin_lib
from repro.models import recsys as rec
from repro.models import transformer as tfm
from repro.models.common import sharding_ctx
from repro.sharding.specs import (
    auto_param_specs,
    batch_specs,
    lm_param_specs,
    table_specs_sharding,
)

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass
class StepDef:
    name: str
    fn: Callable
    args: Tuple                    # abstract argument trees (SDS leaves)
    in_specs: Tuple                # PartitionSpec trees matching args
    donate: Tuple[int, ...] = ()
    model_flops: float = 0.0       # useful-FLOPs estimate for this step
    weight: float = 1.0            # contribution to per-step cost (1/k for merge)


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str
    steps: Dict[str, StepDef]
    skip: Optional[str] = None


def _abstract(tree):
    return jax.tree.map(lambda x: SDS(x.shape, x.dtype), tree)


def _pod_abstract(tree, n_pod: int):
    return jax.tree.map(lambda x: SDS((n_pod,) + tuple(x.shape), x.dtype), tree)


def _spec_pref(spec_tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )


def mesh_pods(mesh) -> int:
    return mesh.shape["pod"] if "pod" in mesh.axis_names else 1


def shard1d(n: int, mesh, prefs=(("pod", "data", "model"), ("pod", "data"),
                                 ("data", "model"), ("data",), ("model",))):
    """Largest preferred axis combo that divides n (None if none do)."""
    for axes in prefs:
        kept = tuple(a for a in axes if a in mesh.axis_names)
        if not kept:
            continue
        size = int(np.prod([mesh.shape[a] for a in kept]))
        if n % size == 0 and n >= size:
            return kept
    return None


def data_ways(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


# ===================================================================== LM
def _lm_train_steps(arch: ArchSpec, shape: ShapeSpec, mesh, kcfg: KStepConfig,
                    style: str = "tp_fsdp"):
    cfg = arch.model_cfg
    if style == "fsdp_seq":
        cfg = dataclasses.replace(cfg, seq_shard=True)
    n_pod = mesh_pods(mesh)
    B, S = shape.dims["batch"], shape.dims["seq"]
    params_a = jax.eval_shape(lambda: tfm.init_params(jax.random.key(0), cfg))
    inner_specs = lm_param_specs(params_a, mesh, podded=False, style=style)
    opt = KStepAdam(kcfg, n_pod, mesh=mesh, param_specs=inner_specs)
    params_pod = _pod_abstract(params_a, n_pod)
    state_a = jax.eval_shape(opt.init, params_pod)
    batch_a = {
        "tokens": SDS((n_pod, B // n_pod, S), jnp.int32),
        "labels": SDS((n_pod, B // n_pod, S), jnp.int32),
    }

    p_specs = lm_param_specs(params_a, mesh, podded=True, style=style)
    state_specs = type(state_a)(
        step=P(), m=p_specs, v_local=p_specs, v_hat=p_specs,
        ef=p_specs if state_a.ef is not None else None,
    )
    pod_e = "pod" if "pod" in mesh.axis_names else None
    seq_e = "model" if style == "fsdp_seq" else None
    batch_sp = {
        "tokens": P(pod_e, "data", seq_e),
        "labels": P(pod_e, "data", seq_e),
    }

    def make(merge: bool):
        def step(params, batch, opt_state):
            with sharding_ctx(mesh):
                def total_loss(p):
                    losses = jax.vmap(lambda pi, bi: tfm.loss_fn(pi, bi, cfg))(p, batch)
                    return jnp.sum(losses)
                grads = jax.grad(total_loss)(params)
                # pin gradients to the parameter layout so cross-replica
                # reductions lower to reduce-scatter, not all-reduce+slice
                gflat, gdef = jax.tree_util.tree_flatten(grads)
                sflat = jax.tree_util.tree_flatten(
                    p_specs, is_leaf=lambda s: isinstance(s, P))[0]
                grads = jax.tree_util.tree_unflatten(gdef, [
                    jax.lax.with_sharding_constraint(g, NamedSharding(mesh, s))
                    for g, s in zip(gflat, sflat)
                ])
                new_p, new_s = opt.step(params, grads, opt_state, merge=merge)
            return new_p, new_s
        return step

    if style == "fsdp_seq" and "pod" in mesh.axis_names:
        # The pod axis must carry ONLY merge traffic, but GSPMD's batched-dot
        # partitioning replicates the vmapped pod dim of FSDP weights across
        # DCN (measured: ~340 GB/step of spurious pod-crossing gathers).
        # Structural fix: make 'pod' a MANUAL shard_map axis — each pod is a
        # genuinely separate worker (the paper's architecture) and the merge
        # is an explicit lax.pmean('pod').
        opt_m = KStepAdam(kcfg, 1, mesh=mesh, manual_pod=True)

        def leafspec_nopod(s):
            return P(*s)  # inner spec, leading local pod dim handled by shard_map

        inner_nopod = jax.tree_util.tree_flatten(
            inner_specs, is_leaf=lambda s: isinstance(s, P))[0]

        def make_sm(merge: bool):
            def body(params, batch, opt_state):
                with sharding_ctx(mesh, exclude=("pod",)):
                    def total_loss(p):
                        losses = jax.vmap(
                            lambda pi, bi: tfm.loss_fn(pi, bi, cfg))(p, batch)
                        return jnp.sum(losses)
                    grads = jax.grad(total_loss)(params)
                    gflat, gdef = jax.tree_util.tree_flatten(grads)
                    grads = jax.tree_util.tree_unflatten(gdef, [
                        jax.lax.with_sharding_constraint(
                            g, NamedSharding(mesh, P(None, *s)))
                        for g, s in zip(gflat, inner_nopod)
                    ])
                    new_p, new_s = opt_m.step(params, grads, opt_state, merge=merge)
                return new_p, new_s

            p_sm = jax.tree.map(lambda _: P("pod"), params_pod)
            st_sm = type(state_a)(
                step=P(), m=p_sm, v_local=p_sm, v_hat=p_sm,
                ef=p_sm if state_a.ef is not None else None,
            )
            b_sm = {"tokens": P("pod"), "labels": P("pod")}
            return jax.shard_map(
                body, mesh=mesh,
                in_specs=(p_sm, b_sm, st_sm),
                out_specs=(p_sm, st_sm),
                axis_names=frozenset({"pod"}),   # pod manual; data/model auto
                check_vma=False,
            )

        flops = 6.0 * cfg.active_params() * B * S
        return {
            "train_local": StepDef(
                "train_local", make_sm(False), (params_pod, batch_a, state_a),
                (p_specs, batch_sp, state_specs), donate=(0, 2),
                model_flops=flops, weight=(kcfg.k - 1) / kcfg.k,
            ),
            "train_merge": StepDef(
                "train_merge", make_sm(True), (params_pod, batch_a, state_a),
                (p_specs, batch_sp, state_specs), donate=(0, 2),
                model_flops=flops, weight=1.0 / kcfg.k,
            ),
        }

    flops = 6.0 * cfg.active_params() * B * S  # fwd+bwd ~ 3x fwd(2ND)
    return {
        "train_local": StepDef(
            "train_local", make(False), (params_pod, batch_a, state_a),
            (p_specs, batch_sp, state_specs), donate=(0, 2),
            model_flops=flops, weight=(kcfg.k - 1) / kcfg.k,
        ),
        "train_merge": StepDef(
            "train_merge", make(True), (params_pod, batch_a, state_a),
            (p_specs, batch_sp, state_specs), donate=(0, 2),
            model_flops=flops, weight=1.0 / kcfg.k,
        ),
    }


def _lm_cache_spec(cfg, B, Skv, mesh):
    """KV cache spec: batch over the data axes and cache LENGTH over 'model'.

    Sharding S (not heads/head-dim) means attention against the cache is a
    flash-decode pattern under GSPMD: each model shard scores its S-slice
    and the softmax/PV reductions cross shards as tiny per-token psums — no
    per-step cache all-gather.  B=1 long-context shards S over everything.
    """
    d_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    n_data = data_ways(mesh)
    if B % n_data == 0 and B >= n_data:
        kv_spec = P(None, d_axes, "model", None, None)
        pos_spec = P("model")
    else:
        all_ax = d_axes + ("model",)
        kv_spec = P(None, None, all_ax, None, None)
        pos_spec = P(all_ax)
    return {"k": kv_spec, "v": kv_spec, "pos": pos_spec, "t": P()}


def _lm_serve_steps(arch: ArchSpec, shape: ShapeSpec, mesh):
    cfg = arch.model_cfg
    B, S = shape.dims["batch"], shape.dims["seq"]
    params_a = jax.eval_shape(lambda: tfm.init_params(jax.random.key(0), cfg))
    p_specs = lm_param_specs(params_a, mesh, podded=False, serve=True)
    d_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)

    if shape.kind == "prefill":
        batch_a = SDS((B, S), jnp.int32)

        def step(params, tokens):
            with sharding_ctx(mesh):
                return tfm.prefill(params, tokens, cfg)

        flops = 2.0 * cfg.active_params() * B * S
        return {"serve_prefill": StepDef(
            "serve_prefill", step, (params_a, batch_a),
            (p_specs, P(d_axes, None)), model_flops=flops,
        )}

    # decode: one new token against a seq_len cache
    Skv = tfm.cache_len(cfg, S)
    cache_a = jax.eval_shape(lambda: tfm.init_cache(cfg, B, S))
    cache_sp = _lm_cache_spec(cfg, B, Skv, mesh)
    tok_a = SDS((B,), jnp.int32)
    tok_sp = P(d_axes) if B % data_ways(mesh) == 0 and B >= data_ways(mesh) else P(None)

    def step(params, cache, tokens):
        with sharding_ctx(mesh):
            return tfm.decode_step(params, cache, tokens, cfg)

    flops = 2.0 * cfg.active_params() * B  # one token per slot
    return {"serve_decode": StepDef(
        "serve_decode", step, (params_a, cache_a, tok_a),
        (p_specs, cache_sp, tok_sp), donate=(1,), model_flops=flops,
    )}


# ==================================================================== GNN
def _gin_batch(shape: ShapeSpec, cfg, n_pod: int):
    d = shape.dims
    if shape.name == "molecule":
        B = d["batch"]
        N, E = B * d["n_nodes"], B * d["n_edges"]
        b = {
            "x": SDS((n_pod, N, d["d_feat"]), jnp.float32),
            "edge_src": SDS((n_pod, E), jnp.int32),
            "edge_dst": SDS((n_pod, E), jnp.int32),
            "graph_ids": SDS((n_pod, N), jnp.int32),
            "labels": SDS((n_pod, B), jnp.int32),
        }
        return b
    if shape.name == "minibatch_lg":
        n_max = NeighborSampler.worst_case_nodes(d["batch_nodes"], (d["fanout0"], d["fanout1"]))
        e_max = NeighborSampler.worst_case_edges(d["batch_nodes"], (d["fanout0"], d["fanout1"]))
        # pad to multiples of 256 for clean sharding
        n_max = -(-n_max // 256) * 256
        e_max = -(-e_max // 256) * 256
        return {
            "x": SDS((n_pod, n_max, d["d_feat"]), jnp.float32),
            "edge_src": SDS((n_pod, e_max), jnp.int32),
            "edge_dst": SDS((n_pod, e_max), jnp.int32),
            "edge_mask": SDS((n_pod, e_max), jnp.float32),
            "node_mask": SDS((n_pod, n_max), jnp.float32),
            "labels": SDS((n_pod, n_max), jnp.int32),
        }
    # full-graph shapes, padded for sharding
    N = -(-d["n_nodes"] // 256) * 256
    E = -(-d["n_edges"] // 256) * 256
    return {
        "x": SDS((n_pod, N, d["d_feat"]), jnp.float32),
        "edge_src": SDS((n_pod, E), jnp.int32),
        "edge_dst": SDS((n_pod, E), jnp.int32),
        "edge_mask": SDS((n_pod, E), jnp.float32),
        "node_mask": SDS((n_pod, N), jnp.float32),
        "labels": SDS((n_pod, N), jnp.int32),
    }


def _gin_steps(arch: ArchSpec, shape: ShapeSpec, mesh, kcfg: KStepConfig,
               style: str = "sharded_nodes"):
    d = shape.dims
    base = arch.model_cfg
    cfg = dataclasses.replace(
        base,
        d_in=d["d_feat"], n_classes=d["n_classes"],
        readout="graph" if shape.name == "molecule" else "node",
        node_shard=(style != "replicated_nodes"),
        # sharded_bf16 (§Perf): whole node state in bf16 so the per-layer
        # h all-gather and agg reduce run on half-width payloads (the MLP
        # z-accumulation stays f32 inside gin.forward)
        dtype=jnp.bfloat16 if style == "sharded_bf16" else base.dtype,
        message_dtype=jnp.bfloat16 if style == "replicated_nodes" else None,
    )
    n_pod = mesh_pods(mesh)
    opt = KStepAdam(kcfg, n_pod, mesh=mesh)
    params_a = jax.eval_shape(lambda: gin_lib.init_params(jax.random.key(0), cfg))
    params_pod = _pod_abstract(params_a, n_pod)
    state_a = jax.eval_shape(opt.init, params_pod)
    batch_a = _gin_batch(shape, cfg, n_pod)

    # Leading dim is the pod-replica dim — it must shard over 'pod' so each
    # pod physically owns its replica and the k-step merge is a real
    # cross-pod collective.  Inner dims are small -> replicated in-pod.
    pod_e = "pod" if "pod" in mesh.axis_names else None
    p_specs = jax.tree.map(lambda x: P(pod_e, *([None] * (x.ndim - 1))), params_pod)
    state_specs = type(state_a)(
        step=P(), m=p_specs, v_local=p_specs, v_hat=p_specs,
        ef=p_specs if state_a.ef is not None else None,
    )
    if style == "replicated_nodes":
        # edges stay fully sharded; node-indexed arrays replicate in-pod so
        # the scatter reduces with one all-reduce per layer
        def gin_leaf_spec(name, x):
            if name.startswith("edge"):
                return P(pod_e, shard1d(x.shape[1], mesh,
                                        prefs=(("data", "model"), ("data",))),
                         *([None] * (x.ndim - 2)))
            return P(pod_e, *([None] * (x.ndim - 1)))
        batch_sp = {n: gin_leaf_spec(n, x) for n, x in batch_a.items()}
    else:
        batch_sp = jax.tree.map(
            lambda x: P(pod_e, shard1d(x.shape[1], mesh,
                                       prefs=(("data", "model"), ("data",), ("model",))),
                        *([None] * (x.ndim - 2))),
            batch_a,
        )

    def make(merge: bool):
        def step(params, batch, opt_state):
            with sharding_ctx(mesh):
                def total_loss(p):
                    losses = jax.vmap(lambda pi, bi: gin_lib.loss_fn(pi, bi, cfg))(p, batch)
                    return jnp.sum(losses)
                grads = jax.grad(total_loss)(params)
                return opt.step(params, grads, opt_state, merge=merge)
        return step

    # message passing: E gathers+adds of d_hidden + node MLPs
    E_real = batch_a["edge_src"].shape[1]
    N_real = batch_a["x"].shape[1]
    mlp_flops = 2 * (cfg.d_in * cfg.d_hidden + cfg.d_hidden * cfg.d_hidden * (2 * cfg.n_layers - 1))
    flops = 3.0 * n_pod * (N_real * mlp_flops + cfg.n_layers * E_real * cfg.d_hidden * 2)
    return {
        "train_local": StepDef(
            "train_local", make(False), (params_pod, batch_a, state_a),
            (p_specs, batch_sp, state_specs), donate=(0, 2),
            model_flops=flops, weight=(kcfg.k - 1) / kcfg.k,
        ),
        "train_merge": StepDef(
            "train_merge", make(True), (params_pod, batch_a, state_a),
            (p_specs, batch_sp, state_specs), donate=(0, 2),
            model_flops=flops, weight=1.0 / kcfg.k,
        ),
    }


# ================================================================== recsys
def _recsys_model_fns(arch: ArchSpec):
    cfg = arch.model_cfg
    name = arch.name
    if name in ("dlrm-mlperf",):
        return {
            "tables": rec.dlrm_table_specs(cfg),
            "init_dense": lambda rng: rec.dlrm_init_dense(rng, cfg),
            "id_fields": {f"emb_{i:02d}": ("sparse_ids", i) for i in range(cfg.n_sparse)},
        }
    if name in ("din", "dien"):
        return {
            "tables": rec.din_table_specs(cfg),
            "init_dense": lambda rng: rec.din_init_dense(rng, cfg),
            "id_fields": {"items": ("hist_target", None)},
        }
    if name == "two-tower-retrieval":
        return {
            "tables": rec.two_tower_table_specs(cfg),
            "init_dense": lambda rng: rec.two_tower_init_dense(rng, cfg),
            "id_fields": {"items": ("user_item", None)},
        }
    if name == "baidu-ctr":
        return {
            "tables": rec.ctr_table_specs(cfg),
            "init_dense": lambda rng: rec.ctr_init_dense(rng, cfg),
            "id_fields": {"sparse": ("ids", None)},
        }
    raise KeyError(name)


def _recsys_batch(arch: ArchSpec, B: int):
    cfg = arch.model_cfg
    if arch.name == "dlrm-mlperf":
        return {
            "dense": SDS((B, cfg.n_dense), jnp.float32),
            "sparse_ids": SDS((B, cfg.n_sparse), jnp.int32),
            "label": SDS((B,), jnp.float32),
        }
    if arch.name in ("din", "dien"):
        return {
            "hist_ids": SDS((B, cfg.seq_len), jnp.int32),
            "hist_mask": SDS((B, cfg.seq_len), jnp.float32),
            "target_id": SDS((B,), jnp.int32),
            "label": SDS((B,), jnp.float32),
        }
    if arch.name == "two-tower-retrieval":
        return {
            "user_ids": SDS((B, cfg.user_hist_len), jnp.int32),
            "user_mask": SDS((B, cfg.user_hist_len), jnp.float32),
            "item_id": SDS((B,), jnp.int32),
        }
    if arch.name == "baidu-ctr":
        return {
            "ids": SDS((B, cfg.nnz_per_instance), jnp.int32),
            "field_ids": SDS((B, cfg.nnz_per_instance), jnp.int32),
            "mask": SDS((B, cfg.nnz_per_instance), jnp.float32),
            "label": SDS((B,), jnp.float32),
        }
    raise KeyError(arch.name)


def _recsys_flat_ids(arch: ArchSpec, batch):
    """Per-table flattened id arrays for the working-set pull."""
    if arch.name == "dlrm-mlperf":
        return {f"emb_{i:02d}": batch["sparse_ids"][:, i]
                for i in range(arch.model_cfg.n_sparse)}
    if arch.name in ("din", "dien"):
        return {"items": jnp.concatenate(
            [batch["hist_ids"].reshape(-1), batch["target_id"]])}
    if arch.name == "two-tower-retrieval":
        return {"items": jnp.concatenate(
            [batch["user_ids"].reshape(-1), batch["item_id"]])}
    if arch.name == "baidu-ctr":
        return {"sparse": batch["ids"].reshape(-1)}
    raise KeyError(arch.name)


def _recsys_capacity(arch: ArchSpec, B: int) -> int:
    cfg = arch.model_cfg
    if arch.name == "dlrm-mlperf":
        n = B
    elif arch.name in ("din", "dien"):
        n = B * (cfg.seq_len + 1)
    elif arch.name == "two-tower-retrieval":
        n = B * (cfg.user_hist_len + 1)
    else:
        n = B * cfg.nnz_per_instance
    return int(-(-n // 256) * 256)


def _recsys_split_inv(arch: ArchSpec, invs: Dict[str, jnp.ndarray], batch, n_pod: int):
    """Reshape the global inverse-index arrays into per-pod slices (leading
    pod dim) matching how ``pod_batch`` splits the batch (pod-major rows)."""
    if arch.name == "dlrm-mlperf":
        return {n: inv.reshape(n_pod, -1) for n, inv in invs.items()}
    if arch.name in ("din", "dien"):
        B, T = batch["hist_ids"].shape
        inv = invs["items"]
        return {"hist": inv[: B * T].reshape(n_pod, -1),
                "target": inv[B * T:].reshape(n_pod, -1)}
    if arch.name == "two-tower-retrieval":
        B, T = batch["user_ids"].shape
        inv = invs["items"]
        return {"user": inv[: B * T].reshape(n_pod, -1),
                "item": inv[B * T:].reshape(n_pod, -1)}
    if arch.name == "baidu-ctr":
        return {"sparse": invs["sparse"].reshape(n_pod, -1)}
    raise KeyError(arch.name)


def _recsys_embed_builder(arch: ArchSpec):
    """(workings, inv_tree_for_this_pod, per-pod batch) -> embedding inputs."""
    cfg = arch.model_cfg
    name = arch.name

    if name == "dlrm-mlperf":
        def embed(workings, invs, bp):
            embs = [jnp.take(workings[f"emb_{i:02d}"], invs[f"emb_{i:02d}"], axis=0)
                    for i in range(cfg.n_sparse)]
            return jnp.stack(embs, axis=1)
        return embed

    if name in ("din", "dien"):
        def embed(workings, invs, bp):
            B, T = bp["hist_ids"].shape
            hist = jnp.take(workings["items"], invs["hist"], axis=0).reshape(B, T, -1)
            target = jnp.take(workings["items"], invs["target"], axis=0)
            return {"hist": hist, "target": target}
        return embed

    if name == "two-tower-retrieval":
        def embed(workings, invs, bp):
            B, T = bp["user_ids"].shape
            flat = jnp.take(workings["items"], invs["user"], axis=0)
            w = bp["user_mask"].reshape(-1)
            seg = jnp.repeat(jnp.arange(B, dtype=jnp.int32), T)
            pooled = jax.ops.segment_sum(flat * w[:, None], seg, num_segments=B)
            cnt = jax.ops.segment_sum(w, seg, num_segments=B)
            user = pooled / jnp.maximum(cnt, 1.0)[:, None]
            item = jnp.take(workings["items"], invs["item"], axis=0)
            return {"user": user, "item": item}
        return embed

    if name == "baidu-ctr":
        def embed(workings, invs, bp):
            B, nnz = bp["ids"].shape
            seg = (jnp.arange(B, dtype=jnp.int32)[:, None] * cfg.n_fields
                   + bp["field_ids"]).reshape(-1)
            emb = jnp.take(workings["sparse"], invs["sparse"], axis=0) \
                * bp["mask"].reshape(-1)[:, None]
            bags = jax.ops.segment_sum(emb, seg, num_segments=B * cfg.n_fields)
            return bags.reshape(B, cfg.n_fields, cfg.embed_dim)
        return embed

    raise KeyError(name)


def _recsys_loss_builder(arch: ArchSpec):
    cfg = arch.model_cfg
    name = arch.name
    if name == "dlrm-mlperf":
        def loss(dp, emb, bp, predict=False):
            logits = rec.dlrm_forward_from_emb(dp, emb, bp, cfg)
            return jax.nn.sigmoid(logits) if predict else rec.pointwise_loss(logits, bp["label"])
        return loss
    if name in ("din", "dien"):
        def loss(dp, emb, bp, predict=False):
            logits = rec.din_forward_from_emb(dp, emb, bp, cfg)
            return jax.nn.sigmoid(logits) if predict else rec.pointwise_loss(logits, bp["label"])
        return loss
    if name == "two-tower-retrieval":
        def loss(dp, emb, bp, predict=False):
            if predict:
                u, v = rec.two_tower_forward_from_emb(dp, emb, bp, cfg)
                return jnp.sum(u * v, -1)
            return rec.two_tower_loss(dp, emb, bp, cfg)
        return loss
    if name == "baidu-ctr":
        def loss(dp, emb, bp, predict=False):
            logits = rec.ctr_forward_from_emb(dp, emb, bp, cfg)
            return jax.nn.sigmoid(logits) if predict else rec.pointwise_loss(logits, bp["label"])
        return loss
    raise KeyError(name)


def _recsys_dense_flops(arch: ArchSpec, B: int) -> float:
    """Useful FLOPs per forward for B instances (2*params_matmul*B)."""
    cfg = arch.model_cfg
    def mlp_f(sizes):
        return sum(2 * a * b for a, b in zip(sizes[:-1], sizes[1:]))
    if arch.name == "dlrm-mlperf":
        f = mlp_f(list(cfg.bot_mlp)) + mlp_f([cfg.interact_dim] + list(cfg.top_mlp))
        F = cfg.n_sparse + 1
        f += 2 * F * F * cfg.embed_dim
        return f * B
    if arch.name in ("din", "dien"):
        d, T = cfg.embed_dim, cfg.seq_len
        h = cfg.gru_dim or d
        f = T * mlp_f([4 * h] + list(cfg.attn_mlp) + [1])
        f += mlp_f([2 * h + 2 * d] + list(cfg.mlp) + [1])
        if cfg.gru_dim:
            f += 2 * T * (2 * 3 * h * (d if False else h) + 3 * d * h)  # GRU+AUGRU
        return f * B
    if arch.name == "two-tower-retrieval":
        return 2.0 * B * mlp_f([cfg.embed_dim] + list(cfg.tower_mlp))
    if arch.name == "baidu-ctr":
        d, F = cfg.embed_dim, cfg.n_fields
        f = 3 * 2 * d * d * F + 2 * F * F * d * 2
        f += mlp_f([F * d] + list(cfg.mlp))
        return f * B
    raise KeyError(arch.name)


def _recsys_local_dedup_steps(arch: ArchSpec, shape: ShapeSpec, mesh,
                              kcfg: KStepConfig,
                              scfg: SparseAdagradConfig = SparseAdagradConfig()):
    """§Perf variant (baidu-ctr): SHARD-LOCAL dedup — the paper's actual
    Algorithm-1 design (each node dedups its own batch before pulling).

    The baseline dedups the global id stream with one jnp.unique — a
    distributed sort (log-rounds of cross-shard traffic).  Here each
    ('pod','data') shard dedups its own slice with a vmapped unique (sort is
    shard-local), pulls its own working rows, and scatters its own updates;
    ids hot on several shards are simply pulled/pushed by each (the paper's
    PS semantics — AdaGrad accumulates per-worker g^2, exactly like
    Algorithm 1's push of per-node updates)."""
    cfg = arch.model_cfg
    assert arch.name == "baidu-ctr", "local_dedup wired for the paper's arch"
    n_pod = mesh_pods(mesh)
    ndp = data_ways(mesh)
    B = shape.dims["batch"]
    nnz = cfg.nnz_per_instance
    cap_l = int(-(-(B // ndp) * nnz // 256) * 256)  # per-shard capacity
    opt = KStepAdam(kcfg, n_pod, mesh=mesh)
    sparse_opt = SparseAdagrad(scfg)
    loss = _recsys_loss_builder(arch)
    fns = _recsys_model_fns(arch)

    dense_a = jax.eval_shape(lambda: fns["init_dense"](jax.random.key(0)))
    dense_pod = _pod_abstract(dense_a, n_pod)
    rows_p = -(-cfg.rows // mesh.size) * mesh.size
    tables_a = {"sparse": SDS((rows_p, cfg.embed_dim), jnp.float32)}
    accum_a = {"sparse": SDS((rows_p, cfg.embed_dim), jnp.float32)}
    state_a = jax.eval_shape(opt.init, dense_pod)
    batch_a = _recsys_batch(arch, B)

    pod_e = "pod" if "pod" in mesh.axis_names else None
    dense_sp = jax.tree.map(lambda x: P(pod_e, *([None] * (x.ndim - 1))), dense_pod)
    table_sp = table_specs_sharding(tables_a, mesh)
    state_sp = type(state_a)(
        step=P(), m=dense_sp, v_local=dense_sp, v_hat=dense_sp,
        ef=dense_sp if state_a.ef is not None else None,
    )
    batch_sp = batch_specs(batch_a, mesh)
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def make(merge: bool):
        def step(dense, tables, accum, batch, opt_state):
            with sharding_ctx(mesh):
                table = tables["sparse"]
                # ---- shard-local dedup + pull
                ids_s = batch["ids"].reshape(ndp, -1)              # (ndp, B/ndp*nnz)
                ids_s = jax.lax.with_sharding_constraint(
                    ids_s, NamedSharding(mesh, P(dp_axes, None)))
                uids, inv = jax.vmap(
                    lambda v: pull_working_set(v, cap_l))(ids_s)   # (ndp,cap_l),(ndp,n)
                working = jax.vmap(lambda u: jnp.take(table, u, axis=0))(uids)
                working = jax.lax.with_sharding_constraint(
                    working, NamedSharding(mesh, P(dp_axes, None, None)))

                def total_loss(dense_p, w):
                    # regroup shards per pod: pod p owns groups [p*dpp,(p+1)*dpp)
                    dpp = ndp // n_pod
                    w_pod = w.reshape(n_pod, dpp * cap_l, cfg.embed_dim)
                    inv_pod = (inv.reshape(n_pod, dpp, -1)
                               + (jnp.arange(dpp, dtype=jnp.int32) * cap_l)[None, :, None]
                               ).reshape(n_pod, -1)
                    bp_pod = jax.tree.map(
                        lambda x: x.reshape((n_pod, x.shape[0] // n_pod) + x.shape[1:]),
                        batch,
                    )

                    def per_pod(dp, bp, wp, invp):
                        Bp, nz = bp["ids"].shape
                        seg = (jnp.arange(Bp, dtype=jnp.int32)[:, None] * cfg.n_fields
                               + bp["field_ids"]).reshape(-1)
                        emb = jnp.take(wp, invp, axis=0) \
                            * bp["mask"].reshape(-1)[:, None]
                        bags = jax.ops.segment_sum(
                            emb, seg, num_segments=Bp * cfg.n_fields)
                        emb = bags.reshape(Bp, cfg.n_fields, cfg.embed_dim)
                        return loss(dp, emb, bp)

                    losses = jax.vmap(per_pod)(dense_p, bp_pod, w_pod, inv_pod)
                    return jnp.sum(losses), losses

                (dg, wg), _ = jax.grad(total_loss, argnums=(0, 1), has_aux=True)(
                    dense, working
                )
                wg = wg / n_pod
                new_dense, new_state = opt.step(dense, dg, opt_state, merge=merge)
                # ---- per-shard push (duplicate ids across shards scatter-add)
                nt, na = sparse_opt.apply_rows(
                    table, accum["sparse"],
                    uids.reshape(-1), wg.reshape(-1, cfg.embed_dim),
                )
            return new_dense, {"sparse": nt}, {"sparse": na}, new_state
        return step

    flops = 3.0 * _recsys_dense_flops(arch, B)
    args = (dense_pod, tables_a, accum_a, batch_a, state_a)
    specs = (dense_sp, table_sp, table_sp, batch_sp, state_sp)
    return {
        "train_local": StepDef(
            "train_local", make(False), args, specs, donate=(0, 1, 2, 4),
            model_flops=flops, weight=(kcfg.k - 1) / kcfg.k,
        ),
        "train_merge": StepDef(
            "train_merge", make(True), args, specs, donate=(0, 1, 2, 4),
            model_flops=flops, weight=1.0 / kcfg.k,
        ),
    }


def _recsys_routed_steps(arch: ArchSpec, shape: ShapeSpec, mesh,
                         kcfg: KStepConfig,
                         scfg: SparseAdagradConfig = SparseAdagradConfig()):
    """§Perf iteration 3 (baidu-ctr): PS-routed pull/push via shard_map
    all-to-alls (core/routed_embedding.py) — replaces GSPMD's value-blind
    masked-gather + all-reduce (~930 MB/device/step) with explicit routing
    (~tens of MB): every device dedups its own id slice, requests rows from
    their hash-owning shards, and pushes fused AdaGrad updates back the same
    route.  This is the paper's parameter-server data path, TPU-native."""
    from repro.core import routed_embedding as RE

    cfg = arch.model_cfg
    assert arch.name == "baidu-ctr"
    n_pod = mesh_pods(mesh)
    B = shape.dims["batch"]
    nnz = cfg.nnz_per_instance
    n_sh = mesh.size
    all_axes = tuple(mesh.axis_names)
    n_dg = data_ways(mesh)            # data groups (pod x data)
    mper = n_sh // n_dg               # model peers per group
    per_dev = B * nnz // n_sh
    cap_local = int(-(-per_dev // 128) * 128)
    cap_route = max(32, int(-(-4 * cap_local // n_sh // 32) * 32))
    rows_p = -(-cfg.rows // n_sh) * n_sh
    dim = cfg.embed_dim
    pull, push = RE.make_routed_pull_push(
        mesh, rows_p // n_sh, dim, cap_local, cap_route, shard_axes=all_axes)

    opt = KStepAdam(kcfg, n_pod, mesh=mesh)
    loss = _recsys_loss_builder(arch)
    fns = _recsys_model_fns(arch)
    dense_a = jax.eval_shape(lambda: fns["init_dense"](jax.random.key(0)))
    dense_pod = _pod_abstract(dense_a, n_pod)
    tables_a = {"sparse": SDS((rows_p, dim), jnp.float32)}
    accum_a = {"sparse": SDS((rows_p, dim), jnp.float32)}
    state_a = jax.eval_shape(opt.init, dense_pod)
    batch_a = _recsys_batch(arch, B)

    pod_e = "pod" if "pod" in mesh.axis_names else None
    dense_sp = jax.tree.map(lambda x: P(pod_e, *([None] * (x.ndim - 1))), dense_pod)
    table_sp = {"sparse": P(all_axes, None)}
    state_sp = type(state_a)(
        step=P(), m=dense_sp, v_local=dense_sp, v_hat=dense_sp,
        ef=dense_sp if state_a.ef is not None else None,
    )
    batch_sp = batch_specs(batch_a, mesh)
    dpp = n_dg // n_pod

    def make(merge: bool):
        def step(dense, tables, accum, batch, opt_state):
            with sharding_ctx(mesh):
                # per-device dedup of this device's id slice
                ids_s = batch["ids"].reshape(n_sh, per_dev)
                ids_s = jax.lax.with_sharding_constraint(
                    ids_s, NamedSharding(mesh, P(all_axes, None)))
                uids, inv = jax.vmap(
                    lambda v: pull_working_set(v, cap_local))(ids_s)
                # ---- routed PULL (a2a): rows move once, to their requester
                working, _, drop_pull = pull(tables["sparse"], uids.reshape(-1))
                # regroup per data group: gather over model peers only (~MBs)
                w_g = working.reshape(n_dg, mper * cap_local, dim)
                w_g = jax.lax.with_sharding_constraint(
                    w_g, NamedSharding(
                        mesh, P(("pod", "data") if pod_e else ("data",), None, None)))
                inv_g = (inv.reshape(n_dg, mper, per_dev)
                         + (jnp.arange(mper, dtype=jnp.int32) * cap_local)[None, :, None]
                         ).reshape(n_dg, mper * per_dev)

                def total_loss(dense_p, w):
                    wp = w.reshape(n_pod, dpp, mper * cap_local, dim)
                    ip = inv_g.reshape(n_pod, dpp, -1)
                    bp = jax.tree.map(
                        lambda x: x.reshape((n_pod, dpp, x.shape[0] // n_dg)
                                            + x.shape[1:]), batch)

                    def group_loss(dp, bg, wg1, ig1):
                        Bg, nz = bg["ids"].shape
                        seg = (jnp.arange(Bg, dtype=jnp.int32)[:, None] * cfg.n_fields
                               + bg["field_ids"]).reshape(-1)
                        emb = jnp.take(wg1, ig1, axis=0) \
                            * bg["mask"].reshape(-1)[:, None]
                        bags = jax.ops.segment_sum(
                            emb, seg, num_segments=Bg * cfg.n_fields)
                        emb = bags.reshape(Bg, cfg.n_fields, dim)
                        return loss(dp, emb, bg)

                    def per_pod(dp, bpp, wpp, ipp):
                        return jnp.sum(jax.vmap(
                            lambda bg, wg1, ig1: group_loss(dp, bg, wg1, ig1)
                        )(bpp, wpp, ipp))

                    losses = jax.vmap(per_pod)(dense_p, bp, wp, ip)
                    return jnp.sum(losses), losses

                (dg_, wg_), _ = jax.grad(total_loss, argnums=(0, 1), has_aux=True)(
                    dense, w_g
                )
                wg_ = (wg_ / n_pod).reshape(n_sh * cap_local, dim)
                new_dense, new_state = opt.step(dense, dg_, opt_state, merge=merge)
                # ---- routed PUSH (a2a) + fused shard-local AdaGrad
                nt, na, drop_push = push(
                    tables["sparse"], accum["sparse"], uids.reshape(-1), wg_,
                    scfg.lr, scfg.eps,
                )
            return new_dense, {"sparse": nt}, {"sparse": na}, new_state
        return step

    flops = 3.0 * _recsys_dense_flops(arch, B)
    args = (dense_pod, tables_a, accum_a, batch_a, state_a)
    specs = (dense_sp, table_sp, table_sp, batch_sp, state_sp)
    return {
        "train_local": StepDef(
            "train_local", make(False), args, specs, donate=(0, 1, 2, 4),
            model_flops=flops, weight=(kcfg.k - 1) / kcfg.k,
        ),
        "train_merge": StepDef(
            "train_merge", make(True), args, specs, donate=(0, 1, 2, 4),
            model_flops=flops, weight=1.0 / kcfg.k,
        ),
    }


def _recsys_train_steps(arch: ArchSpec, shape: ShapeSpec, mesh, kcfg: KStepConfig,
                        scfg: SparseAdagradConfig = SparseAdagradConfig()):
    cfg = arch.model_cfg
    n_pod = mesh_pods(mesh)
    B = shape.dims["batch"]
    capacity = _recsys_capacity(arch, B)
    opt = KStepAdam(kcfg, n_pod, mesh=mesh)
    sparse_opt = SparseAdagrad(scfg)
    embed = _recsys_embed_builder(arch)
    loss = _recsys_loss_builder(arch)
    fns = _recsys_model_fns(arch)

    dense_a = jax.eval_shape(lambda: fns["init_dense"](jax.random.key(0)))
    dense_pod = _pod_abstract(dense_a, n_pod)
    # Pad table rows to the mesh size: jit input shardings require divisible
    # dims, and an unsharded 100GB+ table replica would OOM every chip.
    tables_a = {
        n: SDS((-(-s.rows // mesh.size) * mesh.size, s.dim), jnp.float32)
        for n, s in fns["tables"].items()
    }
    accum_a = jax.tree.map(lambda t: SDS(t.shape, jnp.float32), tables_a)
    state_a = jax.eval_shape(opt.init, dense_pod)
    batch_a = _recsys_batch(arch, B)

    pod_e = "pod" if "pod" in mesh.axis_names else None
    dense_sp = jax.tree.map(lambda x: P(pod_e, *([None] * (x.ndim - 1))), dense_pod)
    table_sp = table_specs_sharding(tables_a, mesh)
    state_sp = type(state_a)(
        step=P(), m=dense_sp, v_local=dense_sp, v_hat=dense_sp,
        ef=dense_sp if state_a.ef is not None else None,
    )
    batch_sp = batch_specs(batch_a, mesh)

    def make(merge: bool):
        def step(dense, tables, accum, batch, opt_state):
            with sharding_ctx(mesh):
                flat_ids = _recsys_flat_ids(arch, batch)
                pulls = {}
                for name in sorted(tables):
                    uids, inv = pull_working_set(flat_ids[name], capacity)
                    pulls[name] = (uids, inv, jnp.take(tables[name], uids, axis=0))
                workings = {n: p[2] for n, p in pulls.items()}
                invs_podded = _recsys_split_inv(
                    arch, {n: p[1] for n, p in pulls.items()}, batch, n_pod
                )
                bp_pod = jax.tree.map(
                    lambda x: x.reshape((n_pod, x.shape[0] // n_pod) + x.shape[1:]),
                    batch,
                )

                def total_loss(dense_p, w):
                    def per_pod(dp, bp, inv_tree):
                        emb = embed(w, inv_tree, bp)
                        return loss(dp, emb, bp)
                    losses = jax.vmap(per_pod)(dense_p, bp_pod, invs_podded)
                    return jnp.sum(losses), losses

                (dg, wg), _ = jax.grad(total_loss, argnums=(0, 1), has_aux=True)(
                    dense, workings
                )
                wg = jax.tree.map(lambda g: g / n_pod, wg)
                new_dense, new_state = opt.step(dense, dg, opt_state, merge=merge)
                new_tables, new_accum = {}, {}
                for name in sorted(tables):
                    nt, na = sparse_opt.apply_rows(
                        tables[name], accum[name], pulls[name][0], wg[name]
                    )
                    new_tables[name] = nt
                    new_accum[name] = na
            return new_dense, new_tables, new_accum, new_state
        return step

    flops = 3.0 * _recsys_dense_flops(arch, B)
    args = (dense_pod, tables_a, accum_a, batch_a, state_a)
    specs = (dense_sp, table_sp, jax.tree.map(lambda s: s, table_sp), batch_sp, state_sp)
    return {
        "train_local": StepDef(
            "train_local", make(False), args, specs, donate=(0, 1, 2, 4),
            model_flops=flops, weight=(kcfg.k - 1) / kcfg.k,
        ),
        "train_merge": StepDef(
            "train_merge", make(True), args, specs, donate=(0, 1, 2, 4),
            model_flops=flops, weight=1.0 / kcfg.k,
        ),
    }


def _recsys_serve_steps(arch: ArchSpec, shape: ShapeSpec, mesh):
    cfg = arch.model_cfg
    fns = _recsys_model_fns(arch)
    embed = _recsys_embed_builder(arch)
    loss = _recsys_loss_builder(arch)
    dense_a = jax.eval_shape(lambda: fns["init_dense"](jax.random.key(0)))
    tables_a = {
        n: SDS((-(-s.rows // mesh.size) * mesh.size, s.dim), jnp.float32)
        for n, s in fns["tables"].items()
    }
    dense_sp = jax.tree.map(lambda x: P(*([None] * x.ndim)), dense_a)
    table_sp = table_specs_sharding(tables_a, mesh)

    if shape.kind == "retrieval":
        C = shape.dims["n_candidates"]
        B = shape.dims["batch"]
        if arch.name == "two-tower-retrieval":
            batch_a = {
                "user_ids": SDS((B, cfg.user_hist_len), jnp.int32),
                "user_mask": SDS((B, cfg.user_hist_len), jnp.float32),
                "cand_ids": SDS((C,), jnp.int32),
            }
            batch_sp = {"user_ids": P(None, None), "user_mask": P(None, None),
                        "cand_ids": P(shard1d(C, mesh))}

            def step(dense, tables, batch):
                with sharding_ctx(mesh):
                    emb = rec.two_tower_embed_batch(
                        tables, {"user_ids": batch["user_ids"],
                                 "user_mask": batch["user_mask"],
                                 "item_id": batch["cand_ids"][:1]}, cfg)
                    return rec.two_tower_score_candidates(
                        dense, tables, emb["user"], batch["cand_ids"], cfg)

            f = _recsys_dense_flops(arch, C)  # item tower dominates
            return {"serve_retrieval": StepDef(
                "serve_retrieval", step, (dense_a, tables_a, batch_a),
                (dense_sp, table_sp, batch_sp), model_flops=f,
            )}
        # din/dien/dlrm/baidu-ctr: 1 user context scored against C candidates
        batch_a = _recsys_batch(arch, C)
        batch_sp = batch_specs(batch_a, mesh)

        def step(dense, tables, batch):
            with sharding_ctx(mesh):
                if arch.name == "dlrm-mlperf":
                    emb = rec.dlrm_embed_batch(tables, batch, cfg)
                elif arch.name in ("din", "dien"):
                    emb = rec.din_embed_batch(tables, batch, cfg)
                else:
                    emb = rec.ctr_embed_batch(tables, batch, cfg)
                return loss(dense, emb, batch, predict=True)

        return {"serve_retrieval": StepDef(
            "serve_retrieval", step, (dense_a, tables_a, batch_a),
            (dense_sp, table_sp, batch_sp),
            model_flops=_recsys_dense_flops(arch, C),
        )}

    B = shape.dims["batch"]
    batch_a = _recsys_batch(arch, B)
    batch_sp = batch_specs(batch_a, mesh)

    def step(dense, tables, batch):
        with sharding_ctx(mesh):
            if arch.name == "dlrm-mlperf":
                emb = rec.dlrm_embed_batch(tables, batch, cfg)
            elif arch.name in ("din", "dien"):
                emb = rec.din_embed_batch(tables, batch, cfg)
            elif arch.name == "two-tower-retrieval":
                emb = rec.two_tower_embed_batch(tables, batch, cfg)
            else:
                emb = rec.ctr_embed_batch(tables, batch, cfg)
            return loss(dense, emb, batch, predict=True)

    return {"serve": StepDef(
        "serve", step, (dense_a, tables_a, batch_a),
        (dense_sp, table_sp, batch_sp),
        model_flops=_recsys_dense_flops(arch, B),
    )}


# ================================================================ assembly
def _smoke_shape(arch: ArchSpec, shape: ShapeSpec) -> ShapeSpec:
    """Shrink a shape spec to CPU-testable dims (same kind/topology)."""
    d = dict(shape.dims)
    if arch.family == "lm":
        d["seq"] = min(d["seq"], 64)
        d["batch"] = min(d["batch"], 8)
    elif arch.family == "gnn":
        for k, v in [("n_nodes", 64), ("n_edges", 256), ("batch_nodes", 8),
                     ("fanout0", 3), ("fanout1", 2), ("d_feat", 8),
                     ("n_classes", 3), ("batch", 4)]:
            if k in d:
                d[k] = min(d[k], v)
    else:
        d["batch"] = min(d["batch"], 16)
        if "n_candidates" in d:
            d["n_candidates"] = min(d["n_candidates"], 512)
    return dataclasses.replace(shape, dims=d, skip=None)


def build_cell(
    arch_name: str, shape_name: str, mesh,
    kcfg: Optional[KStepConfig] = None,
    smoke: bool = False,
    lm_style: str = "tp_fsdp",
    gin_style: str = "sharded_nodes",
    recsys_style: str = "global_dedup",
) -> Cell:
    arch = get_arch(arch_name)
    shape = arch.shapes[shape_name]
    kcfg = kcfg or KStepConfig(k=20, merge="two_phase")
    if smoke:
        arch = dataclasses.replace(arch, model_cfg=arch.smoke_cfg)
        shape = _smoke_shape(arch, shape)
    if shape.skip:
        return Cell(arch_name, shape_name, shape.kind, {}, skip=shape.skip)
    if arch.family == "lm":
        if shape.kind == "train":
            steps = _lm_train_steps(arch, shape, mesh, kcfg, style=lm_style)
        else:
            steps = _lm_serve_steps(arch, shape, mesh)
    elif arch.family == "gnn":
        steps = _gin_steps(arch, shape, mesh, kcfg, style=gin_style)
    else:
        if shape.kind == "train":
            if recsys_style == "local_dedup" and arch.name == "baidu-ctr":
                steps = _recsys_local_dedup_steps(arch, shape, mesh, kcfg)
            elif recsys_style == "routed" and arch.name == "baidu-ctr":
                steps = _recsys_routed_steps(arch, shape, mesh, kcfg)
            else:
                steps = _recsys_train_steps(arch, shape, mesh, kcfg)
        else:
            steps = _recsys_serve_steps(arch, shape, mesh)
    return Cell(arch_name, shape_name, shape.kind, steps)


def all_cells() -> list:
    """The assigned 40 (arch x shape) pairs (+ the paper's own arch)."""
    from repro.configs import list_archs
    out = []
    for a in list_archs():
        spec = get_arch(a)
        for s in spec.shapes:
            out.append((a, s))
    return out
