"""Production mesh definitions.

Single pod: 16x16 = 256 chips, axes ('data', 'model').
Multi-pod:  2 x 16 x 16 = 512 chips, axes ('pod', 'data', 'model') — the
'pod' axis is the slow-fabric (DCN) boundary where the paper's k-step
merging applies; 'data'/'model' live on in-pod ICI.

Defined as functions (never module-level constants) so importing this
module touches no jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(n_pod: int = 2, data: int = 2, model: int = 2) -> jax.sharding.Mesh:
    """Small mesh over host (CPU) devices for distributed tests/benches."""
    return jax.make_mesh(
        (n_pod, data, model), ("pod", "data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 3,
    )


# TPU v5e hardware constants (roofline targets).
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
