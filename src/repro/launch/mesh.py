"""Production mesh definitions.

Single pod: 16x16 = 256 chips, axes ('data', 'model').
Multi-pod:  2 x 16 x 16 = 512 chips, axes ('pod', 'data', 'model') — the
'pod' axis is the slow-fabric (DCN) boundary where the paper's k-step
merging applies; 'data'/'model' live on in-pod ICI.

Defined as functions (never module-level constants) so importing this
module touches no jax device state.
"""

from __future__ import annotations

import jax


def _make_mesh(shape, axes) -> jax.sharding.Mesh:
    """jax.make_mesh across jax versions: ``axis_types`` (and the AxisType
    enum) only exist on newer releases; all axes here are Auto, which is
    also the default, so omit the kwarg when unsupported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(n_pod: int = 2, data: int = 2, model: int = 2) -> jax.sharding.Mesh:
    """Small mesh over host (CPU) devices for distributed tests/benches."""
    return _make_mesh((n_pod, data, model), ("pod", "data", "model"))


# TPU v5e hardware constants (roofline targets).
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
