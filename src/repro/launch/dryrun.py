import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x input-shape) cell on the
production meshes and record memory / cost / collective analyses.

    PYTHONPATH=src python -m repro.launch.dryrun --mesh single --arch qwen3-14b
    PYTHONPATH=src python -m repro.launch.dryrun --mesh both            # all cells

Results land in experiments/dryrun/<mesh>/<arch>__<shape>.json; the roofline
(benchmarks/roofline.py) and EXPERIMENTS.md read from there.

NOTE: the XLA_FLAGS line above MUST execute before any other import (jax
locks the device count on first init) — do not move it.
"""

import argparse   # noqa: E402
import json       # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402

from repro.configs import get as get_arch, list_archs  # noqa: E402
from repro.core.kstep import KStepConfig               # noqa: E402
from repro.launch import cells as cells_lib            # noqa: E402
from repro.launch.hlo_analysis import (                # noqa: E402
    analyze_hlo,
    cost_analysis_dict,
    memory_analysis_dict,
)
from repro.launch.mesh import make_production_mesh     # noqa: E402
from repro.sharding.specs import named_shardings       # noqa: E402


def run_step(step, mesh, devices_per_pod: int, verbose: bool = True):
    in_shardings = tuple(
        named_shardings(s, mesh) for s in step.in_specs
    )
    t0 = time.perf_counter()
    jitted = jax.jit(step.fn, in_shardings=in_shardings, donate_argnums=step.donate)
    lowered = jitted.lower(*step.args)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0
    mem = memory_analysis_dict(compiled)
    cost = cost_analysis_dict(compiled)
    # Loop-aware analysis: XLA cost_analysis counts while bodies once; the
    # HLO analyzer applies known_trip_count multiplicities (see hlo_analysis).
    hlo = analyze_hlo(compiled.as_text(), devices_per_pod)
    coll = hlo["collectives"]
    if verbose:
        print(f"    {step.name}: lower {t_lower:.1f}s compile {t_compile:.1f}s "
              f"flops/dev={hlo['flops']:.3e} bytes/dev={hlo['bytes_accessed']:.3e} "
              f"coll={coll.total_bytes/1e6:.1f}MB/dev (dcn {coll.dcn_bytes/1e6:.2f}MB)")
        print(f"      memory_analysis: {mem}")
    return {
        "name": step.name,
        "weight": step.weight,
        "model_flops": step.model_flops,
        "lower_seconds": t_lower,
        "compile_seconds": t_compile,
        "memory": mem,
        "cost": cost,
        "hlo": {"flops": hlo["flops"], "bytes_accessed": hlo["bytes_accessed"],
                "loop_corrected_computations": hlo["n_while_corrected"]},
        "collectives": {
            "total_bytes_per_device": coll.total_bytes,
            "ici_bytes_per_device": coll.ici_bytes,
            "dcn_bytes_per_device": coll.dcn_bytes,
            "by_kind": coll.by_kind(),
            "n_ops": len(coll.per_op),
        },
    }


def run_cell(arch_name, shape_name, mesh_name, k: int, merge: str,
             out_dir: str, smoke: bool = False, verbose: bool = True,
             lm_style: str = "tp_fsdp", gin_style: str = "sharded_nodes",
             recsys_style: str = "global_dedup"):
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    devices_per_pod = 256 if mesh_name == "multi" else 0
    kcfg = KStepConfig(k=k, merge=merge)
    cell = cells_lib.build_cell(arch_name, shape_name, mesh, kcfg, smoke=smoke,
                                lm_style=lm_style, gin_style=gin_style,
                                recsys_style=recsys_style)
    rec = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_name,
        "k": k, "merge": merge, "kind": cell.kind, "lm_style": lm_style,
        "gin_style": gin_style,
        "n_devices": mesh.size, "steps": {}, "skip": cell.skip,
    }
    if cell.skip:
        if verbose:
            print(f"  SKIP {arch_name} x {shape_name}: {cell.skip}")
    else:
        for name, step in cell.steps.items():
            rec["steps"][name] = run_step(step, mesh, devices_per_pod, verbose)
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch_name}__{shape_name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--k", type=int, default=20)
    ap.add_argument("--merge", default="two_phase",
                    choices=["flat", "two_phase", "bf16", "int8_ef"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--tag", default="", help="suffix for the output dir")
    ap.add_argument("--lm-style", default="tp_fsdp",
                    choices=["tp_fsdp", "fsdp_seq"])
    ap.add_argument("--gin-style", default="sharded_nodes",
                    choices=["sharded_nodes", "replicated_nodes", "sharded_bf16"])
    ap.add_argument("--recsys-style", default="global_dedup",
                    choices=["global_dedup", "local_dedup", "routed"])
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    failures = []
    for mesh_name in meshes:
        for a in archs:
            spec = get_arch(a)
            shapes = list(spec.shapes) if args.shape == "all" else [args.shape]
            for s in shapes:
                print(f"[{mesh_name}] {a} x {s}")
                out_dir = os.path.join(args.out + args.tag, mesh_name)
                try:
                    run_cell(a, s, mesh_name, args.k, args.merge, out_dir,
                             smoke=args.smoke, lm_style=args.lm_style,
                             gin_style=args.gin_style,
                             recsys_style=args.recsys_style)
                except Exception:
                    traceback.print_exc()
                    failures.append((mesh_name, a, s))
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run complete.")


if __name__ == "__main__":
    main()
