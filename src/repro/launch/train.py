"""Production training launcher — config-driven via ``build_trainer``.

    PYTHONPATH=src python -m repro.launch.train --arch baidu-ctr --shape train_mb1k \
        --k 20 --merge two_phase --steps 200 --ckpt-dir /tmp/run1

Model construction is delegated to ``repro.runtime.factory.build_trainer``
(driven by the ``repro.configs`` registry); the launcher only wires flags,
data streams, and fault tolerance.  Every registered arch trains here —
lm and gnn families under ``DenseTrainer``, and ALL recsys archs
(``baidu-ctr``, ``dlrm-mlperf``, ``din``, ``dien``,
``two-tower-retrieval``) under ``HybridTrainer`` through the shared online
predict-then-train loop (``repro.runtime.online.fit_online``).

Sparse placement (``--placement``): how embedding rows move per batch,
behind the ``EmbeddingBackend`` contract
(``pull(table, ids, capacity) -> WorkingSet``,
``push(table, accum, working_set, row_grads, opt)``):

  - ``gather`` (default): dedup + ``jnp.take``; single-device exact, and
    under GSPMD the compiler partitions the gather over row shards at the
    cost of value-blind all-reduce traffic.
  - ``routed``: the paper's PS request routing — ids bucketed by owning
    shard, exchanged with explicit all_to_alls over a hash-sharded table
    (wire ~= rows moved once); dropped-request counters are reported via
    ``trainer.overflow_dropped``.  On this CPU container the mesh
    degenerates to one shard, so the routed path runs end to end and its
    loss matches ``gather`` (the acceptance check).
  - ``cached``: the paper's §2.3 memory hierarchy — the full table and its
    AdaGrad accumulator stay host-resident; a device cache of
    ``--cache-rows`` rows serves the Zipf-hot working set (LFU-with-decay
    admission/eviction, write-through pushes, dirty spills).  Steady-state
    ``cache_hit_rate``/``evictions`` are reported in the training history
    next to ``overflow_dropped``; with ``--cache-rows >= rows`` the cache
    degenerates to a full mirror bit-identical to ``gather``.

``--capacity`` bounds the deduplicated working set per batch (static shape;
must be divisible by the shard count for ``routed``; ``--cache-rows`` must
cover it for ``cached``).

``--store disk`` drops the cold tier one level: full tables + accumulators
live in fixed-size row pages under ``--spill-dir`` (``--page-rows`` rows
per page) with an in-RAM LRU page cache (``--page-cache-pages``), async
read-ahead keyed off each batch's dedup'd id stream, and write-behind
dirty-page flushing — the three-level hierarchy of docs/storage.md.  Works
with ``gather`` and ``cached`` placements (``routed`` addresses
shard-resident rows and is rejected); with an unbounded page cache the
results are bit-identical to ``--store host``.

``--prefetch`` turns on the double-buffered pull prefetch (paper Fig. 5):
the next batch's working-set pull is dispatched while the current step is
still executing, for any placement — bit-identical results, overlapped
pull latency.  ``--merge-delay N`` (DenseTrainer archs only) applies each
k-step merge's cross-pod average N boundaries late (DCN latency hiding).
``--fused-kernels {auto,on,off}`` selects the fused Pallas sparse kernels
(gather+bag pull, scatter+AdaGrad push, cache-tier indirection variants —
see docs/kernels.md); bit-identical to the unfused path on every backend.

``--serve`` co-locates a CTR serving tier with recsys training: a
``CTRServer`` (``runtime.serve_ctr``) scores a second request stream
through the engine's read-only lookup contract against the trainer's live
tables, draining at each step's commit boundary — freshly trained rows are
servable one step later and the training trajectory is bit-identical to a
run without ``--serve`` (see docs/serving.md).

On a real TPU cluster each process calls ``jax.distributed.initialize()``
(args: --coordinator/--num-processes/--process-id, or TPU auto-detection)
and the production mesh spans all pods; in this CPU container it runs the
same code path on the reduced (smoke) configs so the launcher itself is
exercised end to end.

Fault tolerance: on start the launcher resumes from the newest complete
checkpoint in --ckpt-dir; a crashed/preempted job is restarted with the
same command line (elastic: the mesh may differ across restarts).
"""

from __future__ import annotations

import argparse
import time


def build_argparser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--k", type=int, default=20)
    ap.add_argument("--merge", default="two_phase",
                    choices=["flat", "two_phase", "bf16", "int8_ef"])
    ap.add_argument("--n-pod", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--sparse-lr", type=float, default=0.5)
    ap.add_argument("--placement", default="gather",
                    choices=["gather", "routed", "cached"],
                    help="sparse pull/push backend (see module docstring)")
    ap.add_argument("--capacity", type=int, default=0,
                    help="working-set bound per batch (0: arch default)")
    ap.add_argument("--cache-rows", type=int, default=0,
                    help="device cache rows for --placement cached "
                         "(0: working-set capacity, the minimum)")
    ap.add_argument("--store", default="host", choices=["host", "disk"],
                    help="cold tier below the device cache: 'host' keeps "
                         "full tables in host RAM (default); 'disk' pages "
                         "them to --spill-dir (three-level hierarchy: "
                         "device cache -> page cache -> SSD; docs/storage.md)")
    ap.add_argument("--spill-dir", default="",
                    help="DiskStore page directory (required for --store "
                         "disk)")
    ap.add_argument("--page-rows", type=int, default=0,
                    help="rows per spill page for --store disk (0: 1024)")
    ap.add_argument("--page-cache-pages", type=int, default=0,
                    help="in-RAM page-cache budget for --store disk "
                         "(0: unbounded — full mirror)")
    ap.add_argument("--prefetch", action="store_true",
                    help="double-buffered pull prefetch: overlap the next "
                         "batch's pull with the current step (Fig. 5)")
    ap.add_argument("--fused-kernels", default="auto",
                    choices=["auto", "on", "off"],
                    help="fused Pallas sparse pull/push + embedding-bag "
                         "kernels (bit-identical to unfused): auto = on "
                         "for a real TPU backend, off elsewhere; 'on' off-"
                         "TPU runs interpret under REPRO_KERNEL_INTERPRET=1 "
                         "or the jnp reference otherwise")
    ap.add_argument("--merge-delay", type=int, default=0,
                    help="apply k-step merges N boundaries late "
                         "(DenseTrainer archs; 0 = synchronous merges)")
    ap.add_argument("--serve", action="store_true",
                    help="co-locate a CTR serving tier with training "
                         "(recsys archs): a CTRServer scores a second "
                         "request stream through the engine's read-only "
                         "lookup, draining at each commit boundary — the "
                         "rows trained at step t are servable at t+1 and "
                         "the training trajectory is bit-identical to a "
                         "run without --serve (docs/serving.md)")
    ap.add_argument("--serve-batch", type=int, default=64,
                    help="dynamic-batch size of the co-located server "
                         "(one compiled predict executable; tail batches "
                         "pad up to this)")
    ap.add_argument("--strict-transfers", action="store_true",
                    help="fail fast on IMPLICIT host<->device transfers in "
                         "the online hot path (jax.transfer_guard; recsys "
                         "archs). Deliberate crossings stay explicit "
                         "(device_put staging, device_get metrics).")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="use reduced configs (CPU container default)")
    ap.add_argument("--full", dest="smoke", action="store_false",
                    help="full production config (real accelerators)")
    # multi-process bring-up (real clusters)
    ap.add_argument("--coordinator", default="")
    ap.add_argument("--num-processes", type=int, default=0)
    ap.add_argument("--process-id", type=int, default=-1)
    return ap


def main():
    args = build_argparser().parse_args()
    if args.coordinator:
        import jax
        jax.distributed.initialize(
            coordinator_address=args.coordinator,
            num_processes=args.num_processes,
            process_id=args.process_id,
        )

    import numpy as np
    from repro import configs
    from repro.core.kstep import KStepConfig
    from repro.core.sparse_optim import SparseAdagradConfig
    from repro.data import synthetic as S
    from repro.runtime.factory import build_trainer
    from repro.runtime.online import fit_online
    from repro.runtime.trainer import TrainerConfig

    spec = configs.get(args.arch)
    cfg = spec.smoke_cfg if args.smoke else spec.model_cfg
    tcfg = TrainerConfig(
        n_pod=args.n_pod,
        kstep=KStepConfig(lr=args.lr, k=args.k, merge=args.merge),
        sparse=SparseAdagradConfig(lr=args.sparse_lr, initial_accumulator=0.01),
        placement=args.placement, capacity=args.capacity or None,
        cache_rows=args.cache_rows or None, prefetch=args.prefetch,
        store=args.store, spill_dir=args.spill_dir or None,
        page_rows=args.page_rows or None,
        page_cache_pages=args.page_cache_pages or None,
        fused_kernels={"auto": None, "on": True, "off": False}[
            args.fused_kernels],
        merge_delay=args.merge_delay,
        ckpt_dir=args.ckpt_dir or None, ckpt_every=args.ckpt_every,
    )
    t0 = time.perf_counter()

    if spec.family == "lm":
        tr = build_trainer(args.arch, tcfg, smoke=args.smoke)
        if args.ckpt_dir and tr.resume():
            print(f"resumed at step {tr.step_num}")
        gen = S.lm_batches(seed=0, batch=max(args.n_pod * 4, 8), seq_len=64,
                           vocab=cfg.vocab)
        hist = tr.fit(gen, args.steps)
        final = f"{hist[-1]['loss']:.4f}" if hist else "n/a (steps < log_every)"
        print(f"final loss {final} "
              f"({tr.step_num / (time.perf_counter() - t0):.2f} steps/s)")
        return

    if spec.family == "gnn":
        import dataclasses as dc
        gcfg = dc.replace(cfg, d_in=32, n_classes=5)
        g = S.community_graph(seed=0, n_nodes=2000, avg_degree=8,
                              d_feat=32, n_classes=5)
        tr = build_trainer(args.arch, tcfg, smoke=args.smoke, model_cfg=gcfg)
        if args.ckpt_dir and tr.resume():
            print(f"resumed at step {tr.step_num}")
        batch = {k: np.stack([v] * args.n_pod) for k, v in
                 [("x", g.x), ("edge_src", g.edge_src),
                  ("edge_dst", g.edge_dst), ("labels", g.labels)]}
        loss = 0.0
        for _ in range(args.steps):
            loss = tr.train_step(batch, podded=True)
        if tr.ckpt:
            tr.ckpt.wait()   # async writer must land the final checkpoint
        print(f"final loss {loss:.4f} "
              f"({tr.step_num / (time.perf_counter() - t0):.2f} steps/s)")
        return

    # recsys family — hybrid trainer through the factory, every arch
    # (baidu-ctr, dlrm-mlperf, din, dien, two-tower-retrieval): online
    # predict-then-train where the stream carries labels, train-only where
    # it doesn't (two-tower).  --prefetch dispatches each batch's pull
    # before the predict/train pair so it overlaps the previous step.
    tr = build_trainer(args.arch, tcfg, smoke=args.smoke)
    if args.ckpt_dir and tr.resume():
        print(f"resumed at step {tr.step_num}")
    gen = S.recsys_batches(cfg, batch=args.batch, seed=1)

    if args.serve:
        # --- co-located train + serve: one process, one engine.  The
        # server reads the LIVE tables the trainer writes, through the
        # read-only lookup contract; its drain sits at the commit boundary
        # (right after train_step lands), so rows trained at step t are
        # servable for step t+1's traffic, and because lookup mutates
        # nothing the loss trajectory is bit-identical to a run without
        # --serve.
        from repro.runtime.factory import build_ctr_server

        srv = build_ctr_server(tr, max_batch=args.serve_batch)
        serve_gen = S.recsys_batches(cfg, batch=args.serve_batch, seed=2)
        loss = float("nan")
        for _ in range(args.steps):
            b = next(gen)
            if args.prefetch:
                tr.prefetch(b)
            srv.submit_batch(next(serve_gen))   # traffic lands mid-step
            loss = tr.train_step(b)
            srv.drain()                         # commit boundary
        s = srv.summary()
        hit = (f"serve_hit_rate {s['serve_hit_rate']:.3f} "
               if "serve_hit_rate" in s else "")
        print(f"final loss {float(loss):.6f} "
              f"served {int(s['served'])} qps {s['qps']:.1f} "
              f"p50 {s['p50'] * 1e3:.2f}ms p99 {s['p99'] * 1e3:.2f}ms {hit}"
              f"placement {args.placement} prefetch {args.prefetch} "
              f"({args.steps / (time.perf_counter() - t0):.2f} steps/s)")
        return

    hist, online_auc = fit_online(tr, gen, args.steps, window=20, log=print,
                                  strict_transfers=args.strict_transfers)
    loss = hist[-1]["loss"] if hist else float("nan")
    stats = tr.sparse_metrics()
    cache = (
        f"cache_hit_rate {stats['cache_hit_rate_total']:.3f} "
        f"evictions {stats['evictions_total']} "
        if "cache_hit_rate_total" in stats else ""
    )
    auc_s = f"online AUC {online_auc:.4f} " if online_auc is not None else ""
    print(f"final loss {float(loss):.6f} {auc_s}"
          f"placement {args.placement} prefetch {args.prefetch} "
          f"overflow_dropped {tr.overflow_dropped} {cache}"
          f"({args.steps / (time.perf_counter() - t0):.2f} steps/s)")


if __name__ == "__main__":
    main()
