"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch baidu-ctr --shape train_mb1k \
        --k 20 --merge two_phase --steps 200 --ckpt-dir /tmp/run1

On a real TPU cluster each process calls ``jax.distributed.initialize()``
(args: --coordinator/--num-processes/--process-id, or TPU auto-detection)
and the production mesh spans all pods; in this CPU container it runs the
same code path on the reduced (smoke) configs so the launcher itself is
exercised end to end.

Fault tolerance: on start the launcher resumes from the newest complete
checkpoint in --ckpt-dir; a crashed/preempted job is restarted with the
same command line (elastic: the mesh may differ across restarts).
"""

from __future__ import annotations

import argparse
import os
import sys
import time


def build_argparser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--k", type=int, default=20)
    ap.add_argument("--merge", default="two_phase",
                    choices=["flat", "two_phase", "bf16", "int8_ef"])
    ap.add_argument("--n-pod", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--sparse-lr", type=float, default=0.5)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="use reduced configs (CPU container default)")
    ap.add_argument("--full", dest="smoke", action="store_false",
                    help="full production config (real accelerators)")
    # multi-process bring-up (real clusters)
    ap.add_argument("--coordinator", default="")
    ap.add_argument("--num-processes", type=int, default=0)
    ap.add_argument("--process-id", type=int, default=-1)
    return ap


def main():
    args = build_argparser().parse_args()
    if args.coordinator:
        import jax
        jax.distributed.initialize(
            coordinator_address=args.coordinator,
            num_processes=args.num_processes,
            process_id=args.process_id,
        )

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import configs
    from repro.core.kstep import KStepConfig
    from repro.core.sparse_optim import SparseAdagradConfig
    from repro.data import synthetic as S
    from repro.models import gin as G
    from repro.models import recsys as R
    from repro.models import transformer as T
    from repro.runtime.metrics import StreamingAUC
    from repro.runtime.trainer import DenseTrainer, HybridTrainer, TrainerConfig

    spec = configs.get(args.arch)
    cfg = spec.smoke_cfg if args.smoke else spec.model_cfg
    tcfg = TrainerConfig(
        n_pod=args.n_pod,
        kstep=KStepConfig(lr=args.lr, k=args.k, merge=args.merge),
        sparse=SparseAdagradConfig(lr=args.sparse_lr, initial_accumulator=0.01),
        ckpt_dir=args.ckpt_dir or None, ckpt_every=args.ckpt_every,
    )
    t0 = time.perf_counter()

    if spec.family == "lm":
        params = T.init_params(jax.random.key(0), cfg)
        tr = DenseTrainer(lambda p, b: T.loss_fn(p, b, cfg), params, tcfg)
        if args.ckpt_dir and tr.resume():
            print(f"resumed at step {tr.step_num}")
        gen = S.lm_batches(seed=0, batch=max(args.n_pod * 4, 8), seq_len=64,
                           vocab=cfg.vocab)
        hist = tr.fit(gen, args.steps)
        print(f"final loss {hist[-1]['loss']:.4f} "
              f"({tr.step_num / (time.perf_counter() - t0):.2f} steps/s)")
        return

    if spec.family == "gnn":
        import dataclasses as dc
        gcfg = dc.replace(cfg, d_in=32, n_classes=5)
        g = S.community_graph(seed=0, n_nodes=2000, avg_degree=8,
                              d_feat=32, n_classes=5)
        params = G.init_params(jax.random.key(0), gcfg)
        tr = DenseTrainer(lambda p, b: G.loss_fn(p, b, gcfg), params, tcfg)
        if args.ckpt_dir and tr.resume():
            print(f"resumed at step {tr.step_num}")
        batch = {k: np.stack([v] * args.n_pod) for k, v in
                 [("x", g.x), ("edge_src", g.edge_src),
                  ("edge_dst", g.edge_dst), ("labels", g.labels)]}
        loss = 0.0
        for i in range(args.steps):
            loss = tr.train_step(batch, podded=True)
        print(f"final loss {loss:.4f} "
              f"({tr.step_num / (time.perf_counter() - t0):.2f} steps/s)")
        return

    # recsys family — hybrid trainer (adapters mirror cells.py)
    if args.arch == "baidu-ctr":
        rng = jax.random.key(0)
        dense = R.ctr_init_dense(rng, cfg)
        tables = {"sparse": jax.random.normal(rng, (cfg.rows, cfg.embed_dim)) * 0.05}

        def embed_fn(workings, invs, bp):
            B, nnz = bp["ids"].shape
            seg = (jnp.arange(B, dtype=jnp.int32)[:, None] * cfg.n_fields
                   + bp["field_ids"]).reshape(-1)
            emb = jnp.take(workings["sparse"], invs["sparse"], axis=0) \
                * bp["mask"].reshape(-1)[:, None]
            bags = jax.ops.segment_sum(emb, seg, num_segments=B * cfg.n_fields)
            return bags.reshape(B, cfg.n_fields, cfg.embed_dim)

        def loss_fn(dp, emb, bp, predict=False):
            logits = R.ctr_forward_from_emb(dp, emb, bp, cfg)
            return jax.nn.sigmoid(logits) if predict \
                else R.pointwise_loss(logits, bp["label"])

        tr = HybridTrainer(dense, tables, embed_fn, loss_fn, {"sparse": "ids"},
                           capacity=1 << 14, cfg=tcfg)
        if args.ckpt_dir and tr.resume():
            print(f"resumed at step {tr.step_num}")
        gen = S.ctr_batches(seed=1, batch=args.batch, rows=cfg.rows,
                            n_fields=cfg.n_fields, nnz=cfg.nnz_per_instance)
        meter = StreamingAUC(window=20)
        loss = 0.0
        for i in range(args.steps):
            b = next(gen)
            meter.update(b["label"], tr.predict(b))
            loss = tr.train_step(b)
        print(f"final loss {loss:.4f} online AUC {meter.value():.4f} "
              f"({tr.step_num / (time.perf_counter() - t0):.2f} steps/s)")
        return

    print(f"launcher training loop for {args.arch}: use examples/ drivers "
          f"(dlrm/din/dien/two-tower smoke training is covered by tests)")
    sys.exit(0)


if __name__ == "__main__":
    main()
