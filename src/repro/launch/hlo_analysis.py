"""Post-partitioning HLO analysis: collective-byte accounting for the
roofline.  Parses ``compiled.as_text()`` (SPMD — shapes are per-device
shards), sums operand bytes of every collective op, and classifies each op
as in-pod (ICI) or pod-crossing (DCN) from its replica groups.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

import numpy as np

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z]+\d*(?:e\d+m\d+(?:fn)?)?)\[([\d,]*)\]")
# `%all-reduce.3 = f32[256,128]{1,0} all-reduce(%operand), channel_id=...`
# (operands are printed without types in optimized HLO — account via the
# RESULT shape plus a per-kind ring-algorithm wire model).
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z]+\d*[^\s]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(",
)
_GROUPS_LITERAL_RE = re.compile(r"replica_groups=\{(\{[\d,\{\} ]*\})\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?"
)
_PAIRS_RE = re.compile(r"source_target_pairs=\{([\d,\{\} ]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _parse_groups(line: str) -> Optional[List[List[int]]]:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        ng, gs = int(m.group(1)), int(m.group(2))
        iota_dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(iota_dims))).reshape(iota_dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            ids = ids.transpose(perm)
        return ids.reshape(ng, gs).tolist()
    m = _GROUPS_LITERAL_RE.search(line)
    if m:
        groups = []
        for g in re.findall(r"\{([\d, ]*)\}", m.group(1)):
            if g.strip():
                groups.append([int(x) for x in g.replace(" ", "").split(",")])
        return groups or None
    m = _PAIRS_RE.search(line)
    if m:
        pairs = re.findall(r"\{(\d+),(\d+)\}", "{" + m.group(1) + "}")
        return [[int(a), int(b)] for a, b in pairs]
    return None


@dataclasses.dataclass
class CollectiveStats:
    per_op: List[Dict]
    ici_bytes: int = 0      # per-device bytes moved on in-pod links
    dcn_bytes: int = 0      # per-device bytes crossing the pod boundary
    total_bytes: int = 0

    def by_kind(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for op in self.per_op:
            out[op["kind"]] = out.get(op["kind"], 0) + op["bytes"]
        return out


def crosses_pod(groups: Optional[List[List[int]]], devices_per_pod: int) -> bool:
    if not groups or devices_per_pod <= 0:
        return False
    for g in groups:
        pods = {d // devices_per_pod for d in g}
        if len(pods) > 1:
            return True
    return False


def collect_collectives(hlo_text: str, devices_per_pod: int = 0) -> CollectiveStats:
    """Per-device wire-byte model (ring algorithms, n = group size):
    all-reduce: 2 * result * (n-1)/n; all-gather: result * (n-1)/n (result is
    the gathered size); reduce-scatter: result * (n-1) (result is the shard);
    all-to-all / collective-permute: result."""
    stats = CollectiveStats(per_op=[])
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if m is None:
            continue
        kind = m.group(2)
        if m.group(3) == "-done":
            continue  # async pairs: count the -start only
        result_ty = m.group(1)
        res_bytes = sum(_shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(result_ty))
        groups = _parse_groups(line)
        n = len(groups[0]) if groups else 2
        if kind == "all-reduce":
            op_bytes = int(2 * res_bytes * (n - 1) / max(n, 1))
        elif kind == "all-gather":
            op_bytes = int(res_bytes * (n - 1) / max(n, 1))
        elif kind == "reduce-scatter":
            op_bytes = int(res_bytes * (n - 1))
        else:
            op_bytes = res_bytes
        is_dcn = crosses_pod(groups, devices_per_pod)
        rec = {"kind": kind, "bytes": op_bytes, "dcn": is_dcn,
               "n_groups": len(groups) if groups else 0, "group_size": n}
        stats.per_op.append(rec)
        stats.total_bytes += op_bytes
        if is_dcn:
            stats.dcn_bytes += op_bytes
        else:
            stats.ici_bytes += op_bytes
    return stats


def memory_analysis_dict(compiled) -> Dict:
    try:
        ma = compiled.memory_analysis()
        if ma is None:
            return {}
        out = {}
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            if hasattr(ma, attr):
                out[attr] = int(getattr(ma, attr))
        return out
    except Exception as e:  # pragma: no cover - backend dependent
        return {"error": str(e)}


def cost_analysis_dict(compiled) -> Dict:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and (
                    k in ("flops", "transcendentals", "bytes accessed")
                    or k.startswith("bytes accessed"))}
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


# ======================================================================
# Loop-aware whole-module analysis.
#
# XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE — for a
# scan-over-layers transformer that under-counts flops/bytes by ~n_layers.
# The analyzer below parses the optimized HLO, reconstructs the call graph
# (while bodies x known_trip_count, fusions, calls, conditionals) and counts
# dot FLOPs / top-level bytes / collective wire bytes with multiplicities.
# ======================================================================

# header lines look like `%name (args...) -> result {` with possibly nested
# parens/brackets in the arg list — anchor on the trailing `-> ... {` instead.
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_OP_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[^\]]*\][^\s]*)\s+([\w\-]+)\((.*)$"
)
_TRIP_RE = re.compile(r'known_trip_count[":{\s]+n[":\s]+"?(\d+)')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")


def _shape_elems_bytes(type_str: str):
    total_b, total_e = 0, 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total_e += n
        total_b += n * _DTYPE_BYTES.get(dt, 0)
    return total_e, total_b


def _parse_computations(hlo_text: str):
    """-> {comp_name: [ (op_name, type_str, opcode, rest_of_line) ]}"""
    comps = {}
    current = None
    for line in hlo_text.splitlines():
        if line.startswith(" "):
            hdr = None  # op lines are indented; headers are not
        else:
            hdr = _COMP_HDR_RE.match(line.strip())
        if hdr is not None:
            current = hdr.group(1)
            comps[current] = []
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is None:
            continue
        m = _OP_LINE_RE.match(line)
        if m:
            comps[current].append((m.group(1), m.group(2), m.group(3), m.group(4)))
    return comps


# HBM-traffic model per opcode.  Alias/ownership ops (parameter, tuple,
# get-tuple-element, bitcast, while results, ...) move no bytes; slicing ops
# move the slice, not the buffer they slice from; most compute ops read
# their operands once and write their result once.
_ALIAS_OPS = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "while",
    "conditional", "constant", "after-all", "call", "reshape",
    "opt-barrier",
}


def _op_traffic(opcode: str, res_b: int, rest: str, shapes) -> float:
    if opcode in _ALIAS_OPS:
        return 0.0
    if opcode in ("dynamic-slice", "gather"):
        return 2.0 * res_b                      # read slice + write result
    if opcode in ("dynamic-update-slice", "scatter"):
        # update operand (second) read + written region
        ops = _OPERANDS_RE.findall(rest.split(")")[0])
        upd = 0
        if len(ops) >= 2 and ops[1] in shapes:
            upd = _shape_elems_bytes(shapes[ops[1]])[1]
        return 2.0 * (upd if upd else res_b)
    if opcode in ("copy", "transpose", "convert", "broadcast", "iota",
                  "reverse", "pad", "slice", "concatenate"):
        return 2.0 * res_b                      # streaming read+write
    # dots / fusions / reduces / collectives / elementwise: operands + result
    op_b = res_b
    for opn in _OPERANDS_RE.findall(rest.split(")")[0]):
        if opn in shapes:
            op_b += _shape_elems_bytes(shapes[opn])[1]
    return float(op_b)


def analyze_hlo(hlo_text: str, devices_per_pod: int = 0):
    """Loop-aware per-device totals: dot flops, top-level bytes accessed,
    collective wire bytes (ICI/DCN split).  Returns a dict."""
    comps = _parse_computations(hlo_text)

    # entry computation: the one defined with 'ENTRY' — recover by scanning
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                entry = m.group(1)
                break
    if entry is None:
        entry = next(iter(comps), None)

    # computations reached via fusion 'calls=' don't own byte traffic
    fused = set()
    for ops in comps.values():
        for name, ty, opcode, rest in ops:
            if opcode == "fusion":
                m = _CALLS_RE.search(rest)
                if m:
                    fused.add(m.group(1))

    mult = {entry: 1.0}
    order = [entry]
    # propagate multiplicities breadth-first through the call graph
    idx = 0
    while idx < len(order):
        comp = order[idx]
        idx += 1
        m_here = mult.get(comp, 0.0)
        for name, ty, opcode, rest in comps.get(comp, []):
            callees = []
            if opcode == "while":
                trip = 1.0
                tm = _TRIP_RE.search(rest)
                if tm:
                    trip = float(tm.group(1))
                bm = _BODY_RE.search(rest)
                if bm:
                    callees.append((bm.group(1), trip))
                cm = _COND_RE.search(rest)
                if cm:
                    callees.append((cm.group(1), trip))
            elif opcode == "fusion":
                fm = _CALLS_RE.search(rest)
                if fm:
                    callees.append((fm.group(1), 1.0))
            elif opcode == "conditional":
                brm = _BRANCHES_RE.search(rest)
                if brm:
                    for b in brm.group(1).split(","):
                        callees.append((b.strip().lstrip("%"), 1.0))
            elif opcode in ("call", "custom-call", "reduce", "scatter",
                            "all-reduce", "reduce-scatter", "reduce-window",
                            "sort", "map", "select-and-scatter"):
                tm = _TO_APPLY_RE.search(rest)
                if tm:
                    callees.append((tm.group(1), 1.0))
            for cname, factor in callees:
                if cname in comps:
                    add = m_here * factor
                    if cname in mult:
                        mult[cname] += add
                    else:
                        mult[cname] = add
                        order.append(cname)

    flops = 0.0
    bytes_accessed = 0.0
    coll = CollectiveStats(per_op=[])
    for comp, ops in comps.items():
        m_here = mult.get(comp, 0.0)
        if m_here == 0.0:
            continue
        shapes = {name: ty for name, ty, _, _ in ops}
        for name, ty, opcode, rest in ops:
            res_e, res_b = _shape_elems_bytes(ty)
            if opcode in ("dot", "convolution"):
                k = 1
                cm = _CONTRACT_RE.search(rest)
                lhs_name = None
                om = _OPERANDS_RE.findall(rest)
                if om:
                    lhs_name = om[0]
                if cm is not None and lhs_name and lhs_name in shapes:
                    lhs_dims = _SHAPE_RE.findall(shapes[lhs_name])
                    if lhs_dims:
                        dims = [int(d) for d in lhs_dims[0][1].split(",") if d]
                        for ci in cm.group(1).split(","):
                            if ci != "" and int(ci) < len(dims):
                                k *= dims[int(ci)]
                flops += m_here * 2.0 * res_e * k
            if comp not in fused:
                bytes_accessed += m_here * _op_traffic(
                    opcode, res_b, rest, shapes
                )
            if opcode in _COLLECTIVES or any(
                opcode == f"{c}-start" for c in _COLLECTIVES
            ):
                base = opcode.replace("-start", "")
                if opcode.endswith("-done"):
                    continue
                groups = _parse_groups(rest)
                n = len(groups[0]) if groups else 2
                if base == "all-reduce":
                    wire = int(2 * res_b * (n - 1) / max(n, 1))
                elif base == "all-gather":
                    wire = int(res_b * (n - 1) / max(n, 1))
                elif base == "reduce-scatter":
                    wire = int(res_b * (n - 1))
                else:
                    wire = res_b
                wire = int(wire * m_here)
                is_dcn = crosses_pod(groups, devices_per_pod)
                coll.per_op.append({"kind": base, "bytes": wire, "dcn": is_dcn,
                                    "mult": m_here})
                coll.total_bytes += wire
                if is_dcn:
                    coll.dcn_bytes += wire
                else:
                    coll.ici_bytes += wire

    return {
        "flops": flops,
        "bytes_accessed": bytes_accessed,
        "collectives": coll,
        "n_computations": len(comps),
        "n_while_corrected": sum(1 for v in mult.values() if v > 1.0),
    }
