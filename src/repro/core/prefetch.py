"""Double-buffered pull prefetch — the paper's Fig. 5 pipeline for the PS pull.

Algorithm 1 runs pull -> fwd/bwd -> push strictly serially; the paper hides
the parameter-server pull latency behind the accelerator's fwd/bwd work
(Fig. 5's Read-Ins / Pull-Sparse / Train-DNN overlap, the same read-ahead
structure as HugeCTR's hybrid-embedding prefetch and the AIBox hierarchical
PS).  PR 2 made ``pull`` an explicit ``(tables, accum, state) ->
(ws, tables, accum, state)`` transition, which is exactly what a prefetcher
needs: the pull of batch t+1 commutes with the push of batch t except
through those trees, so dispatching it early and handing the returned trees
to the next step preserves bit-exactness (the cache tier's spill is the
only ordering point, serialized by the hand-off).

``PrefetchingEngine`` wraps any ``EmbeddingEngine`` with a one-slot
double buffer:

    pf = PrefetchingEngine(engine)
    pending = pf.dispatch(tables, accum, states, staged_batch, src=batch)
        # jitted pull (buffer donation) dispatched, NOT blocked on — under
        # JAX async dispatch it overlaps the still-running train step
    ...
    wss, tables, accum, states = pf.commit()   # hand-off to the train stage

Invariants (all loud, never silent):
  - at most ONE pull is in flight (``dispatch`` while pending raises),
  - ``commit`` without a pending pull raises,
  - each ``PendingPull`` remembers the source batch object (``src``) so a
    trainer can detect being fed a different batch than it prefetched,
  - dispatch donates the committed table/accum/state buffers into the pull;
    the logically-identical post-pull trees in the pending slot are the only
    valid handles until commit (checkpointing must therefore happen at
    commit boundaries — ``HybridTrainer.save`` enforces this).

Under ``--store disk`` the engine's pull stage is the host-staging wrapper
(``EmbeddingEngine._disk_pull_stage``), and this prefetcher needs no change:
``dispatch`` runs the wrapper, whose read-ahead queues the next batch's
pages BEFORE its absorb blocks on the train step still holding the previous
staged outputs — so disk fault-in overlaps device compute exactly like the
pull itself does.  Inference never absorbs at all: ``HybridTrainer``'s
predict path runs the engine's READ-ONLY lookup contract, and under the
disk store ``EmbeddingEngine.stage_lookup`` overlays any still-pending
staged training outputs onto its serve-metered page reads host-side — the
freshest values are served in every pipeline state without writing to the
store or disturbing the pending metadata this prefetcher owns (see
``_disk_lookup_stage``).
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

from repro.core.embedding_engine import EmbeddingEngine, WorkingSet


class PendingPull(NamedTuple):
    """One dispatched (possibly still executing) working-set pull.

    All array leaves are un-materialized device values: under JAX async
    dispatch they are futures that resolve when the pull executes.  The
    ``tables``/``accum``/``bstate`` trees are the POST-pull sparse state —
    logically identical to the committed state the pull consumed (a pull
    moves rows between host and cache coherently; only push changes
    values), so reads (e.g. online ``predict``) may use them while the
    pull is in flight."""

    wss: Dict[str, WorkingSet]   # per-table pulled working sets
    tables: Dict[str, Any]       # post-pull tables (cache spills applied)
    accum: Dict[str, Any]        # post-pull AdaGrad accumulators
    bstate: Dict[str, Any]       # post-pull backend state (cache admissions)
    batch: Any                   # the device-staged batch the pull serves
    src: Any                     # the caller's original batch object (identity
                                 # key for mismatch detection; keeps it alive)


class PrefetchingEngine:
    """One-slot (double-buffered) speculative pull dispatcher.

    ``donate`` is forwarded to ``EmbeddingEngine.pull_stage``: the committed
    sparse-state buffers are donated into the pull, so the caller must treat
    the ``PendingPull``'s trees as the only live handles until ``commit``.
    """

    def __init__(self, engine: EmbeddingEngine, donate: bool = True):
        self.engine = engine
        self.donate = bool(donate)
        self._pending: Optional[PendingPull] = None

    @property
    def pending(self) -> Optional[PendingPull]:
        return self._pending

    def dispatch(self, tables, accum, states, batch, src=None) -> PendingPull:
        """Dispatch ``batch``'s pull against the committed sparse state.

        Returns immediately (the pull runs under async dispatch); the result
        lives in the pending slot until ``commit``.  ``batch`` must already
        be device-staged; ``src`` is the caller's original batch object,
        kept for identity checks."""
        if self._pending is not None:
            raise RuntimeError(
                "PrefetchingEngine.dispatch: a pull is already in flight — "
                "train on it (commit()) before dispatching another "
                "(the prefetch pipeline is one batch deep)"
            )
        wss, t, a, s = self.engine.pull_async(
            tables, accum, states, batch, donate=self.donate
        )
        self._pending = PendingPull(
            wss=wss, tables=t, accum=a, bstate=s, batch=batch,
            src=batch if src is None else src,
        )
        return self._pending

    def commit(self) -> PendingPull:
        """Take the pending pull for consumption by the train stage (the
        serialization point: its trees carry the only valid sparse state)."""
        p = self._pending
        if p is None:
            raise RuntimeError(
                "PrefetchingEngine.commit: no pull in flight — dispatch() "
                "one first (or run the synchronous pull path)"
            )
        self._pending = None
        return p
