"""Hierarchical hot/cold cache tier — the paper's §2.3 parameter hierarchy.

The paper's core systems claim is that terabyte tables never need to be
accelerator-resident: CTR traffic is Zipf-skewed, so a device cache holding
the hot working set (plus a host/disk tier holding everything) serves almost
all pulls locally.  ``CachedBackend`` is that placement behind the
``EmbeddingBackend`` contract:

  - the FULL table and its AdaGrad accumulator stay off-device — either
    host-committed full arrays threaded through pull/push (``HostStore``,
    the default) or row pages in a spill directory behind an in-RAM page
    cache (``DiskStore``, ``staged=True``: pull sees only the batch's
    working-set rows, staged by the store in dedup'd-uid order),
  - a fixed-size device cache of ``cache_rows`` slots holds the hottest rows
    together with their accumulator rows, an id->slot *linear-probe hash
    map* (``kernels.hash_map``, O(cache_rows) — not O(table_rows)),
    per-slot access-frequency counters, and dirty bits — all carried as a
    jit-traceable ``CacheState`` pytree through the compiled train step.

Per pull (one batched pass, no host round-trips per id):
  1. dedup the batch ids (shared ``_dedup``), probe every unique id in the
     hash map (``ops.hash_lookup`` — Pallas kernel or jnp oracle,
     bit-identical) — hits are served from the cache;
  2. LFU-with-decay eviction: frequencies decay by ``decay``, the coldest
     unprotected slots (never a slot hit by the current batch) are chosen
     with one ``top_k``; evicted *dirty* rows spill value+accumulator back
     to the host table in one batched scatter (or, staged, into explicit
     spill buffers the host applies to the DiskStore at commit);
  3. misses fetch value+accumulator rows from the host tier in ONE batched
     gather (staged: the rows are already uid-aligned) and are admitted
     into the victim slots; the hash map inserts the new (id, slot) pairs
     — reusing each id's stale bucket if it was cached before — and
     rebuilds itself from ``slot_uid`` when stale entries crowd the
     occupancy bound (``n_occupied + capacity > 3H/4``).

``push`` writes the AdaGrad row update through to the cache only (marking
slots dirty) with arithmetic bit-identical to ``SparseAdagrad.apply_rows``
— so with ``cache_rows >= table rows`` the backend never evicts and is
bit-identical to ``GatherBackend`` (asserted by ``tests/test_cache_tier``).
``flush`` writes all dirty rows back (checkpoint export / parity reads).

Host<->device traffic is metered in bytes (value + f32 accumulator rows per
miss fetch and per dirty spill) so ``benchmarks/fig_cache_hier.py`` can
reproduce the cache-size-vs-traffic story; the DiskStore adds page-cache
hit/miss and disk-byte meters below it for the three-level figure.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.embedding_backend import WorkingSet, _dedup, _with_drop_row
from repro.core.sparse_optim import SparseAdagrad
from repro.kernels import ref
from repro.kernels.hash_map import hash_insert, hash_rebuild, hash_table_size


class CacheState(NamedTuple):
    """Device-cache state for ONE table (a jit-traceable pytree).

    Everything is O(cache_rows): the id->slot index is the linear-probe
    hash map (``key_tab``/``slot_tab``, H = ``hash_table_size(C)`` buckets)
    instead of a dense (table_rows,) array.  An entry ``(k, s)`` is live
    iff ``slot_uid[s] == k`` — eviction kills entries by overwriting
    ``slot_uid``, and ``n_occupied`` (occupied buckets, including stale
    ones) triggers the occupancy-bounded rebuild.

    Counter convention: a "lookup" is one (non-dropped) id slot served this
    step; a fetched row serves every same-batch duplicate of its id, so
    ``hit_rate = 1 - fetched / lookups`` is the fraction of lookups served
    without host traffic.  Counters are f32 (monotonic, no x64 in jit).

    ``spill_uid`` exists for the staged (DiskStore) mode: the evicted-dirty
    ids whose rows ride out through the pull's table/accum outputs for the
    host to apply at the commit boundary.  Host mode keeps it 0-sized.
    """

    slot_uid: jnp.ndarray    # (C,) int32 — logical id held by each slot; -1 empty
    key_tab: jnp.ndarray     # (H,) int32 — hash bucket keys; -1 EMPTY
    slot_tab: jnp.ndarray    # (H,) int32 — hash bucket values (cache slots)
    n_occupied: jnp.ndarray  # () int32 — occupied buckets incl. stale entries
    rows: jnp.ndarray        # (C, dim) table dtype — cached row values
    accum: jnp.ndarray       # (C, dim) f32 — cached AdaGrad accumulator rows
    freq: jnp.ndarray        # (C,) f32 — LFU-with-decay counters
    dirty: jnp.ndarray       # (C,) bool — row updated since admission
    spill_uid: jnp.ndarray   # (capacity,) int32 staged mode; (0,) host mode
    lookups: jnp.ndarray     # () f32 — id slots served
    fetched: jnp.ndarray     # () f32 — unique rows fetched from host (misses)
    evictions: jnp.ndarray   # () f32 — occupied slots reassigned
    rebuilds: jnp.ndarray    # () f32 — hash-map occupancy rebuilds
    bytes_h2d: jnp.ndarray   # () f32 — host->device fetch traffic
    bytes_d2h: jnp.ndarray   # () f32 — device->host spill traffic


class CachedBackend:
    """Hot/cold placement: device cache over a host- or disk-resident table.

    Parameters
    ----------
    cache_rows: device cache size C in rows.  Must be >= the pull capacity
        (one batch's working set must fit) — enforced at trace time.
        ``cache_rows >= table rows`` degenerates to a full mirror that is
        bit-identical to ``GatherBackend``.
    decay: multiplicative LFU frequency decay per pull (1.0 = plain LFU;
        lower values forget stale heat faster — drifting Zipf heads).
    fused: serve the hash-map probe, the working-set row gather, and the
        push through the fused cache-tier Pallas kernels
        (``kernels.ops.hash_lookup`` / ``gather_rows_cached`` /
        ``sparse_adagrad_cached_apply``): the probe's id→slot output IS the
        index stream of the gather/scatter kernels, so the (capacity, dim)
        data moves in ONE indexed pass with no slot-translate materialized
        — and the push applies AdaGrad straight into the aliased cache
        buffers.  Bit-identical to the unfused path (same map contents,
        same pinned row math).
    staged: DiskStore mode.  The pull's ``table``/``accum`` inputs are the
        batch working-set rows staged in dedup'd-uid order (not the full
        table); miss fetches read them positionally, and evicted-dirty rows
        leave through the pull's table/accum OUTPUTS (ids in
        ``state.spill_uid``) for the host to write behind at the commit
        boundary.  Requires ``capacity`` (sizes the spill buffers).
    capacity: the pull capacity, required (and only used) when ``staged``.
    """

    def __init__(self, cache_rows: int, decay: float = 0.95,
                 fused: bool = False, staged: bool = False,
                 capacity: Optional[int] = None):
        if cache_rows <= 0:
            raise ValueError(f"cache_rows must be positive, got {cache_rows}")
        if not 0.0 < decay <= 1.0:
            raise ValueError(f"decay must be in (0, 1], got {decay}")
        if staged and not capacity:
            raise ValueError("staged CachedBackend requires capacity "
                             "(sizes the per-pull spill buffers)")
        self.cache_rows = int(cache_rows)
        self.decay = float(decay)
        self.fused = bool(fused)
        self.staged = bool(staged)
        self.capacity = int(capacity) if capacity else None
        self.hash_buckets = hash_table_size(self.cache_rows)

    # tables stay in logical row layout; the hierarchy lives in CacheState
    def prepare(self, table: jnp.ndarray) -> jnp.ndarray:
        return table

    def export(self, table: jnp.ndarray) -> jnp.ndarray:
        return table

    def init_state(self, table: jnp.ndarray) -> CacheState:
        dim = table.shape[1]
        C = self.cache_rows
        H = self.hash_buckets
        spill_cap = self.capacity if self.staged else 0
        # counters get DISTINCT buffers: the state pytree is donated into
        # the compiled pull stage, and donating one shared zero six times
        # is an XLA error ("attempt to donate the same buffer twice")
        z = lambda: jnp.zeros((), jnp.float32)
        return CacheState(
            slot_uid=jnp.full((C,), -1, jnp.int32),
            key_tab=jnp.full((H,), -1, jnp.int32),
            slot_tab=jnp.zeros((H,), jnp.int32),
            n_occupied=jnp.zeros((), jnp.int32),
            rows=jnp.zeros((C, dim), table.dtype),
            accum=jnp.zeros((C, dim), jnp.float32),
            freq=jnp.zeros((C,), jnp.float32),
            dirty=jnp.zeros((C,), bool),
            spill_uid=jnp.full((spill_cap,), -1, jnp.int32),
            lookups=z(), fetched=z(), evictions=z(), rebuilds=z(),
            bytes_h2d=z(), bytes_d2h=z(),
        )

    def _row_bytes(self, table: jnp.ndarray) -> int:
        # one row moved = value row + its f32 accumulator row
        return table.shape[1] * (jnp.dtype(table.dtype).itemsize + 4)

    def _lookup(self, key_tab, slot_tab, slot_uid, uids):
        if self.fused:
            from repro.kernels import ops

            return ops.hash_lookup(key_tab, slot_tab, slot_uid, uids)
        return ref.hash_lookup_ref(key_tab, slot_tab, slot_uid, uids)

    def pull(self, table, accum, state: CacheState, flat_ids, capacity: int):
        C = self.cache_rows
        if C < capacity:
            raise ValueError(
                f"cache_rows ({C}) must cover the pull capacity ({capacity}): "
                f"one batch's working set must fit in the device cache"
            )
        if self.staged and table.shape[0] != capacity:
            raise ValueError(
                f"staged pull expects ({capacity}, dim) working-set rows "
                f"from the RowStore, got {table.shape}"
            )
        H = self.hash_buckets
        n_rows = table.shape[0]
        uids, inverse, n_dropped = _dedup(flat_ids, capacity)
        # dedup pads by repeating an already-present id: count each unique id
        # once (strictly-increasing positions; pads repeat an earlier value)
        valid = jnp.concatenate(
            [jnp.ones((1,), bool), uids[1:] > uids[:-1]]
        )

        # ---- hash-map occupancy rebuild: stale entries (evicted ids) pile
        # up because liveness is checked, not deleted; rebuilding from
        # slot_uid before occupancy can cross 3H/4 keeps every probe chain
        # EMPTY-terminated and every insert placeable.
        need_rebuild = state.n_occupied + capacity > (3 * H) // 4
        key_tab, slot_tab, n_occ = jax.lax.cond(
            need_rebuild,
            lambda: hash_rebuild(state.slot_uid, H),
            lambda: (state.key_tab, state.slot_tab, state.n_occupied),
        )

        slot = self._lookup(key_tab, slot_tab, state.slot_uid, uids)
        hit = valid & (slot >= 0)
        miss = valid & (slot < 0)
        n_miss = jnp.sum(miss.astype(jnp.int32))
        # per-uid lookup multiplicity (dropped slots point at `capacity`)
        counts = jnp.zeros((capacity + 1,), jnp.float32).at[inverse].add(1.0)[
            :capacity
        ]

        # ---- LFU-with-decay victim selection (empty slots first, then the
        # coldest; slots hit by THIS batch are never evicted)
        freq = state.freq * self.decay
        score = jnp.where(state.slot_uid < 0, -1.0, freq)
        protected = (
            jnp.zeros((C,), bool)
            .at[jnp.where(hit, slot, C)]
            .set(True, mode="drop")
        )
        score = jnp.where(protected, jnp.inf, score)
        _, victims = jax.lax.top_k(-score, capacity)     # coldest-first slots
        used = jnp.arange(capacity) < n_miss             # victims we admit into
        v_old = state.slot_uid[victims]
        evict = used & (v_old >= 0)
        spill = evict & state.dirty[victims]

        # ---- spill evicted dirty rows back to the cold tier
        if self.staged:
            # rows leave through the pull outputs; the host scatters them
            # into the DiskStore page cache at the commit boundary
            spill_uid = jnp.where(spill, v_old, -1)
            new_table = state.rows[victims].astype(table.dtype)
            new_haccum = state.accum[victims]
            fetched_rows = table      # staged working-set rows, uid-aligned
            fetched_accum = accum
        else:
            # one batched scatter into the host-resident table
            spill_idx = jnp.where(spill, v_old, n_rows)
            new_table = table.at[spill_idx].set(
                state.rows[victims].astype(table.dtype), mode="drop"
            )
            new_haccum = accum.at[spill_idx].set(
                state.accum[victims], mode="drop")

        # ---- fetch misses from the cold tier in ONE batched gather
        miss_rank = jnp.cumsum(miss.astype(jnp.int32)) - 1
        target = jnp.where(
            miss, victims[jnp.clip(miss_rank, 0, capacity - 1)], C
        )
        if not self.staged:
            fetch_idx = jnp.where(miss, uids, 0)
            fetched_rows = jnp.take(new_table, fetch_idx, axis=0)
            fetched_accum = jnp.take(new_haccum, fetch_idx, axis=0)

        # ---- admit: install rows, reset heat, insert (id, slot) pairs
        slot_uid = state.slot_uid.at[target].set(uids, mode="drop")
        cache_rows = state.rows.at[target].set(fetched_rows, mode="drop")
        cache_accum = state.accum.at[target].set(fetched_accum, mode="drop")
        dirty = state.dirty.at[target].set(False, mode="drop")
        freq = freq.at[target].set(0.0, mode="drop")
        key_tab, slot_tab, n_occ = hash_insert(
            key_tab, slot_tab, n_occ, uids, target, miss
        )
        # every working-set id is now cached: hits keep their probed slot,
        # misses took their victim slot, and dedup pads (repeats of the
        # first uid) share the first position's slot — no second probe.
        slot0 = jnp.where(miss[0], target[0], slot[0])
        slot_now = jnp.where(valid, jnp.where(miss, target, slot), slot0)
        freq = freq.at[slot_now].add(counts, mode="drop")

        if self.fused:
            from repro.kernels import ops

            # the probe output drives the kernel's index stream directly
            wrows = ops.gather_rows_cached(cache_rows, slot_now)
        else:
            wrows = jnp.take(cache_rows, slot_now, axis=0)
        rb = self._row_bytes(table)
        new_state = CacheState(
            slot_uid=slot_uid, key_tab=key_tab, slot_tab=slot_tab,
            n_occupied=n_occ, rows=cache_rows, accum=cache_accum,
            freq=freq, dirty=dirty,
            spill_uid=spill_uid if self.staged else state.spill_uid,
            lookups=state.lookups + jnp.sum(counts),
            fetched=state.fetched + n_miss.astype(jnp.float32),
            evictions=state.evictions + jnp.sum(evict.astype(jnp.float32)),
            rebuilds=state.rebuilds + need_rebuild.astype(jnp.float32),
            bytes_h2d=state.bytes_h2d + n_miss.astype(jnp.float32) * rb,
            bytes_d2h=state.bytes_d2h
            + jnp.sum(spill.astype(jnp.float32)) * rb,
        )
        ws = WorkingSet(uids, inverse, _with_drop_row(wrows), n_dropped)
        return ws, new_table, new_haccum, new_state

    def lookup(self, table, accum, state: CacheState, flat_ids, capacity: int):
        """Read-only serving lookup — the MixCache read side.

        Probes the hash map exactly like ``pull`` but ADMITS NOTHING: hits
        are served from the cached rows (which hold the freshest values —
        push writes through to the cache, so a trained row serves
        immediately), misses fall through to the cold tier (the host table,
        or the uid-aligned staged rows under the DiskStore).  The
        fallthrough is exact by construction: a row absent from the cache
        cannot be dirty (eviction spills dirty rows before killing their
        map entry), so the cold tier holds its authoritative value.  No
        state is returned because none changes: no admission, no eviction,
        no rebuild, no counters — the training trajectory is invariant
        under any interleaving of lookups."""
        C = self.cache_rows
        if C < capacity:
            raise ValueError(
                f"cache_rows ({C}) must cover the lookup capacity "
                f"({capacity}): one batch's working set must fit in the "
                f"device cache"
            )
        if self.staged and table.shape[0] != capacity:
            raise ValueError(
                f"staged lookup expects ({capacity}, dim) working-set rows "
                f"from the RowStore, got {table.shape}"
            )
        uids, inverse, n_dropped = _dedup(flat_ids, capacity)
        valid = jnp.concatenate(
            [jnp.ones((1,), bool), uids[1:] > uids[:-1]]
        )
        slot = self._lookup(state.key_tab, state.slot_tab, state.slot_uid, uids)
        hit = slot >= 0
        safe = jnp.where(hit, slot, 0)
        if self.fused:
            from repro.kernels import ops

            cached = ops.gather_rows_cached(state.rows, safe)
        else:
            cached = jnp.take(state.rows, safe, axis=0)
        if self.staged:
            cold = table          # staged working-set rows, uid-aligned
        else:
            cold = jnp.take(table, uids, axis=0)
        wrows = jnp.where(hit[:, None], cached, cold)
        ws = WorkingSet(uids, inverse, _with_drop_row(wrows), n_dropped)
        # served id slots / unique cold-tier reads, metered separately from
        # the training counters (which live in state and stay untouched)
        counts = jnp.zeros((capacity + 1,), jnp.float32).at[inverse].add(1.0)[
            :capacity
        ]
        aux = {
            "serve_lookups": jnp.sum(counts),
            "serve_misses": jnp.sum((valid & ~hit).astype(jnp.float32)),
        }
        return ws, aux

    def push(self, table, accum, state: CacheState, ws: WorkingSet, row_grads,
             opt: SparseAdagrad):
        """Write-through to the CACHE only (the cold tier sees the update at
        spill or flush time): the same ``SparseAdagrad.apply_rows`` update
        as the gather placement, applied to the cached rows via the hash
        map — bit-identical arithmetic by construction."""
        uids = ws.uids
        # all working-set ids are live in the map after the matching pull
        slot = self._lookup(
            state.key_tab, state.slot_tab, state.slot_uid, uids)
        if self.fused:
            from repro.kernels import ops

            new_rows, new_accum = ops.sparse_adagrad_cached_apply(
                state.rows, state.accum, slot,
                row_grads[: uids.shape[0]],
                lr=opt.cfg.lr, eps=opt.cfg.eps,
            )
        else:
            new_rows, new_accum = opt.apply_rows(
                state.rows, state.accum, slot, row_grads[: uids.shape[0]]
            )
        new_state = state._replace(
            rows=new_rows, accum=new_accum,
            dirty=state.dirty.at[slot].set(True),
        )
        return table, accum, new_state

    def flush(self, table, accum, state: CacheState):
        """Write every dirty cached row (value + accumulator) back to the
        cold tier — checkpoint/export consistency point.

        Staged mode: the host applies the dirty rows to the DiskStore
        itself (it reads ``slot_uid``/``dirty``/``rows``/``accum`` from the
        state *before* calling this — see ``EmbeddingEngine.flush``); here
        only the dirty bits clear and the spill meter advances.
        """
        dirty_occ = state.dirty & (state.slot_uid >= 0)
        n = jnp.sum(dirty_occ.astype(jnp.float32))
        if self.staged:
            new_table, new_accum = table, accum
        else:
            n_rows = table.shape[0]
            idx = jnp.where(dirty_occ, state.slot_uid, n_rows)
            new_table = table.at[idx].set(
                state.rows.astype(table.dtype), mode="drop")
            new_accum = accum.at[idx].set(state.accum, mode="drop")
        new_state = state._replace(
            dirty=jnp.zeros_like(state.dirty),
            bytes_d2h=state.bytes_d2h + n * self._row_bytes(table),
        )
        return new_table, new_accum, new_state

    def stats(self, state: CacheState) -> dict:
        """Raw counters as python floats (call OUTSIDE jit).

        One explicit ``jax.device_get`` materializes all six scalars in a
        single deliberate d2h hop — strict-transfers-clean, where per-field
        ``float()`` would be six implicit syncs."""
        got = jax.device_get({
            "lookups": state.lookups,
            "fetched": state.fetched,
            "evictions": state.evictions,
            "rebuilds": state.rebuilds,
            "bytes_h2d": state.bytes_h2d,
            "bytes_d2h": state.bytes_d2h,
        })
        return {k: float(v) for k, v in got.items()}
