"""Hierarchical hot/cold cache tier — the paper's §2.3 parameter hierarchy.

The paper's core systems claim is that terabyte tables never need to be
accelerator-resident: CTR traffic is Zipf-skewed, so a device cache holding
the hot working set (plus a host tier holding everything) serves almost all
pulls locally.  ``CachedBackend`` is that placement behind the
``EmbeddingBackend`` contract:

  - the FULL table and its AdaGrad accumulator stay host-committed (they are
    threaded through pull/push untouched except for cache spills — on a real
    accelerator they would be ``jax.device_put`` to the host platform and
    touched only by the miss gather / spill scatter DMAs),
  - a fixed-size device cache of ``cache_rows`` slots holds the hottest rows
    together with their accumulator rows, an id->slot map, per-slot
    access-frequency counters, and dirty bits — all carried as a
    jit-traceable ``CacheState`` pytree through the compiled train step.

Per pull (one batched pass, no host round-trips per id):
  1. dedup the batch ids (shared ``_dedup``), look every unique id up in the
     id->slot map — hits are served from the cache;
  2. LFU-with-decay eviction: frequencies decay by ``decay``, the coldest
     unprotected slots (never a slot hit by the current batch) are chosen
     with one ``top_k``; evicted *dirty* rows spill value+accumulator back
     to the host table in one batched scatter;
  3. misses fetch value+accumulator rows from host in ONE batched gather
     and are admitted into the victim slots.

``push`` writes the AdaGrad row update through to the cache only (marking
slots dirty) with arithmetic bit-identical to ``SparseAdagrad.apply_rows``
— so with ``cache_rows >= table rows`` the backend never evicts and is
bit-identical to ``GatherBackend`` (asserted by ``tests/test_cache_tier``).
``flush`` writes all dirty rows back (checkpoint export / parity reads).

Host<->device traffic is metered in bytes (value + f32 accumulator rows per
miss fetch and per dirty spill) so ``benchmarks/fig_cache_hier.py`` can
reproduce the cache-size-vs-traffic story.  At true 1e11-row scale the dense
``id_slot`` map would be a device hash table; at repro scale the dense int32
map (4 bytes/row vs 260+ bytes/row for value+accum) keeps it simple.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.embedding_backend import WorkingSet, _dedup, _with_drop_row
from repro.core.sparse_optim import SparseAdagrad


class CacheState(NamedTuple):
    """Device-cache state for ONE table (a jit-traceable pytree).

    Counter convention: a "lookup" is one (non-dropped) id slot served this
    step; a fetched row serves every same-batch duplicate of its id, so
    ``hit_rate = 1 - fetched / lookups`` is the fraction of lookups served
    without host traffic.  Counters are f32 (monotonic, no x64 in jit).
    """

    slot_uid: jnp.ndarray    # (C,) int32 — logical id held by each slot; -1 empty
    id_slot: jnp.ndarray     # (rows,) int32 — id -> slot; -1 not cached
    rows: jnp.ndarray        # (C, dim) table dtype — cached row values
    accum: jnp.ndarray       # (C, dim) f32 — cached AdaGrad accumulator rows
    freq: jnp.ndarray        # (C,) f32 — LFU-with-decay counters
    dirty: jnp.ndarray       # (C,) bool — row updated since admission
    lookups: jnp.ndarray     # () f32 — id slots served
    fetched: jnp.ndarray     # () f32 — unique rows fetched from host (misses)
    evictions: jnp.ndarray   # () f32 — occupied slots reassigned
    bytes_h2d: jnp.ndarray   # () f32 — host->device fetch traffic
    bytes_d2h: jnp.ndarray   # () f32 — device->host spill traffic


class CachedBackend:
    """Hot/cold placement: device cache over a host-resident table.

    Parameters
    ----------
    cache_rows: device cache size C in rows.  Must be >= the pull capacity
        (one batch's working set must fit) — enforced at trace time.
        ``cache_rows >= table rows`` degenerates to a full mirror that is
        bit-identical to ``GatherBackend``.
    decay: multiplicative LFU frequency decay per pull (1.0 = plain LFU;
        lower values forget stale heat faster — drifting Zipf heads).
    fused: serve the working-set row gather and the push through the fused
        cache-tier Pallas kernels (``kernels.ops.gather_rows_cached`` /
        ``sparse_adagrad_cached_apply``): the id→slot indirection is folded
        into the kernel's index stream, so the (capacity, dim) data moves in
        ONE indexed pass instead of slot-translate-then-gather — and the
        push applies AdaGrad straight into the aliased cache buffers.
        Bit-identical to the unfused path (same pinned row math).
    """

    def __init__(self, cache_rows: int, decay: float = 0.95,
                 fused: bool = False):
        if cache_rows <= 0:
            raise ValueError(f"cache_rows must be positive, got {cache_rows}")
        if not 0.0 < decay <= 1.0:
            raise ValueError(f"decay must be in (0, 1], got {decay}")
        self.cache_rows = int(cache_rows)
        self.decay = float(decay)
        self.fused = bool(fused)

    # tables stay in logical row layout; the hierarchy lives in CacheState
    def prepare(self, table: jnp.ndarray) -> jnp.ndarray:
        return table

    def export(self, table: jnp.ndarray) -> jnp.ndarray:
        return table

    def init_state(self, table: jnp.ndarray) -> CacheState:
        n_rows, dim = table.shape
        C = self.cache_rows
        # counters get DISTINCT buffers: the state pytree is donated into
        # the compiled pull stage, and donating one shared zero five times
        # is an XLA error ("attempt to donate the same buffer twice")
        z = lambda: jnp.zeros((), jnp.float32)
        return CacheState(
            slot_uid=jnp.full((C,), -1, jnp.int32),
            id_slot=jnp.full((n_rows,), -1, jnp.int32),
            rows=jnp.zeros((C, dim), table.dtype),
            accum=jnp.zeros((C, dim), jnp.float32),
            freq=jnp.zeros((C,), jnp.float32),
            dirty=jnp.zeros((C,), bool),
            lookups=z(), fetched=z(), evictions=z(), bytes_h2d=z(), bytes_d2h=z(),
        )

    def _row_bytes(self, table: jnp.ndarray) -> int:
        # one row moved = value row + its f32 accumulator row
        return table.shape[1] * (jnp.dtype(table.dtype).itemsize + 4)

    def pull(self, table, accum, state: CacheState, flat_ids, capacity: int):
        C = self.cache_rows
        if C < capacity:
            raise ValueError(
                f"cache_rows ({C}) must cover the pull capacity ({capacity}): "
                f"one batch's working set must fit in the device cache"
            )
        n_rows = table.shape[0]
        uids, inverse, n_dropped = _dedup(flat_ids, capacity)
        # dedup pads by repeating an already-present id: count each unique id
        # once (strictly-increasing positions; pads repeat an earlier value)
        valid = jnp.concatenate(
            [jnp.ones((1,), bool), uids[1:] > uids[:-1]]
        )
        slot = state.id_slot[uids]                       # (capacity,)
        hit = valid & (slot >= 0)
        miss = valid & (slot < 0)
        n_miss = jnp.sum(miss.astype(jnp.int32))
        # per-uid lookup multiplicity (dropped slots point at `capacity`)
        counts = jnp.zeros((capacity + 1,), jnp.float32).at[inverse].add(1.0)[
            :capacity
        ]

        # ---- LFU-with-decay victim selection (empty slots first, then the
        # coldest; slots hit by THIS batch are never evicted)
        freq = state.freq * self.decay
        score = jnp.where(state.slot_uid < 0, -1.0, freq)
        protected = (
            jnp.zeros((C,), bool)
            .at[jnp.where(hit, slot, C)]
            .set(True, mode="drop")
        )
        score = jnp.where(protected, jnp.inf, score)
        _, victims = jax.lax.top_k(-score, capacity)     # coldest-first slots
        used = jnp.arange(capacity) < n_miss             # victims we admit into
        v_old = state.slot_uid[victims]
        evict = used & (v_old >= 0)
        spill = evict & state.dirty[victims]

        # ---- spill evicted dirty rows back to host (one batched scatter)
        spill_idx = jnp.where(spill, v_old, n_rows)
        new_table = table.at[spill_idx].set(
            state.rows[victims].astype(table.dtype), mode="drop"
        )
        new_haccum = accum.at[spill_idx].set(state.accum[victims], mode="drop")
        id_slot = state.id_slot.at[jnp.where(evict, v_old, n_rows)].set(
            -1, mode="drop"
        )

        # ---- fetch misses from host in ONE batched gather (value + accum)
        miss_rank = jnp.cumsum(miss.astype(jnp.int32)) - 1
        target = jnp.where(
            miss, victims[jnp.clip(miss_rank, 0, capacity - 1)], C
        )
        fetch_idx = jnp.where(miss, uids, 0)
        fetched_rows = jnp.take(new_table, fetch_idx, axis=0)
        fetched_accum = jnp.take(new_haccum, fetch_idx, axis=0)

        # ---- admit: map ids to their new slots, install rows, reset heat
        slot_uid = state.slot_uid.at[target].set(uids, mode="drop")
        cache_rows = state.rows.at[target].set(fetched_rows, mode="drop")
        cache_accum = state.accum.at[target].set(fetched_accum, mode="drop")
        dirty = state.dirty.at[target].set(False, mode="drop")
        freq = freq.at[target].set(0.0, mode="drop")
        id_slot = id_slot.at[jnp.where(miss, uids, n_rows)].set(
            target, mode="drop"
        )
        # every working-set id is now cached; touch its slot by multiplicity
        slot_now = id_slot[uids]
        freq = freq.at[slot_now].add(counts, mode="drop")

        if self.fused:
            from repro.kernels import ops

            # id→slot indirection folded into the kernel's index stream
            wrows = ops.gather_rows_cached(cache_rows, id_slot, uids)
        else:
            wrows = jnp.take(cache_rows, slot_now, axis=0)
        rb = self._row_bytes(table)
        new_state = CacheState(
            slot_uid=slot_uid, id_slot=id_slot, rows=cache_rows,
            accum=cache_accum, freq=freq, dirty=dirty,
            lookups=state.lookups + jnp.sum(counts),
            fetched=state.fetched + n_miss.astype(jnp.float32),
            evictions=state.evictions + jnp.sum(evict.astype(jnp.float32)),
            bytes_h2d=state.bytes_h2d + n_miss.astype(jnp.float32) * rb,
            bytes_d2h=state.bytes_d2h
            + jnp.sum(spill.astype(jnp.float32)) * rb,
        )
        ws = WorkingSet(uids, inverse, _with_drop_row(wrows), n_dropped)
        return ws, new_table, new_haccum, new_state

    def push(self, table, accum, state: CacheState, ws: WorkingSet, row_grads,
             opt: SparseAdagrad):
        """Write-through to the CACHE only (host sees the update at spill or
        flush time): the same ``SparseAdagrad.apply_rows`` update as the
        gather placement, applied to the cached rows via the id->slot map —
        bit-identical arithmetic by construction."""
        uids = ws.uids
        slot = state.id_slot[uids]          # all cached after the pull
        if self.fused:
            from repro.kernels import ops

            new_rows, new_accum = ops.sparse_adagrad_cached_apply(
                state.rows, state.accum, state.id_slot, uids,
                row_grads[: uids.shape[0]],
                lr=opt.cfg.lr, eps=opt.cfg.eps,
            )
        else:
            new_rows, new_accum = opt.apply_rows(
                state.rows, state.accum, slot, row_grads[: uids.shape[0]]
            )
        new_state = state._replace(
            rows=new_rows, accum=new_accum,
            dirty=state.dirty.at[slot].set(True),
        )
        return table, accum, new_state

    def flush(self, table, accum, state: CacheState):
        """Write every dirty cached row (value + accumulator) back to host —
        checkpoint/export consistency point."""
        n_rows = table.shape[0]
        dirty_occ = state.dirty & (state.slot_uid >= 0)
        idx = jnp.where(dirty_occ, state.slot_uid, n_rows)
        new_table = table.at[idx].set(state.rows.astype(table.dtype), mode="drop")
        new_accum = accum.at[idx].set(state.accum, mode="drop")
        n = jnp.sum(dirty_occ.astype(jnp.float32))
        new_state = state._replace(
            dirty=jnp.zeros_like(state.dirty),
            bytes_d2h=state.bytes_d2h + n * self._row_bytes(table),
        )
        return new_table, new_accum, new_state

    def stats(self, state: CacheState) -> dict:
        """Raw counters as python floats (call OUTSIDE jit).

        One explicit ``jax.device_get`` materializes all five scalars in a
        single deliberate d2h hop — strict-transfers-clean, where per-field
        ``float()`` would be five implicit syncs."""
        got = jax.device_get({
            "lookups": state.lookups,
            "fetched": state.fetched,
            "evictions": state.evictions,
            "bytes_h2d": state.bytes_h2d,
            "bytes_d2h": state.bytes_d2h,
        })
        return {k: float(v) for k, v in got.items()}
