"""k-step Adam model merging — Algorithm 2 of Zhao et al. (2022).

Each of the N workers ("pods" here — the slow-fabric boundary on TPU) runs
*local* Adam steps; every k steps all workers average their parameters AND
their second-moment estimates, then continue from the merged point.  Between
merges the denominator uses a *frozen shared* second moment ``v_hat`` (the
paper's ``v_t = v_{t-1}`` branch), while each worker keeps its local EMA
``v_local`` running; at a merge round ``v_hat <- mean_i v_local_i`` and
``x <- mean_i (x_i - lr * m_i / sqrt(v_hat))`` (lines 11-13).

Representation ("podded" trees): every dense parameter and optimizer moment
carries a leading pod dimension ``(n_pod, *shape)``.  Under pjit/GSPMD that
dimension is sharded over the mesh's ``pod`` axis, so each pod physically
holds exactly its own replica (same per-chip bytes as plain replication) and
the merge lowers to a cross-pod all-reduce whose schedule is chosen by the
merge strategy (see ``repro.core.merge``).  On a single CPU device the same
code runs with any ``n_pod`` — that is how the paper's accuracy experiments
(Fig. 9/10) are reproduced in this repo.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import merge as merge_lib

Pytree = Any


@dataclasses.dataclass(frozen=True)
class KStepConfig:
    """Hyper-parameters of k-step Adam (paper defaults where stated)."""

    lr: float = 1e-3
    b1: float = 0.0          # paper §5: beta_1 = 0.0 for the dense tower
    b2: float = 0.999        # paper §5: beta_2 = 0.999
    eps: float = 1e-8        # Algorithm 2 line 2: v_0 = eps * 1
    k: int = 1               # merge every k local steps (k=1 == synchronous Adam)
    weight_decay: float = 0.0
    bias_correction: bool = False  # Algorithm 2 has none (v_0 = eps handles t=0)
    merge_v: bool = True     # paper: "the second moment ... is also averaged"
    merge: str = "flat"      # flat | two_phase | int8_ef | bf16
    grad_clip: float = 0.0   # global-norm clip (0 = off)
    # Deviation from the literal Algorithm 2 (documented in DESIGN.md): the
    # shared denominator v_hat is frozen at eps until the FIRST merge, which
    # from a cold start multiplies early updates by 1/sqrt(eps) ~ 1e4 (the
    # paper always hot-starts from a trained model, hiding this).  With
    # local_v_warmup the pre-first-merge local steps use the running local
    # EMA instead — identical to vanilla local Adam, and identical to
    # Algorithm 2 from the first merge onward.
    local_v_warmup: bool = True


class KStepAdamState(NamedTuple):
    step: jnp.ndarray       # scalar int32, number of completed local steps
    m: Pytree               # podded first moment  (n_pod, *shape) f32
    v_local: Pytree         # podded local second-moment EMA (n_pod, *shape) f32
    v_hat: Pytree           # podded *shared* denominator, frozen between merges
    ef: Optional[Pytree]    # error-feedback residual (int8_ef merge only)


def pod_replicate(tree: Pytree, n_pod: int) -> Pytree:
    """Stack identical replicas along a new leading pod dimension."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_pod,) + x.shape) + jnp.zeros((), x.dtype),
        tree,
    )


def pod_slice(tree: Pytree, i: int = 0) -> Pytree:
    """Extract one pod's replica (e.g. for eval / checkpoint export).

    Runs under jit: an eager ``x[i]`` dispatches dynamic_slice with its
    start index shipped host->device on every call, which serializes the
    per-step predict path (and trips ``transfer_guard('disallow')``).
    Static ``i`` bakes the slice into the compiled executable instead.
    """
    return _pod_slice_jit(tree, i)


@partial(jax.jit, static_argnums=(1,), donate_argnums=())
def _pod_slice_jit(tree: Pytree, i: int) -> Pytree:
    return jax.tree.map(lambda x: x[i], tree)


def pod_consensus_error(tree: Pytree) -> jnp.ndarray:
    """sum_i ||x_i - mean(x)||^2 — the quantity bounded by Eq. (10)."""
    def leaf(x):
        mu = jnp.mean(x, axis=0, keepdims=True)
        return jnp.sum((x - mu) ** 2)
    return sum(jax.tree.leaves(jax.tree.map(leaf, tree)))


class KStepAdam:
    """Functional k-step Adam over podded parameter trees.

    Parameters
    ----------
    cfg: KStepConfig
    n_pod: number of local workers (size of the mesh 'pod' axis, or a pure
        algorithmic worker count when running on a single device).
    mesh / pod_axis / inner_axes: only needed for the topology-aware merge
        schedules ('two_phase'); ``None`` mesh falls back to plain means,
        which GSPMD still lowers to cross-pod all-reduces.
    lr_schedule: optional callable step->lr overriding cfg.lr.
    """

    def __init__(
        self,
        cfg: KStepConfig,
        n_pod: int,
        mesh: Optional[jax.sharding.Mesh] = None,
        pod_axis: str = "pod",
        inner_axes: tuple = ("data", "model"),
        lr_schedule: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
        param_specs=None,   # inner (pod-less) PartitionSpec tree, optional
        manual_pod: bool = False,  # running inside shard_map over 'pod'
    ):
        self.cfg = cfg
        self.n_pod = int(n_pod)
        self.mesh = mesh
        self.pod_axis = pod_axis
        self.inner_axes = inner_axes
        self.lr_schedule = lr_schedule
        self.manual_pod = manual_pod
        if manual_pod:
            # pod is a manual shard_map axis: merge = lax.pmean('pod'); with
            # auto-sharded inner dims this is two-phase by construction.
            self._mean = lambda tree, allow_lossy=True: merge_lib.pmean_mean(
                tree, pod_axis
            )
        else:
            self._mean = merge_lib.make_merge_fn(
                cfg.merge, mesh=mesh, pod_axis=pod_axis, inner_axes=inner_axes,
                param_specs=param_specs,
            )

    # ------------------------------------------------------------------ init
    def init(self, params_podded: Pytree) -> KStepAdamState:
        f32 = lambda x: jnp.zeros(x.shape, jnp.float32)
        m = jax.tree.map(f32, params_podded)
        v0 = jax.tree.map(
            lambda x: jnp.full(x.shape, self.cfg.eps, jnp.float32), params_podded
        )
        v_hat = jax.tree.map(jnp.copy, v0)  # distinct buffers (donation-safe)
        ef = (
            jax.tree.map(f32, params_podded)
            if self.cfg.merge == "int8_ef"
            else None
        )
        return KStepAdamState(
            step=jnp.zeros((), jnp.int32), m=m, v_local=v0, v_hat=v_hat, ef=ef
        )

    # ------------------------------------------------------------- one step
    def step(
        self,
        params: Pytree,
        grads: Pytree,
        state: KStepAdamState,
        merge: Optional[bool] = None,
    ):
        """Apply one local Adam step; merge across pods when due.

        ``merge=None`` keeps the k-step decision inside the program via
        ``lax.cond`` (single compiled step).  ``merge=True/False`` makes the
        decision static — the trainer compiles a *local* executable and a
        *merge* executable, which keeps the big cross-pod collective out of
        the hot local step entirely (and makes dry-run byte attribution
        exact).
        """
        cfg = self.cfg
        t = state.step + 1
        lr = self.lr_schedule(t) if self.lr_schedule else cfg.lr

        if cfg.grad_clip > 0.0:
            # Per-pod global-norm clip (each replica clips its own gradient).
            def pod_sq(g):
                g32 = g.astype(jnp.float32)
                return jnp.sum(g32 * g32, axis=tuple(range(1, g.ndim)))
            norms = jnp.sqrt(sum(jax.tree.leaves(jax.tree.map(pod_sq, grads))))
            scale = jnp.minimum(1.0, cfg.grad_clip / (norms + 1e-12))
            bshape = lambda g: (self.n_pod,) + (1,) * (g.ndim - 1)
            grads = jax.tree.map(
                lambda g: (g.astype(jnp.float32) * scale.reshape(bshape(g))).astype(g.dtype),
                grads,
            )

        # Moment updates (Algorithm 2 lines 5-6) — always local.
        m = jax.tree.map(
            lambda mm, g: cfg.b1 * mm + (1.0 - cfg.b1) * g.astype(jnp.float32),
            state.m, grads,
        )
        v_local = jax.tree.map(
            lambda vv, g: cfg.b2 * vv
            + (1.0 - cfg.b2) * jnp.square(g.astype(jnp.float32)),
            state.v_local, grads,
        )

        if cfg.bias_correction:
            mhat_s = 1.0 / (1.0 - cfg.b1 ** t.astype(jnp.float32)) if cfg.b1 > 0 else 1.0
            vhat_s = 1.0 / (1.0 - cfg.b2 ** t.astype(jnp.float32))
        else:
            mhat_s = 1.0
            vhat_s = 1.0

        def adam_delta(mm, vh, p):
            d = lr * (mm * mhat_s) / jnp.sqrt(vh * vhat_s)
            if cfg.weight_decay > 0.0:
                d = d + lr * cfg.weight_decay * p.astype(jnp.float32)
            return d

        def local_branch(m, v_local, v_hat, params, ef):
            if cfg.local_v_warmup:
                pre_first_merge = t <= cfg.k
                v_use = jax.tree.map(
                    lambda vh, vl: jnp.where(pre_first_merge, vl, vh), v_hat, v_local
                )
            else:
                v_use = v_hat
            new_p = jax.tree.map(
                lambda p, mm, vh: (p.astype(jnp.float32) - adam_delta(mm, vh, p)).astype(p.dtype),
                params, m, v_use,
            )
            return new_p, v_hat, ef

        def merge_branch(m, v_local, v_hat, params, ef):
            # v_hat <- mean_i v_local  (line 12); the v payload rides the same
            # merge schedule as x but is never error-feedback-compressed
            # (positivity must be preserved).
            if cfg.merge_v:
                new_v_hat = self._mean(v_local, allow_lossy=False)
            else:
                new_v_hat = v_hat
            # x_i - lr * m_i / sqrt(v_hat_new)   then average (line 13)
            local_x = jax.tree.map(
                lambda p, mm, vh: p.astype(jnp.float32) - adam_delta(mm, vh, p),
                params, m, new_v_hat,
            )
            if cfg.merge == "int8_ef":
                merged, new_ef = merge_lib.int8_ef_mean(
                    local_x, ef, mesh=self.mesh, pod_axis=self.pod_axis,
                    inner_axes=self.inner_axes,
                )
            else:
                merged = self._mean(local_x, allow_lossy=True)
                new_ef = ef
            new_p = jax.tree.map(
                lambda p, mx: mx.astype(p.dtype), params, merged
            )
            return new_p, new_v_hat, new_ef

        if merge is None:
            is_merge = (t % cfg.k) == 0
            new_p, new_v_hat, new_ef = jax.lax.cond(
                is_merge,
                lambda: merge_branch(m, v_local, state.v_hat, params, state.ef),
                lambda: local_branch(m, v_local, state.v_hat, params, state.ef),
            )
        elif merge:
            new_p, new_v_hat, new_ef = merge_branch(m, v_local, state.v_hat, params, state.ef)
        else:
            new_p, new_v_hat, new_ef = local_branch(m, v_local, state.v_hat, params, state.ef)

        return new_p, KStepAdamState(
            step=t, m=m, v_local=v_local, v_hat=new_v_hat, ef=new_ef
        )

    # ----------------------------------------------------- delayed merging
    def delayed_merge_collective(self, params: Pytree, state: KStepAdamState):
        """Launch the cross-pod collective for a DELAYED merge.

        Returns ``(merged, state')``: the pod-average of the current params
        (to be applied ``merge_delay`` boundaries later through
        ``apply_delayed_merge``) and the state with the Algorithm-2 line-12
        ``v_hat <- mean_i v_local`` refresh, which applies immediately so
        the local Adam denominators stay fresh while the parameter average
        is in flight."""
        merged = self._mean(params, allow_lossy=True)
        if self.cfg.merge_v:
            state = state._replace(
                v_hat=self._mean(state.v_local, allow_lossy=False)
            )
        return merged, state

    @staticmethod
    def snapshot(params: Pytree) -> Pytree:
        """Record params at a merge boundary for async (delayed) application.

        A real COPY: the live params are donated into subsequent local
        steps, so the snapshot must own its buffers until the delayed merge
        lands."""
        return jax.tree.map(jnp.copy, params)

    @staticmethod
    def apply_delayed_merge(params_now, snapshot, merged):
        """Async merge (beyond paper): the cross-pod average computed at a
        past boundary is applied *late*, preserving the local drift since the
        snapshot:  x <- merged + (x_now - x_snapshot).  Lets the slow DCN
        collective overlap with subsequent local compute."""
        return jax.tree.map(
            lambda p, s, g: (g.astype(jnp.float32)
                             + (p.astype(jnp.float32) - s.astype(jnp.float32))).astype(p.dtype),
            params_now, snapshot, merged,
        )
