"""RowStore — the cold bottom of the three-level parameter hierarchy.

The paper's terabyte tables live on SSD, not in host RAM: host memory holds
only a page cache over the full table, and the device cache (cache_tier)
sits above that.  ``RowStore`` is the storage abstraction behind the
``EmbeddingBackend`` state contract:

``HostStore``
    Today's behavior, the default: tables are full jnp arrays threaded
    through pull/push; the store itself is a stateless tag.

``DiskStore``
    The SSD tier.  Per table, the full value table and its AdaGrad
    accumulator live in fixed-size row pages (``page_rows`` rows each) as
    ``page_%06d.npz`` files under ``<spill_dir>/<table>/``, behind an
    in-RAM LRU page cache (``page_cache_pages`` pages; ``None`` = unbounded
    — the full-mirror parity configuration).  Three IO disciplines keep
    disk latency off the critical path and crashes survivable:

    *read-ahead*: the prefetch pipeline knows next batch's dedup'd id
    stream before the device needs the rows; ``readahead(uids)`` queues the
    pages those uids live on for a background thread to fault in while the
    device is still training on the previous batch, so the blocking
    ``gather`` call finds them warm.

    *write-behind*: ``scatter`` updates pages in the RAM cache and marks
    them dirty; pages are persisted by a background writer either on LRU
    eviction or at ``flush()``.  Reads of a page mid-write are served from
    an in-flight lookaside copy — never from a half-written file.

    *rename-aside page writes*: every page write goes to ``<page>.tmp``
    (+fsync) then ``os.replace`` onto the final name, matching
    ``checkpoint/ckpt.py`` semantics — a kill mid write-behind leaves
    either the old complete page or the new complete page, plus at worst a
    stray ``.tmp`` that ``__init__`` and the CheckpointManager GC sweep.

    Background-thread exceptions are captured and re-raised on the next
    API call (the CheckpointManager idiom) — IO errors surface at commit
    boundaries instead of killing daemon threads silently.

All IO is host-side numpy at commit boundaries; nothing here runs under
jit.  Byte/hit meters (``stats()``) feed ``benchmarks/fig_cache_hier.py``'s
three-level sweep.  See docs/storage.md for the full hierarchy story.
"""

from __future__ import annotations

import collections
import os
import queue
import threading
from typing import Dict, Iterable, Optional, Tuple

import numpy as np

_PAGE_FMT = "page_%06d.npz"


class HostStore:
    """Host-RAM resident tables (the default) — a stateless placement tag.

    The engine threads full jnp tables through pull/push exactly as before;
    the store participates in nothing and meters nothing.
    """

    kind = "host"

    def close(self):
        pass

    def flush(self):
        pass

    def stats(self) -> dict:
        return {}

    def serve_stats(self) -> dict:
        return {}


class _TableFile:
    """One table's page set under ``<root>/<name>/`` + its dirty/meta state."""

    def __init__(self, root: str, name: str, rows: int, dim: int,
                 dtype: np.dtype, page_rows: int):
        self.dir = os.path.join(root, name)
        self.rows = int(rows)
        self.dim = int(dim)
        self.dtype = np.dtype(dtype)
        self.page_rows = int(page_rows)
        self.n_pages = -(-self.rows // self.page_rows)  # ceil div
        os.makedirs(self.dir, exist_ok=True)

    def page_path(self, p: int) -> str:
        return os.path.join(self.dir, _PAGE_FMT % p)

    def page_len(self, p: int) -> int:
        """Rows in page p (the last page may be short)."""
        return min(self.page_rows, self.rows - p * self.page_rows)


class DiskStore:
    """Paged spill-directory row store with read-ahead and write-behind.

    Parameters
    ----------
    spill_dir: directory holding one subdirectory of pages per table.
    page_rows: rows per page file.
    page_cache_pages: RAM page-cache capacity in pages across all tables;
        ``None`` = unbounded (every touched page stays resident — the
        full-mirror configuration that is bit-identical to ``HostStore``).
    """

    kind = "disk"

    def __init__(self, spill_dir: str, page_rows: int = 1024,
                 page_cache_pages: Optional[int] = None):
        if page_rows <= 0:
            raise ValueError(f"page_rows must be positive, got {page_rows}")
        if page_cache_pages is not None and page_cache_pages <= 0:
            raise ValueError(
                f"page_cache_pages must be positive or None, "
                f"got {page_cache_pages}")
        self.spill_dir = os.path.abspath(spill_dir)
        self.page_rows = int(page_rows)
        self.page_cache_pages = (
            int(page_cache_pages) if page_cache_pages is not None else None)
        os.makedirs(self.spill_dir, exist_ok=True)
        sweep_stray_tmp(self.spill_dir)

        self._tables: Dict[str, _TableFile] = {}
        self._lock = threading.RLock()
        # page cache: (table, page) -> (rows_arr, accum_arr); LRU via
        # OrderedDict move_to_end; dirty pages tracked separately
        self._cache: "collections.OrderedDict[Tuple[str, int], Tuple[np.ndarray, np.ndarray]]" = (
            collections.OrderedDict())
        self._dirty: set = set()
        # pages handed to the writer thread but not yet on disk: reads hit
        # this lookaside before ever touching the (possibly mid-write) file
        self._in_flight: Dict[Tuple[str, int], Tuple[np.ndarray, np.ndarray]] = {}
        # per-page mutation generation, bumped under the lock on every
        # dirty-mark and every lookaside retirement: a page fault records
        # the generation before dropping the lock for the file read, and
        # discards the bytes (re-faulting) if it changed on reacquire —
        # the file may have been rewritten mid-read by a racing
        # scatter -> evict -> write-behind, and installing the pre-scatter
        # bytes as a clean page would silently lose that update
        self._page_gen: Dict[Tuple[str, int], int] = {}
        # test/audit seam: called (with the page key) in the fault window,
        # lock released, between the file read and the reacquire
        self._fault_hook = None
        self._bg_error: Optional[BaseException] = None

        self._stats = {
            "page_hits": 0.0, "page_misses": 0.0, "pages_evicted": 0.0,
            "disk_bytes_read": 0.0, "disk_bytes_written": 0.0,
        }
        # serving reads (gather(serve=True)) meter here instead, so the
        # trainer's per-interval page stats stay pure training signal
        self._serve_stats = {
            "page_hits": 0.0, "page_misses": 0.0, "pages_evicted": 0.0,
            "disk_bytes_read": 0.0,
        }

        # workers start LAST: every attribute they touch is published
        # before the first start() (start() is the happens-before edge)
        self._write_q: "queue.Queue" = queue.Queue()
        self._read_q: "queue.Queue" = queue.Queue()
        self._stop = threading.Event()
        self._writer = threading.Thread(
            target=self._writer_loop, name="diskstore-writer", daemon=True)
        self._reader = threading.Thread(
            target=self._reader_loop, name="diskstore-readahead", daemon=True)
        self._writer.start()
        self._reader.start()

    # ------------------------------------------------------------- lifecycle
    def _check_bg(self):
        with self._lock:
            err, self._bg_error = self._bg_error, None
        if err is not None:
            raise RuntimeError("DiskStore background IO failed") from err

    def close(self):
        """Flush everything and stop the background threads.

        Raises if a worker is still alive after the join timeout — a
        wedged IO thread must be loud (it may be mid page write, leaving
        a ``.tmp`` behind), never silently leaked.
        """
        try:
            self.flush()
        finally:
            self._stop.set()
            self._write_q.put(None)
            self._read_q.put(None)
            self._writer.join(timeout=30)
            self._reader.join(timeout=30)
        wedged = [th.name for th in (self._writer, self._reader)
                  if th.is_alive()]
        if wedged:
            raise RuntimeError(
                f"DiskStore.close: worker thread(s) {wedged} still alive "
                f"after 30s join — IO is wedged and the spill dir may "
                f"hold an in-flight .tmp page")

    # ------------------------------------------------------- table creation
    def create_table(self, name: str, rows: int, dim: int, dtype,
                     init_rows_fn=None, accum_init: float = 0.0):
        """Register table ``name`` and materialize its pages on disk.

        ``init_rows_fn(start, stop) -> (stop-start, dim)`` generates the
        initial values page by page (so a table larger than RAM never
        materializes whole); ``None`` initializes zeros.  ``accum_init``
        fills the AdaGrad accumulator (``SparseAdagradConfig.
        initial_accumulator``).  Existing page files are adopted as-is
        (resume path).
        """
        self._check_bg()
        t = _TableFile(self.spill_dir, name, rows, dim, np.dtype(dtype),
                       self.page_rows)
        with self._lock:
            self._tables[name] = t
        for p in range(t.n_pages):
            path = t.page_path(p)
            if os.path.exists(path):
                continue
            start = p * t.page_rows
            stop = start + t.page_len(p)
            if init_rows_fn is not None:
                vals = np.asarray(init_rows_fn(start, stop), dtype=t.dtype)
            else:
                vals = np.zeros((stop - start, t.dim), t.dtype)
            acc = np.full((stop - start, t.dim), accum_init, np.float32)
            _write_page_atomic(path, vals, acc)
            with self._lock:
                self._stats["disk_bytes_written"] += vals.nbytes + acc.nbytes

    def has_table(self, name: str) -> bool:
        with self._lock:
            return name in self._tables

    def table_meta(self, name: str) -> dict:
        t = self._get_table(name)
        return {"rows": t.rows, "dim": t.dim, "dtype": str(t.dtype),
                "page_rows": t.page_rows}

    def _get_table(self, name: str) -> _TableFile:
        # _tables is registered on the main thread but read by the
        # read-ahead worker; every lookup goes through the lock (the
        # _TableFile itself is immutable after construction)
        with self._lock:
            return self._tables[name]

    # ----------------------------------------------------------- page cache
    def _page_apply(self, t: _TableFile, p: int, serve: bool = False,
                    fn=None, dirty: bool = False) -> Tuple[np.ndarray, np.ndarray]:
        """Run ``fn(vals, acc)`` on page ``p``'s cached arrays under the
        lock, faulting the page in first if needed.

        The critical section never touches the filesystem: a page fault
        releases the lock, reads the file, reacquires, and re-checks — an
        in-flight write-behind copy observed on reacquire wins over the
        file bytes (it is strictly newer, and the file may be
        mid-replace), and file bytes are only installed if the page's
        mutation generation is unchanged from before the read.  The
        generation guard closes the lost-update window the lookaside
        alone cannot: if, during the unlocked read, another thread
        faults + scatters the same page, eviction queues it, AND the
        write-behind completes and retires the lookaside, both the cache
        and the lookaside are empty on reacquire — yet the bytes this
        thread read may predate the scatter.  Dirty-marks and lookaside
        retirements each bump the generation, so that schedule is
        detected and the fault retries against the (now rewritten) file.
        ``dirty=True`` marks the page dirty in the *same* lock hold as
        the mutation, so an eviction can never classify a just-mutated
        page as clean.  ``serve`` selects the meter bucket (training by
        default; the read-only lookup path passes ``serve=True`` so
        inference page traffic never pollutes training-interval stats).
        """
        key = (t.dir, p)
        from_file = None
        first = True
        gen = None
        while True:
            with self._lock:
                stats = self._serve_stats if serve else self._stats
                if (from_file is not None
                        and self._page_gen.get(key, 0) != gen):
                    # the page mutated (or its write-behind landed) while
                    # we read the file: those bytes may be stale — drop
                    # them and re-fault
                    from_file = None
                got = self._cache.get(key)
                if got is not None:
                    self._cache.move_to_end(key)
                    if first:
                        stats["page_hits"] += 1
                else:
                    if first:
                        stats["page_misses"] += 1
                    pending = self._in_flight.get(key)
                    if pending is not None:
                        got = (pending[0].copy(), pending[1].copy())
                    elif from_file is not None:
                        got = from_file
                        stats["disk_bytes_read"] += (
                            got[0].nbytes + got[1].nbytes)
                    if got is not None:
                        self._cache[key] = got
                        self._evict_lru(keep=key, stats=stats)
                if got is not None:
                    if dirty:
                        self._dirty.add(key)
                        self._page_gen[key] = self._page_gen.get(key, 0) + 1
                    if fn is not None:
                        fn(*got)
                    return got
                first = False
                gen = self._page_gen.get(key, 0)
            # page fault: read the file with the lock RELEASED — a miss
            # must not stall the other threads behind SSD latency
            with np.load(t.page_path(p)) as z:
                from_file = (z["rows"], z["accum"])
            hook = self._fault_hook
            if hook is not None:
                hook(key)

    def _evict_lru(self, keep=None, stats: Optional[dict] = None):
        """Shrink the cache to capacity; dirty victims go to the writer."""
        if self.page_cache_pages is None:
            return
        if stats is None:
            stats = self._stats
        while len(self._cache) > self.page_cache_pages:
            for key in self._cache:      # LRU order; skip the pinned page
                if key != keep:
                    break
            else:
                return
            entry = self._cache.pop(key)
            stats["pages_evicted"] += 1
            if key in self._dirty:
                self._dirty.discard(key)
                # the queued tuple IS the lookaside entry: the writer
                # retires the lookaside only if it still holds this exact
                # object (a newer flush may have replaced it)
                self._in_flight[key] = entry
                self._write_q.put((key, entry))

    def _table_of(self, key) -> _TableFile:
        with self._lock:
            tables = list(self._tables.values())
        for t in tables:
            if t.dir == key[0]:
                return t
        raise KeyError(key)

    # ------------------------------------------------------------ access API
    def gather(self, name: str, uids: np.ndarray,
               serve: bool = False) -> Tuple[np.ndarray, np.ndarray]:
        """(len(uids), dim) value + accumulator rows, in uid order.

        The blocking read of the pull path — ``readahead`` should have
        warmed the pages while the device trained the previous batch.
        ``serve=True`` is the read-only lookup path: identical reads (and
        identical page-cache warming — serving rides the cache the trainer
        keeps hot), but metered into ``serve_stats()`` so training-interval
        page stats never count inference traffic.
        """
        self._check_bg()
        t = self._get_table(name)
        uids = np.asarray(uids, np.int64)
        out_v = np.empty((len(uids), t.dim), t.dtype)
        out_a = np.empty((len(uids), t.dim), np.float32)
        for p in np.unique(uids // t.page_rows):
            sel = uids // t.page_rows == p
            r = uids[sel] - int(p) * t.page_rows

            def copy_out(vals, acc, sel=sel, r=r):
                out_v[sel] = vals[r]
                out_a[sel] = acc[r]

            self._page_apply(t, int(p), serve=serve, fn=copy_out)
        return out_v, out_a

    def scatter(self, name: str, uids: np.ndarray, rows: np.ndarray,
                accum: np.ndarray):
        """Write value + accumulator rows back (write-behind: RAM pages are
        updated and marked dirty; disk catches up on eviction/flush)."""
        self._check_bg()
        t = self._get_table(name)
        uids = np.asarray(uids, np.int64)
        rows = np.asarray(rows)
        accum = np.asarray(accum)
        for p in np.unique(uids // t.page_rows):
            sel = uids // t.page_rows == p
            r = uids[sel] - int(p) * t.page_rows

            def write_in(vals, acc, sel=sel, r=r):
                vals[r] = rows[sel].astype(t.dtype, copy=False)
                acc[r] = accum[sel]

            self._page_apply(t, int(p), fn=write_in, dirty=True)

    def readahead(self, name: str, uids: np.ndarray):
        """Queue the pages holding ``uids`` for background fault-in.

        Non-blocking: the reader thread pulls pages into the cache while
        the device trains, hiding disk latency under the train stage.
        """
        self._check_bg()
        t = self._get_table(name)
        pages = np.unique(np.asarray(uids, np.int64) // t.page_rows)
        with self._lock:
            todo = [int(p) for p in pages if (t.dir, int(p)) not in self._cache]
        for p in todo:
            self._read_q.put((name, p))

    # ------------------------------------------------------------ durability
    def flush(self):
        """Write every dirty page to disk and wait for the writer to drain.

        The durability point: after ``flush`` returns, the page files on
        disk are the complete, current table (checkpoint snapshots and
        parity reads call this first).
        """
        self._check_bg()
        with self._lock:
            dirty = list(self._dirty)
            self._dirty.clear()
            for key in dirty:
                entry = self._cache[key]
                self._in_flight[key] = entry
                self._write_q.put((key, entry))
        self._write_q.join()
        self._check_bg()

    def snapshot_to(self, dest_dir: str):
        """Copy the complete page set into ``dest_dir/<table>/`` (checkpoint
        staging).  Flushes first, then copies page files byte-for-byte —
        the copies inherit the rename-aside crash safety of the enclosing
        checkpoint directory."""
        self.flush()
        for name, t in self._tables.items():
            d = os.path.join(dest_dir, name)
            os.makedirs(d, exist_ok=True)
            for p in range(t.n_pages):
                src = t.page_path(p)
                dst = os.path.join(d, _PAGE_FMT % p)
                _copy_file_atomic(src, dst)

    def restore_from(self, src_dir: str):
        """Replace the live pages with a checkpoint's page set (resume).

        Drops the page cache — restored state must come from the restored
        files, not from stale RAM pages.
        """
        self._check_bg()
        with self._lock:
            self._dirty.clear()
        # drain write-behind AND read-ahead: a stale page write landing
        # AFTER the restore copy — or a read-ahead faulting pre-restore
        # file bytes back into the cache mid-copy — would silently corrupt
        # the resumed state
        self._write_q.join()
        self._read_q.join()
        self._check_bg()
        with self._lock:
            # bump every known page generation: any fault mid-read when
            # the restore starts must discard its pre-restore file bytes
            for key in set(self._cache) | set(self._in_flight):
                self._page_gen[key] = self._page_gen.get(key, 0) + 1
            self._cache.clear()
            self._in_flight.clear()
            tables = list(self._tables.items())
        # copy with the lock released: both queues are drained, the
        # workers are idle, and only this (main) thread faults pages in
        for name, t in tables:
            d = os.path.join(src_dir, name)
            for p in range(t.n_pages):
                src = os.path.join(d, _PAGE_FMT % p)
                if not os.path.exists(src):
                    raise FileNotFoundError(
                        f"checkpoint missing page {src} for table "
                        f"{name!r} — layout mismatch?")
                _copy_file_atomic(src, t.page_path(p))

    def stats(self) -> dict:
        with self._lock:
            return dict(self._stats)

    def serve_stats(self) -> dict:
        """Cumulative page-tier meters for serving reads only (see
        ``gather(serve=True)``)."""
        with self._lock:
            return dict(self._serve_stats)

    # ------------------------------------------------------------ bg threads
    #
    # Each loop is get -> process -> task_done; the processing bodies are
    # separate methods so the schedule audit (repro.analysis.sched_audit)
    # can replay queued work inline at chosen yield points.  Worker
    # exceptions are published under the lock and re-raised on the main
    # thread by _check_bg at the next API call.
    def _process_write_item(self, item):
        key, entry = item
        try:
            vals, acc = entry
            t = self._table_of(key)
            _write_page_atomic(t.page_path(key[1]), vals, acc)
            with self._lock:
                self._stats["disk_bytes_written"] += vals.nbytes + acc.nbytes
                # only retire the lookaside if it still holds OUR entry (a
                # newer flush may have queued a fresher write); the bump
                # invalidates any page fault whose file read raced this
                # write (see _page_apply's generation guard)
                if self._in_flight.get(key) is entry:
                    del self._in_flight[key]
                    self._page_gen[key] = self._page_gen.get(key, 0) + 1
        except BaseException as e:  # surfaced via _check_bg
            with self._lock:
                self._bg_error = e

    def _process_read_item(self, item):
        name, p = item
        try:
            with self._lock:
                t = self._tables.get(name)
                stopping = self._stop.is_set()
            if t is not None and not stopping:
                self._page_apply(t, p)
        except BaseException as e:  # surfaced via _check_bg
            with self._lock:
                self._bg_error = e

    def _writer_loop(self):
        while True:
            item = self._write_q.get()
            try:
                if item is None:
                    return
                self._process_write_item(item)
            finally:
                self._write_q.task_done()

    def _reader_loop(self):
        while True:
            item = self._read_q.get()
            try:
                if item is None:
                    return
                self._process_read_item(item)
            finally:
                self._read_q.task_done()


# ------------------------------------------------------------------ helpers
def _write_page_atomic(path: str, rows: np.ndarray, accum: np.ndarray):
    """npz to ``.tmp`` + fsync + ``os.replace`` — same crash-safety contract
    as ``checkpoint.ckpt.save_tree``: readers only ever see complete pages.

    Retries once if the ``.tmp`` vanishes between fsync and replace: the
    CheckpointManager's wreckage sweep may race a live write-behind, and
    from its view any ``.tmp`` is deletable — a rewrite is always safe.
    """
    tmp = path + ".tmp"
    for attempt in range(3):
        with open(tmp, "wb") as f:
            np.savez(f, rows=rows, accum=accum)
            f.flush()
            os.fsync(f.fileno())
        try:
            os.replace(tmp, path)
            return
        except FileNotFoundError:
            if attempt == 2:
                raise


def _copy_file_atomic(src: str, dst: str):
    tmp = dst + ".tmp"
    with open(src, "rb") as fsrc, open(tmp, "wb") as fdst:
        while True:
            chunk = fsrc.read(1 << 22)
            if not chunk:
                break
            fdst.write(chunk)
        fdst.flush()
        os.fsync(fdst.fileno())
    os.replace(tmp, dst)


def sweep_stray_tmp(root: str) -> int:
    """Delete ``*.tmp`` page wreckage under ``root`` (kill mid write-behind
    or mid page-copy).  Safe by construction: a ``.tmp`` is only ever an
    incomplete write whose complete predecessor (if any) still holds the
    final name.  Returns the number of files removed."""
    removed = 0
    for dirpath, _, files in os.walk(root):
        for fn in files:
            if fn.endswith(".tmp"):
                os.remove(os.path.join(dirpath, fn))
                removed += 1
    return removed


def make_store(store: str = "host", spill_dir: Optional[str] = None,
               page_rows: int = 1024,
               page_cache_pages: Optional[int] = None):
    """``store`` in {"host", "disk"} -> a RowStore instance."""
    if store == "host":
        if spill_dir is not None:
            raise ValueError("spill_dir is a disk-store option; "
                             "remove it or pass store='disk'")
        return HostStore()
    if store == "disk":
        if not spill_dir:
            raise ValueError("store='disk' requires spill_dir")
        return DiskStore(spill_dir, page_rows=page_rows,
                         page_cache_pages=page_cache_pages)
    raise ValueError(f"unknown store {store!r}; use 'host' or 'disk'")
