"""Pluggable sparse-parameter backends — one contract for the PS pull/push.

The paper's Algorithm 1 moves embedding rows, never tables: per batch the
trainer *pulls* the deduplicated working set, runs fwd/bwd against the
compact pulled rows, and *pushes* the row updates back.  How those rows
physically move is a placement decision, so it lives behind a protocol:

    state = backend.init_state(table)
    backend.pull(table, accum, state, flat_ids, capacity)
        -> (WorkingSet, table, accum, state)
    backend.lookup(table, accum, state, flat_ids, capacity)
        -> (WorkingSet, aux)              # read-only (serving/inference)
    backend.push(table, accum, state, working_set, row_grads, opt)
        -> (table, accum, state)
    backend.flush(table, accum, state) -> (table, accum, state)

The pull path is split into two explicit contracts.  ``pull`` is the
TRAINING pull: it may mutate backend state (LFU counters, cache
admissions/evictions, spill buffers) and thread updated tables back.
``lookup`` is the READ-ONLY serving lookup: it returns the same rows a
pull would serve but is side-effect-free on every input — no admissions,
no evictions, no counters, nothing donated — so a co-located inference
server can read the live training state between steps without perturbing
the training trajectory (ScaleFreeCTR's shared MixCache).  ``aux`` is a
small dict of f32 scalars metering the lookup itself (``serve_lookups``,
plus ``serve_misses`` for the cache tier) so serving traffic is counted
separately from training traffic.

Every backend owns an explicit per-table STATE pytree threaded through the
compiled train step (``EmbeddingEngine.pull/push`` -> ``HybridTrainer``).
Stateless placements carry an empty tuple; the cache tier carries its
id->slot map, frequency counters, and cached rows there.  ``pull`` may
write the table/accumulator (cache spills), ``flush`` forces any cached
dirty rows back (checkpoint/export consistency point), and ``prepare``/
``export`` convert between the logical row layout (row i == feature id i)
and whatever physical layout the backend shards by.  Three implementations:

``GatherBackend``
    The single-device / GSPMD path: ``jnp.unique`` dedup + one ``jnp.take``
    gather, push via ``SparseAdagrad.apply_rows``.  Logical layout; under
    GSPMD the gather lowers to masked partials + all-reduce (value-blind).

``RoutedBackend``
    The paper's PS request routing on TPU: tables live hash-sharded
    (``slot_of`` spreads Zipf-hot heads uniformly), ids are bucketed by
    owning shard and exchanged with explicit ``all_to_all``s
    (``repro.core.routed_embedding``), so per-device wire is ~ rows moved
    once instead of ~2x the full working set.  Requests beyond the per-route
    bucket capacity are dropped-and-counted (``WorkingSet.n_dropped``) —
    the production overload signal; with ``cap_route`` at the worst case
    (the default) the exchange is lossless.

``CachedBackend`` (``repro.core.cache_tier``)
    The paper's §2.3 memory hierarchy: the full table + accumulator stay
    host-resident, a fixed-size device cache serves the Zipf-hot rows
    (LFU-with-decay admission/eviction, metered host<->device traffic).

All backends return identical results at lossless capacity (for the cache
tier: ``cache_rows >= table rows``) — asserted by
``tests/test_embedding_backend.py`` / ``tests/test_cache_tier.py`` — so
trainers switch placement with a config flag
(``TrainerConfig.placement`` / ``--placement``).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core import routed_embedding as routed
from repro.core.sparse_optim import SparseAdagrad


# --------------------------------------------------------------- working set
def pull_working_set(
    flat_ids: jnp.ndarray, capacity: int
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Deduplicate the ids referenced by a batch (the PS "pull" manifest).

    Returns (unique_ids (capacity,), inverse (nnz,)) with static shapes:
    ``unique_ids`` is padded by repeating the smallest id (harmless for the
    scatter since padded slots receive zero gradient), ``inverse`` maps each
    original id slot to its row in the pulled working set.
    ``capacity`` must bound the number of distinct ids in a batch.
    """
    uids, inv = jnp.unique(
        flat_ids, size=capacity, fill_value=None, return_inverse=True
    )
    return uids.astype(jnp.int32), inv.astype(jnp.int32)


class WorkingSet(NamedTuple):
    """One table's pulled rows for one batch (Algorithm 1 line 3).

    ``rows`` carries one extra all-zero "drop" row at index ``capacity``:
    id slots that overflowed the dedup capacity have ``inverse ==
    capacity``, so their lookup reads zeros and the gradient landing on the
    drop row is discarded at push — training degrades gracefully (and
    countably) instead of NaN-poisoning on out-of-range gathers.
    """

    uids: jnp.ndarray       # (capacity,) int32 — deduplicated ids, padded
    inverse: jnp.ndarray    # (nnz,) int32 — original id slot -> working row
    rows: jnp.ndarray       # (capacity + 1, dim) — rows[i] = T[uids[i]];
                            # rows[capacity] == 0 (drop row)
    n_dropped: jnp.ndarray  # () int32 — ids not served (capacity overflow)


def _dedup(flat_ids: jnp.ndarray, capacity: int):
    """Dedup + overflow accounting shared by all backends.

    Returns (uids, inverse, n_dropped) where dropped slots (distinct ids
    beyond ``capacity`` — ``jnp.unique`` keeps the smallest) point at the
    zero drop row ``capacity`` instead of out of range.
    """
    uids, inv = pull_working_set(flat_ids, capacity)
    inv_c = jnp.clip(inv, 0, capacity - 1)
    served = jnp.take(uids, inv_c) == flat_ids
    inverse = jnp.where(served, inv_c, capacity)
    return uids, inverse, jnp.sum((~served).astype(jnp.int32))


def _with_drop_row(rows: jnp.ndarray) -> jnp.ndarray:
    return jnp.concatenate([rows, jnp.zeros((1, rows.shape[1]), rows.dtype)])


@runtime_checkable
class EmbeddingBackend(Protocol):
    """Placement strategy for one embedding table.

    All pull/push/flush methods must be jit-traceable (they run inside the
    compiled train step), take and return the per-table backend state
    pytree from ``init_state`` (empty tuple for stateless placements), and
    thread the table + AdaGrad accumulator through so a backend may write
    them (cache spills/flushes).  ``push`` applies the sparse optimizer
    update itself so a backend can fuse it with the reverse route
    (RoutedBackend updates rows shard-locally, exactly where they live) or
    with its cache (CachedBackend writes through to hot rows only).
    """

    def init_state(self, table: jnp.ndarray):
        """Per-table backend state pytree (empty tuple if stateless)."""
        ...

    def prepare(self, table: jnp.ndarray) -> jnp.ndarray:
        """Logical row layout -> this backend's physical layout."""
        ...

    def export(self, table: jnp.ndarray) -> jnp.ndarray:
        """Physical layout -> logical rows (checkpoint export / parity)."""
        ...

    def flush(self, table, accum, state):
        """Force deferred writes (cached dirty rows) back into table/accum."""
        ...

    def pull(self, table, accum, state, flat_ids, capacity: int):
        ...

    def lookup(self, table, accum, state, flat_ids, capacity: int):
        """Read-only serving lookup: ``(WorkingSet, aux)``.

        Must serve the same row values a ``pull`` would (cache-fresh rows
        included) while mutating NOTHING — no admission, no eviction, no
        counter writes; every input pytree is returned untouched by simply
        not being returned at all."""
        ...

    def push(self, table, accum, state, ws: WorkingSet, row_grads,
             opt: SparseAdagrad):
        ...


# ------------------------------------------------------------------- gather
class GatherBackend:
    """Dedup + ``jnp.take`` pull, scatter-AdaGrad push (logical layout).

    The right choice on one device and the baseline under GSPMD: the
    compiler partitions the gather/scatter over a row-sharded table, at the
    cost of value-blind all-reduce traffic (see RoutedBackend).  Stateless:
    the backend-state pytree is an empty tuple.

    ``fused=True`` routes the push through the fused Pallas scatter+AdaGrad
    kernel (``kernels.ops.sparse_adagrad_apply``): the row update is applied
    straight into the aliased table/accumulator buffers instead of
    materializing the intermediate updated-rows arrays — bit-identical to
    the unfused scatter (same pinned row math feeds both).

    ``staged=True`` is the DiskStore dataflow (``--store disk``): the
    ``table``/``accum`` the jitted pull/push see are NOT the full table but
    the batch's (capacity, dim) working-set rows, staged by the
    ``RowStore`` in dedup'd-uid order.  Pull just appends the drop row;
    push applies the same AdaGrad row math elementwise
    (``SparseAdagrad.apply_staged``) and returns the updated rows through
    the table/accum outputs for the host to commit.  Bit-identical to the
    resident path at every valid (first-occurrence) position.
    """

    def __init__(self, fused: bool = False, staged: bool = False):
        self.fused = fused
        self.staged = staged

    def init_state(self, table: jnp.ndarray):
        return ()

    def prepare(self, table: jnp.ndarray) -> jnp.ndarray:
        return table

    def export(self, table: jnp.ndarray) -> jnp.ndarray:
        return table

    def flush(self, table, accum, state):
        return table, accum, state

    def _served_rows(self, table, uids, capacity: int) -> jnp.ndarray:
        """(capacity + 1, dim) rows for ``uids`` — shared by pull/lookup."""
        if self.staged:
            if table.shape[0] != capacity:
                raise ValueError(
                    f"staged pull expects ({capacity}, dim) working-set rows "
                    f"from the RowStore, got {table.shape}"
                )
            # the store already gathered rows in dedup'd-uid order — the
            # host mirrors _dedup exactly (np.unique, truncate-keep-smallest,
            # pad with the minimum), so rows[i] IS T[uids[i]]
            return _with_drop_row(table)
        return _with_drop_row(jnp.take(table, uids, axis=0))

    def pull(self, table, accum, state, flat_ids, capacity: int):
        uids, inv, n_dropped = _dedup(flat_ids, capacity)
        rows = self._served_rows(table, uids, capacity)
        return WorkingSet(uids, inv, rows, n_dropped), table, accum, state

    def lookup(self, table, accum, state, flat_ids, capacity: int):
        """Read-only lookup: identical row service to ``pull`` (the gather
        path is stateless, so the only difference is the contract — nothing
        is threaded back, nothing may be donated into it)."""
        uids, inv, n_dropped = _dedup(flat_ids, capacity)
        rows = self._served_rows(table, uids, capacity)
        aux = {"serve_lookups":
               jnp.float32(flat_ids.size) - n_dropped.astype(jnp.float32)}
        return WorkingSet(uids, inv, rows, n_dropped), aux

    def push(self, table, accum, state, ws: WorkingSet, row_grads,
             opt: SparseAdagrad):
        # row_grads[capacity] belongs to the drop row — discard it.
        if self.staged:
            # elementwise AdaGrad on the staged rows; the updated buffers
            # ride out through the table/accum outputs and the host commits
            # the valid positions into the DiskStore at the next boundary
            new_table, new_accum = opt.apply_staged(
                table, accum, row_grads[: ws.uids.shape[0]]
            )
        else:
            new_table, new_accum = opt.apply_rows(
                table, accum, ws.uids, row_grads[: ws.uids.shape[0]],
                fused=self.fused,
            )
        return new_table, new_accum, state


# ------------------------------------------------------------------- routed
class RoutedBackend:
    """Topology-routed all-to-all pull/push over a hash-sharded table.

    Parameters
    ----------
    mesh: the device mesh the table is row-sharded over.
    shard_axes: mesh axes forming the shard dimension (axes absent from the
        mesh are ignored, so one spec works for single- and multi-pod runs).
    cap_route: per-(requester, owner) bucket capacity.  ``None`` (default)
        uses the worst case — every local id addressing one shard — which
        makes the exchange lossless; smaller values bound the exchange
        buffers and drop-and-count overflow like an overloaded PS shard.
    """

    def __init__(
        self,
        mesh: jax.sharding.Mesh,
        shard_axes: Tuple[str, ...] = ("data", "model"),
        cap_route: Optional[int] = None,
    ):
        self.mesh = mesh
        self.shard_axes = tuple(a for a in shard_axes if a in mesh.axis_names)
        n = 1
        for a in self.shard_axes:
            n *= mesh.shape[a]
        self.n_shards = n
        self.cap_route = cap_route
        self._fns = {}

    def _check_divisible(self, what: str, value: int):
        if value % self.n_shards:
            raise ValueError(
                f"RoutedBackend: {what} ({value}) must be divisible by "
                f"n_shards ({self.n_shards})"
            )

    def _pull_push(self, rows: int, dim: int, capacity: int):
        key = (rows, dim, capacity)
        if key not in self._fns:
            self._check_divisible("table rows", rows)
            self._check_divisible("capacity", capacity)
            cap_local = capacity // self.n_shards
            cap_route = self.cap_route if self.cap_route is not None else cap_local
            self._fns[key] = routed.make_routed_pull_push(
                self.mesh, rows // self.n_shards, dim, cap_local, cap_route,
                shard_axes=self.shard_axes,
            )
        return self._fns[key]

    def _perm(self, rows: int) -> jnp.ndarray:
        """logical id -> physical slot (hash-sharding bijection)."""
        self._check_divisible("table rows", rows)
        return routed.slot_of(
            jnp.arange(rows, dtype=jnp.int32), rows // self.n_shards, self.n_shards
        )

    def init_state(self, table: jnp.ndarray):
        return ()

    def prepare(self, table: jnp.ndarray) -> jnp.ndarray:
        perm = self._perm(table.shape[0])
        return jnp.zeros_like(table).at[perm].set(table)

    def export(self, table: jnp.ndarray) -> jnp.ndarray:
        return jnp.take(table, self._perm(table.shape[0]), axis=0)

    def flush(self, table, accum, state):
        return table, accum, state

    def pull(self, table, accum, state, flat_ids, capacity: int):
        uids, inv, n_dedup_dropped = _dedup(flat_ids, capacity)
        pull_fn, _ = self._pull_push(table.shape[0], table.shape[1], capacity)
        rows, _, dropped = pull_fn(table, uids)
        ws = WorkingSet(
            uids, inv, _with_drop_row(rows), n_dedup_dropped + jnp.sum(dropped)
        )
        return ws, table, accum, state

    def lookup(self, table, accum, state, flat_ids, capacity: int):
        """Read-only lookup: the same all-to-all exchange as ``pull`` (the
        route reads shard-resident rows and mutates nothing), returned
        without the state threading so nothing can be donated into it."""
        uids, inv, n_dedup_dropped = _dedup(flat_ids, capacity)
        pull_fn, _ = self._pull_push(table.shape[0], table.shape[1], capacity)
        rows, _, dropped = pull_fn(table, uids)
        n_dropped = n_dedup_dropped + jnp.sum(dropped)
        ws = WorkingSet(uids, inv, _with_drop_row(rows), n_dropped)
        aux = {"serve_lookups":
               jnp.float32(flat_ids.size) - n_dropped.astype(jnp.float32)}
        return ws, aux

    def push(self, table, accum, state, ws: WorkingSet, row_grads,
             opt: SparseAdagrad):
        _, push_fn = self._pull_push(
            table.shape[0], table.shape[1], ws.uids.shape[0]
        )
        new_table, new_accum, _ = push_fn(
            table, accum, ws.uids, row_grads[: ws.uids.shape[0]],
            opt.cfg.lr, opt.cfg.eps,
        )
        return new_table, new_accum, state


# ------------------------------------------------------------------ factory
def make_backend(
    placement: str,
    mesh: Optional[jax.sharding.Mesh] = None,
    fused: bool = False,
    **kwargs,
) -> EmbeddingBackend:
    """``placement`` in {"gather", "routed", "cached"} -> a backend instance.

    ``routed`` without an explicit mesh builds a 1-D mesh over all local
    devices (on one CPU device that degenerates to n_shards=1, where the
    routed exchange is bit-identical to the gather path — the parity the
    tests and the ``--placement`` acceptance check rely on).  ``cached``
    takes ``cache_rows`` (device cache size, required) and ``decay``
    (LFU decay, optional) — see ``repro.core.cache_tier.CachedBackend``.
    ``staged=True`` (gather/cached; plus ``capacity`` for cached) selects
    the DiskStore dataflow where pull/push see staged working-set rows
    instead of the resident table — wired by ``runtime.factory`` when
    ``store="disk"``.

    ``fused`` selects the fused Pallas pull/push kernels where a placement
    has them (gather: fused push; cached: fused pull + push with the
    id→slot indirection folded in).  The routed push computes AdaGrad
    shard-locally inside its reverse all_to_all route (a different fusion
    boundary already), so ``fused`` is accepted but a no-op there — routed
    training still gets the fused embedding *bag* at the engine layer.
    """
    if placement == "gather":
        # mesh is legitimate shared context (GSPMD shards the gather);
        # placement-specific knobs are not — dropping them silently would
        # make a capacity-bounded experiment run unbounded.
        staged = kwargs.pop("staged", False)
        if kwargs:
            raise TypeError(
                f"placement 'gather' does not accept {sorted(kwargs)} "
                f"(routed/cached-only options)"
            )
        return GatherBackend(fused=fused, staged=staged)
    if placement == "routed":
        if mesh is None:
            mesh = jax.make_mesh((jax.device_count(),), ("data",))
        return RoutedBackend(mesh, **kwargs)
    if placement == "cached":
        from repro.core.cache_tier import CachedBackend

        if "cache_rows" not in kwargs:
            raise TypeError("placement 'cached' requires cache_rows")
        return CachedBackend(fused=fused, **kwargs)
    raise ValueError(
        f"unknown placement {placement!r}; use 'gather', 'routed', or 'cached'"
    )
