"""Sparse AdaGrad on embedding working sets (paper §5 hybrid optimizer split).

The paper trains the 10-TB sparse embedding layers with AdaGrad synchronized
*every* step: the sparse gradient touches only the working set (the
deduplicated rows referenced by the current batch), so every-step sync is
cheap, and AdaGrad avoids storing Adam's first moment for 1e11 rows.

Here tables are row-sharded jnp arrays; the update is a scatter over the
unique row ids of the batch.  Under GSPMD the scatter is partitioned over the
row-sharded table, so only rows crossing shard boundaries generate traffic —
the TPU rendering of the parameter-server "push" path.

The optimizer is owned by ``EmbeddingEngine`` and applied by the engine's
``EmbeddingBackend``: ``GatherBackend`` calls ``apply_rows`` directly, while
``RoutedBackend`` runs the same update shard-locally at the end of its
reverse gradient route (see ``routed_embedding.push_body``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class SparseAdagradConfig:
    lr: float = 0.05
    eps: float = 1e-10
    initial_accumulator: float = 0.1   # paddlepaddle/TF AdaGrad convention


class SparseAdagradState(NamedTuple):
    accum: Pytree  # per-table accumulator, same shape as the table, f32


class SparseAdagrad:
    """Working-set AdaGrad over a pytree of embedding tables."""

    def __init__(self, cfg: SparseAdagradConfig = SparseAdagradConfig()):
        self.cfg = cfg

    def init(self, tables: Pytree) -> SparseAdagradState:
        return SparseAdagradState(
            accum=jax.tree.map(
                lambda t: jnp.full(t.shape, self.cfg.initial_accumulator, jnp.float32),
                tables,
            )
        )

    def apply_rows(
        self,
        table: jnp.ndarray,          # (rows, dim)
        accum: jnp.ndarray,          # (rows, dim) f32
        unique_ids: jnp.ndarray,     # (capacity,) int32 — deduplicated, padded
        row_grads: jnp.ndarray,      # (capacity, dim) — grads w.r.t. pulled rows
        fused: bool = False,
    ):
        """Scatter one working set back into its table (the PS "push").

        The row arithmetic lives in ``kernels.sparse_adagrad.
        adagrad_row_updates`` — the same pinned helper the fused Pallas
        apply uses, so ``fused=True`` (one aliased kernel pass, no
        intermediate updated-rows array) is bit-identical to this scatter.
        Padding slots repeat a real id with zero grads; the scatter-add of
        zeros and the zero g2 keep them inert.
        """
        from repro.kernels import ops
        from repro.kernels.sparse_adagrad import adagrad_row_updates

        if fused:
            return ops.sparse_adagrad_apply(
                table, accum, unique_ids, row_grads,
                lr=self.cfg.lr, eps=self.cfg.eps)
        delta, g2 = adagrad_row_updates(
            accum[unique_ids], row_grads, table.dtype,
            lr=self.cfg.lr, eps=self.cfg.eps)
        new_table = table.at[unique_ids].add(delta)
        new_accum = accum.at[unique_ids].add(g2)
        return new_table, new_accum

    def apply_staged(self, rows, accum_rows, row_grads):
        """Working-set-aligned AdaGrad — the disk-store staged push.

        ``rows``/``accum_rows`` are already gathered in dedup'd-uid order
        (the RowStore staged them), so the update is elementwise: position
        i of the output is bit-equal to row ``uids[i]`` after
        ``apply_rows`` on a resident table — same pinned ``(delta, g2)``
        helper, and the pad positions' ±0.0 contributions are inert under
        the scatter-add exactly as they are here.

        The adds go through an identity-iota scatter-add, NOT ``+``: XLA's
        CPU backend FMA-contracts ``accum + square(g)`` even across the
        ``optimization_barrier`` (the product feeds the add at full
        precision, skipping g2's rounding), while ``apply_rows``'s real
        scatter-add cannot contract — the scatter form here keeps the two
        paths bit-identical.
        """
        from repro.kernels.sparse_adagrad import adagrad_row_updates

        delta, g2 = adagrad_row_updates(
            accum_rows, row_grads, rows.dtype,
            lr=self.cfg.lr, eps=self.cfg.eps)
        idx = jnp.arange(rows.shape[0], dtype=jnp.int32)
        return rows.at[idx].add(delta), accum_rows.at[idx].add(g2)

    def step(self, tables: Pytree, state: SparseAdagradState, updates: Pytree):
        """updates: pytree matching ``tables`` of (unique_ids, row_grads)."""
        flat_t, treedef = jax.tree.flatten(tables)
        flat_a = jax.tree.leaves(state.accum)
        flat_u = jax.tree.flatten(updates, is_leaf=lambda u: isinstance(u, tuple))[0]
        new_t, new_a = [], []
        for t, a, (ids, rg) in zip(flat_t, flat_a, flat_u):
            nt, na = self.apply_rows(t, a, ids, rg)
            new_t.append(nt)
            new_a.append(na)
        return (
            jax.tree.unflatten(treedef, new_t),
            SparseAdagradState(accum=jax.tree.unflatten(treedef, new_a)),
        )

    def dense_reference(self, table, accum, grads):
        """Dense AdaGrad oracle (same math on a full-size gradient) — tests."""
        g = grads.astype(jnp.float32)
        a = accum + jnp.square(g)
        new_table = table - (self.cfg.lr * g / (jnp.sqrt(a) + self.cfg.eps)).astype(table.dtype)
        return new_table, a
