"""Cross-pod merge schedules — the TPU adaptation of the paper's two-phase
communication (§3.2) applied to the k-step merge payload.

All strategies compute ``mean over the leading pod dimension`` of every leaf
and broadcast the result back, but they differ in the *route* the bytes take:

- ``flat``      : plain ``jnp.mean(x, axis=0)``.  If a leaf is replicated over
                  the in-pod axes, GSPMD runs one cross-pod all-reduce per
                  replica group — the full payload crosses the slow DCN fabric
                  once per in-pod chip (the naive route the paper warns about).
- ``two_phase`` : reshard the payload to a full in-pod sharding first (a local
                  slice — zero comm), all-reduce only the 1/(data*model) shard
                  across pods (DCN), then all-gather within the pod over fast
                  ICI.  This is the middleman-buffer idea of §3.2: bulk traffic
                  stays on the fast fabric, the slow link carries the minimum.
- ``bf16``      : two_phase with the payload cast to bfloat16 (2x DCN bytes).
- ``int8_ef``   : two_phase with int8 quantization + error feedback
                  (beyond-paper; ~4x DCN bytes vs f32).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

Pytree = Any


def _mean_keep(x: jnp.ndarray) -> jnp.ndarray:
    mu = jnp.mean(x.astype(jnp.float32), axis=0, keepdims=True)
    return jnp.broadcast_to(mu, x.shape).astype(x.dtype)


def flat_mean(tree: Pytree) -> Pytree:
    return jax.tree.map(_mean_keep, tree)


def pmean_mean(tree: Pytree, axis_name: str = "pod") -> Pytree:
    """Merge for the shard_map-manual pod axis: a plain lax.pmean.  With
    inner dims auto-sharded, each device pmeans only its own shard — this is
    the two-phase schedule by construction (DCN carries 1/|inner| of the
    payload)."""
    return jax.tree.map(
        lambda x: jax.lax.pmean(x.astype(jnp.float32), axis_name).astype(x.dtype),
        tree,
    )


def _wsc(x, mesh, spec):
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def two_phase_mean(
    tree: Pytree,
    mesh: Optional[jax.sharding.Mesh],
    pod_axis: str = "pod",
    inner_axes: tuple = ("data", "model"),
    payload_dtype=None,
) -> Pytree:
    """Hierarchical RS(ICI) -> AR(DCN on shard) -> AG(ICI) mean over pods."""
    inner = tuple(a for a in inner_axes if mesh is None or a in mesh.axis_names)
    pod = pod_axis if (mesh is not None and pod_axis in mesh.axis_names) else None

    def leaf(x):
        n_pod = x.shape[0]
        orig_dtype = x.dtype
        flat = x.reshape(n_pod, -1)
        if payload_dtype is not None:
            flat = flat.astype(payload_dtype)
        # Phase 1: slice the payload across the in-pod axes (local, no comm),
        # so the pod-axis reduction only moves 1/|inner| of the bytes on DCN.
        flat = _wsc(flat, mesh, P(pod, inner))
        mu = jnp.mean(flat.astype(jnp.float32), axis=0, keepdims=True)
        mu = _wsc(mu.astype(flat.dtype), mesh, P(None, inner))
        # Phase 2: broadcast back to each pod replica; the all-gather to the
        # original (wider) layout runs on in-pod ICI.
        out = jnp.broadcast_to(mu, flat.shape)
        return out.reshape(x.shape).astype(orig_dtype)

    return jax.tree.map(leaf, tree)


def int8_ef_mean(
    tree: Pytree,
    ef: Pytree,
    mesh: Optional[jax.sharding.Mesh],
    pod_axis: str = "pod",
    inner_axes: tuple = ("data", "model"),
):
    """int8-quantized two-phase mean with error feedback (beyond paper).

    Each pod contributes ``q_i = round((x_i + ef_i) / (s * n_pod))`` with a
    shared scale ``s = max_i |x_i + ef_i| / 127``; the cross-pod reduction runs
    on int8 (summed values stay within int8 because each term is bounded by
    127/n_pod), so the DCN payload shrinks 4x vs f32.  The quantization error
    of each pod's own contribution is kept locally and re-injected into the
    next merge (error feedback), which restores convergence to the uncompressed
    fixed point.
    Returns (merged_tree_f32, new_ef_tree).
    """
    inner = tuple(a for a in inner_axes if mesh is None or a in mesh.axis_names)
    pod = pod_axis if (mesh is not None and pod_axis in mesh.axis_names) else None

    def leaf(x, r):
        n_pod = x.shape[0]
        p = x.astype(jnp.float32) + r
        flat = p.reshape(n_pod, -1)
        # Shared scale: max over *all* pods (a scalar all-reduce — negligible).
        s = jnp.max(jnp.abs(flat)) / 127.0 + 1e-30
        step = s * n_pod
        q = jnp.clip(jnp.round(flat / step), -127, 127).astype(jnp.int8)
        q = _wsc(q, mesh, P(pod, inner))
        # int8 on the DCN wire: sum over the pod axis without widening.
        qs = jnp.sum(q, axis=0, keepdims=True, dtype=jnp.int8)
        qs = _wsc(qs, mesh, P(None, inner))
        merged = qs.astype(jnp.float32) * s * 1.0  # sum_i q_i * s ~= mean_i p_i
        merged = jnp.broadcast_to(merged, flat.shape).reshape(x.shape)
        # Error feedback: what this pod failed to communicate.
        resid = (flat - q.astype(jnp.float32) * step).reshape(x.shape)
        return merged, resid

    merged_and_ef = jax.tree.map(leaf, tree, ef)
    merged = jax.tree.map(lambda t: t[0], merged_and_ef,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda t: t[1], merged_and_ef,
                          is_leaf=lambda t: isinstance(t, tuple))
    return merged, new_ef


def spec_aware_mean(
    tree: Pytree,
    specs: Optional[Pytree],
    mesh: Optional[jax.sharding.Mesh],
    pod_axis: str = "pod",
    inner_axes: tuple = ("data", "model"),
    payload_dtype=None,
) -> Pytree:
    """Two-phase mean that respects existing leaf shardings.

    A leaf already sharded over in-pod axes needs NO resharding — the plain
    pod-axis mean is already shard-local on DCN (GSPMD all-reduces per-shard
    slices across pods).  Only fully-replicated leaves benefit from the
    flatten -> shard -> AR -> gather route; flattening sharded leaves forces
    involuntary full rematerialization (observed: f32 full-param temps).
    ``specs`` is the inner (pod-less) PartitionSpec tree; None = all
    replicated.
    """
    if specs is None:
        return two_phase_mean(tree, mesh, pod_axis, inner_axes, payload_dtype)

    def is_sharded(spec) -> bool:
        return any(e is not None for e in spec)

    def leaf(x, spec):
        if is_sharded(spec):
            sub = x if payload_dtype is None else x.astype(payload_dtype)
            return _mean_keep(sub).astype(x.dtype)
        return two_phase_mean({"_": x}, mesh, pod_axis, inner_axes,
                              payload_dtype)["_"]

    flat_x, treedef = jax.tree_util.tree_flatten(tree)
    flat_s = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda s: isinstance(s, P))[0]
    return jax.tree_util.tree_unflatten(
        treedef, [leaf(x, s) for x, s in zip(flat_x, flat_s)])


def make_merge_fn(
    name: str,
    mesh: Optional[jax.sharding.Mesh] = None,
    pod_axis: str = "pod",
    inner_axes: tuple = ("data", "model"),
    param_specs: Optional[Pytree] = None,
):
    """Return mean_fn(tree, allow_lossy) for the named schedule.

    ``allow_lossy=False`` callers (the v-merge) always get a lossless route.
    ``param_specs`` (inner, pod-less specs) makes two-phase spec-aware.
    """
    if name == "flat":
        return lambda tree, allow_lossy=True: flat_mean(tree)
    if name == "two_phase":
        return lambda tree, allow_lossy=True: spec_aware_mean(
            tree, param_specs, mesh, pod_axis, inner_axes
        )
    if name == "bf16":
        return lambda tree, allow_lossy=True: spec_aware_mean(
            tree, param_specs, mesh, pod_axis, inner_axes,
            payload_dtype=jnp.bfloat16 if allow_lossy else None,
        )
    if name == "int8_ef":
        # x-payload handled by int8_ef_mean inside kstep; v and other lossless
        # payloads ride the two-phase route.
        return lambda tree, allow_lossy=True: spec_aware_mean(
            tree, param_specs, mesh, pod_axis, inner_axes
        )
    raise ValueError(f"unknown merge schedule: {name!r}")
