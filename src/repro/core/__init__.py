"""Core: the paper's contribution — k-step Adam merging + sparse embedding engine."""

from repro.core.kstep import (  # noqa: F401
    KStepAdam,
    KStepAdamState,
    KStepConfig,
)
from repro.core import merge  # noqa: F401
from repro.core.sparse_optim import SparseAdagrad, SparseAdagradState  # noqa: F401
from repro.core.embedding_backend import (  # noqa: F401
    EmbeddingBackend,
    GatherBackend,
    RoutedBackend,
    WorkingSet,
    make_backend,
)
from repro.core.cache_tier import CachedBackend, CacheState  # noqa: F401
from repro.core.embedding_engine import (  # noqa: F401
    EmbeddingEngine,
    TableSpec,
    embedding_bag,
    pull_working_set,
)
