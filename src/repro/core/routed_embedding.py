"""Routed working-set exchange — the parameter-server pull/push as explicit
all-to-alls (shard_map), replacing GSPMD's value-blind gather.

GSPMD cannot know which table shard a dynamic id lives on, so a gather from
a row-sharded table lowers to "every shard computes masked partials of the
FULL working set + all-reduce" — per-device wire ~= 2x working-set bytes
(measured 930 MB/step on baidu-ctr train_mb8k).  The paper's parameter
server routes each request to the owning node instead.  This module does
the same on TPU:

  pull:  bucket ids by owning shard -> all_to_all requests -> local gather
         -> all_to_all rows back -> unpermute     (wire ~= rows moved once)
  push:  reverse route of row gradients -> local sparse-AdaGrad update

Load balance: ids map to slots via the bijection
    slot(id) = (id % n_shards) * rows_per_shard + id // n_shards
(hash-sharding), so Zipf-hot heads spread uniformly across shards.  Each
bucket has a fixed capacity; overflowed requests are dropped (returned rows
are zero, updates discarded) and COUNTED — production monitoring watches
that counter exactly like PS-shard overload. Capacity is a config knob;
tests run with capacity = worst case (lossless).

Trainers reach this exchange through ``repro.core.embedding_backend.
RoutedBackend`` (``--placement routed`` in the launcher); this module stays
the raw shard_map layer.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def slot_of(ids: jnp.ndarray, rows_per_shard: int, n_shards: int) -> jnp.ndarray:
    """Logical id -> physical slot under hash-sharding."""
    return (ids % n_shards) * rows_per_shard + ids // n_shards


def _bucket(ids: jnp.ndarray, targets: jnp.ndarray, n_shards: int, cap: int):
    """Place each id into (target, position) with per-target capacity.

    Returns (buckets (n_shards, cap) int32 local-row requests padded with -1,
    slot_of_id (len(ids),) position of each id in the flattened buckets or -1
    if dropped, n_dropped scalar)."""
    onehot = (targets[:, None] == jnp.arange(n_shards)[None, :]).astype(jnp.int32)
    pos = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, axis=1) - 1
    keep = pos < cap
    flat_slot = jnp.where(keep, targets * cap + pos, n_shards * cap)
    buckets = jnp.full((n_shards * cap + 1,), -1, jnp.int32)
    buckets = buckets.at[flat_slot].set(ids.astype(jnp.int32), mode="drop")
    return buckets[:-1].reshape(n_shards, cap), jnp.where(keep, flat_slot, -1), \
        jnp.sum(1 - keep.astype(jnp.int32))


def make_routed_pull_push(
    mesh,
    rows_per_shard: int,
    dim: int,
    cap_local: int,
    cap_route: int,
    shard_axes: Tuple[str, ...] = ("data", "model"),
):
    """Build (pull, push) jitted shard_map functions for one table.

    Table layout: (rows, dim) row-sharded over ``shard_axes`` (flattened,
    n_shards devices-on-those-axes), rows hash-permuted by ``slot_of``.
    ids layout: (n_shards * cap_local,) sharded over the same axes — each
    device owns cap_local (deduplicated) ids.
    """
    axes = tuple(a for a in shard_axes if a in mesh.axis_names)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]

    def pull_body(table_shard, my_ids):
        # table_shard: (rows_per_shard, dim); my_ids: (cap_local,) padded w/ dup
        me_targets = (my_ids % n_shards).astype(jnp.int32)
        local_rows = (my_ids // n_shards).astype(jnp.int32)
        buckets, slot_of_id, dropped = _bucket(local_rows, me_targets, n_shards, cap_route)
        # route requests: a2a (n_shards, cap) -> requests addressed to me
        reqs = jax.lax.all_to_all(
            buckets, axes, split_axis=0, concat_axis=0, tiled=True
        )
        valid = reqs >= 0
        rows = jnp.take(table_shard, jnp.maximum(reqs, 0).reshape(-1), axis=0)
        rows = rows * valid.reshape(-1, 1).astype(rows.dtype)
        rows = rows.reshape(n_shards, cap_route, dim)
        # route responses back
        resp = jax.lax.all_to_all(rows, axes, split_axis=0, concat_axis=0, tiled=True)
        flat = jnp.concatenate(
            [resp.reshape(n_shards * cap_route, dim),
             jnp.zeros((1, dim), resp.dtype)], axis=0)
        working = jnp.take(flat, jnp.where(slot_of_id >= 0, slot_of_id,
                                           n_shards * cap_route), axis=0)
        return working, slot_of_id, dropped[None]

    def push_body(table_shard, accum_shard, my_ids, row_grads, lr, eps):
        me_targets = (my_ids % n_shards).astype(jnp.int32)
        local_rows = (my_ids // n_shards).astype(jnp.int32)
        buckets, slot_of_id, dropped = _bucket(local_rows, me_targets, n_shards, cap_route)
        # place grads into bucket slots, route to owners
        gbuf = jnp.zeros((n_shards * cap_route + 1, dim), row_grads.dtype)
        gbuf = gbuf.at[jnp.where(slot_of_id >= 0, slot_of_id, n_shards * cap_route)
                       ].set(row_grads, mode="drop")
        gsend = gbuf[:-1].reshape(n_shards, cap_route, dim)
        greq = jax.lax.all_to_all(buckets, axes, split_axis=0, concat_axis=0, tiled=True)
        grecv = jax.lax.all_to_all(gsend, axes, split_axis=0, concat_axis=0, tiled=True)
        valid = (greq >= 0).reshape(-1)
        rows = jnp.maximum(greq.reshape(-1), 0)
        g = grecv.reshape(-1, dim) * valid[:, None].astype(grecv.dtype)
        g = g.astype(jnp.float32)
        # SPARSE shard-local AdaGrad: touch only the requested rows — a dense
        # read-modify-write of the 2 GB shard per step would be O(shard), not
        # O(working set).  Duplicate rows (several requesters) first combine
        # their g^2 in the accumulator scatter, then each contribution's
        # delta uses the fully-updated denominator (same convention as
        # SparseAdagrad.apply_rows).
        new_accum = accum_shard.at[rows].add(g * g)
        a_rows = jnp.take(new_accum, rows, axis=0)
        delta = -lr * g / (jnp.sqrt(a_rows) + eps)
        new_table = table_shard.at[rows].add(delta.astype(table_shard.dtype))
        return new_table, new_accum, dropped[None]

    table_spec = P(axes, None)
    ids_spec = P(axes)
    pull = shard_map(
        pull_body, mesh=mesh,
        in_specs=(table_spec, ids_spec),
        out_specs=(P(axes, None), ids_spec, P(axes)),
        check_rep=False,
    )
    push = shard_map(
        push_body, mesh=mesh,
        in_specs=(table_spec, table_spec, ids_spec, P(axes, None), P(), P()),
        out_specs=(table_spec, table_spec, P(axes)),
        check_rep=False,
    )
    return pull, push


def reference_pull(table, ids, rows_per_shard, n_shards):
    """Oracle: dense gather through the same hash-slot mapping."""
    return jnp.take(table, slot_of(ids, rows_per_shard, n_shards), axis=0)
