"""Payload compression primitives for cross-pod merges (beyond paper).

Symmetric per-tensor int8 quantization with an explicit scale, plus the
error-feedback residual helper.  ``repro.core.merge.int8_ef_mean`` composes
these with the two-phase schedule; they are exposed separately for reuse
(e.g. compressed checkpoint deltas) and for property tests.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray, levels: int = 127) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x -> (q int8, scale f32) with x ~= q * scale, |q| <= levels."""
    s = jnp.max(jnp.abs(x.astype(jnp.float32))) / levels + 1e-30
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -levels, levels).astype(jnp.int8)
    return q, s


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def quantization_residual(x: jnp.ndarray, q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Error-feedback residual: the part of x the int8 payload failed to carry."""
    return x.astype(jnp.float32) - dequantize_int8(q, scale)
