"""Embedding engine — the single facade over the sparse-parameter path.

The TPU rendering of the paper's hierarchical parameter server (§2.3):
terabyte-class tables row-sharded across the mesh, trained through per-batch
*working-set pulls* (each instance references ~100 of 1e11 features, so
compute and communication scale with the deduplicated working set, never
with the table).  The engine owns everything sparse:

  - the ``TableSpec``s (shape, combiner, which batch field feeds each table),
  - the pull capacity (static working-set bound),
  - the sparse optimizer (``SparseAdagrad`` — every-step sync, paper §5),
  - a pluggable ``EmbeddingBackend`` deciding HOW rows move:
    ``GatherBackend`` (dedup + ``jnp.take``, single-device/GSPMD) or
    ``RoutedBackend`` (explicit all-to-all PS routing, hash-sharded) —
    see ``repro.core.embedding_backend`` for the contract.

Training path per batch (Algorithm 1 lines 3, 11, 13):
  1. ``pull_batch(tables, batch)``  -> {name: WorkingSet} (one pull each)
  2. model fwd/bwd over ``ws.rows[ws.inverse]`` — grads land on the compact
     working set, not the table,
  3. ``push(tables, accum, working_sets, row_grads)`` — backend scatters the
     AdaGrad row updates back.

JAX has no native EmbeddingBag and no CSR/CSC sparse — the bag lookup here is
built from ``jnp.take`` + ``jax.ops.segment_sum`` (this IS part of the system,
per the assignment), with a Pallas TPU kernel for the fused gather-reduce hot
path in ``repro.kernels.embedding_bag``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.embedding_backend import (  # noqa: F401  (re-exported API)
    EmbeddingBackend,
    GatherBackend,
    WorkingSet,
    make_backend,
    pull_working_set,
)
from repro.core.sparse_optim import (
    SparseAdagrad,
    SparseAdagradConfig,
    SparseAdagradState,
)


# --------------------------------------------------------------------- lookup
def embedding_bag(
    table: jnp.ndarray,        # (rows, dim)
    ids: jnp.ndarray,          # (nnz,) int32 — flattened multi-hot ids
    segment_ids: jnp.ndarray,  # (nnz,) int32 — bag index of each id, sorted
    num_bags: int,
    weights: Optional[jnp.ndarray] = None,  # (nnz,) per-id weights
    combiner: str = "sum",
) -> jnp.ndarray:
    """Multi-hot bag lookup: out[b] = combine_{j: seg[j]==b} w_j * table[ids[j]]."""
    emb = jnp.take(table, ids, axis=0)  # (nnz, dim) gather
    if weights is not None:
        emb = emb * weights[:, None].astype(emb.dtype)
    out = jax.ops.segment_sum(emb, segment_ids, num_segments=num_bags)
    if combiner == "sum":
        return out
    if combiner == "mean":
        cnt = jax.ops.segment_sum(
            jnp.ones_like(segment_ids, emb.dtype), segment_ids, num_segments=num_bags
        )
        return out / jnp.maximum(cnt, 1.0)[:, None]
    if combiner == "sqrtn":
        cnt = jax.ops.segment_sum(
            jnp.ones_like(segment_ids, emb.dtype), segment_ids, num_segments=num_bags
        )
        return out / jnp.sqrt(jnp.maximum(cnt, 1.0))[:, None]
    raise ValueError(f"unknown combiner {combiner!r}")


# ---------------------------------------------------------------- the engine
@dataclasses.dataclass(frozen=True)
class TableSpec:
    name: str
    rows: int
    dim: int
    combiner: str = "sum"
    dtype: jnp.dtype = jnp.float32
    id_field: Optional[str] = None   # batch key holding this table's ids
                                     # (None -> the table name itself)


class EmbeddingEngine:
    """Owns the tables' specs, capacity, sparse optimizer, and backend.

    ``optimizer`` may be a ``SparseAdagrad``, a ``SparseAdagradConfig``, or
    ``None`` (defaults).  ``backend`` defaults to ``GatherBackend``.

    Tables handled by the engine live in the BACKEND'S physical layout
    (``init`` prepares them; ``export`` converts back to logical rows for
    inspection/parity).  Checkpoints therefore roundtrip only through the
    same placement they were saved with.
    """

    def __init__(
        self,
        specs: Dict[str, TableSpec],
        capacity: int,
        optimizer=None,
        backend: Optional[EmbeddingBackend] = None,
    ):
        self.specs = dict(specs)
        self.capacity = int(capacity)
        if optimizer is None:
            optimizer = SparseAdagrad()
        elif isinstance(optimizer, SparseAdagradConfig):
            optimizer = SparseAdagrad(optimizer)
        self.opt: SparseAdagrad = optimizer
        self.backend: EmbeddingBackend = backend if backend is not None else GatherBackend()

    # ------------------------------------------------------------ lifecycle
    def init(self, rng: jax.Array, scale: float = 0.01) -> Dict[str, jnp.ndarray]:
        """Random-normal logical init, converted to the backend's layout."""
        tables = {}
        for i, (name, spec) in enumerate(sorted(self.specs.items())):
            key = jax.random.fold_in(rng, i)
            t = (
                jax.random.normal(key, (spec.rows, spec.dim), jnp.float32) * scale
            ).astype(spec.dtype)
            tables[name] = self.backend.prepare(t)
        return tables

    def init_state(self, tables: Dict[str, jnp.ndarray]) -> SparseAdagradState:
        return self.opt.init(tables)

    def prepare(self, tables: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
        """Logical tables -> backend layout (e.g. when init'd externally)."""
        return {n: self.backend.prepare(t) for n, t in tables.items()}

    def export(self, tables: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
        """Backend layout -> logical rows (row i == feature id i)."""
        return {n: self.backend.export(t) for n, t in tables.items()}

    # ------------------------------------------------------------ pull/push
    def ids_from_batch(self, batch) -> Dict[str, jnp.ndarray]:
        """Extract each table's flattened id tensor from a batch dict."""
        return {
            name: batch[spec.id_field or name].reshape(-1)
            for name, spec in self.specs.items()
        }

    def pull(self, tables, flat_ids: Dict[str, jnp.ndarray]) -> Dict[str, WorkingSet]:
        """Algorithm 1 line 3: one working-set pull per table."""
        return {
            name: self.backend.pull(tables[name], ids, self.capacity)
            for name, ids in flat_ids.items()
        }

    def pull_batch(self, tables, batch) -> Dict[str, WorkingSet]:
        return self.pull(tables, self.ids_from_batch(batch))

    def push(self, tables, accum, working_sets: Dict[str, WorkingSet], row_grads):
        """Algorithm 1 line 13: scatter row updates back (sparse optimizer
        applied by the backend, shard-locally for the routed placement)."""
        new_tables, new_accum = {}, {}
        for name, ws in working_sets.items():
            nt, na = self.backend.push(
                tables[name], accum[name], ws, row_grads[name], self.opt
            )
            new_tables[name] = nt
            new_accum[name] = na
        return new_tables, new_accum

    @staticmethod
    def overflow(working_sets: Dict[str, WorkingSet]) -> jnp.ndarray:
        """Total dropped (unserved) requests this batch — the PS overload
        counter production monitoring watches."""
        return sum(ws.n_dropped for ws in working_sets.values())

    # -------------------------------------------------------------- lookups
    @staticmethod
    def bag_from_working(
        working: jnp.ndarray,      # (capacity, dim) pulled rows
        inverse: jnp.ndarray,      # (nnz,) id slot -> working row
        segment_ids: jnp.ndarray,  # (nnz,) id slot -> bag
        num_bags: int,
        weights: Optional[jnp.ndarray] = None,
        combiner: str = "sum",
    ) -> jnp.ndarray:
        """Bag lookup routed through the pulled working set (differentiable in
        ``working`` — its gradient is exactly the row_grads to scatter back)."""
        emb = jnp.take(working, inverse, axis=0)
        if weights is not None:
            emb = emb * weights[:, None].astype(emb.dtype)
        out = jax.ops.segment_sum(emb, segment_ids, num_segments=num_bags)
        if combiner == "mean":
            cnt = jax.ops.segment_sum(
                jnp.ones_like(segment_ids, emb.dtype), segment_ids, num_segments=num_bags
            )
            out = out / jnp.maximum(cnt, 1.0)[:, None]
        return out

    def memory_bytes(self) -> int:
        return sum(
            s.rows * s.dim * jnp.dtype(s.dtype).itemsize for s in self.specs.values()
        )
