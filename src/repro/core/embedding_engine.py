"""Sharded embedding engine — the TPU rendering of the hierarchical parameter
server (paper §2.3): terabyte-class tables row-sharded across every chip of
the mesh, with per-batch *working-set pulls*.

The paper's key observation survives intact on TPU: each instance references
only ~100 of the 1e11 sparse features, so compute and communication are
proportional to the deduplicated working set, never to the table size.

JAX has no native EmbeddingBag and no CSR/CSC sparse — the bag lookup here is
built from ``jnp.take`` + ``jax.ops.segment_sum`` (this IS part of the system,
per the assignment), with a Pallas TPU kernel for the fused gather-reduce hot
path in ``repro.kernels.embedding_bag``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------- lookup
def embedding_bag(
    table: jnp.ndarray,        # (rows, dim)
    ids: jnp.ndarray,          # (nnz,) int32 — flattened multi-hot ids
    segment_ids: jnp.ndarray,  # (nnz,) int32 — bag index of each id, sorted
    num_bags: int,
    weights: Optional[jnp.ndarray] = None,  # (nnz,) per-id weights
    combiner: str = "sum",
) -> jnp.ndarray:
    """Multi-hot bag lookup: out[b] = combine_{j: seg[j]==b} w_j * table[ids[j]]."""
    emb = jnp.take(table, ids, axis=0)  # (nnz, dim) gather
    if weights is not None:
        emb = emb * weights[:, None].astype(emb.dtype)
    out = jax.ops.segment_sum(emb, segment_ids, num_segments=num_bags)
    if combiner == "sum":
        return out
    if combiner == "mean":
        cnt = jax.ops.segment_sum(
            jnp.ones_like(segment_ids, emb.dtype), segment_ids, num_segments=num_bags
        )
        return out / jnp.maximum(cnt, 1.0)[:, None]
    if combiner == "sqrtn":
        cnt = jax.ops.segment_sum(
            jnp.ones_like(segment_ids, emb.dtype), segment_ids, num_segments=num_bags
        )
        return out / jnp.sqrt(jnp.maximum(cnt, 1.0))[:, None]
    raise ValueError(f"unknown combiner {combiner!r}")


# --------------------------------------------------------------- working set
def pull_working_set(
    flat_ids: jnp.ndarray, capacity: int
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Deduplicate the ids referenced by a batch (the PS "pull" manifest).

    Returns (unique_ids (capacity,), inverse (nnz,)) with static shapes:
    ``unique_ids`` is padded by repeating the smallest id (harmless for the
    scatter since padded slots receive zero gradient), ``inverse`` maps each
    original id slot to its row in the pulled working set.
    ``capacity`` must bound the number of distinct ids in a batch.
    """
    uids, inv = jnp.unique(
        flat_ids, size=capacity, fill_value=None, return_inverse=True
    )
    return uids.astype(jnp.int32), inv.astype(jnp.int32)


# ---------------------------------------------------------------- the engine
@dataclasses.dataclass(frozen=True)
class TableSpec:
    name: str
    rows: int
    dim: int
    combiner: str = "sum"
    dtype: jnp.dtype = jnp.float32


class EmbeddingEngine:
    """Owns a dict of row-sharded tables and the pull/lookup/push path.

    Training path per batch (mirrors Algorithm 1 lines 3, 11, 13):
      1. ``pull(ids)``      — dedup ids, gather working rows (one gather).
      2. model fwd/bwd over ``working[inverse]`` — grads land on the compact
         working set, not the table.
      3. ``SparseAdagrad.apply_rows`` — scatter the row updates back.
    """

    def __init__(self, specs: Dict[str, TableSpec], capacity: int):
        self.specs = dict(specs)
        self.capacity = int(capacity)

    def init(self, rng: jax.Array, scale: float = 0.01) -> Dict[str, jnp.ndarray]:
        tables = {}
        for i, (name, spec) in enumerate(sorted(self.specs.items())):
            key = jax.random.fold_in(rng, i)
            tables[name] = (
                jax.random.normal(key, (spec.rows, spec.dim), jnp.float32) * scale
            ).astype(spec.dtype)
        return tables

    def pull(self, table: jnp.ndarray, flat_ids: jnp.ndarray):
        """Gather the working set for one table.  Returns (uids, inv, working)."""
        uids, inv = pull_working_set(flat_ids, self.capacity)
        working = jnp.take(table, uids, axis=0)
        return uids, inv, working

    @staticmethod
    def bag_from_working(
        working: jnp.ndarray,      # (capacity, dim) pulled rows
        inverse: jnp.ndarray,      # (nnz,) id slot -> working row
        segment_ids: jnp.ndarray,  # (nnz,) id slot -> bag
        num_bags: int,
        weights: Optional[jnp.ndarray] = None,
        combiner: str = "sum",
    ) -> jnp.ndarray:
        """Bag lookup routed through the pulled working set (differentiable in
        ``working`` — its gradient is exactly the row_grads to scatter back)."""
        emb = jnp.take(working, inverse, axis=0)
        if weights is not None:
            emb = emb * weights[:, None].astype(emb.dtype)
        out = jax.ops.segment_sum(emb, segment_ids, num_segments=num_bags)
        if combiner == "mean":
            cnt = jax.ops.segment_sum(
                jnp.ones_like(segment_ids, emb.dtype), segment_ids, num_segments=num_bags
            )
            out = out / jnp.maximum(cnt, 1.0)[:, None]
        return out

    def memory_bytes(self) -> int:
        return sum(
            s.rows * s.dim * jnp.dtype(s.dtype).itemsize for s in self.specs.values()
        )
