"""Embedding engine — the single facade over the sparse-parameter path.

The TPU rendering of the paper's hierarchical parameter server (§2.3):
terabyte-class tables row-sharded across the mesh, trained through per-batch
*working-set pulls* (each instance references ~100 of 1e11 features, so
compute and communication scale with the deduplicated working set, never
with the table).  The engine owns everything sparse:

  - the ``TableSpec``s (shape, combiner, which batch field feeds each table),
  - the pull capacity (static working-set bound),
  - the sparse optimizer (``SparseAdagrad`` — every-step sync, paper §5),
  - a pluggable ``EmbeddingBackend`` deciding HOW rows move:
    ``GatherBackend`` (dedup + ``jnp.take``, single-device/GSPMD),
    ``RoutedBackend`` (explicit all-to-all PS routing, hash-sharded), or
    ``CachedBackend`` (device hot-row cache over a host-resident table,
    paper §2.3) — see ``repro.core.embedding_backend`` for the contract.

Every backend carries an explicit per-table STATE pytree (empty for the
stateless placements; the cache tier's id->slot map/frequency counters/
cached rows for ``cached``), created by ``init_backend_state`` and threaded
through every pull/push — it is jit-traceable and checkpointable.

Training path per batch (Algorithm 1 lines 3, 11, 13):
  1. ``pull_batch(tables, accum, states, batch)``
       -> ({name: WorkingSet}, tables, accum, states)  (one pull each;
     tables/accum come back because a cache pull may spill evicted rows)
  2. model fwd/bwd over ``ws.rows[ws.inverse]`` — grads land on the compact
     working set, not the table,
  3. ``push(tables, accum, states, working_sets, row_grads)`` — backend
     scatters the AdaGrad row updates back (or into its cache).

The pull is also exposed as an explicit *stage* (``pull_stage`` — one jitted
executable with buffer donation; ``pull_async`` dispatches it for a batch
WITHOUT blocking, ``commit`` is the documented hand-off point): because a
pull is a pure ``(tables, accum, states) -> (ws, tables, accum, states)``
transition, a prefetcher (``repro.core.prefetch.PrefetchingEngine``) can
speculatively dispatch batch t+1's pull while the device still runs batch
t's fwd/bwd — the cache tier's table spill is the only ordering point, and
it is serialized by handing the pull's returned tables to the next stage.

Serving path (co-located CTR inference, ``runtime/serve_ctr.py``): the same
engine exposes a READ-ONLY lookup next to the training pull —
``lookup``/``lookup_batch`` trace inside a caller's jit, ``lookup_stage``
is the standalone compiled stage (donating NOTHING — it must never consume
live training buffers).  A lookup serves exactly the rows a pull would
(cache-fresh values included) with zero side effects on backend state, so
an inference server can read the live trainer's tables between steps
without moving the training trajectory.  Under the DiskStore the lookup
stage reads pages through ``store.gather(serve=True)`` (serve-metered page
cache, no readahead queueing) and OVERLAYS the pending staged training
outputs read-only (``_staged_updates``) instead of absorbing them — the
store is never written on the serving path.

JAX has no native EmbeddingBag and no CSR/CSC sparse — the bag lookup here is
built from ``jnp.take`` + ``jax.ops.segment_sum`` (this IS part of the system,
per the assignment), with a Pallas TPU kernel for the fused gather-reduce hot
path in ``repro.kernels.embedding_bag``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.embedding_backend import (  # noqa: F401  (re-exported API)
    EmbeddingBackend,
    GatherBackend,
    WorkingSet,
    make_backend,
    pull_working_set,
)
from repro.core.row_store import HostStore
from repro.core.sparse_optim import (
    SparseAdagrad,
    SparseAdagradConfig,
    SparseAdagradState,
)


# --------------------------------------------------------------------- lookup
def embedding_bag(
    table: jnp.ndarray,        # (rows, dim)
    ids: jnp.ndarray,          # (nnz,) int32 — flattened multi-hot ids
    segment_ids: jnp.ndarray,  # (nnz,) int32 — bag index of each id, sorted
    num_bags: int,
    weights: Optional[jnp.ndarray] = None,  # (nnz,) per-id weights
    combiner: str = "sum",
) -> jnp.ndarray:
    """Multi-hot bag lookup: out[b] = combine_{j: seg[j]==b} w_j * table[ids[j]]."""
    emb = jnp.take(table, ids, axis=0)  # (nnz, dim) gather
    if weights is not None:
        emb = emb * weights[:, None].astype(emb.dtype)
    out = jax.ops.segment_sum(emb, segment_ids, num_segments=num_bags)
    if combiner == "sum":
        return out
    if combiner == "mean":
        cnt = jax.ops.segment_sum(
            jnp.ones_like(segment_ids, emb.dtype), segment_ids, num_segments=num_bags
        )
        return out / jnp.maximum(cnt, 1.0)[:, None]
    if combiner == "sqrtn":
        cnt = jax.ops.segment_sum(
            jnp.ones_like(segment_ids, emb.dtype), segment_ids, num_segments=num_bags
        )
        return out / jnp.sqrt(jnp.maximum(cnt, 1.0))[:, None]
    raise ValueError(f"unknown combiner {combiner!r}")


# ---------------------------------------------------------------- the engine
@dataclasses.dataclass(frozen=True)
class TableSpec:
    """Shape + batch wiring of one embedding table.

    ``id_field`` names the batch key(s) holding this table's ids:
      - ``None``: the table name itself is the batch key,
      - a string: that batch key (any trailing shape, flattened),
      - a tuple of strings: several batch keys feeding ONE table (e.g. DIN's
        history + target item ids).  Each field is flattened per instance
        and the fields are concatenated along the per-instance axis, so the
        flat id vector stays instance-major — the trainer relies on that to
        slice the pull's inverse map into per-pod batch shards.
    ``id_col`` selects one column of the (batch, n) id tensor — the DLRM
    regime where 26 single-hot tables share one ``sparse_ids`` field.
    """

    name: str
    rows: int
    dim: int
    combiner: str = "sum"
    dtype: jnp.dtype = jnp.float32
    id_field: Optional[Union[str, Sequence[str]]] = None
    id_col: Optional[int] = None


class EmbeddingEngine:
    """Owns the tables' specs, capacity, sparse optimizer, and backend.

    ``optimizer`` may be a ``SparseAdagrad``, a ``SparseAdagradConfig``, or
    ``None`` (defaults).  ``backend`` defaults to ``GatherBackend``.

    Tables handled by the engine live in the BACKEND'S physical layout
    (``init`` prepares them; ``export`` converts back to logical rows for
    inspection/parity).  Checkpoints therefore roundtrip only through the
    same placement they were saved with.
    """

    def __init__(
        self,
        specs: Dict[str, TableSpec],
        capacity: int,
        optimizer=None,
        backend: Optional[EmbeddingBackend] = None,
        store=None,
    ):
        self.specs = dict(specs)
        self.capacity = int(capacity)
        if optimizer is None:
            optimizer = SparseAdagrad()
        elif isinstance(optimizer, SparseAdagradConfig):
            optimizer = SparseAdagrad(optimizer)
        self.opt: SparseAdagrad = optimizer
        self.backend: EmbeddingBackend = backend if backend is not None else GatherBackend()
        # the cold bottom of the hierarchy: HostStore (full jnp tables, the
        # default) or DiskStore (paged spill dir; pull/push see staged
        # working-set rows).  The backend's dataflow must match the store.
        self.store = store if store is not None else HostStore()
        staged = bool(getattr(self.backend, "staged", False))
        if self.store.kind == "disk" and not staged:
            raise ValueError(
                "DiskStore requires a staged backend (make_backend(..., "
                "staged=True)): the pull must consume working-set rows, "
                "not a resident table")
        if self.store.kind != "disk" and staged:
            raise ValueError(
                "staged backend requires store='disk': nothing stages the "
                "working-set rows under the host store")
        # per-table (uids, valid) of the batch currently staged — what the
        # gather-staged absorb needs to commit push outputs to the store
        self._staged_pending: Dict[str, Any] = {}
        self._staged_stages: Dict[bool, Any] = {}
        self._pull_jits: Dict[bool, Any] = {}   # donate flag -> jitted stage
        self._lookup_jit: Any = None            # read-only serving lookup
        self._staged_lookup: Any = None         # its DiskStore wrapper
        # id extraction runs EVERY step in front of the pull jit; compiled
        # once so per-step eager column slices don't ship their start index
        # host->device each step (id_col tables: 26 slices/step on DLRM).
        # No donation: the batch is re-read by the train stage.
        self._ids_jit = jax.jit(self._ids_from_batch_traced, donate_argnums=())

    # ------------------------------------------------------------ lifecycle
    def init(self, rng: jax.Array, scale: float = 0.01) -> Dict[str, jnp.ndarray]:
        """Random-normal logical init, converted to the backend's layout.

        Under the DiskStore the SAME per-table PRNG values are generated
        (host/disk parity is bit-exact by construction) but land in the
        store's page files; the returned "tables" are the (capacity, dim)
        staging buffers the pull/push stages thread instead.
        """
        tables = {}
        for i, (name, spec) in enumerate(sorted(self.specs.items())):
            key = jax.random.fold_in(rng, i)
            t = (
                jax.random.normal(key, (spec.rows, spec.dim), jnp.float32) * scale
            ).astype(spec.dtype)
            if self.store.kind == "disk":
                vals = np.asarray(jax.device_get(t))
                self.store.create_table(
                    name, spec.rows, spec.dim, spec.dtype,
                    init_rows_fn=lambda a, b, _v=vals: _v[a:b],
                    accum_init=self.opt.cfg.initial_accumulator,
                )
                tables[name] = jnp.zeros((self.capacity, spec.dim), spec.dtype)
            else:
                tables[name] = self.backend.prepare(t)
        return tables

    def init_state(self, tables: Dict[str, jnp.ndarray]) -> SparseAdagradState:
        return self.opt.init(tables)

    def init_backend_state(self, tables: Dict[str, jnp.ndarray]) -> Dict[str, Any]:
        """Per-table backend state pytrees (empty tuples when stateless)."""
        return {n: self.backend.init_state(t) for n, t in tables.items()}

    def prepare(self, tables: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
        """Logical tables -> backend layout (e.g. when init'd externally)."""
        return {n: self.backend.prepare(t) for n, t in tables.items()}

    def export(self, tables: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
        """Backend layout -> logical rows (row i == feature id i).

        For placements with deferred writes (the cache tier), call
        ``flush`` first so dirty cached rows reach the tables."""
        return {n: self.backend.export(t) for n, t in tables.items()}

    def flush(self, tables, accum, states):
        """Force deferred backend writes (dirty cached rows) back into the
        tables/accumulator — the checkpoint/export consistency point."""
        new_tables, new_accum, new_states = {}, {}, {}
        for name in tables:
            nt, na, ns = self.backend.flush(
                tables[name], accum[name], states[name]
            )
            new_tables[name], new_accum[name], new_states[name] = nt, na, ns
        return new_tables, new_accum, new_states

    # ------------------------------------------------------------ pull/push
    def ids_from_batch(self, batch) -> Dict[str, jnp.ndarray]:
        """Extract each table's flattened id tensor from a batch dict.

        Multi-field tables (``id_field`` is a tuple) concatenate their
        fields along the per-instance axis before flattening, so the flat
        ids — and therefore the pull's inverse map — stay instance-major
        and remain sliceable into per-pod shards.  Compiled (one executable
        per batch structure): the hot path calls this every step.
        """
        return self._ids_jit(batch)

    def _ids_from_batch_traced(self, batch) -> Dict[str, jnp.ndarray]:
        out = {}
        for name, spec in self.specs.items():
            field = spec.id_field or name
            if isinstance(field, (tuple, list)):
                parts = [
                    jnp.reshape(batch[f], (batch[f].shape[0], -1))
                    for f in field
                ]
                ids = jnp.concatenate(parts, axis=1)
            else:
                ids = batch[field]
                if spec.id_col is not None:
                    ids = ids[..., spec.id_col]
            out[name] = ids.reshape(-1)
        return out

    def pull(self, tables, accum, states, flat_ids: Dict[str, jnp.ndarray]):
        """Algorithm 1 line 3: one working-set pull per table.

        Returns (working_sets, tables, accum, states) — the table tree comes
        back because a cache-tier pull may spill evicted dirty rows into it.
        """
        wss, new_tables, new_accum, new_states = {}, {}, {}, {}
        for name, ids in flat_ids.items():
            ws, nt, na, ns = self.backend.pull(
                tables[name], accum[name], states[name], ids, self.capacity
            )
            wss[name] = ws
            new_tables[name], new_accum[name], new_states[name] = nt, na, ns
        return wss, new_tables, new_accum, new_states

    def pull_batch(self, tables, accum, states, batch):
        return self.pull(tables, accum, states, self.ids_from_batch(batch))

    # ------------------------------------------------- read-only lookup path
    def lookup(self, tables, accum, states, flat_ids: Dict[str, jnp.ndarray]):
        """Read-only serving lookup: ``({name: WorkingSet}, aux)``.

        The inference counterpart of ``pull``: serves identical row values
        (the cache tier's dirty rows included — freshly trained rows are
        servable immediately) but is side-effect-free on every input, so
        interleaving lookups with training changes nothing.  ``aux`` sums
        the backends' serve meters (f32 scalars) across tables."""
        wss, aux_tot = {}, {}
        for name, ids in flat_ids.items():
            ws, aux = self.backend.lookup(
                tables[name], accum[name], states[name], ids, self.capacity
            )
            wss[name] = ws
            for k, v in aux.items():
                aux_tot[k] = aux_tot.get(k, 0.0) + v
        return wss, aux_tot

    def lookup_batch(self, tables, accum, states, batch):
        return self.lookup(tables, accum, states, self.ids_from_batch(batch))

    def lookup_stage(self):
        """The compiled LOOKUP stage: ``(tables, accum, states, flat_ids) ->
        (wss, aux)`` with NOTHING donated — the stage reads the live
        training buffers and must leave them valid for the trainer.

        Under the DiskStore the returned callable wraps the same jitted
        executable with read-only staging (``stage_lookup``): serve-metered
        page reads plus a host-side overlay of any pending staged training
        outputs, never an absorb."""
        if self._lookup_jit is None:
            def _lookup(tables, accum, states, flat_ids):
                return self.lookup(tables, accum, states, flat_ids)
            # donate_argnums=() is the contract, not an omission: a serving
            # read must never consume the trainer's live buffers
            self._lookup_jit = jax.jit(_lookup, donate_argnums=())
        if self.store.kind == "disk":
            return self._disk_lookup_stage()
        return self._lookup_jit

    # --------------------------------------------------- async pull staging
    def pull_stage(self, donate: bool = True):
        """The compiled PULL stage: ``(tables, accum, states, flat_ids) ->
        (wss, tables, accum, states)``.

        One cached ``jax.jit`` per donate flag — the SAME executable serves
        synchronous pulls and speculative prefetch dispatches, so prefetched
        training is bit-identical to synchronous training by construction.
        With ``donate=True`` the table/accumulator/state buffers are donated
        (the pull consumes the committed sparse state and hands back the
        post-pull state; callers must drop their old references).

        Under the DiskStore the returned callable wraps the SAME jitted
        executable with the host-side staging protocol (read-ahead ->
        absorb -> gather -> stage); see ``_disk_pull_stage``.
        """
        donate = bool(donate)
        if donate not in self._pull_jits:
            def _pull(tables, accum, states, flat_ids):
                return self.pull(tables, accum, states, flat_ids)
            self._pull_jits[donate] = jax.jit(
                _pull, donate_argnums=(0, 1, 2) if donate else ()
            )
        if self.store.kind == "disk":
            return self._disk_pull_stage(donate)
        return self._pull_jits[donate]

    # ----------------------------------------------- disk-store staging path
    def host_dedup(self, ids_np: np.ndarray):
        """Numpy mirror of ``_dedup``'s uid layout, run at staging time.

        Must match ``jnp.unique(size=capacity, fill_value=None)`` bit-for-
        bit: sorted ascending unique, truncated to capacity KEEPING THE
        SMALLEST, padded by repeating the minimum.  ``valid`` marks first
        occurrences (pads repeat an earlier value, so a strict > test finds
        them) — only valid positions commit back to the store, because a
        last-wins numpy scatter would let pad rows overwrite real updates.
        """
        cap = self.capacity
        u = np.unique(np.asarray(ids_np, np.int64).reshape(-1))
        k = min(len(u), cap)
        uids = np.full((cap,), u[0], np.int64)
        uids[:k] = u[:k]
        valid = np.ones((cap,), bool)
        valid[1:] = uids[1:] > uids[:-1]
        return uids, valid

    def _is_cached(self) -> bool:
        return getattr(self.backend, "cache_rows", None) is not None

    def _staged_updates(self, tables, accum, states):
        """Pending staged training outputs as ``{name: (uids, rows, accum)}``
        numpy triples — the rows the DiskStore does not hold yet.

        cached: the pull's table/accum OUTPUTS are the evicted-dirty spill
        rows, ids in ``state.spill_uid`` (-1 = no spill).  gather: the
        push's outputs are the updated staged rows of the batch recorded in
        ``_staged_pending``.  READ-ONLY: shared by ``absorb_staged`` (which
        scatters the triples into the store and clears the pending
        metadata) and the serving lookup's overlay (which patches them onto
        store reads WITHOUT committing anything).  The explicit
        ``jax.device_get`` is the deliberate d2h boundary of the disk path
        (strict-transfers-exempt); it blocks on the train step still
        holding these buffers.
        """
        out: Dict[str, Any] = {}
        if self._is_cached():
            for n in self.specs:
                got = jax.device_get({
                    "uid": states[n].spill_uid,
                    "rows": tables[n], "accum": accum[n],
                })
                m = np.asarray(got["uid"]) >= 0
                if m.any():
                    out[n] = (np.asarray(got["uid"])[m],
                              np.asarray(got["rows"])[m],
                              np.asarray(got["accum"])[m])
        else:
            for n, (uids, valid) in self._staged_pending.items():
                got = jax.device_get({"rows": tables[n], "accum": accum[n]})
                out[n] = (uids[valid],
                          np.asarray(got["rows"])[valid],
                          np.asarray(got["accum"])[valid])
        return out

    def absorb_staged(self, tables, accum, states):
        """Commit the previous step's staged outputs into the DiskStore.

        The writes are of absolute row values, so re-absorbing
        (save-then-continue, resume replay) is idempotent — which is also
        why the serving lookup may overlay the same triples read-only
        while they sit un-absorbed."""
        for n, (uids, rows, acc) in self._staged_updates(
                tables, accum, states).items():
            self.store.scatter(n, uids, rows, acc)
        self._staged_pending = {}

    def _disk_pull_stage(self, donate: bool):
        """Host staging wrapped around the jitted pull (DiskStore only).

        Order is the latency-hiding protocol: (1) the batch's dedup'd id
        stream is computed host-side (cheap numpy), (2) ``readahead``
        queues its pages for background fault-in — disk reads overlap the
        device still training the previous batch, (3) ``absorb_staged`` commits
        the previous staged outputs (this is the call that blocks on the
        train step), (4) ``gather`` finds the pages warm, (5) the rows are
        ``device_put`` and the SAME jitted pull executable dispatches.
        """
        if donate in self._staged_stages:
            return self._staged_stages[donate]
        inner = self._pull_jits[donate]

        def staged_pull(tables, accum, states, flat_ids):
            ids_np = jax.device_get(flat_ids)
            ded = {n: self.host_dedup(ids_np[n]) for n in ids_np}
            for n, (uids, valid) in ded.items():
                self.store.readahead(n, uids[valid])
            self.absorb_staged(tables, accum, states)
            staged_t, staged_a = {}, {}
            for n, (uids, _valid) in ded.items():
                rows, acc = self.store.gather(n, uids)
                staged_t[n] = jax.device_put(rows)
                staged_a[n] = jax.device_put(acc)
            self._staged_pending = ded
            return inner(staged_t, staged_a, states, flat_ids)

        self._staged_stages[donate] = staged_pull
        return staged_pull

    def stage_lookup(self, tables, accum, states, ids_np: Dict[str, np.ndarray]):
        """Read-only staging of a lookup batch's rows from the DiskStore.

        Returns ``(staged_tables, staged_accum)`` — (capacity, dim) device
        buffers in dedup'd-uid order, shaped exactly like the training
        staging buffers (same predict executable, no recompile).  Unlike
        the pull staging this NEVER writes the store: pages are read with
        ``serve=True`` (serve-metered, no readahead queueing), and any
        pending staged training outputs are OVERLAID onto the gathered rows
        host-side — the freshest values are served without absorbing the
        training side's commit, so a serving read cannot perturb the
        staging protocol.  The overlay blocks on the device buffers (an
        in-flight prefetched pull resolves here), which is the same wait
        the training absorb would pay.
        """
        overlay = self._staged_updates(tables, accum, states)
        staged_t, staged_a = {}, {}
        for n, ids in ids_np.items():
            uids, valid = self.host_dedup(ids)
            rows, acc = self.store.gather(n, uids, serve=True)
            ov = overlay.get(n)
            if ov is not None:
                o_uid, o_rows, o_acc = ov
                k = int(valid.sum())     # uids[:k] is sorted unique
                pos = np.searchsorted(uids[:k], o_uid)
                hit = pos < k
                hit[hit] = uids[pos[hit]] == o_uid[hit]
                rows[pos[hit]] = o_rows[hit].astype(rows.dtype, copy=False)
                acc[pos[hit]] = o_acc[hit]
            staged_t[n] = jax.device_put(rows)
            staged_a[n] = jax.device_put(acc)
        return staged_t, staged_a

    def _disk_lookup_stage(self):
        """Read-only staging wrapped around the jitted lookup (DiskStore)."""
        if self._staged_lookup is not None:
            return self._staged_lookup
        inner = self._lookup_jit

        def staged_lookup(tables, accum, states, flat_ids):
            ids_np = jax.device_get(flat_ids)
            staged_t, staged_a = self.stage_lookup(
                tables, accum, states, ids_np)
            return inner(staged_t, staged_a, states, flat_ids)

        self._staged_lookup = staged_lookup
        return staged_lookup

    def sync_store(self, tables, accum, states):
        """DiskStore commit point (checkpoint/export): absorb the pending
        staged outputs, write the device cache's dirty rows through, and
        persist every dirty page.  Leaves device state untouched (dirty
        bits stay set — the next sync rewrites the same values, which is
        idempotent), so it is safe at any commit boundary.  No-op under the
        host store."""
        if self.store.kind != "disk":
            return
        self.absorb_staged(tables, accum, states)
        if self._is_cached():
            for n in self.specs:
                got = jax.device_get({
                    "slot_uid": states[n].slot_uid, "dirty": states[n].dirty,
                    "rows": states[n].rows, "accum": states[n].accum,
                })
                m = np.asarray(got["dirty"]) & (np.asarray(got["slot_uid"]) >= 0)
                if m.any():
                    self.store.scatter(
                        n, np.asarray(got["slot_uid"])[m],
                        np.asarray(got["rows"])[m],
                        np.asarray(got["accum"])[m])
        self.store.flush()

    def reset_staging(self):
        """Drop pending staged-batch metadata (checkpoint resume: the
        restored pages already contain everything committed at save)."""
        self._staged_pending = {}

    def pull_async(self, tables, accum, states, batch, donate: bool = True):
        """Dispatch (do NOT block on) the pull stage for ``batch``.

        Returns the un-materialized ``(wss, tables, accum, states)`` —
        under JAX async dispatch these are futures, so the caller can keep
        queuing work (the next step's fwd/bwd) while the pull executes.
        """
        return self.pull_stage(donate)(
            tables, accum, states, self.ids_from_batch(batch)
        )

    @staticmethod
    def commit(pulled):
        """Hand a dispatched pull's ``(wss, tables, accum, states)`` to the
        train stage — the serialization point of the prefetch protocol.

        No computation happens here: the pull of batch t+1 commutes with the
        push of batch t except through the table/accum/state trees, and
        passing THESE returned trees onward is what serializes the cache
        tier's spills against the next step's reads."""
        return pulled

    def push(self, tables, accum, states, working_sets: Dict[str, WorkingSet],
             row_grads):
        """Algorithm 1 line 13: scatter row updates back (sparse optimizer
        applied by the backend — shard-locally for the routed placement,
        write-through to hot rows for the cache tier)."""
        new_tables, new_accum, new_states = {}, {}, {}
        for name, ws in working_sets.items():
            nt, na, ns = self.backend.push(
                tables[name], accum[name], states[name], ws,
                row_grads[name], self.opt
            )
            new_tables[name], new_accum[name], new_states[name] = nt, na, ns
        return new_tables, new_accum, new_states

    def cache_counters(self, states) -> Dict[str, float]:
        """Raw CUMULATIVE cache-tier counters summed across tables ({} for
        stateless placements).  Call outside jit — materializes the device
        scalars.  Interval (per-logging-window) deltas are the trainer's
        job: it snapshots these totals at each boundary."""
        tot: Dict[str, float] = {}
        stats_fn = getattr(self.backend, "stats", None)
        if stats_fn is not None:
            for s in states.values():
                for k, v in stats_fn(s).items():
                    tot[k] = tot.get(k, 0.0) + v
        # the store's page-cache/disk meters ride the same counter protocol
        # (cumulative floats; the trainer's logger diffs them per interval)
        for k, v in self.store.stats().items():
            tot[k] = tot.get(k, 0.0) + float(v)
        return tot

    @staticmethod
    def derive_cache_stats(counters: Dict[str, float]) -> Dict[str, float]:
        """Counter totals/deltas -> the reported stat dict ({} for {}).

        An interval with zero lookups (idle / predict-only window) reports
        ``cache_hit_rate = 0.0`` — not the fake perfect 1.0 that
        ``1 - 0/max(0, 1)`` would produce in fit history.  Under the
        DiskStore the page-tier meters ride along (``page_hit_rate``,
        ``disk_bytes_read``/``disk_bytes_written``, ``pages_evicted``) —
        the third level of the hierarchy."""
        if not counters:
            return {}
        out: Dict[str, float] = {}
        if "lookups" in counters:
            lookups = counters["lookups"]
            hit_rate = (
                0.0 if lookups <= 0.0 else 1.0 - counters["fetched"] / lookups
            )
            out.update({
                "cache_hit_rate": hit_rate,
                "evictions": int(counters["evictions"]),
                "cache_bytes_h2d": counters["bytes_h2d"],
                "cache_bytes_d2h": counters["bytes_d2h"],
            })
        if "page_hits" in counters:
            touches = counters["page_hits"] + counters["page_misses"]
            out.update({
                "page_hit_rate": (
                    0.0 if touches <= 0.0 else counters["page_hits"] / touches
                ),
                "pages_evicted": int(counters["pages_evicted"]),
                "disk_bytes_read": counters["disk_bytes_read"],
                "disk_bytes_written": counters["disk_bytes_written"],
            })
        return out

    def cache_stats(self, states) -> Dict[str, float]:
        """Whole-run cache stats ({} for stateless placements)."""
        return self.derive_cache_stats(self.cache_counters(states))

    @staticmethod
    def overflow(working_sets: Dict[str, WorkingSet]) -> jnp.ndarray:
        """Total dropped (unserved) requests this batch — the PS overload
        counter production monitoring watches."""
        return sum(ws.n_dropped for ws in working_sets.values())

    # -------------------------------------------------------------- lookups
    @staticmethod
    def bag_from_working(
        working: jnp.ndarray,      # (capacity, dim) pulled rows
        inverse: jnp.ndarray,      # (nnz,) id slot -> working row
        segment_ids: jnp.ndarray,  # (nnz,) id slot -> bag
        num_bags: int,
        weights: Optional[jnp.ndarray] = None,
        combiner: str = "sum",
        fused: bool = False,
    ) -> jnp.ndarray:
        """Bag lookup routed through the pulled working set (differentiable in
        ``working`` — its gradient is exactly the row_grads to scatter back).

        ``fused=True`` runs the gather+bag as ONE Pallas kernel pass over the
        VMEM-resident working set (``kernels.ops.embedding_bag_working``);
        both branches share the same reference expression, so the fused path
        is bit-identical — forward and gradient — to the unfused one.
        """
        from repro.kernels import ops, ref

        if fused:
            return ops.embedding_bag_working(
                working, inverse, segment_ids, weights, num_bags, combiner
            )
        return ref.embedding_bag_combiner_ref(
            working, inverse, segment_ids, weights, num_bags, combiner
        )

    def memory_bytes(self) -> int:
        return sum(
            s.rows * s.dim * jnp.dtype(s.dtype).itemsize for s in self.specs.values()
        )
