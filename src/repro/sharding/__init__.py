from repro.sharding.specs import (  # noqa: F401
    auto_param_specs,
    batch_specs,
    named_shardings,
    pod_prepend,
    table_specs_sharding,
)
