"""PartitionSpec rules for every model family.

Strategy (defaults; §Perf iterations override per cell):
- Dense params: ZeRO-3-style — each weight's two largest dims sharded over
  ('data', 'model'); optimizer moments inherit the same spec; the leading
  'pod' axis is prepended for podded (k-step replicated) trees.
- Embedding tables: rows sharded over ALL mesh axes flattened (512-way) —
  the terabyte table is the thing that must never replicate.
- Batches: leading batch/token dim over ('pod', 'data').
- Small leaves (norms, biases, scalars): replicated.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any

# Leaves smaller than this stay replicated (norm scales, biases, eps, ...).
_MIN_SHARD_ELEMS = 1 << 16


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def auto_leaf_spec(
    shape, mesh: Mesh, *, skip_leading: int = 0, axes=("data", "model")
) -> P:
    """Shard the two largest eligible dims over ``axes`` (largest gets the
    first axis); dims must be divisible by the axis size to qualify."""
    n = len(shape)
    if int(np.prod(shape)) < _MIN_SHARD_ELEMS:
        return P(*([None] * n))
    entries: list = [None] * n
    dims = sorted(
        range(skip_leading, n), key=lambda d: -shape[d]
    )
    remaining = [a for a in axes if a in mesh.axis_names]
    for d in dims:
        if not remaining:
            break
        a = remaining[0]
        if shape[d] % _axis_size(mesh, a) == 0 and shape[d] >= _axis_size(mesh, a):
            entries[d] = a
            remaining.pop(0)
    return P(*entries)


def pod_prepend(spec: P) -> P:
    return P("pod", *spec)


def auto_param_specs(
    params: Pytree, mesh: Mesh, podded: bool = False
) -> Pytree:
    """Spec tree matching ``params``.  ``podded=True`` treats the leading dim
    of every leaf as the pod-replica dim."""

    def leaf(x):
        shape = x.shape
        if podded:
            inner = auto_leaf_spec(shape[1:], mesh)
            if "pod" in mesh.axis_names:
                return P("pod", *inner)
            return P(None, *inner)
        return auto_leaf_spec(shape, mesh)

    return jax.tree.map(leaf, params)


def table_specs_sharding(tables: Pytree, mesh: Mesh) -> Pytree:
    """Row-shard every embedding table over all mesh axes (flattened)."""
    all_axes = tuple(mesh.axis_names)

    def leaf(x):
        rows = x.shape[0]
        total = int(np.prod([mesh.shape[a] for a in all_axes]))
        if rows % total == 0:
            return P(all_axes, *([None] * (x.ndim - 1)))
        # fall back to the largest prefix of axes that divides rows
        for k in range(len(all_axes), 0, -1):
            sub = all_axes[-k:]
            if rows % int(np.prod([mesh.shape[a] for a in sub])) == 0:
                return P(sub, *([None] * (x.ndim - 1)))
        return P(*([None] * x.ndim))

    return jax.tree.map(leaf, tables)


def batch_specs(batch: Pytree, mesh: Mesh, batch_axes=("pod", "data")) -> Pytree:
    """Shard the leading dim of every batch leaf over the data axes."""
    axes = tuple(a for a in batch_axes if a in mesh.axis_names)

    def leaf(x):
        if x.ndim == 0:
            return P()
        size = int(np.prod([mesh.shape[a] for a in axes]))
        if x.shape[0] % size == 0 and x.shape[0] >= size:
            return P(axes, *([None] * (x.ndim - 1)))
        return P(*([None] * x.ndim))

    return jax.tree.map(leaf, batch)


def named_shardings(spec_tree: Pytree, mesh: Mesh) -> Pytree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )


# ------------------------------------------------------------- LM overrides
def lm_param_specs(
    params: Pytree, mesh: Mesh, podded: bool = False, serve: bool = False,
    style: str = "tp_fsdp",
) -> Pytree:
    """Transformer param specs.

    style='tp_fsdp' (paper-faithful baseline): Megatron TP over 'model' +
    ZeRO-3 FSDP over 'data' — column-parallel wq/wk/wv/w_gate/w_up (out-dim
    'model', in-dim 'data'), row-parallel wo/w_down, embedding dim over all
    axes, vocab-parallel head, replicated norms/biases.

    style='fsdp_seq' (beyond-paper, §Perf): pure ZeRO-3 over the flattened
    axes + sequence-sharded activations — see _lm_fsdp_seq_specs.

    serve=True keeps weights TP-resident (no FSDP gathers at decode).
    """
    if style == "fsdp_seq":
        return _lm_fsdp_seq_specs(params, mesh, podded)
    has = lambda a: a in mesh.axis_names
    # Serving keeps weights fully resident (pure TP): no per-step FSDP
    # all-gathers on the latency-critical decode path, and no optimizer
    # state to amortize them against.
    data = None if serve else ("data" if has("data") else None)
    model = "model" if has("model") else None
    all_axes = tuple(a for a in ((() if serve else ("data",)) + ("model",)) if has(a))

    def spec_for(path: str, ndim: int) -> P:
        col = {"wq": 1, "wk": 1, "wv": 1, "w_gate": 1, "w_up": 1,
               "ws_gate": 1, "ws_up": 1}
        row = {"wo": 1, "w_down": 1, "ws_down": 1}
        # layer leaves carry a leading L dim (scan-stacked)
        if "we_gate" in path or "we_up" in path:       # (L, E, D, F)
            return P(None, None, data, model)
        if "we_down" in path:                          # (L, E, F, D)
            return P(None, None, model, data)
        if "router" in path:                           # (L, D, E)
            return P(None, data, None)
        for k in col:
            if path.endswith(k):                       # (L, D, X)
                return P(None, data, model)
        for k in row:
            if path.endswith(k):                       # (L, X, D)
                return P(None, model, data)
        if path.endswith("embed"):                     # (V, D)
            return P(None, all_axes if all_axes else None)
        if path.endswith("head"):                      # (D, V)
            return P(data, model)
        if path.endswith(("bq", "bk", "bv")):          # (L, X)
            return P(None, model)
        return P(*([None] * ndim))                     # norms etc.

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        s = spec_for(name, leaf.ndim)
        if podded:
            s = P("pod" if has("pod") else None, *s)
        out.append(s)
    return jax.tree_util.tree_unflatten(treedef, out)


def _lm_fsdp_seq_specs(params: Pytree, mesh: Mesh, podded: bool) -> Pytree:
    """Beyond-paper LM training layout (§Perf iteration 1): NO tensor
    parallelism — every weight is ZeRO-3-sharded over the flattened
    ('data','model') axes on its d_model-ish dim and all-gathered at use;
    activations shard batch over 'data' and SEQUENCE over 'model'.

    Why: Megatron-style TP moves ~2 full activations per layer per pass over
    the 'model' axis (psum/AG of (tokens, d_model)); at 65k tokens/device
    that is TBs per step.  FSDP moves only ~3x the weight bytes per step
    (all-gather fwd, re-gather in remat bwd, reduce-scatter grads) plus a
    small per-layer KV gather for the seq-sharded attention — ~17x less.
    """
    has = lambda a: a in mesh.axis_names
    big = tuple(a for a in ("data", "model") if has(a))
    big = big if big else None

    def spec_for(path: str, ndim: int) -> P:
        if "we_gate" in path or "we_up" in path:       # (L, E, D, F)
            return P(None, None, big, None)
        if "we_down" in path:                          # (L, E, F, D)
            return P(None, None, big, None)
        if "router" in path:                           # (L, D, E)
            return P(None, big, None)
        for k in ("wq", "wk", "wv", "w_gate", "w_up", "ws_gate", "ws_up"):
            if path.endswith(k):                       # (L, D, X)
                return P(None, big, None)
        for k in ("wo", "w_down", "ws_down"):
            if path.endswith(k):                       # (L, X, D)
                return P(None, big, None)
        if path.endswith("embed"):                     # (V, D)
            return P(None, big)
        if path.endswith("head"):                      # (D, V)
            # vocab-parallel: a d_model-sharded head would force a
            # (tokens, V)-sized psum per CE chunk
            return P(None, "model" if has("model") else None)
        return P(*([None] * ndim))

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        s = spec_for(name, leaf.ndim)
        if podded:
            s = P("pod" if has("pod") else None, *s)
        out.append(s)
    return jax.tree_util.tree_unflatten(treedef, out)
