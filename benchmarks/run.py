"""Benchmark harness: one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig9] [--quick]

Prints ``name,us_per_call,derived`` CSV rows.  The roofline table
(§Roofline) is appended when dry-run artifacts exist in experiments/dryrun.
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--quick", action="store_true",
                    help="shorter training runs (CI-speed)")
    args = ap.parse_args()

    from benchmarks import (  # noqa: E402
        fig5_pipeline,
        fig5_prefetch,
        fig6_twophase,
        fig9_kstep_auc,
        fig10_comm_ratio,
        fig_cache_hier,
        fig_serve_qps,
        roofline,
        table1_hashing,
    )

    steps = 60 if args.quick else 120
    benches = {
        "table1": lambda: table1_hashing.run(steps=steps),
        "fig5": lambda: fig5_pipeline.run(),
        "fig5_prefetch": lambda: fig5_prefetch.run(steps=steps // 2),
        "fig6": lambda: fig6_twophase.run(),
        "fig9": lambda: fig9_kstep_auc.run(steps=steps),
        "fig10": lambda: fig10_comm_ratio.run(),
        "fig_cache": lambda: fig_cache_hier.run(steps=steps),
        # co-located serving tier: QPS + p50/p99 vs dynamic-batch size,
        # cold device cache vs trainer-warmed (runtime/serve_ctr.py)
        "serve_qps": lambda: fig_serve_qps.run(
            steps=steps // 3, n_requests=256 if args.quick else 1024),
        # sparse hot-path fused-vs-unfused referee; also writes
        # BENCH_roofline.json (the perf baseline later PRs diff against)
        "roofline_measure": lambda: roofline.measure_rows(quick=args.quick),
    }

    print("name,us_per_call,derived")
    failed = []
    for name, fn in benches.items():
        if args.only and args.only not in name:
            continue
        try:
            for row in fn():
                n, us, derived = row
                print(f"{n},{us:.1f},{derived}")
                sys.stdout.flush()
        except Exception:
            traceback.print_exc()
            failed.append(name)

    # §Roofline table from dry-run artifacts (if present)
    try:
        import os
        from benchmarks import roofline
        if os.path.isdir("experiments/dryrun"):
            for mesh in ("single", "multi"):
                print(f"# roofline ({mesh}-pod)")
                roofline.print_table(mesh=mesh)
    except Exception:
        traceback.print_exc()
        failed.append("roofline")

    if failed:
        print(f"# FAILED: {failed}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
