import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Subprocess helper: compile merge schedules / train steps on the
production meshes and print collective byte accounting as JSON.
(Separate process because jax locks the device count at first init —
benchmarks.run itself stays on the single real CPU device.)
"""

import argparse  # noqa: E402
import json      # noqa: E402

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import merge as merge_lib              # noqa: E402
from repro.launch.hlo_analysis import analyze_hlo       # noqa: E402
from repro.launch.mesh import make_production_mesh      # noqa: E402


def merge_bytes(schedule: str, payload_mb: float, n_pod: int = 2):
    """DCN/ICI bytes of one merge of a dense tower of the given size."""
    mesh = make_production_mesh(multi_pod=True)
    n = int(payload_mb * 1e6 / 4)
    x = jax.ShapeDtypeStruct((n_pod, n), jnp.float32)
    sh = NamedSharding(mesh, P("pod", None))

    if schedule == "flat":
        fn = lambda v: merge_lib.flat_mean({"w": v})
    elif schedule == "two_phase":
        fn = lambda v: merge_lib.two_phase_mean({"w": v}, mesh)
    elif schedule == "bf16":
        fn = lambda v: merge_lib.two_phase_mean({"w": v}, mesh, payload_dtype=jnp.bfloat16)
    elif schedule == "int8_ef":
        fn = lambda v: merge_lib.int8_ef_mean(
            {"w": v}, {"w": jnp.zeros((n_pod, n), jnp.float32)}, mesh)[0]
    else:
        raise ValueError(schedule)
    compiled = jax.jit(fn, in_shardings=(sh,)).lower(x).compile()
    res = analyze_hlo(compiled.as_text(), devices_per_pod=256)
    c = res["collectives"]
    return {"schedule": schedule, "payload_mb": payload_mb,
            "dcn_bytes_per_device": c.dcn_bytes,
            "ici_bytes_per_device": c.ici_bytes,
            "total_bytes_per_device": c.total_bytes}


def sparse_bytes(placement: str, rows: int = 1 << 18, dim: int = 64,
                 capacity: int = 1 << 13):
    """Per-step collective bytes of one working-set pull+push on the
    production multi-pod mesh: ``routed`` (explicit all_to_all request
    routing, ``repro.core.routed_embedding``) vs ``gather`` (GSPMD
    partitions the gather/scatter over the row-sharded table into masked
    partials + value-blind all-reduce).

    Both probes take the already-deduplicated uid stream as input — dedup
    cost is placement-independent, so the accounting isolates the wire the
    --placement flag actually changes."""
    from repro.core import routed_embedding as routed
    from repro.core.sparse_optim import SparseAdagrad, SparseAdagradConfig

    mesh = make_production_mesh(multi_pod=True)
    axes = ("pod", "data", "model")
    n_shards = 2 * 16 * 16
    opt = SparseAdagrad(SparseAdagradConfig(lr=0.1))
    table_sh = NamedSharding(mesh, P(axes, None))
    if placement == "routed":
        cap_local = capacity // n_shards
        pull_fn, push_fn = routed.make_routed_pull_push(
            mesh, rows // n_shards, dim, cap_local, cap_local,
            shard_axes=axes,
        )
        ids_sh = NamedSharding(mesh, P(axes))   # each shard owns its uids

        def step(table, accum, uids):
            pulled, _, _ = pull_fn(table, uids)
            # row update derived from the pulled rows: nothing constant-folds
            new_table, new_accum, _ = push_fn(
                table, accum, uids, pulled * 0.01, opt.cfg.lr, opt.cfg.eps
            )
            return new_table, new_accum

    elif placement == "gather":
        ids_sh = NamedSharding(mesh, P())       # global replicated requests

        def step(table, accum, uids):
            pulled = jnp.take(table, uids, axis=0)
            return opt.apply_rows(table, accum, uids, pulled * 0.01)

    else:
        raise ValueError(placement)

    shapes = (
        jax.ShapeDtypeStruct((rows, dim), jnp.float32),
        jax.ShapeDtypeStruct((rows, dim), jnp.float32),
        jax.ShapeDtypeStruct((capacity,), jnp.int32),
    )
    compiled = (
        jax.jit(step, in_shardings=(table_sh, table_sh, ids_sh))
        .lower(*shapes)
        .compile()
    )
    res = analyze_hlo(compiled.as_text(), devices_per_pod=256)
    c = res["collectives"]
    return {"placement": placement, "rows": rows, "dim": dim,
            "capacity": capacity,
            "dcn_bytes_per_device": c.dcn_bytes,
            "ici_bytes_per_device": c.ici_bytes,
            "total_bytes_per_device": c.total_bytes}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--probe", required=True, choices=["merge", "sparse"])
    ap.add_argument("--schedule", default="flat")
    ap.add_argument("--placement", default="routed")
    ap.add_argument("--payload-mb", type=float, default=64.0)
    args = ap.parse_args()
    if args.probe == "merge":
        print(json.dumps(merge_bytes(args.schedule, args.payload_mb)))
    elif args.probe == "sparse":
        print(json.dumps(sparse_bytes(args.placement)))


if __name__ == "__main__":
    main()
