"""Roofline derivation from dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell, from ``experiments/dryrun``:

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s        (197e12 bf16)
    memory term     = HLO_bytes_per_device / HBM_bw             (819e9 B/s)
    collective term = ICI bytes / ICI_bw + DCN bytes / DCN_bw   (50e9 / 2.5e9)

FLOPs and bytes come from ``compiled.cost_analysis()`` of the partitioned
(per-device) module; collective bytes from the HLO wire model in
launch/hlo_analysis.py.  Train cells combine their two executables as
``local*(k-1)/k + merge/k`` (the k-step amortization).

Caveats (documented in EXPERIMENTS.md): the CPU backend promotes bf16 dots
to f32, so 'bytes accessed' is an upper bound (~2x) for bf16-dominated
models; DCN bandwidth is an assumption (the spec sheet gives ICI only).
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DCN_BW = 2.5e9   # assumed per-chip inter-pod bandwidth


def model_flops_note(rec: Dict) -> float:
    steps = rec.get("steps", {})
    for s in steps.values():
        return s.get("model_flops", 0.0)
    return 0.0


def cell_terms(rec: Dict) -> Optional[Dict]:
    steps = rec.get("steps", {})
    if not steps:
        return None
    n_dev = rec.get("n_devices", 256)
    agg = {"compute_s": 0.0, "memory_s": 0.0, "ici_s": 0.0, "dcn_s": 0.0,
           "flops_dev": 0.0, "bytes_dev": 0.0, "coll_ici": 0.0, "coll_dcn": 0.0,
           "model_flops": 0.0}
    for s in steps.values():
        w = s.get("weight", 1.0)
        # loop-aware analyzer numbers (fall back to XLA cost_analysis)
        hlo = s.get("hlo", {})
        flops = hlo.get("flops") or s.get("cost", {}).get("flops", 0.0) or 0.0
        bytes_acc = (hlo.get("bytes_accessed")
                     or s.get("cost", {}).get("bytes accessed", 0.0) or 0.0)
        ici = s.get("collectives", {}).get("ici_bytes_per_device", 0)
        dcn = s.get("collectives", {}).get("dcn_bytes_per_device", 0)
        agg["flops_dev"] += w * flops
        agg["bytes_dev"] += w * bytes_acc
        agg["coll_ici"] += w * ici
        agg["coll_dcn"] += w * dcn
        agg["model_flops"] += w * s.get("model_flops", 0.0)
    agg["compute_s"] = agg["flops_dev"] / PEAK_FLOPS
    agg["memory_s"] = agg["bytes_dev"] / HBM_BW
    agg["ici_s"] = agg["coll_ici"] / ICI_BW
    agg["dcn_s"] = agg["coll_dcn"] / DCN_BW
    agg["collective_s"] = agg["ici_s"] + agg["dcn_s"]
    terms = {"compute": agg["compute_s"], "memory": agg["memory_s"],
             "collective": agg["collective_s"]}
    agg["dominant"] = max(terms, key=terms.get)
    bound = max(terms.values())
    agg["bound_s"] = bound
    # useful fraction: model FLOPs per device vs what the bottleneck allows
    agg["useful_flops_dev"] = agg["model_flops"] / n_dev
    agg["flops_ratio"] = (
        agg["useful_flops_dev"] / agg["flops_dev"] if agg["flops_dev"] else 0.0
    )
    agg["roofline_fraction"] = (
        (agg["useful_flops_dev"] / PEAK_FLOPS) / bound if bound > 0 else 0.0
    )
    return agg


def load_records(base: str = "experiments/dryrun") -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(base, "*", "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        rec["_path"] = path
        out.append(rec)
    return out


def table(base: str = "experiments/dryrun", mesh: Optional[str] = None) -> List[Dict]:
    rows = []
    for rec in load_records(base):
        if mesh and rec.get("mesh") != mesh:
            continue
        t = cell_terms(rec)
        row = {"arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
               "kind": rec.get("kind"), "skip": rec.get("skip")}
        if t:
            row.update(t)
        rows.append(row)
    return rows


def print_table(base: str = "experiments/dryrun", mesh: str = "single"):
    hdr = ("arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
           "model/HLO_flops,roofline_fraction")
    print(hdr)
    for r in sorted(table(base, mesh), key=lambda r: (r["arch"], r["shape"])):
        if r.get("skip"):
            print(f"{r['arch']},{r['shape']},{r['mesh']},SKIP({r['skip'][:40]})")
            continue
        if "compute_s" not in r:
            continue
        print(f"{r['arch']},{r['shape']},{r['mesh']},"
              f"{r['compute_s']:.3e},{r['memory_s']:.3e},{r['collective_s']:.3e},"
              f"{r['dominant']},{r['flops_ratio']:.3f},{r['roofline_fraction']:.4f}")


if __name__ == "__main__":
    import sys
    print_table(mesh=sys.argv[1] if len(sys.argv) > 1 else "single")
