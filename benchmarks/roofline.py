"""Roofline derivation from dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell, from ``experiments/dryrun``:

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s        (197e12 bf16)
    memory term     = HLO_bytes_per_device / HBM_bw             (819e9 B/s)
    collective term = ICI bytes / ICI_bw + DCN bytes / DCN_bw   (50e9 / 2.5e9)

FLOPs and bytes come from ``compiled.cost_analysis()`` of the partitioned
(per-device) module; collective bytes from the HLO wire model in
launch/hlo_analysis.py.  Train cells combine their two executables as
``local*(k-1)/k + merge/k`` (the k-step amortization).

Caveats (documented in EXPERIMENTS.md): the CPU backend promotes bf16 dots
to f32, so 'bytes accessed' is an upper bound (~2x) for bf16-dominated
models; DCN bandwidth is an assumption (the spec sheet gives ICI only).

``--measure`` adds the MEASURED referee for the fused sparse kernels: a
sparse hot-path micro-benchmark (pull -> bag fwd/bwd -> push, the exact
backend/engine code the trainer runs) per placement x {fused, unfused},
reporting steps/sec, ``cost_analysis`` bytes-accessed/FLOPs, and HLO op
counts of the compiled step, emitted to ``BENCH_roofline.json`` so every
later PR diffs fusion wins (and regressions) as numbers.  Each cell also
records ``kernel_mode`` — on this CPU container fused ops execute through
the jnp reference (or interpret under REPRO_KERNEL_INTERPRET=1), so the
*measured* fused-vs-unfused delta is only meaningful on a real TPU; the
``model_bytes`` field carries the analytic per-step HBM-traffic model
(intermediates each path materializes), which is backend-independent.
"""

from __future__ import annotations

import glob
import json
import os
import re
import time
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DCN_BW = 2.5e9   # assumed per-chip inter-pod bandwidth


def model_flops_note(rec: Dict) -> float:
    steps = rec.get("steps", {})
    for s in steps.values():
        return s.get("model_flops", 0.0)
    return 0.0


def cell_terms(rec: Dict) -> Optional[Dict]:
    steps = rec.get("steps", {})
    if not steps:
        return None
    n_dev = rec.get("n_devices", 256)
    agg = {"compute_s": 0.0, "memory_s": 0.0, "ici_s": 0.0, "dcn_s": 0.0,
           "flops_dev": 0.0, "bytes_dev": 0.0, "coll_ici": 0.0, "coll_dcn": 0.0,
           "model_flops": 0.0}
    for s in steps.values():
        w = s.get("weight", 1.0)
        # loop-aware analyzer numbers (fall back to XLA cost_analysis)
        hlo = s.get("hlo", {})
        flops = hlo.get("flops") or s.get("cost", {}).get("flops", 0.0) or 0.0
        bytes_acc = (hlo.get("bytes_accessed")
                     or s.get("cost", {}).get("bytes accessed", 0.0) or 0.0)
        ici = s.get("collectives", {}).get("ici_bytes_per_device", 0)
        dcn = s.get("collectives", {}).get("dcn_bytes_per_device", 0)
        agg["flops_dev"] += w * flops
        agg["bytes_dev"] += w * bytes_acc
        agg["coll_ici"] += w * ici
        agg["coll_dcn"] += w * dcn
        agg["model_flops"] += w * s.get("model_flops", 0.0)
    agg["compute_s"] = agg["flops_dev"] / PEAK_FLOPS
    agg["memory_s"] = agg["bytes_dev"] / HBM_BW
    agg["ici_s"] = agg["coll_ici"] / ICI_BW
    agg["dcn_s"] = agg["coll_dcn"] / DCN_BW
    agg["collective_s"] = agg["ici_s"] + agg["dcn_s"]
    terms = {"compute": agg["compute_s"], "memory": agg["memory_s"],
             "collective": agg["collective_s"]}
    agg["dominant"] = max(terms, key=terms.get)
    bound = max(terms.values())
    agg["bound_s"] = bound
    # useful fraction: model FLOPs per device vs what the bottleneck allows
    agg["useful_flops_dev"] = agg["model_flops"] / n_dev
    agg["flops_ratio"] = (
        agg["useful_flops_dev"] / agg["flops_dev"] if agg["flops_dev"] else 0.0
    )
    agg["roofline_fraction"] = (
        (agg["useful_flops_dev"] / PEAK_FLOPS) / bound if bound > 0 else 0.0
    )
    return agg


def load_records(base: str = "experiments/dryrun") -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(base, "*", "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        rec["_path"] = path
        out.append(rec)
    return out


def table(base: str = "experiments/dryrun", mesh: Optional[str] = None) -> List[Dict]:
    rows = []
    for rec in load_records(base):
        if mesh and rec.get("mesh") != mesh:
            continue
        t = cell_terms(rec)
        row = {"arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
               "kind": rec.get("kind"), "skip": rec.get("skip")}
        if t:
            row.update(t)
        rows.append(row)
    return rows


def print_table(base: str = "experiments/dryrun", mesh: str = "single"):
    hdr = ("arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
           "model/HLO_flops,roofline_fraction")
    print(hdr)
    for r in sorted(table(base, mesh), key=lambda r: (r["arch"], r["shape"])):
        if r.get("skip"):
            print(f"{r['arch']},{r['shape']},{r['mesh']},SKIP({r['skip'][:40]})")
            continue
        if "compute_s" not in r:
            continue
        print(f"{r['arch']},{r['shape']},{r['mesh']},"
              f"{r['compute_s']:.3e},{r['memory_s']:.3e},{r['collective_s']:.3e},"
              f"{r['dominant']},{r['flops_ratio']:.3f},{r['roofline_fraction']:.4f}")


# ------------------------------------------------------------ measured mode
# Sparse hot-path micro-benchmark geometry (smoke-scale but with a working
# set large enough that the pull/bag/push streams dominate the step).
MEASURE_GEOM = dict(rows=4096, dim=64, capacity=512, nnz=4096, bags=512)


def sparse_model_bytes(placement: str, fused: bool, *, capacity: int,
                       nnz: int, bags: int, dim: int, itemsize: int = 4,
                       accum_itemsize: int = 4) -> Dict[str, float]:
    """Analytic per-step HBM traffic of the sparse hot path (bytes).

    Counts the (rows x dim) streams each implementation moves through HBM —
    what the fusion actually changes — and ignores O(capacity)/O(nnz) index
    vectors.  Unfused materializes the gathered-embedding intermediate in
    the bag, the non-aliased updated-rows arrays in the push, and (cached)
    the slot-translated gather's extra pass; fused reads/writes each stream
    once, in place.  Backend-independent (unlike the measured cells).
    """
    row = dim * itemsize
    arow = dim * accum_itemsize
    # pull: table rows -> working set (read + write), once per step
    pull = capacity * row * 2
    if placement == "cached" and not fused:
        pull += capacity * row * 2       # slot-translate-then-gather pass
    # bag fwd: read the working-set stream, write the bags
    bag = nnz * row + bags * row
    if not fused:
        bag += nnz * row * 2             # gathered-embedding intermediate
    # push: delta/g2 streams + table/accum rows in, updated rows out.
    # Routed never fuses the push (the AdaGrad update runs shard-locally
    # inside the reverse route), so it keeps the unfused cost either way.
    push = capacity * (row + arow) * 2 + capacity * (row + arow)
    if not fused or placement == "routed":
        push += capacity * (row + arow)  # non-aliased updated-rows arrays
    return {"pull": float(pull), "bag": float(bag), "push": float(push),
            "total": float(pull + bag + push)}


def _hlo_op_count(compiled_text: str) -> int:
    """Instructions in the optimized HLO module (assignment lines)."""
    return len(re.findall(r"^\s+[%\w.\-]+ = ", compiled_text, re.M))


def _cost_analysis(compiled) -> Dict[str, float]:
    try:
        cost = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(cost, (list, tuple)):     # older jax returns [dict]
        cost = cost[0] if cost else {}
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
    }


def measure_cell(placement: str, fused: bool, steps: int = 30,
                 geom: Optional[Dict] = None) -> Dict:
    """One placement x fused cell: compile + time the sparse hot path."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.embedding_backend import make_backend
    from repro.core.embedding_engine import EmbeddingEngine
    from repro.core.sparse_optim import SparseAdagrad
    from repro.kernels import ops

    g = dict(MEASURE_GEOM, **(geom or {}))
    rows, dim = g["rows"], g["dim"]
    capacity, nnz, bags = g["capacity"], g["nnz"], g["bags"]

    kwargs = {"cache_rows": capacity} if placement == "cached" else {}
    backend = make_backend(placement, fused=fused, **kwargs)
    opt = SparseAdagrad()

    rng = np.random.default_rng(0)
    # Zipf-skewed ids: the hot-head distribution the cache tier serves
    ids = jnp.asarray(
        np.minimum(rng.zipf(1.3, size=nnz) - 1, rows - 1), jnp.int32)
    seg = jnp.asarray(np.arange(nnz) % bags, jnp.int32)
    w = jnp.ones((nnz,), jnp.float32)
    table = jnp.asarray(rng.standard_normal((rows, dim)), jnp.float32)
    accum = jnp.full((rows, dim), 0.1, jnp.float32)
    state = backend.init_state(table)

    def step(table, accum, state, ids):
        ws, table, accum, state = backend.pull(
            table, accum, state, ids, capacity)

        def loss(working):
            out = EmbeddingEngine.bag_from_working(
                working, ws.inverse, seg, bags, weights=w,
                combiner="sum", fused=fused)
            return jnp.sum(out * out)

        row_grads = jax.grad(loss)(ws.rows)
        table, accum, state = backend.push(
            table, accum, state, ws, row_grads, opt)
        return table, accum, state

    fn = jax.jit(step, donate_argnums=(0, 1, 2))
    compiled = fn.lower(table, accum, state, ids).compile()
    cell = {
        "placement": placement, "fused": fused,
        "kernel_mode": ops.kernel_mode() if fused else "xla",
        "hlo_ops": _hlo_op_count(compiled.as_text()),
        "model_bytes": sparse_model_bytes(
            placement, fused, capacity=capacity, nnz=nnz, bags=bags, dim=dim),
        **_cost_analysis(compiled),
    }
    # warm-up (also re-materializes donated buffers for the timed loop)
    table, accum, state = fn(table, accum, state, ids)
    jax.block_until_ready(table)
    t0 = time.perf_counter()
    for _ in range(steps):
        table, accum, state = fn(table, accum, state, ids)
    jax.block_until_ready(table)
    dt = time.perf_counter() - t0
    cell["steps_per_sec"] = steps / dt
    cell["us_per_step"] = dt / steps * 1e6
    return cell


def measure(steps: int = 30, geom: Optional[Dict] = None,
            placements=("gather", "routed", "cached")) -> Dict:
    """The full measured grid + analytic model, ready for BENCH_roofline.json."""
    import jax

    cells = [
        measure_cell(p, f, steps=steps, geom=geom)
        for p in placements for f in (False, True)
    ]
    return {
        "bench": "roofline_sparse_hot_path",
        "geom": dict(MEASURE_GEOM, **(geom or {})),
        "backend": jax.default_backend(),
        "steps_timed": steps,
        "cells": cells,
    }


def write_measure(out: str = "BENCH_roofline.json", steps: int = 30,
                  geom: Optional[Dict] = None) -> Dict:
    rec = measure(steps=steps, geom=geom)
    with open(out, "w") as f:
        json.dump(rec, f, indent=2)
    return rec


def measure_rows(quick: bool = False, out: str = "BENCH_roofline.json"):
    """benchmarks/run.py registry adapter: (name, us_per_call, derived) rows."""
    rec = write_measure(out, steps=10 if quick else 30)
    for c in rec["cells"]:
        name = f"roofline/{c['placement']}/{'fused' if c['fused'] else 'unfused'}"
        derived = (f"steps_s={c['steps_per_sec']:.2f} "
                   f"hlo_ops={c['hlo_ops']} "
                   f"model_MB={c['model_bytes']['total'] / 1e6:.3f} "
                   f"mode={c['kernel_mode']}")
        yield name, c["us_per_step"], derived


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("mesh", nargs="?", default="single",
                    help="dry-run mesh for the analytic table")
    ap.add_argument("--measure", action="store_true",
                    help="run the sparse hot-path micro-benchmark per "
                         "placement x {fused, unfused} and emit --out")
    ap.add_argument("--quick", action="store_true",
                    help="fewer timed steps (CI-speed)")
    ap.add_argument("--out", default="BENCH_roofline.json")
    args = ap.parse_args()
    if args.measure:
        import sys
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
        print("name,us_per_step,derived")
        for name, us, derived in measure_rows(quick=args.quick, out=args.out):
            print(f"{name},{us:.1f},{derived}")
        print(f"# wrote {args.out}")
    else:
        print_table(mesh=args.mesh)
