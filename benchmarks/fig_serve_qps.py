"""Co-located CTR serving: sustained QPS + latency percentiles vs batch size,
cold cache vs trainer-warmed cache.

The paper's deployment serves the ads model from the same hierarchical
parameter server that trains it; the repro analogue is ``CTRServer``
(``runtime.serve_ctr``) scoring request streams through the engine's
read-only lookup against a live ``HybridTrainer``.  This benchmark measures
the serving tier's envelope on the cached placement:

  - dynamic-batch size sweep: bigger batches amortize the per-call lookup
    and dense tower, raising QPS and p50 (classic throughput/latency
    trade);
  - cold vs warmed: a fresh trainer's device cache is empty, so every
    lookup falls through to the host table; after a training run on the
    same Zipf-skewed id distribution the LFU cache holds the hot head and
    ``serve_hit_rate`` jumps — the co-location payoff (the trainer warms
    the serving cache for free).

Requests arrive in bursts of several batches before each drain, so the p99
includes real queueing delay, not just per-call compute.
"""

from __future__ import annotations


def run(steps: int = 40, batch_sizes=(16, 64, 256), n_requests: int = 1024,
        burst_batches: int = 4):
    from repro import configs
    from repro.data import synthetic as S
    from repro.runtime.factory import build_ctr_server, build_trainer
    from repro.runtime.trainer import TrainerConfig

    spec = configs.get("baidu-ctr")
    results = []
    for warmed in (False, True):
        for mb in batch_sizes:
            tcfg = TrainerConfig(placement="cached", n_pod=1)
            tr = build_trainer("baidu-ctr", tcfg, smoke=True)
            if warmed:
                gen = S.recsys_batches(spec.smoke_cfg, batch=512, seed=1)
                for _ in range(steps):
                    tr.train_step(next(gen))
            req_gen = S.recsys_batches(spec.smoke_cfg, batch=mb, seed=5)
            # compile + cache-touch warmup on a throwaway server, then
            # measure sustained traffic on a fresh one (same trainer, so
            # the compiled predict executable is reused)
            warm_srv = build_ctr_server(tr, max_batch=mb)
            warm_srv.submit_batch(next(req_gen))
            warm_srv.drain()
            m0 = tr.serve_metrics()
            srv = build_ctr_server(tr, max_batch=mb)
            n_batches = max(burst_batches, n_requests // mb)
            sent = 0
            while sent < n_batches:
                for _ in range(min(burst_batches, n_batches - sent)):
                    srv.submit_batch(next(req_gen))
                    sent += 1
                srv.drain()
            s = srv.summary()
            m1 = tr.serve_metrics()
            lk = m1["serve_lookups"] - m0["serve_lookups"]
            miss = m1.get("serve_misses", 0.0) - m0.get("serve_misses", 0.0)
            us = s["wall_s"] / s["steps"] * 1e6
            results.append((
                f"serve_qps_{'warm' if warmed else 'cold'}_b{mb:03d}", us,
                f"max_batch={mb},served={int(s['served'])},"
                f"qps={s['qps']:.1f},"
                f"p50_ms={s['p50'] * 1e3:.3f},p99_ms={s['p99'] * 1e3:.3f},"
                f"serve_hit_rate={1.0 - miss / max(lk, 1.0):.4f}",
            ))
    return results
