"""Paper Fig. 5: pipelined input staging (core binding / direct I/O analogue).

Measures wall time of N train-shaped iterations with (a) serialized staging
(read+parse inline with compute) vs (b) the PrefetchPipeline overlapping
staging with compute — the paper's Read-Ins/Pull-Sparse/Train-DNN overlap.
"""

from __future__ import annotations

import time

import numpy as np

from repro.data import synthetic as S
from repro.data.pipeline import PrefetchPipeline


def _stage(batch):
    # emulate parse + shard cost (checksum pass over the batch)
    return {k: v.copy() for k, v in batch.items()}


def _compute(batch, ms: float = 8.0):
    t_end = time.perf_counter() + ms / 1e3
    x = 0.0
    while time.perf_counter() < t_end:
        x += float(np.sum(batch["mask"][:64, :8]))
    return x


def run(steps: int = 30, batch: int = 4096):
    results = []
    # serialized
    gen = S.ctr_batches(seed=0, batch=batch, rows=100000, n_fields=16, nnz=50)
    t0 = time.perf_counter()
    for _ in range(steps):
        b = _stage(next(gen))
        _compute(b)
    serial = time.perf_counter() - t0

    # overlapped
    gen2 = S.ctr_batches(seed=0, batch=batch, rows=100000, n_fields=16, nnz=50)
    pipe = PrefetchPipeline(gen2, depth=2, stage_fn=_stage)
    t0 = time.perf_counter()
    for i, b in enumerate(pipe):
        _compute(b)
        if i == steps - 1:
            break
    overlap = time.perf_counter() - t0
    pipe.close()

    results.append(("fig5_serialized", serial / steps * 1e6, ""))
    results.append(("fig5_overlapped", overlap / steps * 1e6,
                    f"speedup={serial / overlap:.2f}x"))
    return results
