"""Cache hierarchy (paper §2.3): the three-level sweep — device-cache size x
host page-cache size vs per-tier hit rates and traffic.

The paper's hierarchical parameter server keeps terabyte tables on SSD,
a page cache over them in CPU MEM, and only the hot working set on the
accelerator, exploiting the Zipf skew of ad features.  This benchmark
reproduces that story on the synthetic Zipf(1.05) CTR stream with the real
storage stack (``CachedBackend`` over a ``DiskStore`` spill directory):
sweep the device-cache size and the RAM page-cache budget, and meter each
tier in the steady state —

  device tier: lookup hit rate, host->device fetch bytes, spill bytes;
  page tier:   page-cache hit rate, pages evicted;
  SSD tier:    bytes read / written per step.

The §2.3 claim lands as: a ~10% device cache already serves >= 80% of
lookups from device memory, and the disk tier's read traffic collapses once
the page cache covers the hot pages — the two caches filter the Zipf tail
level by level.
"""

from __future__ import annotations

import shutil
import tempfile
import time


def run(steps: int = 60, rows: int = 50_000, dim: int = 16,
        capacity: int = 4096, batch: int = 512, nnz: int = 20,
        zipf_a: float = 1.05, page_rows: int = 512):
    import jax
    import jax.numpy as jnp

    from repro.core.cache_tier import CachedBackend
    from repro.core.embedding_engine import EmbeddingEngine, TableSpec
    from repro.core.row_store import DiskStore
    from repro.core.sparse_optim import SparseAdagrad, SparseAdagradConfig
    from repro.data import synthetic as S

    measure_from = steps * 2 // 3
    n_pages = -(-rows // page_rows)
    results = []
    # device cache >= one batch's working set (the capacity floor, ~8% of
    # this table); page cache from hot-head-only to full mirror (None)
    for cfrac in (0.08, 0.20, 1.00):
        for pfrac in (0.10, 0.50, None):
            C = max(capacity, int(rows * cfrac))
            pages = None if pfrac is None else max(2, int(n_pages * pfrac))
            spill = tempfile.mkdtemp(prefix="fig_cache_hier_")
            store = DiskStore(spill, page_rows=page_rows,
                              page_cache_pages=pages)
            engine = EmbeddingEngine(
                {"t": TableSpec("t", rows=rows, dim=dim, id_field="ids")},
                capacity=capacity,
                optimizer=SparseAdagrad(SparseAdagradConfig(lr=0.1)),
                backend=CachedBackend(cache_rows=C, staged=True,
                                      capacity=capacity),
                store=store,
            )
            tables = engine.init(jax.random.key(0))
            accum = engine.init_state(tables).accum
            states = engine.init_backend_state(tables)
            pull = engine.pull_stage(donate=False)
            push = jax.jit(
                lambda t, a, s, wss, g: engine.push(t, a, s, wss, g))

            gen = S.ctr_batches(seed=7, batch=batch, rows=rows, n_fields=8,
                                nnz=nnz, zipf_a=zipf_a)
            warm = None
            t0 = 0.0
            for i in range(steps):
                ids = {"t": jnp.asarray(next(gen)["ids"].reshape(-1))}
                wss, tables, accum, states = pull(tables, accum, states, ids)
                grads = {"t": wss["t"].rows * 0.01}
                tables, accum, states = push(tables, accum, states, wss, grads)
                if i == measure_from - 1:
                    jax.block_until_ready(states["t"].lookups)
                    st = states["t"]
                    warm = (float(st.lookups), float(st.fetched),
                            float(st.bytes_h2d), float(st.bytes_d2h),
                            dict(store.stats()))
                    t0 = time.perf_counter()
            jax.block_until_ready(states["t"].lookups)
            n_meas = steps - measure_from
            us = (time.perf_counter() - t0) / n_meas * 1e6
            st = states["t"]
            lookups = float(st.lookups) - warm[0]
            fetched = float(st.fetched) - warm[1]
            h2d = (float(st.bytes_h2d) - warm[2]) / n_meas
            d2h = (float(st.bytes_d2h) - warm[3]) / n_meas
            # page/SSD tiers: window deltas of the store meters (sync first
            # so the window's write-behind traffic is attributed to it)
            engine.sync_store(tables, accum, states)
            ds = {k: v - warm[4][k] for k, v in store.stats().items()}
            faults = ds["page_hits"] + ds["page_misses"]
            page_hit = 1.0 - ds["page_misses"] / max(faults, 1.0)
            store.close()
            shutil.rmtree(spill, ignore_errors=True)
            pname = "full" if pages is None else f"{pages:03d}"
            results.append((
                f"fig_cache_c{int(cfrac * 100):03d}_p{pname}", us,
                f"cache_rows={C},page_cache_pages={pages},"
                f"hit_rate={1.0 - fetched / lookups:.4f},"
                f"page_hit_rate={page_hit:.4f},"
                f"h2d_MB_per_step={h2d / 1e6:.4f},"
                f"d2h_MB_per_step={d2h / 1e6:.4f},"
                f"disk_rd_MB_per_step={ds['disk_bytes_read'] / n_meas / 1e6:.4f},"
                f"disk_wr_MB_per_step={ds['disk_bytes_written'] / n_meas / 1e6:.4f},"
                f"pages_evicted={int(ds['pages_evicted'])}",
            ))
    return results
