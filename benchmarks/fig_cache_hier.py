"""Cache hierarchy (paper §2.3): device-cache size vs hit rate and traffic.

The paper's hierarchical parameter server keeps terabyte tables in CPU
MEM/SSD and only the hot working set on the accelerator, exploiting the
Zipf skew of ad features.  This benchmark reproduces that story on the
synthetic Zipf(1.05) CTR stream: sweep the device-cache size (as a fraction
of the table) and measure the steady-state hit rate, host->device fetch
traffic, and device->host spill traffic per step through ``CachedBackend``
pull+push cycles (pushes dirty the working set, so evictions spill).

The §2.3 claim lands as: a ~10% cache already serves >= 80% of lookups from
device memory, and h2d traffic per step shrinks toward the (irreducible)
working-set churn as the cache grows.
"""

from __future__ import annotations

import time


def run(steps: int = 60, rows: int = 50_000, dim: int = 16,
        capacity: int = 4096, batch: int = 512, nnz: int = 20,
        zipf_a: float = 1.05):
    import jax
    import jax.numpy as jnp

    from repro.core.cache_tier import CachedBackend
    from repro.core.sparse_optim import SparseAdagrad, SparseAdagradConfig
    from repro.data import synthetic as S

    opt = SparseAdagrad(SparseAdagradConfig(lr=0.1))
    measure_from = steps * 2 // 3
    results = []
    # the cache can never be smaller than one batch's working set, so the
    # sweep starts at the capacity floor (~8% of this table)
    for frac in (0.08, 0.10, 0.20, 0.50, 1.00):
        C = max(capacity, int(rows * frac))
        cb = CachedBackend(cache_rows=C)
        table = jnp.zeros((rows, dim), jnp.float32)
        accum = jnp.full((rows, dim), 0.1, jnp.float32)
        state = cb.init_state(table)

        @jax.jit
        def step_fn(table, accum, state, ids):
            ws, table, accum, state = cb.pull(table, accum, state, ids,
                                              capacity)
            # push a small row update so evictions have dirty rows to spill
            grads = ws.rows * 0.01
            return cb.push(table, accum, state, ws, grads, opt)

        gen = S.ctr_batches(seed=7, batch=batch, rows=rows, n_fields=8,
                            nnz=nnz, zipf_a=zipf_a)
        warm = None
        t0 = 0.0
        for i in range(steps):
            ids = jnp.asarray(next(gen)["ids"].reshape(-1))
            table, accum, state = step_fn(table, accum, state, ids)
            if i == measure_from - 1:
                jax.block_until_ready(state.lookups)
                warm = (float(state.lookups), float(state.fetched),
                        float(state.bytes_h2d), float(state.bytes_d2h))
                t0 = time.perf_counter()
        jax.block_until_ready(state.lookups)
        n_meas = steps - measure_from
        us = (time.perf_counter() - t0) / n_meas * 1e6
        lookups = float(state.lookups) - warm[0]
        fetched = float(state.fetched) - warm[1]
        h2d = (float(state.bytes_h2d) - warm[2]) / n_meas
        d2h = (float(state.bytes_d2h) - warm[3]) / n_meas
        results.append((
            f"fig_cache_f{int(frac * 100):03d}", us,
            f"cache_rows={C},hit_rate={1.0 - fetched / lookups:.4f},"
            f"h2d_MB_per_step={h2d / 1e6:.4f},d2h_MB_per_step={d2h / 1e6:.4f},"
            f"evictions={int(float(state.evictions))}",
        ))
    return results
