"""Paper Fig. 6 (+7): two-phase communication vs the naive route.

The paper's two-phase GPU communication keeps bulk traffic on NVLink; our
TPU adaptation keeps it on in-pod ICI.  This benchmark compiles one k-step
merge of a 64 MB dense tower on the 512-chip multi-pod mesh under each
schedule and reports the slow-fabric (DCN) bytes per device — the quantity
the paper's Fig. 6/7 measure in time.  Runs in a subprocess (512 fake
devices).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time


def run(payload_mb: float = 64.0):
    results = []
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = "src"
    base = None
    for schedule in ["flat", "two_phase", "bf16", "int8_ef"]:
        t0 = time.perf_counter()
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks._mesh_probe", "--probe", "merge",
             "--schedule", schedule, "--payload-mb", str(payload_mb)],
            capture_output=True, text=True, env=env, timeout=900,
        )
        if out.returncode != 0:
            results.append((f"fig6_merge_{schedule}", 0.0, f"ERROR:{out.stderr[-200:]}"))
            continue
        rec = json.loads(out.stdout.strip().splitlines()[-1])
        us = (time.perf_counter() - t0) * 1e6
        dcn = rec["dcn_bytes_per_device"]
        if schedule == "flat":
            base = dcn
        ratio = f",dcn_vs_flat={dcn / base:.4f}" if base else ""
        results.append((
            f"fig6_merge_{schedule}", us,
            f"dcn_MB_per_dev={dcn / 1e6:.3f},ici_MB_per_dev="
            f"{rec['ici_bytes_per_device'] / 1e6:.3f}{ratio}",
        ))
    return results
