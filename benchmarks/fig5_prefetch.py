"""Paper Fig. 5, sparse-pull edition: double-buffered pull prefetch.

Measures steps/sec on the synthetic CTR stream with the pull stage run
synchronously (pull -> train serialized per step) vs prefetched
(``TrainerConfig.prefetch``: the next batch's pull dispatched before the
host blocks).  Results are bit-identical (asserted by
tests/test_prefetch.py); this benchmark reports the throughput side for
the gather and cached placements, in two regimes:

  - ``fit``: the bare training loop.  The host never blocks between steps,
    so on a single-stream device JAX async dispatch already keeps the queue
    full and prefetch is ~parity — reported for honesty, and because on
    real accelerators (separate H2D/compute streams) this is where the
    cache tier's miss-fetch DMAs overlap the fwd/bwd.
  - ``online``: the production predict-then-train protocol (the launcher's
    loop — predict each batch, score it into a streaming AUC, then train).
    The host BLOCKS on prediction scores every step; with prefetch the
    pull executes during that block + the host-side AUC work instead of
    serializing after it — this is the overlap Fig. 5 hides the PS pull
    behind.
"""

from __future__ import annotations

import time

import jax

from repro.core.kstep import KStepConfig
from repro.core.sparse_optim import SparseAdagradConfig
from repro.data import synthetic as S
from repro.runtime.factory import build_trainer
from repro.runtime.trainer import TrainerConfig

ROWS, N_FIELDS, NNZ, BATCH = 50_000, 16, 50, 1024
CAPACITY = 1 << 14


def _tcfg(placement: str, prefetch: bool) -> TrainerConfig:
    return TrainerConfig(
        n_pod=2, kstep=KStepConfig(lr=1e-3, k=5, b1=0.0),
        sparse=SparseAdagradConfig(lr=0.5, initial_accumulator=0.01),
        placement=placement, capacity=CAPACITY,
        cache_rows=CAPACITY if placement == "cached" else None,
        prefetch=prefetch, log_every=10_000,
    )


def _gen():
    return S.ctr_batches(seed=3, batch=BATCH, rows=ROWS, n_fields=N_FIELDS,
                         nnz=NNZ, zipf_a=1.05)


def _fit_steps_per_sec(placement: str, prefetch: bool, steps: int) -> float:
    tr = build_trainer("baidu-ctr", _tcfg(placement, prefetch))
    gen = _gen()
    tr.fit(gen, 3)             # warmup: compile both stages off the clock
    jax.block_until_ready((tr.tables, tr.dense))
    t0 = time.perf_counter()
    tr.fit(gen, steps)
    # fit never blocks mid-run; charge the pipeline drain to the run
    jax.block_until_ready((tr.tables, tr.dense))
    return steps / (time.perf_counter() - t0)


def _online_steps_per_sec(placement: str, prefetch: bool, steps: int) -> float:
    from repro.runtime.metrics import StreamingAUC

    tr = build_trainer("baidu-ctr", _tcfg(placement, prefetch))
    gen = _gen()
    meter = StreamingAUC(window=20)

    def one(b):
        tr.prefetch(b)                       # no-op in the sync runs
        meter.update(b["label"], tr.predict(b))   # host blocks on scores
        tr.train_step(b)

    for _ in range(3):                       # warmup/compile
        one(next(gen))
    jax.block_until_ready((tr.tables, tr.dense))
    t0 = time.perf_counter()
    for _ in range(steps):
        one(next(gen))
    jax.block_until_ready((tr.tables, tr.dense))
    return steps / (time.perf_counter() - t0)


def run(steps: int = 40):
    results = []
    for regime, measure in (("fit", _fit_steps_per_sec),
                            ("online", _online_steps_per_sec)):
        for placement in ("gather", "cached"):
            sync = measure(placement, False, steps)
            pre = measure(placement, True, steps)
            results.append((f"fig5_prefetch_{regime}_{placement}_sync",
                            1e6 / sync, f"steps_per_sec={sync:.2f}"))
            results.append((
                f"fig5_prefetch_{regime}_{placement}_prefetched",
                1e6 / pre,
                f"steps_per_sec={pre:.2f} speedup={pre / sync:.2f}x",
            ))
    return results
