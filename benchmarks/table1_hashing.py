"""Paper Table 1: hashing shrinks the model but costs AUC.

Trains the CTR model on teacher-labelled data with the full id space vs
hashed id spaces (ids % k) and reports AUC per k.  The paper's point — any
noticeable AUC loss is unacceptable, so the 10-TB table cannot be hashed
away — shows up as monotonically decreasing AUC with smaller k.
"""

from __future__ import annotations

import time

from repro.data import synthetic as S
from repro.runtime.metrics import auc


def run(rows: int = 20000, steps: int = 240, batch: int = 512):
    import numpy as np
    from repro.core.kstep import KStepConfig
    from repro.core.sparse_optim import SparseAdagradConfig
    from repro.models import recsys as R
    from repro.runtime.factory import build_trainer
    from repro.runtime.trainer import TrainerConfig

    results = []
    for hash_k in [rows, rows // 4, rows // 16, rows // 64]:
        cfg = R.CTRConfig(rows=hash_k, n_fields=8, nnz_per_instance=20,
                          mlp=(64, 1), attn_heads=2)
        tc = TrainerConfig(n_pod=1, kstep=KStepConfig(lr=1e-3, k=1),
                           sparse=SparseAdagradConfig(lr=0.5, initial_accumulator=0.01),
                           capacity=16384)
        tr = build_trainer("baidu-ctr", tc, model_cfg=cfg)
        gen = S.ctr_batches(seed=1, batch=batch, rows=rows, n_fields=8, nnz=20)
        labels, scores = [], []
        t0 = time.perf_counter()
        for i in range(steps):
            b = next(gen)
            b = dict(b, ids=(b["ids"] % hash_k).astype(b["ids"].dtype))
            if i >= steps * 2 // 3:
                scores.append(tr.predict(b))
                labels.append(b["label"])
            tr.train_step(b)
        a = auc(np.concatenate(labels), np.concatenate(scores))
        us = (time.perf_counter() - t0) / steps * 1e6
        results.append((f"table1_hash_k={hash_k}", us, f"auc={a:.4f}"))
    return results
