"""Paper Table 1: hashing shrinks the model but costs AUC.

Trains the CTR model on teacher-labelled data with the full id space vs
hashed id spaces (ids % k) and reports AUC per k.  The paper's point — any
noticeable AUC loss is unacceptable, so the 10-TB table cannot be hashed
away — shows up as monotonically decreasing AUC with smaller k.
"""

from __future__ import annotations

import time

from repro.data import synthetic as S
from repro.runtime.metrics import auc


def run(rows: int = 20000, steps: int = 240, batch: int = 512):
    import jax
    import jax.numpy as jnp
    from repro.core.kstep import KStepConfig
    from repro.core.sparse_optim import SparseAdagradConfig
    from repro.models import recsys as R
    from repro.runtime.trainer import HybridTrainer, TrainerConfig

    results = []
    for hash_k in [rows, rows // 4, rows // 16, rows // 64]:
        cfg = R.CTRConfig(rows=hash_k, n_fields=8, nnz_per_instance=20, mlp=(64, 1))
        rng = jax.random.key(0)
        dense = R.ctr_init_dense(rng, cfg)
        tables = {"sparse": jax.random.normal(rng, (hash_k, 64)) * 0.05}

        def embed(workings, invs, bp, cfg=cfg):
            B, nnz = bp["ids"].shape
            seg = (jnp.arange(B, dtype=jnp.int32)[:, None] * cfg.n_fields
                   + bp["field_ids"]).reshape(-1)
            emb = jnp.take(workings["sparse"], invs["sparse"], axis=0) \
                * bp["mask"].reshape(-1)[:, None]
            bags = jax.ops.segment_sum(emb, seg, num_segments=B * cfg.n_fields)
            return bags.reshape(B, cfg.n_fields, cfg.embed_dim)

        def loss(dp, emb, bp, predict=False, cfg=cfg):
            logits = R.ctr_forward_from_emb(dp, emb, bp, cfg)
            if predict:
                return jax.nn.sigmoid(logits)
            return R.pointwise_loss(logits, bp["label"])

        tc = TrainerConfig(n_pod=1, kstep=KStepConfig(lr=1e-3, k=1),
                           sparse=SparseAdagradConfig(lr=0.5, initial_accumulator=0.01))
        tr = HybridTrainer(dense, tables, embed, loss, {"sparse": "ids"},
                           capacity=16384, cfg=tc)
        gen = S.ctr_batches(seed=1, batch=batch, rows=rows, n_fields=8, nnz=20)
        labels, scores = [], []
        t0 = time.perf_counter()
        for i in range(steps):
            b = next(gen)
            b = dict(b, ids=(b["ids"] % hash_k).astype(b["ids"].dtype))
            if i >= steps * 2 // 3:
                scores.append(tr.predict(b))
                labels.append(b["label"])
            tr.train_step(b)
        import numpy as np
        a = auc(np.concatenate(labels), np.concatenate(scores))
        us = (time.perf_counter() - t0) / steps * 1e6
        results.append((f"table1_hash_k={hash_k}", us, f"auc={a:.4f}"))
    return results
