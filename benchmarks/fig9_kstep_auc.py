"""Paper Fig. 9: AUC under k-step merging vs the every-step baseline.

Online (predict-then-train) AUC for worker counts {1,2,4,8} and
k in {1,10,20,50}: the paper's claim is that the AUC difference stays in
the noise.  Runs the REAL training stack (hybrid k-step Adam + sparse
AdaGrad working sets through ``build_trainer``) on teacher-labelled CTR
data.
"""

from __future__ import annotations

import time

import numpy as np


def run(steps: int = 120):
    from repro.core.kstep import KStepConfig
    from repro.core.sparse_optim import SparseAdagradConfig
    from repro.data import synthetic as S
    from repro.models import recsys as R
    from repro.runtime.factory import build_trainer
    from repro.runtime.metrics import auc
    from repro.runtime.trainer import TrainerConfig

    CFG = R.CTRConfig(rows=5000, n_fields=8, nnz_per_instance=20, mlp=(64, 1),
                      attn_heads=2)

    def train_one(n_pod, k, n_steps):
        tc = TrainerConfig(n_pod=n_pod, kstep=KStepConfig(lr=1e-3, k=k, b1=0.0),
                           sparse=SparseAdagradConfig(lr=0.5, initial_accumulator=0.01),
                           capacity=16384)
        tr = build_trainer("baidu-ctr", tc, model_cfg=CFG)
        gen = S.ctr_batches(seed=1, batch=512, rows=CFG.rows, n_fields=8, nnz=20)
        labels, scores = [], []
        t0 = time.perf_counter()
        for i in range(n_steps):
            b = next(gen)
            if i >= n_steps * 2 // 3:
                scores.append(tr.predict(b))
                labels.append(b["label"])
            tr.train_step(b)
        wall = time.perf_counter() - t0
        return auc(np.concatenate(labels), np.concatenate(scores)), wall

    results = []
    base_auc, base_wall = train_one(1, 1, steps)
    results.append(("fig9_baseline_n1_k1", base_wall / steps * 1e6,
                    f"auc={base_auc:.4f}"))
    for n_pod, k in [(2, 10), (4, 20), (8, 50)]:
        # Large k needs enough steps that several merge rounds precede the
        # evaluation window (the paper trains for hours; 120 steps with k=50
        # would evaluate right after the FIRST merge).
        st = max(steps, 6 * k)
        a, wall = train_one(n_pod, k, st)
        results.append((
            f"fig9_n{n_pod}_k{k}_steps{st}", wall / st * 1e6,
            f"auc={a:.4f},auc_diff={a - base_auc:+.4f}",
        ))
    return results
