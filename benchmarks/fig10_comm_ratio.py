"""Paper Fig. 10: communication ratio of k-step merging vs the baseline,
plus the sparse-placement wire accounting (routed vs GSPMD gather).

The paper measures model-transmission time ratio ~ 1/k (18.1%, 10.8%, 6.4%,
2.8%, 1.2% for k = 10..200).  We reproduce the byte accounting exactly: the
per-step cross-pod (DCN) bytes of the k-step scheme are the merge payload
amortized over k local steps, vs the every-step gradient sync of the
baseline (same payload every step).  Byte counts come from the compiled
multi-pod merge HLO (fig6 probe); the ratio is payload-independent.

The sparse rows quantify what ``--placement routed`` buys on the same
production mesh: one working-set pull+push compiled under GSPMD (row-
sharded table, value-blind masked-partials + all-reduce) vs the explicit
all_to_all request routing — per-device collective bytes and their ratio.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys


def _probe(probe_args):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks._mesh_probe"] + probe_args,
        capture_output=True, text=True, env=env, timeout=900,
    )
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-200:])
    return json.loads(out.stdout.strip().splitlines()[-1])


def run(payload_mb: float = 64.0):
    results = []
    try:
        rec = _probe(["--probe", "merge", "--schedule", "two_phase",
                      "--payload-mb", str(payload_mb)])
    except RuntimeError as e:
        return [("fig10_comm_ratio", 0.0, f"ERROR:{e}")]
    merge_dcn = rec["dcn_bytes_per_device"]
    # baseline: the same payload synchronizes cross-pod EVERY step
    for k in [10, 20, 50, 100, 200]:
        ratio = 1.0 / k
        results.append((
            f"fig10_k{k}", 0.0,
            f"per_step_dcn_MB={merge_dcn / k / 1e6:.4f},"
            f"ratio_vs_every_step={ratio:.4f},paper={_paper_ratio(k):.3f}",
        ))

    # --placement routed vs GSPMD gather: per-step sparse exchange bytes
    try:
        sparse = {
            p: _probe(["--probe", "sparse", "--placement", p])
            for p in ("gather", "routed")
        }
    except RuntimeError as e:
        results.append(("fig10_sparse", 0.0, f"ERROR:{e}"))
        return results
    for p, rec in sparse.items():
        results.append((
            f"fig10_sparse_{p}", 0.0,
            f"total_MB_per_device={rec['total_bytes_per_device'] / 1e6:.4f},"
            f"dcn_MB={rec['dcn_bytes_per_device'] / 1e6:.4f},"
            f"ici_MB={rec['ici_bytes_per_device'] / 1e6:.4f}",
        ))
    g = sparse["gather"]["total_bytes_per_device"]
    r = sparse["routed"]["total_bytes_per_device"]
    results.append((
        "fig10_routed_vs_gspmd", 0.0,
        f"wire_ratio={r / max(g, 1):.4f},saving={1 - r / max(g, 1):.4f}",
    ))
    return results


def _paper_ratio(k: int) -> float:
    return {10: 0.181, 20: 0.108, 50: 0.064, 100: 0.028, 200: 0.012}[k]
