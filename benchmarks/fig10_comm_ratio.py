"""Paper Fig. 10: communication ratio of k-step merging vs the baseline.

The paper measures model-transmission time ratio ~ 1/k (18.1%, 10.8%, 6.4%,
2.8%, 1.2% for k = 10..200).  We reproduce the byte accounting exactly: the
per-step cross-pod (DCN) bytes of the k-step scheme are the merge payload
amortized over k local steps, vs the every-step gradient sync of the
baseline (same payload every step).  Byte counts come from the compiled
multi-pod merge HLO (fig6 probe); the ratio is payload-independent.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys


def run(payload_mb: float = 64.0):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks._mesh_probe", "--probe", "merge",
         "--schedule", "two_phase", "--payload-mb", str(payload_mb)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    results = []
    if out.returncode != 0:
        return [("fig10_comm_ratio", 0.0, f"ERROR:{out.stderr[-200:]}")]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    merge_dcn = rec["dcn_bytes_per_device"]
    # baseline: the same payload synchronizes cross-pod EVERY step
    for k in [10, 20, 50, 100, 200]:
        ratio = 1.0 / k
        results.append((
            f"fig10_k{k}", 0.0,
            f"per_step_dcn_MB={merge_dcn / k / 1e6:.4f},"
            f"ratio_vs_every_step={ratio:.4f},paper={_paper_ratio(k):.3f}",
        ))
    return results


def _paper_ratio(k: int) -> float:
    return {10: 0.181, 20: 0.108, 50: 0.064, 100: 0.028, 200: 0.012}[k]
