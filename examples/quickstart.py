"""Quickstart: train the paper's CTR model with k-step Adam in ~1 minute.

    PYTHONPATH=src python examples/quickstart.py

Simulates 4 workers ("pods") with k=10 merging on one CPU device — the
podded representation runs the exact Algorithm-2 semantics anywhere — and
reports online (predict-then-train) AUC, which should clear 0.75 on the
teacher-labelled synthetic click stream.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kstep import KStepConfig
from repro.core.sparse_optim import SparseAdagradConfig
from repro.data import synthetic as S
from repro.models import recsys as R
from repro.runtime.metrics import StreamingAUC
from repro.runtime.trainer import HybridTrainer, TrainerConfig


def main(steps: int = 150, n_pod: int = 4, k: int = 10):
    cfg = R.CTRConfig(rows=20_000, n_fields=8, nnz_per_instance=20, mlp=(64, 1))
    rng = jax.random.key(0)
    dense = R.ctr_init_dense(rng, cfg)
    tables = {"sparse": jax.random.normal(rng, (cfg.rows, cfg.embed_dim)) * 0.05}

    def embed(workings, invs, bp):
        B, nnz = bp["ids"].shape
        seg = (jnp.arange(B, dtype=jnp.int32)[:, None] * cfg.n_fields
               + bp["field_ids"]).reshape(-1)
        emb = jnp.take(workings["sparse"], invs["sparse"], axis=0) \
            * bp["mask"].reshape(-1)[:, None]
        bags = jax.ops.segment_sum(emb, seg, num_segments=B * cfg.n_fields)
        return bags.reshape(B, cfg.n_fields, cfg.embed_dim)

    def loss(dp, emb, bp, predict=False):
        logits = R.ctr_forward_from_emb(dp, emb, bp, cfg)
        if predict:
            return jax.nn.sigmoid(logits)
        return R.pointwise_loss(logits, bp["label"])

    tr = HybridTrainer(
        dense, tables, embed, loss, {"sparse": "ids"}, capacity=16384,
        cfg=TrainerConfig(
            n_pod=n_pod,
            kstep=KStepConfig(lr=1e-3, k=k, b1=0.0, merge="flat"),
            sparse=SparseAdagradConfig(lr=0.5, initial_accumulator=0.01),
        ),
    )
    gen = S.ctr_batches(seed=1, batch=512, rows=cfg.rows,
                        n_fields=cfg.n_fields, nnz=cfg.nnz_per_instance)
    meter = StreamingAUC(window=20)
    for i in range(steps):
        b = next(gen)
        meter.update(b["label"], tr.predict(b))  # predict-then-train
        l = tr.train_step(b)
        if (i + 1) % 25 == 0:
            print(f"step {i+1:4d}  loss {l:.4f}  online AUC {meter.value():.4f}")
    print(f"\nfinal online AUC ({n_pod} workers, k={k}): {meter.value():.4f}")
    return meter.value()


if __name__ == "__main__":
    a = main()
    assert a > 0.72, f"expected AUC > 0.72, got {a}"
