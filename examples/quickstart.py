"""Quickstart: train the paper's CTR model with k-step Adam in ~1 minute.

    PYTHONPATH=src python examples/quickstart.py

Simulates 4 workers ("pods") with k=10 merging on one CPU device — the
podded representation runs the exact Algorithm-2 semantics anywhere — and
reports online (predict-then-train) AUC, which should clear 0.75 on the
teacher-labelled synthetic click stream.

Model + sparse-path construction is config-driven through ``build_trainer``;
switch the embedding placement with ``placement="routed"``.
"""

from repro.core.kstep import KStepConfig
from repro.core.sparse_optim import SparseAdagradConfig
from repro.data import synthetic as S
from repro.models.recsys import CTRConfig
from repro.runtime.factory import build_trainer
from repro.runtime.metrics import StreamingAUC
from repro.runtime.trainer import TrainerConfig


def main(steps: int = 150, n_pod: int = 4, k: int = 10, placement: str = "gather"):
    cfg = CTRConfig(rows=20_000, n_fields=8, nnz_per_instance=20, mlp=(64, 1),
                    attn_heads=2)
    tr = build_trainer(
        "baidu-ctr",
        TrainerConfig(
            n_pod=n_pod,
            kstep=KStepConfig(lr=1e-3, k=k, b1=0.0, merge="flat"),
            sparse=SparseAdagradConfig(lr=0.5, initial_accumulator=0.01),
            placement=placement, capacity=16384,
        ),
        model_cfg=cfg,
    )
    gen = S.ctr_batches(seed=1, batch=512, rows=cfg.rows,
                        n_fields=cfg.n_fields, nnz=cfg.nnz_per_instance)
    meter = StreamingAUC(window=20)
    for i in range(steps):
        b = next(gen)
        meter.update(b["label"], tr.predict(b))  # predict-then-train
        l = tr.train_step(b)
        if (i + 1) % 25 == 0:
            print(f"step {i+1:4d}  loss {l:.4f}  online AUC {meter.value():.4f}")
    print(f"\nfinal online AUC ({n_pod} workers, k={k}): {meter.value():.4f}")
    return meter.value()


if __name__ == "__main__":
    a = main()
    assert a > 0.72, f"expected AUC > 0.72, got {a}"
