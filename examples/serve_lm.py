"""Batched LM serving: continuous batching with slot refill + KV caches.

    PYTHONPATH=src python examples/serve_lm.py --requests 12 --slots 4

Trains nothing — loads a small randomly-initialized qwen3-style model (its
smoke config), submits a queue of prompt requests and decodes them with the
BatchedServer, reporting tokens/s and per-request outputs.
"""

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import transformer as T
from repro.runtime.serve import BatchedServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b", choices=configs.list_archs())
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = configs.get(args.arch).smoke_cfg
    params = T.init_params(jax.random.key(0), cfg)
    srv = BatchedServer(params, cfg, slots=args.slots, max_len=256)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompt = rng.integers(1, cfg.vocab, rng.integers(3, 10))
        srv.submit(Request(prompt=prompt, max_new_tokens=args.max_new))

    t0 = time.perf_counter()
    stats = srv.run_to_completion()
    wall = time.perf_counter() - t0
    print(f"arch={args.arch} (smoke config), slots={args.slots}")
    print(f"decoded {stats['decoded_tokens']} tokens in {wall:.2f}s "
          f"({stats['decoded_tokens'] / wall:.1f} tok/s, "
          f"{stats['steps']} decode steps)")


if __name__ == "__main__":
    main()
