"""GIN training example — both GNN regimes:

  full-graph:  node classification on a synthetic community graph
  minibatch:   fanout neighbor sampling (the minibatch_lg regime)

    PYTHONPATH=src python examples/train_gin.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.kstep import KStepConfig
from repro.data import synthetic as S
from repro.data.graph_sampler import NeighborSampler
from repro.models import gin as G
from repro.runtime.trainer import DenseTrainer, TrainerConfig


def accuracy(params, g, cfg):
    logits = G.forward(params, jnp.asarray(g.x), jnp.asarray(g.edge_src),
                       jnp.asarray(g.edge_dst), cfg)
    return float(np.mean(np.argmax(np.asarray(logits), -1) == g.labels))


def full_graph(steps: int = 60):
    g = S.community_graph(seed=0, n_nodes=2000, avg_degree=8, d_feat=32, n_classes=5)
    cfg = dataclasses.replace(configs.get("gin-tu").smoke_cfg, d_in=32, n_classes=5)
    params = G.init_params(jax.random.key(0), cfg)
    tr = DenseTrainer(lambda p, b: G.loss_fn(p, b, cfg), params,
                      TrainerConfig(n_pod=2, kstep=KStepConfig(lr=3e-3, k=5, b1=0.9)))
    # full-graph: every pod trains on the same (whole) graph
    batch = {"x": np.stack([g.x] * 2), "edge_src": np.stack([g.edge_src] * 2),
             "edge_dst": np.stack([g.edge_dst] * 2), "labels": np.stack([g.labels] * 2)}
    acc0 = accuracy(jax.tree.map(lambda x: x[0], tr.params), g, cfg)
    for i in range(steps):
        loss = tr.train_step(batch, podded=True)
    acc1 = accuracy(jax.tree.map(lambda x: x[0], tr.params), g, cfg)
    print(f"full-graph:  acc {acc0:.3f} -> {acc1:.3f} (loss {loss:.3f})")
    return acc1


def minibatch(steps: int = 80):
    g = S.community_graph(seed=1, n_nodes=5000, avg_degree=10, d_feat=32, n_classes=5)
    cfg = dataclasses.replace(configs.get("gin-tu").smoke_cfg, d_in=32, n_classes=5)
    params = G.init_params(jax.random.key(0), cfg)
    sampler = NeighborSampler(5000, g.edge_src.astype(np.int64),
                              g.edge_dst.astype(np.int64))
    rng = np.random.default_rng(0)
    tr = DenseTrainer(lambda p, b: G.loss_fn(p, b, cfg), params,
                      TrainerConfig(n_pod=1, kstep=KStepConfig(lr=3e-3, k=1, b1=0.9)))
    for i in range(steps):
        seeds = rng.choice(5000, 128, replace=False)
        blk = sampler.sample_block(rng, seeds, fanouts=(8, 5))
        batch = {
            "x": g.x[blk["nodes"]],
            "edge_src": blk["edge_src"], "edge_dst": blk["edge_dst"],
            "edge_mask": blk["edge_mask"],
            "labels": g.labels[blk["nodes"]],
            "node_mask": blk["seed_mask"],
        }
        loss = tr.train_step(batch)
    acc = accuracy(jax.tree.map(lambda x: x[0], tr.params), g, cfg)
    print(f"minibatch:   final acc {acc:.3f} (loss {loss:.3f})")
    return acc


if __name__ == "__main__":
    a1 = full_graph()
    a2 = minibatch()
    assert a1 > 0.5 and a2 > 0.4, (a1, a2)
    print("GIN examples OK")
