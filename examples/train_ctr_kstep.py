"""End-to-end driver: train a ~100M-parameter CTR model for a few hundred
steps with the full production stack — k-step Adam with two-phase merging,
working-set sparse AdaGrad behind a pluggable placement backend, prefetched
input pipeline, checkpoint/restart.

    PYTHONPATH=src python examples/train_ctr_kstep.py --steps 300

~100M params: 1.5M-row x 64-d table (96M) + field-attention tower (~4M).
Reports the paper's Fig. 9/10 quantities at laptop scale: online AUC and
the cross-pod communication amortization.  ``--placement routed`` swaps the
gather path for the explicit all-to-all PS exchange, ``--placement cached``
runs the hierarchical host/device cache tier (``--cache-rows`` sizes it),
and ``--prefetch`` overlaps each batch's working-set pull with the previous
step.  The training loop itself is the shared online predict-then-train
loop (``repro.runtime.online.fit_online``) — the same one the launcher
runs for every recsys arch.
"""

import argparse
import os
import tempfile

import numpy as np

from repro.core.kstep import KStepConfig
from repro.core.sparse_optim import SparseAdagradConfig
from repro.data import synthetic as S
from repro.data.pipeline import PrefetchPipeline
from repro.models import recsys as R
from repro.runtime.factory import build_trainer
from repro.runtime.online import fit_online
from repro.runtime.trainer import TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=1024)
    ap.add_argument("--rows", type=int, default=1_500_000)
    ap.add_argument("--n-pod", type=int, default=4)
    ap.add_argument("--k", type=int, default=20)
    ap.add_argument("--merge", default="two_phase",
                    choices=["flat", "two_phase", "bf16", "int8_ef"])
    ap.add_argument("--placement", default="gather",
                    choices=["gather", "routed", "cached"])
    ap.add_argument("--cache-rows", type=int, default=0)
    ap.add_argument("--prefetch", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = R.CTRConfig(rows=args.rows, embed_dim=64, n_fields=24,
                      nnz_per_instance=48, mlp=(512, 256, 1))
    n_dense = sum(np.prod(s) for s in [(64, 64)] * 3) + (24 * 64) * 512 + 512 * 256 + 256
    print(f"model: ~{(cfg.rows * cfg.embed_dim + n_dense) / 1e6:.0f}M params "
          f"({cfg.rows * cfg.embed_dim / 1e6:.0f}M sparse)")

    ckpt_dir = args.ckpt_dir or os.path.join(tempfile.gettempdir(), "ctr_kstep_ckpt")
    tr = build_trainer(
        "baidu-ctr",
        TrainerConfig(
            n_pod=args.n_pod,
            kstep=KStepConfig(lr=1e-3, k=args.k, b1=0.0, merge=args.merge),
            sparse=SparseAdagradConfig(lr=0.5, initial_accumulator=0.01),
            placement=args.placement, capacity=1 << 16,
            cache_rows=args.cache_rows or None, prefetch=args.prefetch,
            ckpt_dir=ckpt_dir, ckpt_every=100, ckpt_async=True,
        ),
        model_cfg=cfg,
    )
    if args.resume and tr.resume():
        print(f"resumed from step {tr.step_num}")

    src = S.ctr_batches(seed=1, batch=args.batch, rows=cfg.rows,
                        n_fields=cfg.n_fields, nnz=cfg.nnz_per_instance)
    pipe = PrefetchPipeline(src, depth=2)
    # the one canonical online predict-then-train loop (shared with the
    # launcher and the other recsys archs) — no hand-rolled step loop here
    steps = max(args.steps - tr.step_num, 0)
    _, online_auc = fit_online(tr, iter(pipe), steps, window=30, log=print)
    pipe.close()
    auc_s = f"{online_auc:.4f}" if online_auc is not None else "n/a"
    print(f"\ndone: step {tr.step_num}, online AUC {auc_s}, "
          f"overflow_dropped {tr.overflow_dropped}, "
          f"input stall {pipe.wait_seconds:.1f}s vs staging {pipe.read_seconds:.1f}s")


if __name__ == "__main__":
    main()
