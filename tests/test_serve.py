"""Batched serving loop: continuous batching, slot refill, throughput stats."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.runtime.serve import BatchedServer, Request


def make_model():
    cfg = T.TransformerConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                              d_ff=64, vocab=50, dtype=jnp.float32, moe_group_size=32)
    return cfg, T.init_params(jax.random.key(0), cfg)


def test_server_completes_all_requests():
    cfg, p = make_model()
    srv = BatchedServer(p, cfg, slots=4, max_len=64)
    reqs = [Request(prompt=np.asarray([1 + i, 2, 3]), max_new_tokens=5)
            for i in range(7)]  # more requests than slots -> refill path
    for r in reqs:
        srv.submit(r)
    stats = srv.run_to_completion()
    assert all(len(r.out) == 5 for r in reqs)
    assert stats["decoded_tokens"] == 35
    assert stats["steps"] >= 10  # 7 requests over 4 slots: at least 2 waves


def test_server_greedy_matches_manual_decode():
    cfg, p = make_model()
    prompt = np.asarray([5, 9, 11])
    srv = BatchedServer(p, cfg, slots=1, max_len=32)
    r = Request(prompt=prompt, max_new_tokens=4)
    srv.submit(r)
    srv.run_to_completion()

    cache = T.init_cache(cfg, 1, 32)
    tok = None
    for t in prompt:
        logits, cache = T.decode_step(p, cache, jnp.asarray([int(t)]), cfg)
    outs = []
    for _ in range(4):
        nxt = int(jnp.argmax(logits[0]))
        outs.append(nxt)
        logits, cache = T.decode_step(p, cache, jnp.asarray([nxt]), cfg)
    assert r.out == outs


def test_decode_donates_kv_cache():
    """Regression (found by repro.analysis): the KV cache is rewritten every
    decode step and the old handle dropped on reassignment, so the decode
    jit must mark arg 1 as a donor — otherwise every step materializes a
    second full cache and peak serving memory doubles."""
    cfg, p = make_model()
    srv = BatchedServer(p, cfg, slots=2, max_len=16)
    txt = srv._decode.lower(p, srv.cache, jnp.zeros(2, jnp.int32)).as_text()
    assert "tf.aliasing_output" in txt or "jax.buffer_donor" in txt


def test_server_eos_frees_slot():
    cfg, p = make_model()
    # find the greedy first token for a given prompt, then use it as EOS
    srv0 = BatchedServer(p, cfg, slots=1, max_len=32)
    r0 = Request(prompt=np.asarray([7, 3]), max_new_tokens=1)
    srv0.submit(r0)
    srv0.run_to_completion()
    eos = r0.out[0]
    srv = BatchedServer(p, cfg, slots=1, max_len=32, eos_id=eos)
    r1 = Request(prompt=np.asarray([7, 3]), max_new_tokens=10)
    srv.submit(r1)
    srv.run_to_completion()
    assert len(r1.out) == 1 and r1.out[0] == eos
