"""LM transformer correctness: attention variants, decode/forward parity,
MoE routing, loss chunking."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models import moe as moe_lib

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab=97, dtype=jnp.float32, moe_group_size=64)


def mk(params_key=0, **kw):
    cfg = T.TransformerConfig(**{**BASE, **kw})
    return cfg, T.init_params(jax.random.key(params_key), cfg)


def toks(shape, key=1, vocab=97):
    return jax.random.randint(jax.random.key(key), shape, 0, vocab)


def test_forward_shapes_no_nan():
    cfg, p = mk(qk_norm=True, qkv_bias=True)
    t = toks((3, 16))
    logits, aux = T.forward(p, t, cfg)
    assert logits.shape == (3, 16, 97)
    assert not bool(jnp.isnan(logits).any())


def test_causality():
    """Changing a future token must not change past logits."""
    cfg, p = mk()
    t1 = toks((1, 16))
    t2 = t1.at[0, 10].set((t1[0, 10] + 1) % 97)
    l1, _ = T.forward(p, t1, cfg)
    l2, _ = T.forward(p, t2, cfg)
    np.testing.assert_allclose(np.asarray(l1[0, :10]), np.asarray(l2[0, :10]), atol=1e-5)
    assert not np.allclose(np.asarray(l1[0, 10:]), np.asarray(l2[0, 10:]), atol=1e-5)


def test_swa_window_semantics():
    """Single layer, window W: logits at i depend only on tokens (i-W, i].
    (Stacked SWA layers extend the receptive field by (W-1) per layer, so
    the strict check needs n_layers=1.)"""
    cfg, p = mk(n_layers=1, attn_window=4)
    t1 = toks((1, 24))
    t2 = t1.at[0, 2].set((t1[0, 2] + 3) % 97)  # far in the past
    l1, _ = T.forward(p, t1, cfg)
    l2, _ = T.forward(p, t2, cfg)
    # positions >= 2+4 see identical windows (token 2 out of range)
    np.testing.assert_allclose(np.asarray(l1[0, 6:]), np.asarray(l2[0, 6:]), atol=1e-5)
    assert not np.allclose(np.asarray(l1[0, 2:6]), np.asarray(l2[0, 2:6]), atol=1e-5)


def test_chunked_attention_locality():
    cfg, p = mk(attn_chunk=8)
    t1 = toks((1, 24))
    t2 = t1.at[0, 1].set((t1[0, 1] + 3) % 97)
    l1, _ = T.forward(p, t1, cfg)
    l2, _ = T.forward(p, t2, cfg)
    # chunk 2/3 (positions 8+) never see position 1
    np.testing.assert_allclose(np.asarray(l1[0, 8:]), np.asarray(l2[0, 8:]), atol=1e-5)


def test_chunked_with_global_layers_sees_everything():
    cfg, p = mk(n_layers=4, attn_chunk=8, global_every=2)
    t1 = toks((1, 24))
    t2 = t1.at[0, 1].set((t1[0, 1] + 3) % 97)
    l1, _ = T.forward(p, t1, cfg)
    l2, _ = T.forward(p, t2, cfg)
    assert not np.allclose(np.asarray(l1[0, 8:]), np.asarray(l2[0, 8:]), atol=1e-6)


@pytest.mark.parametrize("variant", ["full", "swa", "chunked"])
def test_decode_matches_forward(variant):
    kw = {}
    if variant == "swa":
        kw["attn_window"] = 6
    if variant == "chunked":
        kw["attn_chunk"] = 8
    cfg, p = mk(**kw)
    t = toks((2, 20))
    ref, _ = T.forward(p, t, cfg)
    cache = T.init_cache(cfg, 2, 20)
    outs = []
    for i in range(20):
        lg, cache = T.decode_step(p, cache, t[:, i], cfg)
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref), atol=2e-4)


def test_swa_ring_cache_is_window_sized():
    cfg, _ = mk(attn_window=6)
    cache = T.init_cache(cfg, 2, 100)
    assert cache["k"].shape[2] == 6


@pytest.mark.parametrize("impl", ["qblocked", "online"])
def test_long_attention_impls_match_dense(impl):
    cfg_d, p = mk(dense_attn_threshold=4096)
    t = toks((2, 32))
    ref, _ = T.forward(p, t, cfg_d)
    if impl == "qblocked":
        cfg_x = T.TransformerConfig(**{**BASE, "dense_attn_threshold": 8, "attn_block_q": 8})
        got, _ = T.forward(p, t, cfg_x)
    else:
        q_pos = jnp.arange(32, dtype=jnp.int32)
        # direct comparison of the online-softmax primitive
        cfg_x = T.TransformerConfig(**{**BASE, "attn_block_kv": 8})
        rng = jax.random.key(9)
        q = jax.random.normal(rng, (2, 32, 4, 16), jnp.float32)
        k = jax.random.normal(jax.random.fold_in(rng, 1), (2, 32, 2, 16), jnp.float32)
        v = jax.random.normal(jax.random.fold_in(rng, 2), (2, 32, 2, 16), jnp.float32)
        a = T._sdpa_dense(cfg_x, 0, q, k, v, q_pos, q_pos)
        b = T._sdpa_blockwise(cfg_x, 0, q, k, v, q_pos, q_pos)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
        return
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-4)


def test_moe_top1_and_top2_grads_finite():
    for topk, shared in [(1, True), (2, False)]:
        cfg, p = mk(n_experts=4, top_k=topk, shared_expert=shared,
                    moe_group_size=16, router_aux_coef=0.01)
        t = toks((2, 16))
        g = jax.grad(T.loss_fn)(p, {"tokens": t, "labels": t}, cfg)
        for leaf in jax.tree.leaves(g):
            assert np.all(np.isfinite(np.asarray(leaf)))


def test_moe_capacity_drops_consistent():
    """All tokens kept when capacity is ample: MoE == weighted expert sum."""
    cfg, p = mk(n_experts=2, top_k=2, moe_group_size=8, capacity_factor=4.0)
    rng = jax.random.key(5)
    x = jax.random.normal(rng, (1, 8, 64), jnp.float32)
    lp = jax.tree.map(lambda v: v[0], p["layers"])
    y, aux = moe_lib.moe_ffn(x, lp, cfg)
    # dense-dispatch oracle: every expert on every token, combine by router
    logits = (x.reshape(8, 64) @ lp["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    outs = []
    for e in range(2):
        g = jax.nn.silu(x.reshape(8, 64) @ lp["we_gate"][e]) * (x.reshape(8, 64) @ lp["we_up"][e])
        outs.append(g @ lp["we_down"][e])
    expect = sum(probs[:, e:e+1] * outs[e] for e in range(2))
    np.testing.assert_allclose(np.asarray(y.reshape(8, 64)), np.asarray(expect), atol=1e-4)


def test_ce_chunking_invariance():
    cfg1, p = mk(ce_chunk_tokens=8)
    cfg2 = T.TransformerConfig(**{**BASE, "ce_chunk_tokens": 1 << 30})
    t = toks((2, 32))
    b = {"tokens": t, "labels": t}
    l1, l2 = T.loss_fn(p, b, cfg1), T.loss_fn(p, b, cfg2)
    assert abs(float(l1) - float(l2)) < 1e-5


def test_active_params_accounting():
    cfg = T.TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                              d_ff=128, vocab=100, n_experts=4, top_k=2)
    total, active = cfg.total_params(), cfg.active_params()
    assert active < total  # MoE: only top-k experts active
    cfg_d = T.TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                                d_ff=128, vocab=100)
    # dense: active == total (modulo final norms not counted in active)
    assert abs(cfg_d.active_params() - cfg_d.total_params()) / cfg_d.total_params() < 0.01
