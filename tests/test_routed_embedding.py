"""Routed PS exchange (core/routed_embedding.py): exactness vs the dense
oracle on a real 8-device mesh (subprocess — device count locks at init)."""

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_routed_pull_push_exact():
    run_sub("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_host_mesh
from repro.core import routed_embedding as RE

mesh = make_host_mesh(2, 2, 2)
n_shards, rows_per_shard, dim = 8, 16, 4
rows = n_shards * rows_per_shard
rng = np.random.default_rng(0)
table = jnp.asarray(rng.standard_normal((rows, dim)), jnp.float32)
ids = jnp.asarray(rng.integers(0, rows, 64), jnp.int32)
pull, push = RE.make_routed_pull_push(mesh, rows_per_shard, dim, 8, 8,
                                      shard_axes=("pod","data","model"))
tsh = NamedSharding(mesh, P(("pod","data","model"), None))
ish = NamedSharding(mesh, P(("pod","data","model"),))
tq, iq = jax.device_put(table, tsh), jax.device_put(ids, ish)

working, slots, dropped = jax.jit(pull)(tq, iq)
ref = RE.reference_pull(table, ids, rows_per_shard, n_shards)
assert np.asarray(dropped).sum() == 0
np.testing.assert_allclose(np.asarray(working), np.asarray(ref), atol=1e-6)

accum = jnp.full((rows, dim), 0.1, jnp.float32)
grads = jnp.asarray(rng.standard_normal((64, dim)), jnp.float32)
nt, na, _ = jax.jit(push)(tq, jax.device_put(accum, tsh), iq,
                          jax.device_put(grads, tsh), 0.1, 1e-10)
slots_ref = RE.slot_of(ids, rows_per_shard, n_shards)
g2 = np.zeros((rows, dim))
for i, s in enumerate(np.asarray(slots_ref)):
    g2[s] += np.asarray(grads[i])**2
na_ref = np.asarray(accum) + g2
nt_ref = np.asarray(table).copy()
for i, s in enumerate(np.asarray(slots_ref)):
    nt_ref[s] -= 0.1 * np.asarray(grads[i]) / (np.sqrt(na_ref[s]) + 1e-10)
np.testing.assert_allclose(np.asarray(nt), nt_ref, atol=1e-5)
np.testing.assert_allclose(np.asarray(na), na_ref, atol=1e-5)
print("OK")
""")


def test_routed_overflow_counted():
    """With capacity 1, collisions on a shard are dropped AND counted."""
    run_sub("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_host_mesh
from repro.core import routed_embedding as RE

mesh = make_host_mesh(2, 2, 2)
n_shards, rows_per_shard, dim = 8, 16, 2
rows = n_shards * rows_per_shard
table = jnp.ones((rows, dim), jnp.float32)
# every device requests ids 0 and 8 -> both map to shard 0; cap_route=1 drops one
ids = jnp.asarray([0, 8] * 32, jnp.int32)[:64]
pull, _ = RE.make_routed_pull_push(mesh, rows_per_shard, dim, 8, 1,
                                   shard_axes=("pod","data","model"))
tsh = NamedSharding(mesh, P(("pod","data","model"), None))
ish = NamedSharding(mesh, P(("pod","data","model"),))
working, slots, dropped = jax.jit(pull)(jax.device_put(table, tsh),
                                        jax.device_put(ids, ish))
total_dropped = int(np.asarray(dropped).sum())
assert total_dropped > 0, "collisions must be counted"
# dropped rows read back as zeros; delivered rows are exact
w = np.asarray(working)
assert set(np.unique(w.round(6))) <= {0.0, 1.0}
print("OK dropped:", total_dropped)
""")


def test_slot_mapping_bijective():
    import numpy as np
    from repro.core.routed_embedding import slot_of
    import jax.numpy as jnp
    rows_per_shard, n_shards = 7, 8
    ids = jnp.arange(rows_per_shard * n_shards)
    slots = np.asarray(slot_of(ids, rows_per_shard, n_shards))
    assert len(set(slots.tolist())) == rows_per_shard * n_shards
    assert slots.min() == 0 and slots.max() == rows_per_shard * n_shards - 1
