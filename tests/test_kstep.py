"""Unit + property tests for the k-step Adam optimizer (Algorithm 2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic replay
    from tests._hypothesis_compat import given, settings, strategies as st

from repro.core.kstep import KStepAdam, KStepConfig, pod_replicate, pod_consensus_error
from repro.optim.adam import Adam


def tree_allclose(a, b, atol=1e-6):
    return all(
        np.allclose(x, y, atol=atol)
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def make_problem(seed=0, n_pod=1):
    rng = np.random.default_rng(seed)
    params = {"w": jnp.asarray(rng.standard_normal((4, 3)), jnp.float32),
              "b": jnp.asarray(rng.standard_normal(3), jnp.float32)}
    return pod_replicate(params, n_pod)


def grads_like(params, seed):
    rng = np.random.default_rng(seed)
    return jax.tree.map(lambda x: jnp.asarray(rng.standard_normal(x.shape), jnp.float32), params)


def test_n1_k1_matches_reference_adam():
    """k-step Adam with one worker and k=1 must equal plain Adam exactly."""
    pp = make_problem(n_pod=1)
    opt = KStepAdam(KStepConfig(lr=0.01, b1=0.9, k=1), n_pod=1)
    ref = Adam(lr=0.01, b1=0.9)
    st_k = opt.init(pp)
    st_r = ref.init(pp)
    p_k, p_r = pp, pp
    for i in range(5):
        g = grads_like(pp, i)
        p_k, st_k = opt.step(p_k, g, st_k)
        p_r, st_r = ref.step_fn(p_r, g, st_r)
        assert tree_allclose(p_k, p_r), f"divergence at step {i}"


def test_merge_restores_consensus():
    pp = make_problem(n_pod=4)
    opt = KStepAdam(KStepConfig(lr=0.05, k=3), n_pod=4)
    state = opt.init(pp)
    p = pp
    for i in range(1, 7):
        g = jax.tree.map(
            lambda x: jnp.arange(4.0).reshape((4,) + (1,) * (x.ndim - 1)) * jnp.ones_like(x),
            pp,
        )
        p, state = opt.step(p, g, state)
        err = float(pod_consensus_error(p))
        if i % 3 == 0:
            assert err < 1e-10, f"step {i}: consensus error {err} after merge"
        else:
            assert err > 1e-8, f"step {i}: replicas should diverge locally"


def test_v_hat_is_averaged_at_merge():
    """Algorithm 2 line 12: the shared denominator becomes mean_i v_local."""
    pp = make_problem(n_pod=2)
    opt = KStepAdam(KStepConfig(lr=0.01, k=2), n_pod=2)
    state = opt.init(pp)
    p = pp
    g1 = jax.tree.map(lambda x: jnp.ones_like(x) * jnp.array([1.0, 3.0]).reshape((2,) + (1,) * (x.ndim - 1)), pp)
    p, state = opt.step(p, g1, state)            # local
    v_loc = jax.tree.leaves(state.v_local)[0]
    p, state = opt.step(p, g1, state)            # merge at t=2
    v_hat = jax.tree.leaves(state.v_hat)[0]
    v_loc2 = jax.tree.leaves(state.v_local)[0]
    expect = np.mean(np.asarray(v_loc2), axis=0)
    assert np.allclose(np.asarray(v_hat)[0], expect, atol=1e-7)
    assert np.allclose(np.asarray(v_hat)[1], expect, atol=1e-7)


def test_static_vs_dynamic_merge_identical():
    pp = make_problem(n_pod=3)
    cfg = KStepConfig(lr=0.02, k=2, b1=0.5)
    o1, o2 = KStepAdam(cfg, 3), KStepAdam(cfg, 3)
    s1, s2 = o1.init(pp), o2.init(pp)
    p1 = p2 = pp
    for i in range(4):
        g = grads_like(pp, i)
        p1, s1 = o1.step(p1, g, s1)                       # lax.cond path
        p2, s2 = o2.step(p2, g, s2, merge=((i + 1) % 2 == 0))  # static path
        assert tree_allclose(p1, p2)


def test_identical_workers_match_single_worker():
    """If all pods see the same gradients, k-step == single-worker Adam."""
    p1 = make_problem(n_pod=1)
    p4 = make_problem(n_pod=4)
    cfg = KStepConfig(lr=0.01, k=3, b1=0.0)
    o1, o4 = KStepAdam(cfg, 1), KStepAdam(cfg, 4)
    s1, s4 = o1.init(p1), o4.init(p4)
    for i in range(6):
        g1 = grads_like(p1, i)
        g4 = jax.tree.map(lambda x: jnp.broadcast_to(x[0:1], (4,) + x.shape[1:]) + 0.0,
                          pod_replicate(jax.tree.map(lambda y: y[0], g1), 4))
        g4 = jax.tree.map(lambda x: jnp.concatenate([x[:1]] * 4), g4)
        g1_ = g1
        p1, s1 = o1.step(p1, g1_, s1)
        g4 = jax.tree.map(lambda a, b: jnp.broadcast_to(a, b.shape) + jnp.zeros_like(b),
                          g1, p4)
        p4, s4 = o4.step(p4, g4, s4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        assert np.allclose(a[0], b[0], atol=1e-6)
        assert np.allclose(b[0], b[3], atol=1e-6)


def rosenbrock_like(x):
    return jnp.sum((x[..., 1:] - x[..., :-1] ** 2) ** 2 + (1 - x[..., :-1]) ** 2)


@pytest.mark.parametrize("k", [1, 5, 20])
def test_kstep_converges_nonconvex(k):
    """Convergence on a non-convex problem for several k (Theorem 1 regime)."""
    n_pod = 4
    x0 = pod_replicate({"x": jnp.zeros(8)}, n_pod)
    opt = KStepAdam(KStepConfig(lr=0.05, k=k, b1=0.9), n_pod=n_pod)
    state = opt.init(x0)
    p = x0
    key = jax.random.key(0)
    T = 400

    def pod_loss(px, noise):
        return rosenbrock_like(px["x"] + noise)

    @jax.jit
    def step(p, state, key):
        key, sub = jax.random.split(key)
        noise = jax.random.normal(sub, (n_pod, 8)) * 0.05
        g = jax.grad(
            lambda pp: jnp.sum(jax.vmap(lambda px, nz: pod_loss(px, nz))(pp, noise))
        )(p)
        p, state = opt.step(p, g, state)
        return p, state, key

    for t in range(T):
        p, state, key = step(p, state, key)
    final = rosenbrock_like(jnp.mean(jax.tree.leaves(p)[0], axis=0))
    assert float(final) < 0.5, f"k={k}: did not converge, f={float(final)}"


@settings(max_examples=20, deadline=None)
@given(
    n_pod=st.integers(1, 5),
    k=st.integers(1, 8),
    steps=st.integers(1, 16),
    b1=st.sampled_from([0.0, 0.9]),
)
def test_property_kstep_invariants(n_pod, k, steps, b1):
    """Properties that must hold for any (n_pod, k, b1, steps):
    - after a merge step: consensus error == 0 and v_hat == mean(v_local);
    - between merges: v_hat unchanged (frozen shared denominator);
    - all states remain finite."""
    pp = make_problem(seed=n_pod * 7 + k, n_pod=n_pod)
    opt = KStepAdam(KStepConfig(lr=0.03, k=k, b1=b1), n_pod=n_pod)
    state = opt.init(pp)
    p = pp
    prev_vhat = state.v_hat
    for i in range(1, steps + 1):
        g = grads_like(pp, seed=100 + i)
        p, state = opt.step(p, g, state)
        is_merge = i % k == 0
        if is_merge:
            assert float(pod_consensus_error(p)) < 1e-9
            for vh, vl in zip(jax.tree.leaves(state.v_hat), jax.tree.leaves(state.v_local)):
                mean_vl = np.mean(np.asarray(vl), axis=0)
                for pod in range(n_pod):
                    assert np.allclose(np.asarray(vh)[pod], mean_vl, rtol=1e-5)
        else:
            assert tree_allclose(state.v_hat, prev_vhat)
        prev_vhat = state.v_hat
        for leaf in jax.tree.leaves(p) + jax.tree.leaves(state.m):
            assert np.all(np.isfinite(leaf))


def test_grad_clip():
    pp = make_problem(n_pod=2)
    opt = KStepAdam(KStepConfig(lr=0.1, k=1, grad_clip=0.5), n_pod=2)
    state = opt.init(pp)
    g = jax.tree.map(lambda x: jnp.ones_like(x) * 100.0, pp)
    p1, _ = opt.step(pp, g, state)
    # with clipping, the effective |g| per pod is <= 0.5 -> bounded update
    delta = max(float(jnp.max(jnp.abs(a - b))) for a, b in
                zip(jax.tree.leaves(p1), jax.tree.leaves(pp)))
    assert delta < 10.0


def test_delayed_merge_blend():
    pp = make_problem(n_pod=2)
    snap = pp
    merged = jax.tree.map(lambda x: x * 0.0 + 1.0, pp)
    now = jax.tree.map(lambda x: x + 0.25, pp)
    out = KStepAdam.apply_delayed_merge(now, snap, merged)
    for leaf in jax.tree.leaves(out):
        assert np.allclose(leaf, 1.25, atol=1e-6)
