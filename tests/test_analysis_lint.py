"""Layer-1 lint: each rule catches its seeded fixture violation at the exact
file:line, stays silent on the clean fixture, and the baseline + CLI gate
behave (new findings fail, baselined findings pass, stale entries report)."""
import json
from pathlib import Path

import pytest

from repro.analysis.baseline import FILL_ME, Baseline
from repro.analysis.lint import Finding, Project, run_lint, summarize

FIXTURES = Path(__file__).parent / "fixtures" / "analysis_cases"


@pytest.fixture(scope="module")
def findings():
    return run_lint(Project(FIXTURES))


def marked_lines(rel: str, rule: str):
    """1-based lines carrying a ``# LINT: <rule>`` marker in a fixture."""
    text = (FIXTURES / rel).read_text()
    return sorted(
        i for i, line in enumerate(text.splitlines(), 1)
        if f"LINT: {rule}" in line
    )


def lines_for(findings, rel: str, rule: str):
    return sorted(f.line for f in findings if f.path == rel and f.rule == rule)


# ------------------------------------------------------- rule-by-rule exact
@pytest.mark.parametrize("rel,rule", [
    ("viol_host_sync.py", "host-sync-in-jit"),
    ("viol_dead_knob.py", "dead-config-knob"),
    ("viol_nondet.py", "nondeterminism-in-trace"),
    ("hot/runtime/trainer.py", "undonated-hot-jit"),
    ("viol_unguarded_state.py", "unguarded-shared-state"),
    ("viol_blocking_io_lock.py", "blocking-io-under-lock"),
    ("viol_lock_order.py", "lock-order-inversion"),
    ("viol_unjoined.py", "unjoined-worker"),
    ("viol_daemon_death.py", "silent-daemon-death"),
])
def test_rule_catches_exact_lines(findings, rel, rule):
    expected = marked_lines(rel, rule)
    assert expected, f"fixture {rel} lost its LINT markers"
    assert lines_for(findings, rel, rule) == expected


def test_host_sync_details_and_symbols(findings):
    by_detail = {
        f.detail: f for f in findings
        if f.path == "viol_host_sync.py" and f.rule == "host-sync-in-jit"
    }
    assert set(by_detail) == {
        "float()", ".item()", "numpy.asarray", "jax.device_get",
        ".block_until_ready()",
    }
    # symbol is the qualname of the traced function owning the call
    assert by_detail["float()"].symbol == "decorated_step"
    assert by_detail[".item()"].symbol == "_make_step.<locals>.step"
    assert by_detail["jax.device_get"].symbol == "helper"


def test_host_side_float_not_flagged(findings):
    # float()/device_get OUTSIDE traces (logging boundaries) must not fire
    assert not [
        f for f in findings
        if f.path == "viol_host_sync.py" and f.symbol == "host_side_is_fine"
    ]


def test_dead_knob_names_field(findings):
    (f,) = [f for f in findings if f.rule == "dead-config-knob"]
    assert f.symbol == "WidgetConfig.dead_knob"
    assert f.detail == "dead_knob"
    # used/fetched knobs are read (attribute load / getattr) -> not flagged;
    # the constructor keyword in construct_only() is a write, not a read


def test_nondet_details(findings):
    details = {
        f.detail for f in findings
        if f.path == "viol_nondet.py" and f.rule == "nondeterminism-in-trace"
    }
    assert details == {"time.time", "numpy.random.normal", "random.random"}


def test_donation_rule_scoped_to_hot_modules(findings):
    hot = [f for f in findings if f.rule == "undonated-hot-jit"]
    # both undonated jits in the hot fixture, nothing elsewhere (clean.py's
    # jit lives outside the hot-module globs)
    assert {f.path for f in hot} == {"hot/runtime/trainer.py"}
    assert sorted(f.detail for f in hot) == ["jit(<lambda>)", "jit(fn)"]


def test_clean_fixture_no_false_positives(findings):
    assert not [f for f in findings if f.path == "clean.py"]


# -------------------------------------------------- concurrency rules (R5-R9)
def test_unguarded_state_names_both_domains(findings):
    (f,) = [f for f in findings if f.rule == "unguarded-shared-state"]
    assert f.detail == "Meter.count"
    assert f.symbol == "Meter._run"          # anchored at the worker write
    assert "Meter.value" in f.message        # ...citing the main-thread side
    # GuardedMeter (same shape, locked both sides) stays silent — asserted
    # by the exact-line parametrize above


def test_blocking_io_details(findings):
    by_line = {
        f.line: f.detail for f in findings
        if f.rule == "blocking-io-under-lock"
    }
    # direct IO under a lexical lock, IO inside a helper that is lock-held
    # by call-site fixpoint, and the lock-held call to that helper
    assert sorted(by_line.values()) == [
        "_persist()", "json.dump", "json.dump", "open", "open",
    ]


def test_lock_order_reports_both_orders(findings):
    inv = [f for f in findings if f.rule == "lock-order-inversion"]
    details = sorted(f.detail for f in inv)
    a, b = "viol_lock_order._lock_a", "viol_lock_order._lock_b"
    # a->b witnessed twice (nested with + call transitivity), b->a once
    assert details == [f"{a} -> {b}", f"{a} -> {b}", f"{b} -> {a}"]
    # every message points at a witness of the opposite order
    assert all("opposite order is taken at viol_lock_order.py:" in f.message
               for f in inv)


def test_unjoined_worker_labels(findings):
    uj = {f.detail for f in findings if f.rule == "unjoined-worker"}
    assert uj == {"FireAndForget._run", "AnonStart._run"}
    # Joined (sentinel + join at close) stays silent


def test_silent_daemon_death_target(findings):
    (f,) = [f for f in findings if f.rule == "silent-daemon-death"]
    assert f.detail == "SilentWorker._run"
    assert f.symbol == "SilentWorker._run"
    # LoudWorker's guarded except-capture + check() re-raise stays silent


def test_summarize_counts(findings):
    s = summarize(findings)
    assert s["host-sync-in-jit"] == 5
    assert s["dead-config-knob"] == 1
    assert s["nondeterminism-in-trace"] == 3
    assert s["undonated-hot-jit"] == 2
    assert s["unguarded-shared-state"] == 1
    assert s["blocking-io-under-lock"] == 5
    assert s["lock-order-inversion"] == 3
    assert s["unjoined-worker"] == 2
    assert s["silent-daemon-death"] == 1


# ------------------------------------------------------------------ baseline
def _finding(rule="r", path="p.py", line=3, symbol="s", detail="d"):
    return Finding(rule=rule, path=path, line=line, symbol=symbol,
                   detail=detail, message="m")


def test_baseline_split_and_line_drift(tmp_path):
    bl = Baseline.load(tmp_path / "b.json")
    bl.update([_finding(line=3)])
    # same key at a DIFFERENT line still matches (keys carry no line)
    new, old, stale = bl.split([_finding(line=99)])
    assert not new and len(old) == 1 and not stale


def test_baseline_new_and_stale(tmp_path):
    bl = Baseline.load(tmp_path / "b.json")
    bl.update([_finding(detail="old")])
    new, old, stale = bl.split([_finding(detail="fresh")])
    assert [f.detail for f in new] == ["fresh"]
    assert not old
    assert stale == [("r", "p.py", "s", "old")]


def test_baseline_update_preserves_justifications(tmp_path):
    path = tmp_path / "b.json"
    bl = Baseline.load(path)
    assert bl.update([_finding()]) == 1          # one justification missing
    data = json.loads(path.read_text())
    data["entries"][0]["justification"] = "accepted: frozen hot loop"
    path.write_text(json.dumps(data))
    bl = Baseline.load(path)
    assert bl.update([_finding(), _finding(detail="d2")]) == 1
    kept = {e["detail"]: e["justification"]
            for e in json.loads(path.read_text())["entries"]}
    assert kept["d"] == "accepted: frozen hot loop"
    assert kept["d2"] == FILL_ME


# ----------------------------------------------------------------- CLI gate
def test_cli_gate_fail_then_baseline_then_pass(tmp_path, capsys):
    from repro.analysis.__main__ import main

    bl = tmp_path / "baseline.json"
    argv = ["--lint", "--src", str(FIXTURES), "--baseline", str(bl), "-q"]
    assert main(argv) == 1                       # unbaselined findings fail
    assert "FAIL" in capsys.readouterr().out
    assert main(argv + ["--update-baseline"]) == 0
    assert bl.exists()
    assert main(argv) == 0                       # fully baselined passes
    assert "all baselined" in capsys.readouterr().out


def test_cli_report_artifact(tmp_path):
    from repro.analysis.__main__ import main

    bl = tmp_path / "baseline.json"
    rep = tmp_path / "report.json"
    main(["--lint", "--src", str(FIXTURES), "--baseline", str(bl),
          "--report", str(rep), "-q"])
    data = json.loads(rep.read_text())
    assert data["new"] and not data["baselined"]
    assert {f["rule"] for f in data["new"]} == {
        "host-sync-in-jit", "dead-config-knob", "nondeterminism-in-trace",
        "undonated-hot-jit", "unguarded-shared-state",
        "blocking-io-under-lock", "lock-order-inversion", "unjoined-worker",
        "silent-daemon-death",
    }
    assert data["sched_checks"] == []        # lint-only run: key still there


def test_cli_github_format(tmp_path, capsys):
    from repro.analysis.__main__ import main

    bl = tmp_path / "baseline.json"
    assert main(["--lint", "--src", str(FIXTURES), "--baseline", str(bl),
                 "--format", "github", "-q"]) == 1
    out = capsys.readouterr().out
    lines = [ln for ln in out.splitlines() if ln.startswith("::error ")]
    assert lines, "github format must emit ::error workflow commands"
    # one annotation per finding, anchored at the marked violation line
    (anno,) = [ln for ln in lines if "title=silent-daemon-death" in ln]
    (exp_line,) = marked_lines("viol_daemon_death.py", "silent-daemon-death")
    assert f"file=viol_daemon_death.py,line={exp_line}," in anno
    assert "FAIL" not in out                 # text format is replaced


def test_cli_strict_baseline_fails_on_stale(tmp_path, capsys):
    from repro.analysis.__main__ import main

    bl = tmp_path / "baseline.json"
    argv = ["--lint", "--src", str(FIXTURES), "--baseline", str(bl), "-q"]
    assert main(argv + ["--update-baseline"]) == 0
    # plant a stale entry: it matches nothing in the fixtures
    data = json.loads(bl.read_text())
    data["entries"].append({"rule": "host-sync-in-jit", "file": "gone.py",
                            "symbol": "s", "detail": "float()",
                            "justification": "stale"})
    bl.write_text(json.dumps(data))
    capsys.readouterr()
    assert main(argv) == 0                   # default: stale only warns
    assert main(argv + ["--strict-baseline"]) == 1
    assert "stale baseline" in capsys.readouterr().out


def test_repo_src_is_lint_clean():
    """The gate the CI job enforces: the real source tree has no findings
    (everything previously flagged was fixed, not baselined)."""
    import repro

    src = Path(repro.__file__).resolve().parent
    assert run_lint(Project(src)) == []
