"""Per-architecture smoke tests: every assigned arch instantiates its
REDUCED config and runs one forward/train step on CPU — output shapes and
finiteness asserted.  (Full configs are exercised only via the dry-run.)"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import gin as G
from repro.models import recsys as R
from repro.models import transformer as T

LM_ARCHS = ["qwen3-14b", "qwen2-7b", "granite-8b", "mixtral-8x7b",
            "llama4-scout-17b-16e"]


@pytest.mark.parametrize("name", LM_ARCHS)
def test_lm_smoke_train_step(name):
    spec = configs.get(name)
    cfg = spec.smoke_cfg
    p = T.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    loss, grads = jax.value_and_grad(T.loss_fn)(p, batch, cfg)
    assert np.isfinite(float(loss))
    for leaf in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(leaf))), name
    logits, _ = T.forward(p, toks, cfg)
    assert logits.shape == (4, 32, cfg.vocab)


@pytest.mark.parametrize("name", LM_ARCHS)
def test_lm_smoke_decode_step(name):
    spec = configs.get(name)
    cfg = spec.smoke_cfg
    p = T.init_params(jax.random.key(0), cfg)
    cache = T.init_cache(cfg, 2, 16)
    tok = jnp.asarray([1, 2], jnp.int32)
    for _ in range(3):
        logits, cache = T.decode_step(p, cache, tok, cfg)
    assert logits.shape == (2, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())


def test_gin_smoke_all_shapes():
    spec = configs.get("gin-tu")
    base = spec.smoke_cfg
    rng = np.random.default_rng(0)
    # node-classification regime
    cfg = dataclasses.replace(base, d_in=8, n_classes=3)
    params = G.init_params(jax.random.key(0), cfg)
    batch = {
        "x": jnp.asarray(rng.standard_normal((32, 8)), jnp.float32),
        "edge_src": jnp.asarray(rng.integers(0, 32, 64), jnp.int32),
        "edge_dst": jnp.asarray(rng.integers(0, 32, 64), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 3, 32), jnp.int32),
    }
    loss, grads = jax.value_and_grad(G.loss_fn)(params, batch, cfg)
    assert np.isfinite(float(loss))
    # graph-readout (molecule) regime
    cfgm = dataclasses.replace(base, d_in=8, n_classes=2, readout="graph")
    pm = G.init_params(jax.random.key(1), cfgm)
    bm = {
        "x": jnp.asarray(rng.standard_normal((20, 8)), jnp.float32),
        "edge_src": jnp.asarray(rng.integers(0, 20, 30), jnp.int32),
        "edge_dst": jnp.asarray(rng.integers(0, 20, 30), jnp.int32),
        "graph_ids": jnp.asarray(np.repeat(np.arange(4), 5), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 2, 4), jnp.int32),
    }
    lm = G.loss_fn(pm, bm, cfgm)
    assert np.isfinite(float(lm))


def test_dlrm_smoke():
    spec = configs.get("dlrm-mlperf")
    cfg = spec.smoke_cfg
    rng = np.random.default_rng(0)
    dense = R.dlrm_init_dense(jax.random.key(0), cfg)
    tables = {f"emb_{i:02d}": jnp.asarray(
        rng.standard_normal((cfg.rows[i], cfg.embed_dim)) * 0.1, jnp.float32)
        for i in range(cfg.n_sparse)}
    batch = {
        "dense": jnp.asarray(rng.standard_normal((8, cfg.n_dense)), jnp.float32),
        "sparse_ids": jnp.asarray(rng.integers(0, 200, (8, 26)), jnp.int32),
        "label": jnp.ones(8, jnp.float32),
    }
    emb = R.dlrm_embed_batch(tables, batch, cfg)
    logits = R.dlrm_forward_from_emb(dense, emb, batch, cfg)
    assert logits.shape == (8,)
    assert np.all(np.isfinite(np.asarray(logits)))


@pytest.mark.parametrize("name", ["din", "dien"])
def test_din_dien_smoke(name):
    spec = configs.get(name)
    cfg = spec.smoke_cfg
    rng = np.random.default_rng(0)
    dense = R.din_init_dense(jax.random.key(0), cfg)
    tables = {"items": jnp.asarray(
        rng.standard_normal((cfg.item_vocab, cfg.embed_dim)) * 0.1, jnp.float32)}
    batch = {
        "hist_ids": jnp.asarray(rng.integers(0, cfg.item_vocab, (8, cfg.seq_len)), jnp.int32),
        "hist_mask": jnp.ones((8, cfg.seq_len), jnp.float32),
        "target_id": jnp.asarray(rng.integers(0, cfg.item_vocab, 8), jnp.int32),
        "label": jnp.ones(8, jnp.float32),
    }
    emb = R.din_embed_batch(tables, batch, cfg)
    logits = R.din_forward_from_emb(dense, emb, batch, cfg)
    assert logits.shape == (8,)
    assert np.all(np.isfinite(np.asarray(logits)))
    if name == "dien":
        assert cfg.gru_dim > 0


def test_two_tower_smoke():
    spec = configs.get("two-tower-retrieval")
    cfg = spec.smoke_cfg
    rng = np.random.default_rng(0)
    dense = R.two_tower_init_dense(jax.random.key(0), cfg)
    tables = {"items": jnp.asarray(
        rng.standard_normal((cfg.item_vocab, cfg.embed_dim)) * 0.1, jnp.float32)}
    batch = {
        "user_ids": jnp.asarray(rng.integers(0, cfg.item_vocab, (8, cfg.user_hist_len)), jnp.int32),
        "user_mask": jnp.ones((8, cfg.user_hist_len), jnp.float32),
        "item_id": jnp.asarray(rng.integers(0, cfg.item_vocab, 8), jnp.int32),
    }
    emb = R.two_tower_embed_batch(tables, batch, cfg)
    loss = R.two_tower_loss(dense, emb, batch, cfg)
    assert np.isfinite(float(loss))
    scores = R.two_tower_score_candidates(dense, tables, emb["user"][:1],
                                          jnp.arange(64), cfg)
    assert scores.shape == (1, 64)


def test_baidu_ctr_smoke():
    spec = configs.get("baidu-ctr")
    cfg = spec.smoke_cfg
    rng = np.random.default_rng(0)
    dense = R.ctr_init_dense(jax.random.key(0), cfg)
    tables = {"sparse": jnp.asarray(
        rng.standard_normal((cfg.rows, cfg.embed_dim)) * 0.1, jnp.float32)}
    batch = {
        "ids": jnp.asarray(rng.integers(0, cfg.rows, (8, cfg.nnz_per_instance)), jnp.int32),
        "field_ids": jnp.asarray(rng.integers(0, cfg.n_fields, (8, cfg.nnz_per_instance)), jnp.int32),
        "mask": jnp.ones((8, cfg.nnz_per_instance), jnp.float32),
        "label": jnp.ones(8, jnp.float32),
    }
    emb = R.ctr_embed_batch(tables, batch, cfg)
    logits = R.ctr_forward_from_emb(dense, emb, batch, cfg)
    assert logits.shape == (8,)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_registry_complete():
    names = configs.list_archs()
    assert len(names) == 11  # 10 assigned + the paper's own arch
    total_cells = 0
    for n in names:
        spec = configs.get(n)
        assert spec.shapes, n
        if n != "baidu-ctr":
            total_cells += len(spec.shapes)
    assert total_cells == 40  # the assigned 40 (arch x shape) cells
