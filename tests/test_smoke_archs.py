"""Per-architecture smoke tests: every assigned arch instantiates its
REDUCED config and trains on CPU — lm/gnn via model-level steps, every
recsys arch through ``build_trainer`` (the factory is the only supported
recsys training path: fit under all three placements, fit-parity against a
hand-rolled full-table driver, and gather-vs-cached bit-identity at a
full-size cache).  Full configs are exercised only via the dry-run."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.kstep import KStepAdam, KStepConfig, pod_replicate
from repro.core.sparse_optim import SparseAdagrad, SparseAdagradConfig
from repro.data import synthetic as S
from repro.models import gin as G
from repro.models import recsys as R
from repro.models import transformer as T
from repro.runtime.factory import build_trainer
from repro.runtime.trainer import TrainerConfig

LM_ARCHS = ["qwen3-14b", "qwen2-7b", "granite-8b", "mixtral-8x7b",
            "llama4-scout-17b-16e"]
RECSYS_ARCHS = ["dlrm-mlperf", "din", "dien", "two-tower-retrieval",
                "baidu-ctr"]


@pytest.mark.parametrize("name", LM_ARCHS)
def test_lm_smoke_train_step(name):
    spec = configs.get(name)
    cfg = spec.smoke_cfg
    p = T.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    loss, grads = jax.value_and_grad(T.loss_fn)(p, batch, cfg)
    assert np.isfinite(float(loss))
    for leaf in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(leaf))), name
    logits, _ = T.forward(p, toks, cfg)
    assert logits.shape == (4, 32, cfg.vocab)


@pytest.mark.parametrize("name", LM_ARCHS)
def test_lm_smoke_decode_step(name):
    spec = configs.get(name)
    cfg = spec.smoke_cfg
    p = T.init_params(jax.random.key(0), cfg)
    cache = T.init_cache(cfg, 2, 16)
    tok = jnp.asarray([1, 2], jnp.int32)
    for _ in range(3):
        logits, cache = T.decode_step(p, cache, tok, cfg)
    assert logits.shape == (2, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())


def test_gin_smoke_all_shapes():
    spec = configs.get("gin-tu")
    base = spec.smoke_cfg
    rng = np.random.default_rng(0)
    # node-classification regime
    cfg = dataclasses.replace(base, d_in=8, n_classes=3)
    params = G.init_params(jax.random.key(0), cfg)
    batch = {
        "x": jnp.asarray(rng.standard_normal((32, 8)), jnp.float32),
        "edge_src": jnp.asarray(rng.integers(0, 32, 64), jnp.int32),
        "edge_dst": jnp.asarray(rng.integers(0, 32, 64), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 3, 32), jnp.int32),
    }
    loss, grads = jax.value_and_grad(G.loss_fn)(params, batch, cfg)
    assert np.isfinite(float(loss))
    # graph-readout (molecule) regime
    cfgm = dataclasses.replace(base, d_in=8, n_classes=2, readout="graph")
    pm = G.init_params(jax.random.key(1), cfgm)
    bm = {
        "x": jnp.asarray(rng.standard_normal((20, 8)), jnp.float32),
        "edge_src": jnp.asarray(rng.integers(0, 20, 30), jnp.int32),
        "edge_dst": jnp.asarray(rng.integers(0, 20, 30), jnp.int32),
        "graph_ids": jnp.asarray(np.repeat(np.arange(4), 5), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 2, 4), jnp.int32),
    }
    lm = G.loss_fn(pm, bm, cfgm)
    assert np.isfinite(float(lm))


# ------------------------------------------------------------ recsys family
# Every recsys arch trains through the factory — the smoke tests ride the
# same ``build_trainer`` path the launcher, examples, and CI use.

def _recsys_tcfg(placement, prefetch=False, n_pod=1, k=1, cache_rows=None,
                 log_every=1, capacity=None):
    return TrainerConfig(
        n_pod=n_pod, kstep=KStepConfig(lr=1e-3, k=k, b1=0.0),
        sparse=SparseAdagradConfig(lr=0.1, initial_accumulator=0.01),
        placement=placement, capacity=capacity, cache_rows=cache_rows,
        prefetch=prefetch, log_every=log_every,
    )


def _recsys_batches(arch, n, batch=64, seed=3):
    gen = S.recsys_batches(configs.get(arch).smoke_cfg, batch=batch, seed=seed)
    return [next(gen) for _ in range(n)]


def _full_mirror_cache_rows(tr) -> int:
    """cache_rows covering every table AND the pull capacity — the cache
    degenerates to a full mirror (bit-identical to gather)."""
    max_rows = max(s.rows for s in tr.engine.specs.values())
    return max(max_rows, tr.engine.capacity)


def _logical_state(tr):
    """(tables, accum) in logical row layout, flushed + exported — the
    placement-independent view used for cross-placement parity."""
    tables, accum, _ = tr.engine.flush(
        tr.tables, tr.sparse_state.accum, tr.backend_state
    )
    return (
        {n: np.asarray(v) for n, v in tr.engine.export(tables).items()},
        {n: np.asarray(v) for n, v in accum.items()},
    )


@pytest.mark.parametrize("placement", ["gather", "routed", "cached"])
@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_factory_fit_all_placements(arch, placement):
    """Acceptance: ``build_trainer(arch, cfg).fit(...)`` runs for every
    recsys arch under every placement (prefetch on for the non-gather
    placements, so the placement x prefetch grid is covered across the
    matrix), and online ``predict`` serves scores."""
    prefetch = placement != "gather"
    tr = build_trainer(arch, _recsys_tcfg(placement, prefetch=prefetch,
                                          n_pod=2, k=2, log_every=2))
    batches = _recsys_batches(arch, 4)
    hist = tr.fit(iter(batches), 4)
    assert tr.step_num == 4 and len(hist) == 2
    assert all(np.isfinite(r["loss"]) for r in hist)
    assert tr.overflow_dropped == 0, (arch, placement)
    scores = tr.predict(batches[0])
    assert scores.shape == (64,)
    assert np.all(np.isfinite(scores))


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_undersized_capacity_degrades_gracefully(arch):
    """Capacity overflow is counted, never NaN: dropped ids read the zero
    drop row, whose gradient is discarded at push — for every arch
    (two-tower's L2-normalize used to NaN-poison the push through
    ``jnp.linalg.norm``'s 0/0 gradient at the zero row)."""
    # capacity 32 << the per-batch distinct ids of every arch (including
    # DLRM's per-table single-hot draws at batch 128)
    tr = build_trainer(arch, _recsys_tcfg("gather", capacity=32))
    hist = tr.fit(iter(_recsys_batches(arch, 4, batch=128)), 4)
    assert tr.overflow_dropped > 0, arch
    assert all(np.isfinite(r["loss"]) for r in hist), (arch, hist)
    for leaf in jax.tree.leaves((tr.tables, tr.sparse_state.accum, tr.dense)):
        assert np.all(np.isfinite(np.asarray(leaf))), arch


def test_fit_online_stops_on_exhausted_stream():
    """The shared online loop ends cleanly when a finite stream runs out
    before ``steps`` — final history record and checkpoint flush included
    (the hand-rolled loops it replaced also terminated gracefully)."""
    from repro.runtime.online import fit_online

    tr = build_trainer("din", _recsys_tcfg("gather", log_every=10))
    hist, online_auc = fit_online(tr, iter(_recsys_batches("din", 3)), 10)
    assert tr.step_num == 3
    assert hist and hist[-1]["step"] == 3
    assert online_auc is not None


# hand-rolled full-table drivers (what the example drivers used to do):
# dense-side grads through ``*_embed_batch`` on the WHOLE table + dense
# AdaGrad — the oracle the factory's pull/push path must reproduce.
_HANDROLLED = {
    "dlrm-mlperf": (R.dlrm_init_dense, R.dlrm_embed_batch, R.dlrm_hybrid_loss),
    "din": (R.din_init_dense, R.din_embed_batch, R.din_hybrid_loss),
    "dien": (R.din_init_dense, R.din_embed_batch, R.din_hybrid_loss),
    "two-tower-retrieval": (R.two_tower_init_dense, R.two_tower_embed_batch,
                            R.two_tower_hybrid_loss),
    "baidu-ctr": (R.ctr_init_dense, R.ctr_embed_batch, R.ctr_hybrid_loss),
}


@pytest.mark.parametrize("arch", sorted(_HANDROLLED))
def test_recsys_factory_fit_parity_with_handrolled(arch):
    """The factory's working-set path must train exactly like a hand-rolled
    full-table driver: same dense k-step Adam, same AdaGrad arithmetic —
    the only difference is pull/push vs whole-table gradients."""
    mcfg = configs.get(arch).smoke_cfg
    batches = _recsys_batches(arch, 3)
    tcfg = _recsys_tcfg("gather")
    tr = build_trainer(arch, tcfg)
    tables0 = {n: np.array(v) for n, v in tr.engine.export(tr.tables).items()}
    hist = tr.fit(iter(batches), 3)
    factory_losses = [r["loss"] for r in hist]

    init_dense, embed_batch, loss_of = _HANDROLLED[arch]
    loss_ad = loss_of(mcfg)
    dense = init_dense(jax.random.key(0), mcfg)   # factory's seed=0 default
    dense_p = pod_replicate(dense, 1)
    opt = KStepAdam(tcfg.kstep, 1)
    opt_state = opt.init(dense_p)
    sa = SparseAdagrad(tcfg.sparse)
    tables = {n: jnp.asarray(v) for n, v in tables0.items()}
    accum = {n: jnp.full(v.shape, tcfg.sparse.initial_accumulator, jnp.float32)
             for n, v in tables.items()}
    ref_losses = []
    for step, b in enumerate(batches, start=1):
        b = jax.tree.map(jnp.asarray, b)

        def lf(dp, tbs):
            return loss_ad(dp, embed_batch(tbs, b, mcfg), b)

        loss, (dg, tg) = jax.value_and_grad(lf, argnums=(0, 1))(dense, tables)
        dense_p, opt_state = opt.step(
            dense_p, jax.tree.map(lambda g: g[None], dg), opt_state,
            merge=(step % tcfg.kstep.k == 0),
        )
        dense = jax.tree.map(lambda x: x[0], dense_p)
        for n in tables:
            tables[n], accum[n] = sa.dense_reference(tables[n], accum[n], tg[n])
        ref_losses.append(float(loss))

    np.testing.assert_allclose(factory_losses, ref_losses, rtol=1e-5, atol=1e-6)
    final_tables, final_accum = _logical_state(tr)
    # rtol absorbs summation-order noise on hot rows (autodiff's duplicate
    # reduction vs the push's scatter-add accumulate in different orders)
    for n in final_tables:
        np.testing.assert_allclose(final_tables[n], np.asarray(tables[n]),
                                   rtol=1e-4, atol=1e-5, err_msg=n)
        np.testing.assert_allclose(final_accum[n], np.asarray(accum[n]),
                                   rtol=1e-4, atol=1e-5, err_msg=n)
    for a, b_ in zip(jax.tree.leaves(tr.dense), jax.tree.leaves(dense_p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-6)


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_factory_placement_parity(arch):
    """gather vs cached BIT-identity at ``cache_rows >= rows`` (the cache
    degenerates to a full mirror; same AdaGrad arithmetic by construction),
    with and without prefetch.  routed (one shard on this container) runs
    the same math through the shard-local fused push, which reorders the
    update arithmetic — identical to ULP level, asserted via allclose."""
    batches = _recsys_batches(arch, 6)
    tr_g = build_trainer(arch, _recsys_tcfg("gather", n_pod=2, k=2))
    hist_g = tr_g.fit(iter(batches), 6)
    losses_g = [r["loss"] for r in hist_g]
    tables_g, accum_g = _logical_state(tr_g)
    mirror = _full_mirror_cache_rows(tr_g)

    variants = [("cached", False), ("cached", True), ("routed", False)]
    for placement, prefetch in variants:
        cache_rows = mirror if placement == "cached" else None
        tr = build_trainer(arch, _recsys_tcfg(
            placement, prefetch=prefetch, n_pod=2, k=2, cache_rows=cache_rows
        ))
        hist = tr.fit(iter(batches), 6)
        losses = [r["loss"] for r in hist]
        tables_p, accum_p = _logical_state(tr)
        tag = f"{arch}/{placement}/prefetch={prefetch}"
        if placement == "cached":
            assert losses == losses_g, tag
            for n in tables_g:
                np.testing.assert_array_equal(tables_g[n], tables_p[n],
                                              err_msg=f"{tag}/{n}")
                np.testing.assert_array_equal(accum_g[n], accum_p[n],
                                              err_msg=f"{tag}/{n}")
            for a, b_ in zip(jax.tree.leaves(tr_g.dense),
                             jax.tree.leaves(tr.dense)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))
        else:
            np.testing.assert_allclose(losses, losses_g, rtol=1e-5,
                                       atol=1e-6, err_msg=tag)
            for n in tables_g:
                np.testing.assert_allclose(tables_g[n], tables_p[n],
                                           rtol=1e-4, atol=1e-6,
                                           err_msg=f"{tag}/{n}")
                np.testing.assert_allclose(accum_g[n], accum_p[n],
                                           rtol=1e-4, atol=1e-6,
                                           err_msg=f"{tag}/{n}")
            for a, b_ in zip(jax.tree.leaves(tr_g.dense),
                             jax.tree.leaves(tr.dense)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                           rtol=1e-4, atol=1e-6)


def test_registry_complete():
    names = configs.list_archs()
    assert len(names) == 11  # 10 assigned + the paper's own arch
    total_cells = 0
    for n in names:
        spec = configs.get(n)
        assert spec.shapes, n
        if n != "baidu-ctr":
            total_cells += len(spec.shapes)
    assert total_cells == 40  # the assigned 40 (arch x shape) cells
