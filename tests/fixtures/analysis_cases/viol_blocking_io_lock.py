"""Seeded violations for ``blocking-io-under-lock`` (R6).

``flush_bad`` does filesystem IO inside the critical section directly;
``_persist`` does the same transitively (every call site holds the lock,
so the lock-held fixpoint marks it locked); ``flush_helper``'s call to it
is the third witness class.  ``flush_good`` shows the copy-then-write
idiom that must stay silent.
"""
import json
import threading


class Spiller:
    def __init__(self, path):
        self.path = path
        self._lock = threading.Lock()
        self._pages = {}

    def flush_bad(self):
        with self._lock:
            with open(self.path, "w") as f:   # LINT: blocking-io-under-lock
                json.dump(self._pages, f)     # LINT: blocking-io-under-lock

    def _persist(self):
        # only ever called with the lock held -> lock-held by fixpoint
        with open(self.path, "w") as f:       # LINT: blocking-io-under-lock
            json.dump(self._pages, f)         # LINT: blocking-io-under-lock

    def flush_helper(self):
        with self._lock:
            self._persist()                   # LINT: blocking-io-under-lock

    def flush_good(self):
        with self._lock:
            snapshot = dict(self._pages)
        with open(self.path, "w") as f:
            json.dump(snapshot, f)
