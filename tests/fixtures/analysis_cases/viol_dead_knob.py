"""R2 fixture: a *Config dataclass with one field nothing reads."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class WidgetConfig:
    used_knob: int = 1
    fetched_knob: str = "a"
    dead_knob: float = 0.5                # LINT: dead-config-knob
    _private_state: int = 0               # leading underscore: never checked


def consume(cfg: WidgetConfig) -> int:
    # attribute load and literal getattr both count as reads
    return cfg.used_knob + len(getattr(cfg, "fetched_knob"))


def construct_only() -> WidgetConfig:
    # constructor keywords are WRITES — setting dead_knob is not reading it
    return WidgetConfig(dead_knob=2.0)
