"""R4 fixture: hot-path jit call sites (path matches */runtime/trainer.py).

Undonated jits are findings; an explicit ``donate_argnums=(...)`` OR an
explicit ``donate_argnums=()`` (a considered decision to donate nothing)
passes.
"""
import jax


def make_steps(fn):
    undonated = jax.jit(fn)               # LINT: undonated-hot-jit
    lam = jax.jit(lambda x: x + 1)        # LINT: undonated-hot-jit
    donated = jax.jit(fn, donate_argnums=(0,))
    explicit_none = jax.jit(fn, donate_argnums=())
    return undonated, lam, donated, explicit_none
