"""Seeded violation for ``unguarded-shared-state`` (R5).

``Meter.count`` is written by the worker thread and read by the main
thread with no common lock; ``_exc`` (guarded on both sides) and
``GuardedMeter`` (fully guarded) are negative controls.
"""
import queue
import threading


class Meter:
    def __init__(self):
        self.count = 0
        self._exc = None
        self._lock = threading.Lock()
        self._q = queue.Queue()
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._run, daemon=True)
        self._t.start()

    def _run(self):
        try:
            while not self._stop.is_set():
                item = self._q.get()
                if item is None:
                    return
                self.count += 1    # LINT: unguarded-shared-state
        except BaseException as e:
            with self._lock:
                self._exc = e

    def check(self):
        with self._lock:
            exc, self._exc = self._exc, None
        if exc is not None:
            raise exc

    def value(self):
        return self.count          # racy read: the main-domain side

    def close(self):
        self._stop.set()
        self._q.put(None)
        self._t.join()


class GuardedMeter:
    """Negative control: both domains take the same lock."""

    def __init__(self):
        self.count = 0
        self._lock = threading.Lock()
        self._q = queue.Queue()
        self._t = threading.Thread(target=self._run, daemon=True)
        self._t.start()

    def _run(self):
        try:
            while True:
                item = self._q.get()
                if item is None:
                    return
                with self._lock:
                    self.count += 1
        except BaseException as e:
            with self._lock:
                self._exc = e

    def value(self):
        with self._lock:
            return self.count

    def close(self):
        self._q.put(None)
        self._t.join()
