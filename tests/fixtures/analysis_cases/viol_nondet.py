"""R3 fixture: wall clock / host RNG inside a traced function."""
import random
import time

import jax
import numpy as np


@jax.jit
def noisy_step(x):
    t = time.time()                       # LINT: nondeterminism-in-trace
    noise = np.random.normal()            # LINT: nondeterminism-in-trace
    jitter = random.random()              # LINT: nondeterminism-in-trace
    return x * t + noise + jitter


def host_loop(n):
    # NOT traced: host-side timing/RNG is legal
    return [time.time() + random.random() for _ in range(n)]
