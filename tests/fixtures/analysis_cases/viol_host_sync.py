"""R1 fixture: host-synchronizing calls inside traced functions.

Parsed by the lint tests, NEVER imported — the violations are deliberate.
Each offending line carries a marker comment naming the rule; the test
asserts the rule reports exactly the marked lines.
"""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def decorated_step(x):
    y = jnp.sum(x)
    return float(y)                       # LINT: host-sync-in-jit


def _make_step(scale):
    def step(x):
        v = x.item()                      # LINT: host-sync-in-jit
        arr = np.asarray(x)               # LINT: host-sync-in-jit
        return v * scale + arr.sum()

    return step


def helper(x):
    jax.device_get(x)                     # LINT: host-sync-in-jit
    return x.block_until_ready()          # LINT: host-sync-in-jit


step_fn = jax.jit(helper)


def host_side_is_fine(x):
    # NOT traced: float()/device_get at a logging boundary must not fire
    return float(jax.device_get(x))
