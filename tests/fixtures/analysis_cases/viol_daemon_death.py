"""Seeded violation for ``silent-daemon-death`` (R9).

``SilentWorker._run`` can die without anyone noticing; ``LoudWorker``
publishes the exception into guarded instance state for the main thread
to re-raise at the next boundary (the repo-wide idiom).
"""
import queue
import threading


class SilentWorker:
    def __init__(self):
        self._q = queue.Queue()
        self._t = threading.Thread(target=self._run, daemon=True)
        self._t.start()

    def _run(self):               # LINT: silent-daemon-death
        while True:
            item = self._q.get()
            if item is None:
                return
            item()

    def close(self):
        self._q.put(None)
        self._t.join()


class LoudWorker:
    """Negative control: failures cross back to the main thread."""

    def __init__(self):
        self._q = queue.Queue()
        self._lock = threading.Lock()
        self._exc = None
        self._t = threading.Thread(target=self._run, daemon=True)
        self._t.start()

    def _run(self):
        try:
            while True:
                item = self._q.get()
                if item is None:
                    return
                item()
        except BaseException as e:
            with self._lock:
                self._exc = e

    def check(self):
        with self._lock:
            exc, self._exc = self._exc, None
        if exc is not None:
            raise exc

    def close(self):
        self._q.put(None)
        self._t.join()
