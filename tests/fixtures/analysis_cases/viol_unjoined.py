"""Seeded violations for ``unjoined-worker`` (R8).

``FireAndForget`` starts a bound worker no code ever joins; ``AnonStart``
chains ``.start()`` on an anonymous Thread nothing can ever join.
``Joined`` is the negative control (sentinel + join at close).
"""
import queue
import threading


class FireAndForget:
    def __init__(self):
        self._q = queue.Queue()
        self._exc = None
        self._t = threading.Thread(target=self._run, daemon=True)  # LINT: unjoined-worker
        self._t.start()

    def _run(self):
        try:
            while True:
                item = self._q.get()
                if item is None:
                    return
        except BaseException as e:
            self._exc = e

    def close(self):
        self._q.put(None)   # asks the worker to exit, but never joins it


class AnonStart:
    def __init__(self):
        self._q = queue.Queue()
        self._exc = None
        threading.Thread(target=self._run, daemon=True).start()  # LINT: unjoined-worker

    def _run(self):
        try:
            while True:
                item = self._q.get()
                if item is None:
                    return
        except BaseException as e:
            self._exc = e


class Joined:
    """Negative control: shutdown is ordered after the worker's last op."""

    def __init__(self):
        self._q = queue.Queue()
        self._exc = None
        self._t = threading.Thread(target=self._run, daemon=True)
        self._t.start()

    def _run(self):
        try:
            while True:
                item = self._q.get()
                if item is None:
                    return
        except BaseException as e:
            self._exc = e

    def close(self):
        self._q.put(None)
        self._t.join()
