"""Clean fixture: NO rule may fire anywhere in this module (the
false-positive guard for the whole rule set)."""
import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CleanConfig:
    width: int = 4
    depth: int = 2


@jax.jit
def traced(x):
    # device-only math, jnp.asarray is a DEVICE placement (not numpy's)
    return jnp.sum(jnp.asarray(x)) * 2.0


def host_boundary(x, cfg: CleanConfig):
    # host side: float()/device_get at the logging boundary are legal
    return float(jax.device_get(traced(x))) + cfg.width + cfg.depth


def make_scaled(fn):
    # jit OUTSIDE a hot-path module: no donation decision required
    return jax.jit(fn, static_argnums=(1,))
