"""Seeded violations for ``lock-order-inversion`` (R7).

``transfer_ab`` and ``transfer_call`` take A then B (the latter through a
helper, exercising call-graph transitivity); ``transfer_ba`` takes B then
A — every witness of the inverted pair is reported.  ``double_a`` shows
that re-entering the same lock (RLock style) is not an inversion.
"""
import threading

_lock_a = threading.Lock()
_lock_b = threading.Lock()
_rlock = threading.RLock()


def transfer_ab(src, dst):
    with _lock_a:
        with _lock_b:              # LINT: lock-order-inversion
            dst.update(src)


def transfer_ba(src, dst):
    with _lock_b:
        with _lock_a:              # LINT: lock-order-inversion
            src.update(dst)


def _grab_b(dst):
    with _lock_b:
        dst.clear()


def transfer_call(dst):
    with _lock_a:
        _grab_b(dst)               # LINT: lock-order-inversion


def double_a(fn):
    with _rlock:
        with _rlock:               # reentrant: same id, not an inversion
            return fn()
