"""Deterministic fallback for ``hypothesis`` when it isn't installed.

The property tests in this suite use a small slice of the hypothesis API:
``@settings(max_examples=N, deadline=None)``, ``@given(...)`` and the
``integers`` / ``floats`` / ``sampled_from`` strategies.  This shim replays
each property over a deterministic sample — range endpoints first (the
classic edge cases), then seeded pseudo-random draws — so the suite keeps
its coverage in containers without the dependency instead of skipping four
modules at collection time.

Usage (see test_embedding_engine.py):

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from tests._hypothesis_compat import given, settings, strategies as st

With real hypothesis installed (``pip install -e .[test]``) this module is
never imported.
"""

from __future__ import annotations

import functools
import zlib

import numpy as np

# Cap on replayed examples per property: hypothesis shrinks/dedups cheaply,
# a plain replay recompiles jitted code per distinct shape — keep it fast.
_MAX_FALLBACK_EXAMPLES = 5


class _Strategy:
    def __init__(self, draw, edges=()):
        self._draw = draw
        self.edges = list(edges)

    def example(self, rng, i: int):
        if i < len(self.edges):
            return self.edges[i]
        return self._draw(rng)


class strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)),
            edges=(min_value, max_value),
        )

    @staticmethod
    def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
        return _Strategy(
            lambda rng: float(min_value + (max_value - min_value) * rng.random()),
            edges=(min_value, max_value),
        )

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        xs = list(elements)
        return _Strategy(lambda rng: xs[int(rng.integers(0, len(xs)))], edges=xs)

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.integers(0, 2)), edges=(False, True))


def settings(max_examples: int = 10, **_kw):
    """Record the example budget; ignore hypothesis-only knobs (deadline...)."""

    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(*arg_strategies, **kw_strategies):
    """Replay the property over deterministic draws (edges, then seeded)."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = min(
                getattr(wrapper, "_max_examples", 10), _MAX_FALLBACK_EXAMPLES
            )
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            for i in range(n):
                pos = tuple(s.example(rng, i) for s in arg_strategies)
                kw = {k: s.example(rng, i) for k, s in kw_strategies.items()}
                fn(*args, *pos, **kwargs, **kw)

        # pytest resolves fixtures through __wrapped__'s signature; the
        # property's drawn arguments must not look like fixture requests.
        del wrapper.__wrapped__
        return wrapper

    return deco
