"""Checkpoint layer: atomicity, retention, resume, cross-mesh logic."""

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore_tree, save_tree


def tree():
    return {"a": jnp.arange(6.0).reshape(2, 3),
            "nested": {"b": jnp.ones(4, jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path)
    save_tree(d, 7, tree(), meta={"k": 20})
    assert latest_step(d) == 7
    out = restore_tree(d, 7, tree())
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree())):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_torn_checkpoint_ignored(tmp_path):
    d = str(tmp_path)
    save_tree(d, 5, tree())
    # simulate a crash mid-save: tmp dir without manifest
    os.makedirs(os.path.join(d, "step_0000000009.tmp"))
    # and a final-named dir without a manifest (worst case)
    os.makedirs(os.path.join(d, "step_0000000011"))
    assert latest_step(d) == 5


def test_retention_gc(tmp_path):
    d = str(tmp_path)
    mgr = CheckpointManager(d, keep_last=2, save_every=1, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree())
    steps = sorted(n for n in os.listdir(d) if n.startswith("step_"))
    assert steps == ["step_0000000003", "step_0000000004"]


def test_async_save_completes(tmp_path):
    d = str(tmp_path)
    mgr = CheckpointManager(d, keep_last=3, save_every=1, async_save=True)
    mgr.save(1, tree())
    mgr.wait()
    assert latest_step(d) == 1


def test_shape_mismatch_raises(tmp_path):
    d = str(tmp_path)
    save_tree(d, 1, tree())
    bad = {"a": jnp.zeros((3, 3)), "nested": {"b": jnp.ones(4, jnp.int32)}}
    with pytest.raises(ValueError, match="shape mismatch"):
        restore_tree(d, 1, bad)


def test_manifest_contents(tmp_path):
    d = str(tmp_path)
    path = save_tree(d, 3, tree(), meta={"mesh": [16, 16]})
    with open(os.path.join(path, "manifest.json")) as f:
        man = json.load(f)
    assert man["step"] == 3
    assert man["meta"]["mesh"] == [16, 16]
    assert man["leaves"]["a"]["shape"] == [2, 3]


def test_restore_latest_none(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=1)
    step, t = mgr.restore_latest(tree())
    assert step is None and t is None


def test_async_save_failure_raises_on_wait(tmp_path):
    """A failed background save must surface, not vanish: wait() re-raises
    the writer's exception (once), and the manager recovers afterwards."""
    d = str(tmp_path)
    mgr = CheckpointManager(d, save_every=1, async_save=True)
    # a json-unserializable meta poisons the writer thread mid-save
    mgr.save(1, tree(), meta={"bad": object()})
    with pytest.raises(TypeError):
        mgr.wait()
    mgr.wait()   # the failure is reported once, then cleared
    assert latest_step(d) is None   # the poisoned step never became visible
    mgr.save(2, tree())
    mgr.wait()
    assert latest_step(d) == 2


def test_async_save_failure_raises_on_next_save(tmp_path):
    """The next save() re-raises a pending background failure instead of
    silently dropping it and dispatching a new write — including a
    ``block=True`` save, which must also drain the in-flight writer."""
    d = str(tmp_path)
    mgr = CheckpointManager(d, save_every=1, async_save=True)
    mgr.save(1, tree(), meta={"bad": object()})
    with pytest.raises(TypeError):
        mgr.save(2, tree(), block=True)
    assert latest_step(d) is None


def test_gc_sweeps_stale_tmp_and_aside_dirs(tmp_path):
    """Wreckage of crashed/failed saves (.tmp/.old dirs) must not leak
    forever: the manager's retention GC sweeps them on the next save."""
    d = str(tmp_path)
    mgr = CheckpointManager(d, keep_last=2, save_every=1, async_save=False)
    os.makedirs(os.path.join(d, "step_0000000001.tmp"))   # crashed save 1
    os.makedirs(os.path.join(d, "step_0000000002.old"))   # killed overwrite
    mgr.save(3, tree())
    left = sorted(os.listdir(d))
    assert left == ["step_0000000003"], left


def test_overwrite_crash_between_renames_keeps_previous(tmp_path):
    """Overwriting an existing step renames it aside rather than rmtree'ing
    it: a kill between the two renames leaves the previous checkpoint step
    complete and restorable, and a rerun of the save cleans up."""
    d = str(tmp_path)
    save_tree(d, 4, tree())
    save_tree(d, 5, tree())
    final = os.path.join(d, "step_0000000005")
    # simulate save_tree(d, 5, ...) killed after rename(final -> aside) but
    # before rename(tmp -> final)
    os.rename(final, final + ".old")
    os.makedirs(final + ".tmp")
    assert latest_step(d) == 4               # aside/tmp dirs are invisible
    out = restore_tree(d, 4, tree())
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree())):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # rerunning the interrupted save clears the wreckage and completes
    t2 = jax.tree.map(lambda x: x * 2, tree())
    save_tree(d, 5, t2)
    assert latest_step(d) == 5
    assert not os.path.exists(final + ".old")
    assert not os.path.exists(final + ".tmp")
    out = restore_tree(d, 5, tree())
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
