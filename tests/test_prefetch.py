"""Double-buffered pull prefetch (paper Fig. 5 pipeline).

Acceptance properties:
  - prefetched ``fit`` is BIT-identical to synchronous ``fit`` for all three
    placements (dense params, tables, accumulator, backend state, and every
    logged history record except wall time),
  - checkpoints taken during a prefetched run resume bit-exactly (and never
    capture an in-flight pull — ``save`` mid-flight is a loud error),
  - the one-deep pipeline is loud about misuse: prefetching or training a
    different batch than the one in flight raises,
  - online predict-then-train works mid-flight with identical predictions,
  - DenseTrainer rejects ``prefetch=True`` (no pull stage to overlap).
"""

import jax
import numpy as np
import pytest

from repro.core.kstep import KStepConfig
from repro.core.sparse_optim import SparseAdagradConfig
from repro.data import synthetic as S
from repro.runtime.factory import build_trainer
from repro.runtime.trainer import TrainerConfig

ROWS = 20_000


def _tcfg(placement, prefetch, ckpt_dir=None):
    return TrainerConfig(
        n_pod=2, kstep=KStepConfig(lr=1e-3, k=5, b1=0.0),
        sparse=SparseAdagradConfig(lr=0.5, initial_accumulator=0.01),
        placement=placement, capacity=4096,
        cache_rows=4096 if placement == "cached" else None,
        prefetch=prefetch, log_every=3,
        ckpt_dir=ckpt_dir, ckpt_every=6, ckpt_async=False,
    )


def _batches(n, seed=9):
    gen = S.ctr_batches(seed=seed, batch=256, rows=ROWS, n_fields=8, nnz=20,
                        zipf_a=1.05)
    return [next(gen) for _ in range(n)]


def _state_leaves(tr):
    return [np.asarray(x) for x in jax.tree.leaves(
        (tr.dense, tr.tables, tr.sparse_state.accum, tr.backend_state)
    )]


@pytest.mark.parametrize("placement", ["gather", "routed", "cached"])
def test_prefetched_fit_bit_identical(placement):
    """Prefetch changes WHEN the pull is dispatched, never WHAT it computes:
    the pull of batch t+1 commutes with the push of batch t except through
    the table/accum/state hand-off, which the commit protocol serializes."""
    batches = _batches(12)
    t_sync = build_trainer("baidu-ctr", _tcfg(placement, prefetch=False))
    h_sync = t_sync.fit(iter(batches), 12)
    t_pre = build_trainer("baidu-ctr", _tcfg(placement, prefetch=True))
    h_pre = t_pre.fit(iter(batches), 12)

    for a, b in zip(_state_leaves(t_sync), _state_leaves(t_pre)):
        np.testing.assert_array_equal(a, b)
    assert len(h_sync) == len(h_pre) > 0
    for ra, rb in zip(h_sync, h_pre):
        assert {k: v for k, v in ra.items() if k != "sec"} == \
               {k: v for k, v in rb.items() if k != "sec"}


def test_prefetch_checkpoint_resume_bitexact(tmp_path):
    """Crash/resume mid-way through a prefetched cached-placement run:
    checkpoints land at commit boundaries (never capturing the speculative
    pull), so the resumed prefetched run matches an uninterrupted
    SYNCHRONOUS run bit-for-bit."""
    batches = _batches(18)
    ref = build_trainer("baidu-ctr", _tcfg("cached", prefetch=False))
    for b in batches:
        ref.train_step(b)

    d = str(tmp_path)
    t_a = build_trainer("baidu-ctr", _tcfg("cached", prefetch=True, ckpt_dir=d))
    t_a.fit(iter(batches[:12]), 12)    # ckpt_every=6 -> ckpts at 6 and 12
    del t_a  # crash after step 12

    t_b = build_trainer("baidu-ctr", _tcfg("cached", prefetch=True, ckpt_dir=d))
    assert t_b.resume() and t_b.step_num == 12
    t_b.fit(iter(batches[12:]), 6)

    for a, b_ in zip(_state_leaves(ref), _state_leaves(t_b)):
        np.testing.assert_array_equal(a, b_)


def test_prefetch_pipeline_misuse_is_loud():
    """The one-deep pipeline never silently trains on the wrong batch, and
    never checkpoints a speculative pull."""
    tr = build_trainer("baidu-ctr", _tcfg("gather", prefetch=True))
    b1, b2 = _batches(2)
    assert tr.prefetch(b1) is True
    assert tr.prefetch(b1) is True          # idempotent for the same batch
    with pytest.raises(RuntimeError, match="different batch"):
        tr.prefetch(b2)
    with pytest.raises(RuntimeError, match="in flight"):
        tr.save()
    with pytest.raises(RuntimeError, match="different batch"):
        tr.train_step(b2)
    # a caught misuse error must not shift the step/merge/ckpt cadence
    assert tr.step_num == 0
    tr.train_step(b1)                       # the right batch commits the pull
    assert tr._prefetcher.pending is None
    tr.train_step(b2)                       # cold start: pulls synchronously
    assert tr.step_num == 2


def test_predict_mid_flight_matches_sync():
    """The launcher's online predict-then-train protocol: predictions read
    the in-flight pull's pass-through state and must match the synchronous
    run exactly (a pull moves rows coherently; only push changes values)."""
    batches = _batches(6)
    t_sync = build_trainer("baidu-ctr", _tcfg("cached", prefetch=False))
    t_pre = build_trainer("baidu-ctr", _tcfg("cached", prefetch=True))
    for b in batches:
        p_sync = t_sync.predict(b)
        t_sync.train_step(b)
        t_pre.prefetch(b)
        p_pre = t_pre.predict(b)            # pull for b is in flight here
        t_pre.train_step(b)
        np.testing.assert_array_equal(p_sync, p_pre)


def test_train_step_prefetched_manual_loop():
    """The manual-loop convenience wrapper pipelines like fit does."""
    batches = _batches(6)
    t_sync = build_trainer("baidu-ctr", _tcfg("gather", prefetch=False))
    for b in batches:
        t_sync.train_step(b)
    t_pre = build_trainer("baidu-ctr", _tcfg("gather", prefetch=True))
    for i, b in enumerate(batches):
        nxt = batches[i + 1] if i + 1 < len(batches) else None
        t_pre.train_step_prefetched(b, nxt)
    assert t_pre._prefetcher.pending is None
    for a, b_ in zip(_state_leaves(t_sync), _state_leaves(t_pre)):
        np.testing.assert_array_equal(a, b_)


def test_hot_path_returns_device_values():
    """The sync-stall fix: train_step must not block the host — loss comes
    back as a device array, the overflow counter accumulates on-device and
    materializes only through the property/metrics accessors."""
    tr = build_trainer("baidu-ctr", _tcfg("gather", prefetch=False))
    (b,) = _batches(1)
    loss = tr.train_step(b)
    assert isinstance(loss, jax.Array)
    assert isinstance(tr._overflow, jax.Array)
    assert isinstance(tr.overflow_dropped, int) and tr.overflow_dropped == 0


def test_dense_trainer_rejects_prefetch():
    with pytest.raises(ValueError, match="prefetch"):
        build_trainer("qwen3-14b", TrainerConfig(
            n_pod=2, kstep=KStepConfig(lr=1e-3, k=2, b1=0.9), prefetch=True,
        ))
