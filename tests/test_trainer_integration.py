"""Integration: end-to-end training behaviour — the paper's experimental
claims at CPU scale (k-step matches baseline AUC; crash/resume; online
predict-then-train)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.embedding_engine import EmbeddingEngine, TableSpec
from repro.core.kstep import KStepConfig
from repro.core.sparse_optim import SparseAdagrad, SparseAdagradConfig
from repro.data import synthetic as S
from repro.models import recsys as R
from repro.models import transformer as T
from repro.runtime.metrics import auc
from repro.runtime.trainer import DenseTrainer, HybridTrainer, TrainerConfig

# attn_heads=2: with 4 heads this tower fails to train on the synthetic
# stream at lr 1e-3 (AUC ~0.5 regardless of steps) — a calibration issue of
# the smoke setup, not of the k-step/sparse machinery under test.
CTR_CFG = R.CTRConfig(rows=5000, n_fields=8, nnz_per_instance=20, mlp=(64, 1),
                      attn_heads=2)


def ctr_trainer(n_pod, k, merge="flat", ckpt_dir=None, seed=0, backend=None):
    rng = jax.random.key(seed)
    dense = R.ctr_init_dense(rng, CTR_CFG)
    tc = TrainerConfig(
        n_pod=n_pod,
        kstep=KStepConfig(lr=1e-3, k=k, b1=0.0, merge=merge),
        sparse=SparseAdagradConfig(lr=0.5, initial_accumulator=0.01),
        ckpt_dir=ckpt_dir, ckpt_every=10, ckpt_async=False,
    )
    engine = EmbeddingEngine(
        {"sparse": TableSpec("sparse", rows=CTR_CFG.rows, dim=CTR_CFG.embed_dim,
                             id_field="ids")},
        capacity=8192, optimizer=SparseAdagrad(tc.sparse), backend=backend,
    )
    tables = engine.prepare(
        {"sparse": jax.random.normal(rng, (CTR_CFG.rows, CTR_CFG.embed_dim)) * 0.05}
    )
    return HybridTrainer(
        dense, engine, R.ctr_embed_from_workings(CTR_CFG),
        R.ctr_hybrid_loss(CTR_CFG), tc, tables=tables,
    )


def run_online(tr, steps, seed=1):
    """Paper §5 protocol: predict each batch with the current model, then
    train on it; report AUC over the last third."""
    gen = S.ctr_batches(seed=seed, batch=512, rows=CTR_CFG.rows,
                        n_fields=CTR_CFG.n_fields, nnz=CTR_CFG.nnz_per_instance)
    labels, scores = [], []
    for i in range(steps):
        b = next(gen)
        if i >= steps * 2 // 3:
            scores.append(tr.predict(b))
            labels.append(b["label"])
        tr.train_step(b)
    return auc(np.concatenate(labels), np.concatenate(scores))


def test_ctr_baseline_learns():
    a = run_online(ctr_trainer(n_pod=1, k=1), steps=120)
    assert a > 0.70, f"baseline AUC {a}"


def test_kstep_matches_baseline_auc():
    """Fig. 9: k-step merging must not hurt AUC measurably."""
    a_base = run_online(ctr_trainer(n_pod=1, k=1), steps=120)
    a_k = run_online(ctr_trainer(n_pod=4, k=10), steps=120)
    assert abs(a_base - a_k) < 0.03, (a_base, a_k)


def test_two_phase_and_int8_merges_learn():
    for merge in ("two_phase", "int8_ef"):
        a = run_online(ctr_trainer(n_pod=2, k=5, merge=merge), steps=100)
        assert a > 0.68, (merge, a)


def test_crash_resume_bitexact(tmp_path):
    """Fault tolerance: train 20, crash, resume from ckpt -> identical state
    to an uninterrupted run consuming the same stream."""
    d = str(tmp_path)
    t_ref = ctr_trainer(n_pod=2, k=5, seed=3)
    gen = S.ctr_batches(seed=9, batch=256, rows=CTR_CFG.rows,
                        n_fields=CTR_CFG.n_fields, nnz=CTR_CFG.nnz_per_instance)
    batches = [next(gen) for _ in range(30)]
    for b in batches:
        t_ref.train_step(b)

    t_a = ctr_trainer(n_pod=2, k=5, ckpt_dir=d, seed=3)
    for b in batches[:20]:
        t_a.train_step(b)
    del t_a  # crash after step 20 (ckpt_every=10 -> ckpt at 20 exists)

    t_b = ctr_trainer(n_pod=2, k=5, ckpt_dir=d, seed=3)
    assert t_b.resume()
    assert t_b.step_num == 20
    for b in batches[20:]:
        t_b.train_step(b)
    for a, b_ in zip(jax.tree.leaves(t_ref.tables), jax.tree.leaves(t_b.tables)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-6)
    for a, b_ in zip(jax.tree.leaves(t_ref.dense), jax.tree.leaves(t_b.dense)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-6)


def test_int8_ef_residual_survives_resume(tmp_path):
    """merge="int8_ef": the error-feedback residual is optimizer state and
    must roundtrip through save/resume (dropping it re-zeros compensation)."""
    d = str(tmp_path)
    t_a = ctr_trainer(n_pod=2, k=5, merge="int8_ef", ckpt_dir=d, seed=3)
    gen = S.ctr_batches(seed=9, batch=256, rows=CTR_CFG.rows,
                        n_fields=CTR_CFG.n_fields, nnz=CTR_CFG.nnz_per_instance)
    for _ in range(10):   # merges at 5 and 10 -> nonzero residual; ckpt at 10
        t_a.train_step(next(gen))
    ef_ref = [np.asarray(x) for x in jax.tree.leaves(t_a.opt_state.ef)]
    assert max(float(np.abs(x).max()) for x in ef_ref) > 0.0

    t_b = ctr_trainer(n_pod=2, k=5, merge="int8_ef", ckpt_dir=d, seed=3)
    assert t_b.resume() and t_b.step_num == 10
    for a, b in zip(ef_ref, jax.tree.leaves(t_b.opt_state.ef)):
        np.testing.assert_array_equal(a, np.asarray(b))


def test_resume_int8_ef_from_pre_ef_checkpoint(tmp_path):
    """A checkpoint without the residual (older run / lossless merge) must
    resume cleanly under merge="int8_ef", keeping the zero residual."""
    d = str(tmp_path)
    t_a = ctr_trainer(n_pod=2, k=5, merge="flat", ckpt_dir=d, seed=3)
    gen = S.ctr_batches(seed=9, batch=256, rows=CTR_CFG.rows,
                        n_fields=CTR_CFG.n_fields, nnz=CTR_CFG.nnz_per_instance)
    for _ in range(10):
        t_a.train_step(next(gen))
    t_b = ctr_trainer(n_pod=2, k=5, merge="int8_ef", ckpt_dir=d, seed=3)
    assert t_b.resume() and t_b.step_num == 10
    for leaf in jax.tree.leaves(t_b.opt_state.ef):
        assert float(jnp.abs(leaf).max()) == 0.0


def test_resume_rejects_backend_mismatch(tmp_path):
    """Tables are checkpointed in the backend's physical layout; resuming
    under a different backend must fail loudly, not read wrong rows."""
    from repro.core.embedding_backend import make_backend
    d = str(tmp_path)
    t_a = ctr_trainer(n_pod=1, k=1, ckpt_dir=d)
    gen = S.ctr_batches(seed=9, batch=256, rows=CTR_CFG.rows,
                        n_fields=CTR_CFG.n_fields, nnz=CTR_CFG.nnz_per_instance)
    for _ in range(10):
        t_a.train_step(next(gen))
    t_b = ctr_trainer(n_pod=1, k=1, ckpt_dir=d, backend=make_backend("routed"))
    with pytest.raises(ValueError, match="physical"):
        t_b.resume()


def test_suggest_capacity_from_overflow():
    """Overflow-aware capacity autoscaling (step 1): a trainer that drops
    pulls recommends a larger power-of-two capacity; a clean trainer keeps
    its current one."""
    clean = ctr_trainer(n_pod=1, k=1)
    gen = S.ctr_batches(seed=9, batch=256, rows=CTR_CFG.rows,
                        n_fields=CTR_CFG.n_fields, nnz=CTR_CFG.nnz_per_instance)
    clean.cfg.log_every = 2
    clean.fit(gen, 4)
    assert clean.overflow_dropped == 0
    assert clean.suggest_capacity() == clean.engine.capacity

    # synthetic history (PER-INTERVAL records, as sparse_metrics emits):
    # 300 drops in the 2-step window -> 150/step
    # -> needs >= 8192 + 1.25 * 150 -> next pow2 = 16384
    hist = [{"step": 2, "overflow_dropped": 300}]
    assert clean.suggest_capacity(history=hist) == 16384

    # live overflow: capacity 64 cannot hold ~2k distinct ids per batch
    from repro.runtime.factory import build_trainer
    tight = build_trainer("baidu-ctr", TrainerConfig(
        n_pod=1, kstep=KStepConfig(lr=1e-3, k=1, b1=0.0),
        sparse=SparseAdagradConfig(lr=0.5, initial_accumulator=0.01),
        capacity=64, log_every=2,
    ))
    gen = S.ctr_batches(seed=1, batch=256, rows=20000, n_fields=8, nnz=20)
    tight.fit(gen, 4)
    assert tight.overflow_dropped > 0
    suggested = tight.suggest_capacity()
    assert suggested > 64 and (suggested & (suggested - 1)) == 0


def test_dense_trainer_lm_learns_and_resumes(tmp_path):
    cfg = T.TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                              d_ff=128, vocab=64, dtype=jnp.float32, moe_group_size=64)
    p = T.init_params(jax.random.key(1), cfg)
    tc = TrainerConfig(n_pod=2, kstep=KStepConfig(lr=2e-3, k=5, b1=0.9),
                       ckpt_dir=str(tmp_path), ckpt_every=20, ckpt_async=False)
    tr = DenseTrainer(lambda pp, bb: T.loss_fn(pp, bb, cfg), p, tc)
    gen = S.lm_batches(seed=0, batch=16, seq_len=32, vocab=64)
    losses = [tr.train_step(next(gen)) for _ in range(40)]
    assert losses[-1] < losses[0] - 1.0
    tr2 = DenseTrainer(lambda pp, bb: T.loss_fn(pp, bb, cfg), p, tc)
    assert tr2.resume() and tr2.step_num == 40


def test_overflow_counter_survives_resume(tmp_path):
    """The cumulative overflow counter is training state: it rides the
    checkpoint so post-resume ``*_total`` metrics share one baseline with
    the cache counters (which live inside the checkpointed bstate)."""
    from repro.runtime.factory import build_trainer
    tcfg = TrainerConfig(
        n_pod=1, kstep=KStepConfig(lr=1e-3, k=1, b1=0.0),
        sparse=SparseAdagradConfig(lr=0.5, initial_accumulator=0.01),
        capacity=64, ckpt_dir=str(tmp_path), ckpt_every=4, ckpt_async=False,
    )
    tr = build_trainer("baidu-ctr", tcfg)
    gen = S.ctr_batches(seed=1, batch=256, rows=20000, n_fields=8, nnz=20)
    for _ in range(4):
        tr.train_step(next(gen))
    assert tr.overflow_dropped > 0
    tr2 = build_trainer("baidu-ctr", tcfg)
    assert tr2.resume() and tr2.step_num == 4
    assert tr2.overflow_dropped == tr.overflow_dropped
    # the first post-resume interval reports only post-resume drops
    m = tr2.sparse_metrics()
    assert m["overflow_dropped"] == 0
    assert m["overflow_dropped_total"] == tr.overflow_dropped


def _lm_cfg():
    return T.TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                               d_ff=128, vocab=64, dtype=jnp.float32,
                               moe_group_size=64)


def test_dense_merge_delay_converges():
    """merge_delay>0 (async DCN-hiding merges): the delayed application
    x <- merged + (x_now - x_snapshot) must still learn on the
    quickstart-scale smoke config and track the synchronous-merge loss."""
    cfg = _lm_cfg()
    p = T.init_params(jax.random.key(1), cfg)

    def run(delay):
        tc = TrainerConfig(n_pod=4, kstep=KStepConfig(lr=2e-3, k=10, b1=0.9),
                           merge_delay=delay)
        tr = DenseTrainer(lambda pp, bb: T.loss_fn(pp, bb, cfg), p, tc)
        gen = S.lm_batches(seed=0, batch=16, seq_len=32, vocab=64)
        return tr, [float(tr.train_step(next(gen))) for _ in range(60)]

    tr0, l0 = run(0)
    tr2, l2 = run(2)
    assert l2[-1] < l2[0] - 1.0, "delayed merges must still converge"
    assert abs(l2[-1] - l0[-1]) < 0.5, (l0[-1], l2[-1])
    # the pipeline reached steady state: exactly `delay` merges in flight
    assert len(tr2._pending_merges) == 2
    assert len(tr0._pending_merges) == 0


def test_dense_merge_delay_resumes(tmp_path):
    """The in-flight delayed-merge queue is not checkpointed; resume starts
    with an empty queue and keeps training."""
    cfg = _lm_cfg()
    p = T.init_params(jax.random.key(1), cfg)
    tc = TrainerConfig(n_pod=2, kstep=KStepConfig(lr=2e-3, k=5, b1=0.9),
                       merge_delay=1, ckpt_dir=str(tmp_path), ckpt_every=20,
                       ckpt_async=False)
    tr = DenseTrainer(lambda pp, bb: T.loss_fn(pp, bb, cfg), p, tc)
    gen = S.lm_batches(seed=0, batch=16, seq_len=32, vocab=64)
    for _ in range(20):
        tr.train_step(next(gen))
    tr2 = DenseTrainer(lambda pp, bb: T.loss_fn(pp, bb, cfg), p, tc)
    assert tr2.resume() and tr2.step_num == 20
    assert len(tr2._pending_merges) == 0
    assert np.isfinite(float(tr2.train_step(next(gen))))


def test_dead_knobs_rejected_loudly():
    """The no-silent-config contract: documented knobs a trainer cannot
    honor raise at construction instead of being ignored."""
    from repro.runtime.factory import build_trainer

    # HybridTrainer has no delayed dense merge (sparse syncs every step)
    with pytest.raises(ValueError, match="merge_delay"):
        build_trainer("baidu-ctr", TrainerConfig(n_pod=1, merge_delay=1))
    # merge_quorum < 1.0 has no failure detector behind it anywhere yet
    with pytest.raises(NotImplementedError, match="merge_quorum"):
        build_trainer("baidu-ctr", TrainerConfig(n_pod=1, merge_quorum=0.5))
    cfg = _lm_cfg()
    p = T.init_params(jax.random.key(1), cfg)
    with pytest.raises(NotImplementedError, match="merge_quorum"):
        DenseTrainer(lambda pp, bb: T.loss_fn(pp, bb, cfg), p,
                     TrainerConfig(n_pod=2, merge_quorum=0.75))
    # int8_ef's error feedback requires the fused merge path
    with pytest.raises(NotImplementedError, match="int8_ef"):
        DenseTrainer(
            lambda pp, bb: T.loss_fn(pp, bb, cfg), p,
            TrainerConfig(n_pod=2, merge_delay=1,
                          kstep=KStepConfig(merge="int8_ef")),
        )
    with pytest.raises(ValueError, match="merge_delay"):
        DenseTrainer(lambda pp, bb: T.loss_fn(pp, bb, cfg), p,
                     TrainerConfig(n_pod=2, merge_delay=-1))


def test_merge_quorum_subset_average():
    """Straggler mitigation: merging over a pod subset is a valid merge —
    params equal the subset mean, stragglers keep their local value."""
    from repro.core.kstep import KStepAdam, pod_replicate
    pp = pod_replicate({"x": jnp.zeros(4)}, 4)
    opt = KStepAdam(KStepConfig(lr=0.1, k=1), n_pod=4)
    state = opt.init(pp)
    g = jax.tree.map(
        lambda x: jnp.arange(4.0).reshape(4, 1) * jnp.ones_like(x), pp)
    p1, state = opt.step(pp, g, state, merge=False)
    # emulate quorum merge of pods {0,1,2}: average their replicas only
    subset = jax.tree.map(lambda x: x.at[:3].set(jnp.mean(x[:3], 0)), p1)
    for leaf in jax.tree.leaves(subset):
        assert np.allclose(leaf[0], leaf[1])
        assert not np.allclose(leaf[3], leaf[0])
