"""Embedding engine: bag lookup, working-set pull, sparse updates —
property tested (these are the paper's Algorithm 1 lines 3/11/13)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic replay
    from tests._hypothesis_compat import given, settings, strategies as st

from repro.core.embedding_engine import (
    EmbeddingEngine,
    TableSpec,
    embedding_bag,
    pull_working_set,
)
from repro.core.sparse_optim import SparseAdagrad, SparseAdagradConfig


@settings(max_examples=30, deadline=None)
@given(
    rows=st.integers(4, 200),
    dim=st.integers(1, 32),
    nnz=st.integers(1, 100),
    bags=st.integers(1, 40),
    combiner=st.sampled_from(["sum", "mean", "sqrtn"]),
    seed=st.integers(0, 999),
)
def test_bag_matches_dense_onehot(rows, dim, nnz, bags, combiner, seed):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.standard_normal((rows, dim)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, rows, nnz), jnp.int32)
    seg = jnp.asarray(rng.integers(0, bags, nnz), jnp.int32)
    w = jnp.asarray(rng.random(nnz), jnp.float32)
    out = embedding_bag(table, ids, seg, bags, weights=w, combiner=combiner)
    # dense one-hot oracle
    onehot = np.zeros((bags, nnz), np.float32)
    onehot[np.asarray(seg), np.arange(nnz)] = np.asarray(w)
    expect = onehot @ (np.asarray(table)[np.asarray(ids)])
    if combiner in ("mean", "sqrtn"):
        cnt = np.zeros(bags, np.float32)
        np.add.at(cnt, np.asarray(seg), 1.0)
        denom = np.maximum(cnt, 1.0)
        if combiner == "sqrtn":
            denom = np.sqrt(denom)
        expect = expect / denom[:, None]
    np.testing.assert_allclose(np.asarray(out), expect, atol=1e-4)


@settings(max_examples=30, deadline=None)
@given(
    rows=st.integers(4, 100),
    dim=st.integers(1, 16),
    nnz=st.integers(1, 80),
    bags=st.integers(1, 20),
    combiner=st.sampled_from(["sum", "mean", "sqrtn"]),
    seed=st.integers(0, 999),
)
def test_bag_from_working_matches_embedding_bag(rows, dim, nnz, bags,
                                                combiner, seed):
    """The working-set bag lookup must agree with ``embedding_bag`` for ALL
    supported combiners (the sqrtn branch used to silently fall through to
    sum)."""
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.standard_normal((rows, dim)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, rows, nnz), jnp.int32)
    seg = jnp.asarray(rng.integers(0, bags, nnz), jnp.int32)
    w = jnp.asarray(rng.random(nnz), jnp.float32)
    uids, inv = pull_working_set(ids, capacity=nnz)
    working = jnp.take(table, uids, axis=0)
    out_ws = EmbeddingEngine.bag_from_working(
        working, inv, seg, bags, weights=w, combiner=combiner
    )
    out_ref = embedding_bag(table, ids, seg, bags, weights=w,
                            combiner=combiner)
    np.testing.assert_allclose(np.asarray(out_ws), np.asarray(out_ref),
                               atol=1e-6)


def test_unknown_combiner_raises():
    """Unknown combiners are an error in BOTH lookup paths — never a silent
    fall-through to sum."""
    table = jnp.zeros((4, 2), jnp.float32)
    ids = jnp.zeros((3,), jnp.int32)
    seg = jnp.zeros((3,), jnp.int32)
    with pytest.raises(ValueError, match="combiner"):
        embedding_bag(table, ids, seg, 2, combiner="max")
    with pytest.raises(ValueError, match="combiner"):
        EmbeddingEngine.bag_from_working(table, ids, seg, 2, combiner="max")


@settings(max_examples=30, deadline=None)
@given(
    rows=st.integers(2, 500),
    nnz=st.integers(1, 200),
    seed=st.integers(0, 999),
)
def test_pull_working_set_roundtrip(rows, nnz, seed):
    """uids[inv] must reconstruct the original ids (the pull is lossless)."""
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, rows, nnz), jnp.int32)
    capacity = nnz  # worst case
    uids, inv = pull_working_set(ids, capacity)
    np.testing.assert_array_equal(np.asarray(uids)[np.asarray(inv)], np.asarray(ids))
    # dedup: real unique ids appear exactly once among the first n_unique
    n_unique = len(np.unique(np.asarray(ids)))
    assert len(np.unique(np.asarray(uids))) == n_unique


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(4, 100),
    dim=st.integers(1, 16),
    nnz=st.integers(1, 64),
    seed=st.integers(0, 999),
)
def test_sparse_adagrad_equals_dense(rows, dim, nnz, seed):
    """Working-set AdaGrad must equal dense AdaGrad on the gathered grads."""
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.standard_normal((rows, dim)), jnp.float32)
    accum = jnp.asarray(rng.random((rows, dim)) + 0.1, jnp.float32)
    ids = jnp.asarray(rng.integers(0, rows, nnz), jnp.int32)
    uids, inv = pull_working_set(ids, nnz)
    # per-slot gradients, accumulated onto working rows like autodiff would
    slot_g = rng.standard_normal((nnz, dim)).astype(np.float32)
    row_g = np.zeros((nnz, dim), np.float32)
    np.add.at(row_g, np.asarray(inv), slot_g)
    sa = SparseAdagrad(SparseAdagradConfig(lr=0.1))
    nt, na = sa.apply_rows(table, accum, uids, jnp.asarray(row_g))
    dense_g = np.zeros((rows, dim), np.float32)
    np.add.at(dense_g, np.asarray(ids), slot_g)
    nt_ref, na_ref = sa.dense_reference(table, accum, jnp.asarray(dense_g))
    np.testing.assert_allclose(np.asarray(nt), np.asarray(nt_ref), atol=1e-5)
    np.testing.assert_allclose(np.asarray(na), np.asarray(na_ref), atol=1e-5)


def test_engine_end_to_end():
    engine = EmbeddingEngine(
        {"t": TableSpec("t", rows=50, dim=4)}, capacity=16
    )
    tables = engine.init(jax.random.key(0))
    accum = engine.init_state(tables).accum
    states = engine.init_backend_state(tables)
    ids = jnp.asarray([3, 3, 7, 9, 3], jnp.int32)
    seg = jnp.asarray([0, 0, 1, 1, 2], jnp.int32)
    wss, _, _, _ = engine.pull(tables, accum, states, {"t": ids})
    ws = wss["t"]
    assert int(ws.n_dropped) == 0
    bags = engine.bag_from_working(ws.rows, ws.inverse, seg, num_bags=3)
    expect = embedding_bag(tables["t"], ids, seg, 3)
    np.testing.assert_allclose(np.asarray(bags), np.asarray(expect), atol=1e-6)
    assert engine.memory_bytes() == 50 * 4 * 4
    assert engine.cache_stats(states) == {}   # stateless placement


def test_engine_ids_from_batch_and_push():
    """Facade roundtrip: pull_batch -> push applies working-set AdaGrad."""
    engine = EmbeddingEngine(
        {"t": TableSpec("t", rows=40, dim=4, id_field="my_ids")}, capacity=8,
        optimizer=SparseAdagradConfig(lr=0.1),
    )
    tables = engine.init(jax.random.key(1))
    state = engine.init_state(tables)
    states = engine.init_backend_state(tables)
    batch = {"my_ids": jnp.asarray([[1, 2], [2, 5]], jnp.int32)}
    wss, tables_p, accum_p, states_p = engine.pull_batch(
        tables, state.accum, states, batch
    )
    # per-slot unit grads accumulated onto working rows, like autodiff would
    grads = {"t": jnp.zeros_like(wss["t"].rows).at[wss["t"].inverse].add(1.0)}
    new_tables, new_accum, _ = engine.push(
        tables_p, accum_p, states_p, wss, grads
    )
    # only the 3 touched rows moved
    moved = np.flatnonzero(
        np.any(np.asarray(new_tables["t"]) != np.asarray(tables["t"]), axis=1)
    )
    np.testing.assert_array_equal(moved, [1, 2, 5])
    assert int(engine.overflow(wss)) == 0


def test_gradient_through_pull_equals_direct():
    """d loss/d table via (pull -> working -> scatter) == direct path."""
    rng = np.random.default_rng(1)
    table = jnp.asarray(rng.standard_normal((30, 4)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, 30, 20), jnp.int32)
    seg = jnp.asarray(np.sort(rng.integers(0, 5, 20)), jnp.int32)
    tgt = jnp.asarray(rng.standard_normal((5, 4)), jnp.float32)

    def loss_direct(t):
        return jnp.sum((embedding_bag(t, ids, seg, 5) - tgt) ** 2)

    uids, inv = pull_working_set(ids, 20)

    def loss_ws(working):
        emb = jnp.take(working, inv, axis=0)
        bags = jax.ops.segment_sum(emb, seg, num_segments=5)
        return jnp.sum((bags - tgt) ** 2)

    gt = jax.grad(loss_direct)(table)
    gw = jax.grad(loss_ws)(table[uids])
    gt2 = jnp.zeros_like(table).at[uids].add(gw)
    np.testing.assert_allclose(np.asarray(gt), np.asarray(gt2), atol=1e-5)
