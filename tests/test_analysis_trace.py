"""Layer-2 trace audit: each check catches a seeded violation (callback,
f64 widening, missing donation, retrace, implicit transfer) and passes on a
real trainer; plus the ``fit_online(strict_transfers=True)`` runtime gate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.trace_audit import (
    audit_recsys,
    audit_serve_decode,
    audit_serve_lookup,
    callback_primitives,
    donation_marked,
    f64_leaks,
)


# ----------------------------------------------- seeded-violation detection
def test_callback_check_catches_host_round_trip():
    """A step that smuggles host code in via pure_callback is caught."""

    def bad_step(x):
        y = jax.pure_callback(
            lambda v: np.asarray(v) * 2.0,
            jax.ShapeDtypeStruct(x.shape, x.dtype), x,
        )
        return jnp.sum(y)

    jx = jax.make_jaxpr(bad_step)(jnp.ones((4,), jnp.float32))
    assert callback_primitives(jx) == ["pure_callback"]


def test_callback_check_clean_on_pure_step():
    jx = jax.make_jaxpr(lambda x: jnp.sum(x * 2))(jnp.ones((4,)))
    assert callback_primitives(jx) == []


def test_callback_check_recurses_into_scan():
    def bad_scan(x):
        def body(c, _):
            c = jax.pure_callback(
                lambda v: np.asarray(v), jax.ShapeDtypeStruct((), x.dtype), c
            )
            return c, c
        out, _ = jax.lax.scan(body, x, None, length=3)
        return out

    jx = jax.make_jaxpr(bad_scan)(jnp.float32(1.0))
    assert "pure_callback" in callback_primitives(jx)


def test_f64_check_catches_widening():
    with jax.experimental.enable_x64():
        jx = jax.make_jaxpr(lambda x: x * 2.0)(np.float64(1.0))
    assert f64_leaks(jx) != []


def test_f64_check_clean_at_f32():
    jx = jax.make_jaxpr(lambda x: x * 2.0)(jnp.float32(1.0))
    assert f64_leaks(jx) == []


def test_donation_check_sees_donor_marking():
    x = jnp.ones((8,))
    donated = jax.jit(lambda a: a + 1, donate_argnums=(0,)).lower(x).as_text()
    plain = jax.jit(lambda a: a + 1).lower(x).as_text()
    assert donation_marked(donated)
    assert not donation_marked(plain)


def test_retrace_detection_via_cache_size():
    """_cache_size() growth is how the audit sees a silent recompile."""
    f = jax.jit(lambda x: x * 2)
    f(jnp.ones((2,)))
    size0 = f._cache_size()
    f(jnp.ones((2,)))              # same signature: no growth
    assert f._cache_size() == size0
    f(jnp.ones((3,)))              # new shape: the seeded retrace
    assert f._cache_size() == size0 + 1


def test_transfer_guard_trips_on_implicit_h2d():
    """A raw numpy operand mixed into a device op is an implicit per-step
    host->device transfer — the runtime check's seeded violation."""
    y = jax.jit(lambda x: x * 2)(jnp.ones((4,)))
    host = np.ones((4,), np.float32)
    with pytest.raises(Exception, match="[Dd]isallow"):
        with jax.transfer_guard("disallow"):
            _ = y + host


def test_transfer_guard_passes_explicit_put():
    y = jax.jit(lambda x: x * 2)(jnp.ones((4,)))
    with jax.transfer_guard("disallow"):
        _ = y + jax.device_put(np.ones((4,), np.float32))


# ------------------------------------------------------- real-trainer audit
@pytest.mark.parametrize("placement", ["gather", "routed"])
def test_audit_recsys_clean(placement):
    """One real arch x placement passes every check (ctr exercises the
    multi-hot bag path; routed exercises the mesh-committed state fix)."""
    results = audit_recsys("baidu-ctr", placement)
    failed = [(r.check, r.detail) for r in results if not r.ok]
    assert failed == []
    assert {r.check for r in results} == {
        "callback", "f64", "donation", "retrace", "transfer-sync"}


def test_audit_serve_decode_clean():
    results = audit_serve_decode()
    failed = [(r.check, r.detail) for r in results if not r.ok]
    assert failed == []


def test_audit_serve_lookup_clean():
    """The co-located CTR serving tier passes its audit: clean jaxpr, NO
    donation of the live training buffers it shares with the trainer, one
    compiled executable across drains, and a transfer-guard-clean
    interleaved train+serve loop."""
    results = audit_serve_lookup()
    failed = [(r.check, r.detail) for r in results if not r.ok]
    assert failed == []
    assert {r.check for r in results} == {
        "callback", "f64", "no-donation", "retrace", "transfer-sync"}


# --------------------------------------------------- fit_online strict gate
class _SyncingTrainer:
    """Train loop double whose step mixes a HOST numpy array into a device
    op — the implicit-transfer bug strict_transfers must catch."""

    class cfg:
        log_every = 10_000

    def __init__(self):
        self.step_num = 0
        self.history = []
        self.ckpt = None
        self._w = jax.jit(lambda x: x * 2)(jnp.ones((4,)))

    def train_step(self, batch):
        self.step_num += 1
        self._w = self._w + batch["dense"]          # implicit h2d of numpy
        return jnp.sum(self._w)


def _np_batches(n):
    for _ in range(n):
        yield {"dense": np.ones((4,), np.float32)}


def test_fit_online_strict_catches_implicit_transfer():
    from repro.runtime.online import fit_online

    with pytest.raises(Exception, match="[Dd]isallow"):
        fit_online(_SyncingTrainer(), _np_batches(3), steps=3,
                   strict_transfers=True)


def test_fit_online_lenient_allows_it():
    from repro.runtime.online import fit_online

    hist, auc = fit_online(_SyncingTrainer(), _np_batches(3), steps=3)
    assert auc is None


def test_fit_online_strict_real_trainer():
    """The production loop survives the guard end to end: staging is
    explicit device_put, metrics materialize via explicit device_get."""
    from repro import configs
    from repro.analysis.trace_audit import _build_recsys
    from repro.data import synthetic as S
    from repro.runtime.online import fit_online

    tr = _build_recsys("baidu-ctr", "gather", False)
    gen = S.recsys_batches(configs.get("baidu-ctr").smoke_cfg,
                           batch=32, seed=3)
    hist, auc = fit_online(tr, gen, steps=4, strict_transfers=True)
    assert tr.step_num == 4
    assert auc is not None
