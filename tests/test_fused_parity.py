"""Fused-kernel bit-parity suite (the acceptance contract of the fusion PR).

The fused Pallas hot path — gather+bag pull, scatter+AdaGrad push, and the
cache-tier double-indirection variants — must be BIT-identical to the
unfused jnp expressions on every backend, forward and gradient.  Anything
weaker would make ``--fused-kernels`` a numerics knob instead of a perf
knob, and fused-vs-unfused loss curves would silently diverge.

Property tests (hypothesis, with the deterministic fallback shim) sweep
odd geometries, all combiners, weighted/unweighted bags and drop-row
traffic; the remaining tests check the backend objects and a short
end-to-end fit.  The suite runs under ``REPRO_KERNEL_INTERPRET=1`` (set by
conftest), so fused ops execute through Pallas interpret mode — the same
kernel code that compiles on TPU.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from tests._hypothesis_compat import given, settings, strategies as st

from repro.core.cache_tier import CachedBackend
from repro.core.embedding_backend import GatherBackend, make_backend
from repro.core.embedding_engine import EmbeddingEngine
from repro.core.sparse_optim import SparseAdagrad, SparseAdagradConfig


def _bitwise(a, b, msg=""):
    __tracebackhint__ = True
    a, b = np.asarray(a), np.asarray(b)
    assert a.dtype == b.dtype and a.shape == b.shape, msg
    assert np.array_equal(a, b, equal_nan=True), (
        f"{msg}: max |diff| = {np.abs(a.astype(np.float64) - b.astype(np.float64)).max()}"
    )


# ------------------------------------------------------------- bag property
@settings(max_examples=20, deadline=None)
@given(
    cap=st.integers(3, 40),
    dim=st.integers(1, 33),
    nnz=st.integers(1, 97),
    bags=st.integers(1, 19),
    combiner=st.sampled_from(["sum", "mean", "sqrtn"]),
    weighted=st.booleans(),
)
def test_bag_fused_matches_unfused(cap, dim, nnz, bags, combiner, weighted):
    """Forward AND gradient of the fused gather+bag are bit-identical to the
    unfused reference for arbitrary odd geometries, including id slots that
    point at the zero drop row (``inverse == cap``)."""
    rng = np.random.default_rng(cap * 1_000_003 + dim * 101 + nnz * 7 + bags)
    working = jnp.asarray(
        rng.standard_normal((cap + 1, dim)), jnp.float32
    ).at[cap].set(0.0)
    inv = jnp.asarray(rng.integers(0, cap + 1, nnz), jnp.int32)
    seg = jnp.asarray(np.sort(rng.integers(0, bags, nnz)), jnp.int32)
    w = jnp.asarray(rng.standard_normal(nnz), jnp.float32) if weighted else None

    def bag(wk, fused):
        return EmbeddingEngine.bag_from_working(
            wk, inv, seg, bags, w, combiner, fused=fused)

    out_u, vjp_u = jax.vjp(lambda wk: bag(wk, False), working)
    out_f, vjp_f = jax.vjp(lambda wk: bag(wk, True), working)
    _bitwise(out_f, out_u, f"bag fwd {combiner} weighted={weighted}")

    ct = jnp.asarray(rng.standard_normal((bags, dim)), jnp.float32)
    _bitwise(vjp_f(ct)[0], vjp_u(ct)[0],
             f"bag grad {combiner} weighted={weighted}")


def _pad_slots(uids) -> np.ndarray:
    """Boolean mask of working-set slots that are capacity pads (duplicates
    of an already-present id, the ``pull_working_set`` fill convention)."""
    u = np.asarray(uids)
    mask = np.ones(u.shape[0], bool)
    _, first = np.unique(u, return_index=True)
    mask[first] = False
    return mask


# ----------------------------------------------------- push property (drop)
@settings(max_examples=10, deadline=None)
@given(
    rows=st.integers(8, 64),
    dim=st.integers(1, 16),
    n_ids=st.integers(1, 80),
    cap=st.integers(4, 12),
)
def test_gather_push_fused_matches_unfused(rows, dim, n_ids, cap):
    """Fused scatter+AdaGrad push == unfused push, bit for bit — including
    batches that overflow ``cap`` (drop-row gradient discarded identically)
    and rows the batch never touched (bit-unchanged)."""
    rng = np.random.default_rng(rows * 7919 + dim * 31 + n_ids)
    opt = SparseAdagrad(SparseAdagradConfig(lr=0.1))
    table = jnp.asarray(rng.standard_normal((rows, dim)), jnp.float32)
    accum = jnp.asarray(rng.random((rows, dim)) + 0.05, jnp.float32)
    ids = jnp.asarray(rng.integers(0, rows, n_ids), jnp.int32)
    # drop-row slot gets a nonzero gradient; both paths must discard it.
    # Pad slots (uids padded by REPEATING an existing id) must carry zero
    # gradient — that is the pipeline invariant (``inverse`` only references
    # the canonical slot, so the bag gradient never lands on a pad).
    row_g = jnp.asarray(rng.standard_normal((cap + 1, dim)) * 2, jnp.float32)
    row_g = row_g.at[:cap].set(jnp.where(
        _pad_slots(GatherBackend().pull(table, accum, (), ids, cap)[0].uids)[
            :, None],
        0.0, row_g[:cap]))

    outs = {}
    for fused in (False, True):
        be = GatherBackend(fused=fused)
        st_ = be.init_state(table)
        ws, t, a, st_ = be.pull(table, accum, st_, ids, cap)
        outs[fused] = be.push(t, a, st_, ws, row_g, opt)[:2]
    (tu, au), (tf, af) = outs[False], outs[True]
    _bitwise(tf, tu, "pushed table")
    _bitwise(af, au, "pushed accum")

    touched = np.zeros(rows, bool)
    touched[np.unique(np.asarray(ids))] = True
    _bitwise(np.asarray(tf)[~touched], np.asarray(table)[~touched],
             "untouched rows")


# -------------------------------------------------------- cached backend
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_cached_fused_matches_unfused(seed):
    """Full-mirror CachedBackend: fused pull (double-indirection gather) and
    fused push (id->slot folded into the kernel index stream) are
    bit-identical to the unfused cache path across several steps, including
    the flushed-back table/accumulator."""
    rng = np.random.default_rng(seed)
    rows, dim, cap = 48, 6, 32
    opt = SparseAdagrad(SparseAdagradConfig(lr=0.1))
    table = jnp.asarray(rng.standard_normal((rows, dim)), jnp.float32)
    accum = jnp.full((rows, dim), 0.1, jnp.float32)

    steps = [
        (jnp.asarray(rng.integers(0, rows, 40), jnp.int32),
         jnp.asarray(rng.standard_normal((cap + 1, dim)), jnp.float32))
        for _ in range(3)
    ]

    def run(fused):
        be = CachedBackend(cache_rows=rows, fused=fused)
        t, a = be.prepare(table), jnp.array(accum)
        st_ = be.init_state(t)
        pulled = []
        for ids, row_g in steps:
            ws, t, a, st_ = be.pull(t, a, st_, ids, cap)
            pulled.append(ws.rows)
            # pipeline invariant: capacity-pad slots carry zero gradient
            # (uids are identical on both sides, so the masking is too)
            row_g = row_g.at[:cap].set(jnp.where(
                _pad_slots(ws.uids)[:, None], 0.0, row_g[:cap]))
            t, a, st_ = be.push(t, a, st_, ws, row_g, opt)
        t, a, st_ = be.flush(t, a, st_)
        return pulled, be.export(t), be.export(a)

    pu, tu, au = run(False)
    pf, tf, af = run(True)
    for i, (ru, rf) in enumerate(zip(pu, pf)):
        _bitwise(rf, ru, f"cached pulled rows, step {i}")
    _bitwise(tf, tu, "flushed table")
    _bitwise(af, au, "flushed accum")


# -------------------------------------------------------------- end-to-end
@pytest.mark.parametrize("placement", ["gather", "cached", "routed"])
def test_fit_fused_matches_unfused(placement):
    """Six online steps through the real trainer: the per-step loss floats
    are identical with ``fused_kernels`` off and on.  (For routed, fusion
    covers the bag only — the push stays inside the reverse route — so this
    doubles as the no-op-safety check.)"""
    from repro import configs
    from repro.data import synthetic as S
    from repro.runtime.factory import build_trainer
    from repro.runtime.online import fit_online
    from repro.runtime.trainer import TrainerConfig

    def run(fused):
        cfg = configs.get("baidu-ctr").smoke_cfg
        tcfg = TrainerConfig(
            n_pod=2, placement=placement, capacity=256,
            cache_rows=256 if placement == "cached" else None,
            fused_kernels=fused, log_every=1,
        )
        tr = build_trainer("baidu-ctr", tcfg, seed=3)
        gen = S.recsys_batches(cfg, batch=32, seed=5)
        hist, _ = fit_online(tr, gen, 6, window=5)
        return [float(h["loss"]) for h in hist]

    unfused, fused = run(False), run(True)
    assert len(unfused) == 6
    assert unfused == fused, f"loss drift: {unfused} vs {fused}"
