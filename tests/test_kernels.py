"""Per-kernel correctness: Pallas (interpret mode) vs the pure-jnp oracle,
swept over shapes and dtypes."""

import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.dot_interaction import dot_interaction_pallas
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.fused_adam import fused_adam_pallas
from repro.kernels.sparse_adagrad import (
    adagrad_row_updates,
    gather_rows_cached_pallas,
    sparse_adagrad_apply_pallas,
    sparse_adagrad_cached_apply_pallas,
    sparse_adagrad_pallas,
)

TOL = {jnp.float32: 1e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("C,D,nnz,nb,bag_blk,nnz_blk", [
    (64, 32, 256, 128, 32, 64),
    (128, 64, 512, 256, 256, 512),
    (256, 128, 1024, 64, 64, 128),
    (32, 8, 128, 512, 128, 128),
    # arbitrary geometries: nothing divides anything (cdiv grids + padding)
    (33, 17, 77, 13, 8, 32),
    (7, 5, 129, 50, 256, 512),
])
def test_embedding_bag(dtype, C, D, nnz, nb, bag_blk, nnz_blk):
    rng = np.random.default_rng(0)
    working = jnp.asarray(rng.standard_normal((C, D)), dtype)
    inv = jnp.asarray(rng.integers(0, C, nnz), jnp.int32)
    seg = jnp.asarray(rng.integers(0, nb, nnz), jnp.int32)
    w = jnp.asarray(rng.random(nnz), dtype)
    out = embedding_bag_pallas(working, inv, seg, w, nb,
                               bag_block=bag_blk, nnz_block=nnz_blk, interpret=True)
    expect = ref.embedding_bag_ref(working, inv, seg, w, nb)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        atol=TOL[dtype] * 10, rtol=TOL[dtype] * 10,
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,F,D,blk", [
    (64, 27, 32, 32), (128, 27, 128, 64), (32, 8, 16, 32), (256, 13, 64, 128),
])
def test_dot_interaction(dtype, B, F, D, blk):
    rng = np.random.default_rng(1)
    feats = jnp.asarray(rng.standard_normal((B, F, D)), dtype)
    out = dot_interaction_pallas(feats, batch_block=blk, interpret=True)
    expect = ref.dot_interaction_ref(feats)
    assert out.shape == (B, F * (F - 1) // 2)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        atol=TOL[dtype] * D, rtol=TOL[dtype] * 4,
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("C,D,nnz,nb", [(48, 16, 200, 31), (13, 7, 57, 9)])
def test_embedding_bag_exact_formulation(dtype, C, D, nnz, nb):
    """The scatter formulation (interpret default) is BIT-identical to the
    jnp segment-sum oracle — the fused-vs-unfused parity contract."""
    rng = np.random.default_rng(7)
    working = jnp.asarray(rng.standard_normal((C, D)), dtype)
    inv = jnp.asarray(rng.integers(0, C, nnz), jnp.int32)
    seg = jnp.asarray(rng.integers(0, nb, nnz), jnp.int32)
    w = jnp.asarray(rng.random(nnz), dtype)
    for weights in (w, None):
        out = embedding_bag_pallas(working, inv, seg, weights, nb,
                                   bag_block=8, interpret=True, exact=True)
        expect = ref.embedding_bag_ref(working, inv, seg, weights, nb)
        assert np.array_equal(np.asarray(out), np.asarray(expect)), (
            np.abs(np.asarray(out, np.float32)
                   - np.asarray(expect, np.float32)).max())


@pytest.mark.parametrize("n,blk", [(1 << 12, 1 << 10), (1 << 16, 1 << 14), (640, 64),
                                   (1000, 384)])  # uneven trailing block
@pytest.mark.parametrize("b1", [0.0, 0.9])
def test_fused_adam(n, blk, b1):
    rng = np.random.default_rng(2)
    p = jnp.asarray(rng.standard_normal(n), jnp.float32)
    g = jnp.asarray(rng.standard_normal(n), jnp.float32)
    m = jnp.asarray(rng.standard_normal(n), jnp.float32)
    v = jnp.asarray(rng.random(n) + 1e-8, jnp.float32)
    vh = jnp.asarray(rng.random(n) + 1e-3, jnp.float32)
    got = fused_adam_pallas(p, g, m, v, vh, lr=0.01, b1=b1, block=blk, interpret=True)
    want = ref.fused_adam_ref(p, g, m, v, vh, lr=0.01, b1=b1)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6, rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("C,D,blk", [(256, 64, 64), (512, 128, 512), (64, 16, 32),
                                     (100, 17, 48), (5, 3, 512)])  # uneven
def test_sparse_adagrad(dtype, C, D, blk):
    rng = np.random.default_rng(3)
    rows = jnp.asarray(rng.standard_normal((C, D)), dtype)
    accum = jnp.asarray(rng.random((C, D)) + 0.1, jnp.float32)
    grads = jnp.asarray(rng.standard_normal((C, D)), dtype)
    got = sparse_adagrad_pallas(rows, accum, grads, row_block=blk, interpret=True)
    want = ref.sparse_adagrad_ref(rows, accum, grads)
    for a, b in zip(got, want):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=TOL[dtype] * 5, rtol=TOL[dtype] * 5,
        )


def _push_case(seed, R=37, D=7, cap=9, n_real=5, dtype=jnp.float32):
    """A working-set push case shaped like pull_working_set output: sorted
    real ids, pads (= min real id) at the END with zero grads."""
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.standard_normal((R, D)), dtype)
    accum = jnp.asarray(rng.random((R, D)) + 0.1, jnp.float32)
    real = np.sort(rng.choice(R, size=n_real, replace=False))
    uids = jnp.asarray(
        np.concatenate([real, np.full(cap - n_real, real.min())]), jnp.int32)
    grads = jnp.asarray(rng.standard_normal((cap, D)) * 3, dtype)
    grads = grads.at[n_real:].set(0.0)
    return table, accum, uids, grads, real


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_sparse_adagrad_apply(dtype, seed):
    """The fused scatter push is BIT-identical to the unfused scatter: both
    consume the same pinned (delta, g2) from ``adagrad_row_updates`` and the
    kernel is pure data movement over the aliased table/accumulator."""
    table, accum, uids, grads, _ = _push_case(seed, dtype=dtype)
    delta, g2 = jax.jit(
        lambda a, g: adagrad_row_updates(a, g, table.dtype, lr=0.05, eps=1e-10)
    )(accum[uids], grads)
    want_t, want_a = jax.jit(ref.sparse_adagrad_apply_ref)(
        table, accum, uids, delta, g2)
    got_t, got_a = sparse_adagrad_apply_pallas(
        table, accum, uids, delta, g2, interpret=True)
    assert np.array_equal(np.asarray(got_t), np.asarray(want_t))
    assert np.array_equal(np.asarray(got_a), np.asarray(want_a))


@pytest.mark.parametrize("seed", [0, 1])
def test_gather_rows_cached(seed):
    """Slot-stream gather: out[i] = cache[slots[i]], exact — slots being the
    hash-probe output the cache tier feeds the kernel."""
    rng = np.random.default_rng(seed)
    SLOTS, D, cap = 16, 5, 7
    cache = jnp.asarray(rng.standard_normal((SLOTS, D)), jnp.float32)
    real_slots = rng.choice(SLOTS, size=cap - 2, replace=False)
    slots = jnp.asarray(
        np.concatenate([real_slots, np.full(2, real_slots[0])]), jnp.int32)
    got = gather_rows_cached_pallas(cache, slots, interpret=True)
    want = ref.gather_rows_cached_ref(cache, slots)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_sparse_adagrad_cached_apply(seed):
    """The cache-tier push kernel (probe output as the index stream) is
    bit-identical to the jnp scatter over the same slots."""
    rng = np.random.default_rng(seed + 10)
    SLOTS, D, cap, n_real = 16, 5, 7, 5
    cache = jnp.asarray(rng.standard_normal((SLOTS, D)), jnp.float32)
    caccum = jnp.asarray(rng.random((SLOTS, D)) + 0.1, jnp.float32)
    real_slots = rng.choice(SLOTS, size=n_real, replace=False)
    # pads share the first real id's slot and carry zero grads, exactly as
    # the cache tier's slot_now stream does
    slots = jnp.asarray(
        np.concatenate([real_slots, np.full(cap - n_real, real_slots[0])]),
        jnp.int32)
    grads = jnp.asarray(rng.standard_normal((cap, D)), jnp.float32)
    grads = grads.at[n_real:].set(0.0)
    delta, g2 = jax.jit(
        lambda a, g: adagrad_row_updates(a, g, cache.dtype, lr=0.05, eps=1e-10)
    )(caccum[slots], grads)
    want_t, want_a = jax.jit(ref.sparse_adagrad_apply_ref)(
        cache, caccum, slots, delta, g2)
    got_t, got_a = sparse_adagrad_cached_apply_pallas(
        cache, caccum, slots, delta, g2, interpret=True)
    assert np.array_equal(np.asarray(got_t), np.asarray(want_t))
    assert np.array_equal(np.asarray(got_a), np.asarray(want_a))


def test_ops_dispatch_ref_mode(monkeypatch):
    """Without the env flag on CPU, ops fall back to the oracle path."""
    monkeypatch.delenv("REPRO_KERNEL_INTERPRET", raising=False)
    from repro.kernels import ops
    rng = np.random.default_rng(4)
    feats = jnp.asarray(rng.standard_normal((8, 5, 4)), jnp.float32)
    out = ops.dot_interaction(feats)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.dot_interaction_ref(feats)))
    # new fused ops must dispatch (and bit-match their refs) in ref mode too
    table, accum, uids, grads, _ = _push_case(11)
    got_t, got_a = jax.jit(
        lambda *a: ops.sparse_adagrad_apply(*a, lr=0.05, eps=1e-10)
    )(table, accum, uids, grads)
    delta, g2 = adagrad_row_updates(accum[uids], grads, table.dtype,
                                    lr=0.05, eps=1e-10)
    want_t, want_a = ref.sparse_adagrad_apply_ref(table, accum, uids, delta, g2)
    assert np.array_equal(np.asarray(got_t), np.asarray(want_t))
    assert np.array_equal(np.asarray(got_a), np.asarray(want_a))


def test_fused_adam_defaults_match_kstep_config():
    """Loud-mismatch guard: ``ops.fused_adam``'s (b1, b2) defaults are
    single-sourced from ``KStepConfig`` (paper §5: b1=0.0, b2=0.999), and the
    kernel/ref signature defaults must agree — a drift here would silently
    train the benchmark path with a different optimizer than the trainer."""
    from repro.core.kstep import KStepConfig
    from repro.kernels import ops

    db1, db2 = ops.adam_defaults()
    assert (db1, db2) == (KStepConfig.b1, KStepConfig.b2)
    for fn in (ref.fused_adam_ref, fused_adam_pallas):
        sig = inspect.signature(fn)
        assert sig.parameters["b1"].default == KStepConfig.b1, (
            f"{fn.__name__} b1 default {sig.parameters['b1'].default} != "
            f"KStepConfig.b1 {KStepConfig.b1} — update the kernel default or "
            f"the config, they must not drift apart")
        assert sig.parameters["b2"].default == KStepConfig.b2, (
            f"{fn.__name__} b2 default {sig.parameters['b2'].default} != "
            f"KStepConfig.b2 {KStepConfig.b2}")
    # ops-level None resolves to the config values (one jnp-ref call)
    one = jnp.ones((4,), jnp.float32)
    got = ops.fused_adam(one, one, one, one, one)
    want = ref.fused_adam_ref(one, one, one, one, one,
                              b1=KStepConfig.b1, b2=KStepConfig.b2)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
