"""Per-kernel correctness: Pallas (interpret mode) vs the pure-jnp oracle,
swept over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.dot_interaction import dot_interaction_pallas
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.fused_adam import fused_adam_pallas
from repro.kernels.sparse_adagrad import sparse_adagrad_pallas

TOL = {jnp.float32: 1e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("C,D,nnz,nb,bag_blk,nnz_blk", [
    (64, 32, 256, 128, 32, 64),
    (128, 64, 512, 256, 256, 512),
    (256, 128, 1024, 64, 64, 128),
    (32, 8, 128, 512, 128, 128),
])
def test_embedding_bag(dtype, C, D, nnz, nb, bag_blk, nnz_blk):
    rng = np.random.default_rng(0)
    working = jnp.asarray(rng.standard_normal((C, D)), dtype)
    inv = jnp.asarray(rng.integers(0, C, nnz), jnp.int32)
    seg = jnp.asarray(rng.integers(0, nb, nnz), jnp.int32)
    w = jnp.asarray(rng.random(nnz), dtype)
    out = embedding_bag_pallas(working, inv, seg, w, nb,
                               bag_block=bag_blk, nnz_block=nnz_blk, interpret=True)
    expect = ref.embedding_bag_ref(working, inv, seg, w, nb)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        atol=TOL[dtype] * 10, rtol=TOL[dtype] * 10,
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,F,D,blk", [
    (64, 27, 32, 32), (128, 27, 128, 64), (32, 8, 16, 32), (256, 13, 64, 128),
])
def test_dot_interaction(dtype, B, F, D, blk):
    rng = np.random.default_rng(1)
    feats = jnp.asarray(rng.standard_normal((B, F, D)), dtype)
    out = dot_interaction_pallas(feats, batch_block=blk, interpret=True)
    expect = ref.dot_interaction_ref(feats)
    assert out.shape == (B, F * (F - 1) // 2)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        atol=TOL[dtype] * D, rtol=TOL[dtype] * 4,
    )


@pytest.mark.parametrize("n,blk", [(1 << 12, 1 << 10), (1 << 16, 1 << 14), (640, 64)])
@pytest.mark.parametrize("b1", [0.0, 0.9])
def test_fused_adam(n, blk, b1):
    rng = np.random.default_rng(2)
    p = jnp.asarray(rng.standard_normal(n), jnp.float32)
    g = jnp.asarray(rng.standard_normal(n), jnp.float32)
    m = jnp.asarray(rng.standard_normal(n), jnp.float32)
    v = jnp.asarray(rng.random(n) + 1e-8, jnp.float32)
    vh = jnp.asarray(rng.random(n) + 1e-3, jnp.float32)
    got = fused_adam_pallas(p, g, m, v, vh, lr=0.01, b1=b1, block=blk, interpret=True)
    want = ref.fused_adam_ref(p, g, m, v, vh, lr=0.01, b1=b1)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6, rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("C,D,blk", [(256, 64, 64), (512, 128, 512), (64, 16, 32)])
def test_sparse_adagrad(dtype, C, D, blk):
    rng = np.random.default_rng(3)
    rows = jnp.asarray(rng.standard_normal((C, D)), dtype)
    accum = jnp.asarray(rng.random((C, D)) + 0.1, jnp.float32)
    grads = jnp.asarray(rng.standard_normal((C, D)), dtype)
    got = sparse_adagrad_pallas(rows, accum, grads, row_block=blk, interpret=True)
    want = ref.sparse_adagrad_ref(rows, accum, grads)
    for a, b in zip(got, want):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=TOL[dtype] * 5, rtol=TOL[dtype] * 5,
        )


def test_ops_dispatch_ref_mode(monkeypatch):
    """Without the env flag on CPU, ops fall back to the oracle path."""
    monkeypatch.delenv("REPRO_KERNEL_INTERPRET", raising=False)
    from repro.kernels import ops
    rng = np.random.default_rng(4)
    feats = jnp.asarray(rng.standard_normal((8, 5, 4)), jnp.float32)
    out = ops.dot_interaction(feats)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.dot_interaction_ref(feats)))
