"""RowStore / three-level hierarchy: DiskStore unit behaviour, crash-safety
GC, and the ISSUE's acceptance parity — ``--store disk`` bit-identical to
``--store host`` across placements, with save->resume surviving the loss of
the spill directory."""

import os
import shutil

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core.row_store import (
    DiskStore,
    HostStore,
    make_store,
    sweep_stray_tmp,
)


# ------------------------------------------------------------------ helpers
def _mk_store(tmp_path, **kw):
    return DiskStore(str(tmp_path / "spill"), **kw)


def _init_fn(start, stop):
    # row r filled with r: page-local slicing errors show up as value errors
    return np.arange(start, stop, dtype=np.float32)[:, None] * np.ones(
        (1, 4), np.float32)


# --------------------------------------------------------------- unit: pages
def test_create_gather_roundtrip(tmp_path):
    st = _mk_store(tmp_path, page_rows=8)
    st.create_table("t", rows=50, dim=4, dtype=np.float32,
                    init_rows_fn=_init_fn, accum_init=0.25)
    uids = np.array([0, 7, 8, 49, 13], np.int64)   # page edges + last short page
    vals, acc = st.gather("t", uids)
    np.testing.assert_array_equal(vals, _init_fn(0, 50)[uids])
    np.testing.assert_array_equal(acc, np.full((5, 4), 0.25, np.float32))
    st.close()


def test_scatter_write_behind_and_flush(tmp_path):
    st = _mk_store(tmp_path, page_rows=8)
    st.create_table("t", rows=32, dim=4, dtype=np.float32)
    uids = np.array([3, 9, 31], np.int64)
    rows = np.full((3, 4), 7.0, np.float32)
    accum = np.full((3, 4), 2.0, np.float32)
    st.scatter("t", uids, rows, accum)
    # visible through the cache immediately...
    v, a = st.gather("t", uids)
    np.testing.assert_array_equal(v, rows)
    np.testing.assert_array_equal(a, accum)
    # ...and durable on disk after flush: a FRESH store sees the values
    st.flush()
    st.close()
    st2 = _mk_store(tmp_path, page_rows=8)
    st2.create_table("t", rows=32, dim=4, dtype=np.float32)  # adopts pages
    v, a = st2.gather("t", uids)
    np.testing.assert_array_equal(v, rows)
    np.testing.assert_array_equal(a, accum)
    st2.close()


def test_bounded_cache_evicts_and_stays_correct(tmp_path):
    st = _mk_store(tmp_path, page_rows=4, page_cache_pages=2)
    st.create_table("t", rows=64, dim=4, dtype=np.float32,
                    init_rows_fn=_init_fn)
    # touch every page, writing as we go — evictions must persist dirty pages
    for lo in range(0, 64, 4):
        uids = np.arange(lo, lo + 4, dtype=np.int64)
        v, a = st.gather("t", uids)
        st.scatter("t", uids, v + 1.0, a + 1.0)
    v, _ = st.gather("t", np.arange(64, dtype=np.int64))
    np.testing.assert_array_equal(v, _init_fn(0, 64) + 1.0)
    stats = st.stats()
    assert stats["pages_evicted"] > 0
    assert stats["disk_bytes_written"] > 0
    st.close()


def test_readahead_warms_pages(tmp_path):
    st = _mk_store(tmp_path, page_rows=8)
    st.create_table("t", rows=64, dim=4, dtype=np.float32)
    uids = np.array([1, 17, 42], np.int64)
    st.readahead("t", uids)
    # the reader thread is asynchronous — wait for it to drain
    import time
    for _ in range(100):
        if st._read_q.empty():
            break
        time.sleep(0.01)
    time.sleep(0.05)
    before = st.stats()
    st.gather("t", uids)
    after = st.stats()
    # all three pages were faulted in by the reader: gather only hits
    assert after["page_hits"] - before["page_hits"] == 3
    assert after["page_misses"] == before["page_misses"]
    st.close()


def test_snapshot_restore_roundtrip(tmp_path):
    st = _mk_store(tmp_path, page_rows=8)
    st.create_table("t", rows=20, dim=4, dtype=np.float32,
                    init_rows_fn=_init_fn, accum_init=0.5)
    snap = str(tmp_path / "snap")
    st.snapshot_to(snap)
    # mutate after the snapshot, then restore: mutation must vanish
    st.scatter("t", np.arange(20, dtype=np.int64),
               np.zeros((20, 4), np.float32), np.zeros((20, 4), np.float32))
    st.restore_from(snap)
    v, a = st.gather("t", np.arange(20, dtype=np.int64))
    np.testing.assert_array_equal(v, _init_fn(0, 20))
    np.testing.assert_array_equal(a, np.full((20, 4), 0.5, np.float32))
    st.close()


def test_restore_missing_page_raises(tmp_path):
    st = _mk_store(tmp_path, page_rows=8)
    st.create_table("t", rows=20, dim=4, dtype=np.float32)
    snap = str(tmp_path / "snap")
    st.snapshot_to(snap)
    os.remove(os.path.join(snap, "t", "page_000001.npz"))
    with pytest.raises(FileNotFoundError):
        st.restore_from(snap)
    st.close()


def test_make_store_validation(tmp_path):
    assert isinstance(make_store("host"), HostStore)
    with pytest.raises(ValueError, match="spill_dir is a disk-store option"):
        make_store("host", spill_dir=str(tmp_path))
    with pytest.raises(ValueError, match="requires spill_dir"):
        make_store("disk")
    with pytest.raises(ValueError, match="unknown store"):
        make_store("tape")
    with pytest.raises(ValueError, match="page_rows must be positive"):
        DiskStore(str(tmp_path / "s"), page_rows=0)
    with pytest.raises(ValueError, match="page_cache_pages must be positive"):
        DiskStore(str(tmp_path / "s"), page_cache_pages=0)


# -------------------------------------------------------- crash-safety / GC
def test_stray_tmp_swept_on_init_and_by_ckpt_gc(tmp_path):
    """Kill mid write-behind leaves ``<page>.npz.tmp`` wreckage: both the
    next DiskStore boot AND the CheckpointManager GC sweep it, and the
    complete predecessor page survives untouched."""
    spill = tmp_path / "spill"
    st = DiskStore(str(spill), page_rows=8)
    st.create_table("t", rows=16, dim=4, dtype=np.float32,
                    init_rows_fn=_init_fn)
    st.close()
    page = spill / "t" / "page_000000.npz"
    wreck = spill / "t" / "page_000000.npz.tmp"
    wreck.write_bytes(b"torn half-written page")
    # (a) CheckpointManager GC sweeps spill wreckage alongside ckpt wreckage
    ck = tmp_path / "ck"
    os.makedirs(ck / "pages_staging_00005")   # crashed pre-rename staging
    # age the wreckage past the staleness gate: the construction sweep
    # only takes OLD staging dirs — a fresh one may belong to a LIVE
    # trainer sharing the directory (e.g. an eval job constructing its
    # own manager against a running trainer's ckpt dir)
    import time

    from repro.checkpoint import ckpt as ckpt_mod
    old = time.time() - 2 * ckpt_mod._STAGING_STALE_S
    os.utime(ck / "pages_staging_00005", (old, old))
    os.makedirs(ck / "pages_staging_00006")   # fresh: could be live, keep
    mgr = CheckpointManager(str(ck), keep_last=2, save_every=1,
                            spill_dir=str(spill))
    # stale staging dirs are swept at CONSTRUCTION (this manager has no
    # writer yet, and nobody live has touched the dir for an hour)...
    assert not (ck / "pages_staging_00005").exists()
    # ...but a fresh staging dir survives — it may be another process's
    assert (ck / "pages_staging_00006").exists()
    shutil.rmtree(ck / "pages_staging_00006")
    # ...but never by _gc: it runs on the async writer thread, and a
    # staging dir present then may belong to the NEXT in-flight save
    # (the schedule audit's flush-vs-save cell caught _gc deleting one)
    os.makedirs(ck / "pages_staging_00007")
    mgr.save(1, {"a": np.zeros(3)})
    assert not wreck.exists()
    assert (ck / "pages_staging_00007").exists()
    # (b) a fresh boot over the same dir also sweeps (no manager needed)
    wreck.write_bytes(b"torn again")
    st2 = DiskStore(str(spill), page_rows=8)
    assert not wreck.exists()
    st2.create_table("t", rows=16, dim=4, dtype=np.float32)
    v, _ = st2.gather("t", np.arange(8, dtype=np.int64))
    np.testing.assert_array_equal(v, _init_fn(0, 8))  # old page intact
    st2.close()


def test_fault_window_race_with_writeback_retirement(tmp_path):
    """Lost-update regression: while a page fault reads its file with the
    lock released, a racing thread faults + scatters the same page, the
    dirty page is evicted into the write-behind queue, the write lands,
    AND the lookaside retires — all inside the fault window.  On
    reacquire both the cache and the lookaside are empty, so without the
    generation guard the fault would install its pre-scatter file bytes
    as a clean page, silently shadowing the scatter."""
    st = _mk_store(tmp_path, page_rows=4, page_cache_pages=1)
    st.create_table("t", rows=8, dim=2, dtype=np.float32)
    new_rows = np.full((2, 2), 5.0, np.float32)
    new_acc = np.full((2, 2), 1.0, np.float32)
    fired = []

    def interfere(key):
        # one-shot, page 0 only: the inner scatters re-enter the fault
        # path (for page 0 and page 1) and must not recurse
        if fired or key[1] != 0:
            return
        fired.append(key)
        # the racing thread, run inline in the fault window:
        st.scatter("t", np.array([0, 1], np.int64), new_rows, new_acc)
        # faulting page 1 into the 1-page cache evicts dirty page 0 into
        # the write-behind queue...
        st.scatter("t", np.array([4], np.int64),
                   np.full((1, 2), 9.0, np.float32),
                   np.full((1, 2), 2.0, np.float32))
        # ...and the real writer thread lands it + retires the lookaside
        st._write_q.join()

    st._fault_hook = interfere
    v, a = st.gather("t", np.arange(4, dtype=np.int64))
    assert fired, "fault hook never fired — page 0 was not faulted"
    np.testing.assert_array_equal(v[:2], new_rows)
    np.testing.assert_array_equal(a[:2], new_acc)
    np.testing.assert_array_equal(v[2:], np.zeros((2, 2), np.float32))
    st._fault_hook = None
    st.close()


def test_close_raises_on_wedged_worker(tmp_path, monkeypatch):
    """A worker still alive after the join timeout must fail close()
    loudly — a wedged IO thread may be mid page write."""
    import threading

    st = _mk_store(tmp_path, page_rows=8)
    st.create_table("t", rows=8, dim=2, dtype=np.float32)
    gate = threading.Event()

    def stuck(item):
        gate.wait()   # simulate a writer wedged in IO

    monkeypatch.setattr(st, "_process_write_item", stuck)
    st._write_q.put(("wedge", None))
    # flush would (correctly) block behind the wedged write — close()'s
    # join-timeout path is what we're testing, so skip it, and shrink the
    # 30s instance join to a no-op so the test stays fast
    monkeypatch.setattr(st, "flush", lambda: None)
    monkeypatch.setattr(st._writer, "join", lambda timeout=None: None)
    try:
        with pytest.raises(RuntimeError, match="still alive"):
            st.close()
    finally:
        gate.set()   # release the worker so the daemon thread can exit
        threading.Thread.join(st._writer, timeout=5)


def test_write_page_survives_concurrent_tmp_sweep(tmp_path):
    """The GC may delete a live write's .tmp between fsync and replace —
    the writer retries instead of dying (regression for the race)."""
    from repro.core import row_store as RS

    calls = {"n": 0}
    orig_replace = os.replace

    def flaky_replace(src, dst):
        if calls["n"] == 0 and src.endswith(".tmp"):
            calls["n"] += 1
            os.remove(src)              # the sweep got there first
            raise FileNotFoundError(src)
        return orig_replace(src, dst)

    path = str(tmp_path / "page_000000.npz")
    rows = np.ones((4, 2), np.float32)
    acc = np.zeros((4, 2), np.float32)
    import unittest.mock as mock
    with mock.patch.object(RS.os, "replace", side_effect=flaky_replace):
        RS._write_page_atomic(path, rows, acc)
    with np.load(path) as z:
        np.testing.assert_array_equal(z["rows"], rows)


def test_sweep_counts(tmp_path):
    (tmp_path / "a.npz.tmp").write_bytes(b"x")
    sub = tmp_path / "t"
    sub.mkdir()
    (sub / "b.npz.tmp").write_bytes(b"x")
    (sub / "keep.npz").write_bytes(b"x")
    assert sweep_stray_tmp(str(tmp_path)) == 2
    assert (sub / "keep.npz").exists()


# ------------------------------------------- integration: host/disk parity
def _trainer(placement, store, spill_dir, prefetch, ckpt_dir=None,
             page_cache_pages=None):
    from repro.core.kstep import KStepConfig
    from repro.core.sparse_optim import SparseAdagradConfig
    from repro.runtime.factory import build_trainer
    from repro.runtime.trainer import TrainerConfig

    tcfg = TrainerConfig(
        n_pod=2, kstep=KStepConfig(lr=1e-3, k=3, merge="two_phase"),
        sparse=SparseAdagradConfig(lr=0.5, initial_accumulator=0.01),
        placement=placement, prefetch=prefetch,
        store=store, spill_dir=spill_dir, page_rows=256 if spill_dir else None,
        page_cache_pages=page_cache_pages,
        ckpt_dir=ckpt_dir, ckpt_every=3, ckpt_async=False,
    )
    return build_trainer("baidu-ctr", tcfg)


def _batches(n, batch=48):
    from repro import configs
    from repro.data import synthetic as S

    cfg = configs.get("baidu-ctr").smoke_cfg
    gen = S.recsys_batches(cfg, batch=batch, seed=1)
    return [next(gen) for _ in range(n)]


def _final_rows(tr):
    """(rows, accum) per table from the authoritative store/placement."""
    eng = tr.engine
    if eng.store.kind == "disk":
        eng.sync_store(tr.tables, tr.sparse_state.accum, tr.backend_state)
        out = {}
        for n, s in eng.specs.items():
            out[n] = eng.store.gather(n, np.arange(s.rows, dtype=np.int64))
        return out
    ft, fa, _ = eng.flush(tr.tables, tr.sparse_state.accum, tr.backend_state)
    ex, exa = eng.export(ft), eng.export(fa)
    return {n: (np.asarray(ex[n]), np.asarray(exa[n])) for n in ex}


@pytest.mark.parametrize("placement", ["gather", "cached"])
def test_disk_bitwise_parity_with_host(placement, tmp_path):
    """The acceptance bar: full-mirror disk training is bit-identical to
    host training — losses, predictions, final rows, final accumulators —
    under both the sync and the prefetched pull."""
    batches = _batches(5)
    ref = _trainer(placement, "host", None, prefetch=False)
    ref_losses = [float(ref.train_step(b)) for b in batches]
    ref_pred = ref.predict(batches[0])
    ref_rows = _final_rows(ref)

    for prefetch in (False, True):
        spill = str(tmp_path / f"spill_{int(prefetch)}")
        tr = _trainer(placement, "disk", spill, prefetch=prefetch)
        losses = [float(tr.train_step(b)) for b in batches]
        assert losses == ref_losses
        np.testing.assert_array_equal(tr.predict(batches[0]), ref_pred)
        rows = _final_rows(tr)
        for n in ref_rows:
            np.testing.assert_array_equal(rows[n][0], ref_rows[n][0])
            np.testing.assert_array_equal(rows[n][1], ref_rows[n][1])
        tr.engine.store.close()


def test_disk_trains_beyond_page_cache_budget(tmp_path):
    """page_cache_pages smaller than the table's page count still trains —
    and stays bit-identical (the page cache is a cache, not a capacity)."""
    batches = _batches(4)
    ref = _trainer("gather", "host", None, prefetch=False)
    ref_losses = [float(ref.train_step(b)) for b in batches]

    tr = _trainer("gather", "disk", str(tmp_path / "spill"), prefetch=False,
                  page_cache_pages=4)   # 4*256 rows resident << table rows
    losses = [float(tr.train_step(b)) for b in batches]
    assert losses == ref_losses
    assert tr.engine.store.stats()["pages_evicted"] > 0
    tr.engine.store.close()


def test_disk_save_resume_replay_bitexact(tmp_path):
    """Crash after step 4 (last checkpoint at 3), lose the spill dir, resume
    into a FRESH one from the checkpoint pages, replay to 6: losses and the
    final store match the uninterrupted run bit-for-bit."""
    batches = _batches(6)

    ref = _trainer("cached", "disk", str(tmp_path / "s_ref"), prefetch=True,
                   ckpt_dir=str(tmp_path / "ck_ref"))
    ref_losses = [float(ref.train_step(b)) for b in batches]
    ref_rows = _final_rows(ref)
    ref.ckpt.wait()
    ref.engine.store.close()

    crash = _trainer("cached", "disk", str(tmp_path / "s1"), prefetch=True,
                     ckpt_dir=str(tmp_path / "ck"))
    for b in batches[:4]:
        crash.train_step(b)
    crash.ckpt.wait()
    shutil.rmtree(tmp_path / "s1")   # node loss: local SSD gone

    tr = _trainer("cached", "disk", str(tmp_path / "s2"), prefetch=True,
                  ckpt_dir=str(tmp_path / "ck"))
    assert tr.resume()
    start = tr.step_num
    assert start == 3
    losses = [float(tr.train_step(b)) for b in batches[start:]]
    assert losses == ref_losses[start:]
    rows = _final_rows(tr)
    for n in ref_rows:
        np.testing.assert_array_equal(rows[n][0], ref_rows[n][0])
        np.testing.assert_array_equal(rows[n][1], ref_rows[n][1])
    tr.ckpt.wait()
    tr.engine.store.close()


def test_resume_rejects_store_mismatch(tmp_path):
    """A host-store checkpoint must not silently resume as disk (and the
    layout guard says so out loud)."""
    batches = _batches(3)
    tr = _trainer("gather", "host", None, prefetch=False,
                  ckpt_dir=str(tmp_path / "ck"))
    for b in batches:
        tr.train_step(b)
    tr.ckpt.wait()

    tr2 = _trainer("gather", "disk", str(tmp_path / "spill"), prefetch=False,
                   ckpt_dir=str(tmp_path / "ck"))
    with pytest.raises(ValueError, match="store"):
        tr2.resume()
    tr2.engine.store.close()


def test_factory_rejects_bad_combos(tmp_path):
    with pytest.raises(ValueError, match="disk-store knobs"):
        _trainer("gather", "host", None, prefetch=False, page_cache_pages=4)
    from repro.core.kstep import KStepConfig
    from repro.core.sparse_optim import SparseAdagradConfig
    from repro.runtime.factory import build_trainer
    from repro.runtime.trainer import TrainerConfig

    tcfg = TrainerConfig(
        n_pod=2, kstep=KStepConfig(lr=1e-3, k=3, merge="two_phase"),
        sparse=SparseAdagradConfig(lr=0.5, initial_accumulator=0.01),
        placement="routed", store="disk", spill_dir=str(tmp_path / "s"),
    )
    with pytest.raises(NotImplementedError, match="routed"):
        build_trainer("baidu-ctr", tcfg)
