"""EmbeddingBackend contract: GatherBackend and RoutedBackend must be
interchangeable at lossless capacity — identical pulled rows and identical
post-push tables — and the config-driven factory must train through both.
The multi-shard routed case runs in a subprocess (device count locks at
jax init; same pattern as test_routed_embedding)."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.embedding_backend import (
    EmbeddingBackend,
    GatherBackend,
    RoutedBackend,
    make_backend,
)
from repro.core.kstep import KStepConfig
from repro.core.sparse_optim import SparseAdagrad, SparseAdagradConfig
from repro.data import synthetic as S
from repro.runtime.factory import build_trainer
from repro.runtime.trainer import TrainerConfig

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_backends_satisfy_protocol():
    assert isinstance(GatherBackend(), EmbeddingBackend)
    assert isinstance(make_backend("routed"), EmbeddingBackend)
    assert isinstance(make_backend("cached", cache_rows=8), EmbeddingBackend)


def test_gather_routed_parity_single_shard():
    """Same pulled rows, same post-push tables, on random id batches."""
    rng = np.random.default_rng(0)
    rows, dim, cap = 64, 8, 64
    opt = SparseAdagrad(SparseAdagradConfig(lr=0.1))
    gb, rb = GatherBackend(), make_backend("routed")

    table = jnp.asarray(rng.standard_normal((rows, dim)), jnp.float32)
    tg, tr = gb.prepare(table), rb.prepare(table)
    sg, sr = gb.init_state(tg), rb.init_state(tr)
    ag = jnp.full((rows, dim), 0.1, jnp.float32)
    ar = jnp.full((rows, dim), 0.1, jnp.float32)

    for step in range(3):
        ids = jnp.asarray(rng.integers(0, rows, 50), jnp.int32)
        wg, tg, ag, sg = gb.pull(tg, ag, sg, ids, cap)
        wr, tr, ar, sr = rb.pull(tr, ar, sr, ids, cap)
        assert int(wg.n_dropped) == 0 and int(wr.n_dropped) == 0
        np.testing.assert_array_equal(np.asarray(wg.uids), np.asarray(wr.uids))
        np.testing.assert_array_equal(np.asarray(wg.inverse), np.asarray(wr.inverse))
        np.testing.assert_allclose(np.asarray(wg.rows), np.asarray(wr.rows),
                                   atol=1e-6)
        slot_g = rng.standard_normal((50, dim)).astype(np.float32)
        row_g = np.zeros((cap, dim), np.float32)
        np.add.at(row_g, np.asarray(wg.inverse), slot_g)
        row_g = jnp.asarray(row_g)
        tg, ag, sg = gb.push(tg, ag, sg, wg, row_g, opt)
        tr, ar, sr = rb.push(tr, ar, sr, wr, row_g, opt)
        np.testing.assert_allclose(
            np.asarray(gb.export(tg)), np.asarray(rb.export(tr)), atol=1e-5
        )
        np.testing.assert_allclose(
            np.asarray(gb.export(ag)), np.asarray(rb.export(ar)), atol=1e-5
        )


def test_dedup_overflow_counted_and_graceful():
    """More distinct ids than capacity: counted on ALL backends, and the
    dropped slots read the zero drop row (finite lookups, no NaN fill)."""
    table = jnp.ones((32, 2), jnp.float32)
    ids = jnp.arange(16, dtype=jnp.int32)
    for backend in (GatherBackend(), make_backend("routed"),
                    make_backend("cached", cache_rows=16)):
        t = backend.prepare(table)
        accum = jnp.full(table.shape, 0.1, jnp.float32)
        state = backend.init_state(t)
        ws, _, _, _ = backend.pull(t, accum, state, ids, 8)
        assert int(ws.n_dropped) == 8
        looked_up = np.asarray(jnp.take(ws.rows, ws.inverse, axis=0))
        assert np.all(np.isfinite(looked_up))
        # served slots see real rows, dropped slots see zeros
        assert np.all(looked_up[:8] == 1.0) and np.all(looked_up[8:] == 0.0)
        ws2, _, _, _ = backend.pull(t, accum, backend.init_state(t), ids, 16)
        assert int(ws2.n_dropped) == 0


def test_make_backend_validation():
    import pytest
    with pytest.raises(ValueError, match="placement"):
        make_backend("bogus")
    with pytest.raises(TypeError, match="cache_rows"):
        make_backend("cached")
    with pytest.raises(TypeError, match="gather"):
        make_backend("gather", cache_rows=8)
    # shard axes absent from the mesh are ignored (single-pod spec reuse)
    rb = RoutedBackend(jax.make_mesh((1,), ("data",)),
                       shard_axes=("pod", "data", "model"))
    assert rb.shard_axes == ("data",) and rb.n_shards == 1


def test_gather_routed_parity_multi_shard():
    """8 host devices: the real all-to-all route vs the gather oracle."""
    run_sub("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.embedding_backend import GatherBackend, RoutedBackend
from repro.core.sparse_optim import SparseAdagrad, SparseAdagradConfig
from repro.launch.mesh import make_host_mesh

mesh = make_host_mesh(2, 2, 2)
rb = RoutedBackend(mesh, shard_axes=("pod", "data", "model"))
gb = GatherBackend()
assert rb.n_shards == 8
rows, dim, cap = 128, 4, 128   # cap >= any distinct-id count: lossless
rng = np.random.default_rng(0)
opt = SparseAdagrad(SparseAdagradConfig(lr=0.1))
table = jnp.asarray(rng.standard_normal((rows, dim)), jnp.float32)
tg, tr = gb.prepare(table), rb.prepare(table)
sg, sr = gb.init_state(tg), rb.init_state(tr)
ag = ar = jnp.full((rows, dim), 0.1, jnp.float32)
for _ in range(2):
    ids = jnp.asarray(rng.integers(0, rows, 100), jnp.int32)
    wg, tg, ag, sg = gb.pull(tg, ag, sg, ids, cap)
    wr, tr, ar, sr = rb.pull(tr, ar, sr, ids, cap)
    assert int(wg.n_dropped) == 0 and int(wr.n_dropped) == 0
    np.testing.assert_allclose(np.asarray(wg.rows), np.asarray(wr.rows), atol=1e-6)
    slot_g = rng.standard_normal((100, dim)).astype(np.float32)
    row_g = np.zeros((cap, dim), np.float32)
    np.add.at(row_g, np.asarray(wg.inverse), slot_g)
    row_g = jnp.asarray(row_g)
    tg, ag, sg = gb.push(tg, ag, sg, wg, row_g, opt)
    tr, ar, sr = rb.push(tr, ar, sr, wr, row_g, opt)
    np.testing.assert_allclose(np.asarray(gb.export(tg)),
                               np.asarray(rb.export(tr)), atol=1e-5)
    np.testing.assert_allclose(np.asarray(gb.export(ag)),
                               np.asarray(rb.export(ar)), atol=1e-5)
print("OK")
""")


# ---------------------------------------------------------------- factory
def _tcfg(placement):
    return TrainerConfig(
        n_pod=2, kstep=KStepConfig(lr=1e-3, k=5, b1=0.0),
        sparse=SparseAdagradConfig(lr=0.5, initial_accumulator=0.01),
        placement=placement, capacity=8192, log_every=5,
    )


def test_build_trainer_fit_smoke():
    """HybridTrainer.fit() through the config-driven factory."""
    tr = build_trainer("baidu-ctr", _tcfg("gather"))
    gen = S.ctr_batches(seed=1, batch=256, rows=20000, n_fields=8, nnz=20)
    hist = tr.fit(gen, 10)
    assert tr.step_num == 10
    assert [h["step"] for h in hist] == [5, 10]
    assert all(np.isfinite(h["loss"]) for h in hist)
    assert tr.overflow_dropped == 0


def test_build_trainer_placement_parity():
    """--placement routed/cached train end to end and match gather losses
    (cached runs with a full-mirror cache, its lossless configuration)."""
    losses = {}
    for placement in ("gather", "routed", "cached"):
        tcfg = _tcfg(placement)
        if placement == "cached":
            tcfg.cache_rows = 20000   # >= table rows: bit-identical regime
        tr = build_trainer("baidu-ctr", tcfg)
        gen = S.ctr_batches(seed=1, batch=256, rows=20000, n_fields=8, nnz=20)
        losses[placement] = [tr.train_step(next(gen)) for _ in range(5)]
    np.testing.assert_allclose(losses["gather"], losses["routed"], atol=1e-4)
    np.testing.assert_allclose(losses["gather"], losses["cached"], atol=1e-6)


def test_build_trainer_dense_families():
    """The factory also covers lm/gnn archs (DenseTrainer)."""
    tcfg = TrainerConfig(n_pod=2, kstep=KStepConfig(lr=1e-3, k=2, b1=0.9),
                         log_every=1)
    tr = build_trainer("qwen3-14b", tcfg)
    from repro import configs
    vocab = configs.get("qwen3-14b").smoke_cfg.vocab
    gen = S.lm_batches(seed=0, batch=8, seq_len=16, vocab=vocab)
    hist = tr.fit(gen, 2)
    assert tr.step_num == 2 and np.isfinite(hist[-1]["loss"])
