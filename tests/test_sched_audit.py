"""Layer-3 schedule audit: the deterministic replay machinery (schedules,
pump queues, comparators) plus one real disk cell and the pipeline cell
end to end."""
import numpy as np
import pytest

from repro.analysis.sched_audit import (
    Schedule,
    _PumpQueue,
    _Run,
    _runs_identical,
    cell_evict_vs_readahead,
    cell_fault_vs_writeback,
    default_schedules,
    run_sched_audit,
)


# ----------------------------------------------------------- the machinery
def test_schedule_cycles_and_fresh_resets():
    s = Schedule("alt", [1, 0])
    assert [s.take() for _ in range(4)] == [True, False, True, False]
    f = s.fresh()
    assert f.take() is True            # bit index starts over
    assert f.name == "alt" and f.pattern == [1, 0]


def test_schedule_rejects_empty_pattern():
    with pytest.raises(ValueError):
        Schedule("bad", [])


def test_default_schedules_cover_extremes():
    names = [s.name for s in default_schedules()]
    assert {"eager", "lazy", "alternate", "alternate-off"} <= set(names)
    # deterministic: two calls produce identical random streams
    a, b = default_schedules()[-1], default_schedules()[-1]
    assert a.pattern == b.pattern


def test_pump_queue_parks_and_replays_inline():
    done = []
    q = _PumpQueue(done.append)
    q.put("a")
    q.put(None)          # shutdown sentinel: ignored, no thread to stop
    q.put("b")
    q.put("c")
    assert done == [] and len(q) == 3    # parked, nothing ran
    assert q.pump(2) == 2
    assert done == ["a", "b"]            # FIFO replay on the caller
    q.join()
    assert done == ["a", "b", "c"] and len(q) == 0
    q.task_done()                        # no-op, present for Queue parity


def test_runs_identical_flags_divergence():
    ref = _Run([0.5, 0.25], [np.zeros(4)])
    ok = _runs_identical("t", "trajectory", "lazy", ref,
                         _Run([0.5, 0.25], [np.zeros(4)]))
    assert ok.ok
    bad = _runs_identical("t", "trajectory", "lazy", ref,
                          _Run([0.5, 0.2500001], [np.zeros(4)]))
    assert not bad.ok and "step 1" in bad.detail
    bad = _runs_identical("t", "trajectory", "lazy", ref,
                          _Run([0.5, 0.25], [np.ones(4)]))
    assert not bad.ok and "predict" in bad.detail


def test_unknown_cell_fails_fast():
    with pytest.raises(ValueError, match="no-such-cell"):
        run_sched_audit(cells=["no-such-cell"])


# ------------------------------------------------------------ end to end
def test_pipeline_cell_clean():
    findings, report = run_sched_audit(
        cells=["pipeline-producer"],
        schedules=[Schedule("eager", [1])],
    )
    assert findings == []
    assert [r["check"] for r in report] == ["pipeline", "pipeline"]
    assert all(r["ok"] for r in report)


def test_fault_cell_covers_both_retirement_orders():
    """The fault-window cell: whether the racing write-behind retires the
    lookaside inside the fault window (eager) or stays parked (lazy), the
    gather must observe the scattered rows and the page files converge."""
    results = cell_fault_vs_writeback(
        [Schedule("eager", [1]), Schedule("lazy", [0])])
    failed = [(r.check, r.detail) for r in results if not r.ok]
    assert failed == []
    checks = {r.check for r in results}
    assert checks == {"trajectory", "pages", "store-state"}


def test_evict_cell_bit_identical_across_two_schedules():
    """The real thing, scaled down: eager vs lazy replay over the paged
    disk store must produce identical trajectories, page files, and a
    clean post-flush store state."""
    results = cell_evict_vs_readahead(
        [Schedule("eager", [1]), Schedule("lazy", [0])], steps=4)
    failed = [(r.check, r.detail) for r in results if not r.ok]
    assert failed == []
    checks = {r.check for r in results}
    assert checks == {"trajectory", "pages", "store-state"}
