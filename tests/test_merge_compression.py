"""Merge schedules + payload compression: numerical contracts."""

import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic replay
    from tests._hypothesis_compat import given, settings, strategies as st

from repro.core import merge as merge_lib
from repro.core.compression import dequantize_int8, quantize_int8, quantization_residual
from repro.core.kstep import KStepAdam, KStepConfig, pod_replicate


def test_flat_mean_correct():
    x = {"a": jnp.arange(12.0).reshape(4, 3)}
    out = merge_lib.flat_mean(x)
    expect = np.broadcast_to(np.arange(12.0).reshape(4, 3).mean(0), (4, 3))
    np.testing.assert_allclose(np.asarray(out["a"]), expect, rtol=1e-6)


def test_two_phase_equals_flat_without_mesh():
    rng = np.random.default_rng(0)
    x = {"w": jnp.asarray(rng.standard_normal((3, 8, 5)), jnp.float32)}
    a = merge_lib.flat_mean(x)
    b = merge_lib.two_phase_mean(x, mesh=None)
    for u, v in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(u), np.asarray(v), rtol=1e-6)


@settings(max_examples=30, deadline=None)
@given(
    n_pod=st.integers(2, 6),
    n=st.integers(1, 64),
    scale=st.floats(1e-3, 1e3),
)
def test_int8_ef_error_bounded(n_pod, n, scale):
    """Quantized merge error is bounded by one quantization step, and the
    error-feedback residual exactly accounts for what was not transmitted."""
    rng = np.random.default_rng(n_pod * 31 + n)
    x = {"w": jnp.asarray(rng.standard_normal((n_pod, n)) * scale, jnp.float32)}
    ef = {"w": jnp.zeros((n_pod, n), jnp.float32)}
    merged, new_ef = merge_lib.int8_ef_mean(x, ef, mesh=None)
    true_mean = np.mean(np.asarray(x["w"]), axis=0)
    s = np.max(np.abs(np.asarray(x["w"]))) / 127.0 + 1e-30
    err = np.max(np.abs(np.asarray(merged["w"])[0] - true_mean))
    assert err <= s * n_pod + 1e-6, (err, s)
    # residuals bounded by one local quantization step
    assert np.max(np.abs(np.asarray(new_ef["w"]))) <= s * n_pod / 2 + 1e-6 + s


def test_ef_recovers_lost_mass_over_rounds():
    """With constant payload, EF-compressed merges converge to the true mean."""
    n_pod = 4
    rng = np.random.default_rng(3)
    payload = jnp.asarray(rng.standard_normal((n_pod, 32)), jnp.float32)
    ef = jnp.zeros_like(payload)
    true_mean = np.mean(np.asarray(payload), axis=0)
    acc = np.zeros(32)
    for r in range(1, 50):
        merged, ef_d = merge_lib.int8_ef_mean({"w": payload}, {"w": ef}, mesh=None)
        ef = ef_d["w"]
        acc += np.asarray(merged["w"])[0]
        # running average of transmitted means approaches the true mean
    np.testing.assert_allclose(acc / 49, true_mean, atol=2e-2)


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 1000), st.floats(1e-6, 1e4))
def test_quantize_roundtrip_bound(n, scale):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.standard_normal(n) * scale, jnp.float32)
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    assert float(jnp.max(jnp.abs(back - x))) <= float(s) / 2 + 1e-9 + float(s) * 1e-3
    resid = quantization_residual(x, q, s)
    np.testing.assert_allclose(np.asarray(back + resid), np.asarray(x), rtol=1e-6)


def test_int8_ef_merge_inside_optimizer_converges():
    """End-to-end: quadratic optimization under int8_ef merging reaches the
    optimum (error feedback does its job)."""
    n_pod = 4
    target = jnp.asarray(np.random.default_rng(0).standard_normal(16), jnp.float32)
    pp = pod_replicate({"x": jnp.zeros(16)}, n_pod)
    opt = KStepAdam(KStepConfig(lr=0.05, k=4, merge="int8_ef"), n_pod=n_pod)
    state = opt.init(pp)
    p = pp

    @jax.jit
    def step(p, state):
        g = jax.grad(
            lambda q: jnp.sum(jax.vmap(lambda qi: jnp.sum((qi["x"] - target) ** 2))(q))
        )(p)
        return opt.step(p, g, state)

    for t in range(300):
        p, state = step(p, state)
    final = np.asarray(jax.tree.leaves(p)[0]).mean(axis=0)
    # converges to the optimum up to the int8 quantization floor (~s*n_pod)
    np.testing.assert_allclose(final, np.asarray(target), atol=0.12)
