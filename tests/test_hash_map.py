"""Linear-probe hash map: Pallas probe vs jnp oracle vs a dense-map model.

The map replaces the cache tier's dense O(table_rows) id→slot array, so
the contract is *exactness*: for any sequence of admissions/evictions the
lookup must return precisely what the dense array would.  Collisions,
stale-entry reuse after eviction, and the occupancy-triggered rebuild are
the cases that can silently corrupt — each is pinned here.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.hash_map import (
    EMPTY,
    hash_bucket,
    hash_insert,
    hash_lookup_pallas,
    hash_rebuild,
    hash_table_size,
)


def _fresh(H):
    return (jnp.full((H,), EMPTY, jnp.int32), jnp.zeros((H,), jnp.int32),
            jnp.zeros((), jnp.int32))


def _insert(key_tab, slot_tab, n_occ, pairs):
    keys = jnp.asarray([k for k, _ in pairs], jnp.int32)
    slots = jnp.asarray([s for _, s in pairs], jnp.int32)
    mask = jnp.ones((len(pairs),), bool)
    return hash_insert(key_tab, slot_tab, n_occ, keys, slots, mask)


def _lookup_both(key_tab, slot_tab, slot_uid, uids):
    """The oracle and the kernel must agree bit-for-bit."""
    uids = jnp.asarray(uids, jnp.int32)
    want = ref.hash_lookup_ref(key_tab, slot_tab, slot_uid, uids)
    got = hash_lookup_pallas(key_tab, slot_tab, slot_uid, uids,
                             interpret=True)
    assert np.array_equal(np.asarray(got), np.asarray(want)), (
        "pallas probe diverged from jnp oracle")
    return np.asarray(want)


def test_table_size_bounds():
    for c in (1, 8, 100, 4096):
        H = hash_table_size(c)
        assert H >= 4 * c and H & (H - 1) == 0


def test_insert_lookup_roundtrip():
    C, H = 16, hash_table_size(16)
    key_tab, slot_tab, n_occ = _fresh(H)
    ids = np.array([3, 99, 1024, 7, 2**30, 0], np.int32)
    slots = np.arange(len(ids), dtype=np.int32)
    key_tab, slot_tab, n_occ = _insert(
        key_tab, slot_tab, n_occ, list(zip(ids, slots)))
    slot_uid = jnp.full((C,), -1, jnp.int32).at[slots].set(ids)
    got = _lookup_both(key_tab, slot_tab, slot_uid, ids)
    assert np.array_equal(got, slots)
    assert int(n_occ) == len(ids)
    # absent keys miss
    got = _lookup_both(key_tab, slot_tab, slot_uid, [5, 123456, 2**30 - 1])
    assert np.array_equal(got, [-1, -1, -1])


def test_forced_collisions_probe_past_occupied():
    """Keys engineered into one home bucket: every probe chain walks the
    same cluster and still resolves each key exactly."""
    C = 8
    H = hash_table_size(C)
    # mine ids that collide in their home bucket
    cand = np.arange(0, 200000, dtype=np.int32)
    buckets = np.asarray(hash_bucket(jnp.asarray(cand), H))
    target = buckets[0]
    ids = cand[buckets == target][:6]
    assert len(ids) == 6, "need 6 colliding ids for the test"
    key_tab, slot_tab, n_occ = _fresh(H)
    slots = np.arange(6, dtype=np.int32)
    key_tab, slot_tab, n_occ = _insert(
        key_tab, slot_tab, n_occ, list(zip(ids, slots)))
    # the cluster is exactly 6 consecutive buckets from the shared home
    kt = np.asarray(key_tab)
    assert sorted(np.nonzero(kt != EMPTY)[0].tolist()) == sorted(
        ((int(target) + i) & (H - 1)) for i in range(6))
    slot_uid = jnp.full((C,), -1, jnp.int32).at[slots].set(ids)
    got = _lookup_both(key_tab, slot_tab, slot_uid, ids)
    assert np.array_equal(got, slots)
    # a 7th id with the same home bucket misses (probe walks the whole
    # cluster and stops at the first EMPTY)
    extra = cand[buckets == target][6]
    got = _lookup_both(key_tab, slot_tab, slot_uid, [extra])
    assert got[0] == -1


def test_eviction_stale_entry_and_reuse():
    """Evicting id A (slot reassigned via slot_uid) makes A's entry stale —
    lookup must miss, NOT return the old slot — and re-admitting A must
    reuse the stale bucket in place (never two buckets for one key)."""
    C = 4
    H = hash_table_size(C)
    key_tab, slot_tab, n_occ = _fresh(H)
    key_tab, slot_tab, n_occ = _insert(
        key_tab, slot_tab, n_occ, [(10, 0), (20, 1)])
    slot_uid = jnp.asarray([10, 20, -1, -1], jnp.int32)
    assert np.array_equal(
        _lookup_both(key_tab, slot_tab, slot_uid, [10, 20]), [0, 1])

    # evict 10: slot 0 now belongs to 30
    slot_uid = jnp.asarray([30, 20, -1, -1], jnp.int32)
    key_tab, slot_tab, n_occ = _insert(key_tab, slot_tab, n_occ, [(30, 0)])
    got = _lookup_both(key_tab, slot_tab, slot_uid, [10, 20, 30])
    assert np.array_equal(got, [-1, 1, 0])

    # re-admit 10 into slot 2: the stale bucket is reused, occupancy
    # does not grow for it
    occ_before = int(n_occ)
    key_tab, slot_tab, n_occ = _insert(key_tab, slot_tab, n_occ, [(10, 2)])
    slot_uid = jnp.asarray([30, 20, 10, -1], jnp.int32)
    got = _lookup_both(key_tab, slot_tab, slot_uid, [10, 20, 30])
    assert np.array_equal(got, [2, 1, 0])
    assert int(n_occ) == occ_before  # reuse must not grow occupancy
    assert int(np.sum(np.asarray(key_tab) == 10)) == 1, (
        "re-admission must reuse the stale bucket, not open a second one")


def test_rebuild_drops_stale_keeps_live():
    C = 8
    H = hash_table_size(C)
    key_tab, slot_tab, n_occ = _fresh(H)
    pairs = [(i * 17 + 3, i) for i in range(C)]
    key_tab, slot_tab, n_occ = _insert(key_tab, slot_tab, n_occ, pairs)
    # half the slots get reassigned (stale entries pile up)
    live = [(k if i % 2 == 0 else k + 1000, i) for i, (k, _) in
            zip(range(C), pairs)]
    slot_uid = jnp.asarray([k for k, _ in live], jnp.int32)
    key_tab2, slot_tab2, n_occ2 = hash_rebuild(slot_uid, H)
    assert int(n_occ2) == C
    got = _lookup_both(key_tab2, slot_tab2, slot_uid, [k for k, _ in live])
    assert np.array_equal(got, np.arange(C))
    # the stale (evicted) keys are gone entirely
    stale = [k for i, (k, _) in zip(range(C), pairs) if i % 2 == 1]
    got = _lookup_both(key_tab2, slot_tab2, slot_uid, stale)
    assert np.array_equal(got, -np.ones(len(stale)))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_random_churn_matches_dense_map(seed):
    """Fuzz admission/eviction churn against a dense id→slot model array:
    after every round the probe (oracle AND kernel) must equal the dense
    truth for hits, misses, and evicted ids alike."""
    rng = np.random.default_rng(seed)
    C, R = 32, 500
    H = hash_table_size(C)
    key_tab, slot_tab, n_occ = _fresh(H)
    slot_uid = np.full((C,), -1, np.int32)
    dense = np.full((R,), -1, np.int32)
    for _ in range(30):
        k = rng.integers(1, 9)
        ids = rng.choice(R, size=k, replace=False).astype(np.int32)
        ids = ids[dense[ids] < 0]  # admit only ids not currently cached
        if len(ids) == 0:
            continue
        victims = rng.choice(C, size=len(ids), replace=False).astype(np.int32)
        for v in victims:  # evict whoever held the victim slot
            old = slot_uid[v]
            if old >= 0:
                dense[old] = -1
        slot_uid[victims] = ids
        dense[ids] = victims
        key_tab, slot_tab, n_occ = _insert(
            key_tab, slot_tab, n_occ, list(zip(ids, victims)))
        probe_ids = rng.choice(R, size=64).astype(np.int32)
        got = _lookup_both(key_tab, slot_tab, jnp.asarray(slot_uid),
                           probe_ids)
        assert np.array_equal(got, dense[probe_ids])


def test_insert_conflicting_claims_one_round():
    """Several keys whose chains race for the same EMPTY buckets in one
    batch insert: all must land, each findable, no bucket double-booked."""
    C = 8
    H = hash_table_size(C)
    cand = np.arange(0, 200000, dtype=np.int32)
    buckets = np.asarray(hash_bucket(jnp.asarray(cand), H))
    target = buckets[0]
    ids = cand[buckets == target][:5]
    key_tab, slot_tab, n_occ = _fresh(H)
    slots = np.arange(5, dtype=np.int32)
    key_tab, slot_tab, n_occ = _insert(
        key_tab, slot_tab, n_occ, list(zip(ids, slots)))
    kt = np.asarray(key_tab)
    assert int(n_occ) == 5 == int(np.sum(kt != EMPTY))
    slot_uid = jnp.full((C,), -1, jnp.int32).at[slots].set(ids)
    got = _lookup_both(key_tab, slot_tab, slot_uid, ids)
    assert np.array_equal(got, slots)
