"""Co-located CTR serving tier (``runtime/serve_ctr.py``) + the read-only
lookup contract under it.

Acceptance properties (ISSUE: split pull into training vs serving lookups):
  - ``lookup`` NEVER mutates: device state (tables, accum, backend state)
    and host store stats are bit-identical across any number of predicts,
    for every placement and store,
  - the training loss trajectory is BIT-identical with and without a
    co-located server draining between steps, across placement x prefetch
    x store,
  - training-interval stats (``sparse_metrics``) never move on serving
    traffic; the serve-side meters (``serve_metrics``) do,
  - the server's dynamic batching (FIFO order, tail padding to the static
    batch) returns exactly ``trainer.predict``'s scores per instance,
  - rows trained at step t are servable immediately after the commit
    boundary (freshness), and the disk-store lookup overlay serves values
    bit-identical to the host store even while a prefetched pull is
    pending.
"""

import collections

import jax
import numpy as np
import pytest

from repro import configs
from repro.core.kstep import KStepConfig
from repro.core.sparse_optim import SparseAdagradConfig
from repro.data import synthetic as S
from repro.runtime.factory import build_ctr_server, build_trainer
from repro.runtime.serve_ctr import CTRServer, requests_from_batch
from repro.runtime.trainer import TrainerConfig

SMOKE = configs.get("baidu-ctr").smoke_cfg


def _tcfg(placement, prefetch=False, store="host", spill_dir=None):
    return TrainerConfig(
        n_pod=1, kstep=KStepConfig(lr=1e-3, k=3, b1=0.0),
        sparse=SparseAdagradConfig(lr=0.5, initial_accumulator=0.01),
        placement=placement, prefetch=prefetch, log_every=1000,
        store=store, spill_dir=spill_dir,
        page_rows=64 if store == "disk" else None,
    )


def _batches(n, seed=3, batch=32):
    gen = S.recsys_batches(SMOKE, batch=batch, seed=seed)
    return [next(gen) for _ in range(n)]


def _snapshot(tr):
    leaves = jax.tree.leaves(
        (tr.tables, tr.sparse_state.accum, tr.backend_state))
    return ([np.asarray(jax.device_get(x)).copy() for x in leaves],
            dict(tr.engine.store.stats()))


# --------------------------------------------------------- never mutates
@pytest.mark.parametrize("placement", ["gather", "routed", "cached"])
def test_lookup_never_mutates(placement):
    """Serving reads leave every byte of sparse training state — and the
    store's training-side meters — untouched."""
    tr = build_trainer("baidu-ctr", _tcfg(placement), smoke=True)
    batches = _batches(6)
    for b in batches[:2]:
        tr.train_step(b)
    before, stats_before = _snapshot(tr)
    for b in batches[2:]:
        tr.predict(b)
    after, stats_after = _snapshot(tr)
    for a, b_ in zip(before, after):
        np.testing.assert_array_equal(a, b_)
    assert stats_before == stats_after


def test_lookup_never_mutates_disk(tmp_path):
    """Disk store: predict's page reads are serve-metered; the training
    stats bucket and the pending staged state stay untouched."""
    tr = build_trainer(
        "baidu-ctr", _tcfg("cached", store="disk", spill_dir=str(tmp_path)),
        smoke=True)
    batches = _batches(6)
    for b in batches[:2]:
        tr.train_step(b)
    before, stats_before = _snapshot(tr)
    for b in batches[2:]:
        tr.predict(b)
    after, stats_after = _snapshot(tr)
    for a, b_ in zip(before, after):
        np.testing.assert_array_equal(a, b_)
    assert stats_before == stats_after
    assert tr.engine.store.serve_stats()["page_hits"] + \
        tr.engine.store.serve_stats()["page_misses"] > 0


# ------------------------------------------------- trajectory invariance
def _run(serve, placement, prefetch, store, spill_dir, n=6):
    tr = build_trainer(
        "baidu-ctr",
        _tcfg(placement, prefetch=prefetch, store=store,
              spill_dir=spill_dir),
        smoke=True)
    batches = _batches(n)
    serve_batches = _batches(n, seed=77)
    srv = build_ctr_server(tr, max_batch=16) if serve else None
    losses = []
    for b, sb in zip(batches, serve_batches):
        if prefetch:
            tr.prefetch(b)
        if serve:
            srv.submit_batch(sb)   # drains MID-FLIGHT under prefetch
            srv.drain()
        losses.append(float(tr.train_step(b)))
    if serve:
        assert srv.stats["served"] == sum(
            len(next(iter(sb.values()))) for sb in serve_batches)
    return losses, tr


@pytest.mark.parametrize("placement", ["gather", "cached"])
@pytest.mark.parametrize("prefetch", [False, True])
@pytest.mark.parametrize("store", ["host", "disk"])
def test_fit_trajectory_invariant_under_serving(
        placement, prefetch, store, tmp_path):
    """The tentpole acceptance: interleaving a co-located server changes
    NOTHING about training — loss trajectory and final sparse state are
    bit-identical, in every placement x prefetch x store cell."""
    d_a = str(tmp_path / "a") if store == "disk" else None
    d_b = str(tmp_path / "b") if store == "disk" else None
    if store == "disk":
        (tmp_path / "a").mkdir()
        (tmp_path / "b").mkdir()
    base, tr_a = _run(False, placement, prefetch, store, d_a)
    served, tr_b = _run(True, placement, prefetch, store, d_b)
    assert base == served
    a_leaves, _ = _snapshot(tr_a)
    b_leaves, _ = _snapshot(tr_b)
    for a, b_ in zip(a_leaves, b_leaves):
        np.testing.assert_array_equal(a, b_)


def test_training_stats_invariant_serve_meters_advance():
    """Satellite regression: serving traffic must not move the
    training-interval cache stats; it lands in ``serve_metrics``."""
    tr = build_trainer("baidu-ctr", _tcfg("cached"), smoke=True)
    batches = _batches(8)
    for b in batches[:4]:
        tr.train_step(b)
    ref = tr.sparse_metrics()          # non-advancing window read
    assert tr.serve_metrics() == {}    # no serving traffic yet
    for b in batches[4:]:
        tr.predict(b)
    assert tr.sparse_metrics() == ref  # invariant under serving
    m = tr.serve_metrics()
    assert m["serve_requests"] == 4 * len(batches[0]["label"])
    assert m["serve_lookups"] > 0 and 0.0 <= m["serve_hit_rate"] <= 1.0


# ------------------------------------------------------- server mechanics
def test_server_fifo_batching_and_padding():
    """Dynamic batches preserve FIFO order; a short tail batch pads up to
    ``max_batch`` and still returns each request its own
    ``trainer.predict`` score."""
    tr = build_trainer("baidu-ctr", _tcfg("gather"), smoke=True)
    tr.train_step(_batches(1)[0])
    b = _batches(1, seed=21, batch=24)[0]    # 24 = 16 + tail of 8
    srv = build_ctr_server(tr, max_batch=16)
    reqs = requests_from_batch(b)
    for r in reqs:
        srv.submit(r)
    assert isinstance(srv.pending, collections.deque)
    srv.drain()
    assert srv.stats["served"] == 24 and srv.stats["steps"] == 2
    ref = tr.predict({k: v for k, v in b.items() if k != "label"})
    got = np.asarray([r.score for r in reqs])
    np.testing.assert_allclose(got, ref, rtol=1e-6)
    assert len(srv.latencies) == 24
    p = srv.latency_percentiles()
    assert p["p99"] >= p["p50"] > 0.0


def test_batched_server_queue_is_deque():
    """Satellite: the LM server's admission queue shares the deque shape
    (list.pop(0) was O(depth) per refilled slot)."""
    import jax.numpy as jnp
    from repro.models import transformer as tfm
    from repro.runtime.serve import BatchedServer

    cfg = tfm.TransformerConfig(
        n_layers=1, d_model=16, n_heads=2, n_kv_heads=1, d_ff=32,
        vocab=32, dtype=jnp.float32, moe_group_size=16)
    srv = BatchedServer(tfm.init_params(jax.random.key(0), cfg),
                        cfg, slots=2, max_len=8)
    assert isinstance(srv.pending, collections.deque)


def test_build_ctr_server_rejects_dense():
    with pytest.raises(TypeError, match="HybridTrainer"):
        build_ctr_server(object())


# ------------------------------------------------------------- freshness
def test_freshly_trained_rows_servable():
    """A row updated by the step-t push is served at the next boundary:
    scoring the SAME instances straddling a train step on their ids must
    change (the server reads live tables, not a stale snapshot)."""
    tr = build_trainer("baidu-ctr", _tcfg("cached"), smoke=True)
    b = _batches(1)[0]
    feats = {k: v for k, v in b.items() if k != "label"}
    srv = build_ctr_server(tr, max_batch=32)

    reqs = requests_from_batch(b)
    for r in reqs:
        srv.submit(r)
    srv.drain()
    before = np.asarray([r.score for r in reqs])

    tr.train_step(b)                         # trains exactly these ids

    reqs2 = requests_from_batch(b)
    for r in reqs2:
        srv.submit(r)
    srv.drain()
    after = np.asarray([r.score for r in reqs2])
    assert not np.array_equal(before, after)
    # and the served scores agree with the live predict
    np.testing.assert_allclose(after, tr.predict(feats), rtol=1e-6)


def test_disk_lookup_matches_host_mid_flight(tmp_path):
    """The disk lookup's pending-output overlay is exact: predictions under
    ``store=disk`` equal the host-store reference bit-for-bit even while a
    prefetched pull (with un-absorbed staged outputs) is in flight."""
    host = build_trainer("baidu-ctr", _tcfg("cached", prefetch=True),
                         smoke=True)
    disk = build_trainer(
        "baidu-ctr",
        _tcfg("cached", prefetch=True, store="disk",
              spill_dir=str(tmp_path)),
        smoke=True)
    batches = _batches(5)
    probe = {k: v for k, v in _batches(1, seed=55)[0].items()
             if k != "label"}
    for i, b in enumerate(batches):
        host.prefetch(b)
        disk.prefetch(b)
        # mid-flight: the speculative pull for b is pending in both
        np.testing.assert_array_equal(host.predict(probe),
                                      disk.predict(probe))
        host.train_step(b)
        disk.train_step(b)
    np.testing.assert_array_equal(host.predict(probe), disk.predict(probe))
