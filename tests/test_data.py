"""Data layer: generators, prefetch pipeline, neighbor sampler."""

import time

import numpy as np
import pytest

from repro.data import synthetic as S
from repro.data.graph_sampler import NeighborSampler
from repro.data.pipeline import PrefetchPipeline, serialized_baseline
from repro.runtime.metrics import auc


def test_ctr_stream_learnable_and_deterministic():
    g1 = S.ctr_batches(seed=5, batch=512, rows=1000, n_fields=4, nnz=10)
    g2 = S.ctr_batches(seed=5, batch=512, rows=1000, n_fields=4, nnz=10)
    b1, b2 = next(g1), next(g2)
    np.testing.assert_array_equal(b1["ids"], b2["ids"])
    # teacher separability: true-weight scores beat chance comfortably
    sc = (S._id_weights(b1["ids"]) * b1["mask"]).sum(1)
    assert auc(b1["label"], sc) > 0.65


def test_worker_shards_differ():
    a = next(S.ctr_batches(seed=5, batch=64, rows=1000, worker=0))
    b = next(S.ctr_batches(seed=5, batch=64, rows=1000, worker=1))
    assert not np.array_equal(a["ids"], b["ids"])


def test_dlrm_and_din_streams():
    d = next(S.dlrm_batches(seed=0, batch=128, rows=[50] * 26))
    assert d["sparse_ids"].shape == (128, 26)
    assert d["sparse_ids"].max() < 50
    b = next(S.din_batches(seed=0, batch=128, vocab=500))
    assert b["hist_ids"].shape == (128, 100)
    assert set(np.unique(b["label"])) <= {0.0, 1.0}


def test_lm_stream_is_learnable():
    b = next(S.lm_batches(seed=0, batch=4, seq_len=16, vocab=64))
    # ~95% of transitions follow the affine rule
    nxt = (b["tokens"] * 31 + 17) % 64
    frac = np.mean(nxt[:, :] == b["labels"][:, :])
    assert frac > 0.8


def test_community_graph_homophily():
    g = S.community_graph(seed=0, n_nodes=500, avg_degree=8, d_feat=16, n_classes=4)
    same = np.mean(g.labels[g.edge_src] == g.labels[g.edge_dst])
    assert same > 0.6  # intra-community edges dominate


def test_molecule_batches_disjoint():
    b = next(S.molecule_batches(seed=0, batch=4, n_nodes=5, n_edges=6,
                                d_feat=3, n_classes=2))
    assert b["x"].shape == (20, 3)
    # edges stay within their graph's node range
    gid_src = b["edge_src"] // 5
    gid_dst = b["edge_dst"] // 5
    np.testing.assert_array_equal(gid_src, gid_dst)


# ------------------------------------------------------------ prefetching
def test_prefetch_pipeline_overlap():
    def slow_source():
        for i in range(8):
            yield i

    def stage(x):
        time.sleep(0.02)
        return x * 2

    pipe = PrefetchPipeline(slow_source(), depth=2, stage_fn=stage)
    out = []
    for item in pipe:
        time.sleep(0.02)  # consumer work overlaps producer staging
        out.append(item)
    assert out == [i * 2 for i in range(8)]
    # overlapped: consumer wait should be well below total staging time
    assert pipe.wait_seconds < pipe.read_seconds + 0.1


def test_serialized_baseline():
    src = iter(range(5))
    out, secs = serialized_baseline(src, lambda x: x + 1, 5)
    assert out == [1, 2, 3, 4, 5]
    assert secs >= 0.0


def test_prefetch_pipeline_producer_exception_reraised():
    def failing_source():
        yield 1
        yield 2
        raise ValueError("disk gone")

    pipe = PrefetchPipeline(failing_source(), depth=2)
    assert next(pipe) == 1
    assert next(pipe) == 2
    with pytest.raises(RuntimeError, match="producer failed") as ei:
        next(pipe)
    assert isinstance(ei.value.__cause__, ValueError)
    assert str(ei.value.__cause__) == "disk gone"
    # sticky: every subsequent next() re-raises instead of blocking on a
    # queue the dead producer will never feed
    with pytest.raises(RuntimeError, match="producer failed"):
        next(pipe)
    pipe.close()


def test_prefetch_pipeline_stage_fn_exception_reraised():
    def bad_stage(x):
        if x == 3:
            raise KeyError("bad batch")
        return x

    pipe = PrefetchPipeline(iter(range(6)), depth=2, stage_fn=bad_stage)
    assert [next(pipe) for _ in range(3)] == [0, 1, 2]
    with pytest.raises(RuntimeError, match="producer failed") as ei:
        next(pipe)
    assert isinstance(ei.value.__cause__, KeyError)
    pipe.close()
    assert not pipe._thread.is_alive()


def test_prefetch_pipeline_close_joins_producer():
    pipe = PrefetchPipeline(iter(range(10_000)), depth=2)
    assert next(pipe) == 0
    pipe.close()
    assert not pipe._thread.is_alive()


# --------------------------------------------------------------- sampler
def test_neighbor_sampler_edges_valid():
    rng = np.random.default_rng(0)
    n, e = 200, 2000
    src = rng.integers(0, n, e).astype(np.int64)
    dst = rng.integers(0, n, e).astype(np.int64)
    sampler = NeighborSampler(n, src, dst)
    seeds = rng.choice(n, 16, replace=False)
    block = sampler.sample_block(rng, seeds, fanouts=(4, 3))
    n_real = int(block["n_real_nodes"])
    assert n_real <= NeighborSampler.worst_case_nodes(16, (4, 3))
    nodes = block["nodes"][:n_real]
    # every sampled edge must exist in the original graph
    edge_set = set(zip(src.tolist(), dst.tolist()))
    m = block["edge_mask"].astype(bool)
    for s_loc, d_loc in zip(block["edge_src"][m], block["edge_dst"][m]):
        gs, gd = int(nodes[s_loc]), int(nodes[d_loc])
        assert (gs, gd) in edge_set, (gs, gd)
    # all seeds present and flagged
    seed_locs = np.searchsorted(nodes, np.unique(seeds))
    assert np.all(block["seed_mask"][seed_locs] == 1.0)
    assert block["seed_mask"].sum() == len(np.unique(seeds))


def test_sampler_respects_fanout_caps():
    rng = np.random.default_rng(1)
    n, e = 100, 1500
    src = rng.integers(0, n, e).astype(np.int64)
    dst = rng.integers(0, n, e).astype(np.int64)
    sampler = NeighborSampler(n, src, dst)
    seeds = np.arange(8)
    block = sampler.sample_block(rng, seeds, fanouts=(5, 2))
    assert block["edge_src"].shape[0] == NeighborSampler.worst_case_edges(8, (5, 2))
    m = block["edge_mask"].astype(bool)
    assert m.sum() <= NeighborSampler.worst_case_edges(8, (5, 2))


def test_sampler_isolated_nodes():
    # node 0 has no in-edges: sampling from it yields masked edges only
    src = np.asarray([1, 2], np.int64)
    dst = np.asarray([2, 1], np.int64)
    sampler = NeighborSampler(3, src, dst)
    rng = np.random.default_rng(0)
    block = sampler.sample_block(rng, np.asarray([0]), fanouts=(2,))
    assert block["edge_mask"].sum() == 0
