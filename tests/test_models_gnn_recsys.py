"""GIN + recsys model correctness."""

import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic replay
    from tests._hypothesis_compat import given, settings, strategies as st

from repro.models import gin as G
from repro.models import recsys as R


# ------------------------------------------------------------------- GIN
@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(3, 40),
    e=st.integers(1, 150),
    seed=st.integers(0, 99),
)
def test_gin_matches_dense_adjacency(n, e, seed):
    rng = np.random.default_rng(seed)
    cfg = G.GINConfig(n_layers=3, d_in=6, d_hidden=8, n_classes=3)
    params = G.init_params(jax.random.key(seed), cfg)
    x = jnp.asarray(rng.standard_normal((n, 6)), jnp.float32)
    src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    out = G.forward(params, x, src, dst, cfg)
    adj = jnp.zeros((n, n)).at[src, dst].add(1.0)
    ref = G.dense_reference_forward(params, x, adj, cfg)
    # f32 accumulation order differs (segment_sum vs matmul); relus amplify
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-2)


def test_gin_edge_mask_removes_messages():
    cfg = G.GINConfig(n_layers=2, d_in=4, d_hidden=8, n_classes=2)
    params = G.init_params(jax.random.key(0), cfg)
    x = jnp.ones((6, 4))
    src = jnp.asarray([0, 1, 2], jnp.int32)
    dst = jnp.asarray([3, 4, 5], jnp.int32)
    full = G.forward(params, x, src, dst, cfg,
                     edge_mask=jnp.ones(3))
    masked = G.forward(params, x, src, dst, cfg,
                       edge_mask=jnp.asarray([1.0, 0.0, 1.0]))
    none_ = G.forward(params, x, src[:2], dst[:2], cfg,
                      edge_mask=jnp.asarray([1.0, 0.0]))
    assert not np.allclose(np.asarray(full), np.asarray(masked))
    np.testing.assert_allclose(np.asarray(masked[5]), np.asarray(full[5]), atol=1e-6)


def test_gin_graph_readout():
    cfg = G.GINConfig(n_layers=2, d_in=4, d_hidden=8, n_classes=3, readout="graph")
    params = G.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    batch = {
        "x": jnp.asarray(rng.standard_normal((20, 4)), jnp.float32),
        "edge_src": jnp.asarray(rng.integers(0, 20, 30), jnp.int32),
        "edge_dst": jnp.asarray(rng.integers(0, 20, 30), jnp.int32),
        "graph_ids": jnp.asarray(np.repeat(np.arange(4), 5), jnp.int32),
        "labels": jnp.asarray([0, 1, 2, 0], jnp.int32),
    }
    loss = G.loss_fn(params, batch, cfg)
    g = jax.grad(G.loss_fn)(params, batch, cfg)
    assert np.isfinite(float(loss))
    for leaf in jax.tree.leaves(g):
        assert np.all(np.isfinite(np.asarray(leaf)))


def test_gin_node_mask_loss():
    cfg = G.GINConfig(n_layers=2, d_in=4, d_hidden=8, n_classes=3)
    params = G.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    batch = {
        "x": jnp.asarray(rng.standard_normal((10, 4)), jnp.float32),
        "edge_src": jnp.asarray(rng.integers(0, 10, 20), jnp.int32),
        "edge_dst": jnp.asarray(rng.integers(0, 10, 20), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 3, 10), jnp.int32),
        "node_mask": jnp.asarray([1.0] * 3 + [0.0] * 7),
    }
    l1 = G.loss_fn(params, batch, cfg)
    batch2 = dict(batch, labels=batch["labels"].at[5].set(
        (batch["labels"][5] + 1) % 3))
    l2 = G.loss_fn(params, batch2, cfg)
    assert abs(float(l1) - float(l2)) < 1e-9  # masked node label irrelevant


# ---------------------------------------------------------------- recsys
def test_dlrm_interaction_count():
    cfg = R.DLRMConfig(rows=tuple([10] * 26))
    assert cfg.interact_dim == 27 * 26 // 2 + 128
    feats = jnp.asarray(np.random.default_rng(0).standard_normal((4, 5, 3)), jnp.float32)
    inter = R.dot_interaction(feats)
    assert inter.shape == (4, 10)
    z = np.einsum("bfd,bgd->bfg", np.asarray(feats), np.asarray(feats))
    li, lj = np.tril_indices(5, -1)
    np.testing.assert_allclose(np.asarray(inter), z[:, li, lj], atol=1e-5)


def test_din_attention_mask():
    """Masked history positions must not influence the output."""
    cfg = R.DINConfig(item_vocab=100, seq_len=8)
    dense = R.din_init_dense(jax.random.key(0), cfg)
    tables = {"items": jax.random.normal(jax.random.key(1), (100, 18)) * 0.1}
    rng = np.random.default_rng(0)
    hist = rng.integers(0, 100, (2, 8))
    batch1 = {
        "hist_ids": jnp.asarray(hist, jnp.int32),
        "hist_mask": jnp.asarray([[1, 1, 1, 0, 0, 0, 0, 0]] * 2, jnp.float32),
        "target_id": jnp.asarray([5, 7], jnp.int32),
    }
    hist2 = hist.copy()
    hist2[:, 5] = (hist2[:, 5] + 13) % 100  # change a masked position
    batch2 = dict(batch1, hist_ids=jnp.asarray(hist2, jnp.int32))
    e1 = R.din_embed_batch(tables, batch1, cfg)
    e2 = R.din_embed_batch(tables, batch2, cfg)
    o1 = R.din_forward_from_emb(dense, e1, batch1, cfg)
    o2 = R.din_forward_from_emb(dense, e2, batch2, cfg)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


def test_dien_augru_attention_effect():
    """AUGRU (DIEN eq. 5): zero attention freezes the hidden state; full
    attention recovers the plain GRU."""
    cfg = R.DINConfig(name="dien", item_vocab=50, seq_len=6, gru_dim=12)
    dense = R.din_init_dense(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.standard_normal((6, 3, 12)), jnp.float32)
    h0 = jnp.asarray(rng.standard_normal((3, 12)), jnp.float32)
    zeros_att = jnp.zeros((6, 3))
    _, final = R._gru_scan(dense["augru"], xs, h0, att=zeros_att)
    np.testing.assert_allclose(np.asarray(final), np.asarray(h0), atol=1e-6)
    ones_att = jnp.ones((6, 3))
    _, final_plain = R._gru_scan(dense["augru"], xs, h0)
    _, final_ones = R._gru_scan(dense["augru"], xs, h0, att=ones_att)
    np.testing.assert_allclose(np.asarray(final_ones), np.asarray(final_plain), atol=1e-6)


def test_two_tower_inbatch_softmax_and_logq():
    cfg = R.TwoTowerConfig(item_vocab=100, embed_dim=8, tower_mlp=(16, 8), user_hist_len=4)
    dense = R.two_tower_init_dense(jax.random.key(0), cfg)
    tables = {"items": jax.random.normal(jax.random.key(1), (100, 8)) * 0.1}
    rng = np.random.default_rng(0)
    batch = {
        "user_ids": jnp.asarray(rng.integers(0, 100, (4, 4)), jnp.int32),
        "user_mask": jnp.ones((4, 4)),
        "item_id": jnp.asarray(rng.integers(0, 100, 4), jnp.int32),
    }
    emb = R.two_tower_embed_batch(tables, batch, cfg)
    l1 = R.two_tower_loss(dense, emb, batch, cfg)
    l2 = R.two_tower_loss(dense, emb, {**batch, "sample_logq": jnp.ones(4)}, cfg)
    assert np.isfinite(float(l1)) and np.isfinite(float(l2))
    # positive logQ on negatives downweights them -> loss strictly decreases
    assert float(l2) < float(l1)
    # capped-pool path must equal the full in-batch softmax when pool >= B
    u, v = R.two_tower_forward_from_emb(dense, emb, batch, cfg)
    logits = np.asarray(u @ v.T, np.float64) / cfg.temperature
    lse = np.log(np.exp(logits).sum(1))
    full = float(np.mean(lse - np.diag(logits)))
    np.testing.assert_allclose(float(l1), full, rtol=1e-4)


def test_two_tower_retrieval_scores():
    cfg = R.TwoTowerConfig(item_vocab=100, embed_dim=8, tower_mlp=(16, 8), user_hist_len=4)
    dense = R.two_tower_init_dense(jax.random.key(0), cfg)
    tables = {"items": jax.random.normal(jax.random.key(1), (100, 8)) * 0.1}
    user_emb = jax.random.normal(jax.random.key(2), (2, 8))
    scores = R.two_tower_score_candidates(dense, tables, user_emb, jnp.arange(50), cfg)
    assert scores.shape == (2, 50)
    # normalized towers: scores bounded by 1
    assert float(jnp.max(jnp.abs(scores))) <= 1.0 + 1e-5


def test_ctr_model_field_attention():
    cfg = R.CTRConfig(rows=100, n_fields=4, nnz_per_instance=6, mlp=(16, 1), attn_heads=2)
    dense = R.ctr_init_dense(jax.random.key(0), cfg)
    tables = {"sparse": jax.random.normal(jax.random.key(1), (100, 64)) * 0.1}
    rng = np.random.default_rng(0)
    batch = {
        "ids": jnp.asarray(rng.integers(0, 100, (3, 6)), jnp.int32),
        "field_ids": jnp.asarray(rng.integers(0, 4, (3, 6)), jnp.int32),
        "mask": jnp.ones((3, 6)),
    }
    emb = R.ctr_embed_batch(tables, batch, cfg)
    assert emb.shape == (3, 4, 64)
    out = R.ctr_forward_from_emb(dense, emb, batch, cfg)
    assert out.shape == (3,) and np.all(np.isfinite(np.asarray(out)))


# ------------------------------------------------- config-knob regressions
def test_gin_train_eps_gates_eps_gradient():
    """``train_eps`` (found dead by repro.analysis) now gates the GIN-0
    self-weight: the forward pass is identical either way, but gradients
    reach eps only when the knob is on."""
    rng = np.random.default_rng(1)
    batch = {
        "x": jnp.asarray(rng.standard_normal((10, 4)), jnp.float32),
        "edge_src": jnp.asarray(rng.integers(0, 10, 20), jnp.int32),
        "edge_dst": jnp.asarray(rng.integers(0, 10, 20), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 3, 10), jnp.int32),
    }
    frozen = G.GINConfig(n_layers=2, d_in=4, d_hidden=8, n_classes=3,
                         train_eps=False)
    learned = G.GINConfig(n_layers=2, d_in=4, d_hidden=8, n_classes=3,
                          train_eps=True)
    params = G.init_params(jax.random.key(0), frozen)
    np.testing.assert_array_equal(
        np.asarray(G.loss_fn(params, batch, frozen)),
        np.asarray(G.loss_fn(params, batch, learned)))
    g_frozen = jax.grad(G.loss_fn)(params, batch, frozen)
    g_learned = jax.grad(G.loss_fn)(params, batch, learned)
    assert np.all(np.asarray(g_frozen["eps"]) == 0.0)
    assert np.any(np.asarray(g_learned["eps"]) != 0.0)


def test_two_tower_spec_declares_mean_and_pools_by_it():
    """``TableSpec.combiner`` (found dead by repro.analysis) now drives the
    user-history pooling: the two-tower bag is a mean over the padded
    history window, not a raw sum."""
    cfg = R.TwoTowerConfig(item_vocab=20, embed_dim=4, tower_mlp=(4,),
                           user_hist_len=3)
    assert R.two_tower_table_specs(cfg)["items"].combiner == "mean"
    rng = np.random.default_rng(0)
    tables = {"items": jnp.asarray(rng.standard_normal((20, 4)), jnp.float32)}
    batch = {
        "user_ids": jnp.asarray(rng.integers(0, 20, (2, 3)), jnp.int32),
        "user_mask": jnp.asarray([[1, 1, 0], [1, 0, 0]], jnp.float32),
        "item_id": jnp.asarray([3, 7], jnp.int32),
    }
    emb = R.two_tower_embed_batch(tables, batch, cfg)
    rows = np.asarray(tables["items"])[np.asarray(batch["user_ids"])]
    manual = (np.asarray(batch["user_mask"])[..., None] * rows).sum(1) / 3
    np.testing.assert_allclose(np.asarray(emb["user"]), manual,
                               rtol=1e-6, atol=1e-6)


def test_ctr_workings_adapter_matches_direct_bag():
    """The working-set adapter pools with the same spec combiner as the
    direct path — bit-exact when the working set is the table itself."""
    rng = np.random.default_rng(2)
    cfg = R.CTRConfig(rows=64, embed_dim=8, n_fields=3, nnz_per_instance=5)
    table = jnp.asarray(rng.standard_normal((64, 8)), jnp.float32)
    batch = {
        "ids": jnp.asarray(rng.integers(0, 64, (4, 5)), jnp.int32),
        "field_ids": jnp.asarray(rng.integers(0, 3, (4, 5)), jnp.int32),
        "mask": jnp.asarray(rng.integers(0, 2, (4, 5)), jnp.float32),
    }
    direct = R.ctr_embed_batch({"sparse": table}, batch, cfg)
    via_ws = R.ctr_embed_from_workings(cfg)(
        {"sparse": table}, {"sparse": batch["ids"].reshape(-1)}, batch)
    np.testing.assert_array_equal(np.asarray(direct), np.asarray(via_ws))
