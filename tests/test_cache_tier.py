"""Cache tier (paper §2.3): CachedBackend keeps hot rows on device over a
host-resident table.

Acceptance properties:
  - with ``cache_rows >= table rows`` the backend is BIT-identical to
    GatherBackend (pulls, pushes, exported tables/accumulator),
  - with a 10%-sized cache on the Zipf(1.05) synthetic CTR stream the
    steady-state hit rate is >= 80%,
  - evicted dirty rows spill value+accumulator back to the host table,
  - cache state checkpoints and resumes bit-exactly through HybridTrainer,
    and resuming cached tables under a different placement (or cache
    geometry) is rejected by the layout guard.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cache_tier import CachedBackend
from repro.core.embedding_backend import GatherBackend, make_backend
from repro.core.kstep import KStepConfig
from repro.core.sparse_optim import SparseAdagrad, SparseAdagradConfig
from repro.data import synthetic as S
from repro.runtime.factory import build_trainer
from repro.runtime.trainer import TrainerConfig


def test_cache_rows_must_cover_capacity():
    cb = CachedBackend(cache_rows=8)
    table = jnp.zeros((32, 2), jnp.float32)
    accum = jnp.zeros((32, 2), jnp.float32)
    with pytest.raises(ValueError, match="cache_rows"):
        cb.pull(table, accum, cb.init_state(table), jnp.zeros(4, jnp.int32), 16)
    with pytest.raises(ValueError, match="cache_rows"):
        CachedBackend(cache_rows=0)
    with pytest.raises(ValueError, match="decay"):
        CachedBackend(cache_rows=8, decay=0.0)


def test_cached_full_mirror_bit_identical_to_gather():
    """cache_rows >= rows: no eviction ever happens and every pull/push is
    bit-identical to the gather placement (the PR acceptance parity)."""
    rng = np.random.default_rng(0)
    rows, dim, cap = 64, 8, 64
    opt = SparseAdagrad(SparseAdagradConfig(lr=0.1))
    gb, cb = GatherBackend(), CachedBackend(cache_rows=rows)

    table = jnp.asarray(rng.standard_normal((rows, dim)), jnp.float32)
    tg, tc = gb.prepare(table), cb.prepare(table)
    sg, sc = gb.init_state(tg), cb.init_state(tc)
    ag = jnp.full((rows, dim), 0.1, jnp.float32)
    ac = jnp.full((rows, dim), 0.1, jnp.float32)

    for step in range(4):
        ids = jnp.asarray(rng.integers(0, rows, 50), jnp.int32)
        wg, tg, ag, sg = gb.pull(tg, ag, sg, ids, cap)
        wc, tc, ac, sc = cb.pull(tc, ac, sc, ids, cap)
        np.testing.assert_array_equal(np.asarray(wg.uids), np.asarray(wc.uids))
        np.testing.assert_array_equal(
            np.asarray(wg.inverse), np.asarray(wc.inverse)
        )
        np.testing.assert_array_equal(np.asarray(wg.rows), np.asarray(wc.rows))
        slot_g = rng.standard_normal((50, dim)).astype(np.float32)
        row_g = np.zeros((cap, dim), np.float32)
        np.add.at(row_g, np.asarray(wg.inverse), slot_g)
        row_g = jnp.asarray(row_g)
        tg, ag, sg = gb.push(tg, ag, sg, wg, row_g, opt)
        tc, ac, sc = cb.push(tc, ac, sc, wc, row_g, opt)
        # flush on a COPY each step: host tables must match gather exactly
        ft, fa, _ = cb.flush(tc, ac, sc)
        np.testing.assert_array_equal(np.asarray(gb.export(tg)),
                                      np.asarray(cb.export(ft)))
        np.testing.assert_array_equal(np.asarray(ag), np.asarray(fa))
    assert float(sc.evictions) == 0.0
    assert float(sc.bytes_d2h) == 0.0   # nothing ever spilled


def test_cached_eviction_spills_dirty_rows():
    """A full cache turnover must write the dirty rows (value + accumulator)
    back to the host table before the slots are reused."""
    rows, dim, cap = 8, 2, 4
    opt = SparseAdagrad(SparseAdagradConfig(lr=0.5))
    cb = CachedBackend(cache_rows=cap, decay=1.0)
    gb = GatherBackend()

    table0 = jnp.arange(rows * dim, dtype=jnp.float32).reshape(rows, dim)
    accum0 = jnp.full((rows, dim), 0.1, jnp.float32)
    tc, ac, sc = table0, accum0, cb.init_state(table0)
    tg, ag, sg = table0, accum0, gb.init_state(table0)

    ids_a = jnp.asarray([0, 1, 2, 3], jnp.int32)
    grads = jnp.ones((cap + 1, dim), jnp.float32)

    wc, tc, ac, sc = cb.pull(tc, ac, sc, ids_a, cap)
    tc, ac, sc = cb.push(tc, ac, sc, wc, grads, opt)
    wg, tg, ag, sg = gb.pull(tg, ag, sg, ids_a, cap)
    tg, ag, sg = gb.push(tg, ag, sg, wg, grads, opt)
    # write-through to cache only: host rows 0..3 still pristine
    np.testing.assert_array_equal(np.asarray(tc), np.asarray(table0))
    assert bool(jnp.all(sc.dirty))

    # second batch misses on 4 fresh ids -> evicts all 4 slots -> spills
    ids_b = jnp.asarray([4, 5, 6, 7], jnp.int32)
    wc, tc, ac, sc = cb.pull(tc, ac, sc, ids_b, cap)
    assert float(sc.evictions) == 4.0
    assert float(sc.bytes_d2h) == 4 * dim * (4 + 4)
    np.testing.assert_array_equal(np.asarray(tc[:4]), np.asarray(tg[:4]))
    np.testing.assert_array_equal(np.asarray(ac[:4]), np.asarray(ag[:4]))

    # pulling the spilled ids again re-fetches the pushed values from host
    wc2, tc, ac, sc = cb.pull(tc, ac, sc, ids_a, cap)
    np.testing.assert_array_equal(np.asarray(wc2.rows[:cap]),
                                  np.asarray(tg[:4]))


def test_cached_hit_rate_zipf_10pct_cache():
    """PR acceptance: >= 80% steady-state hit rate with a 10%-sized cache on
    the Zipf(1.05) synthetic CTR stream.

    Hit rate counts id LOOKUPS served without a host fetch: a fetched row
    serves every same-batch duplicate of its id, so
    ``hit_rate = 1 - fetched / lookups``.
    """
    rows, dim, cap = 50_000, 8, 4096
    C = rows // 10
    cb = CachedBackend(cache_rows=C, decay=0.95)
    table = jnp.zeros((rows, dim), jnp.float32)
    accum = jnp.zeros((rows, dim), jnp.float32)
    state = cb.init_state(table)

    pull = jax.jit(functools.partial(cb.pull, capacity=cap))
    gen = S.ctr_batches(seed=7, batch=512, rows=rows, n_fields=8, nnz=20,
                        zipf_a=1.05)
    warm_lookups = warm_fetched = 0.0
    for step in range(60):
        ids = jnp.asarray(next(gen)["ids"].reshape(-1))
        ws, table, accum, state = pull(table, accum, state, flat_ids=ids)
        assert int(ws.n_dropped) == 0   # capacity covers the working set
        if step == 39:                  # steady state: measure the last 20
            warm_lookups = float(state.lookups)
            warm_fetched = float(state.fetched)
    hit_rate = 1.0 - (float(state.fetched) - warm_fetched) / (
        float(state.lookups) - warm_lookups
    )
    assert hit_rate >= 0.80, f"steady-state hit rate {hit_rate:.3f}"
    # the cold start must have fetched at least a cache-full of rows
    assert float(state.fetched) >= C


def _cached_tcfg(ckpt_dir=None, cache_rows=4096, capacity=4096):
    return TrainerConfig(
        n_pod=2, kstep=KStepConfig(lr=1e-3, k=5, b1=0.0),
        sparse=SparseAdagradConfig(lr=0.5, initial_accumulator=0.01),
        placement="cached", capacity=capacity, cache_rows=cache_rows,
        ckpt_dir=ckpt_dir, ckpt_every=10, ckpt_async=False, log_every=5,
    )


def _ctr_gen(seed=9):
    return S.ctr_batches(seed=seed, batch=256, rows=20000, n_fields=8,
                         nnz=20, zipf_a=1.05)


def test_factory_rejects_undersized_cache():
    """An EXPLICIT cache_rows below the working-set capacity is an error,
    not a silent clamp — a cache-size experiment must run with the cache it
    asked for (cache_rows=None defaults to the capacity floor)."""
    with pytest.raises(ValueError, match="cache_rows"):
        build_trainer("baidu-ctr", _cached_tcfg(cache_rows=1024))
    tr = build_trainer("baidu-ctr", _cached_tcfg(cache_rows=None))
    assert tr.engine.backend.cache_rows == tr.engine.capacity


def test_cached_trainer_history_metrics():
    """fit() surfaces cache_hit_rate/evictions next to overflow_dropped —
    PER LOGGING INTERVAL (so history shows the current window, not a
    whole-run blend), with cumulative values under ``*_total`` keys."""
    tr = build_trainer("baidu-ctr", _cached_tcfg())
    hist = tr.fit(_ctr_gen(), 10)
    assert tr.step_num == 10
    for rec in hist:
        assert np.isfinite(rec["loss"])
        assert 0.0 <= rec["cache_hit_rate"] <= 1.0
        assert 0.0 <= rec["cache_hit_rate_total"] <= 1.0
        assert rec["evictions"] >= 0
        assert rec["overflow_dropped"] == 0
        assert rec["overflow_dropped_total"] == 0
    # a 4096-row cache over a 20k-row Zipf table must evict and still hit
    assert hist[-1]["evictions"] > 0
    assert hist[-1]["cache_hit_rate"] > 0.5
    assert hist[-1]["cache_bytes_h2d"] > 0
    # the interval deltas tile the run exactly: their sums equal the totals
    assert sum(r["evictions"] for r in hist) == hist[-1]["evictions_total"]
    assert sum(r["cache_bytes_h2d"] for r in hist) == \
        hist[-1]["cache_bytes_h2d_total"]
    # warm-up is visible only in the per-interval view: the last window's
    # hit rate beats the whole-run blend (which drags the cold start along)
    assert hist[-1]["cache_hit_rate"] >= hist[-1]["cache_hit_rate_total"]
    # sparse_metrics is a pure read unless the fit logger advances it:
    # polling twice returns the same window, and fit's records stay whole
    assert tr.sparse_metrics() == tr.sparse_metrics()


def test_zero_lookup_interval_reports_zero_hit_rate():
    """An idle logging window (no train steps, or predict-only traffic —
    predict discards its cache side effects) must report cache_hit_rate
    0.0, not the fake perfect 1.0 that ``1 - 0/max(0, 1)`` produced."""
    from repro.core.embedding_engine import EmbeddingEngine

    zero = {"lookups": 0.0, "fetched": 0.0, "evictions": 0.0,
            "bytes_h2d": 0.0, "bytes_d2h": 0.0}
    assert EmbeddingEngine.derive_cache_stats(zero)["cache_hit_rate"] == 0.0
    assert EmbeddingEngine.derive_cache_stats({}) == {}

    tr = build_trainer("baidu-ctr", _cached_tcfg())
    m = tr.sparse_metrics()                    # nothing trained yet: idle
    assert m["cache_hit_rate"] == 0.0
    assert m["cache_hit_rate_total"] == 0.0
    gen = _ctr_gen()
    for _ in range(2):
        tr.predict(next(gen))                  # predict-only stays idle
    m = tr.sparse_metrics()
    assert m["cache_hit_rate"] == 0.0
    # a real training window reports a real (nonzero-lookup) rate again
    tr.train_step(next(gen))
    assert 0.0 <= tr.sparse_metrics()["cache_hit_rate"] <= 1.0


def test_cached_checkpoint_resume_bitexact(tmp_path):
    """Crash/resume with the cache tier: host tables + device-cache state
    roundtrip so the resumed run is bit-identical to an uninterrupted one."""
    d = str(tmp_path)
    gen = _ctr_gen()
    batches = [next(gen) for _ in range(30)]

    t_ref = build_trainer("baidu-ctr", _cached_tcfg())
    for b in batches:
        t_ref.train_step(b)

    t_a = build_trainer("baidu-ctr", _cached_tcfg(ckpt_dir=d))
    for b in batches[:20]:
        t_a.train_step(b)
    del t_a  # crash after step 20 (ckpt_every=10 -> ckpt at 20 exists)

    t_b = build_trainer("baidu-ctr", _cached_tcfg(ckpt_dir=d))
    assert t_b.resume() and t_b.step_num == 20
    for b in batches[20:]:
        t_b.train_step(b)

    for a, b_ in zip(jax.tree.leaves(t_ref.tables), jax.tree.leaves(t_b.tables)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))
    for a, b_ in zip(jax.tree.leaves(t_ref.backend_state),
                     jax.tree.leaves(t_b.backend_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))
    for a, b_ in zip(jax.tree.leaves(t_ref.dense), jax.tree.leaves(t_b.dense)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-6)


def test_cached_resume_rejects_other_placements(tmp_path):
    """Cached-run checkpoints hold host tables that are stale wherever rows
    sat dirty in the device cache — resuming them under gather (or under a
    different cache geometry) must fail loudly."""
    d = str(tmp_path)
    t_a = build_trainer("baidu-ctr", _cached_tcfg(ckpt_dir=d))
    gen = _ctr_gen()
    for _ in range(10):
        t_a.train_step(next(gen))

    gather_cfg = _cached_tcfg(ckpt_dir=d)
    gather_cfg.placement = "gather"
    t_gather = build_trainer("baidu-ctr", gather_cfg)
    with pytest.raises(ValueError, match="physical"):
        t_gather.resume()

    t_resized = build_trainer(
        "baidu-ctr", _cached_tcfg(ckpt_dir=d, cache_rows=8192)
    )
    with pytest.raises(ValueError, match="physical"):
        t_resized.resume()


def test_gather_resume_rejects_cached(tmp_path):
    """The guard works in the other direction too: a gather checkpoint must
    not silently seed a cached run's cold cache state."""
    d = str(tmp_path)
    cfg = _cached_tcfg(ckpt_dir=d)
    cfg.placement = "gather"
    t_a = build_trainer("baidu-ctr", cfg)
    gen = _ctr_gen()
    for _ in range(10):
        t_a.train_step(next(gen))
    t_b = build_trainer("baidu-ctr", _cached_tcfg(ckpt_dir=d))
    with pytest.raises(ValueError, match="physical"):
        t_b.resume()
