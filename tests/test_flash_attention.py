"""Pallas flash attention vs the dense softmax oracle (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_pallas


def ref_attn(q, k, v, causal):
    BH, S, hd = q.shape
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (hd ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((q.shape[1], k.shape[1]), bool))
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("BH,S,hd,bq,bkv", [
    (2, 64, 16, 16, 16),
    (4, 128, 32, 32, 64),
    (1, 256, 64, 64, 32),
])
def test_flash_matches_dense(dtype, causal, BH, S, hd, bq, bkv):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((BH, S, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((BH, S, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((BH, S, hd)), dtype)
    out = flash_attention_pallas(q, k, v, causal=causal,
                                 block_q=bq, block_kv=bkv, interpret=True)
    expect = ref_attn(q, k, v, causal)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol * 10, rtol=tol)


def test_flash_gqa_wrapper_matches_model_attention():
    """flash (with GQA head-broadcast) == the model's _sdpa_dense."""
    from repro.models import transformer as T
    cfg = T.TransformerConfig(n_layers=1, d_model=64, n_heads=4, n_kv_heads=2,
                              d_ff=64, vocab=32, dtype=jnp.float32)
    rng = np.random.default_rng(1)
    B, S, H, Kv, hd = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Kv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Kv, hd)), jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32)
    expect = T._sdpa_dense(cfg, 0, q, k, v, pos, pos)
    # GQA flatten: q -> (B*H, S, hd); k/v repeat per group
    G = H // Kv
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, S, hd)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, S, hd)
    out = flash_attention_pallas(qf, kf, vf, causal=True,
                                 block_q=16, block_kv=16, interpret=True)
    out = out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-4)
