import os

# Tests must see the single real CPU device (the 512-device override is
# exclusively for the dry-run).  Kernel tests opt into interpret mode.
os.environ.setdefault("REPRO_KERNEL_INTERPRET", "1")
