"""System-level behaviour: the paper's end-to-end claims at CPU scale.

These are the highest-level assertions in the suite — the claims the
framework exists to deliver:
 1. k-step merging preserves CTR accuracy (paper Fig. 9) while cutting
    cross-pod communication by ~1/k (paper Fig. 10 — byte accounting is
    asserted in benchmarks/, wall-clock on the host mesh).
 2. The hybrid optimizer split (dense k-step Adam + sparse every-step
    AdaGrad) trains the paper's CTR model end to end.
 3. The working-set pull path is numerically identical to dense training.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.embedding_engine import pull_working_set
from repro.core.kstep import KStepConfig
from repro.data import synthetic as S
from repro.models import recsys as R
from repro.runtime.metrics import auc
from tests.test_trainer_integration import CTR_CFG, ctr_trainer, run_online


def test_paper_claim_kstep_auc_parity_across_k():
    """AUC(k in {5, 20}) within noise of AUC(k=1) — Fig. 9's claim."""
    aucs = {}
    for n_pod, k in [(1, 1), (2, 5), (4, 20)]:
        aucs[k] = run_online(ctr_trainer(n_pod=n_pod, k=k), steps=100)
    assert aucs[1] > 0.70
    for k in (5, 20):
        assert abs(aucs[k] - aucs[1]) < 0.04, aucs


def test_working_set_path_equals_dense_path():
    """Algorithm 1's pull/push is exact, not approximate."""
    rng = jax.random.key(0)
    cfg = R.CTRConfig(rows=1000, n_fields=4, nnz_per_instance=10, mlp=(16, 1))
    dense = R.ctr_init_dense(rng, cfg)
    table = jax.random.normal(rng, (1000, 64)) * 0.1
    b = next(S.ctr_batches(seed=0, batch=32, rows=1000, n_fields=4, nnz=10))
    b = {k: jnp.asarray(v) for k, v in b.items()}

    def loss_dense(t):
        emb = R.ctr_embed_batch({"sparse": t}, b, cfg)
        return R.pointwise_loss(R.ctr_forward_from_emb(dense, emb, b, cfg), b["label"])

    uids, inv = pull_working_set(b["ids"].reshape(-1), 512)

    def loss_ws(working):
        B, nnz = b["ids"].shape
        seg = (jnp.arange(B, dtype=jnp.int32)[:, None] * cfg.n_fields
               + b["field_ids"]).reshape(-1)
        emb = jnp.take(working, inv, axis=0) * b["mask"].reshape(-1)[:, None]
        bags = jax.ops.segment_sum(emb, seg, num_segments=B * cfg.n_fields)
        emb = bags.reshape(B, cfg.n_fields, cfg.embed_dim)
        return R.pointwise_loss(R.ctr_forward_from_emb(dense, emb, b, cfg), b["label"])

    gd = jax.grad(loss_dense)(table)
    gw = jax.grad(loss_ws)(table[uids])
    scattered = jnp.zeros_like(table).at[uids].add(gw)
    np.testing.assert_allclose(np.asarray(gd), np.asarray(scattered), atol=1e-6)


def test_hybrid_sparse_dense_split_respected():
    """Dense params merge on k-boundaries; tables update every step."""
    tr = ctr_trainer(n_pod=2, k=3)
    gen = S.ctr_batches(seed=2, batch=256, rows=CTR_CFG.rows,
                        n_fields=CTR_CFG.n_fields, nnz=CTR_CFG.nnz_per_instance)
    t0 = np.asarray(jax.tree.leaves(tr.tables)[0]).copy()
    d0 = np.asarray(jax.tree.leaves(tr.dense)[0]).copy()
    tr.train_step(next(gen))  # step 1: local
    t1 = np.asarray(jax.tree.leaves(tr.tables)[0])
    d1 = np.asarray(jax.tree.leaves(tr.dense)[0])
    assert not np.allclose(t0, t1), "sparse table must update at every step"
    assert not np.allclose(d0, d1), "dense params must update locally"
    # replicas diverge until the merge at step 3
    assert not np.allclose(d1[0], d1[1])
    tr.train_step(next(gen))
    tr.train_step(next(gen))  # merge
    d3 = np.asarray(jax.tree.leaves(tr.dense)[0])
    np.testing.assert_allclose(d3[0], d3[1], atol=1e-7)
