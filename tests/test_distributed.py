"""Distributed semantics on a real multi-device (8 host CPU) mesh.

jax locks the device count at first init, so these run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("REPRO_KERNEL_INTERPRET", None)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=600,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_podded_kstep_on_mesh_matches_single_device():
    """The same k-step trajectory must be produced on a (2,2,2) device mesh
    (pod-sharded replicas + real collectives) and on one device."""
    run_sub("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.kstep import KStepAdam, KStepConfig, pod_replicate
from repro.launch.mesh import make_host_mesh

mesh = make_host_mesh(2, 2, 2)
params = {"w": jnp.arange(32.0).reshape(4, 8) / 10.0}
pp = pod_replicate(params, 2)

def grads(i):
    rng = np.random.default_rng(i)
    return {"w": jnp.asarray(rng.standard_normal((2, 4, 8)), jnp.float32)}

# reference: single device
opt_ref = KStepAdam(KStepConfig(lr=0.05, k=2), n_pod=2)
st = opt_ref.init(pp); p_ref = pp
for i in range(4):
    p_ref, st = opt_ref.step(p_ref, grads(i), st, merge=((i+1) % 2 == 0))

# mesh: pod-sharded replicas, two_phase merge with real collectives
opt = KStepAdam(KStepConfig(lr=0.05, k=2, merge="two_phase"), n_pod=2, mesh=mesh)
sh = NamedSharding(mesh, P("pod", None, None))
pm = jax.tree.map(lambda x: jax.device_put(x, sh), pp)
stm = opt.init(pm)
stepm = jax.jit(lambda p, g, s, m: opt.step(p, g, s, merge=m), static_argnums=3)
for i in range(4):
    g = jax.tree.map(lambda x: jax.device_put(x, sh), grads(i))
    pm, stm = stepm(pm, g, stm, (i+1) % 2 == 0)

for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(pm)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
print("OK")
""")


def test_two_phase_reduces_dcn_bytes():
    """The DCN (pod-crossing) payload of a two-phase merge must be ~1/|inner|
    of the flat merge's for replicated-in-pod params."""
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import merge as merge_lib
from repro.launch.mesh import make_host_mesh
from repro.launch.hlo_analysis import collect_collectives

mesh = make_host_mesh(2, 2, 2)
x = {"w": jnp.ones((2, 256, 256), jnp.float32)}
sh = NamedSharding(mesh, P("pod", None, None))
xa = jax.tree.map(lambda v: jax.ShapeDtypeStruct(v.shape, v.dtype), x)

def flat(v): return merge_lib.flat_mean(v)
def two(v): return merge_lib.two_phase_mean(v, mesh)

res = {}
for name, fn in [("flat", flat), ("two_phase", two)]:
    comp = jax.jit(fn, in_shardings=(jax.tree.map(lambda _: sh, x),)).lower(xa).compile()
    st = collect_collectives(comp.as_text(), devices_per_pod=4)
    res[name] = st.dcn_bytes
print("flat", res["flat"], "two_phase", res["two_phase"])
assert res["two_phase"] > 0
assert res["two_phase"] <= res["flat"] / 2, res
""")
    assert "flat" in out


def test_int8_merge_wire_dtype():
    """The cross-pod reduction of the int8_ef merge must run on int8."""
    run_sub("""
import jax, jax.numpy as jnp, re
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import merge as merge_lib
from repro.launch.mesh import make_host_mesh

mesh = make_host_mesh(2, 2, 2)
x = {"w": jnp.ones((2, 4096), jnp.float32)}
ef = {"w": jnp.zeros((2, 4096), jnp.float32)}
sh = NamedSharding(mesh, P("pod", None))
fn = lambda v, e: merge_lib.int8_ef_mean(v, e, mesh)[0]
comp = jax.jit(fn).lower(
    jax.tree.map(lambda v: jax.ShapeDtypeStruct(v.shape, v.dtype), x),
    jax.tree.map(lambda v: jax.ShapeDtypeStruct(v.shape, v.dtype), ef),
).compile()
txt = comp.as_text()
int8_collectives = [l for l in txt.splitlines()
                    if any(k in l for k in ("all-reduce", "all-gather", "reduce-scatter"))
                    and "=" in l and "s8[" in l.split("=", 1)[1][:40]]
assert int8_collectives, "no int8 collective found:" + txt[:2000]
print("OK", len(int8_collectives))
""")


def test_sharded_hybrid_train_step_runs():
    """A full hybrid (dense k-step + sparse working-set) step executes on a
    (2,2,2) mesh with row-sharded tables and produces finite outputs."""
    run_sub("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.kstep import KStepConfig
from repro.launch.mesh import make_host_mesh
from repro.launch import cells as cells_lib

mesh = make_host_mesh(2, 2, 2)
cell = cells_lib.build_cell("baidu-ctr", "train_mb1k", mesh,
                            KStepConfig(k=4, merge="two_phase"), smoke=True)
step = cell.steps["train_merge"]
from repro.sharding.specs import named_shardings
in_sh = tuple(named_shardings(s, mesh) for s in step.in_specs)
fn = jax.jit(step.fn, in_shardings=in_sh)
rng = np.random.default_rng(0)
def materialize(a, s):
    arr = jnp.asarray((rng.random(a.shape) * 10).astype(a.dtype)) if a.dtype != jnp.int32 \
        else jnp.asarray(rng.integers(0, 100, a.shape), jnp.int32)
    return jax.device_put(arr, s)
args = jax.tree.map(materialize, step.args, in_sh)
out = fn(*args)
for leaf in jax.tree.leaves(out):
    assert np.all(np.isfinite(np.asarray(leaf, dtype=np.float64)))
print("OK")
""")
